"""Multi-factor batched serving: FactorBank vs looped single sessions.

Quantifies the FactorBank tentpole (DESIGN.md Sec. 9).  The workload
is the paper's Sec. I consumer pattern — M triangular factors served
simultaneously (per-layer KFAC preconditioners, per-tenant models) —
solved two ways against identical factors and right-hand sides:

  looped   — M independent single-factor solves at steady state, one
             dispatch per factor per round (the PR-1/2 serving model —
             the fused program with phase 1 inside, driven through the
             unbanked compiled-solver path — applied M times, at its
             own tuned n0; kept on that path so the comparison
             semantics match the recorded baseline).
  bank     — ONE BatchedTrsmSession over a FactorBank: phase 1 (the
             Diagonal-Inverter) ran once at admission, and the
             steady-state program maps the unrolled sweep over the
             factor axis ("vmap": every sweep step is an M-wide
             batched GEMM; "scan": factors serialized inside the same
             single program).  The bank runs at its own serving-tuned
             n0 (tuning.serving_n0 — larger, because the inversion
             term left the per-solve cost), plus an n0 = n row: the
             full-inversion end of the same knob (m = 1, one batched
             GEMM per wave).

The bank's win has three parts: M-1 dispatch overheads disappear, the
hoisted phase 1 stops being re-paid every solve, and the serving n0
re-tunes upward once inversion is free.  The run ASSERTS the
acceptance bar — >= 5x lower per-solve latency at M = 16, n = 256 on
one device — and the zero-transfer / zero-retrace steady state of the
bank for EVERY precision preset (TRACE_COUNTS + jax.transfer_guard,
the session invariants extended to banks).

Run standalone or via ``python -m benchmarks.run bank``.
"""

from __future__ import annotations

import time

import numpy as np

M, N, K, N0 = 16, 256, 16, 32
PRESETS = ["fp32", "bf16", "bf16_refine", "fp64_refine"]


def _time_per_round(fn, reps: int, passes: int = 3) -> float:
    """Min-of-passes per-round time (the standard timeit hygiene: the
    minimum is the least noise-contaminated estimate of the program's
    cost on a busy host)."""
    import jax
    fn()                                    # settle any lazy first-call
    best = float("inf")
    for _ in range(passes):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def _factors(rng, dtype=np.float32):
    return np.stack([
        np.tril(rng.standard_normal((N, N))) + N * np.eye(N)
        for _ in range(M)]).astype(dtype)


def _assert_bank_steady_state(report):
    """Zero transfers / zero retraces for the bank, every preset."""
    import jax
    from repro import api
    from repro.core import session

    x64_was = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)   # fp64_refine needs it
    try:
        grid = api.make_trsm_mesh(1, 1)
        rng = np.random.default_rng(1)
        rows = {}
        for preset in PRESETS:
            dt = np.float64 if preset == "fp64_refine" else np.float32
            sess = api.Solver.from_factors(_factors(rng, dt), grid,
                                           method="inv",
                                           precision=preset)
            key = sess.program_for(K).key   # program built, not yet traced
            before = session.TRACE_COUNTS[key]
            sess.warmup(K)
            traces = session.TRACE_COUNTS[key]
            assert traces == before + 1, (preset, before, traces)
            Bs = [sess.place_rhs(rng.standard_normal((M, N, K)))
                  for _ in range(3)]
            with jax.transfer_guard("disallow"):
                for b in Bs:
                    sess.solve(b)
            assert session.TRACE_COUNTS[key] == traces, preset
            rows[preset] = "ok"
            report(f"steady state [{preset}]: 1 trace, 0 transfers, "
                   f"0 retraces over {len(Bs)} banked rounds "
                   f"({len(Bs) * M} solves)")
        return rows
    finally:
        jax.config.update("jax_enable_x64", x64_was)


def run(report):
    import jax
    from repro import api
    from repro.core import precision as preclib
    from repro.core.solver import SolveSpec, solver_for

    grid = api.make_trsm_mesh(1, 1)
    rng = np.random.default_rng(0)
    Ls = _factors(rng)
    reps, passes = 20, 3
    nfeeds = reps * passes + 2

    # looped single-factor solves: M dispatches per round, steady
    # state, via the PR-1/2 serving model — the UNBANKED fused program
    # (phase 1 re-runs inside every solve), factors distributed once
    spec = SolveSpec(n=N, k=K, grid=grid,
                     policy=preclib.resolve(None, np.float32),
                     method="inv", n0=N0)
    prog = solver_for(spec)
    factors = [prog.prep(L) for L in Ls]
    feeds = [[jax.device_put(
        rng.standard_normal((N, K)).astype(np.float32),
        prog.rhs_sharding) for _ in Ls] for _ in range(nfeeds)]
    for b in feeds[-1]:
        prog.solve_donating(factors[0], b)          # warm
    it = iter(feeds[:-1])

    def looped_round():
        batch = next(it)
        return [prog.solve_donating(f, b)
                for f, b in zip(factors, batch)][-1]

    with jax.transfer_guard("disallow"):
        t_loop = _time_per_round(looped_round, reps, passes)

    rows = []
    cases = [("vmap", None), ("scan", None), ("vmap", N)]
    for map_mode, n0 in cases:
        bsess = api.Solver.from_factors(Ls, grid, method="inv", n0=n0,
                                        dtype=np.float32,
                                        map_mode=map_mode).warmup(K)
        bfeeds = [bsess.place_rhs(
            rng.standard_normal((M, N, K)).astype(np.float32))
            for _ in range(nfeeds)]
        bit = iter(bfeeds)
        with jax.transfer_guard("disallow"):
            t_bank = _time_per_round(lambda: bsess.solve(next(bit)),
                                     reps, passes)
        speedup = t_loop / t_bank
        rows.append(dict(map_mode=map_mode, M=M, n=N, k=K,
                         looped_n0=N0, bank_n0=bsess.n0,
                         looped_ms_per_solve=t_loop / M * 1e3,
                         bank_ms_per_solve=t_bank / M * 1e3,
                         speedup=speedup))
        report(f"M={M} n={N} k={K} [{map_mode:4s} n0={bsess.n0:3d}]: "
               f"looped(n0={N0}) {t_loop / M * 1e3:7.3f} ms/solve | "
               f"bank {t_bank / M * 1e3:7.3f} ms/solve | "
               f"{speedup:5.1f}x")

    best = max(r["speedup"] for r in rows)
    assert best >= 5.0, (
        f"acceptance: bank must be >= 5x per solve vs looped sessions, "
        f"got {best:.1f}x")

    steady = _assert_bank_steady_state(report)
    return dict(latency=rows, steady_state=steady)


if __name__ == "__main__":
    run(print)
