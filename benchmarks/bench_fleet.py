"""Mixed-order serving: SolverFleet buckets vs one bank per order.

Quantifies the fleet tier (DESIGN.md Sec. 12).  The workload is the
paper's consumer pattern at fleet scale — a tenant model emits a
SPECTRUM of factor orders (the KFAC Kronecker spectrum of
``optim.kfac_ca``), and every serving wave carries one solve per
order.  Two ways to serve it:

  per-order — the PR-5 world: one width-1 capacity bank + SolveServer
              per distinct order, so a mixed-order wave pays one
              program dispatch PER ORDER, however small the factors.
  fleet     — ``plan_fleet`` buckets the manifest a priori (pure cost
              model arithmetic: orders merge into a shared bucket via
              zero-padding exactly when the modeled padding overhead
              is bought back by the saved dispatch), and the fleet
              server packs the whole mixed-order wave into one panel
              per BUCKET.

The run ASSERTS the acceptance bar — the fleet serves the mixed-order
wave in >= 3x fewer program dispatches than per-order banks at
matched residual quality (both sides meet the same relres bar; the
padded lanes' leading blocks are bit-identical to unpadded solves) —
and reports the measured per-wave wall time of both sides.

Each run also appends a trajectory point to the committed
``benchmarks/BENCH_fleet.json``.  Set ``BENCH_FLEET_SMOKE=1`` (the
weekly CI job does) for a reduced-rep run that skips the trajectory
write.

Run standalone or via ``python -m benchmarks.run fleet``.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

# six distinct orders, every one small enough that sharing one padded
# bucket is modeled cheaper than its own dispatch — the regime the
# planner's merge rule targets (large orders split; see
# launch.dryrun --fleet for that side)
ORDERS = [192, 160, 128, 96, 64, 32]
K = 8
RELRES_BAR = 1e-4
SMOKE = bool(int(os.environ.get("BENCH_FLEET_SMOKE", "0")))
TRAJECTORY = os.path.join(os.path.dirname(__file__), "BENCH_fleet.json")


def _tri(d, rng):
    return (np.tril(rng.standard_normal((d, d)))
            + d * np.eye(d)).astype(np.float32)


def _relres(L, x, b):
    x = np.asarray(x, np.float64)
    return float(np.linalg.norm(L.astype(np.float64) @ x - b)
                 / np.linalg.norm(b))


def _time_waves(serve_wave, ready, waves, passes):
    import jax
    best = float("inf")
    for _ in range(passes):
        t0 = time.perf_counter()
        for _ in range(waves):
            out = serve_wave()
        jax.block_until_ready(ready(out))
        best = min(best, (time.perf_counter() - t0) / waves)
    return best


def run(report):
    from repro import api

    grid = api.make_trsm_mesh(1, 1)
    rng = np.random.default_rng(0)
    factors = {d: _tri(d, rng) for d in ORDERS}
    waves, passes = (3, 2) if SMOKE else (10, 3)

    # --- fleet: planner-chosen buckets, one dispatch per bucket ---
    plan = api.plan_fleet({d: 1 for d in ORDERS}, grid, k=K)
    fleet = api.SolverFleet(grid, plan)
    for d, L in factors.items():
        fleet.admit(L, tenant="m", tag=d)
    fserver = api.SolveServer(fleet, panel_k=K).warmup()
    reqs = {d: rng.standard_normal((d, 1)).astype(np.float32)
            for d in ORDERS}

    def fleet_wave():
        for d, b in reqs.items():
            fserver.submit(b, tenant="m", tag=d)
        return fserver.drain()

    fleet_out = fleet_wave()                       # settle the programs
    fleet_waves_before = fserver.waves_solved
    fleet_wave()
    fleet_dispatches = fserver.waves_solved - fleet_waves_before
    t_fleet = _time_waves(
        fleet_wave, lambda out: out[("m", ORDERS[0])][0], waves, passes)

    # --- per-order: one width-1 bank + server per distinct order ---
    servers = {}
    for d, L in factors.items():
        bank = api.FactorBank(grid, d, capacity=1, dtype=np.float32)
        bank.admit(L)
        servers[d] = api.SolveServer(
            api.Solver.from_bank(bank), panel_k=K).warmup()

    def split_wave():
        for d, b in reqs.items():
            servers[d].submit(b)
        return {d: s.drain()[0][0] for d, s in servers.items()}

    split_out = split_wave()
    split_before = sum(s.waves_solved for s in servers.values())
    split_wave()
    split_dispatches = sum(s.waves_solved
                           for s in servers.values()) - split_before
    t_split = _time_waves(
        split_wave, lambda out: out[ORDERS[0]], waves, passes)

    # --- matched residual quality on the SAME requests ---
    worst_fleet, worst_split = 0.0, 0.0
    for d in ORDERS:
        b = np.asarray(reqs[d], np.float64)
        worst_fleet = max(worst_fleet,
                          _relres(factors[d], fleet_out[("m", d)][0], b))
        worst_split = max(worst_split,
                          _relres(factors[d], split_out[d], b))
    assert worst_fleet < RELRES_BAR and worst_split < RELRES_BAR, \
        (worst_fleet, worst_split)

    ratio = split_dispatches / fleet_dispatches
    report(f"{len(ORDERS)} orders {ORDERS}: fleet "
           f"{len(plan.buckets)} bucket(s), {fleet_dispatches} "
           f"dispatch(es)/wave vs per-order {split_dispatches} "
           f"({ratio:.1f}x fewer); wave {t_fleet * 1e3:7.3f} ms vs "
           f"{t_split * 1e3:7.3f} ms ({t_split / t_fleet:4.1f}x)")
    report(f"matched relres: fleet {worst_fleet:.2e} | per-order "
           f"{worst_split:.2e} (bar {RELRES_BAR:.0e})")
    assert ratio >= 3.0, (
        f"acceptance: the fleet must serve the mixed-order wave in "
        f">= 3x fewer dispatches than per-order banks, got {ratio:.1f}x")

    point = dict(orders=ORDERS, buckets=len(plan.buckets),
                 fleet_dispatches=fleet_dispatches,
                 split_dispatches=split_dispatches,
                 dispatch_ratio=round(ratio, 2),
                 fleet_ms_per_wave=round(t_fleet * 1e3, 3),
                 split_ms_per_wave=round(t_split * 1e3, 3),
                 relres_fleet=worst_fleet, relres_split=worst_split)
    if not SMOKE:
        _record_trajectory(point)
        report(f"trajectory point appended to {TRAJECTORY}")
    return point


def _record_trajectory(point):
    traj = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY) as f:
            traj = json.load(f).get("trajectory", [])
    date = time.strftime("%Y-%m-%d")
    traj = [p for p in traj if p.get("date") != date] + \
        [dict(date=date, **point)]
    with open(TRAJECTORY, "w") as f:
        json.dump({"bench": "fleet", "trajectory": traj}, f, indent=1)
        f.write("\n")


if __name__ == "__main__":
    run(print)
