"""TPU-motivation benchmark (DESIGN.md Sec. 2): MXU-eligible flop share.

On TPU the substitution base case is VPU-serial (no MXU work); the
paper's inversion swap turns those flops into batched GEMMs.  This
bench counts, for the It-Inv-TRSM schedule at varying n0:

  * GEMM flops (solve multiplies + trailing updates + inversion
    doubling-level matmuls) — MXU-eligible,
  * substitution flops (what the baseline spends serially),

and reports the MXU-eligible fraction plus the paper's flop overhead
(the extra n*n0^2-ish inversion work, Sec. VII-D: F = n^2k/p + n0^2n/p).

Also wall-clock sanity on CPU: inversion-based local solve vs row
substitution (even on CPU the batched form wins by a large factor for
small n0 — the latency-bound regime the paper attacks)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def flop_model(n, k, n0):
    m = n // n0
    gemm_solve = m * n0 * n0 * k * 2                 # L~_ii @ B_i
    gemm_update = sum((n - (i + 1) * n0) * n0 * k * 2 for i in range(m))
    gemm_inv = sum((n0 // (2 * s)) * 2 * (2 * s ** 3)
                   for s in [2 ** j for j in range(int(np.log2(n0)))]) * m
    return gemm_solve, gemm_update, gemm_inv


def run(report):
    from repro.core import blocked

    n, k = 512, 128
    rows = []
    for n0 in [8, 32, 128, 512]:
        gs, gu, gi = flop_model(n, k, n0)
        sub_flops = n * n * k          # the baseline's substitution flops
        mxu = gs + gu + gi
        frac = (gs + gu) / (gs + gu + gi)
        overhead = gi / (gs + gu)
        rows.append(dict(n0=n0, gemm=mxu, inv_overhead=overhead,
                         useful_frac=frac))
        report(f"n0={n0:4d}: GEMM flops={mxu:.2e} "
               f"(inversion overhead={overhead * 100:.1f}%, "
               f"useful={frac * 100:.1f}%) — baseline substitution flops "
               f"{sub_flops:.2e} are 0% MXU-eligible")

    # wall-clock: batched inversion+GEMM vs row-by-row substitution
    rng = np.random.default_rng(0)
    L = jnp.asarray(np.tril(rng.standard_normal((n, n))) + n * np.eye(n),
                    jnp.float32)
    B = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
    it = jax.jit(lambda l, b: blocked.it_inv_trsm_local(l, b, 64))
    fs = jax.jit(blocked.forward_substitution)
    it(L, B).block_until_ready()
    fs(L, B).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        it(L, B).block_until_ready()
    t_it = (time.perf_counter() - t0) / 20
    t0 = time.perf_counter()
    for _ in range(20):
        fs(L, B).block_until_ready()
    t_fs = (time.perf_counter() - t0) / 20
    report(f"wall-clock (CPU, n={n}, k={k}): It-Inv(n0=64)={t_it * 1e3:.2f}ms"
           f"  row-substitution={t_fs * 1e3:.2f}ms  "
           f"speedup={t_fs / t_it:.1f}x")
    rows.append(dict(t_it_inv_ms=t_it * 1e3, t_subst_ms=t_fs * 1e3,
                     speedup=t_fs / t_it))
    return rows
