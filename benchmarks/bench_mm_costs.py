"""Paper Sec. III validation: traced collective costs of the
implemented MM vs the closed-form model, line by line.

The paper's 'experiment' for MM is its cost table; we reproduce it by
tracing the real shard_map program (repro.core.comm records every
collective with its exact payload at trace time) and comparing against
repro.core.cost_model.mm_cost.  Runs on 8 forced host devices in a
subprocess when invoked via benchmarks.run; direct invocation needs
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

from __future__ import annotations

import numpy as np


def run(report):
    import jax
    from repro.core import comm, cost_model as cm, grid as gridlib, mm3d

    rows = []
    for (p1, p2, n, k) in [(2, 2, 256, 64), (2, 2, 512, 512),
                           (2, 1, 256, 64), (1, 8, 512, 64),
                           (2, 2, 1024, 128)]:
        if p1 * p1 * p2 > len(jax.devices()):
            continue
        grid = gridlib.make_trsm_mesh(p1, p2)
        fn = mm3d.mm3d_fn(grid, n, n, k)
        t = comm.traced_cost(
            fn, jax.ShapeDtypeStruct((n, n), np.float32),
            jax.ShapeDtypeStruct((n, k), np.float32))
        model = cm.mm_cost(n, k, p1 * p1 * p2, p1, p2)
        w_err = abs(t.w - model.w) / max(model.w, 1)
        s_err = abs(t.s - model.s) / max(model.s, 1)
        rows.append(dict(p1=p1, p2=p2, n=n, k=k, traced_w=t.w,
                         model_w=model.w, traced_s=t.s, model_s=model.s,
                         w_rel_err=w_err, s_rel_err=s_err))
        status = "OK" if w_err < 0.05 and s_err < 0.3 else "MISMATCH"
        report(f"MM p1={p1} p2={p2} n={n} k={k}: "
               f"W traced={t.w:.0f} model={model.w:.0f} "
               f"S traced={t.s:.0f} model={model.s:.0f}  {status}")
    assert all(r["w_rel_err"] < 0.05 for r in rows), rows
    return rows
