"""Paper Sec. IX validation: the conclusion's S/W/F comparison table
(standard Rec-TRSM vs the new It-Inv-TRSM) across the three regimes,
both from the closed-form models AND from the traced implementations.

The headline claims validated here:
  * 3D regime: latency improvement Theta((n/k)^{1/6} p^{2/3}),
    bandwidth parity, flops within 2x.
  * 2D regime: bandwidth improvement Theta(log p).
  * 1D regime: parity (inversion costs an extra log factor in latency).

This bench is ALSO the calibration producer (DESIGN.md Sec. 16): it
measures steady-state solve wall times across simulated (p, n/k)
regimes, fits the per-Machine (a, b, g) rescale
(``cost_model.fit_calibration``), measures the per-dispatch host
overhead, and measures the overlapped-vs-sequential sweep ratio on a
p >= 4 grid — all committed to ``benchmarks/BENCH_overlap.json``,
which ``tuning.default_machine()`` loads so every a-priori plan
(SolveSpec.auto, serving_n0, choose_serving_method, plan_fleet) prices
from calibrated numbers.  Set ``BENCH_OVERLAP_SMOKE=1`` (the weekly CI
job does) for a reduced-rep run that CHECKS the committed calibration
instead of rewriting it: the committed (a, b, g) must still reduce the
median relative prediction error against fresh measurements.
"""

from __future__ import annotations

import json
import math
import os
import time

import numpy as np

SMOKE = bool(int(os.environ.get("BENCH_OVERLAP_SMOKE", "0")))
OVERLAP_JSON = os.path.join(os.path.dirname(__file__),
                            "BENCH_overlap.json")

# simulated (p, n/k) analogues of the Sec. IX regimes on p = 4 and
# p = 8 grids: many-RHS (1D-flavored), square-ish (3D-flavored), and
# tall-solve (2D-flavored) shapes — (p1, p2, n, k, n0) each
CAL_CONFIGS = [
    (2, 1, 256, 64, 32),
    (2, 1, 256, 8, 32),
    (2, 1, 512, 16, 64),
    (2, 2, 256, 16, 32),
    (2, 2, 512, 32, 64),
    (2, 2, 512, 128, 64),
]
# the overlap on-vs-off ratio is measured at this config (p = 4): the
# deepest sweep of the set (m = 8 panels), where the pipelined issue
# order has the most room to hide collectives under GEMMs
OVERLAP_CONFIG = (2, 1, 2048, 16, 256)


def closed_form_rows(report):
    from repro.core import cost_model as cm

    rows = []
    k, p = 1 << 10, 1 << 9
    for regime, n in [("1D", max(4, int(2 * k / p))),
                      ("3D", 64 * k), ("2D", int(8 * k * math.sqrt(p)))]:
        row = cm.paper_table_row(n, k, p)
        s_ratio = row["standard"]["S"] / row["new"]["S"]
        w_ratio = row["standard"]["W"] / row["new"]["W"]
        f_ratio = row["new"]["F"] / row["standard"]["F"]
        rows.append(dict(regime=row["regime"], n=n, k=k, p=p,
                         s_ratio=s_ratio, w_ratio=w_ratio,
                         f_ratio=f_ratio))
        report(f"{row['regime']} n={n} k={k} p={p}: "
               f"S ratio={s_ratio:.1f} W ratio={w_ratio:.2f} "
               f"F new/std={f_ratio:.2f}")
        if row["regime"] == "3D":
            expect = (n / k) ** (1 / 6) * p ** (2 / 3)
            report(f"   expected 3D S-improvement Theta((n/k)^1/6 p^2/3)"
                   f" = {expect:.0f}; model gives {s_ratio:.0f}")
            assert 0.1 * expect < s_ratio < 10 * expect
            assert abs(w_ratio - 1) < 0.01
            assert f_ratio <= 2.01
        if row["regime"] == "2D":
            assert abs(w_ratio - math.log2(p)) < 1.0
    return rows


def traced_rows(report):
    """Trace both implementations on an 8-device grid and compare
    measured S/W (per-processor words) — the implementation-level
    version of the Sec. IX table."""
    import jax
    from repro.core import comm, grid as gridlib, inv_trsm, rec_trsm

    rows = []
    for (p1, p2, n, k, n0) in [(2, 2, 512, 64, 64), (2, 2, 512, 512, 64)]:
        if p1 * p1 * p2 > len(jax.devices()):
            continue
        grid = gridlib.make_trsm_mesh(p1, p2)
        fi = inv_trsm.it_inv_trsm_fn(grid, n, k, n0, np.float32)
        ti = comm.traced_cost(fi, jax.ShapeDtypeStruct((n, n), np.float32),
                              jax.ShapeDtypeStruct((n, k), np.float32))
        fr = rec_trsm.rec_trsm_fn(grid, n, k)
        tr = comm.traced_cost(fr, jax.ShapeDtypeStruct((n, n), np.float32),
                              jax.ShapeDtypeStruct((n, k), np.float32))
        rows.append(dict(n=n, k=k, n0=n0, it_s=ti.s, rec_s=tr.s,
                         it_w=ti.w, rec_w=tr.w))
        report(f"traced n={n} k={k}: It-Inv S={ti.s:.0f} W={ti.w:.0f} | "
               f"Rec S={tr.s:.0f} W={tr.w:.0f} | "
               f"S ratio={tr.s / max(ti.s, 1):.2f}")
    return rows


def _measure_steady(grid, n, k, n0, overlap, reps, passes):
    """Min-of-passes per-solve steady-state seconds for one config:
    factor admitted once, RHS pre-placed, ``donate=False`` so the same
    placed panel is re-solved (timeit hygiene — the minimum is the
    least noise-contaminated estimate on a busy host)."""
    import jax
    from repro import api
    rng = np.random.default_rng(0)
    L = (np.tril(rng.standard_normal((n, n)))
         + n * np.eye(n)).astype(np.float32)
    solver = api.Solver.from_factor(L, grid, n0=n0, overlap=overlap)
    solver.warmup(k)
    B = solver.place_rhs(
        rng.standard_normal((n, k)).astype(np.float32))
    jax.block_until_ready(solver.solve(B, donate=False))   # settle
    best = float("inf")
    for _ in range(passes):
        t0 = time.perf_counter()
        for _ in range(reps):
            X = solver.solve(B, donate=False)
        jax.block_until_ready(X)
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def _measure_steady_pair(grid, n, k, n0, reps, passes):
    """Min-of-passes steady seconds for overlap on AND off with the
    passes INTERLEAVED, so slow host drift (other processes, thermal)
    cannot bias one arm: each pass times both programs back to back
    on the same placed RHS."""
    import jax
    from repro import api
    rng = np.random.default_rng(0)
    L = (np.tril(rng.standard_normal((n, n)))
         + n * np.eye(n)).astype(np.float32)
    solvers, rhs = {}, {}
    for ov in ("on", "off"):
        s = api.Solver.from_factor(L, grid, n0=n0, overlap=ov)
        s.warmup(k)
        B = s.place_rhs(rng.standard_normal((n, k)).astype(np.float32))
        jax.block_until_ready(s.solve(B, donate=False))   # settle
        solvers[ov], rhs[ov] = s, B
    best = {"on": float("inf"), "off": float("inf")}
    for _ in range(passes):
        for ov in ("on", "off"):
            s, B = solvers[ov], rhs[ov]
            t0 = time.perf_counter()
            for _ in range(reps):
                X = s.solve(B, donate=False)
            jax.block_until_ready(X)
            best[ov] = min(best[ov], (time.perf_counter() - t0) / reps)
    return best["on"], best["off"]


def _measure_dispatch_s(reps=200, passes=5):
    """Measured per-program host dispatch overhead: min-of-passes time
    of a trivial compiled dispatch (the quantity ``plan_fleet`` weighs
    a merge's padding overhead against)."""
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x + 1.0)
    x = jnp.zeros((8,), np.float32)
    jax.block_until_ready(f(x))
    best = float("inf")
    for _ in range(passes):
        t0 = time.perf_counter()
        for _ in range(reps):
            y = f(x)
        jax.block_until_ready(y)
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def calibration_rows(report):
    """Measured-vs-predicted steady times across the (p, n/k) regimes;
    fits (and commits, full runs) the calibration — or checks the
    committed one (smoke runs)."""
    import jax
    from repro.core import cost_model as cm, grid as gridlib

    if len(jax.devices()) < 8:
        report("calibration: <8 devices, skipping")
        return []

    reps, passes = (3, 2) if SMOKE else (10, 4)
    configs = CAL_CONFIGS[::2] if SMOKE else CAL_CONFIGS
    base = cm.tpu_v5e()
    grids = {}
    rows = []
    for (p1, p2, n, k, n0) in configs:
        grid = grids.setdefault((p1, p2),
                                gridlib.make_trsm_mesh(p1, p2))
        c = cm.it_inv_trsm_steady_cost(n, k, n0, p1, p2)
        t = _measure_steady(grid, n, k, n0, "on", reps, passes)
        rows.append(dict(p1=p1, p2=p2, n=n, k=k, n0=n0,
                         s=c.s, w=c.w, f=c.f, measured_s=t,
                         predicted_s=c.time(base)))
        report(f"cal p={p1 * p1 * p2} n={n} k={k} n0={n0}: "
               f"measured {t * 1e3:8.3f} ms | predicted "
               f"{c.time(base) * 1e3:8.3f} ms")

    dispatch_s = _measure_dispatch_s()
    report(f"dispatch overhead: {dispatch_s * 1e6:.1f} us/program")

    if SMOKE:
        with open(OVERLAP_JSON) as fh:
            payload = json.load(fh)
        cal = cm.Calibration(**payload["calibration"])
        assert cal.a > 0 and cal.b > 0 and cal.g > 0, payload
    else:
        cal = cm.fit_calibration(rows, base, dispatch_s=dispatch_s)
    calm = cal.apply(base)
    err0 = [abs(r["predicted_s"] - r["measured_s"]) / r["measured_s"]
            for r in rows]
    c_rows = [cm.it_inv_trsm_steady_cost(r["n"], r["k"], r["n0"],
                                         r["p1"], r["p2"])
              for r in rows]
    err1 = [abs(c.time(calm) - r["measured_s"]) / r["measured_s"]
            for c, r in zip(c_rows, rows)]
    med0, med1 = float(np.median(err0)), float(np.median(err1))
    report(f"median |pred-meas|/meas: uncalibrated {med0:.3f} -> "
           f"calibrated {med1:.3f} (a={cal.a:.3g} b={cal.b:.3g} "
           f"g={cal.g:.3g})")
    if SMOKE:
        assert med1 < med0, (
            f"committed calibration no longer improves prediction "
            f"(uncal {med0:.3f} vs cal {med1:.3f}): regenerate "
            f"BENCH_overlap.json (python -m benchmarks.run paper_table)")
    else:
        assert med1 * 2 <= med0, (
            f"acceptance: calibration must reduce the median relative "
            f"error >= 2x, got {med0:.3f} -> {med1:.3f}")

    # overlapped vs sequential steady latency on a p >= 4 grid; the
    # two programs are bit-identical in VALUE, so this measures that
    # the pipelined issue order costs nothing (>= 1.0x) on hosts with
    # no async collectives, and the real win where XLA can overlap.
    # Passes interleave the two arms so host-load drift hits both
    # equally — back-to-back blocks bias whichever runs first.
    # On hosts where the simulated devices SERIALIZE onto one core
    # there is no concurrency to exploit, so the honest expectation is
    # parity (the committed ratio states what was measured either
    # way); the assert is a noise guard, not the win condition.
    (p1, p2, n, k, n0) = OVERLAP_CONFIG
    grid = grids.setdefault((p1, p2), gridlib.make_trsm_mesh(p1, p2))
    t_on, t_off = _measure_steady_pair(grid, n, k, n0,
                                       reps=max(reps, 10),
                                       passes=max(passes, 12))
    ratio = t_off / t_on
    report(f"overlap p={p1 * p1 * p2} n={n} k={k}: sequential "
           f"{t_off * 1e3:.3f} ms | overlapped {t_on * 1e3:.3f} ms | "
           f"ratio {ratio:.3f}x")
    assert ratio >= 0.9, (
        f"overlapped sweep slower than sequential: {ratio:.3f}x")

    if not SMOKE:
        payload = dict(
            bench="overlap",
            date=time.strftime("%Y-%m-%d"),
            machine=base.name,
            calibration=dict(a=cal.a, b=cal.b, g=cal.g,
                             dispatch_s=dispatch_s),
            median_rel_err=dict(uncalibrated=med0, calibrated=med1),
            overlap=dict(p1=p1, p2=p2, n=n, k=k, n0=n0,
                         sequential_ms=t_off * 1e3,
                         overlapped_ms=t_on * 1e3, ratio=ratio),
            rows=[{kk: (round(v, 9) if isinstance(v, float) else v)
                   for kk, v in r.items()} for r in rows])
        with open(OVERLAP_JSON, "w") as fh:
            json.dump(payload, fh, indent=1)
        report(f"calibration committed -> {OVERLAP_JSON}")
    return rows


def run(report):
    rows = closed_form_rows(report)
    rows += traced_rows(report)
    rows += calibration_rows(report)
    return rows
