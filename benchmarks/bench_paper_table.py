"""Paper Sec. IX validation: the conclusion's S/W/F comparison table
(standard Rec-TRSM vs the new It-Inv-TRSM) across the three regimes,
both from the closed-form models AND from the traced implementations.

The headline claims validated here:
  * 3D regime: latency improvement Theta((n/k)^{1/6} p^{2/3}),
    bandwidth parity, flops within 2x.
  * 2D regime: bandwidth improvement Theta(log p).
  * 1D regime: parity (inversion costs an extra log factor in latency).
"""

from __future__ import annotations

import math

import numpy as np


def closed_form_rows(report):
    from repro.core import cost_model as cm

    rows = []
    k, p = 1 << 10, 1 << 9
    for regime, n in [("1D", max(4, int(2 * k / p))),
                      ("3D", 64 * k), ("2D", int(8 * k * math.sqrt(p)))]:
        row = cm.paper_table_row(n, k, p)
        s_ratio = row["standard"]["S"] / row["new"]["S"]
        w_ratio = row["standard"]["W"] / row["new"]["W"]
        f_ratio = row["new"]["F"] / row["standard"]["F"]
        rows.append(dict(regime=row["regime"], n=n, k=k, p=p,
                         s_ratio=s_ratio, w_ratio=w_ratio,
                         f_ratio=f_ratio))
        report(f"{row['regime']} n={n} k={k} p={p}: "
               f"S ratio={s_ratio:.1f} W ratio={w_ratio:.2f} "
               f"F new/std={f_ratio:.2f}")
        if row["regime"] == "3D":
            expect = (n / k) ** (1 / 6) * p ** (2 / 3)
            report(f"   expected 3D S-improvement Theta((n/k)^1/6 p^2/3)"
                   f" = {expect:.0f}; model gives {s_ratio:.0f}")
            assert 0.1 * expect < s_ratio < 10 * expect
            assert abs(w_ratio - 1) < 0.01
            assert f_ratio <= 2.01
        if row["regime"] == "2D":
            assert abs(w_ratio - math.log2(p)) < 1.0
    return rows


def traced_rows(report):
    """Trace both implementations on an 8-device grid and compare
    measured S/W (per-processor words) — the implementation-level
    version of the Sec. IX table."""
    import jax
    from repro.core import comm, grid as gridlib, inv_trsm, rec_trsm

    rows = []
    for (p1, p2, n, k, n0) in [(2, 2, 512, 64, 64), (2, 2, 512, 512, 64)]:
        if p1 * p1 * p2 > len(jax.devices()):
            continue
        grid = gridlib.make_trsm_mesh(p1, p2)
        fi = inv_trsm.it_inv_trsm_fn(grid, n, k, n0, np.float32)
        ti = comm.traced_cost(fi, jax.ShapeDtypeStruct((n, n), np.float32),
                              jax.ShapeDtypeStruct((n, k), np.float32))
        fr = rec_trsm.rec_trsm_fn(grid, n, k)
        tr = comm.traced_cost(fr, jax.ShapeDtypeStruct((n, n), np.float32),
                              jax.ShapeDtypeStruct((n, k), np.float32))
        rows.append(dict(n=n, k=k, n0=n0, it_s=ti.s, rec_s=tr.s,
                         it_w=ti.w, rec_w=tr.w))
        report(f"traced n={n} k={k}: It-Inv S={ti.s:.0f} W={ti.w:.0f} | "
               f"Rec S={tr.s:.0f} W={tr.w:.0f} | "
               f"S ratio={tr.s / max(ti.s, 1):.2f}")
    return rows


def run(report):
    rows = closed_form_rows(report)
    rows += traced_rows(report)
    return rows
