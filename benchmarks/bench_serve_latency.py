"""Per-solve serving latency: host-round-trip vs device-resident.

Quantifies the tentpole of the device-resident solve pipeline.  Three
configurations of repeated solves against a FIXED factor:

  legacy   — what every solve() call used to do: copy L and B to host
             NumPy, permute to cyclic storage on the CPU, re-upload,
             and rebuild (re-trace, re-compile) the shard_map program.
  cached   — core.trsm today: on-device permutations, compiled program
             from the CompiledSolverCache (L still re-distributed per
             call — the one-shot API's cost).
  session  — repro.api.Solver (width-1) steady state: factor resident
             in cyclic device storage — diagonal blocks pre-inverted
             at admission — one compiled program per RHS shape,
             donated B; zero host transfers, zero retraces.
  bf16_refine — the same steady state under the bf16_refine precision
             policy: bf16 (MXU-native) sweep + 2 unrolled on-device
             refinement passes serving fp32 answers (DESIGN.md Sec. 7).
             Three sweeps + two residual GEMMs per solve; on CPU at
             small n, where per-program overhead dominates, that shows
             up as ~10x the fp32 session (see baseline.json) — on TPU
             the bf16 GEMMs run ~2x the fp32 rate, which is the point.

The second half is the OPEN-loop traffic harness over
:class:`repro.api.AsyncSolveServer` (DESIGN.md Sec. 13): Poisson
arrivals (exponential inter-arrival gaps) at a swept offered rate
against the background drain loop, latency measured from each
request's SCHEDULED arrival (open-loop honesty: a submit that falls
behind still pays for the delay), goodput = served/s.  The sweep
walks the rate geometrically to the SATURATION point — the highest
offered rate the server sustains at >= 95% goodput — then re-runs at
0.8x saturation and asserts the PR-7 acceptance bar: goodput >= 95%
of offered, p99 <= 5x p50, ZERO retraces and ZERO host transfers for
the whole run (global ``jax_transfer_guard`` — the drain loop is a
thread, so the context-manager guard would not see it).  Each full
run appends a dated point to the committed
``benchmarks/BENCH_traffic.json``; ``BENCH_TRAFFIC_SMOKE=1`` (the
weekly CI job) runs a reduced sweep and instead checks the measured
saturation against the committed trajectory within tolerance.

The third part is the CONTROL-PLANE overload comparison (DESIGN.md
Sec. 15): the same open-loop harness driven at 2x the measured
closed-loop capacity — sustained saturation, where a depth-bounded
queue keeps every admitted request waiting a full backlog and the
within-SLO goodput collapses.  Two arms, identical traffic: depth-only
admission (the PR-7 baseline) vs the SLO-aware AdmissionController
(requests whose estimated queue wait cannot meet the SLO are shed AT
SUBMIT, so capacity serves requests that can still finish in time).
Metric: the fraction of OFFERED requests completing within the SLO;
the acceptance bar is >= 1.2x the baseline fraction, with zero
retraces and zero transfers across both measured runs.  Full runs
append to ``benchmarks/BENCH_control.json``; ``BENCH_CONTROL_SMOKE=1``
(the weekly CI job) runs a reduced overload and asserts the bar plus
the committed-trajectory band.

Run standalone (``--traffic`` / ``--control`` for one harness alone)
or via ``python -m benchmarks.run serve_latency``.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

TRAFFIC_SMOKE = bool(int(os.environ.get("BENCH_TRAFFIC_SMOKE", "0")))
CONTROL_SMOKE = bool(int(os.environ.get("BENCH_CONTROL_SMOKE", "0")))
TRAJECTORY = os.path.join(os.path.dirname(__file__),
                          "BENCH_traffic.json")
CONTROL_TRAJECTORY = os.path.join(os.path.dirname(__file__),
                                  "BENCH_control.json")
# the weekly smoke runs on whatever shared CPU the CI lands on, so the
# committed-saturation comparison is a sanity band, not a perf gate
SMOKE_TOLERANCE = 4.0


def _time_per_call(fn, reps: int) -> float:
    import jax
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _legacy_solve(L, B, grid, n0):
    """The pre-refactor end-to-end path, reproduced for comparison:
    host-side cyclic permutation + a freshly built (hence freshly
    traced) solver program on every call."""
    import jax.numpy as jnp
    from repro.core import inv_trsm
    from repro.core.grid import (to_cyclic_matrix, to_cyclic_rows,
                                 from_cyclic_rows)
    p1, p2 = grid.p1, grid.p2
    L_cyc = to_cyclic_matrix(np.asarray(L), p1, p1 * p2)
    B_cyc = to_cyclic_rows(np.asarray(B), p1)
    fn = inv_trsm.it_inv_trsm_fn(grid, B.shape[0], B.shape[1], n0,
                                 L.dtype)
    X_cyc = fn(jnp.asarray(L_cyc), jnp.asarray(B_cyc))
    return from_cyclic_rows(np.asarray(X_cyc), p1)


# ---------------------- open-loop traffic harness ----------------------

def _traffic_server(n, slots, panel_k, queue_depth):
    import numpy as _np
    from repro import api
    grid = api.make_trsm_mesh(1, 1)
    rng = np.random.default_rng(7)
    Ls = np.stack([np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
                   for _ in range(slots)]).astype(_np.float32)
    solver = api.Solver.from_factors(Ls, grid, n0=32)
    srv = api.AsyncSolveServer(solver, panel_k,
                               queue_depth=queue_depth).warmup()
    return srv, rng


def _place_pool(srv, rng, n, width, count=64):
    """A device-resident RHS pool: arrival-time submits must not pay
    (or trip the guard on) a host->device upload."""
    import jax
    import jax.numpy as jnp
    pool = [jnp.asarray(rng.standard_normal((n, width))
                        .astype(np.float32)) for _ in range(count)]
    jax.block_until_ready(pool)
    return pool


def _offer(srv, pool, rate, duration_s, rng, slots):
    """One open-loop Poisson run at ``rate`` req/s against the RUNNING
    server.  Returns (futures, scheduled arrival times, elapsed)."""
    gaps = rng.exponential(1.0 / rate,
                           size=max(int(rate * duration_s), 1))
    t0 = time.monotonic()
    sched = t0 + np.cumsum(gaps)
    futs, sched_kept = [], []
    from repro.api import Overloaded
    for i, t_i in enumerate(sched):
        delay = t_i - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        try:
            f = srv.submit(pool[i % len(pool)], factor=i % slots)
        except Overloaded:
            continue           # shed: counted by the server
        futs.append(f)
        sched_kept.append(t_i)
    for f in futs:
        f.result(timeout=120)
    elapsed = time.monotonic() - t0
    return futs, np.asarray(sched_kept), elapsed


def _measure(srv, futs, sched, elapsed, rate):
    lat = np.asarray([f.completed for f in futs]) - sched
    goodput = len(futs) / elapsed
    return dict(
        offered_rps=round(rate, 1), served=len(futs),
        shed=srv.stats()["shed"], goodput_rps=round(goodput, 1),
        goodput_ratio=round(goodput / rate, 3),
        p50_ms=round(float(np.percentile(lat, 50)) * 1e3, 2),
        p99_ms=round(float(np.percentile(lat, 99)) * 1e3, 2))


def _traffic(report):
    """Rate sweep to saturation, then the acceptance run at 0.8x."""
    import jax
    from repro.core import session

    # n is sized so one wave is compute-bound (>= ~10 ms on one CPU):
    # below that, single-core OS timeslices — not the serving path —
    # own the tail and the p99/p50 ratio measures the scheduler
    n, slots, panel_k, width = 512, 4, 16, 4
    depth = 128
    sweep_s, accept_s = (1.0, 2.0) if TRAFFIC_SMOKE else (2.0, 5.0)
    srv, rng = _traffic_server(n, slots, panel_k, depth)
    pool = _place_pool(srv, rng, n, width)
    key = srv.solver.program_for(panel_k).key

    # closed-loop capacity estimate to anchor the sweep: one full wave
    # carries slots * (panel_k / width) requests
    per_wave = slots * (panel_k // width)
    # prime EVERY wave composition traffic can produce (full and
    # partial panels hit different filler/extraction slice programs —
    # a lazy first compile mid-run would be a 100 ms tail spike)
    for count in list(range(1, per_wave + 1)) * 2:
        futs = [srv.submit(pool[i % len(pool)], factor=i % slots)
                for i in range(count)]
        while srv.pending() or srv._inflight:
            srv.step()
    t0 = time.monotonic()
    reps = 5
    for _ in range(reps):
        futs = [srv.submit(pool[i % len(pool)], factor=i % slots)
                for i in range(per_wave)]
        while srv.pending() or srv._inflight:
            srv.step()
    capacity = per_wave * reps / (time.monotonic() - t0)
    report(f"traffic: closed-loop capacity ~ {capacity:.0f} req/s "
           f"({per_wave} req/wave)")

    # geometric sweep: climb until the server stops sustaining
    points, saturation = [], None
    rate = capacity * 0.25
    srv.start()
    try:
        for _ in range(8):
            base = srv.stats()["shed"]
            futs, sched, elapsed = _offer(srv, pool, rate, sweep_s,
                                          rng, slots)
            pt = _measure(srv, futs, sched, elapsed, rate)
            pt["shed"] -= base
            points.append(pt)
            report(f"traffic: offered {pt['offered_rps']:8.1f} rps -> "
                   f"goodput {pt['goodput_rps']:8.1f} "
                   f"({pt['goodput_ratio']:.3f}) | p50 "
                   f"{pt['p50_ms']:7.2f} ms p99 {pt['p99_ms']:7.2f} ms"
                   f" | shed {pt['shed']}")
            if pt["goodput_ratio"] < 0.95:
                break
            saturation = rate
            rate *= 1.5
        if saturation is None:            # even the floor overloads —
            saturation = capacity * 0.25  # report, and let the
        report(f"traffic: saturation ~ {saturation:.0f} req/s")

        # the acceptance run: 0.8x saturation, steady state PINNED —
        # global guard because the drain loop is its own thread
        accept_rate = 0.8 * saturation
        import gc
        for attempt in range(2):       # best-of-2: one noisy-host
            traces = session.TRACE_COUNTS[key]   # burst != regression
            base = srv.stats()["shed"]
            # timeit-style hygiene for the measured run: collect the
            # sweep debris now, not as a 100 ms GC pause mid-run
            gc.collect()
            gc.disable()
            jax.config.update("jax_transfer_guard", "disallow")
            try:
                futs, sched, elapsed = _offer(srv, pool, accept_rate,
                                              accept_s, rng, slots)
            finally:
                jax.config.update("jax_transfer_guard", "allow")
                gc.enable()
            accept = _measure(srv, futs, sched, elapsed, accept_rate)
            accept["shed"] -= base
            assert session.TRACE_COUNTS[key] == traces, \
                "acceptance: the wave program retraced under traffic"
            report(f"traffic: ACCEPT @ 0.8x saturation "
                   f"({accept_rate:.0f} rps): goodput "
                   f"{accept['goodput_rps']:.1f} "
                   f"({accept['goodput_ratio']:.3f}) | p50 "
                   f"{accept['p50_ms']:.2f} ms p99 "
                   f"{accept['p99_ms']:.2f} ms | 0 retraces, "
                   f"0 transfers")
            if accept["goodput_ratio"] >= 0.95 \
                    and accept["p99_ms"] <= 5 * accept["p50_ms"]:
                break
    finally:
        srv.stop(drain=True)

    if TRAFFIC_SMOKE:
        _check_saturation_vs_committed(report, saturation)
    else:
        assert accept["goodput_ratio"] >= 0.95, accept
        assert accept["p99_ms"] <= 5 * accept["p50_ms"], accept
        _record_traffic(dict(
            n=n, slots=slots, panel_k=panel_k, width=width,
            queue_depth=depth, capacity_rps=round(capacity, 1),
            saturation_rps=round(saturation, 1), accept=accept))
        report(f"trajectory point appended to {TRAJECTORY}")
    return dict(capacity_rps=round(capacity, 1),
                saturation_rps=round(saturation, 1),
                sweep=points, accept=accept)


# ---------------------- control-plane overload harness ----------------------

def _prime_compositions(srv, pool, slots, per_wave):
    """Compile every wave composition traffic can produce, then leave
    the server idle — identical to the traffic harness's warm-up."""
    for count in list(range(1, per_wave + 1)) * 2:
        for i in range(count):
            srv.submit(pool[i % len(pool)], factor=i % slots)
        while srv.pending() or srv._inflight:
            srv.step()


def _offer_overload(srv, pool, rate, duration_s, rng, slots, slo_s):
    """One open-loop overload run.  Unlike :func:`_offer`, this keeps
    the books the control plane is judged on: EVERY scheduled arrival
    counts as offered, depth sheds raise at submit, deadline sheds
    come back as already-failed futures, and 'good' means completed
    within the SLO measured from the SCHEDULED arrival."""
    from repro.api import DeadlineUnmeetable, Overloaded
    gaps = rng.exponential(1.0 / rate,
                           size=max(int(rate * duration_s), 1))
    t0 = time.monotonic()
    sched = t0 + np.cumsum(gaps)
    futs, depth_shed = [], 0
    for i, t_i in enumerate(sched):
        delay = t_i - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        try:
            futs.append((t_i, srv.submit(pool[i % len(pool)],
                                         factor=i % slots)))
        except Overloaded:
            depth_shed += 1
    served = within = deadline_shed = 0
    for t_i, f in futs:
        try:
            f.result(timeout=120)
        except DeadlineUnmeetable:
            deadline_shed += 1
            continue
        served += 1
        if f.completed - t_i <= slo_s:
            within += 1
    offered = len(sched)
    return dict(offered=offered, served=served,
                within_slo=within, depth_shed=depth_shed,
                deadline_shed=deadline_shed,
                good_fraction=round(within / offered, 4))


def _control_arm(report, label, rate, slo_ms, seed, *,
                 admission, duration_s):
    """One fresh server + one overload run; returns the arm's books.
    Fresh per arm so queues, counters, and the service EWMA never
    leak between the baseline and the controller."""
    import gc

    import jax
    from repro import api
    from repro.core import session

    n, slots, panel_k, width, depth = 512, 4, 16, 4, 64
    srv, _ = _traffic_server(n, slots, panel_k, depth)
    rng = np.random.default_rng(seed)
    pool = _place_pool(srv, rng, n, width)
    per_wave = slots * (panel_k // width)
    _prime_compositions(srv, pool, slots, per_wave)
    key = srv.solver.program_for(panel_k).key
    # the EWMA must reflect STEADY waves, not the priming compiles
    srv.reset_service_ewma()
    for _ in range(3):
        for i in range(per_wave):
            srv.submit(pool[i % len(pool)], factor=i % slots)
        while srv.pending() or srv._inflight:
            srv.step()
    if admission:
        srv.set_admission(api.AdmissionController(slo_ms=slo_ms))
    traces = session.TRACE_COUNTS[key]
    gc.collect()
    gc.disable()
    jax.config.update("jax_transfer_guard", "disallow")
    srv.start()
    try:
        books = _offer_overload(srv, pool, rate, duration_s, rng,
                                slots, slo_ms * 1e-3)
    finally:
        srv.stop(drain=True)
        jax.config.update("jax_transfer_guard", "allow")
        gc.enable()
    assert session.TRACE_COUNTS[key] == traces, \
        f"control/{label}: the wave program retraced under overload"
    report(f"control: {label:9s} @ {rate:.0f} rps x {duration_s:.0f}s:"
           f" {books['within_slo']}/{books['offered']} within "
           f"{slo_ms:.0f} ms SLO (good {books['good_fraction']:.3f})"
           f" | served {books['served']} | shed "
           f"{books['depth_shed']} depth + {books['deadline_shed']} "
           f"deadline | 0 retraces, 0 transfers")
    return books


def _control(report):
    """The 2x-overload comparison: depth-only vs SLO-aware admission,
    within-SLO goodput fraction, >= 1.2x acceptance bar."""
    n, slots, panel_k, width, depth = 512, 4, 16, 4, 64
    duration_s = 1.0 if CONTROL_SMOKE else 3.0

    # closed-loop capacity anchor (its own throwaway server)
    srv, rng0 = _traffic_server(n, slots, panel_k, depth)
    pool = _place_pool(srv, rng0, n, width)
    per_wave = slots * (panel_k // width)
    _prime_compositions(srv, pool, slots, per_wave)
    t0 = time.monotonic()
    reps = 5
    for _ in range(reps):
        for i in range(per_wave):
            srv.submit(pool[i % len(pool)], factor=i % slots)
        while srv.pending() or srv._inflight:
            srv.step()
    capacity = per_wave * reps / (time.monotonic() - t0)
    wave_ms = per_wave / capacity * 1e3
    # the SLO buys ~6 waves of queueing — deep enough to serve real
    # bursts, far shallower than the depth bound's ~16-wave backlog
    slo_ms = 6.0 * wave_ms
    rate = 2.0 * capacity                 # sustained saturation
    report(f"control: capacity ~ {capacity:.0f} rps "
           f"({wave_ms:.1f} ms/wave) -> overload {rate:.0f} rps, "
           f"SLO {slo_ms:.0f} ms")

    base = _control_arm(report, "depth", rate, slo_ms, 11,
                        admission=False, duration_s=duration_s)
    slo = _control_arm(report, "slo", rate, slo_ms, 11,
                       admission=True, duration_s=duration_s)
    gain = slo["good_fraction"] / max(base["good_fraction"], 1e-9)
    report(f"control: within-SLO goodput {slo['good_fraction']:.3f} "
           f"vs depth-only {base['good_fraction']:.3f} "
           f"({min(gain, 999):.2f}x)")
    assert slo["good_fraction"] >= 1.2 * base["good_fraction"], (
        f"SLO-aware admission did not clear the 1.2x within-SLO "
        f"goodput bar: {slo} vs {base}")
    result = dict(n=n, slots=slots, panel_k=panel_k, width=width,
                  queue_depth=depth, capacity_rps=round(capacity, 1),
                  overload_rps=round(rate, 1),
                  slo_ms=round(slo_ms, 2), base=base, slo=slo,
                  gain=round(min(gain, 999.0), 3))
    if CONTROL_SMOKE:
        _check_control_vs_committed(report, result)
    else:
        _record_control(result)
        report(f"trajectory point appended to {CONTROL_TRAJECTORY}")
    return result


def _check_control_vs_committed(report, result):
    if not os.path.exists(CONTROL_TRAJECTORY):
        report("control: no committed trajectory; smoke check skipped")
        return
    with open(CONTROL_TRAJECTORY) as f:
        traj = json.load(f).get("trajectory", [])
    if not traj:
        return
    # band the GAIN, not the absolute fraction: both arms share the
    # host's noise and the short window's cold-start transient, so
    # their ratio is what a 1 s smoke can reproduce
    committed = traj[-1]["gain"]
    floor = committed / SMOKE_TOLERANCE
    got = result["gain"]
    assert got >= floor, (
        f"smoke: goodput gain {got:.2f}x fell below {floor:.2f}x "
        f"({SMOKE_TOLERANCE}x band around the committed "
        f"{committed:.2f}x) — the admission path regressed (or the "
        f"trajectory needs a refresh)")
    report(f"control: goodput gain {got:.2f}x within "
           f"{SMOKE_TOLERANCE}x of committed {committed:.2f}x")


def _record_control(point):
    traj = []
    if os.path.exists(CONTROL_TRAJECTORY):
        with open(CONTROL_TRAJECTORY) as f:
            traj = json.load(f).get("trajectory", [])
    date = time.strftime("%Y-%m-%d")
    traj = [p for p in traj if p.get("date") != date] + \
        [dict(date=date, **point)]
    with open(CONTROL_TRAJECTORY, "w") as f:
        json.dump({"bench": "control", "trajectory": traj}, f,
                  indent=1)
        f.write("\n")


def _check_saturation_vs_committed(report, saturation):
    if not os.path.exists(TRAJECTORY):
        report("traffic: no committed trajectory; smoke check skipped")
        return
    with open(TRAJECTORY) as f:
        traj = json.load(f).get("trajectory", [])
    if not traj:
        return
    committed = traj[-1]["saturation_rps"]
    lo, hi = committed / SMOKE_TOLERANCE, committed * SMOKE_TOLERANCE
    assert lo <= saturation <= hi, (
        f"smoke: measured saturation {saturation:.0f} rps is outside "
        f"[{lo:.0f}, {hi:.0f}] around the committed "
        f"{committed:.0f} rps — the serving path regressed (or the "
        f"trajectory needs a refresh)")
    report(f"traffic: saturation {saturation:.0f} rps within "
           f"{SMOKE_TOLERANCE}x of committed {committed:.0f} rps")


def _record_traffic(point):
    traj = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY) as f:
            traj = json.load(f).get("trajectory", [])
    date = time.strftime("%Y-%m-%d")
    traj = [p for p in traj if p.get("date") != date] + \
        [dict(date=date, **point)]
    with open(TRAJECTORY, "w") as f:
        json.dump({"bench": "traffic", "trajectory": traj}, f, indent=1)
        f.write("\n")


def run(report):
    import jax
    import jax.numpy as jnp
    from repro import api, core

    rows = []
    cases = [(1, 1, 256, 16, 32), (2, 2, 256, 16, 32)]
    for (p1, p2, n, k, n0) in cases:
        if p1 * p1 * p2 > len(jax.devices()):
            continue
        grid = api.make_trsm_mesh(p1, p2)
        rng = np.random.default_rng(0)
        L = np.tril(rng.standard_normal((n, n))).astype(np.float32) \
            + n * np.eye(n, dtype=np.float32)
        B = rng.standard_normal((n, k)).astype(np.float32)

        reps_slow, reps = 3, 20
        t_legacy = _time_per_call(
            lambda: _legacy_solve(L, B, grid, n0), reps_slow)

        st0 = api.default_cache().stats()
        core.trsm(L, B, grid, method="inv", n0=n0)        # warm the cache
        t_cached = _time_per_call(
            lambda: core.trsm(L, B, grid, method="inv", n0=n0), reps)
        st1 = api.default_cache().stats()
        # steady-state hit rate of the one-shot path: every timed call
        # after the warm-up must hit the compiled-program cache
        hits = st1["hits"] - st0["hits"]
        lookups = hits + st1["misses"] - st0["misses"]
        hit_rate = hits / lookups if lookups else 0.0

        sess = api.Solver.from_factor(L, grid, method="inv",
                                      n0=n0).warmup(k)
        Bs = [sess.place_rhs(rng.standard_normal((n, k)).astype(np.float32))
              for _ in range(reps)]
        it = iter(Bs)
        with jax.transfer_guard("disallow"):
            t_session = _time_per_call(lambda: sess.solve(next(it)), reps)

        sess_bf = api.Solver.from_factor(
            L, grid, method="inv", n0=n0,
            precision="bf16_refine").warmup(k)
        Bs_bf = [sess_bf.place_rhs(
            rng.standard_normal((n, k)).astype(np.float32))
            for _ in range(reps)]
        it_bf = iter(Bs_bf)
        with jax.transfer_guard("disallow"):
            t_bf = _time_per_call(lambda: sess_bf.solve(next(it_bf)), reps)

        row = dict(p1=p1, p2=p2, n=n, k=k, n0=n0,
                   legacy_ms=t_legacy * 1e3, cached_ms=t_cached * 1e3,
                   session_ms=t_session * 1e3,
                   bf16_refine_ms=t_bf * 1e3,
                   speedup=t_legacy / t_session,
                   cache_hit_rate=hit_rate)
        rows.append(row)
        report(f"p1={p1} p2={p2} n={n} k={k}: "
               f"legacy {row['legacy_ms']:8.2f} ms | "
               f"cached {row['cached_ms']:7.2f} ms "
               f"(hit rate {hit_rate:.2f}) | "
               f"session {row['session_ms']:6.2f} ms | "
               f"bf16_refine {row['bf16_refine_ms']:6.2f} ms | "
               f"{row['speedup']:6.1f}x")
        assert hit_rate > 0.9, f"one-shot cache hit rate {hit_rate}"
    # each smoke env var focuses the weekly CI job on ITS harness;
    # a full (no-env) run still exercises both
    traffic = None if CONTROL_SMOKE else _traffic(report)
    control = None if TRAFFIC_SMOKE else _control(report)
    return dict(latency=rows, traffic=traffic, control=control)


if __name__ == "__main__":
    import sys
    if "--traffic" in sys.argv:
        _traffic(print)
    elif "--control" in sys.argv:
        _control(print)
    else:
        run(print)
