"""Per-solve serving latency: host-round-trip vs device-resident.

Quantifies the tentpole of the device-resident solve pipeline.  Three
configurations of repeated solves against a FIXED factor:

  legacy   — what every solve() call used to do: copy L and B to host
             NumPy, permute to cyclic storage on the CPU, re-upload,
             and rebuild (re-trace, re-compile) the shard_map program.
  cached   — core.trsm today: on-device permutations, compiled program
             from the CompiledSolverCache (L still re-distributed per
             call — the one-shot API's cost).
  session  — repro.api.Solver (width-1) steady state: factor resident
             in cyclic device storage — diagonal blocks pre-inverted
             at admission — one compiled program per RHS shape,
             donated B; zero host transfers, zero retraces.
  bf16_refine — the same steady state under the bf16_refine precision
             policy: bf16 (MXU-native) sweep + 2 unrolled on-device
             refinement passes serving fp32 answers (DESIGN.md Sec. 7).
             Three sweeps + two residual GEMMs per solve; on CPU at
             small n, where per-program overhead dominates, that shows
             up as ~10x the fp32 session (see baseline.json) — on TPU
             the bf16 GEMMs run ~2x the fp32 rate, which is the point.

Run standalone or via ``python -m benchmarks.run serve_latency``.
"""

from __future__ import annotations

import time

import numpy as np


def _time_per_call(fn, reps: int) -> float:
    import jax
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _legacy_solve(L, B, grid, n0):
    """The pre-refactor end-to-end path, reproduced for comparison:
    host-side cyclic permutation + a freshly built (hence freshly
    traced) solver program on every call."""
    import jax.numpy as jnp
    from repro.core import inv_trsm
    from repro.core.grid import (to_cyclic_matrix, to_cyclic_rows,
                                 from_cyclic_rows)
    p1, p2 = grid.p1, grid.p2
    L_cyc = to_cyclic_matrix(np.asarray(L), p1, p1 * p2)
    B_cyc = to_cyclic_rows(np.asarray(B), p1)
    fn = inv_trsm.it_inv_trsm_fn(grid, B.shape[0], B.shape[1], n0,
                                 L.dtype)
    X_cyc = fn(jnp.asarray(L_cyc), jnp.asarray(B_cyc))
    return from_cyclic_rows(np.asarray(X_cyc), p1)


def run(report):
    import jax
    import jax.numpy as jnp
    from repro import api, core

    rows = []
    cases = [(1, 1, 256, 16, 32), (2, 2, 256, 16, 32)]
    for (p1, p2, n, k, n0) in cases:
        if p1 * p1 * p2 > len(jax.devices()):
            continue
        grid = api.make_trsm_mesh(p1, p2)
        rng = np.random.default_rng(0)
        L = np.tril(rng.standard_normal((n, n))).astype(np.float32) \
            + n * np.eye(n, dtype=np.float32)
        B = rng.standard_normal((n, k)).astype(np.float32)

        reps_slow, reps = 3, 20
        t_legacy = _time_per_call(
            lambda: _legacy_solve(L, B, grid, n0), reps_slow)

        st0 = api.default_cache().stats()
        core.trsm(L, B, grid, method="inv", n0=n0)        # warm the cache
        t_cached = _time_per_call(
            lambda: core.trsm(L, B, grid, method="inv", n0=n0), reps)
        st1 = api.default_cache().stats()
        # steady-state hit rate of the one-shot path: every timed call
        # after the warm-up must hit the compiled-program cache
        hits = st1["hits"] - st0["hits"]
        lookups = hits + st1["misses"] - st0["misses"]
        hit_rate = hits / lookups if lookups else 0.0

        sess = api.Solver.from_factor(L, grid, method="inv",
                                      n0=n0).warmup(k)
        Bs = [sess.place_rhs(rng.standard_normal((n, k)).astype(np.float32))
              for _ in range(reps)]
        it = iter(Bs)
        with jax.transfer_guard("disallow"):
            t_session = _time_per_call(lambda: sess.solve(next(it)), reps)

        sess_bf = api.Solver.from_factor(
            L, grid, method="inv", n0=n0,
            precision="bf16_refine").warmup(k)
        Bs_bf = [sess_bf.place_rhs(
            rng.standard_normal((n, k)).astype(np.float32))
            for _ in range(reps)]
        it_bf = iter(Bs_bf)
        with jax.transfer_guard("disallow"):
            t_bf = _time_per_call(lambda: sess_bf.solve(next(it_bf)), reps)

        row = dict(p1=p1, p2=p2, n=n, k=k, n0=n0,
                   legacy_ms=t_legacy * 1e3, cached_ms=t_cached * 1e3,
                   session_ms=t_session * 1e3,
                   bf16_refine_ms=t_bf * 1e3,
                   speedup=t_legacy / t_session,
                   cache_hit_rate=hit_rate)
        rows.append(row)
        report(f"p1={p1} p2={p2} n={n} k={k}: "
               f"legacy {row['legacy_ms']:8.2f} ms | "
               f"cached {row['cached_ms']:7.2f} ms "
               f"(hit rate {hit_rate:.2f}) | "
               f"session {row['session_ms']:6.2f} ms | "
               f"bf16_refine {row['bf16_refine_ms']:6.2f} ms | "
               f"{row['speedup']:6.1f}x")
        assert hit_rate > 0.9, f"one-shot cache hit rate {hit_rate}"
    return rows


if __name__ == "__main__":
    run(print)
