"""Numerical stability of inversion-based TRSM (the Du Croz/Higham
claim the paper's Sec. I leans on: triangular inversion is stable,
unlike general inversion).

Part 1 — kappa sweep: compare forward error of
  * substitution TRSM (baseline),
  * It-Inv-TRSM with diagonal-block inversion (the paper: only n0-sized
    blocks are inverted),
  * full-inverse multiply X = L^{-1} B (what the paper's blocking
    AVOIDS for large n).

Expected: block-inversion tracks substitution closely across kappa; the
full inverse drifts as kappa grows — matching the paper's design point
that selective (block) inversion preserves stability.

Part 2 — precision-policy x n0 sweep (DESIGN.md Sec. 7): run the real
device-resident pipeline (core.trsm through the compiled-solver cache)
at every precision preset, recording relative residual, refinement trip
count, and steady-state per-solve latency.  The acceptance bar asserted
here: at n >= 1024 the bf16_refine residual lands within 10x of the
pure-fp32 solve — the MXU-native sweep serves fp32-grade answers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def make_tril_with_cond(n, cond, seed=0):
    """Lower-triangular with controlled condition: D(I + N) with small
    strictly-lower N (kappa(I+N) modest) and a graded diagonal spanning
    the target range, so kappa(L) ~ cond."""
    rng = np.random.default_rng(seed)
    N = np.tril(rng.standard_normal((n, n)), -1) * (0.5 / n)
    d = np.logspace(0, -np.log10(cond), n)
    return (np.diag(d) @ (np.eye(n) + N)).astype(np.float64)


def run(report):
    from repro.core import blocked

    jax.config.update("jax_enable_x64", False)   # stress in f32
    n, k, n0 = 256, 32, 32
    rows = []
    for cond in [1e1, 1e3, 1e5, 1e7]:
        L64 = make_tril_with_cond(n, cond)
        rng = np.random.default_rng(1)
        X64 = rng.standard_normal((n, k))
        B64 = L64 @ X64
        L = jnp.asarray(L64, jnp.float32)
        B = jnp.asarray(B64, jnp.float32)

        x_sub = np.asarray(
            jax.scipy.linalg.solve_triangular(L, B, lower=True), np.float64)
        x_inv_blk = np.asarray(
            blocked.it_inv_trsm_local(L, B, n0), np.float64)
        li = blocked.tri_inv_doubling(L)
        x_full = np.asarray(li @ B, np.float64)

        def err(x):
            return np.linalg.norm(x - X64) / np.linalg.norm(X64)

        rows.append(dict(cond=cond, sub=err(x_sub), blk=err(x_inv_blk),
                         full=err(x_full)))
        report(f"kappa={cond:.0e}: substitution={err(x_sub):.2e}  "
               f"block-inv(n0={n0})={err(x_inv_blk):.2e}  "
               f"full-inv={err(x_full):.2e}")
    # block inversion stays within ~100x of substitution error
    for r in rows:
        if r["sub"] > 0:
            assert r["blk"] < max(200 * r["sub"], 1e-4), r
    report("block-inversion error tracks substitution across kappa (OK)")

    rows += run_policy_sweep(report)
    return rows


def run_policy_sweep(report):
    """Precision-policy x n0 sweep through the serving pipeline."""
    import time

    from repro import core
    from repro.core import grid as gridlib

    x64_was = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)   # fp64_refine needs it
    rows = []
    try:
        grid = gridlib.make_trsm_mesh(1, 1)
        policies = ["fp32", "bf16", "bf16_refine", "fp64_refine"]
        for n, n0s in [(256, [32, 64]), (1024, [64, 128])]:
            k = 32
            rng = np.random.default_rng(n)
            L64 = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
            B64 = rng.standard_normal((n, k))
            for n0 in n0s:
                res = {}
                for pol in policies:
                    in_dt = np.float64 if pol == "fp64_refine" \
                        else np.float32
                    sess = core.Solver.from_factor(
                        L64.astype(in_dt), grid, method="inv", n0=n0,
                        precision=pol)
                    sess.warmup(k)
                    B = sess.place_rhs(B64.astype(in_dt))
                    X = np.asarray(sess.solve(B, donate=False)[0],
                                   np.float64)
                    rr = (np.linalg.norm(L64 @ X - B64)
                          / np.linalg.norm(B64))
                    reps = 5
                    t0 = time.perf_counter()
                    for _ in range(reps):
                        out = sess.solve(B, donate=False)
                    jax.block_until_ready(out)
                    ms = (time.perf_counter() - t0) / reps * 1e3
                    res[pol] = rr
                    rows.append(dict(part="policy", n=n, k=k, n0=n0,
                                     policy=pol, relres=rr,
                                     refine_steps=sess.policy.refine_steps,
                                     solve_ms=ms))
                    report(f"n={n} n0={n0} {pol:12s}: relres={rr:.2e}  "
                           f"steps={sess.policy.refine_steps}  "
                           f"{ms:7.2f} ms/solve")
                # acceptance: bf16_refine within 10x of pure fp32
                if n >= 1024:
                    assert res["bf16_refine"] < 10 * res["fp32"], res
        report("bf16_refine within 10x of fp32 residual at n>=1024 (OK)")
    finally:
        jax.config.update("jax_enable_x64", x64_was)
    return rows
