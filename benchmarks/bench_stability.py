"""Numerical stability of inversion-based TRSM (the Du Croz/Higham
claim the paper's Sec. I leans on: triangular inversion is stable,
unlike general inversion).

Sweep condition number kappa(L); compare forward error of:
  * substitution TRSM (baseline),
  * It-Inv-TRSM with diagonal-block inversion (the paper: only n0-sized
    blocks are inverted),
  * full-inverse multiply X = L^{-1} B (what the paper's blocking
    AVOIDS for large n).

Expected: block-inversion tracks substitution closely across kappa; the
full inverse drifts as kappa grows — matching the paper's design point
that selective (block) inversion preserves stability."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def make_tril_with_cond(n, cond, seed=0):
    """Lower-triangular with controlled condition: D(I + N) with small
    strictly-lower N (kappa(I+N) modest) and a graded diagonal spanning
    the target range, so kappa(L) ~ cond."""
    rng = np.random.default_rng(seed)
    N = np.tril(rng.standard_normal((n, n)), -1) * (0.5 / n)
    d = np.logspace(0, -np.log10(cond), n)
    return (np.diag(d) @ (np.eye(n) + N)).astype(np.float64)


def run(report):
    from repro.core import blocked

    jax.config.update("jax_enable_x64", False)   # stress in f32
    n, k, n0 = 256, 32, 32
    rows = []
    for cond in [1e1, 1e3, 1e5, 1e7]:
        L64 = make_tril_with_cond(n, cond)
        rng = np.random.default_rng(1)
        X64 = rng.standard_normal((n, k))
        B64 = L64 @ X64
        L = jnp.asarray(L64, jnp.float32)
        B = jnp.asarray(B64, jnp.float32)

        x_sub = np.asarray(
            jax.scipy.linalg.solve_triangular(L, B, lower=True), np.float64)
        x_inv_blk = np.asarray(
            blocked.it_inv_trsm_local(L, B, n0), np.float64)
        li = blocked.tri_inv_doubling(L)
        x_full = np.asarray(li @ B, np.float64)

        def err(x):
            return np.linalg.norm(x - X64) / np.linalg.norm(X64)

        rows.append(dict(cond=cond, sub=err(x_sub), blk=err(x_inv_blk),
                         full=err(x_full)))
        report(f"kappa={cond:.0e}: substitution={err(x_sub):.2e}  "
               f"block-inv(n0={n0})={err(x_inv_blk):.2e}  "
               f"full-inv={err(x_full):.2e}")
    # block inversion stays within ~100x of substitution error
    for r in rows:
        if r["sub"] > 0:
            assert r["blk"] < max(200 * r["sub"], 1e-4), r
    report("block-inversion error tracks substitution across kappa (OK)")
    return rows
