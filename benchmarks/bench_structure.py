"""Structured vs dense steady-state solves (DESIGN.md Sec. 14).

Quantifies the StructuredFactor layer: a banded factor admitted with
``FactorStructure.banded(n // 8)`` against the same factor served
dense, at MATCHED block size n0 so the comparison isolates the
structure machinery (level-scheduled sweep, statically narrowed
trailing updates, skipped collectives) from block-size tuning.  At
n = 512, n0 = 64, bandwidth 64, only the main and first sub
block-diagonals are nonzero — off-diagonal fill 7/28 = 0.25 — and
every trailing update touches one block row instead of up to seven.

The run ASSERTS the acceptance bar — banded steady-state solve >= 2x
faster than dense at n = 512, bandwidth n/8, on one device — and that
the structured result matches the dense solve of the same (masked)
operator to solver precision.

Each run also appends a trajectory point to the committed
``benchmarks/BENCH_structure.json`` (date, per-solve latencies,
speedup, modeled speedup) so the structured win is tracked across
PRs.  Set ``BENCH_STRUCTURE_SMOKE=1`` (the weekly CI job does) for a
reduced-rep run that skips the trajectory write.

Run standalone or via ``python -m benchmarks.run structure``.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

N, N0, K, C = 512, 64, 64, 4
BW = N // 8
SMOKE = bool(int(os.environ.get("BENCH_STRUCTURE_SMOKE", "0")))
TRAJECTORY = os.path.join(os.path.dirname(__file__),
                          "BENCH_structure.json")


def _banded_factor(rng, n=N, bw=BW):
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    rows, cols = np.indices((n, n))
    return np.where(rows - cols <= bw, L, 0.0).astype(np.float32)


def _time_solves(solver, Bs, passes):
    """Min-of-passes per-solve seconds over a pre-placed RHS cycle
    (timeit hygiene: the minimum is the least noise-contaminated
    estimate on a busy host)."""
    import jax
    best = float("inf")
    for _ in range(passes):
        t0 = time.perf_counter()
        outs = [solver.solve(b) for b in Bs]
        jax.block_until_ready(outs)
        best = min(best, (time.perf_counter() - t0) / len(Bs))
    return best


def _bench_banded_vs_dense(report):
    from repro import api

    grid = api.make_trsm_mesh(1, 1)
    rng = np.random.default_rng(0)
    st = api.FactorStructure.banded(BW)
    Ls = [_banded_factor(rng) for _ in range(C)]
    reps, passes = (2, 2) if SMOKE else (6, 3)

    solvers = {}
    for name, structure in (("dense", None), ("banded", st)):
        bank = api.FactorBank(grid, N, n0=N0, capacity=C,
                              structure=structure, dtype=np.float32)
        solver = api.Solver.from_bank(bank).warmup(K)
        for L in Ls:
            bank.admit(L)
        solvers[name] = solver

    # correctness first: same factors, same operator (the band mask is
    # a no-op on an already-banded factor), answers must agree
    probe = rng.standard_normal((C, N, K)).astype(np.float32)
    Xd = np.asarray(solvers["dense"].solve(
        solvers["dense"].place_rhs(probe.copy())))
    Xs = np.asarray(solvers["banded"].solve(
        solvers["banded"].place_rhs(probe.copy())))
    err = float(np.max(np.abs(Xd - Xs)) / max(1.0, np.max(np.abs(Xd))))
    assert err < 1e-5, f"structured solve diverged from dense: {err}"

    times = {}
    for name, solver in solvers.items():
        # solve() donates its RHS, so each timing pass re-places; keep
        # placement outside the timed region via a fresh cycle per pass
        def cycle():
            return [solver.place_rhs(
                rng.standard_normal((C, N, K)).astype(np.float32))
                for _ in range(reps)]
        solver.solve(solver.place_rhs(probe.copy()))     # settle
        best = float("inf")
        for _ in range(passes):
            Bs = cycle()
            best = min(best, _time_solves(solver, Bs, 1))
        times[name] = best

    speedup = times["dense"] / times["banded"]
    modeled = _modeled_speedup()
    report(f"n={N} n0={N0} bw={BW} C={C} k={K}: dense "
           f"{times['dense'] * 1e3:7.3f} ms/solve | banded "
           f"{times['banded'] * 1e3:7.3f} ms/solve | {speedup:5.2f}x "
           f"(modeled sweep {modeled:4.2f}x)")
    assert speedup >= 2.0, (
        f"acceptance: banded (bandwidth n/8) steady-state solve must "
        f"be >= 2x faster than dense at n = {N}, got {speedup:.2f}x")
    return dict(n=N, n0=N0, bandwidth=BW, capacity=C, k=K,
                dense_ms=times["dense"] * 1e3,
                banded_ms=times["banded"] * 1e3,
                speedup=speedup, modeled_speedup=modeled)


def _modeled_speedup():
    """The cost model's own prediction for the same comparison (the
    honest-pricing contract: the model prices exactly what runs)."""
    from repro.core import cost_model as cm
    from repro.core.structure import FactorStructure

    st = FactorStructure.banded(BW)
    machine = cm.tpu_v5e()
    td = cm.it_inv_trsm_steady_cost(N, K, N0, 1, 1).time(machine)
    ts = cm.it_inv_trsm_steady_cost(N, K, N0, 1, 1,
                                    structure=st).time(machine)
    return td / ts


def _record_trajectory(point):
    """Append a dated point to the committed trajectory file (the
    cross-PR record of the structured win)."""
    traj = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY) as f:
            traj = json.load(f).get("trajectory", [])
    date = time.strftime("%Y-%m-%d")
    traj = [p for p in traj if p.get("date") != date] + \
        [dict(date=date, **point)]
    with open(TRAJECTORY, "w") as f:
        json.dump({"bench": "structure", "trajectory": traj}, f,
                  indent=1)
        f.write("\n")


def run(report):
    row = _bench_banded_vs_dense(report)
    if not SMOKE:
        _record_trajectory({k: round(v, 3) if isinstance(v, float)
                            else v for k, v in row.items()})
        report(f"trajectory point appended to {TRAJECTORY}")
    return row


if __name__ == "__main__":
    run(print)
