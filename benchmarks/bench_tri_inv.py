"""Paper Sec. V validation: triangular-inversion communication costs.

Traces the distributed bottom-up inversion and compares against the
paper's closed form  W = nu * (n^2/(8 p1^2) + n^2/(2 p1 p2)),
S = O(log^2 p).  Our batched-doubling schedule has a slightly different
constant (all p processors cooperate on every level instead of the
paper's shrinking subgrids — see DESIGN.md Sec. 8.3); the bench reports
both and asserts we are within the paper's constant."""

from __future__ import annotations

import math

import numpy as np


def run(report):
    import jax
    from repro.core import comm, cost_model as cm, grid as gridlib, tri_inv

    rows = []
    for (p1, p2, n) in [(2, 2, 512), (2, 2, 1024), (1, 8, 512),
                        (2, 1, 512)]:
        p = p1 * p1 * p2
        if p > len(jax.devices()):
            continue
        grid = gridlib.make_trsm_mesh(p1, p2)
        fn = tri_inv.tri_inv_fn(grid, n)
        t = comm.traced_cost(fn, jax.ShapeDtypeStruct((n, n), np.float32))
        model = cm.tri_inv_cost(n, p1, p2)
        ratio = t.w / max(model.w, 1)
        rows.append(dict(p1=p1, p2=p2, n=n, traced_w=t.w, paper_w=model.w,
                         traced_s=t.s, paper_s=model.s, w_ratio=ratio))
        report(f"tri-inv p1={p1} p2={p2} n={n}: "
               f"W traced={t.w:.0f} paper={model.w:.0f} "
               f"(ratio {ratio:.2f})  S traced={t.s:.0f} "
               f"paper~log^2p={model.s:.0f}")
        # within the paper's leading constant x2, latency polylog
        assert t.w < 2.5 * model.w + n, (t.w, model.w)
        assert t.s <= 10 * math.log2(p) ** 2 + 20
    report("traced inversion costs within the Sec. V closed forms (OK)")
    return rows
