"""Paper Sec. VIII validation: regime selection and a-priori optimal
parameters (p1, p2, n0, r1, r2) across the (n, k, p) space, from the
closed forms and from the feasibility-snapped argmin tuner."""

from __future__ import annotations

import math


def run(report):
    from repro.core import tuning

    rows = []
    cases = [
        # (n, k, p) spanning the three regimes of Fig. 1
        (1 << 10, 1 << 16, 512),       # n < 4k/p       -> 1D
        (1 << 14, 1 << 10, 64),        # middle         -> 3D
        (1 << 16, 1 << 10, 64),        # hmm boundary
        (1 << 18, 1 << 8, 64),         # n > 4k sqrt(p) -> 2D
        (1 << 14, 1 << 14, 256),       # square         -> 3D
    ]
    for (n, k, p) in cases:
        t = tuning.tuning_table(n, k, p)
        ideal, plan = t["ideal"], t["plan"]
        rows.append(dict(n=n, k=k, p=p, regime=ideal["regime"],
                         ideal_p1=ideal["p1"], plan_p1=plan["p1"],
                         ideal_n0=ideal["n0"], plan_n0=plan["n0"],
                         r1=plan["r1"], r2=plan["r2"]))
        report(f"n=2^{int(math.log2(n))} k=2^{int(math.log2(k))} p={p}: "
               f"regime={ideal['regime']} "
               f"ideal p1={ideal['p1']:.1f} n0={ideal['n0']:.0f} | "
               f"snapped p1={plan['p1']} p2={plan['p2']} n0={plan['n0']} "
               f"r1={plan['r1']} r2={plan['r2']}")
        # feasibility invariants
        assert plan["p1"] ** 2 * plan["p2"] == p
        assert n % plan["n0"] == 0
    # regime boundaries behave per Sec. VIII
    assert tuning.regime(10, 1 << 16, 512) == "1d"
    assert tuning.regime(1 << 18, 1 << 8, 64) == "2d"
    report("regime boundaries OK (n<4k/p -> 1D, n>4k sqrt(p) -> 2D)")
    return rows
