"""Live bank mutation: in-place replace vs rebuild-and-readmit.

Quantifies the live-mutable FactorBank (DESIGN.md Sec. 11).  The
workload is the churn pattern the paper's hoisting argument targets —
a KFAC preconditioner that re-factorizes every step, a tenant whose
model turns over — where ONE factor of a C-wide resident bank changes
per update.  Two ways to apply the update:

  rebuild  — the append-only world (PRs 3-4): banks cannot mutate, so
             every update rebuilds the whole pool — a fresh bank,
             the full (C, n, n) natural stack re-uploaded from host
             and re-admitted (stacked gather + stacked phase-1
             inversion for all C factors), even though C-1 of them
             did not change.
  replace  — ``bank.replace(slot, L)``: ONE compiled donated program
             re-runs the single-factor admission pipeline (gather +
             dtype casts + hoisted phase 1) and scatters the factor's
             roles into the preallocated resident stacks in place.
             The a-priori point: admission work is O(1) factors per
             update, not O(C), and the compiled solve program (keyed
             on the capacity C, not the occupancy) never changes.

The run ASSERTS the acceptance bar — in-place replace >= 5x faster
per update than rebuild-and-readmit at n = 256, C = 16 on one device —
and the churn steady state: an interleaved churn-and-solve schedule
(solve, replace, solve, evict + re-admit, solve) under
``jax.transfer_guard("disallow")`` with TRACE_COUNTS pinned, for EVERY
precision preset at occupancies 1, C/2, and C.  All occupancies share
ONE compiled solve program and ONE compiled updater per preset.

Each run also appends a trajectory point to the committed
``benchmarks/BENCH_update.json`` (date, per-update latencies, speedup)
so the update-path cost is tracked across PRs.  Set
``BENCH_UPDATE_SMOKE=1`` (the weekly CI job does) for a reduced-rep
run that skips the trajectory write.

Run standalone or via ``python -m benchmarks.run update``.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

C, N, K = 16, 256, 16
PRESETS = ["fp32", "bf16", "bf16_refine", "fp64_refine"]
SMOKE = bool(int(os.environ.get("BENCH_UPDATE_SMOKE", "0")))
TRAJECTORY = os.path.join(os.path.dirname(__file__), "BENCH_update.json")


def _factors(rng, count=C, n=N, dtype=np.float32):
    return np.stack([
        np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
        for _ in range(count)]).astype(dtype)


def _time_updates(fn, updates, ready, passes=3):
    """Min-of-passes per-update seconds (timeit hygiene: the minimum is
    the least noise-contaminated estimate on a busy host)."""
    import jax
    best = float("inf")
    for _ in range(passes):
        t0 = time.perf_counter()
        for j in range(updates):
            fn(j)
        jax.block_until_ready(ready())
        best = min(best, (time.perf_counter() - t0) / updates)
    return best


def _bench_replace_vs_rebuild(report):
    import jax
    from repro import api

    grid = api.make_trsm_mesh(1, 1)
    rng = np.random.default_rng(0)
    Ls = _factors(rng)
    updates, passes = (4, 2) if SMOKE else (10, 3)
    fresh = _factors(rng, count=updates)

    # the mutable world: capacity bank, one in-place replace per update
    bank = api.FactorBank(grid, N, capacity=C, dtype=np.float32)
    bank.admit_stack(Ls)
    bank.replace(0, fresh[0])                   # compile the updater
    t_replace = _time_updates(
        lambda j: bank.replace(j % C, fresh[j % updates]),
        updates, lambda: bank.factors_cyclic, passes)

    # the append-only world (PRs 3-4, faithfully: no capacity
    # machinery): every update rebuilds the pool from host — a fresh
    # append-only bank, the full stack re-admitted in its fastest form
    # (ONE stacked gather + ONE stacked phase 1)
    def rebuild(j):
        Ls[j % C] = fresh[j % updates]
        b = api.FactorBank(grid, N, dtype=np.float32)
        b.admit_stack(Ls)
        rebuild.bank = b
    rebuild(0)                                  # settle the programs
    t_rebuild = _time_updates(
        rebuild, updates, lambda: rebuild.bank.factors_cyclic, passes)

    speedup = t_rebuild / t_replace
    report(f"n={N} C={C}: rebuild-and-readmit {t_rebuild * 1e3:7.3f} "
           f"ms/update | in-place replace {t_replace * 1e3:7.3f} "
           f"ms/update | {speedup:5.1f}x")
    assert speedup >= 5.0, (
        f"acceptance: in-place replace must be >= 5x faster per update "
        f"than rebuild-and-readmit, got {speedup:.1f}x")
    return dict(n=N, capacity=C, updates=updates,
                rebuild_ms_per_update=t_rebuild * 1e3,
                replace_ms_per_update=t_replace * 1e3, speedup=speedup)


def _assert_churn_steady_state(report):
    """Zero transfers / zero retraces across an interleaved
    churn-and-solve schedule, every preset, occupancies 1, C/2, C."""
    import jax
    from repro import api
    from repro.core import session

    presets = ["fp32"] if SMOKE else PRESETS
    x64_was = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)   # fp64_refine needs it
    try:
        grid = api.make_trsm_mesh(1, 1)
        rng = np.random.default_rng(1)
        rows = {}
        for preset in presets:
            dt = np.float64 if preset == "fp64_refine" else np.float32
            keys = set()
            for occ in (1, C // 2, C):
                bank = api.FactorBank(grid, N, capacity=C,
                                      precision=preset)
                solver = api.Solver.from_bank(bank).warmup(K)
                for L in _factors(rng, count=occ, dtype=dt):
                    bank.admit(L)
                key, uspec = solver.spec_for(K), bank.update_spec()
                keys.add((key, uspec))
                traces = (session.TRACE_COUNTS[key],
                          session.TRACE_COUNTS[uspec])
                live = bank.live_slots()
                placed_L = [bank.place_factor(L) for L in
                            _factors(rng, count=3, dtype=dt)]
                placed_B = [solver.place_rhs(
                    rng.standard_normal((C, N, K)).astype(dt))
                    for _ in range(3)]
                with jax.transfer_guard("disallow"):
                    solver.solve(placed_B[0])
                    solver.replace_factor(int(live[0]), placed_L[0])
                    solver.solve(placed_B[1])
                    solver.evict_factor(int(live[-1]))
                    readmitted = solver.admit_factor(placed_L[1])
                    assert readmitted == live[-1], (readmitted, live)
                    solver.solve(placed_B[2])
                assert (session.TRACE_COUNTS[key],
                        session.TRACE_COUNTS[uspec]) == traces, \
                    (preset, occ, "retraced")
            # capacity keying: every occupancy shared ONE solve program
            # and ONE updater
            assert len(keys) == 1, (preset, len(keys))
            rows[preset] = "ok"
            report(f"churn steady state [{preset}]: occupancies "
                   f"(1, {C // 2}, {C}) share 1 program + 1 updater; "
                   f"0 transfers, 0 retraces across solve/replace/"
                   f"evict/re-admit")
        return rows
    finally:
        jax.config.update("jax_enable_x64", x64_was)


def _record_trajectory(point):
    """Append a dated point to the committed trajectory file (the
    cross-PR record of the update path's cost)."""
    traj = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY) as f:
            traj = json.load(f).get("trajectory", [])
    date = time.strftime("%Y-%m-%d")
    traj = [p for p in traj if p.get("date") != date] + \
        [dict(date=date, **point)]
    with open(TRAJECTORY, "w") as f:
        json.dump({"bench": "update", "trajectory": traj}, f, indent=1)
        f.write("\n")


def run(report):
    latency = _bench_replace_vs_rebuild(report)
    steady = _assert_churn_steady_state(report)
    if not SMOKE:
        _record_trajectory({k: round(v, 3) if isinstance(v, float) else v
                            for k, v in latency.items()})
        report(f"trajectory point appended to {TRAJECTORY}")
    return dict(latency=latency, steady_state=steady)


if __name__ == "__main__":
    run(print)
