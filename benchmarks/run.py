"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [names...]

Re-execs itself with 8 forced host devices so traced distributed
benches run in-process; writes benchmarks/results.json."""

from __future__ import annotations

import json
import os
import sys
import time

_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                               + _FLAG).strip()
    os.execv(sys.executable, [sys.executable, "-m", "benchmarks.run"]
             + sys.argv[1:])

BENCHES = [
    ("mm_costs", "Sec. III MM cost table", "benchmarks.bench_mm_costs"),
    ("tri_inv", "Sec. V inversion costs", "benchmarks.bench_tri_inv"),
    ("paper_table", "Sec. IX comparison table",
     "benchmarks.bench_paper_table"),
    ("tuning", "Sec. VIII tuning tables", "benchmarks.bench_tuning"),
    ("stability", "inversion stability (Du Croz/Higham)",
     "benchmarks.bench_stability"),
    ("gemm_fraction", "TPU MXU-eligible flop share",
     "benchmarks.bench_gemm_fraction"),
    ("serve_latency", "device-resident solve pipeline latency",
     "benchmarks.bench_serve_latency"),
    ("bank", "multi-factor batched serving (FactorBank)",
     "benchmarks.bench_bank"),
    ("update", "live bank mutation (in-place replace vs rebuild)",
     "benchmarks.bench_update"),
    ("fleet", "mixed-order serving (fleet buckets vs per-order banks)",
     "benchmarks.bench_fleet"),
    ("structure", "structured factors (banded vs dense sweep)",
     "benchmarks.bench_structure"),
]


def main():
    import importlib

    want = sys.argv[1:]
    results = {}
    failures = 0
    for name, desc, mod in BENCHES:
        if want and name not in want:
            continue
        print(f"\n=== {name}: {desc} ===", flush=True)
        t0 = time.time()
        try:
            m = importlib.import_module(mod)
            rows = m.run(lambda s: print("  " + s, flush=True))
            results[name] = {"status": "ok", "rows": rows,
                             "seconds": round(time.time() - t0, 1)}
        except Exception as e:
            import traceback
            traceback.print_exc()
            results[name] = {"status": "error", "error": repr(e)}
            failures += 1
    out = os.path.join(os.path.dirname(__file__), "results.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"\nbenchmarks: {len(results) - failures}/{len(results)} ok; "
          f"results -> {out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
