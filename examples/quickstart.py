"""Quickstart: the paper's TRSM engine in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Solves L X = B with the It-Inv-TRSM algorithm (paper Secs. VI-VII) and
the recursive baseline (Sec. IV) on an 8-device grid (forced host
devices), checks them against each other, prints the Sec. VIII tuning
decision and the traced alpha-beta-gamma costs."""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro import core
from repro.core import comm, grid as gridlib, inv_trsm, rec_trsm, tuning


def main():
    n, k = 512, 128
    p1, p2 = 2, 2
    rng = np.random.default_rng(0)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    B = rng.standard_normal((n, k))

    # 1. a-priori tuning (paper Sec. VIII)
    plan = tuning.tune(n, k, p1 * p1 * p2)
    print(f"tuned: regime={plan.regime} grid={plan.grid} n0={plan.n0} "
          f"r1={plan.r1} r2={plan.r2}")

    # 2. solve with both algorithms
    grid = gridlib.make_trsm_mesh(p1, p2)
    X_inv = core.trsm(L, B, grid, method="inv")
    X_rec = core.trsm(L, B, grid, method="rec")
    ref = np.linalg.solve(L, B)
    print(f"It-Inv-TRSM error: {np.abs(X_inv - ref).max():.2e}")
    print(f"Rec-TRSM   error: {np.abs(X_rec - ref).max():.2e}")

    # 3. mixed precision: bf16 sweep + on-device iterative refinement
    #    recovers fp32 accuracy (precision="bf16_refine"; DESIGN.md
    #    Sec. 7) — same compiled-program pipeline, MXU-native GEMMs
    X_bf = core.trsm(L.astype(np.float32), B.astype(np.float32), grid,
                     method="inv", precision="bf16_refine")
    print(f"bf16_refine error: {np.abs(np.asarray(X_bf, np.float64) - ref).max():.2e}")

    # 4. traced communication costs (the paper's S/W/F, measured)
    n0 = plan.n0
    fi = inv_trsm.it_inv_trsm_fn(grid, n, k, n0, np.float64)
    ti = comm.traced_cost(fi, jax.ShapeDtypeStruct((n, n), np.float64),
                          jax.ShapeDtypeStruct((n, k), np.float64))
    fr = rec_trsm.rec_trsm_fn(grid, n, k)
    tr = comm.traced_cost(fr, jax.ShapeDtypeStruct((n, n), np.float64),
                          jax.ShapeDtypeStruct((n, k), np.float64))
    print(f"traced It-Inv: S={ti.s:.0f} messages, W={ti.w:.0f} words")
    print(f"traced Rec   : S={tr.s:.0f} messages, W={tr.w:.0f} words")
    print(f"latency improvement: {tr.s / max(ti.s, 1):.2f}x "
          f"(paper: Theta((n/k)^1/6 p^2/3) in the 3D regime)")


if __name__ == "__main__":
    main()
