"""Serving driver: batched prefill + greedy decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --new-tokens 16

Demonstrates the single-code-path prefill (decode_step with S=prompt
length) and per-step decode, with simple continuous batching: finished
sequences are replaced from a request queue.

Also demos the paper's serving workload (--serve-solves N): a
repro.api.Solver holds a triangular factor resident in cyclic device
storage and a SolveServer serves batched solve requests through the
same continuous-batching pattern — the steady state is pure device
work (zero host transfers, zero retraces).

--serve-fleet takes that one step further (DESIGN.md Sec. 12): the
model's per-layer factor SPECTRUM (mixed orders) is bucketed by the
fleet's cost-model planner, and one SolveServer over the SolverFleet
serves requests addressed by (tenant, order) — one dispatch per
BUCKET per wave instead of one per order.

--serve-traffic N closes the loop on production serving (DESIGN.md
Sec. 13): N requests submitted OPEN-loop to an AsyncSolveServer's
background drain loop — callers get SolveFuture handles back
immediately and block only on their own result, while waves pack and
dispatch on the serving thread."""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--serve-solves", type=int, default=8,
                    help="also serve this many TRSM solve requests "
                         "against a device-resident factor (0 = off)")
    ap.add_argument("--solve-n", type=int, default=128)
    ap.add_argument("--solve-precision", default="fp32",
                    choices=["fp32", "bf16", "bf16_refine"],
                    help="precision policy for the solve workload "
                         "(bf16_refine: MXU-native sweep, fp32 answers)")
    ap.add_argument("--serve-traffic", type=int, default=12,
                    help="open-loop async solve requests to serve "
                         "through AsyncSolveServer's background drain "
                         "loop (0 disables)")
    ap.add_argument("--serve-fleet", type=int, default=2,
                    help="serve this many mixed-order solve waves "
                         "through a planner-bucketed SolverFleet "
                         "(0 = off)")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get(args.arch)
    params = lm.init(cfg, jax.random.key(0))
    B, P = args.batch, args.prompt_len
    max_seq = P + args.new_tokens

    prefill = jax.jit(
        lambda p, t, c: lm.decode_step(p, cfg, t, c))
    decode = jax.jit(
        lambda p, t, c: lm.decode_step(p, cfg, t, c))

    rng = np.random.default_rng(0)
    queue = [jnp.asarray(rng.integers(0, cfg.vocab, (1, P)))
             for _ in range(args.requests)]
    done = 0
    t0 = time.time()
    tokens_out = 0

    while done < args.requests:
        wave = queue[done:done + B]
        if len(wave) < B:
            wave += [wave[-1]] * (B - len(wave))
        prompts = jnp.concatenate(wave, axis=0)
        cache = lm.init_cache(cfg, B, max_seq)
        logits, cache = prefill(params, prompts, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        outs = [tok]
        for _ in range(args.new_tokens - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits[:, -1:], axis=-1)
            outs.append(tok)
        gen = jnp.concatenate(outs, axis=1)
        n = min(B, args.requests - done)
        for i in range(n):
            print(f"req {done + i}: prompt[:8]="
                  f"{np.asarray(wave[i])[0, :8].tolist()} -> "
                  f"gen[:8]={np.asarray(gen)[i, :8].tolist()}")
        tokens_out += n * args.new_tokens
        done += n

    dt = time.time() - t0
    print(f"served {args.requests} requests, {tokens_out} tokens "
          f"in {dt:.2f}s ({tokens_out / dt:.1f} tok/s)")

    if args.serve_solves:
        serve_solves(args)
    if args.serve_fleet:
        serve_fleet(args)
    if args.serve_traffic:
        serve_traffic(args)


def serve_solves(args):
    """Continuous batching for the paper's workload: solve requests
    against a factor held resident in cyclic device storage."""
    from repro import api

    n = args.solve_n
    rng = np.random.default_rng(1)
    L = (np.tril(rng.standard_normal((n, n)))
         + n * np.eye(n)).astype(np.float32)
    solver = api.Solver.from_factor(L, api.make_trsm_mesh(1, 1),
                                    method="inv",
                                    precision=args.solve_precision)
    server = api.SolveServer(solver, panel_k=8).warmup()
    t0 = time.time()
    for _ in range(args.serve_solves):
        server.submit(jnp.asarray(rng.standard_normal((n,))))
    outs = server.drain()[0]
    jax.block_until_ready(outs[-1])
    dt = time.time() - t0
    policy = solver.policy
    print(f"served {server.requests_served} solve requests "
          f"(n={n}, precision={policy.name}) in "
          f"{server.panels_solved} panels, {dt:.3f}s — "
          f"factor resident on device, steady state transfer-free")


def serve_traffic(args):
    """Async open-loop serving: submit returns a SolveFuture at once;
    the background drain loop packs fair waves and resolves futures
    as each wave finalizes (DESIGN.md Sec. 13)."""
    from repro import api

    n = args.solve_n
    rng = np.random.default_rng(3)
    L = (np.tril(rng.standard_normal((n, n)))
         + n * np.eye(n)).astype(np.float32)
    solver = api.Solver.from_factor(L, api.make_trsm_mesh(1, 1),
                                    method="inv",
                                    precision=args.solve_precision)
    server = api.AsyncSolveServer(solver, panel_k=8, queue_depth=64,
                                  slo_ms=100.0).warmup()
    t0 = time.time()
    with server:                          # background drain loop
        futs = [server.submit(
            jnp.asarray(rng.standard_normal((n,))
                        .astype(np.float32)),
            tenant=f"user{i % 3}")        # fair-shared panel
            for i in range(args.serve_traffic)]
        outs = [f.result(timeout=60) for f in futs]
    dt = time.time() - t0
    st = server.stats()
    assert all(x.shape == (n, 1) for x in outs)
    print(f"async-served {st['served']} open-loop requests from "
          f"{min(args.serve_traffic, 3)} tenants in {st['waves']} "
          f"waves, {dt:.3f}s — p50 {st['p50_ms']:.2f} ms, p99 "
          f"{st['p99_ms']:.2f} ms, shed {st['shed']}, "
          f"{st['slo_violations']} SLO violations")


def serve_fleet(args):
    """The mixed-order tier: a model's whole factor spectrum served
    through planner-chosen buckets, addressed by (tenant, order)."""
    from repro import api

    n = args.solve_n
    orders = [n, n // 2, n // 4]
    grid = api.make_trsm_mesh(1, 1)
    plan = api.plan_fleet({d: 1 for d in orders}, grid, k=8)
    print(f"fleet plan: {len(orders)} orders -> "
          f"{len(plan.buckets)} bucket(s)")
    print(plan.table())
    fleet = api.SolverFleet(grid, plan)
    rng = np.random.default_rng(2)
    Ls = {}
    for d in orders:
        Ls[d] = (np.tril(rng.standard_normal((d, d)))
                 + d * np.eye(d)).astype(np.float32)
        fleet.admit(Ls[d], tenant="lm", tag=d)
    server = api.SolveServer(fleet, panel_k=8).warmup()
    t0 = time.time()
    for _ in range(args.serve_fleet):
        for d in orders:
            server.submit(rng.standard_normal((d,)).astype(np.float32),
                          tenant="lm", tag=d)
        outs = server.drain()
    for d in orders:
        X = outs[("lm", d)][-1]
        assert X.shape == (d, 1), X.shape
    jax.block_until_ready(X)
    dt = time.time() - t0
    st = fleet.stats()
    print(f"served {server.requests_served} mixed-order requests "
          f"({orders}) in {server.waves_solved} bucket dispatches, "
          f"{dt:.3f}s — per-order serving would have paid "
          f"{args.serve_fleet * len(orders)}; fleet hit_rate="
          f"{st['hit_rate']:.2f}")


if __name__ == "__main__":
    main()
