"""End-to-end training driver: ~100M-parameter LM for a few hundred
steps on the synthetic pipeline, with checkpoint/restart and the
KFAC-CA (CA-TRSM-preconditioned) optimizer available.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --arch smollm-360m \
        --optimizer kfac_ca --steps 50 --smoke

--smoke uses the reduced config (CI-speed); the default preset is a
~134M model.  Restart mid-run with the same --ckpt dir to resume
bit-exactly (see also examples/ft_demo in tests/test_substrate.py)."""

import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import configs, optim
from repro.configs import ModelConfig
from repro.data import synthetic
from repro.models import lm
from repro.optim import schedules
from repro.train import checkpoint as ckpt

PRESET_100M = ModelConfig(
    name="preset-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv=4, d_ff=2048, vocab=32768, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="preset-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "kfac_ca"])
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.arch == "preset-100m":
        cfg = PRESET_100M
    elif args.smoke:
        cfg = configs.get_smoke(args.arch)
    else:
        cfg = configs.get(args.arch)
    print(f"arch={cfg.name} params~{cfg.param_count / 1e6:.0f}M "
          f"optimizer={args.optimizer}")

    lr = schedules.warmup_cosine(args.lr, warmup=20, total=args.steps)
    kw = dict(lr=lr)
    if args.optimizer == "kfac_ca":
        kw.update(max_dim=4096, update_freq=10)
    opt = optim.get(args.optimizer, **kw)

    # resume or init
    start = ckpt.latest_step(args.ckpt)
    params = lm.init(cfg, jax.random.key(0))
    state = opt.init(params)
    if start is not None:
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                            {"p": params, "s": state})
        restored, start = ckpt.restore(args.ckpt, start, like)
        params, state = restored["p"], restored["s"]
        print(f"resumed from step {start}")
    else:
        start = 0

    @jax.jit
    def step_fn(p, s, b):
        loss, g = jax.value_and_grad(
            lambda q: lm.loss_fn(q, cfg, b, dtype=jnp.float32))(p)
        p2, s2, m = opt.update(g, s, p)
        return p2, s2, loss, m

    pf = synthetic.Prefetcher(cfg, args.seq, args.batch, start_step=start)
    t0 = time.time()
    try:
        for i in range(start, args.steps):
            s_idx, batch = next(pf)
            assert s_idx == i
            params, state, loss, m = step_fn(params, state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                dt = time.time() - t0
                tok_s = args.batch * args.seq * args.log_every / max(dt, 1e-9)
                print(f"step {i:5d} loss {float(loss):.4f} "
                      f"lr {float(m['lr']):.2e} "
                      f"gnorm {float(m['grad_norm']):.2f} "
                      f"tok/s {tok_s:,.0f}")
                t0 = time.time()
            if (i + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt, i + 1, {"p": params, "s": state},
                          blocking=False)
    finally:
        pf.close()
    ckpt.save(args.ckpt, args.steps, {"p": params, "s": state})
    print(f"done; final checkpoint at step {args.steps} in {args.ckpt}")


if __name__ == "__main__":
    main()
