"""The paper end-to-end: distributed TRSM, triangular inversion,
Cholesky, Sec. VIII tuning and the Sec. IX comparison — on one page.

    PYTHONPATH=src python examples/trsm_demo.py
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import (cholesky, comm, cost_model as cm, grid as gridlib,
                        inv_trsm, lu, mm3d, rec_trsm, tri_inv, tuning)
from repro.kernels import ops


def main():
    rng = np.random.default_rng(0)
    n, k = 256, 64
    grid = gridlib.make_trsm_mesh(2, 2)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    B = rng.standard_normal((n, k))
    ref = np.linalg.solve(L, B)

    print("== distributed solvers (2x2x2 grid, 8 host devices) ==")
    X = inv_trsm.solve(L, B, grid, n0=32)
    print(f"It-Inv-TRSM (paper Secs. VI-VII): err="
          f"{np.abs(X - ref).max():.2e}")
    X = inv_trsm.solve(L, B, grid, n0=32, block_inv=ops.block_inv_kernel)
    print(f"It-Inv-TRSM + Pallas block-inverter: err="
          f"{np.abs(X - ref).max():.2e}")
    X = rec_trsm.solve(L, B, grid, n0=32)
    print(f"Rec-TRSM baseline (Sec. IV):      err="
          f"{np.abs(X - ref).max():.2e}")

    Li = tri_inv.invert(L, grid)
    print(f"RecTriInv (Sec. V):               err="
          f"{np.abs(Li @ L - np.eye(n)).max():.2e}")

    M = rng.standard_normal((n, n))
    A = M @ M.T + n * np.eye(n)
    C = cholesky.cholesky(A, grid)
    print(f"Cholesky via selective inversion: err="
          f"{np.abs(C @ C.T - A).max():.2e}")

    P = mm3d.matmul(L, B, grid)
    print(f"Sec. III 3D matmul:               err="
          f"{np.abs(P - L @ B).max():.2e}")

    Add = rng.standard_normal((n, n)) + n * np.eye(n)
    Lf, Uf = lu.lu(Add, grid)
    print(f"LU via selective inversion:       err="
          f"{np.abs(Lf @ Uf - Add).max():.2e}")

    print("\n== Sec. VIII a-priori tuning ==")
    for (nn, kk, p) in [(1 << 14, 1 << 10, 256), (1 << 12, 1 << 14, 256),
                        (1 << 17, 1 << 8, 256)]:
        plan = tuning.tune(nn, kk, p)
        print(f"n={nn} k={kk} p={p}: regime={plan.regime} "
              f"grid={plan.grid} n0={plan.n0}")

    print("\n== Sec. IX comparison (closed forms, p=512) ==")
    for nn in [1 << 12, 1 << 16, 1 << 19]:
        row = cm.paper_table_row(nn, 1 << 10, 512)
        s_ratio = row["standard"]["S"] / row["new"]["S"]
        print(f"n={nn}: regime={row['regime']} latency improvement "
              f"{s_ratio:.1f}x, bandwidth ratio "
              f"{row['standard']['W'] / row['new']['W']:.2f}x")


if __name__ == "__main__":
    main()
