"""Regenerate EXPERIMENTS.md from the dry-run artifacts
(experiments/dryrun/*.json), benchmark results (benchmarks/results.json)
and the perf-iteration log (experiments/perf_log.json).

    PYTHONPATH=src python experiments/make_report.py
"""

import glob
import json
import os

HERE = os.path.dirname(__file__)
ROOT = os.path.join(HERE, "..")


def load_cells():
    recs = [json.load(open(f))
            for f in sorted(glob.glob(os.path.join(HERE, "dryrun",
                                                   "*.json")))]
    base = [r for r in recs if "__" not in
            os.path.basename(r.get("arch", "")) and "kv_dtype" not in
            ("",) and True]
    # baseline cells have no tag: filenames arch__shape__mesh.json
    out = []
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun", "*.json"))):
        name = os.path.basename(f)[:-5]
        if name.count("__") == 2:
            out.append(json.load(open(f)))
    return out


def fmt_e(x):
    return f"{x:.2e}"


def dryrun_table(cells, mesh):
    lines = ["| arch | shape | status | chips | compile s | mem/dev GB "
             "| collective ops (HLO) |",
             "|---|---|---|---:|---:|---:|---|"]
    for r in cells:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | — | — "
                         f"| — | {r['reason'][:58]} |")
            continue
        mem = r.get("memory", {})
        mg = (mem.get("temp_size_in_bytes", 0)
              + mem.get("argument_size_in_bytes", 0)) / 1e9
        ops = ",".join(sorted(r.get("collectives", {}).keys())) or "none"
        lines.append(f"| {r['arch']} | {r['shape']} | ok | "
                     f"{r['n_chips']} | {r['compile_s']:.1f} | "
                     f"{mg:.1f} | {ops} |")
    return "\n".join(lines)


def roofline_table(cells):
    lines = ["| arch | shape | bottleneck | t_compute s | t_memory s | "
             "t_collective s | MODEL/HLO | roofline frac | one-line fix |",
             "|---|---|---|---:|---:|---:|---:|---:|---|"]
    fixes = {
        ("compute",): "already MXU-bound; fuse/quantify remat waste",
        ("memory",): "int8 KV cache / fewer weight streams (see Perf A)",
        ("collective",): "fewer microbatches (FSDP gathers) or TP "
                         "re-roling (see Perf B)",
    }
    for r in cells:
        if r["mesh"] != "single" or r["status"] != "ok":
            continue
        ro = r["roofline"]
        fix = fixes[(ro["bottleneck"],)]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['bottleneck']} | "
            f"{fmt_e(ro['t_compute'])} | {fmt_e(ro['t_memory'])} | "
            f"{fmt_e(ro['t_collective'])} | {ro['useful_ratio']:.2f} | "
            f"{ro['roofline_fraction']:.3f} | {fix} |")
    return "\n".join(lines)


def trsm_scale_section():
    path = os.path.join(HERE, "trsm_scale.json")
    if not os.path.exists(path):
        return "_run experiments/trsm_scale_dryrun.py first_"
    rows = json.load(open(path))
    lines = ["| algo | grid | n | k | n0 | compile s | traced S | "
             "traced W | temp/dev GB |",
             "|---|---|---:|---:|---:|---:|---:|---:|---:|"]
    for r in rows:
        lines.append(
            f"| {r['algo']} | {r['p1']}x{r['p1']}x{r['p2']} (p={r['p']})"
            f" | {r['n']} | {r['k']} | {r['n0']} | {r['compile_s']} | "
            f"{r['traced']['S']:.0f} | {r['traced']['W']:.2e} | "
            f"{r['temp_gb']:.2f} |")
    # latency ratios per (grid, k)
    pairs = {}
    for r in rows:
        pairs.setdefault((r["p"], r["k"]), {})[r["algo"]] = r
    extra = []
    for (p, k), d in pairs.items():
        if "it_inv" in d and "rec" in d:
            ratio = d["rec"]["traced"]["S"] / d["it_inv"]["traced"]["S"]
            extra.append(f"* p={p}, k={k}: traced latency improvement "
                         f"**{ratio:.1f}x** (It-Inv vs Rec)")
    return "\n".join(lines) + "\n\n" + "\n".join(extra)


def perf_section():
    path = os.path.join(HERE, "perf_log.json")
    if not os.path.exists(path):
        return "_run experiments/perf_hillclimb.py first_"
    log = json.load(open(path))
    out = []
    for cell, iters in log["cells"].items():
        out.append(f"\n### {cell}\n")
        for it in iters:
            tag = "CONFIRMED" if it["confirmed"] else "REFUTED"
            out.append(f"**{it['iteration']}** [{tag}]")
            out.append(f"- hypothesis: {it['hypothesis']}")
            out.append(f"- before: `{json.dumps(it['before'])}`")
            out.append(f"- after: `{json.dumps(it['after'])}`")
            out.append(f"- {it['note']}")
            out.append("")
    return "\n".join(out)


def bench_section():
    path = os.path.join(ROOT, "benchmarks", "results.json")
    if not os.path.exists(path):
        return "_run python -m benchmarks.run first_"
    res = json.load(open(path))
    lines = ["| bench | status | seconds |", "|---|---|---:|"]
    for name, r in res.items():
        lines.append(f"| {name} | {r['status']} | "
                     f"{r.get('seconds', '—')} |")
    return "\n".join(lines)


TEMPLATE = """# EXPERIMENTS

All artifacts regenerable: `experiments/dryrun/*.json` (via
`python -m repro.launch.dryrun`), `benchmarks/results.json` (via
`python -m benchmarks.run`), `experiments/perf_log.json` (via
`python experiments/perf_hillclimb.py`); this file via
`python experiments/make_report.py`.

## Paper-validation

The paper has no wall-clock experiments — its results ARE its cost
tables.  We validate them by *tracing the implementations*: every
collective in `repro.core` goes through `repro.core.comm`, which
records the paper's alpha-beta-gamma cost from static shapes at trace
time.  One benchmark per paper table:

{bench}

Key outcomes (see benchmarks/results.json for numbers):

* **Sec. III MM table**: traced W matches the closed form to the word
  (exact equality across 5 grid/shape combos); our mesh-native schedule
  drops the paper's two O(nk log p / p) rectangular-grid transposes.
* **Sec. V inversion**: traced W = 0.66–0.82x the paper's closed form —
  the SPMD batched-doubling schedule beats the shrinking-subgrid
  constant (beyond-paper); latency stays polylog.
* **Sec. IX comparison**: 3D-regime latency improvement reproduced
  (model 60x at n/k=64, p=512 vs the Theta((n/k)^{{1/6}}p^{{2/3}})=128
  prediction — same order), 2D bandwidth improvement = log2(p) exactly,
  1D parity with the predicted extra log p latency for inversion.
* **Stability** (Du Croz/Higham): block-inversion forward error tracks
  substitution across kappa(L) in 1e1..1e7 (f32); selective inversion
  is as stable as substitution for the block sizes the paper uses.
* **GEMM fraction** (TPU motivation): the inversion swap converts 100%
  of base-case substitution flops (VPU-serial, 0% MXU) into batched
  GEMMs with <1.1% inversion overhead at n0<=32 (13% at n0=128).

## Dry-run

`src/repro/launch/dryrun.py` lowers + compiles every (arch x shape)
cell with full production shardings (FSDP x TP x EP + sequence-sharded
KV caches) on both meshes, 512 forced host devices.  **All 40 cells x 2
meshes: 64 ok + 16 documented skips, 0 failures.**  Skips are exactly
the 8 full-attention archs x long_500k (quadratic-cost by definition)
x 2 meshes, per DESIGN.md Sec. 6.

### single pod (16 x 16 = 256 chips)

{dry_single}

### multi-pod (2 x 16 x 16 = 512 chips; proves the "pod" axis shards)

{dry_multi}

Memory note: `memory_analysis()` on the CPU backend reports the
partitioned module's buffer sizes; decode cells fit v5e HBM (e.g.
llama3-405b decode_32k: 8.6 GB/dev KV cache + 3.2 GB/dev params).
Small/mid train cells fit after the Perf-F memory sweep (vocab-over-TP
embedding, flash-backward remat, vocab padding); the 3 biggest archs'
train cells additionally need bf16 moments + deeper microbatching
(Perf cell D) and, for llama3-405b at 256 chips, optimizer offload or
the 512-chip mesh.

End-to-end evidence: `examples/train_lm.py` trained the ~134M preset
for 120 steps on the synthetic pipeline (loss 10.63 -> 10.48, ~21k
tok/s host CPU; log in `experiments/train_100m_log.txt`), with async
checkpoints and bit-exact restart (tests/test_substrate.py).

### The TRSM engine itself at pod scale

`experiments/trsm_scale_dryrun.py` lowers + compiles It-Inv-TRSM and
Rec-TRSM on 8x8x4 = 256 and 16x16x2 = 512 device grids (ShapeDtypeStruct
inputs, full cyclic-layout shard_map), with trace-time S/W recorded:

{trsm_scale}

The paper's headline — the pre-inversion algorithm needs an order of
magnitude fewer critical-path messages — is measured here at production
scale on the real lowered programs (the recursive baseline's S grows
with its n/n0 sequential base cases; It-Inv stays at
(n/n0) log p + log^2 p).

## Roofline (single pod, per step)

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI.  Terms from the ANALYTIC model (`repro.roofline.model`), which is
scan-trip-count-exact; XLA `cost_analysis()` counts while bodies once
and is kept in the artifacts as `compiled_raw` (the flop model is
validated against an UNROLLED compile in tests/test_roofline.py, within
30%).  Collective bytes of one scan iteration and the collective op set
come from the compiled HLO (`collectives` field).  MODEL/HLO =
useful-flops ratio = 6*N_matmul*D / analytic flops (N excludes the
embedding gather, so 1.00 means zero redundant compute).

{roofline}

Reading: big dense/MoE train cells are compute-bound at 0.93–0.98
useful fraction (remat recompute is the gap); prefill is compute-bound;
decode is memory-bound by KV-cache reads (the roofline fraction is an
MFU-style number — decode at fixed batch is bandwidth-limited by
construction, see Perf cell A); small models and whisper/xlstm are
collective-bound (FSDP+TP overhead vs tiny matmuls).

## Perf — hillclimb log (3 cells)

Cells chosen per the assignment: worst roofline fraction
(smollm decode), most collective-bound (arctic train), most
representative of the paper's technique (the KFAC-CA preconditioner's
CA-TRSM solves).  Paper-faithful baselines are recorded first; the
beyond-paper changes are marked.

{perf}

### Perf summary

| cell | dominant term before | after | change |
|---|---:|---:|---|
| A smollm-360m/decode_32k | t_mem 1.64e-3 s | 8.48e-4 s | int8 KV cache (1.94x); structural bandwidth floor reached |
| B arctic-480b/train_4k | t_coll 2.38 s | 1.48 s serialized / 2.04 s overlapped bound | mb 8->2 (1.6x) + overlap headroom; fsdp_all REFUTED by napkin math (16x worse) |
| C granite-8b/kfac-trsm | rec 3.28e-3 s (k=512) | inv 4.78e-4 s | paper technique 6.9x at k<<n; REFUTED at n=k on ICI (bandwidth), wins 1.5x on DCN -> method=auto |
| D llama3-405b/train_4k | args 22.0 GB, temps 116.6 GB | args 14.7 GB, temps 70.5 GB | bf16 moments + mb 8->16 (memory fit; cell stays compute-bound 0.98 useful) |
| E smollm-360m/train_4k | t_coll 7.71e-2 s (collective-bound, frac 0.585) | t_coll 4.34e-2 s (compute-bound, frac 0.742) | shard_mode=fsdp_all + mb=1: TP re-roled into FSDP+SP for the small model |
| F memory-fit sweep | qwen3-multi 323 GB / smollm 152 GB / whisper 116 GB temps | 13.3 / 16.9 / 5.4 GB | vocab-over-TP embedding + flash-backward remat + vocab padding (fleet-wide fixes) |

Stop criterion: each cell ended on a structural bound (A: bandwidth
floor at fixed batch; B: overlap bound; C: model argmin bracketed; D:
remaining temps are backend-aliasing artifacts) — further <5% moves.

Beyond-paper deltas recorded: mesh-native MM (drops 2 transposes),
batched-doubling inversion (W 0.66–0.82x of paper), all-to-all phase-1
(2 collectives vs O(log^2 p)), int8 KV cache, int8 cross-pod gradient
compression, model-driven rec/inv auto-dispatch.
"""


def main():
    cells = load_cells()
    md = TEMPLATE.format(
        bench=bench_section(),
        dry_single=dryrun_table(cells, "single"),
        dry_multi=dryrun_table(cells, "multi"),
        roofline=roofline_table(cells),
        trsm_scale=trsm_scale_section(),
        perf=perf_section(),
    )
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write(md)
    print(f"wrote {out} ({len(md)} chars)")


if __name__ == "__main__":
    main()
