"""Perf hillclimb (EXPERIMENTS.md Sec. Perf): three cells, iterated
hypothesis -> change -> re-lower -> validate cycles on the dominant
roofline term.

Cells (chosen from the 40-cell baseline table):
  A. smollm-360m x decode_32k   — worst roofline fraction (0.001),
                                   memory-bound (KV-cache traffic).
  B. arctic-480b x train_4k     — most collective-bound cell
                                   (t_coll > t_comp at baseline).
  C. granite-8b train + KFAC-CA — the paper's own technique: tune the
                                   CA-TRSM plan (n0 / grid / phase-1
                                   mode) for the preconditioner solves.

Run:  PYTHONPATH=src python experiments/perf_hillclimb.py
Writes experiments/perf_log.json consumed by make_report.py.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# the dryrun import must come first: it forces 512 host devices.
from repro.launch import dryrun                      # noqa: E402

import json                                          # noqa: E402
import math                                          # noqa: E402

from repro import configs                            # noqa: E402
from repro.core import cost_model as cm, tuning      # noqa: E402
from repro.roofline import model as rmodel           # noqa: E402

LOG = os.path.join(os.path.dirname(__file__), "perf_log.json")
log: dict = {"cells": {}}


def record(cell, name, hypothesis, before, after, confirmed, note=""):
    log["cells"].setdefault(cell, []).append(dict(
        iteration=name, hypothesis=hypothesis, before=before, after=after,
        confirmed=bool(confirmed), note=note))
    print(f"[{cell}] {name}: {'CONFIRMED' if confirmed else 'REFUTED'}  "
          f"{before} -> {after}  {note}")


# ===================== Cell A: smollm decode ==========================

def cell_a():
    arch, shape = "smollm-360m", "decode_32k"
    base = dryrun.run_cell(arch, shape, "single")
    rb = base["roofline"]
    t0 = rb["t_memory"]

    # A1: int8 KV cache.  Napkin: cache read is ~99% of decode HBM
    # bytes (172 GB vs 0.7 GB params); halving it should cut t_memory
    # ~1.9x and double the roofline fraction.
    it1 = dryrun.run_cell(arch, shape, "single", kv_dtype="int8")
    r1 = it1["roofline"]
    record("A:smollm-360m/decode_32k", "A1-int8-kv",
           "KV-cache bytes dominate decode HBM traffic; int8 cache with "
           "per-(pos,head) scales halves them -> t_memory ~/1.9, "
           "fraction ~x2",
           {"t_memory": t0, "frac": rb["roofline_fraction"],
            "bottleneck": rb["bottleneck"]},
           {"t_memory": r1["t_memory"], "frac": r1["roofline_fraction"],
            "bottleneck": r1["bottleneck"]},
           confirmed=r1["t_memory"] < 0.62 * t0,
           note="lowered+compiled with quantized cache (correctness: "
                "tests/test_models_smoke.py::test_int8_kv_cache...)")

    # A2: structural floor.  Napkin: after int8, remaining bytes are the
    # irreducible cache+param read per token; fraction is bounded by
    # 2*N*B / (PEAK * bytes/BW) — decode at batch 128 is bandwidth-
    # limited by construction.  Record the bound instead of iterating.
    cfg = configs.get(arch)
    floor = r1["t_memory"]
    record("A:smollm-360m/decode_32k", "A2-structural-floor",
           "with the cache at 1B/elem the memory term is the "
           "irreducible cache+param read; no sharding change moves it",
           {"t_memory": floor}, {"t_memory": floor}, confirmed=True,
           note="decode fraction is bandwidth-roofline-bound at fixed "
                "batch; serving-level fixes (larger batch, speculative "
                "decoding) are out of the assigned shape")
    return base, it1


# ===================== Cell B: arctic train ==========================

def cell_b():
    arch, shape = "arctic-480b", "train_4k"
    base = dryrun.run_cell(arch, shape, "single")      # mb=8 default
    rb = base["roofline"]

    # B1: FSDP gathers scale with microbatch count ((2mb+1) x shard
    # bytes).  Napkin with the Sec. model: mb 8->2 cuts the FSDP term
    # 17/5 = 3.4x; activation stash grows 4x but stays < HBM
    # (35 boundaries x 32768 tok/dev... ~16 GB -> pick mb=4 as the
    # feasible point: 9/17 of FSDP traffic, stash ~8 GB).
    it_mb4 = dryrun.run_cell(arch, shape, "single", mb=4)
    it_mb2 = dryrun.run_cell(arch, shape, "single", mb=2)
    r4, r2 = it_mb4["roofline"], it_mb2["roofline"]
    record("B:arctic-480b/train_4k", "B1-microbatches-8to4to2",
           "collective term is FSDP-gather dominated: (2mb+1)*pbytes/tp "
           "per step; halving mb twice cuts it ~2x with 4x activation "
           "stash (fits: ~35*8k*7168*2B*4 = 8GB/dev at mb=2)",
           {"t_collective": rb["t_collective"],
            "frac": rb["roofline_fraction"], "mb": 8},
           {"t_collective(mb4)": r4["t_collective"],
            "t_collective(mb2)": r2["t_collective"],
            "frac(mb2)": r2["roofline_fraction"]},
           confirmed=r2["t_collective"] < 0.75 * rb["t_collective"],
           note="re-lowered at mb=4 and mb=2; memory_analysis recorded "
                "in the dryrun artifacts")

    # B2: re-role TP into pure FSDP (fsdp_all)?  Napkin REFUTES before
    # lowering: without EP, every device would gather the full 480B
    # expert bank per microbatch: (2mb+1) * 960GB of gathers vs 60GB/tp
    # shard — 16x MORE collective traffic.  MoE needs EP; record as a
    # refuted hypothesis (no lowering needed, the model is conclusive).
    pb = configs.get(arch).param_count * 2
    bad = (2 * 2 + 1) * pb / 1 * 256 / 256 / 50e9
    record("B:arctic-480b/train_4k", "B2-fsdp_all-refuted",
           "killing TP reductions by re-roling model axis into FSDP "
           "might cut the TP term",
           {"t_collective": r2["t_collective"]},
           {"t_collective(modeled)": bad},
           confirmed=False,
           note="napkin math refutes: full expert bank gathered per "
                "microbatch = ~16x more bytes; EP is load-bearing for "
                "MoE. Not lowered.")

    # B3: compute/comm overlap.  The static model serializes terms; XLA
    # async collectives overlap FSDP gathers of layer l+1 with layer l
    # compute (scan prefetch).  Bound: overlapped t >= max(terms)
    # instead of sum — record the overlap headroom as the final state.
    r = r2
    overlapped = max(r["t_compute"], r["t_memory"], r["t_collective"])
    serial = r["t_compute"] + r["t_collective"]
    record("B:arctic-480b/train_4k", "B3-overlap-headroom",
           "scan-prefetched FSDP gathers + async TP collectives overlap "
           "with MXU compute; the step bound improves from sum to "
           "max(terms)",
           {"serialized_s": serial},
           {"overlapped_bound_s": overlapped,
            "frac_at_bound": r["roofline_fraction"]},
           confirmed=overlapped < serial,
           note="XLA latency-hiding scheduler; structurally available "
                "since the gather of unit i+1 has no dependence on unit "
                "i outputs")
    return base, it_mb2


# ============== Cell C: the paper's technique (KFAC TRSM) =============

def cell_c():
    # The KFAC-CA preconditioner refresh for granite-8b's d_ff weight
    # (14336 x 4096): Denman-Beavers runs SPD solves with n = k = 14336
    # on the 256-chip pod -> the paper's 3D regime (n = k).
    n = k = 16384           # pow2 envelope of 14336
    p = 256
    plan = tuning.tune(n, k, p)
    rec = cm.rec_trsm_cost(n, k, p)
    it = plan.cost
    m = cm.tpu_v5e()
    record("C:granite-8b/kfac-trsm", "C0-baseline-recursive",
           "substitution-based Rec-TRSM (paper Sec. IV) as the "
           "preconditioner solver",
           {}, {"S": rec.s, "W": rec.w, "F": rec.f,
                "v5e_time_s": rec.time(m)}, confirmed=True,
           note="paper-faithful baseline")
    # C1: does the paper's trade win HERE?  Napkin: dS ~ 200 messages
    # x alpha(1us) = 0.2ms saved; dW ~ 6.5e7 words x beta = +2.6ms paid.
    # Expect REFUTED on ICI at n = k: v5e's alpha is ~1000x smaller than
    # the MPI machines the paper targets, so bandwidth wins.
    record("C:granite-8b/kfac-trsm", "C1-it-inv-at-nk-on-ici",
           "paper Secs. VI-VII: pre-inverted blocks should beat the "
           "recursive solver (expected S improvement "
           f"{(n / k) ** (1 / 6) * p ** (2 / 3):.0f}x)",
           {"S": rec.s, "v5e_time_s": rec.time(m)},
           {"S": it.s, "W": it.w, "v5e_time_s": it.time(m),
            "plan": dict(p1=plan.p1, p2=plan.p2, n0=plan.n0)},
           confirmed=it.time(m) < rec.time(m),
           note="REFUTED as predicted by napkin math: at n=k on "
                "low-alpha ICI the inversion's extra bandwidth "
                "(~10x words) outweighs the 3x latency saving. The "
                "paper's model still holds — only the machine constants "
                "differ from its MPI target.  Led to C1b/C1c + the "
                "method='auto' dispatcher (beyond-paper).")

    # C1b: latency-dominated shape (k << n): the KFAC 'inverse'-mode
    # solve (A+lI)^{-1}G hits k=d_in panels; model k=512.
    k2 = 512
    plan2 = tuning.tune(n, k2, p)
    rec2 = cm.rec_trsm_cost(n, k2, p)
    record("C:granite-8b/kfac-trsm", "C1b-it-inv-at-small-k",
           "with k << n the recursive solver is latency-bound "
           "(S ~ (np/k)^{2/3} log p ~ 3300 messages = 3.3ms on ICI); "
           "It-Inv should win by ~Theta((n/k)^{1/6} p^{2/3})",
           {"S": rec2.s, "v5e_time_s": rec2.time(m)},
           {"S": plan2.cost.s, "v5e_time_s": plan2.cost.time(m),
            "speedup": rec2.time(m) / plan2.cost.time(m)},
           confirmed=plan2.cost.time(m) < rec2.time(m) / 5,
           note="the paper's headline regime, reproduced on v5e "
                "constants")

    # C1c: high-alpha network (cross-pod DCN): the paper's MPI-like
    # regime; even the square solve flips to It-Inv.
    mdcn = cm.tpu_v5e_dcn()
    plan3 = tuning.tune(n, k, p, mdcn)
    rec3t = cm.rec_trsm_cost(n, k, p).time(mdcn)
    record("C:granite-8b/kfac-trsm", "C1c-it-inv-on-dcn",
           "on the cross-pod DCN (alpha ~50us) latency dominates again "
           "and the paper's trade should win even at n = k",
           {"rec_dcn_time_s": rec3t},
           {"inv_dcn_time_s": plan3.cost.time(mdcn),
            "speedup": rec3t / plan3.cost.time(mdcn)},
           confirmed=plan3.cost.time(mdcn) < rec3t,
           note="multi-pod KFAC factors sharded across pods solve "
                "through DCN; method='auto' flips to 'inv' here")

    # C1d: the auto-dispatcher encodes all three findings.
    mth_ici, _, t_ici = tuning.choose_method(n, k, p, m)
    mth_k, _, t_k = tuning.choose_method(n, k2, p, m)
    mth_dcn, _, t_dcn = tuning.choose_method(n, k, p, mdcn)
    record("C:granite-8b/kfac-trsm", "C1d-auto-dispatch",
           "a model-driven method='auto' should pick rec on "
           "(n=k, ICI), inv on (k<<n) and inv on DCN",
           {},
           {"(n=k,ICI)": mth_ici, "(k=512,ICI)": mth_k,
            "(n=k,DCN)": mth_dcn},
           confirmed=(mth_ici == "rec" and mth_k == "inv"
                      and mth_dcn == "inv"),
           note="core.trsm(method='auto') — beyond-paper contribution")

    # C2: bracket n0 around the tuned value — is the argmin real?
    times = {}
    for mult in (0.25, 0.5, 1.0, 2.0, 4.0):
        n0 = max(int(plan.n0 * mult), plan.p1 * plan.p2)
        if n % n0 or n0 % (plan.p1 * plan.p2):
            continue
        r1, r2 = tuning._inv_subgrid(n, n0, p)
        c = cm.it_inv_trsm_cost(n, k, n0, plan.p1, plan.p2, r1, r2)
        times[n0] = c.time(m)
    best_n0 = min(times, key=times.get)
    record("C:granite-8b/kfac-trsm", "C2-n0-bracket",
           "the Sec. VIII closed-form n0 should be a real argmin of "
           "the alpha-beta-gamma time across a 16x bracket",
           {"tuned_n0": plan.n0},
           {"times_by_n0": {str(kk): vv for kk, vv in times.items()},
            "argmin": best_n0},
           confirmed=abs(math.log2(max(best_n0, 1))
                         - math.log2(max(plan.n0, 1))) <= 1,
           note="tuner argmin within 2x of bracket argmin")

    # C3: beyond-paper — phase-1 alltoall routing (2 collectives)
    # instead of the paper's per-subgrid recursion (O(log^2 p)).
    s_paper = math.log2(p) ** 2
    s_ours = 2 * math.log2(p)   # two all-to-alls
    record("C:granite-8b/kfac-trsm", "C3-alltoall-phase1",
           "when n/n0 >= p, routing whole diagonal blocks with one "
           "all-to-all (invert locally, route faces back) needs 2 "
           "collectives instead of the paper's O(log^2 p) subgrid "
           "recursion",
           {"S_inv_paper": s_paper}, {"S_inv_ours": s_ours},
           confirmed=s_ours < s_paper,
           note="implemented as inv_trsm phase-1 'alltoall' mode; "
                "traced in benchmarks; batched-doubling fallback for "
                "n/n0 < p keeps W 0.66-0.82x of the paper's closed form "
                "(bench_tri_inv)")


def _cached_cell(arch, shape, mesh, tag=None, **kw):
    """Load a tagged artifact if present, else lower it now."""
    name = f"{arch}__{shape}__{mesh}" + (f"__{tag}" if tag else "")
    path = os.path.join(os.path.dirname(__file__), "dryrun",
                        name + ".json")
    if os.path.exists(path):
        return json.load(open(path))
    rec = dryrun.run_cell(arch, shape, mesh, **kw)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def cell_d():
    """Bonus cell: llama3-405b train_4k memory fit (the flagship dense
    cell; analytic bottleneck is compute at 0.98 useful, but the
    per-device buffer report exceeds v5e HBM — iterate the memory)."""
    base = _cached_cell("llama3-405b", "train_4k", "single")
    m0 = base["memory"]
    args0 = m0["argument_size_in_bytes"] / 1e9
    tmp0 = m0["temp_size_in_bytes"] / 1e9

    # D1: f32 Adam moments are 2/3 of the persistent state; bf16
    # moments cut arguments 20.5 -> ~13.7 GB (params stay f32 master).
    it1 = _cached_cell("llama3-405b", "train_4k", "single",
                       tag="bf16mom", moment_dtype="bf16")
    m1 = it1["memory"]
    record("D:llama3-405b/train_4k", "D1-bf16-moments",
           "Adam m/v at f32 are 12.7 GB/dev of the 20.5 GB persistent "
           "state; bf16 moments halve them with negligible quality "
           "impact at this scale",
           {"argument_GB": args0},
           {"argument_GB": m1["argument_size_in_bytes"] / 1e9},
           confirmed=m1["argument_size_in_bytes"] < 0.75
           * m0["argument_size_in_bytes"],
           note="params remain f32 master weights; moments dtype is an "
                "optimizer knob (repro.optim.adamw moment_dtype)")

    # D2: temp buffers scale with the per-microbatch activation stash
    # (126 unit boundaries x tokens_mb/dp x d); mb 8 -> 16 halves the
    # stash.
    it2 = _cached_cell("llama3-405b", "train_4k", "single",
                       tag="bf16mom_mb16", moment_dtype="bf16", mb=16)
    m2 = it2["memory"]
    record("D:llama3-405b/train_4k", "D2-microbatches-8to16",
           "remat stash = n_units x tokens_mb/dp x d x 2B dominates "
           "temps; doubling microbatches halves it (collective cost "
           "rises per Perf-B tradeoff — acceptable: cell is "
           "compute-bound at 0.98)",
           {"temp_GB": tmp0},
           {"temp_GB": m2["temp_size_in_bytes"] / 1e9},
           confirmed=m2["temp_size_in_bytes"] < 0.7
           * m0["temp_size_in_bytes"],
           note="remaining ~100 GB/dev on the CPU-backend buffer report "
                "reflects unfused f32 optimizer temporaries the TPU "
                "backend aliases; multi-pod (512 chips) halves all "
                "per-device terms. Residual mitigation: optimizer-state "
                "offload (not implemented).")


def cell_e():
    """Extra cell: smollm-360m train_4k — the second-most
    collective-bound cell (t_coll > t_comp); TP is pure overhead for a
    360M model (d/16 = 60-wide shards starve the MXU anyway)."""
    base = _cached_cell("smollm-360m", "train_4k", "single")
    rb = base["roofline"]

    # E1: re-role the model axis into FSDP+SP (fsdp_all) and drop
    # gradient accumulation.  Napkin: TP term (4*2*32 reduction points
    # x tokens*d bytes ~ 9.7e11 global) vanishes; FSDP gathers at mb=1
    # cost 3*pbytes*dp = 0.55e12 < TP's 0.97e12; activations at 4096
    # tokens/dev fit easily for a 360M model.
    it1 = _cached_cell("smollm-360m", "train_4k", "single",
                       tag="fsdpall_mb1", shard_mode="fsdp_all", mb=1)
    r1 = it1["roofline"]
    record("E:smollm-360m/train_4k", "E1-fsdp_all-mb1",
           "for small models 16-way TP is pure collective overhead "
           "(60-wide shards); re-roling model->FSDP+SP with mb=1 should "
           "cut t_coll below t_compute and flip the cell compute-bound",
           {"t_collective": rb["t_collective"],
            "t_compute": rb["t_compute"],
            "bottleneck": rb["bottleneck"],
            "frac": rb["roofline_fraction"]},
           {"t_collective": r1["t_collective"],
            "bottleneck": r1["bottleneck"],
            "frac": r1["roofline_fraction"]},
           confirmed=(r1["t_collective"] < rb["t_collective"]
                      and r1["roofline_fraction"]
                      > rb["roofline_fraction"]),
           note="lowered+compiled with shard_mode=fsdp_all (sequence "
                "over the model axis); same lever REFUTED for arctic "
                "(B2) — it only pays when params are small relative to "
                "activations")


def cell_f():
    """Memory-fit sweep (whole-fleet iteration, not one cell): the dry
    run's per-device buffer reports exposed three structural memory
    bugs; each was diagnosed by ranking HLO tensor sizes, fixed, and
    re-lowered.  Before-numbers are the recorded pre-fix artifacts."""
    record("F:memory-fit-sweep", "F1-vocab-over-tp-embedding",
           "a V-replicated (tied) embedding forces the backward to "
           "all-gather the full (B,S,V) logits gradient per device; "
           "sharding the vocab dim over TP keeps logits and their "
           "grads sharded end-to-end (the lookup becomes a partitioned "
           "gather)",
           {"qwen3_train_multi_temp_GB": 323.0},
           {"qwen3_train_multi_temp_GB": 13.3},
           confirmed=True,
           note="diagnosed from f32[64,4096,151936] buffers in the "
                "partitioned HLO; fix in models/sharding.py")
    record("F:memory-fit-sweep", "F2-flash-backward-remat",
           "AD through the chunked-attention scan stashes the "
           "(q_chunk x kv_chunk) scores for EVERY chunk pair — O(S^2) "
           "residuals; jax.checkpoint on the scan body recomputes "
           "scores in the bwd pass (flash-attention backward)",
           {"smollm_train_multi_temp_GB": 152.0,
            "xlstm_train_multi_temp_GB": 43.8},
           {"smollm_train_multi_temp_GB": 16.9,
            "xlstm_train_multi_temp_GB": 9.0},
           confirmed=True,
           note="same fix applied to the mLSTM chunk scan and whisper "
                "encoder/decoder layer scans; models/layers.py")
    record("F:memory-fit-sweep", "F3-vocab-padding",
           "whisper's 51865 vocab divides no mesh axis, so its logits "
           "replicate regardless of sharding rules; padding the "
           "embedding table to a multiple of 256 (logits masked to "
           "-inf) restores shardability",
           {"whisper_train_multi_temp_GB": 116.4},
           {"whisper_train_multi_temp_GB": 5.4},
           confirmed=True,
           note="configs.vocab_padded; config-level vocab unchanged; "
                "smoke vocabs are already multiples of 256 so all "
                "equivalence tests still pass")


def main():
    cell_a()
    cell_b()
    cell_c()
    cell_d()
    cell_e()
    cell_f()
    with open(LOG, "w") as f:
        json.dump(log, f, indent=1, default=float)
    print(f"\nperf log -> {LOG}")


if __name__ == "__main__":
    main()
