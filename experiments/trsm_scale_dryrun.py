"""Pod-scale dry-run of the TRSM engine itself: lower + compile
It-Inv-TRSM and Rec-TRSM on 256-chip (8x8x4) and 512-chip (16x16x2)
grids with ShapeDtypeStruct inputs, and cross-check the traced
alpha-beta-gamma costs against the Sec. VII closed forms at production
scale.

    PYTHONPATH=src python experiments/trsm_scale_dryrun.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import (comm, cost_model as cm, grid as gridlib,
                        inv_trsm, rec_trsm, tuning)
from repro.roofline import analysis

OUT = os.path.join(os.path.dirname(__file__), "trsm_scale.json")


def run_one(p1, p2, n, k, results):
    p = p1 * p1 * p2
    grid = gridlib.make_trsm_mesh(p1, p2)
    plan = tuning.tune_for_grid(n, k, grid)
    n0 = plan.n0
    L = jax.ShapeDtypeStruct((n, n), np.float32)
    B = jax.ShapeDtypeStruct((n, k), np.float32)

    for name, build in [
            ("it_inv", lambda: inv_trsm.it_inv_trsm_fn(
                grid, n, k, n0, np.float32)),
            ("rec", lambda: rec_trsm.rec_trsm_fn(grid, n, k))]:
        t0 = time.time()
        fn = build()
        with comm.trace() as tr:
            lowered = fn.lower(L, B)
        compiled = lowered.compile()
        dt = time.time() - t0
        colls = analysis.parse_collectives(compiled.as_text())
        mem = compiled.memory_analysis()
        rec_d = dict(
            algo=name, p1=p1, p2=p2, p=p, n=n, k=k, n0=n0,
            compile_s=round(dt, 1),
            traced=dict(S=tr.s, W=tr.w, F=tr.f),
            hlo_collectives={kk: vv for kk, vv in colls.items()},
            temp_gb=getattr(mem, "temp_size_in_bytes", 0) / 1e9,
        )
        results.append(rec_d)
        model = (cm.it_inv_trsm_cost(n, k, n0, p1, p2, plan.r1, plan.r2)
                 if name == "it_inv" else cm.rec_trsm_cost(n, k, p))
        print(f"{name} p={p} ({p1}x{p1}x{p2}) n={n} k={k} n0={n0}: "
              f"compile {dt:.0f}s | traced S={tr.s:.0f} W={tr.w:.3e} | "
              f"model S={model.s:.0f} W={model.w:.3e} | "
              f"temp/dev {rec_d['temp_gb']:.2f} GB", flush=True)


def main():
    results = []
    # single pod: 256 chips as 8x8x4; multi-pod: 512 as 16x16x2
    run_one(8, 4, 1 << 16, 1 << 11, results)
    run_one(16, 2, 1 << 16, 1 << 11, results)
    # latency-bound shape (k << n), the paper's headline regime
    run_one(8, 4, 1 << 16, 1 << 8, results)
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"-> {OUT}")


if __name__ == "__main__":
    main()
