"""The declarative front door to the solve stack (DESIGN.md Sec. 10).

One import surface for everything a serving client needs:

    from repro import api

    grid = api.make_trsm_mesh(2, 2)                 # p1 x p1 x p2 mesh
    spec = api.SolveSpec.auto(n=4096, k=64, grid=grid,
                              precision="bf16_refine")
    server = api.SolveServer.from_spec(spec, L, panel_k=16)
    server.submit(b)
    X, = server.drain()[0]

* :class:`SolveSpec` — a frozen, hashable description of one solve
  configuration (problem / plan / execution); ``SolveSpec.auto``
  resolves method, grid, and block size a priori from the paper's
  Sec. VIII cost model, and a concrete spec IS the compiled-program
  cache key.
* :class:`Solver` — resident factor(s) at any bank width (a width-1
  bank is the single-factor case), one compiled program per RHS
  width, zero steady-state host<->device transfers and retraces.
* :class:`SolveServer` — continuous batching over a Solver: per-factor
  queues, first-fit packed panels, one dispatch per wave.
* :class:`FactorBank` — the admission layer (stacked cyclic storage,
  hoisted phase 1, cyclic ingestion from the on-grid factor
  producers).
* :class:`SolverFleet` / :func:`plan_fleet` — the mixed-order,
  multi-tenant tier (DESIGN.md Sec. 12): a cost-model-driven capacity
  planner buckets factor orders (zero-padding small orders into shared
  banks where the modeled padding overhead is bought back by the saved
  dispatch), and the fleet routes admits/solves by ``(tenant, order)``
  with cross-tenant LRU slot reclamation.
* :class:`AsyncSolveServer` — the open-loop traffic tier (DESIGN.md
  Sec. 13): a background drain loop over the same wave machinery with
  bounded per-slot queues, typed :class:`Overloaded` shedding,
  weighted fair per-tenant packing, and :class:`SolveFuture`
  completion handles; evict-under-flight surfaces as
  :class:`StrandedRequestError` through the future.  All serving
  faults share the :class:`ServingError` hierarchy
  (``repro.core.errors``).
* :class:`AdmissionController` / :class:`Autoscaler` — the control
  plane (DESIGN.md Sec. 15): SLO-aware admission sheds requests whose
  estimated queue wait cannot meet their deadline
  (:class:`DeadlineUnmeetable`, surfaced only through the future),
  and the autoscaler re-prices the live manifest with
  :func:`plan_fleet` under load drift, migrating resident factors
  into the new buckets without stranding queued work.
* :class:`FactorStructure` — the block-structure layer (DESIGN.md
  Sec. 14): a frozen ``dense`` / ``banded`` / ``block_sparse``
  promise analyzed once at admission; the level-scheduled sweep skips
  zero blocks and the cost model prices exactly what runs.
* :func:`trsm` — one-shot solves through the same compiled-program
  cache; :func:`solver_for` — the spec -> compiled-program mapping.

Everything here is re-exported from ``repro.core``; this module is the
stable spelling for scripts and downstream users.
"""

from repro.core import trsm  # noqa: F401
from repro.core.bank import FactorBank  # noqa: F401
from repro.core.control import (  # noqa: F401
    AdmissionController, Autoscaler)
from repro.core.errors import (  # noqa: F401
    DeadlineUnmeetable, Overloaded, ServingError,
    StrandedRequestError)
from repro.core.fleet import (  # noqa: F401
    BucketPlan, FleetHandle, FleetPlan, SolverFleet, plan_fleet)
from repro.core.grid import TrsmGrid, make_trsm_mesh  # noqa: F401
from repro.core.precision import (  # noqa: F401
    PRESETS, PrecisionPolicy)
from repro.core.session import (  # noqa: F401
    CompiledSolverCache, default_cache)
from repro.core.serving import (  # noqa: F401
    AsyncSolveServer, SolveFuture)
from repro.core.solver import (  # noqa: F401
    Solver, SolveServer, SolveSpec, UpdateSpec,
    plan_grid, resolve_plan, solver_for, updater_for)
from repro.core.structure import FactorStructure  # noqa: F401
