"""jax version-compatibility shims — the single place that knows which
jax API surface is installed.

The codebase targets the modern jax API (``jax.shard_map``,
``jax.lax.pcast``, ``AbstractMesh(axis_sizes, axis_names)``); older
releases (e.g. 0.4.x, as shipped in some containers) spell these
``jax.experimental.shard_map.shard_map`` (with ``check_rep`` instead of
``check_vma``), have no ``pcast`` (no varying-manual-axes bookkeeping to
satisfy), and construct ``AbstractMesh`` from a tuple of (name, size)
pairs.  Every module that needs one of these goes through this file, so
a jax upgrade/downgrade is a one-file change.
"""

from __future__ import annotations

import jax

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_PCAST = hasattr(jax.lax, "pcast")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with graceful fallback to the experimental API.

    On old jax the ``check_vma`` knob maps to ``check_rep=False``: the
    0.4.x replication checker predates the varying-manual-axes model and
    rejects valid programs that the modern checker accepts (e.g. psum
    results consumed at different manual-axis subsets)."""
    if _HAS_NEW_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental import shard_map as _sm
    return _sm.shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def pcast_varying(x, axes):
    """``jax.lax.pcast(x, axes, to="varying")`` where it exists; the
    identity elsewhere (pre-vma jax has no varying/replicated types to
    reconcile, so the cast is purely bookkeeping)."""
    if _HAS_PCAST:
        return jax.lax.pcast(x, axes, to="varying")
    return x


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` (new jax) or the classic constant-folded
    ``psum(1, axis)`` idiom (0.4.x), which returns a concrete int for a
    unit constant.  Accepts a single name or a tuple of names."""
    if hasattr(jax.lax, "axis_size"):
        import math
        if isinstance(axis_name, (tuple, list)):
            return int(math.prod(jax.lax.axis_size(a) for a in axis_name))
        return int(jax.lax.axis_size(axis_name))
    return int(jax.lax.psum(1, axis_name))


def abstract_mesh(axis_sizes, axis_names, **kw):
    """``AbstractMesh`` across the 0.4.x -> 0.5+ signature change:
    new jax wants ``(axis_sizes, axis_names)``, 0.4.x wants a single
    ``shape_tuple`` of (name, size) pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names), **kw)
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)), **kw)
