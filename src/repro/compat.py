"""jax version-compatibility shims — the single place that knows which
jax API surface is installed.

The codebase targets the modern jax API (``jax.shard_map``,
``jax.lax.pcast``, ``AbstractMesh(axis_sizes, axis_names)``); older
releases (e.g. 0.4.x, as shipped in some containers) spell these
``jax.experimental.shard_map.shard_map`` (with ``check_rep`` instead of
``check_vma``), have no ``pcast`` (no varying-manual-axes bookkeeping to
satisfy), and construct ``AbstractMesh`` from a tuple of (name, size)
pairs.  Every module that needs one of these goes through this file, so
a jax upgrade/downgrade is a one-file change.
"""

from __future__ import annotations

import jax

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_PCAST = hasattr(jax.lax, "pcast")
# Async (start/finish split) collectives: no released jax exposes them
# as stable lax primitives yet (XLA performs the split internally via
# its latency-hiding scheduler), so this probes for the experimental
# spelling and otherwise reports False — callers then fall back to
# eager-issue + identity-finish, which is value-identical (see
# ``async_*`` below and DESIGN.md Sec. 16).
_HAS_ASYNC_COLLECTIVES = hasattr(jax.lax, "all_gather_start") and \
    hasattr(jax.lax, "all_gather_finish")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with graceful fallback to the experimental API.

    On old jax the ``check_vma`` knob maps to ``check_rep=False``: the
    0.4.x replication checker predates the varying-manual-axes model and
    rejects valid programs that the modern checker accepts (e.g. psum
    results consumed at different manual-axis subsets)."""
    if _HAS_NEW_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental import shard_map as _sm
    return _sm.shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def pcast_varying(x, axes):
    """``jax.lax.pcast(x, axes, to="varying")`` where it exists; the
    identity elsewhere (pre-vma jax has no varying/replicated types to
    reconcile, so the cast is purely bookkeeping)."""
    if _HAS_PCAST:
        return jax.lax.pcast(x, axes, to="varying")
    return x


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` (new jax) or the classic constant-folded
    ``psum(1, axis)`` idiom (0.4.x), which returns a concrete int for a
    unit constant.  Accepts a single name or a tuple of names."""
    if hasattr(jax.lax, "axis_size"):
        import math
        if isinstance(axis_name, (tuple, list)):
            return int(math.prod(jax.lax.axis_size(a) for a in axis_name))
        return int(jax.lax.axis_size(axis_name))
    return int(jax.lax.psum(1, axis_name))


def has_async_collectives() -> bool:
    """Whether the installed jax can express a true start/finish
    collective split.  False on every 0.4.x (and, at the time of
    writing, every released) jax: there the ``async_*_start`` shims
    below issue the collective eagerly and ``async_finish`` is the
    identity — the VALUES are identical either way, and XLA's
    latency-hiding scheduler is still free to overlap the issued
    collective with any data-independent compute between start and
    finish (DESIGN.md Sec. 16)."""
    return _HAS_ASYNC_COLLECTIVES


def async_all_gather_start(x, axis_name, *, axis: int = 0,
                           tiled: bool = False):
    """Begin an all-gather; returns an opaque handle for
    :func:`async_finish`.  True split where jax exposes one, else the
    eager synchronous gather (the handle is then just the result)."""
    if _HAS_ASYNC_COLLECTIVES:
        return jax.lax.all_gather_start(x, axis_name, axis=axis,
                                        tiled=tiled)
    return jax.lax.all_gather(x, axis_name, axis=axis, tiled=tiled)


def async_all_gather_finish(handle):
    """Complete an all-gather started by :func:`async_all_gather_start`."""
    if _HAS_ASYNC_COLLECTIVES:
        return jax.lax.all_gather_finish(handle)
    return handle


def async_ppermute_start(x, axis_name, perm):
    """Begin a ppermute; returns an opaque handle for
    :func:`async_finish`.  Same fallback contract as the gather."""
    if _HAS_ASYNC_COLLECTIVES:
        return jax.lax.ppermute_start(x, axis_name, perm=perm)
    return jax.lax.ppermute(x, axis_name, perm=perm)


def async_ppermute_finish(handle):
    """Complete a ppermute started by :func:`async_ppermute_start`."""
    if _HAS_ASYNC_COLLECTIVES:
        return jax.lax.ppermute_finish(handle)
    return handle


def abstract_mesh(axis_sizes, axis_names, **kw):
    """``AbstractMesh`` across the 0.4.x -> 0.5+ signature change:
    new jax wants ``(axis_sizes, axis_names)``, 0.4.x wants a single
    ``shape_tuple`` of (name, size) pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names), **kw)
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)), **kw)
