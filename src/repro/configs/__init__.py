"""Architecture registry: ``--arch <id>`` resolves here.

One module per assigned architecture with the exact published dims;
``get(arch)`` returns the full config, ``get_smoke(arch)`` a reduced
config of the same family for CPU tests.  ``SHAPES`` defines the four
assigned input-shape cells; ``cells()`` enumerates the 40 (arch x shape)
dry-run cells with applicability per DESIGN.md Sec. 6.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

ARCH_IDS = [
    "qwen3-1.7b", "granite-8b", "smollm-360m", "llama3-405b",
    "grok-1-314b", "arctic-480b", "recurrentgemma-2b", "qwen2-vl-72b",
    "xlstm-1.3b", "whisper-tiny",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | hybrid | vlm | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    # --- optional features ---
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    mrope_sections: Optional[tuple] = None      # qwen2-vl M-RoPE
    # MoE
    n_experts: int = 0
    topk: int = 0
    dense_residual: bool = False                # arctic: MoE + dense FFN
    moe_d_ff: int = 0                           # expert FFN width
    moe_capacity: float = 1.25                  # capacity factor (GShard)
    # hybrid/ssm pattern: repeating unit of block kinds
    block_pattern: tuple = ("attn",)            # e.g. ("rec","rec","attn")
    local_window: int = 0                       # local attention window
    conv_width: int = 4                         # RG temporal conv
    # enc-dec (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500                      # stub frontend output len
    # vlm/audio stub frontend: inputs are precomputed embeddings
    embed_inputs: bool = False
    norm_eps: float = 1e-6
    head_dim_override: int = 0

    @property
    def head_dim(self) -> int:
        return self.head_dim_override or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding/head tables are allocated padded to a multiple of
        256 so the vocab dim shards over any mesh axis (whisper's 51865
        would otherwise replicate the logits gradient); padded logits
        are masked to -inf.  Config-level vocab is unchanged."""
        return (self.vocab + 255) // 256 * 256

    @property
    def sub_quadratic(self) -> bool:
        """Supports long-context decode with bounded state."""
        return self.family in ("hybrid", "ssm")

    def _block_param_counts(self, experts: int) -> int:
        """Sum of block parameters over the layer stack, pattern-aware.
        ``experts``: how many experts' FFNs to count per MoE block
        (n_experts for storage, topk for active)."""
        d, hd = self.d_model, self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd) \
            + (self.n_heads * hd) * d
        ffn_mult = 2 if self.family == "audio" else 3
        per_kind = {}
        per_kind["attn"] = attn
        if self.n_experts:
            per_kind["attn"] += ffn_mult * d * self.moe_d_ff * experts \
                + d * self.n_experts
            if self.dense_residual:
                per_kind["attn"] += ffn_mult * d * self.d_ff
        elif self.d_ff:
            per_kind["attn"] += ffn_mult * d * self.d_ff
        per_kind["rec"] = 5 * d * d + 3 * d * self.d_ff
        hd2 = d // self.n_heads
        per_kind["mlstm"] = 5 * d * d + 2 * d * self.n_heads
        per_kind["slstm"] = 5 * d * d + 4 * d * hd2
        pat = self.block_pattern
        n_units, tail = divmod(self.n_layers, len(pat))
        total = 0
        for i, kind in enumerate(pat):
            total += per_kind[kind] * (n_units + (1 if i < tail else 0))
        if self.enc_dec:
            total += self.n_layers * attn          # decoder cross-attn
        return total

    @property
    def enc_param_count(self) -> int:
        """Encoder-stack params (enc-dec archs; processes enc_frames
        tokens, so its flops scale separately from decoder tokens)."""
        if not self.enc_dec:
            return 0
        d, hd = self.d_model, self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd) \
            + (self.n_heads * hd) * d
        return self.n_enc_layers * (attn + 2 * d * self.d_ff)

    @property
    def param_count(self) -> int:
        """Parameter count (pattern-aware; used for 6ND model flops)."""
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self._block_param_counts(self.n_experts) + emb

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self._block_param_counts(self.topk) + emb

    @property
    def flop_param_count(self) -> int:
        """Matmul-participating active params per decoder token: block
        weights (top-k experts for MoE) + the output head, EXCLUDING the
        embedding gather (0 matmul flops) and the encoder stack (scales
        with enc_frames, not decoder tokens).  6*this*D is the 'useful
        flops' denominator that makes useful_ratio <= 1 meaningful."""
        return self._block_param_counts(self.topk) \
            + self.vocab * self.d_model


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def get(arch: str) -> ModelConfig:
    import importlib
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def get_smoke(arch: str) -> ModelConfig:
    import importlib
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    return mod.SMOKE


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Applicability per the assignment: long_500k only for sub-quadratic
    archs; every assigned arch has a decoder, so decode shapes all run."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 512k dense-attention decode is "
                       "quadratic-cost by definition (DESIGN.md Sec. 6)")
    return True, ""


def cells():
    """All 40 (arch x shape) cells with applicability verdicts."""
    out = []
    for a in ARCH_IDS:
        cfg = get(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            out.append((a, s.name, ok, why))
    return out
