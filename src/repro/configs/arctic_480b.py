"""arctic-480b [moe] — 128 experts top-2 + dense residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv=8, d_ff=4864,
    vocab=32000, n_experts=128, topk=2, moe_d_ff=4864,
    dense_residual=True,
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=96, vocab=256,
    n_experts=8, topk=2, moe_d_ff=96, dense_residual=True,
)
