"""granite-8b [dense] — llama-arch, code.  [arXiv:2405.04324; hf]"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=49152, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=160, vocab=256,
)
