"""grok-1-314b [moe] — 8 experts top-2.  [hf:xai-org/grok-1; unverified]"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv=8, d_ff=0,
    vocab=131072, n_experts=8, topk=2, moe_d_ff=32768,
)

SMOKE = ModelConfig(
    name="grok-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=0, vocab=256,
    n_experts=4, topk=2, moe_d_ff=128,
)
