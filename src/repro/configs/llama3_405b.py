"""llama3-405b [dense] — GQA, 128k vocab.  [arXiv:2407.21783; unverified]"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv=8, d_ff=53248,
    vocab=128256, rope_theta=5e5,
)

SMOKE = ModelConfig(
    name="llama3-smoke", family="dense",
    n_layers=3, d_model=128, n_heads=8, n_kv=2, d_ff=320, vocab=512,
)
