"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution (patch-embed stub:
inputs are precomputed patch embeddings per the assignment).
[arXiv:2409.12191; hf]"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8, d_ff=29568,
    vocab=152064, mrope_sections=(16, 24, 24), embed_inputs=True,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2vl-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
    mrope_sections=(4, 6, 6), embed_inputs=True,
)
