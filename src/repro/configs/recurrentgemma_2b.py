"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern.
[arXiv:2402.19427; hf]"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv=1, d_ff=7680,
    vocab=256000, block_pattern=("rec", "rec", "attn"),
    local_window=2048, conv_width=4, head_dim_override=256,
)

SMOKE = ModelConfig(
    name="rg-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=2, n_kv=1, d_ff=128, vocab=256,
    block_pattern=("rec", "rec", "attn"), local_window=32, conv_width=4,
)
