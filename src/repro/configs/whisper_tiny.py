"""whisper-tiny [audio] — enc-dec transformer backbone; the conv/mel
frontend is a stub per the assignment (input_specs provides precomputed
frame embeddings).  [arXiv:2212.04356; unverified]"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv=6, d_ff=1536,
    vocab=51865, enc_dec=True, n_enc_layers=4, enc_frames=1500,
    embed_inputs=False,   # decoder consumes tokens; encoder takes frames
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=256,
    enc_dec=True, n_enc_layers=2, enc_frames=64,
)
