"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM units).
[arXiv:2405.04517; unverified]"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv=4, d_ff=0,
    vocab=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=2, n_kv=2, d_ff=0, vocab=256,
    block_pattern=("mlstm", "slstm"),
)
