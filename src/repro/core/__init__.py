"""Communication-avoiding TRSM (Wicky/Solomonik/Hoefler, CS.DC 2016).

Public API:

    trsm(L, B, grid, method="inv"|"rec", ...)   distributed solve L X = B
    TrsmSession(L, grid, precision=...)         factor resident on device,
                                                serves batched RHS
    FactorBank / BatchedTrsmSession             pool of M resident factors,
                                                M solves in one dispatch
    PrecisionPolicy / PRESETS                   mixed-precision policies
                                                (fp32, bf16, bf16_refine,
                                                fp64_refine)
    CompiledSolverCache / default_cache()       LRU of compiled programs
    tri_inv.invert(L, grid)                     distributed L^{-1}
    cholesky.cholesky(A, grid)                  distributed chol via inversion
    cholesky.cholesky_cyclic / lu.lu_cyclic     factor producers emitting
                                                cyclic storage (bank feed)
    mm3d.matmul(L, X, grid)                     Sec. III 3D matmul
    tuning.tune(n, k, p)                        Sec. VIII a-priori parameters
    comm.trace()                                alpha-beta-gamma cost tracing
"""

from repro.core.bank import BatchedTrsmSession, FactorBank  # noqa: F401
from repro.core.grid import TrsmGrid, make_trsm_mesh  # noqa: F401
from repro.core.precision import PrecisionPolicy, PRESETS  # noqa: F401
from repro.core.session import (  # noqa: F401
    CompiledSolverCache, TrsmSession, default_cache)


def trsm(L, B, grid, method: str = "inv", n0: int | None = None,
         machine=None, lower: bool = True, transpose: bool = False,
         mode: str | None = None, block_inv=None, precision=None):
    """Solve op(L) X = B on a TrsmGrid.

    method="inv":  It-Inv-TRSM (paper Secs. VI-VII, the contribution).
    method="rec":  recursive baseline (paper Sec. IV).
    method="auto": beyond-paper — pick by the alpha-beta-gamma model
                   instantiated with the machine constants (the paper's
                   trade wins on high-alpha networks / k << n; the
                   recursive solver wins bandwidth-bound square solves
                   on low-alpha ICI).
    lower/transpose: upper-triangular and transposed solves reduce to
    the lower case by the reversal identity (DESIGN.md Sec. 3); the
    reversal is an index permutation *folded into the distribution-time
    on-device gather* (repro.core.session), not host slicing.
    n0 defaults to the Sec. VIII tuned block size.
    precision: a preset name ("fp32", "bf16", "bf16_refine",
    "fp64_refine") or a repro.core.precision.PrecisionPolicy; defaults
    to the uniform policy at L's dtype.  Refining policies run the
    sweep at low precision and recover residual-dtype accuracy with
    on-device iterative refinement (DESIGN.md Sec. 7) — all inside the
    same compiled program.

    Device-resident: the compiled program (B-permute -> sweep ->
    X-unpermute [-> refinement passes]) comes from the process-wide
    CompiledSolverCache, so repeated same-shape calls never re-trace.
    For repeated solves against a FIXED factor use
    :class:`TrsmSession`, which also keeps L distributed across calls.
    """
    import jax.numpy as jnp
    from repro.core import session
    n, k = B.shape
    prog = session.get_solver(grid, n=n, k=k, dtype=jnp.result_type(L),
                              method=method, n0=n0, mode=mode,
                              lower=lower, transpose=transpose,
                              machine=machine, block_inv=block_inv,
                              precision=precision)
    return prog.solve(prog.prep(L), B)
