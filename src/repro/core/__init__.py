"""Communication-avoiding TRSM (Wicky/Solomonik/Hoefler, CS.DC 2016).

Public API (the declarative front door is ``repro.api`` /
``repro.core.solver``; DESIGN.md Sec. 10):

    SolveSpec.auto(n, k, grid=|p=, ...)         frozen a-priori solve spec
                                                (= the compiled-program
                                                cache key)
    Solver.from_factor / from_factors / from_spec
                                                resident factor(s), any bank
                                                width, one dispatch per solve
    SolveServer(solver, panel_k)                continuous-batching front-end
    trsm(L, B, grid, method="inv"|"rec", ...)   one-shot distributed solve
    FactorBank                                  the admission layer: M
                                                factors in stacked cyclic
                                                storage
    PrecisionPolicy / PRESETS                   mixed-precision policies
                                                (fp32, bf16, bf16_refine,
                                                fp64_refine)
    CompiledSolverCache / default_cache()       LRU of compiled programs
    tri_inv.invert(L, grid)                     distributed L^{-1}
    cholesky.cholesky(A, grid)                  distributed chol via inversion
    cholesky.cholesky_cyclic / lu.lu_cyclic     factor producers emitting
                                                cyclic storage (bank feed)
    mm3d.matmul(L, X, grid)                     Sec. III 3D matmul
    tuning.tune(n, k, p)                        Sec. VIII a-priori parameters
    comm.trace()                                alpha-beta-gamma cost tracing

Deprecated (thin shims, one DeprecationWarning each — see the README
migration table): TrsmSession -> Solver.from_factor,
BatchedTrsmSession -> Solver.from_bank, and the request servers in
repro.train.serve_step -> SolveServer.
"""

from repro.core.bank import BatchedTrsmSession, FactorBank  # noqa: F401
from repro.core.grid import TrsmGrid, make_trsm_mesh  # noqa: F401
from repro.core.precision import PrecisionPolicy, PRESETS  # noqa: F401
from repro.core.session import (  # noqa: F401
    CompiledSolverCache, TrsmSession, default_cache)
from repro.core.solver import (  # noqa: F401
    Solver, SolveServer, SolveSpec, solver_for)


def trsm(L, B, grid, method: str = "inv", n0: int | None = None,
         machine=None, lower: bool = True, transpose: bool = False,
         mode: str | None = None, block_inv=None, precision=None):
    """Solve op(L) X = B on a TrsmGrid.

    method="inv":  It-Inv-TRSM (paper Secs. VI-VII, the contribution).
    method="rec":  recursive baseline (paper Sec. IV).
    method="auto": beyond-paper — pick by the alpha-beta-gamma model
                   instantiated with the machine constants (the paper's
                   trade wins on high-alpha networks / k << n; the
                   recursive solver wins bandwidth-bound square solves
                   on low-alpha ICI).
    lower/transpose: upper-triangular and transposed solves reduce to
    the lower case by the reversal identity (DESIGN.md Sec. 3); the
    reversal is an index permutation *folded into the distribution-time
    on-device gather* (repro.core.session), not host slicing.
    n0 defaults to the Sec. VIII tuned block size.
    precision: a preset name ("fp32", "bf16", "bf16_refine",
    "fp64_refine") or a repro.core.precision.PrecisionPolicy; defaults
    to the uniform policy at L's dtype.  Refining policies run the
    sweep at low precision and recover residual-dtype accuracy with
    on-device iterative refinement (DESIGN.md Sec. 7) — all inside the
    same compiled program.

    Device-resident: the compiled program (B-permute -> sweep ->
    X-unpermute [-> refinement passes]) comes from the process-wide
    CompiledSolverCache, so repeated same-shape calls never re-trace.
    For repeated solves against a FIXED factor use
    :class:`TrsmSession`, which also keeps L distributed across calls.
    """
    import jax.numpy as jnp
    from repro.core import solver as solverlib
    n, k = B.shape
    method, n0 = solverlib.resolve_plan(grid, n, k, method=method,
                                        n0=n0, machine=machine)
    from repro.core import precision as preclib
    spec = SolveSpec(n=n, k=k, grid=grid,
                     policy=preclib.resolve(precision,
                                            jnp.result_type(L)),
                     method=method, n0=n0, mode=mode, lower=lower,
                     transpose=transpose, block_inv=block_inv)
    prog = solver_for(spec)
    return prog.solve(prog.prep(L), B)
