"""Communication-avoiding TRSM (Wicky/Solomonik/Hoefler, CS.DC 2016).

Public API:

    trsm(L, B, grid, method="inv"|"rec", ...)   distributed solve L X = B
    tri_inv.invert(L, grid)                     distributed L^{-1}
    cholesky.cholesky(A, grid)                  distributed chol via inversion
    mm3d.matmul(L, X, grid)                     Sec. III 3D matmul
    tuning.tune(n, k, p)                        Sec. VIII a-priori parameters
    comm.trace()                                alpha-beta-gamma cost tracing
"""

from repro.core.grid import TrsmGrid, make_trsm_mesh  # noqa: F401


def trsm(L, B, grid, method: str = "inv", n0: int | None = None,
         machine=None, lower: bool = True, transpose: bool = False,
         **kw):
    """Solve op(L) X = B on a TrsmGrid.

    method="inv":  It-Inv-TRSM (paper Secs. VI-VII, the contribution).
    method="rec":  recursive baseline (paper Sec. IV).
    method="auto": beyond-paper — pick by the alpha-beta-gamma model
                   instantiated with the machine constants (the paper's
                   trade wins on high-alpha networks / k << n; the
                   recursive solver wins bandwidth-bound square solves
                   on low-alpha ICI).
    lower/transpose: upper-triangular and transposed solves reduce to
    the lower case by the reversal identity (DESIGN.md Sec. 3); the
    reversal is an index permutation applied at distribution time.
    n0 defaults to the Sec. VIII tuned block size.
    """
    if transpose:
        # op(L) = L^T: L^T X = B  <=>  reversed lower solve on L^T
        return trsm(L.T, B, grid, method=method, n0=n0, machine=machine,
                    lower=not lower, **kw)
    if not lower:
        # U X = B with U upper: (J U J) is lower; solve on reversed data
        Xr = trsm(L[::-1, ::-1], B[::-1], grid, method=method, n0=n0,
                  machine=machine, lower=True, **kw)
        return Xr[::-1]
    n, k = B.shape
    if method == "auto":
        from repro.core import tuning
        method, _, _ = tuning.choose_method(n, k, grid.p, machine)
    if method == "inv":
        from repro.core import inv_trsm, tuning
        if n0 is None:
            plan = tuning.tune_for_grid(n, k, grid)
            n0 = plan.n0
        return inv_trsm.solve(L, B, grid, n0, **kw)
    if method == "rec":
        from repro.core import rec_trsm
        return rec_trsm.solve(L, B, grid, n0=n0, **kw)
    raise ValueError(method)
