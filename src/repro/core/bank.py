"""Multi-factor batched serving: FactorBank + BatchedTrsmSession
(DESIGN.md Sec. 9).

The paper's Sec. I pitch is that TRSM is the inner kernel of Cholesky /
LU / QR — real workloads solve against *many* triangular factors at
once (per-layer KFAC preconditioners, per-tenant models), not one.
A :class:`~repro.core.session.TrsmSession` serves one resident factor;
this module pools M of them:

* :class:`FactorBank` — a device-resident pool of M same-order
  triangular factors held as ONE stacked cyclic array (M, n, n),
  sharded ``P(None, "x", ("z", "y"))`` — the single-factor
  cyclic-storage contract (DESIGN.md Sec. 4) with a leading factor
  axis.  Admission runs the same fused distribution gather as a
  session (``grid.cyclic_matrix_device`` permutes the trailing two
  axes, so a whole (M, n, n) stack distributes in one program), and a
  refining precision policy keeps DUAL stacks (storage dtype for the
  sweep + residual dtype for the refinement GEMM), cast once at
  admission.  For the "inv" method admission ALSO runs phase 1 (the
  paper's Diagonal-Inverter) once per factor: the factors are
  immutable, so the inverted diagonal faces become resident state and
  the steady-state program is the sweep alone — which is why the
  bank's default n0 is the larger hoisted-serving argmin
  (``tuning.serving_n0``), not the session's fused-solve argmin.

* **Cyclic ingestion** — ``admit_cyclic`` accepts a factor ALREADY in
  cyclic storage, exactly what ``core.cholesky.cholesky_cyclic`` /
  ``core.lu.lu_cyclic`` produce: a factor computed on the grid enters
  the bank with zero host traffic and zero re-permutation (no
  unpermute -> re-permute round trip), closing the paper's
  factor-producer -> TRSM-consumer loop on device.

* :class:`BatchedTrsmSession` — solves op(L_i) X_i = B_i for ALL i in
  one compiled program: the per-factor body (B-permute -> shard_map
  sweep -> X-unpermute -> unrolled refinement) is mapped over the
  factor axis with ``jax.vmap`` (every sweep step becomes an M-wide
  batched GEMM; the default) or ``jax.lax.scan`` (factors serialized
  inside the same single program; memory-lean for large M).  M
  per-layer or per-tenant solves cost ONE dispatch, and the
  single-session invariants extend verbatim: zero steady-state
  host<->device transfers and zero retraces for every precision policy
  (asserted in tests/test_factor_bank.py via
  :data:`repro.core.session.TRACE_COUNTS` + ``jax.transfer_guard``).

Programs come from the same :class:`CompiledSolverCache`; the bank
width M (and map mode) join the cache key, so two same-width banks of
the same configuration share one compiled program and the factors are
runtime operands, never baked-in constants.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import precision as preclib
from repro.core import session as sessionlib
from repro.core.grid import TrsmGrid
from repro.core.session import CompiledSolverCache, SolverProgram


class FactorBank:
    """A device-resident pool of M triangular factors in stacked cyclic
    storage, ready for one-dispatch batched solves.

        bank = FactorBank(grid, n=256, method="inv", n0=32,
                          precision="bf16_refine")
        for L in per_layer_factors:        # natural-layout (n, n)
            bank.admit(L)
        sess = BatchedTrsmSession(bank)
        X = sess.solve(B_stack)            # (M, n, k) in one dispatch

    All factors share one operator configuration (method, n0, lower,
    transpose, precision): the bank is a pool of *interchangeable*
    solves, which is what makes the single mapped program possible.

    ``dtype`` / ``precision`` follow :class:`TrsmSession` (a preset
    name or a PrecisionPolicy; default fp32 uniform).  ``map_mode``
    picks how the batched program maps the factor axis ("vmap" |
    "scan"); it is part of the compiled-program cache key.
    """

    def __init__(self, grid: TrsmGrid, n: int, *, method: str = "inv",
                 n0: int | None = None, mode: str | None = None,
                 lower: bool = True, transpose: bool = False,
                 machine=None, block_inv: Callable | None = None,
                 dtype=None, precision=None, map_mode: str = "vmap",
                 cache: CompiledSolverCache | None = None):
        if precision is None and dtype is None:
            dtype = jnp.float32
        self.policy = preclib.resolve(precision, dtype)
        sessionlib._check_policy_supported(self.policy)
        if map_mode not in ("vmap", "scan"):
            raise ValueError(f"unknown map_mode {map_mode!r}")
        if method not in ("inv", "rec"):
            raise ValueError(f"bank method must be 'inv' or 'rec', got "
                             f"{method!r} (auto-dispatch is k-dependent; "
                             f"a bank's plan is fixed at admission)")
        self.grid = grid
        self.n = n
        self.method = method
        self.mode = mode
        self.lower = lower
        self.transpose = transpose
        self.machine = machine
        self.block_inv = block_inv
        self.map_mode = map_mode
        self.cache = cache if cache is not None \
            else sessionlib.default_cache()
        if method == "inv":
            # n0 is pinned at construction (admission pre-inverts the
            # diagonal blocks, so every program over this bank must
            # agree on the block size) — default: the hoisted-serving
            # argmin, which is LARGER than the session default because
            # the inversion cost leaves the steady state (DESIGN.md
            # Sec. 9 / tuning.serving_n0).
            from repro.core import tuning
            self.n0 = n0 if n0 is not None else tuning.serving_n0(n, grid)
            if n % self.n0 or self.n0 % (grid.p1 * grid.p2):
                raise ValueError(f"n0={self.n0} infeasible for n={n} on "
                                 f"p1={grid.p1}, p2={grid.p2}")
            from repro.core import inv_trsm
            self._phase1_mode = mode or inv_trsm.pick_phase1_mode(
                n, self.n0, grid)
        else:
            self.n0 = n0
            self._phase1_mode = None
        # resident cyclic copies, stored as admitted CHUNKS — tuples of
        # per-role arrays with a leading chunk axis (an admit_stack's
        # whole (M, ...) gather output stays one chunk, so the common
        # admit-stack-then-serve path never re-slices or re-stacks it);
        # the fused (M_total, ...) views are built lazily and cached
        # until admission changes the pool.
        self._chunks: list[tuple] = []
        self._size = 0
        self._stacks: tuple | None = None

    # ------------------------------ admission ------------------------------

    @property
    def size(self) -> int:
        """M — the number of resident factors."""
        return self._size

    def __len__(self) -> int:
        return self.size

    def _check_square(self, L, ndim: int) -> None:
        if L.ndim != ndim or L.shape[-2:] != (self.n, self.n):
            lead = "(M, " if ndim == 3 else "("
            raise ValueError(f"factor must be {lead}{self.n}, {self.n}), "
                             f"got {L.shape}")

    def _phase1(self, L_lo, stacked: bool = False):
        """Admission-time phase 1: invert the factor's diagonal blocks
        ONCE (the paper's Diagonal-Inverter), so the steady-state
        program is the sweep alone."""
        ph1 = sessionlib._build_phase1(
            self.grid, self.n, self.n0, self._phase1_mode,
            self.policy.accumulate_dtype, self.block_inv, stacked)
        return ph1(L_lo)

    def _entry(self, parts: tuple, stacked: bool = False) -> tuple:
        """(L_lo[, L_hi]) -> the resident tuple (L_lo[, Dt][, L_hi])."""
        if self.method != "inv":
            return parts
        return (parts[0], self._phase1(parts[0], stacked)) + parts[1:]

    def admit(self, L) -> int:
        """Distribute one natural-layout (n, n) factor into the bank
        (the session's fused gather, operator reductions folded in,
        diagonal blocks pre-inverted); returns the factor's bank
        index."""
        L = jnp.asarray(L)
        self._check_square(L, 2)
        preps = sessionlib._factor_preps(self.grid, self.lower,
                                         self.transpose, self.policy)
        self._append(self._entry(tuple(p(L) for p in preps)))
        return self.size - 1

    def admit_stack(self, Ls) -> range:
        """Distribute a whole natural-layout (M, n, n) stack in ONE
        stacked gather program per dtype role (plus one stacked
        phase-1 inversion); returns the admitted index range."""
        Ls = jnp.asarray(Ls)
        self._check_square(Ls, 3)
        preps = sessionlib._factor_preps(self.grid, self.lower,
                                         self.transpose, self.policy,
                                         stacked=True)
        stacks = self._entry(tuple(p(Ls) for p in preps), stacked=True)
        first = self.size
        self._append_chunk(stacks, Ls.shape[0])
        return range(first, self.size)

    def admit_cyclic(self, L_cyc) -> int:
        """Direct cyclic ingestion: admit a factor ALREADY in the cyclic
        storage the producers emit (``cholesky_cyclic`` / ``lu_cyclic``
        outputs, or a session's ``factor_cyclic``) — no unpermute ->
        re-permute host round trip, no layout change at all; only the
        policy's dtype casts are applied (both resident copies when the
        policy refines, so pass the factor at residual precision or
        better).

        Only valid for the identity operator reduction (lower=True,
        transpose=False): for the other variants the distribution
        gather is not the plain cyclic map, so a raw cyclic array would
        be misinterpreted."""
        if not self.lower or self.transpose:
            raise ValueError(
                "cyclic ingestion requires lower=True, transpose=False "
                "(the reversal/transpose reductions are folded into the "
                "natural-layout distribution gather; a pre-permuted "
                "factor cannot carry them)")
        L_cyc = jnp.asarray(L_cyc)
        self._check_square(L_cyc, 2)
        sharding = NamedSharding(self.grid.mesh, self.grid.spec_L())
        dts = (self.policy.storage_dtype,)
        if self.policy.refines:
            dts += (self.policy.residual_dtype,)
        parts = tuple(jax.device_put(jnp.asarray(L_cyc, dt), sharding)
                      for dt in dts)
        self._append(self._entry(parts))
        return self.size - 1

    def _append(self, entry: tuple) -> None:
        """Admit one factor: a chunk of width 1."""
        self._append_chunk(tuple(a[None] for a in entry), 1)

    def _append_chunk(self, stacks: tuple, count: int) -> None:
        self._chunks.append(stacks)
        self._size += count
        self._stacks = None

    # ------------------------------- storage -------------------------------

    def _role_specs(self) -> list:
        """Per-role shard specs of a resident entry: L_lo[, Dt][, L_hi]."""
        specs = [self.grid.spec_L()]
        if self.method == "inv":
            from repro.core.inv_trsm import SPEC_DT
            specs.append(SPEC_DT)
        if self.policy.refines:
            specs.append(self.grid.spec_L())
        return specs

    def stacks(self) -> tuple:
        """The resident stacked arrays — one (M, ...) stack per factor
        role (sweep factor[, inverted diagonal faces][, residual-dtype
        factor]), each sharded with a leading unmapped factor axis.
        Built lazily after admission and cached: the steady state
        reuses the same device buffers, and a pool admitted as one
        ``admit_stack`` IS its gather output (no re-slice/re-stack —
        ``jax.device_put`` onto the sharding it already has is free)."""
        if not self._chunks:
            raise ValueError("empty bank: admit factors before solving")
        if self._stacks is None:
            fused = self._chunks[0] if len(self._chunks) == 1 else tuple(
                jnp.concatenate([c[r] for c in self._chunks])
                for r in range(len(self._chunks[0])))
            self._stacks = tuple(
                jax.device_put(a,
                               NamedSharding(self.grid.mesh,
                                             P(None, *spec)))
                for a, spec in zip(fused, self._role_specs()))
        return self._stacks

    @property
    def factors_cyclic(self):
        """The storage-dtype (M, n, n) stacked cyclic factor."""
        return self.stacks()[0]

    @property
    def factors_cyclic_residual(self):
        """The residual-precision (M, n, n) stacked copy (None unless
        the policy refines)."""
        return self.stacks()[-1] if self.policy.refines else None


class BatchedTrsmSession:
    """DEPRECATED multi-factor serving session — a thin shim over
    :meth:`repro.core.solver.Solver.from_bank`, kept for source
    compatibility; results are bit-identical to the
    :class:`~repro.core.solver.Solver` path.

    ``solve(B)`` takes an (M, n, k) stack — row i is the RHS panel for
    bank factor i — and returns the (M, n, k) solutions in one
    dispatch, with the usual steady-state invariants (zero transfers,
    zero retraces, every precision policy).  New code:

        solver = repro.api.Solver.from_bank(bank)   # or .from_factors
        X = solver.solve(B_stack)
    """

    def __init__(self, bank: FactorBank):
        from repro.core import solver as solverlib
        solverlib._warn_deprecated("BatchedTrsmSession",
                                   "Solver.from_bank")
        self._solver = solverlib.Solver.from_bank(bank)

    @classmethod
    def _wrap(cls, solver) -> "BatchedTrsmSession":
        self = object.__new__(cls)
        self._solver = solver
        return self

    @property
    def bank(self) -> FactorBank:
        return self._solver.bank

    @property
    def solves_served(self) -> int:
        return self._solver.solves_served

    @property
    def n(self) -> int:
        return self._solver.n

    @property
    def policy(self):
        return self._solver.policy

    @property
    def dtype(self):
        """The I/O dtype (what ``solve`` returns, what ``place_rhs``
        casts to): residual dtype for refining policies, compute dtype
        otherwise."""
        return self._solver.dtype

    def program_for(self, k: int) -> SolverProgram:
        return self._solver.program_for(k)

    def place_rhs(self, B):
        return self._solver.place_rhs(jnp.asarray(B, self.dtype))

    def solve(self, B, *, donate: bool = True):
        """Solve op(L_i) X_i = B_i for all M factors in one dispatch
        (strictly the (M, n, k) stack form, as before)."""
        M = self.bank.size
        if B.ndim != 3 or B.shape[0] != M or B.shape[1] != self.n:
            raise ValueError(f"rhs stack must be ({M}, {self.n}, k), "
                             f"got {B.shape}")
        return self._solver.solve(B, donate=donate)

    def warmup(self, k: int):
        self._solver.warmup(k)
        return self
