"""Multi-factor batched serving: FactorBank + BatchedTrsmSession
(DESIGN.md Sec. 9).

The paper's Sec. I pitch is that TRSM is the inner kernel of Cholesky /
LU / QR — real workloads solve against *many* triangular factors at
once (per-layer KFAC preconditioners, per-tenant models), not one.
A :class:`~repro.core.session.TrsmSession` serves one resident factor;
this module pools M of them:

* :class:`FactorBank` — a device-resident pool of M same-order
  triangular factors held as ONE stacked cyclic array (M, n, n),
  sharded ``P(None, "x", ("z", "y"))`` — the single-factor
  cyclic-storage contract (DESIGN.md Sec. 4) with a leading factor
  axis.  Admission runs the same fused distribution gather as a
  session (``grid.cyclic_matrix_device`` permutes the trailing two
  axes, so a whole (M, n, n) stack distributes in one program), and a
  refining precision policy keeps DUAL stacks (storage dtype for the
  sweep + residual dtype for the refinement GEMM), cast once at
  admission.  For the "inv" method admission ALSO runs phase 1 (the
  paper's Diagonal-Inverter) once per factor: the factors are
  immutable, so the inverted diagonal faces become resident state and
  the steady-state program is the sweep alone — which is why the
  bank's default n0 is the larger hoisted-serving argmin
  (``tuning.serving_n0``), not the session's fused-solve argmin.

* **Cyclic ingestion** — ``admit_cyclic`` accepts a factor ALREADY in
  cyclic storage, exactly what ``core.cholesky.cholesky_cyclic`` /
  ``core.lu.lu_cyclic`` produce: a factor computed on the grid enters
  the bank with zero host traffic and zero re-permutation (no
  unpermute -> re-permute round trip), closing the paper's
  factor-producer -> TRSM-consumer loop on device.

* **Live mutation** (DESIGN.md Sec. 11) — a bank built with
  ``capacity=C`` allocates its resident stacks at width C up front and
  becomes mutable in place: ``replace(slot, L)`` /
  ``replace_cyclic(slot, L_cyc)`` re-run the single-factor admission
  pipeline (gather + policy casts + hoisted phase 1) and scatter every
  factor role into the resident stacks through ONE compiled, donated
  updater program (cached in the :class:`CompiledSolverCache` under an
  :class:`~repro.core.solver.UpdateSpec`); ``evict(slot)`` frees a
  slot and ``admit`` re-uses freed slots.  The compiled solve program
  is keyed on C, not on occupancy, so churn — replace, evict, re-admit
  — never retraces and never rebuilds the bank.

* :class:`BatchedTrsmSession` — solves op(L_i) X_i = B_i for ALL i in
  one compiled program: the per-factor body (B-permute -> shard_map
  sweep -> X-unpermute -> unrolled refinement) is mapped over the
  factor axis with ``jax.vmap`` (every sweep step becomes an M-wide
  batched GEMM; the default) or ``jax.lax.scan`` (factors serialized
  inside the same single program; memory-lean for large M).  M
  per-layer or per-tenant solves cost ONE dispatch, and the
  single-session invariants extend verbatim: zero steady-state
  host<->device transfers and zero retraces for every precision policy
  (asserted in tests/test_factor_bank.py via
  :data:`repro.core.session.TRACE_COUNTS` + ``jax.transfer_guard``).

Programs come from the same :class:`CompiledSolverCache`; the bank
width M (and map mode) join the cache key, so two same-width banks of
the same configuration share one compiled program and the factors are
runtime operands, never baked-in constants.
"""

from __future__ import annotations

import bisect
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import precision as preclib
from repro.core import session as sessionlib
from repro.core.grid import TrsmGrid
from repro.core.session import CompiledSolverCache, SolverProgram


class FactorBank:
    """A device-resident pool of M triangular factors in stacked cyclic
    storage, ready for one-dispatch batched solves.

        bank = FactorBank(grid, n=256, method="inv", n0=32,
                          precision="bf16_refine")
        for L in per_layer_factors:        # natural-layout (n, n)
            bank.admit(L)
        sess = BatchedTrsmSession(bank)
        X = sess.solve(B_stack)            # (M, n, k) in one dispatch

    All factors share one operator configuration (method, n0, lower,
    transpose, precision): the bank is a pool of *interchangeable*
    solves, which is what makes the single mapped program possible.

    ``dtype`` / ``precision`` follow :class:`TrsmSession` (a preset
    name or a PrecisionPolicy; default fp32 uniform).  ``map_mode``
    picks how the batched program maps the factor axis ("vmap" |
    "scan"); it is part of the compiled-program cache key.

    ``capacity=C`` allocates the resident stacks at width C up front
    (zero-filled slots solve to zeros — they never contaminate live
    lanes) and makes the bank LIVE-MUTABLE: ``admit`` fills the lowest
    free slot, ``replace``/``replace_cyclic`` refresh a live slot in
    place through one compiled donated scatter, and ``evict`` returns
    a slot to the free list.  The bank's *width* (what the compiled
    solve program is keyed on) is then C regardless of occupancy, so
    occupancy changes and per-slot churn never retrace (DESIGN.md
    Sec. 11).  Without ``capacity`` the bank is the classic append-only
    pool (width == size grows with each admission).
    """

    def __init__(self, grid: TrsmGrid, n: int, *, method: str = "inv",
                 n0: int | None = None, mode: str | None = None,
                 lower: bool = True, transpose: bool = False,
                 machine=None, block_inv: Callable | None = None,
                 dtype=None, precision=None, map_mode: str = "vmap",
                 capacity: int | None = None, structure=None,
                 overlap="auto",
                 cache: CompiledSolverCache | None = None):
        if precision is None and dtype is None:
            dtype = jnp.float32
        self.policy = preclib.resolve(precision, dtype)
        sessionlib._check_policy_supported(self.policy)
        if map_mode not in ("vmap", "scan"):
            raise ValueError(f"unknown map_mode {map_mode!r}")
        if method not in ("inv", "rec"):
            raise ValueError(f"bank method must be 'inv' or 'rec', got "
                             f"{method!r} (auto-dispatch is k-dependent; "
                             f"a bank's plan is fixed at admission)")
        # dense IS the unstructured bank (one cache key, one program)
        if structure is not None and structure.is_dense:
            structure = None
        if structure is not None:
            structure.validate_for(n, lower=lower, transpose=transpose)
        self.structure = structure
        # software pipelining of the steady-state sweep (DESIGN.md
        # Sec. 16): "auto" -> "on" (results are bit-identical either
        # way); "off"/None keys the pre-overlap program.
        from repro.core import solver as solverlib
        self.overlap = solverlib._normalize_overlap(overlap)
        self.grid = grid
        self.n = n
        self.method = method
        self.mode = mode
        self.lower = lower
        self.transpose = transpose
        self.machine = machine
        self.block_inv = block_inv
        self.map_mode = map_mode
        self.cache = cache if cache is not None \
            else sessionlib.default_cache()
        if method == "inv":
            # n0 is pinned at construction (admission pre-inverts the
            # diagonal blocks, so every program over this bank must
            # agree on the block size) — default: the hoisted-serving
            # argmin, which is LARGER than the session default because
            # the inversion cost leaves the steady state (DESIGN.md
            # Sec. 9 / tuning.serving_n0), and which prices the
            # structure's skipped blocks when one is declared
            # (Sec. 14).
            from repro.core import tuning
            self.n0 = n0 if n0 is not None else \
                tuning.serving_n0(n, grid, structure=structure)
            if n % self.n0 or self.n0 % (grid.p1 * grid.p2):
                raise ValueError(f"n0={self.n0} infeasible for n={n} on "
                                 f"p1={grid.p1}, p2={grid.p2}")
            from repro.core import inv_trsm
            self._phase1_mode = mode or inv_trsm.pick_phase1_mode(
                n, self.n0, grid)
        else:
            self.n0 = n0
            self._phase1_mode = None
        # resident cyclic copies: ``_stacks`` is the fused per-role
        # tuple of (width, ...) device arrays; ``_chunks`` holds
        # admitted-but-not-yet-fused chunks (tuples of per-role arrays
        # with a leading chunk axis).  stacks() fuses PENDING chunks
        # into the cached fused tuple incrementally — it never
        # re-concatenates the whole history, and a pool admitted as one
        # admit_stack IS its gather output.  Capacity-allocated banks
        # have no chunks at all: admission scatters into the
        # preallocated stacks through the compiled updater.
        self._chunks: list[tuple] = []
        self._size = 0
        self._stacks: tuple | None = None
        self._slot_ids: dict[int, object] = {}
        self._updaters: dict[tuple, object] = {}
        self.updates_dispatched = 0    # compiled scatter dispatches
        self.capacity = capacity
        if capacity is not None:
            if capacity < 1:
                raise ValueError(f"capacity must be >= 1, got {capacity}")
            self._live = [False] * capacity
            self._gens = [0] * capacity            # bumped per evict
            self._free = list(range(capacity))     # kept sorted, min-first
            # device-resident slot indices, pinned ONCE so steady-state
            # churn (replace/evict/admit) uploads nothing per update
            self._slot_ids = {i: self._place_slot_id(i)
                              for i in range(capacity)}
            self._stacks = self._alloc_stacks()
        else:
            self._live = None
            self._free = None

    # ------------------------------ admission ------------------------------

    @property
    def size(self) -> int:
        """M — the number of LIVE resident factors (occupancy)."""
        return self._size

    @property
    def width(self) -> int:
        """The resident stack width the compiled programs are keyed on:
        ``capacity`` for a capacity-allocated bank (occupancy changes
        never re-key), else the live size (append-only growth)."""
        return self.capacity if self.capacity is not None else self._size

    def __len__(self) -> int:
        return self.size

    def is_live(self, slot: int) -> bool:
        """Whether ``slot`` currently holds an admitted factor."""
        if self.capacity is None:
            return 0 <= slot < self._size
        return 0 <= slot < self.capacity and self._live[slot]

    def live_slots(self) -> tuple:
        """The live slot indices, ascending."""
        if self.capacity is None:
            return tuple(range(self._size))
        return tuple(i for i, live in enumerate(self._live) if live)

    def slot_generation(self, slot: int) -> int:
        """How many times ``slot`` has been TURNED OVER (evicted).  A
        server records this at submit time so a request can never be
        served against a factor admitted after its slot was evicted —
        ``replace`` deliberately does NOT bump it (refreshing a live
        factor in place is the intended serving semantic).  Append-only
        banks never turn slots over (always 0)."""
        return 0 if self.capacity is None else self._gens[slot]

    def _place_slot_id(self, slot: int):
        from jax.sharding import NamedSharding, PartitionSpec
        return jax.device_put(jnp.asarray(slot, jnp.int32),
                              NamedSharding(self.grid.mesh,
                                            PartitionSpec()))

    def _roles(self) -> list:
        """(global shape, dtype, shard spec) per resident entry role:
        L_lo[, Dt][, L_hi]."""
        pol = self.policy
        roles = [((self.n, self.n), pol.storage_dtype,
                  self.grid.spec_L())]
        if self.method == "inv":
            from repro.core import inv_trsm
            roles.append((inv_trsm.dt_shape(self.n, self.n0),
                          pol.storage_dtype, inv_trsm.SPEC_DT))
        if pol.refines:
            roles.append(((self.n, self.n), pol.residual_dtype,
                          self.grid.spec_L()))
        return roles

    def _alloc_stacks(self) -> tuple:
        """Preallocate the (C, ...) resident stacks (zero-filled: a
        zero factor sweeps to a zero solution, so empty slots are
        inert lanes, never NaN sources for "inv")."""
        C = self.capacity
        return tuple(
            jax.device_put(jnp.zeros((C,) + shape, dt),
                           NamedSharding(self.grid.mesh, P(None, *spec)))
            for shape, dt, spec in self._roles())

    def _check_square(self, L, ndim: int, order: int | None = None) -> None:
        d = self.n if order is None else order
        if L.ndim != ndim or L.shape[-2:] != (d, d):
            lead = "(M, " if ndim == 3 else "("
            raise ValueError(f"factor must be {lead}{d}, {d}), "
                             f"got {L.shape}")

    def _resolve_pad(self, L, pad_to: int | None) -> int | None:
        """Normalize a padded-admission request: ``pad_to`` must name
        THIS bank's order (the bucket order the caller was routed to),
        the incoming factor a smaller (d, d).  Returns the UpdateSpec
        ``pad_from`` (None when d == n, i.e. no padding needed)."""
        if pad_to is None:
            return None
        if pad_to != self.n:
            raise ValueError(f"pad_to={pad_to} must equal the bank's "
                             f"order n={self.n} (route to the right "
                             f"bucket first)")
        if self.capacity is None:
            raise ValueError(
                "padded admission requires a capacity-allocated bank "
                "(FactorBank(..., capacity=C)): padding runs inside the "
                "compiled updater")
        d = int(L.shape[-1])
        if L.shape[-2:] != (d, d) or not 1 <= d <= self.n:
            raise ValueError(f"padded factor must be (d, d) with "
                             f"1 <= d <= {self.n}, got {L.shape}")
        return None if d == self.n else d

    def _phase1(self, L_lo, stacked: bool = False):
        """Admission-time phase 1: invert the factor's diagonal blocks
        ONCE (the paper's Diagonal-Inverter), so the steady-state
        program is the sweep alone."""
        ph1 = sessionlib._build_phase1(
            self.grid, self.n, self.n0, self._phase1_mode,
            self.policy.accumulate_dtype, self.block_inv, stacked)
        return ph1(L_lo)

    def _entry(self, parts: tuple, stacked: bool = False) -> tuple:
        """(L_lo[, L_hi]) -> the resident tuple (L_lo[, Dt][, L_hi])."""
        if self.method != "inv":
            return parts
        return (parts[0], self._phase1(parts[0], stacked)) + parts[1:]

    def admit(self, L, *, pad_to: int | None = None) -> int:
        """Distribute one natural-layout (n, n) factor into the bank
        (the session's fused gather, operator reductions folded in,
        diagonal blocks pre-inverted); returns the factor's bank
        slot.  A capacity-allocated bank fills its LOWEST free slot
        (re-using evicted slots) through the compiled in-place
        updater; an append-only bank grows by one.

        ``pad_to=n`` admits a SMALLER (d, d) factor into this bank's
        (n, n) bucket order: the compiled updater embeds it as
        ``blockdiag(L, I)`` so the inert tail solves to exact zeros and
        the leading d x k solution block is bit-identical to an
        unpadded order-d solve at the same n0 (DESIGN.md Sec. 12).
        Capacity banks only."""
        L = jnp.asarray(L)
        pad_from = self._resolve_pad(L, pad_to)
        self._check_square(L, 2, order=pad_from)
        if self.capacity is not None:
            return self._admit_slot(L, "natural", pad_from=pad_from)
        preps = sessionlib._factor_preps(self.grid, self.lower,
                                         self.transpose, self.policy,
                                         structure=self.structure,
                                         n0=self.n0)
        self._append(self._entry(tuple(p(L) for p in preps)))
        return self.size - 1

    def admit_stack(self, Ls):
        """Distribute a whole natural-layout (M, n, n) stack; returns
        the admitted slots (a range for append-only banks; a list for
        capacity banks, whose free slots may be non-contiguous).  An
        append-only bank (and an EMPTY capacity bank filled to exactly
        C) ingests the stack in ONE stacked gather program per dtype
        role (plus one stacked phase-1 inversion); a partially-filled
        capacity bank falls back to per-slot admission through the
        compiled updater."""
        Ls = jnp.asarray(Ls)
        self._check_square(Ls, 3)
        M = Ls.shape[0]
        if self.capacity is not None:
            if M > len(self._free):
                raise ValueError(
                    f"bank full: {M} factors for {len(self._free)} free "
                    f"slot(s) of capacity {self.capacity} (evict first)")
            if self._size == 0 and M == self.capacity:
                # full-width fast path: the stacked gather output IS
                # the resident stack — no per-slot scatters at all
                preps = sessionlib._factor_preps(
                    self.grid, self.lower, self.transpose, self.policy,
                    stacked=True, structure=self.structure, n0=self.n0)
                entry = self._entry(tuple(p(Ls) for p in preps),
                                    stacked=True)
                self._stacks = tuple(
                    jax.device_put(a, NamedSharding(self.grid.mesh,
                                                    P(None, *spec)))
                    for a, spec in zip(entry, self._role_specs()))
                self._live = [True] * M
                self._free = []
                self._size = M
                return list(range(M))
            return [self.admit(Ls[j]) for j in range(M)]
        preps = sessionlib._factor_preps(self.grid, self.lower,
                                         self.transpose, self.policy,
                                         stacked=True,
                                         structure=self.structure,
                                         n0=self.n0)
        stacks = self._entry(tuple(p(Ls) for p in preps), stacked=True)
        first = self.size
        self._append_chunk(stacks, Ls.shape[0])
        return range(first, self.size)

    def admit_cyclic(self, L_cyc) -> int:
        """Direct cyclic ingestion: admit a factor ALREADY in the cyclic
        storage the producers emit (``cholesky_cyclic`` / ``lu_cyclic``
        outputs, or a session's ``factor_cyclic``) — no unpermute ->
        re-permute host round trip, no layout change at all; only the
        policy's dtype casts are applied (both resident copies when the
        policy refines, so pass the factor at residual precision or
        better).

        Only valid for the identity operator reduction (lower=True,
        transpose=False): for the other variants the distribution
        gather is not the plain cyclic map, so a raw cyclic array would
        be misinterpreted."""
        if not self.lower or self.transpose:
            raise ValueError(
                "cyclic ingestion requires lower=True, transpose=False "
                "(the reversal/transpose reductions are folded into the "
                "natural-layout distribution gather; a pre-permuted "
                "factor cannot carry them)")
        if self.structure is not None:
            raise ValueError(
                "cyclic ingestion into a structured bank is not "
                "supported: the admission-time block mask is applied "
                "in natural layout, before distribution (mask the "
                "factor yourself and use natural admission)")
        L_cyc = jnp.asarray(L_cyc)
        self._check_square(L_cyc, 2)
        if self.capacity is not None:
            return self._admit_slot(L_cyc, "cyclic")
        sharding = NamedSharding(self.grid.mesh, self.grid.spec_L())
        dts = (self.policy.storage_dtype,)
        if self.policy.refines:
            dts += (self.policy.residual_dtype,)
        parts = tuple(jax.device_put(jnp.asarray(L_cyc, dt), sharding)
                      for dt in dts)
        self._append(self._entry(parts))
        return self.size - 1

    def _append(self, entry: tuple) -> None:
        """Admit one factor: a chunk of width 1."""
        self._append_chunk(tuple(a[None] for a in entry), 1)

    def _append_chunk(self, stacks: tuple, count: int) -> None:
        self._chunks.append(stacks)
        self._size += count

    # ----------------------- live mutation (Sec. 11) -----------------------

    def _alloc_slot(self) -> int:
        if not self._free:
            raise ValueError(
                f"bank full: all {self.capacity} capacity slots are "
                f"live (evict one before admitting)")
        return self._free.pop(0)                  # lowest free slot

    def _admit_slot(self, L, ingest: str, pad_from: int | None = None) -> int:
        """Capacity admission: fill the lowest free slot through the
        compiled updater.  The slot is only committed once the scatter
        succeeds — a failed build/compile (or an interrupt during the
        updater's first trace) puts it back on the free list instead of
        leaking it."""
        slot = self._alloc_slot()
        try:
            self._scatter(slot, L, ingest, pad_from=pad_from)
        except BaseException:
            bisect.insort(self._free, slot)
            raise
        self._live[slot] = True
        self._size += 1
        return slot

    def _check_live(self, slot: int) -> None:
        if not 0 <= slot < self.width:
            raise ValueError(f"slot {slot} out of range for a "
                             f"width-{self.width} bank")
        if not self.is_live(slot):
            raise ValueError(f"slot {slot} is not live (evicted or "
                             f"never admitted); use admit to fill it")

    def update_spec(self, ingest: str = "natural", *, chunk: int = 1,
                    pad_from: int | None = None):
        """The frozen :class:`~repro.core.solver.UpdateSpec` keying
        this bank's compiled in-place updater (== its
        CompiledSolverCache / TRACE_COUNTS key)."""
        from repro.core import solver as solverlib
        if self.width < 1:
            raise ValueError("empty bank: admit factors before updating")
        return solverlib.UpdateSpec(
            n=self.n, grid=self.grid, policy=self.policy,
            method=self.method, n0=self.n0, mode=self._phase1_mode,
            lower=self.lower, transpose=self.transpose,
            block_inv=self.block_inv, bank_width=self.width,
            ingest=ingest, chunk=chunk, pad_from=pad_from,
            structure=self.structure)

    def _slot_id(self, slot: int):
        sid = self._slot_ids.get(slot)
        if sid is None:                  # append-only banks: pin lazily
            sid = self._slot_ids[slot] = self._place_slot_id(slot)
        return sid

    def _scatter(self, slot: int, L, ingest: str, *, chunk: int = 1,
                 pad_from: int | None = None) -> None:
        """Run the compiled donated updater: single-factor admission
        pipeline + scatter of every role into the resident stacks.
        The program is memoized per (ingest, width, chunk, pad_from) on
        the bank so the per-update host overhead is one dict probe, not
        an UpdateSpec construction + cache hash (width is in the key
        only for append-only banks, whose stacks grow; a capacity
        bank's width never changes)."""
        from repro.core import solver as solverlib
        memo = (ingest, self.width, chunk, pad_from)
        prog = self._updaters.get(memo)
        if prog is None:
            prog = solverlib.updater_for(
                self.update_spec(ingest, chunk=chunk, pad_from=pad_from),
                self.cache)
            self._updaters[memo] = prog
        self._stacks = prog.update(self.stacks(), self._slot_id(slot), L)
        self.updates_dispatched += 1

    def place_factor(self, L):
        """Pin a natural-layout replacement factor on device
        (replicated), so a subsequent :meth:`replace`/:meth:`admit`
        pays the (unavoidable) ingestion upload HERE and the update
        itself moves no host data — the factor-side analogue of
        ``Solver.place_rhs``."""
        return jax.device_put(jnp.asarray(L),
                              NamedSharding(self.grid.mesh,
                                            P(None, None)))

    def replace(self, slot: int, L, *, pad_to: int | None = None) -> int:
        """Refresh live ``slot`` IN PLACE with a new natural-layout
        (n, n) factor: one compiled program re-runs the admission
        pipeline for this factor alone (fused distribution gather +
        policy dtype casts + hoisted phase-1 inversion for "inv") and
        scatters all factor roles into the resident stacks with the
        stack buffers donated — zero retraces, zero host round trips,
        no re-stacking, no occupancy change (DESIGN.md Sec. 11).
        ``pad_to=n`` refreshes with a smaller (d, d) factor embedded as
        ``blockdiag(L, I)``, exactly as :meth:`admit`.  Returns the
        slot."""
        L = L if isinstance(L, jax.Array) else jnp.asarray(L)
        pad_from = self._resolve_pad(L, pad_to)
        self._check_square(L, 2, order=pad_from)
        self._check_live(slot)
        self._scatter(slot, L, "natural", pad_from=pad_from)
        return slot

    def replace_run(self, start: int, Ls, *, pad_to: int | None = None
                    ) -> range:
        """Refresh a CONTIGUOUS RUN of live slots
        ``start .. start + u - 1`` with a stacked (u, d, d) factor
        batch in ONE compiled dispatch (``UpdateSpec.chunk = u``):
        stacked gather + stacked phase 1 + a single
        ``dynamic_update_slice`` into the donated resident stacks —
        where a per-slot loop would pay u dispatches
        (the ``refresh_banks`` stacked-parameter path, DESIGN.md
        Sec. 11).  Capacity banks only.  Returns the refreshed slot
        range."""
        if self.capacity is None:
            raise ValueError(
                "replace_run requires a capacity-allocated bank "
                "(FactorBank(..., capacity=C))")
        Ls = Ls if isinstance(Ls, jax.Array) else jnp.asarray(Ls)
        pad_from = self._resolve_pad(Ls, pad_to)
        self._check_square(Ls, 3, order=pad_from)
        u = int(Ls.shape[0])
        if u < 1:
            raise ValueError("replace_run needs at least one factor")
        for slot in range(start, start + u):
            self._check_live(slot)
        if u == 1:
            self._scatter(start, jax.lax.squeeze(Ls, (0,)), "natural",
                          pad_from=pad_from)
        else:
            self._scatter(start, Ls, "natural", chunk=u,
                          pad_from=pad_from)
        return range(start, start + u)

    def replace_cyclic(self, slot: int, L_cyc) -> int:
        """:meth:`replace` for a factor ALREADY in cyclic storage (a
        ``cholesky_cyclic``/``lu_cyclic`` producer output): the updater
        skips the distribution gather and only applies the policy's
        dtype casts (plus phase 1).  Same restriction as
        :meth:`admit_cyclic`: lower=True, transpose=False only."""
        if not self.lower or self.transpose:
            raise ValueError(
                "cyclic ingestion requires lower=True, transpose=False "
                "(the reversal/transpose reductions are folded into the "
                "natural-layout distribution gather; a pre-permuted "
                "factor cannot carry them)")
        L_cyc = L_cyc if isinstance(L_cyc, jax.Array) \
            else jnp.asarray(L_cyc)
        self._check_square(L_cyc, 2)
        self._check_live(slot)
        self._scatter(slot, L_cyc, "cyclic")
        return slot

    def evict(self, slot: int) -> None:
        """Return live ``slot`` to the free list (capacity banks only:
        an append-only bank has no slot lifecycle).  The slot's stale
        device data stays resident but inert — it is never solved
        against (servers zero its panel) and the next ``admit``
        overwrites it in place."""
        if self.capacity is None:
            raise ValueError(
                "evict requires a capacity-allocated bank "
                "(FactorBank(..., capacity=C)); append-only banks have "
                "no free slots")
        self._check_live(slot)
        self._live[slot] = False
        self._gens[slot] += 1
        bisect.insort(self._free, int(slot))
        self._size -= 1

    # ------------------------------- storage -------------------------------

    def _role_specs(self) -> list:
        """Per-role shard specs of a resident entry: L_lo[, Dt][, L_hi]."""
        specs = [self.grid.spec_L()]
        if self.method == "inv":
            from repro.core.inv_trsm import SPEC_DT
            specs.append(SPEC_DT)
        if self.policy.refines:
            specs.append(self.grid.spec_L())
        return specs

    def stacks(self) -> tuple:
        """The resident stacked arrays — one (width, ...) stack per
        factor role (sweep factor[, inverted diagonal faces][,
        residual-dtype factor]), each sharded with a leading unmapped
        factor axis.  Capacity banks return the preallocated stacks
        (admission/replace scattered into them in place — even an
        empty capacity bank has servable, zero-filled stacks, so a
        server can warm up BEFORE any factor exists).  Append-only
        banks fuse lazily and INCREMENTALLY: pending chunks are
        concatenated onto the cached fused stack — never a re-concat
        of the whole admission history per admission — and a pool
        admitted as one ``admit_stack`` IS its gather output
        (``jax.device_put`` onto the sharding it already has is
        free)."""
        if self._stacks is None and not self._chunks:
            raise ValueError("empty bank: admit factors before solving")
        if self._chunks:
            parts = ([self._stacks] if self._stacks is not None else []) \
                + self._chunks
            fused = parts[0] if len(parts) == 1 else tuple(
                jnp.concatenate([c[r] for c in parts])
                for r in range(len(parts[0])))
            self._stacks = tuple(
                jax.device_put(a,
                               NamedSharding(self.grid.mesh,
                                             P(None, *spec)))
                for a, spec in zip(fused, self._role_specs()))
            self._chunks = []
        return self._stacks

    @property
    def factors_cyclic(self):
        """The storage-dtype (M, n, n) stacked cyclic factor."""
        return self.stacks()[0]

    @property
    def factors_cyclic_residual(self):
        """The residual-precision (M, n, n) stacked copy (None unless
        the policy refines)."""
        return self.stacks()[-1] if self.policy.refines else None


class BatchedTrsmSession:
    """DEPRECATED multi-factor serving session — a thin shim over
    :meth:`repro.core.solver.Solver.from_bank`, kept for source
    compatibility; results are bit-identical to the
    :class:`~repro.core.solver.Solver` path.

    ``solve(B)`` takes an (M, n, k) stack — row i is the RHS panel for
    bank factor i — and returns the (M, n, k) solutions in one
    dispatch, with the usual steady-state invariants (zero transfers,
    zero retraces, every precision policy).  New code:

        solver = repro.api.Solver.from_bank(bank)   # or .from_factors
        X = solver.solve(B_stack)
    """

    def __init__(self, bank: FactorBank):
        from repro.core import solver as solverlib
        solverlib._warn_deprecated("BatchedTrsmSession",
                                   "Solver.from_bank")
        self._solver = solverlib.Solver.from_bank(bank)

    @classmethod
    def _wrap(cls, solver) -> "BatchedTrsmSession":
        self = object.__new__(cls)
        self._solver = solver
        return self

    @property
    def bank(self) -> FactorBank:
        return self._solver.bank

    @property
    def solves_served(self) -> int:
        return self._solver.solves_served

    @property
    def n(self) -> int:
        return self._solver.n

    @property
    def policy(self):
        return self._solver.policy

    @property
    def dtype(self):
        """The I/O dtype (what ``solve`` returns, what ``place_rhs``
        casts to): residual dtype for refining policies, compute dtype
        otherwise."""
        return self._solver.dtype

    def program_for(self, k: int) -> SolverProgram:
        return self._solver.program_for(k)

    def place_rhs(self, B):
        return self._solver.place_rhs(jnp.asarray(B, self.dtype))

    def solve(self, B, *, donate: bool = True):
        """Solve op(L_i) X_i = B_i for all M factors in one dispatch
        (strictly the (M, n, k) stack form, as before; M is the bank
        WIDTH — capacity for a capacity-allocated bank)."""
        M = self.bank.width
        if B.ndim != 3 or B.shape[0] != M or B.shape[1] != self.n:
            raise ValueError(f"rhs stack must be ({M}, {self.n}, k), "
                             f"got {B.shape}")
        return self._solver.solve(B, donate=donate)

    def warmup(self, k: int):
        self._solver.warmup(k)
        return self
