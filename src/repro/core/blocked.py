"""Local (single-device) blocked triangular primitives.

These are the numerical building blocks and oracles for the distributed
algorithms in this package:

* ``tri_inv_doubling`` — bottom-up ("recursive doubling") triangular
  inversion.  This is the SPMD-friendly re-derivation of the paper's
  RecTriInv (Sec. V): level ``l`` finalizes the off-diagonal block of every
  diagonal ``2^(l+1)``-block with two batched GEMMs
  (``inv([[A,0],[B,C]]) = [[A^-1,0],[-C^-1 B A^-1, C^-1]]``).
* ``block_diag_invert`` — invert only the ``n/n0`` diagonal blocks
  (the paper's Diagonal-Inverter output ``L~``).
* ``it_inv_trsm_local`` — the single-device schedule of It-Inv-TRSM
  (Sec. VI): multiply by pre-inverted diagonal blocks + trailing GEMM
  updates; no substitution in the sweep.
* ``rec_trsm_local`` — the recursive baseline (Sec. IV) with a
  substitution base case.
* reversal identities to reduce upper/transposed solves to the lower case.

Everything is pure jnp and jit-friendly (static shapes, lax control flow).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def _diag_blocks(a: jnp.ndarray, s: int) -> jnp.ndarray:
    """Extract the (n/s, s, s) diagonal blocks of an (n, n) matrix."""
    n = a.shape[-1]
    nb = n // s
    v = a.reshape(nb, s, nb, s)
    idx = jnp.arange(nb)
    return v[idx, :, idx, :]  # (nb, s, s)


def _set_diag_blocks(a: jnp.ndarray, blocks: jnp.ndarray) -> jnp.ndarray:
    n = a.shape[-1]
    nb, s, _ = blocks.shape
    v = a.reshape(nb, s, nb, s)
    idx = jnp.arange(nb)
    v = v.at[idx, :, idx, :].set(blocks)
    return v.reshape(n, n)


def tri_inv_doubling(L: jnp.ndarray) -> jnp.ndarray:
    """Invert a lower-triangular matrix by bottom-up block doubling.

    Cost-identical to the paper's RecTriInv but single-program: log2(n)
    levels, each two batched GEMMs over all off-diagonal blocks at that
    level.  Pads to the next power of two with an identity block
    (``inv([[L,0],[0,I]]) = [[L^-1,0],[0,I]]``).
    """
    n = L.shape[-1]
    N = next_pow2(n)
    if N != n:
        Lp = jnp.eye(N, dtype=L.dtype)
        L = Lp.at[:n, :n].set(L)
    # Level 0: invert the 1x1 diagonal.
    d = jnp.diagonal(L)
    A = L * (1.0 - jnp.eye(N, dtype=L.dtype)) + jnp.diag(1.0 / d)
    s = 1
    while s < N:
        blk = _diag_blocks(A, 2 * s)          # (nb, 2s, 2s)
        a11i = blk[:, :s, :s]                  # already inverted
        a22i = blk[:, s:, s:]                  # already inverted
        l21 = blk[:, s:, :s]                   # still original L entries
        new21 = -jnp.einsum("bij,bjk,bkl->bil", a22i, l21, a11i)
        blk = blk.at[:, s:, :s].set(new21)
        A = _set_diag_blocks(A, blk)
        s *= 2
    return A[:n, :n] if N != n else A


def tri_inv_batched(Ls: jnp.ndarray) -> jnp.ndarray:
    """vmap of tri_inv_doubling over a stack (m, n0, n0)."""
    return jax.vmap(tri_inv_doubling)(Ls)


def block_diag_invert(L: jnp.ndarray, n0: int) -> jnp.ndarray:
    """Return L~: L with every (n0 x n0) diagonal block inverted in place.

    This is the output contract of the paper's Diagonal-Inverter: the
    off-diagonal panels are untouched; only diagonal blocks are inverted.
    """
    n = L.shape[-1]
    assert n % n0 == 0, (n, n0)
    blocks = _diag_blocks(L, n0)
    inv = tri_inv_batched(blocks)
    return _set_diag_blocks(L, inv)


def it_inv_trsm_local(L: jnp.ndarray, B: jnp.ndarray, n0: int,
                      block_inv=None) -> jnp.ndarray:
    """It-Inv-TRSM (paper Sec. VI) on one device: solve L X = B.

    1. Invert diagonal n0-blocks ("inversion" phase).
    2. Sweep i = 0..n/n0-1:  X_i = L~_ii @ B_i   (GEMM, not substitution)
       then the trailing update B_{>i} -= L[:, S_i] @ X_i  (GEMM),
       masked to rows > (i+1) n0 (the paper's T_{i+1} update range,
       expressed with static shapes for SPMD/jit friendliness).

    ``block_inv``: optional override for the batched diagonal-block
    inverter (e.g. the Pallas kernel); defaults to tri_inv_batched.
    """
    n = L.shape[-1]
    k = B.shape[-1]
    assert n % n0 == 0
    m = n // n0
    inv_fn = block_inv if block_inv is not None else tri_inv_batched
    dblocks = inv_fn(_diag_blocks(L, n0))      # (m, n0, n0) inverted

    row_ids = jnp.arange(n)

    def body(i, carry):
        B_cur, X = carry
        Bi = jax.lax.dynamic_slice(B_cur, (i * n0, 0), (n0, k))
        Xi = dblocks[i] @ Bi                                   # solve via GEMM
        X = jax.lax.dynamic_update_slice(X, Xi, (i * n0, 0))
        panel = jax.lax.dynamic_slice(L, (0, i * n0), (n, n0))  # L[:, S_i]
        mask = (row_ids >= (i + 1) * n0).astype(L.dtype)[:, None]
        B_cur = B_cur - mask * (panel @ Xi)
        return B_cur, X

    _, X = jax.lax.fori_loop(0, m, body, (B, jnp.zeros_like(B)))
    return X


def rec_trsm_local(L: jnp.ndarray, B: jnp.ndarray, n0: int) -> jnp.ndarray:
    """Recursive TRSM baseline (paper Sec. IV) on one device.

    Splits L into quadrants until n <= n0, base case = forward
    substitution (jax.scipy solve_triangular).  Python recursion over
    static shapes — unrolled at trace time, as in the paper's recursion.
    """
    n = L.shape[-1]
    if n <= n0:
        return jax.scipy.linalg.solve_triangular(L, B, lower=True)
    h = n // 2
    L11, L21, L22 = L[:h, :h], L[h:, :h], L[h:, h:]
    X1 = rec_trsm_local(L11, B[:h], n0)
    B2 = B[h:] - L21 @ X1
    X2 = rec_trsm_local(L22, B2, n0)
    return jnp.concatenate([X1, X2], axis=0)


def forward_substitution(L: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """Row-by-row forward substitution (the latency/VPU-bound baseline
    that the paper's inversion approach replaces).  Reference only."""
    n = L.shape[-1]

    def body(i, X):
        xi = (B[i] - L[i] @ X) / L[i, i]
        return X.at[i].set(xi)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(B))


# ----- reductions of the other triangular cases to the lower-left one -----

def solve_lower(L, B, solver, **kw):
    return solver(L, B, **kw)


def solve_upper(U, B, solver, **kw):
    """U X = B via the reversal identity: J U J is lower-triangular."""
    Lr = U[::-1, ::-1]
    return solver(Lr, B[::-1], **kw)[::-1]


def solve_lower_t(L, B, solver, **kw):
    """L^T X = B (upper solve with the lower factor) via reversal."""
    return solve_upper(L.T, B, solver, **kw)


def spd_solve(L_chol, B, solver, **kw):
    """A^-1 B given A = L L^T: two triangular solves (the K-FAC use)."""
    Y = solve_lower(L_chol, B, solver, **kw)
    return solve_lower_t(L_chol, Y, solver, **kw)
