"""Cholesky factorization built on the paper's primitives.

The paper's Sec. I motivation: "TRSM is used extensively ... to compute
factorizations with triangular matrices, such as Cholesky, LU, and QR."
This module closes that loop: a distributed Cholesky whose panel solve
is performed by *selective triangular inversion* (multiplication by an
inverted triangular factor) instead of substitution-based TRSM — i.e.
the paper's technique applied to its own motivating consumer.

  chol([[A11, .], [A21, A22]]):
      L11  = chol(A11)                        (recursive)
      L21  = A21 * L11^{-T}                   (invert + MM, Secs. V/III)
      A22' = A22 - L21 * L21^T                (MM, Sec. III)
      L22  = chol(A22')                       (recursive)

Also provides the local blocked factorization used by the KFAC-CA
optimizer (per-layer Kronecker factors), and the distributed transpose
for cyclic storage (1 permute + 1 all-to-all) used by the L11^{-T} and
L21^T steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import blocked, comm
from repro.core import tri_inv as ti
from repro.core.grid import TrsmGrid
from repro.core.mm3d import mm3d_shard

MESH_AXES = ("x", "y", "z")


# ------------------------ local blocked Cholesky ------------------------

def chol_blocked_local(A: jnp.ndarray, bs: int) -> jnp.ndarray:
    """Left-looking blocked Cholesky; panel solve by multiplication with
    the inverted diagonal block (the paper's selective inversion)."""
    n = A.shape[-1]
    assert n % bs == 0, (n, bs)
    nb = n // bs
    L = jnp.zeros_like(A)
    for j in range(nb):
        s0, s1 = j * bs, (j + 1) * bs
        Ljl = L[s0:s1, :s0]
        Ajj = A[s0:s1, s0:s1] - Ljl @ Ljl.T
        Ljj = jnp.linalg.cholesky(Ajj)
        L = L.at[s0:s1, s0:s1].set(Ljj)
        if s1 < n:
            Pj = A[s1:, s0:s1] - L[s1:, :s0] @ Ljl.T
            Ljj_inv = blocked.tri_inv_doubling(Ljj)
            L = L.at[s1:, s0:s1].set(Pj @ Ljj_inv.T)
    return L


# -------------------- distributed cyclic-storage transpose --------------

def _swap_perm(p1: int):
    return [(x * p1 + y, y * p1 + x) for x in range(p1) for y in range(p1)]


def transpose_shard(Aloc, *, mr: int, nc: int, p1: int, p2: int):
    """Per-shard transpose: (mr x nc) cyclic piece -> (nc x mr) cyclic
    piece of A^T, same storage scheme.  1 ppermute + 1 all_to_all."""
    a, b = Aloc.shape                  # (mr/p1, nc/(p1 p2))
    assert a == mr // p1 and b == nc // (p1 * p2)
    Pc = comm.ppermute(Aloc, ("x", "y"), _swap_perm(p1)) if p1 > 1 else Aloc
    if p2 > 1:
        aq = a // p2
        Q = Pc.reshape(aq, p2, b).transpose(1, 0, 2)       # [z'', q, c']
        G = comm.all_to_all(Q, "z", split_axis=0, concat_axis=0,
                            tiled=True)                    # [z_src, q, c']
        G = G.reshape(p2, aq, b)
        T = G.transpose(2, 0, 1).reshape(b * p2, aq)       # [c'*p2+z, q]
    else:
        T = Pc.T
    return T


@functools.lru_cache(maxsize=64)
def transpose_fn(grid: TrsmGrid, mr: int, nc: int):
    body = functools.partial(transpose_shard, mr=mr, nc=nc,
                             p1=grid.p1, p2=grid.p2)
    spec = P("x", ("z", "y"))
    return jax.jit(compat.shard_map(body, mesh=grid.mesh, in_specs=(spec,),
                                 out_specs=spec))


# ---------------------- distributed recursive Cholesky ------------------

def _chol_base(Aloc, *, n, p1, p2):
    """Base case: allgather, factor locally, keep the cyclic piece."""
    xi = comm.axis_index("x")
    yi = comm.axis_index("y")
    zi = comm.axis_index("z")
    Ag = comm.all_gather(Aloc[None], MESH_AXES, axis=0, tiled=False)
    from repro.core.tri_inv import _assemble_blocks, _cyclic_piece
    Afull = _assemble_blocks(Ag, p1, p2)[0]            # (n, n)
    Lfull = jnp.linalg.cholesky(Afull)
    return _cyclic_piece(Lfull[None], xi, yi, zi, p1, p2)[0]


def _chol_rec(Aloc, *, n, n0, p1, p2):
    if n <= n0:
        return _chol_base(Aloc, n=n, p1=p1, p2=p2)
    h = n // 2
    hl, hc = h // p1, h // (p1 * p2)
    A11 = Aloc[:hl, :hc]
    A21 = Aloc[hl:, :hc]
    A22 = Aloc[hl:, hc:]
    L11 = _chol_rec(A11, n=h, n0=n0, p1=p1, p2=p2)
    # panel: L21 = A21 L11^{-T}  via selective inversion (no substitution)
    L11i = ti.tri_inv_shard(L11, n=h, p1=p1, p2=p2)
    L11iT = transpose_shard(L11i, mr=h, nc=h, p1=p1, p2=p2)
    L21 = mm3d_shard(A21, L11iT, m=h, n=h, k=h, p1=p1, p2=p2)
    # trailing update: A22 - L21 L21^T
    L21T = transpose_shard(L21, mr=h, nc=h, p1=p1, p2=p2)
    A22u = A22 - mm3d_shard(L21, L21T, m=h, n=h, k=h, p1=p1, p2=p2)
    L22 = _chol_rec(A22u, n=h, n0=n0, p1=p1, p2=p2)
    top = jnp.concatenate([L11, jnp.zeros((hl, hc), Aloc.dtype)], axis=1)
    bot = jnp.concatenate([L21, L22], axis=1)
    return jnp.concatenate([top, bot], axis=0)


@functools.lru_cache(maxsize=64)
def cholesky_fn(grid: TrsmGrid, n: int, n0: int | None = None):
    """Jitted distributed Cholesky for fixed shapes (cyclic storage).
    Memoized: repeated same-shape factorizations reuse the compiled
    program."""
    n0 = n0 or max(grid.p1 * grid.p1 * grid.p2, n // 8)
    while n % n0 != 0:
        n0 *= 2
    body = functools.partial(_chol_rec, n=n, n0=min(n0, n),
                             p1=grid.p1, p2=grid.p2)
    spec = P("x", ("z", "y"))
    return jax.jit(compat.shard_map(body, mesh=grid.mesh, in_specs=(spec,),
                                 out_specs=spec))


def cholesky_cyclic(A, grid: TrsmGrid, n0: int | None = None):
    """Factor A (natural layout, symmetric PD) and return L in CYCLIC
    storage — the factorization's own working layout, un-unpermuted.

    This is the factor-producer end of the paper's producer->consumer
    loop (Sec. I: "TRSM is used extensively ... Cholesky, LU, QR"): the
    result feeds ``repro.core.bank.FactorBank.admit_cyclic`` (or any
    cyclic-storage consumer) directly, with no unpermute -> re-permute
    round trip and no host traffic."""
    from repro.core.grid import cyclic_matrix_device
    n = A.shape[0]
    p1, p2 = grid.p1, grid.p2
    Ac = cyclic_matrix_device(jnp.asarray(A), p1, p1 * p2)
    return cholesky_fn(grid, n, n0)(Ac)


def cholesky(A, grid: TrsmGrid, n0: int | None = None):
    """Natural-layout convenience entry point (A symmetric PD).

    Device-resident: the cyclic permutations run as on-device gathers
    (repro.core.grid.cyclic_matrix_device) and the compiled program is
    memoized — no host round-trip, no per-call retrace.  For feeding a
    FactorBank keep the cyclic output instead: :func:`cholesky_cyclic`."""
    from repro.core.grid import cyclic_matrix_device
    p1, p2 = grid.p1, grid.p2
    Lc = cholesky_cyclic(A, grid, n0)
    return cyclic_matrix_device(Lc, p1, p1 * p2, inverse=True)
