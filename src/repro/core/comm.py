"""Collective-communication shims with trace-time cost accounting.

Every distributed algorithm in ``repro.core`` issues its collectives
through this module.  Each wrapper (a) calls the corresponding
``jax.lax`` primitive unchanged and (b) — when a :class:`CostTrace` is
active — records the paper's alpha-beta-gamma cost of the call computed
from *static* shapes (Sec. II-C1 closed forms).  Because shapes are
static, the full critical-path cost of an algorithm is known at trace
time: tracing the program once (e.g. via ``jax.eval_shape``) yields the
exact S/W/F counts that the paper derives by hand.  This is the
mechanism behind ``benchmarks/bench_mm_costs.py`` and
``bench_paper_table.py`` (paper-table validation) and the collective
term of the roofline analysis.

Loop bodies are traced once but execute many times; wrap the loop in
``with comm.scope(trip_count):`` so recorded costs are multiplied by the
trip count (see ``inv_trsm.py``).

Cost conventions (paper Sec. II-C1, words = elements):
    allgather(n_total, p):      S = log p,   W = n_total * 1_p
    reduce-scatter(n_total, p): S = log p,   W = n_total * 1_p, F = n_total * 1_p
    allreduce(n, p):            S = 2 log p, W = 2 n * 1_p,     F = n * 1_p
    bcast(n, p):                S = 2 log p, W = 2 n * 1_p
    all-to-all(n_local, p):     S = log p,   W = n_local * log(p) / 2
    point-to-point (permute):   S = 1,       W = n_local
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import compat


def _lg(p: float) -> float:
    return math.log2(max(p, 1.0))


def _ind(p: float) -> float:
    return 1.0 if p > 1 else 0.0


@dataclasses.dataclass
class Record:
    op: str
    axis: str
    p: int
    words: float      # payload measure used by the closed form (see op)
    s: float          # latency contribution (messages)
    w: float          # bandwidth contribution (words)
    f: float          # flop contribution
    mult: float       # loop multiplier in effect


@dataclasses.dataclass
class CostTrace:
    records: list[Record] = dataclasses.field(default_factory=list)

    @property
    def s(self) -> float:
        return sum(r.s * r.mult for r in self.records)

    @property
    def w(self) -> float:
        return sum(r.w * r.mult for r in self.records)

    @property
    def f(self) -> float:
        return sum(r.f * r.mult for r in self.records)

    def by_op(self) -> dict:
        out: dict[str, dict] = {}
        for r in self.records:
            d = out.setdefault(r.op, dict(count=0.0, s=0.0, w=0.0, f=0.0))
            d["count"] += r.mult
            d["s"] += r.s * r.mult
            d["w"] += r.w * r.mult
            d["f"] += r.f * r.mult
        return out

    def summary(self) -> dict:
        return dict(s=self.s, w=self.w, f=self.f)


_ACTIVE: contextvars.ContextVar[CostTrace | None] = \
    contextvars.ContextVar("repro_comm_trace", default=None)
_MULT: contextvars.ContextVar[float] = \
    contextvars.ContextVar("repro_comm_mult", default=1.0)


@contextlib.contextmanager
def trace():
    """Activate cost recording; yields the CostTrace being filled."""
    t = CostTrace()
    tok = _ACTIVE.set(t)
    try:
        yield t
    finally:
        _ACTIVE.reset(tok)


@contextlib.contextmanager
def scope(mult: float):
    """Multiply costs recorded inside by ``mult`` (loop trip counts)."""
    tok = _MULT.set(_MULT.get() * mult)
    try:
        yield
    finally:
        _MULT.reset(tok)


def _axis_size(axis_name) -> int:
    return int(compat.axis_size(axis_name))


def _size(x) -> int:
    return int(math.prod(x.shape)) if x.shape else 1


def _rec(op, axis, p, words, s, w, f):
    t = _ACTIVE.get()
    if t is not None:
        name = ",".join(axis) if isinstance(axis, (tuple, list)) else str(axis)
        t.records.append(Record(op, name, p, words, s, w, f, _MULT.get()))


# --------------------------- the wrappers ---------------------------

def all_gather(x, axis_name, *, axis: int = 0, tiled: bool = False):
    p = _axis_size(axis_name)
    n_total = _size(x) * p
    _rec("allgather", axis_name, p, n_total,
         s=_lg(p), w=n_total * _ind(p), f=0.0)
    return jax.lax.all_gather(x, axis_name, axis=axis, tiled=tiled)


def psum(x, axis_name):
    p = _axis_size(axis_name)
    n = _size(x)
    _rec("allreduce", axis_name, p, n,
         s=2 * _lg(p), w=2 * n * _ind(p), f=n * _ind(p))
    return jax.lax.psum(x, axis_name)


def psum_scatter(x, axis_name, *, scatter_dimension: int = 0,
                 tiled: bool = False):
    p = _axis_size(axis_name)
    n_total = _size(x)          # input holds the full (pre-scatter) array
    _rec("reduce-scatter", axis_name, p, n_total,
         s=_lg(p), w=n_total * _ind(p), f=n_total * _ind(p))
    return jax.lax.psum_scatter(x, axis_name,
                                scatter_dimension=scatter_dimension,
                                tiled=tiled)


def all_to_all(x, axis_name, *, split_axis: int, concat_axis: int,
               tiled: bool = False):
    p = _axis_size(axis_name)
    n_local = _size(x)
    _rec("alltoall", axis_name, p, n_local,
         s=_lg(p), w=n_local * _lg(p) / 2.0, f=0.0)
    return jax.lax.all_to_all(x, axis_name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=tiled)


def ppermute(x, axis_name, perm: Sequence[tuple[int, int]]):
    p = _axis_size(axis_name)
    n_local = _size(x)
    _rec("permute", axis_name, p, n_local, s=1.0, w=n_local, f=0.0)
    return jax.lax.ppermute(x, axis_name, perm)


# ------------------- async (start/finish) wrappers -------------------
#
# Software-pipelining primitives (DESIGN.md Sec. 16): ``*_start``
# issues the collective and returns an opaque handle; ``*_finish``
# yields its value.  The COST is recorded once, at start — that is
# where the messages leave the wire — so a start/finish pair prices
# identically to the synchronous wrapper it replaces.  On jax builds
# with no async collective API (every 0.4.x), ``repro.compat`` issues
# the collective eagerly and finish is the identity: bit-identical
# values, with overlap left to XLA's latency-hiding scheduler (the
# data dependence between start and finish is the same either way).

def all_gather_start(x, axis_name, *, axis: int = 0,
                     tiled: bool = False):
    """Begin ``all_gather``; pair with :func:`all_gather_finish`."""
    p = _axis_size(axis_name)
    n_total = _size(x) * p
    _rec("allgather", axis_name, p, n_total,
         s=_lg(p), w=n_total * _ind(p), f=0.0)
    return compat.async_all_gather_start(x, axis_name, axis=axis,
                                         tiled=tiled)


def all_gather_finish(handle):
    """Complete an :func:`all_gather_start` (cost already recorded)."""
    return compat.async_all_gather_finish(handle)


def ppermute_start(x, axis_name, perm: Sequence[tuple[int, int]]):
    """Begin ``ppermute``; pair with :func:`ppermute_finish`."""
    p = _axis_size(axis_name)
    n_local = _size(x)
    _rec("permute", axis_name, p, n_local, s=1.0, w=n_local, f=0.0)
    return compat.async_ppermute_start(x, axis_name, perm)


def ppermute_finish(handle):
    """Complete a :func:`ppermute_start` (cost already recorded)."""
    return compat.async_ppermute_finish(handle)


def bcast_from(x, axis_name, root: int = 0):
    """Broadcast the value held at ``root`` along ``axis_name`` to all.

    Implemented as mask + psum (the standard SPMD idiom); accounted with
    the paper's bcast cost 2 log p latency, 2n bandwidth (allgather +
    scatter construction, Sec. II-C1) — NOT with the allreduce cost of
    the implementation idiom, since on TPU XLA pattern-matches this to a
    broadcast.
    """
    p = _axis_size(axis_name)
    n = _size(x)
    _rec("bcast", axis_name, p, n,
         s=2 * _lg(p), w=2 * n * _ind(p), f=0.0)
    idx = jax.lax.axis_index(axis_name)
    masked = jnp.where(idx == root, x, jnp.zeros_like(x))
    return jax.lax.psum(masked, axis_name)


def axis_index(axis_name):
    return jax.lax.axis_index(axis_name)


# ---------------------- trace helpers ----------------------

def traced_cost(fn, *args, **kwargs) -> CostTrace:
    """Trace ``fn`` (typically a jitted shard_map program) on abstract
    values and return the recorded collective costs.  ``args`` may be
    ShapeDtypeStructs or concrete arrays (no compute happens)."""
    with trace() as t:
        jax.eval_shape(fn, *args, **kwargs)
    return t
