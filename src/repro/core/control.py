"""Control plane: SLO-aware admission + fleet autoscaling for the
async serving tier (DESIGN.md Sec. 15; re-exported via ``repro.api``).

The paper's a priori cost analysis makes configurations priceable
BEFORE anything compiles — :func:`~repro.core.fleet.plan_fleet`
already exploits that to bucket a mixed-order manifest, and the async
tier (DESIGN.md Sec. 13) tracks a live latency window but sheds only
on queue depth.  This module closes the loop, scheduling work against
a priced DAG in the Böhnlein et al. (arXiv:2503.05408) sense:

* :class:`AdmissionController` — deadline-aware admission.  At submit
  time it estimates the request's queue wait from the live per-wave
  service EWMA (seeded by the cost-model steady solve time,
  :func:`repro.core.tuning.serving_steady_s`, until real waves have
  been measured) and the target slot's queued backlog
  (:func:`repro.core.cost_model.queue_wait_estimate`).  A request
  whose ``arrival + wait_estimate`` cannot meet ``slo_ms`` is shed
  with :class:`~repro.core.errors.DeadlineUnmeetable` — surfaced ONLY
  through its :class:`~repro.core.serving.SolveFuture`, so producers
  keep one exception-free submit path.  Admitted requests are stamped
  with their deadline, and :meth:`FairQueue.pack
  <repro.core.serving.FairQueue.pack>` reorders WITHIN each tenant's
  FIFO window by earliest deadline first (cross-tenant weighted
  fairness untouched).

* :class:`Autoscaler` — planner-driven bucket splits/merges.  It
  tracks per-bucket offered-rate EWMAs (columns/s submitted) against
  each bucket's service capacity (``panel_k`` / measured-or-modeled
  seconds per wave) and, when the worst bucket's utilization drifts
  out of the [low_water, high_water] band, re-prices the LIVE manifest
  with :func:`plan_fleet` at a load-scaled dispatch budget: saturation
  shrinks the budget (padding overhead stops being bought back →
  split), underutilization grows it (dispatch overhead dominates →
  merge).  Replanning is pure cost-model arithmetic — nothing
  compiles until the new buckets serve.  An adopted plan is applied
  LIVE: resident factors migrate through the existing admit/evict
  churn path (:meth:`SolverFleet.apply_plan
  <repro.core.fleet.SolverFleet.apply_plan>`), queued requests are
  re-keyed onto their new slots (:meth:`AsyncSolveServer.rekey_queue
  <repro.core.serving.AsyncSolveServer.rekey_queue>`) so migration
  strands NOTHING, and buckets that survive the replan keep their
  banks — their compiled programs, and the zero-retrace/zero-transfer
  steady state, hold on every non-migrating wave.

Determinism contract: neither class ever reads a wall clock — every
decision is a function of the server's injected clock and its
counters, so the FakeClock/DrainDriver harness (tests/conftest.py)
reproduces admission and scaling decisions exactly.
"""

from __future__ import annotations

import math

from repro.core import cost_model as cm
from repro.core import errors as _errors
from repro.core import tuning
from repro.core.fleet import plan_fleet


def _steady_seed_s(server, unit, machine=None) -> float:
    """Cost-model seed for one dispatch unit's seconds-per-wave: the
    hoisted steady solve time at the unit's order and the server's
    panel width — the a priori stand-in until the unit's measured
    wave EWMA exists."""
    if server.fleet is not None:
        solver = server.fleet.solver(unit)
    else:
        solver = server.solver
    n0 = solver.n0 if solver.method == "inv" else None
    return tuning.serving_steady_s(
        solver.n, server.panel_k, solver.grid, machine=machine, n0=n0,
        structure=getattr(solver.bank, "structure", None))


class AdmissionController:
    """Deadline-aware admission for :class:`~repro.core.serving.
    AsyncSolveServer` (DESIGN.md Sec. 15).

        ctrl = api.AdmissionController()
        server = api.AsyncSolveServer(solver, panel_k, slo_ms=50.0,
                                      admission=ctrl).warmup()
        fut = server.submit(b)          # never raises for deadline
        err = fut.exception(timeout=1)  # DeadlineUnmeetable when shed

    ``slo_ms`` defaults to the server's; ``safety`` scales the budget
    (0.8 sheds at 80% of the SLO — headroom for estimate error).
    ``dispatch_s`` is the per-wave launch overhead added to the
    modeled service time, the same budget :func:`plan_fleet` prices
    merges against.  All state is derived from the server's injected
    clock and queue/latency counters — no wall-clock reads, so
    decisions replay exactly under the FakeClock harness."""

    def __init__(self, *, slo_ms: float | None = None,
                 safety: float = 1.0, dispatch_s: float = 0.0,
                 machine=None):
        if not safety > 0:
            raise ValueError(f"safety must be > 0, got {safety}")
        self.slo_ms = slo_ms
        self.safety = safety
        self.dispatch_s = dispatch_s
        self.machine = machine
        self.admitted = 0
        self.shed = 0
        self._seeds: dict = {}      # dispatch unit -> modeled s/wave
        self._server = None

    def attach(self, server) -> None:
        """Called by the server at construction (``admission=``)."""
        self._server = server

    def service_s(self, server, unit) -> float:
        """Seconds per wave for a dispatch unit: the live measured
        EWMA once waves have finalized, the cost-model steady seed
        before (both plus ``dispatch_s``)."""
        s = server._wave_ewma.get(unit)
        if s is None:
            s = self._seeds.get(unit)
            if s is None:
                s = self._seeds[unit] = _steady_seed_s(
                    server, unit, self.machine)
        return s + self.dispatch_s

    def wait_estimate(self, server, key, width: int) -> float:
        """Estimated seconds from submit to completion for a request
        of ``width`` columns against queue ``key`` — backlog waves
        ahead of it, its own wave, and the in-flight pipeline, each at
        the unit's per-wave service time."""
        fq = server._queues.get(key)
        queued = fq.queued_width() if fq is not None else 0
        unit = server._unit(key)
        return cm.queue_wait_estimate(
            queued, width, len(server._inflight), server.panel_k,
            self.service_s(server, unit) - self.dispatch_s,
            self.dispatch_s)

    def admit(self, server, key, req, now: float) -> None:
        """The server's submit hook: stamp the request's deadline, or
        shed it by raising
        :class:`~repro.core.errors.DeadlineUnmeetable` (the server
        fails the future with it; submit still returns the handle)."""
        slo_ms = self.slo_ms if self.slo_ms is not None \
            else server.slo_ms
        if slo_ms is None:
            return                   # no SLO: depth-only admission
        budget_s = slo_ms * 1e-3 * self.safety
        fq = server._queues.get(key)
        if (fq is None or len(fq) == 0) and not server._inflight:
            # probe path: an idle system always admits one request —
            # its measured wave refreshes the service EWMA, so a
            # pessimistic estimate (e.g. startup compiles folded into
            # early samples) can never wedge admission shut
            self.admitted += 1
            req.deadline = now + slo_ms * 1e-3
            return
        wait_s = self.wait_estimate(server, key, req.width)
        if wait_s > budget_s:
            self.shed += 1
            raise _errors.DeadlineUnmeetable(
                f"request for tenant {req.tenant!r} at slot {key} "
                f"cannot meet its {slo_ms:.1f} ms SLO: estimated "
                f"queue wait {wait_s * 1e3:.1f} ms > budget "
                f"{budget_s * 1e3:.1f} ms — shed at admission so "
                f"capacity serves requests that CAN finish in time")
        self.admitted += 1
        req.deadline = now + slo_ms * 1e-3

    def stats(self) -> dict:
        return dict(admitted=self.admitted, shed=self.shed,
                    slo_ms=self.slo_ms, safety=self.safety)


class Autoscaler:
    """Planner-driven online bucket splits/merges for a fleet-mode
    :class:`~repro.core.serving.AsyncSolveServer` (DESIGN.md Sec. 15).

        fleet = api.SolverFleet(grid, api.plan_fleet(manifest, grid))
        server = api.AsyncSolveServer(fleet, panel_k).warmup()
        scaler = api.Autoscaler(server)     # attaches: step() ticks it

    Each :meth:`tick` (driven by the server's ``step`` once attached,
    or called directly by a harness) refreshes the per-bucket
    offered-rate EWMAs from the server's submit counters; when the
    maximum bucket utilization leaves [``low_water``, ``high_water``]
    and the ``dwell_s`` hold-down has elapsed, the live manifest is
    re-priced with :func:`plan_fleet` at dispatch budget
    ``base_dispatch_s * target / pressure`` and — if the bucket set
    actually changes — applied as a live migration.  Under sustained
    pressure the post-replan plan is a fixed point (same keys → no-op
    ticks), so scaling CONVERGES instead of thrashing; ``dwell_s``
    bounds the replan rate on top of that.  Decision records
    accumulate in :attr:`replans`."""

    def __init__(self, server, *, high_water: float = 0.85,
                 low_water: float = 0.25, target: float = 0.5,
                 dwell_s: float = 1.0, rate_alpha: float = 0.3,
                 dispatch_s: float | None = None, headroom: int = 0,
                 machine=None, attach: bool = True):
        if server.fleet is None:
            raise ValueError(
                "Autoscaler needs a fleet-mode AsyncSolveServer "
                "(AsyncSolveServer(SolverFleet, ...)): bucket "
                "splits/merges are a fleet concept")
        if not 0 < low_water < target < high_water:
            raise ValueError(
                f"need 0 < low_water < target < high_water, got "
                f"{low_water}, {target}, {high_water}")
        self.server = server
        self.high_water = high_water
        self.low_water = low_water
        self.target = target
        self.dwell_s = dwell_s
        self.rate_alpha = rate_alpha
        self.base_dispatch_s = dispatch_s if dispatch_s is not None \
            else server.fleet.plan.dispatch_s
        self.headroom = headroom
        self.machine = machine
        self.offered_ewma: dict = {}     # bucket key -> cols/s
        self.replans: list[dict] = []
        self._seeds: dict = {}
        self._last_tick: float | None = None
        self._last_offered: dict = {}
        self._last_replan: float | None = None
        if attach:
            server.attach_autoscaler(self)

    # ------------------------------ signals ------------------------------

    def _observe(self, now: float) -> None:
        """Fold the submit-counter deltas since the last tick into the
        per-bucket offered-rate EWMAs."""
        if self._last_tick is None:
            self._last_tick = now
            self._last_offered = dict(self.server._offered_cols)
            return
        dt = now - self._last_tick
        if dt <= 0:
            return
        cur = dict(self.server._offered_cols)
        a = self.rate_alpha
        for key in self.server.fleet.buckets:
            rate = (cur.get(key, 0)
                    - self._last_offered.get(key, 0)) / dt
            prev = self.offered_ewma.get(key)
            self.offered_ewma[key] = rate if prev is None \
                else (1 - a) * prev + a * rate
        self._last_tick = now
        self._last_offered = cur

    def observe(self, now: float | None = None) -> None:
        """Refresh the offered-rate EWMAs WITHOUT making a scaling
        decision — re-baselines the observation window (useful after
        a known-idle gap that should not read as underutilization)."""
        self._observe(self.server._now() if now is None else now)

    def _service_s(self, key) -> float:
        s = self.server._wave_ewma.get(key)
        if s is None:
            s = self._seeds.get(key)
            if s is None:
                s = self._seeds[key] = _steady_seed_s(
                    self.server, key, self.machine)
        return s

    def utilization(self) -> dict:
        """Per-bucket offered/capacity ratio: offered cols/s over
        ``panel_k / s_per_wave`` (measured EWMA, cost-model seed until
        one exists)."""
        out = {}
        for key in self.server.fleet.buckets:
            cap = self.server.panel_k / max(self._service_s(key),
                                            1e-12)
            out[key] = self.offered_ewma.get(key, 0.0) / cap
        return out

    # ------------------------------ decisions ------------------------------

    def replan(self, dispatch_s: float):
        """Price a new :class:`~repro.core.fleet.FleetPlan` for the
        LIVE manifest at the given dispatch budget — pure arithmetic,
        no compilation, no migration (that is :meth:`apply`)."""
        fleet = self.server.fleet
        man = fleet.manifest()
        if not man:
            return None
        ref = fleet.plan.buckets[0]
        structure = next((b.structure for b in fleet.plan.buckets
                          if b.structure is not None), None)
        return plan_fleet(man, fleet.grid, k=fleet.plan.k,
                          precision=ref.policy, machine=self.machine,
                          dispatch_s=dispatch_s,
                          headroom=self.headroom,
                          structure=structure)

    def apply(self, plan) -> dict:
        """Adopt a plan LIVE under the server's step lock: migrate
        resident factors (:meth:`SolverFleet.apply_plan
        <repro.core.fleet.SolverFleet.apply_plan>`), re-key queued
        requests onto their new slots (stranding nothing), and drop
        the dispatchers of closed/rebuilt buckets so the next wave
        packs against the new banks."""
        srv = self.server
        with srv._step_lock:
            report = srv.fleet.apply_plan(
                plan, on_move=srv.rekey_queue)
            for key in report["closed"] + report["rebuilt"]:
                srv.drop_dispatch_unit(key)
        return report

    def tick(self, now: float | None = None):
        """One control-loop iteration on the server's clock.  Returns
        the migration report when a replan was applied, else None."""
        srv = self.server
        now = srv._now() if now is None else now
        self._observe(now)
        if self._last_replan is not None \
                and now - self._last_replan < self.dwell_s:
            return None
        if not self.offered_ewma:
            return None              # no completed observation yet
        utils = self.utilization()
        pressure = max(utils.values(), default=0.0)
        if pressure > self.high_water:
            # saturation side: decay the dispatch price linearly,
            # hitting ZERO at 2x target — once offered exceeds
            # capacity, latency is queue-bound and every padded
            # column is pure waste, so buy ALL padding back (full
            # split by order)
            eff = self.base_dispatch_s \
                * max(0.0, 2.0 - pressure / self.target)
        elif pressure < self.low_water:
            # idle side (down to fully idle): dispatch overhead
            # dominates → raise its price so plan_fleet merges
            eff = self.base_dispatch_s * self.target \
                / max(pressure, 1e-12)
        else:
            return None              # inside the band: hold
        plan = self.replan(eff)
        if plan is None:
            return None
        before = set(srv.fleet.buckets)
        if set(b.key for b in plan.buckets) == before:
            return None              # fixed point: converged
        kind = "split" if len(plan.buckets) > len(before) \
            else "merge"
        report = self.apply(plan)
        self._last_replan = now
        self.replans.append(dict(
            t=now, pressure=pressure, dispatch_s=eff, kind=kind,
            moved=len(report["moved"]), opened=report["opened"],
            closed=report["closed"], rebuilt=report["rebuilt"]))
        return report

    def stats(self) -> dict:
        def label(key):              # JSON-safe bucket-key spelling
            return f"{key[0]}/{key[1].name}"
        return dict(replans=len(self.replans),
                    utilization={label(k): round(u, 4) for k, u
                                 in self.utilization().items()},
                    offered_ewma={label(k): v for k, v
                                  in self.offered_ewma.items()},
                    last_replan=self._last_replan)
