"""The paper's alpha-beta-gamma cost model (Secs. II, III, IV, VII).

All closed forms from the paper are implemented here, leading-order
constants included where the paper gives them.  ``Cost`` carries the
three critical-path counts:

    s : latency  — number of messages (collectives) on the critical path
    w : bandwidth — words sent/received on the critical path
    f : flops

``Machine`` instantiates the model with hardware constants; the TPU v5e
preset is used for all a-priori tuning decisions (Sec. VIII: "the exact
choice is machine dependent") and for the roofline collective term.
"""

from __future__ import annotations

import dataclasses
import functools
import math


def lg(x: float) -> float:
    return math.log2(max(x, 1.0))


def ind(p: float) -> float:
    """The paper's unit step 1_p  (1 if p > 1 else 0)."""
    return 1.0 if p > 1 else 0.0


@dataclasses.dataclass(frozen=True)
class Cost:
    s: float = 0.0   # messages
    w: float = 0.0   # words
    f: float = 0.0   # flops

    def __add__(self, o: "Cost") -> "Cost":
        if not isinstance(o, Cost):      # PipelinedCost handles Cost +
            return NotImplemented        # PipelinedCost via __radd__
        return Cost(self.s + o.s, self.w + o.w, self.f + o.f)

    def __mul__(self, c: float) -> "Cost":
        return Cost(self.s * c, self.w * c, self.f * c)

    __rmul__ = __mul__

    def time(self, m: "Machine") -> float:
        return m.alpha * self.s + m.beta * self.w + m.gamma * self.f


@dataclasses.dataclass(frozen=True)
class PipelinedCost:
    """A sequence of pipelined stages, each a (comm, comp) pair of
    :class:`Cost` terms that execute CONCURRENTLY (DESIGN.md Sec. 16).

    The S/W/F *counts* are unchanged by overlap — the same messages,
    words and flops happen — so ``s``/``w``/``f`` sum both sides; only
    ``time`` changes: each stage prices ``max(comm.time, comp.time)``
    instead of their sum, which is the overlapped sweep's steady-state
    critical path (the panel collective of step i+1 rides under step
    i's GEMMs).  Stages are sequential with respect to each other, so
    ``__add__`` concatenates stage lists; adding a plain :class:`Cost`
    appends it as a serial stage (``max(0, c) == c``).
    """
    stages: tuple = ()        # tuple of (comm: Cost, comp: Cost) pairs

    @property
    def s(self) -> float:
        return sum(c.s + g.s for c, g in self.stages)

    @property
    def w(self) -> float:
        return sum(c.w + g.w for c, g in self.stages)

    @property
    def f(self) -> float:
        return sum(c.f + g.f for c, g in self.stages)

    def time(self, m: "Machine") -> float:
        return sum(max(c.time(m), g.time(m)) for c, g in self.stages)

    def serial(self) -> Cost:
        """Collapse to a plain (non-overlapped) :class:`Cost`."""
        return Cost(self.s, self.w, self.f)

    @staticmethod
    def _lift(o) -> tuple:
        if isinstance(o, PipelinedCost):
            return o.stages
        if isinstance(o, Cost):
            return ((Cost(), o),)
        return NotImplemented

    def __add__(self, o):
        stages = self._lift(o)
        if stages is NotImplemented:
            return NotImplemented
        return PipelinedCost(self.stages + stages)

    def __radd__(self, o):
        stages = self._lift(o)
        if stages is NotImplemented:
            return NotImplemented
        return PipelinedCost(stages + self.stages)

    def __mul__(self, c: float):
        return PipelinedCost(tuple((cm * c, cp * c)
                                   for cm, cp in self.stages))

    __rmul__ = __mul__


def pipelined(comm: Cost, comp: Cost) -> PipelinedCost:
    """One pipelined stage: ``comm`` and ``comp`` overlap, so the
    stage's machine time is ``max`` of the two instead of their sum
    (the counts still sum — overlap hides time, not traffic)."""
    return PipelinedCost(((comm, comp),))


@dataclasses.dataclass(frozen=True)
class Machine:
    """alpha [s/message], beta [s/word], gamma [s/flop]."""
    name: str
    alpha: float
    beta: float
    gamma: float


def tpu_v5e(dtype_bytes: int = 2) -> Machine:
    """TPU v5e: 197 TFLOP/s bf16, ~50 GB/s/link ICI, ~1us collective hop."""
    return Machine(
        name="tpu_v5e",
        alpha=1e-6,
        beta=dtype_bytes / 50e9,
        gamma=1.0 / 197e12,
    )


def tpu_v5e_dcn(dtype_bytes: int = 2) -> Machine:
    """Cross-pod (DCN) network: ~50us collective setup, ~25 GB/s/host.
    The high-alpha regime where the paper's latency-avoiding trade pays
    off even for square (n = k) solves."""
    return Machine(
        name="tpu_v5e_dcn",
        alpha=5e-5,
        beta=dtype_bytes / 25e9,
        gamma=1.0 / 197e12,
    )


# --------------------- measured-cost calibration ---------------------

@dataclasses.dataclass(frozen=True)
class Calibration:
    """A fitted per-:class:`Machine` correction (DESIGN.md Sec. 16).

    The closed forms above predict in MODEL units (messages, words,
    flops x nominal hardware constants); measured wall times on a real
    host differ by per-term constant factors (dispatch overhead per
    collective, achieved vs peak bandwidth, achieved vs peak flops).
    ``(a, b, g)`` are multiplicative rescales of (alpha, beta, gamma)
    fitted by least squares from ``bench_paper_table`` measurements
    (committed in ``benchmarks/BENCH_overlap.json``); ``dispatch_s`` is
    the measured per-program host dispatch overhead, which keeps
    absolute-seconds comparisons (``plan_fleet`` merges, queue-wait
    admission) in the SAME units as the calibrated steady costs.

    Argmin plan choices are invariant under a UNIFORM rescale; a
    non-uniform fit deliberately shifts the latency/bandwidth/compute
    balance toward what the host actually delivers — that is the
    point.  Any plan change this induces is asserted by test, not just
    logged (tests/test_overlap.py)."""
    a: float = 1.0
    b: float = 1.0
    g: float = 1.0
    dispatch_s: float | None = None

    def apply(self, m: Machine) -> Machine:
        return Machine(name=m.name + "+cal", alpha=m.alpha * self.a,
                       beta=m.beta * self.b, gamma=m.gamma * self.g)


def fit_calibration(rows, machine: Machine,
                    dispatch_s: float | None = None) -> Calibration:
    """Least-squares fit of the (a, b, g) rescale from measured rows.

    Each row needs model counts ``s``/``w``/``f`` and a wall-clock
    ``measured_s``; the fit solves ``min || A x - t ||`` with
    ``A[i] = [alpha*s_i, beta*w_i, gamma*f_i]`` (plain
    ``numpy.linalg.lstsq`` — no scipy dependency) and clips the scales
    positive: a negative term rate is never physical, it only means
    the regime set did not separate that term."""
    import numpy as np
    A = np.array([[machine.alpha * r["s"], machine.beta * r["w"],
                   machine.gamma * r["f"]] for r in rows], dtype=float)
    t = np.array([r["measured_s"] for r in rows], dtype=float)
    x, *_ = np.linalg.lstsq(A, t, rcond=None)
    x = np.clip(x, 1e-9, None)
    return Calibration(a=float(x[0]), b=float(x[1]), g=float(x[2]),
                       dispatch_s=dispatch_s)


def _default_calibration_path():
    import pathlib
    return pathlib.Path(__file__).resolve().parents[3] \
        / "benchmarks" / "BENCH_overlap.json"


def load_calibration(path=None) -> Calibration | None:
    """Load the committed calibration (``benchmarks/BENCH_overlap.json``,
    written by ``benchmarks/bench_paper_table.py``).  Returns None when
    the file is missing or has no calibration block — planners then
    fall back to the nominal machine constants.  Cached per path."""
    import pathlib
    p = pathlib.Path(path) if path is not None \
        else _default_calibration_path()
    return _load_calibration_cached(str(p))


@functools.lru_cache(maxsize=8)
def _load_calibration_cached(path: str) -> Calibration | None:
    import json
    import pathlib
    p = pathlib.Path(path)
    if not p.is_file():
        return None
    try:
        payload = json.loads(p.read_text())
        cal = payload.get("calibration")
        if not cal:
            return None
        ds = cal.get("dispatch_s")
        return Calibration(a=float(cal["a"]), b=float(cal["b"]),
                           g=float(cal["g"]),
                           dispatch_s=None if ds is None else float(ds))
    except (ValueError, KeyError, TypeError, OSError):
        return None


# --------------------- collectives (Sec. II-C1) ---------------------

def allgather(n: float, p: float) -> Cost:
    return Cost(s=lg(p), w=n * ind(p))


def scatter(n: float, p: float) -> Cost:
    return Cost(s=lg(p), w=n * ind(p))


def gather(n: float, p: float) -> Cost:
    return Cost(s=lg(p), w=n * ind(p))


def reduce_scatter(n: float, p: float) -> Cost:
    return Cost(s=lg(p), w=n * ind(p), f=n * ind(p))


def alltoall(n: float, p: float) -> Cost:
    return Cost(s=lg(p), w=n * lg(p) / 2.0)


def reduction(n: float, p: float) -> Cost:
    return Cost(s=2 * lg(p), w=2 * n * ind(p), f=n * ind(p))


def allreduction(n: float, p: float) -> Cost:
    return Cost(s=2 * lg(p), w=2 * n * ind(p), f=n * ind(p))


def bcast(n: float, p: float) -> Cost:
    return Cost(s=2 * lg(p), w=2 * n * ind(p))


# --------------------- MM (Sec. III) ---------------------

def mm_cost_paper(n: float, k: float, p: float, p1: float,
                  p2: float) -> Cost:
    """3D matmul from a 2D cyclic start, line-by-line per the paper
    (Sec. III cost table), INCLUDING the two rectangular-grid transposes
    (lines 3 and 8, O(nk log(p)/p) each) required by the paper's 4D-grid
    construction.
    """
    c = Cost()
    c = c + Cost(s=lg(p2), w=(n * n / (p1 * p1)) * ind(p2))       # line 2
    c = c + Cost(s=lg(p), w=n * k * lg(p) / p)                    # line 3
    c = c + Cost(s=1, w=n * k / p)                                # line 4
    c = c + Cost(s=lg(p1), w=n * k / (p1 * p2) * ind(p1))         # line 5
    c = c + Cost(f=n * n * k / p)                                 # line 6
    c = c + Cost(s=lg(p1), w=n * k / (p1 * p2) * ind(p1),
                 f=n * k / (p1 * p2) * ind(p1))                   # line 7
    c = c + Cost(s=lg(p), w=n * k * lg(p) / p)                    # line 8
    return c


def mm_cost(n: float, k: float, p: float, p1: float, p2: float,
            m: float | None = None) -> Cost:
    """Cost of OUR MM schedule (repro.core.mm3d): the mesh-native cyclic
    layout removes the paper's lines 3/8 transposes; the x<->y exchange
    is a single permute (line 4).  Leading order matches the paper:
    W = m*n/p1^2 * 1_{p2} + 2nk/(p1 p2),  F = m*n*k/p,  S = O(log p).
    ``m`` is the row count of the left operand (defaults to n: square).
    """
    m = n if m is None else m
    c = Cost()
    c = c + Cost(s=lg(p2), w=(m * n / (p1 * p1)) * ind(p2))       # gather L
    c = c + Cost(s=ind(p1), w=n * k / p * ind(p1))                # permute
    c = c + Cost(s=lg(p1), w=n * k / (p1 * p2) * ind(p1))        # gather X
    c = c + Cost(f=m * n * k / p)                                 # GEMM
    c = c + Cost(s=lg(p1), w=m * k / (p1 * p2) * ind(p1),
                 f=m * k / (p1 * p2) * ind(p1))                   # red-scat
    return c


def w_mm_optimal(n: float, k: float, p: float) -> float:
    """Asymptotically optimal MM bandwidth (Demmel et al.), Sec. II-C2."""
    if n > k * math.sqrt(p):
        return n * k / math.sqrt(p)
    if n >= k / p:
        return (n * n * k / p) ** (2.0 / 3.0)
    return n * n


# --------------------- Recursive TRSM (Sec. IV) ---------------------

def rec_trsm_cost(n: float, k: float, p: float,
                  model: str = "paper", structure=None) -> Cost:
    """Closed-form leading-order cost of Rec-TRSM with the paper's
    parameter choices, by regime.

    ``model="tang2024"`` applies the bandwidth-cost correction of
    Tang, "A Reexamination of the Communication Bandwidth Cost
    Analysis of A Parallel Recursive Algorithm for Solving Triangular
    Systems of Linear Equations" (arXiv:2407.00871): in the recursive
    regimes the triangular operand is re-communicated across the
    lg(n/k)-deep recursion over n, so the paper's W under-counts by an
    n^2-order term — Θ(n^2/sqrt(p)) in the two-large-dimensions regime
    and the matching (n^2 k / p)^{2/3}-per-level term in the
    three-large-dimensions regime.  The 1D regime (no recursion over
    n) is unchanged.  Planner comparisons use the corrected figure so
    recursion is not over-credited against It-Inv serving
    (DESIGN.md Sec. 12).

    ``structure`` (a non-dense ``FactorStructure``) prices the
    STRUCTURED recursion from the :class:`StructureInfo` nnz counts:
    admission masks the factor to its block structure, so the
    L-proportional terms — the n^2-order words that move the factor
    and the trailing-MM flops — scale with the factor's block fill
    (diagonal blocks included, they are always present).  The
    RHS-proportional nk words stay dense (B/X are dense regardless of
    L's structure), and the message count S is NOT scaled: the
    recursion depth and its base-case collectives are structure-blind
    (Rec-TRSM has no level schedule to skip them).
    Before this, the rec side was priced dense, which over-priced rec
    on banded/block-sparse specs and biased
    ``tuning.choose_serving_method`` toward It-Inv (DESIGN.md
    Sec. 14/16)."""
    if model not in ("paper", "tang2024"):
        raise ValueError(f"unknown rec cost model {model!r}")
    fill = 1.0
    if structure is not None and not structure.is_dense:
        fill = _structure_fill_total(structure, n)
    corrected = model == "tang2024"
    if n < 4 * k / p:      # one large dimension
        return Cost(s=lg(p), w=n * n * fill, f=n * n * k / p * fill)
    if n > 4 * k * math.sqrt(p):   # two large dimensions
        w = n * k * lg(p) / math.sqrt(p)
        if corrected:
            w += n * n / math.sqrt(p) * fill
        return Cost(s=math.sqrt(p), w=w, f=n * n * k / p * fill)
    # three large dimensions
    w = (n * n * k / p) ** (2.0 / 3.0)
    if corrected:
        w *= max(lg(n / k), 1.0)   # one optimal-size term per level
    return Cost(s=(n * p / k) ** (2.0 / 3.0) * lg(p), w=w,
                f=n * n * k / p * fill)


def _structure_fill_total(structure, n: float) -> float:
    """Whole-factor (diagonal included) block fill of a structure at
    its natural granularity, from the admission analysis's nnz counts
    (``StructureInfo``, DESIGN.md Sec. 14).  Falls back to dense (1.0)
    when n cannot host the structure's granularity."""
    from repro.core.structure import analyze
    n = int(n)
    if n < 2:
        return 1.0
    if structure.kind == "block_sparse":
        g = len(structure.mask)
        n0 = n // g if g and n % g == 0 else 0
    else:
        g = 64
        while g > 1 and n % g:
            g //= 2
        n0 = n // g
    if n0 < 1 or n % n0:
        return 1.0
    info = analyze(structure, n, n0)
    m = info.m
    total = m * (m + 1) / 2.0
    return (info.nnz_offdiag + m) / total if total else 1.0


# --------------------- Triangular inversion (Sec. V) ---------------------

NU = 2.0 ** (1.0 / 3.0) / (2.0 ** (1.0 / 3.0) - 1.0)   # 2^{1/3}/(2^{1/3}-1)


def tri_inv_cost(n: float, p1: float, p2: float) -> Cost:
    """RecTriInv total cost (Sec. V-B)."""
    p = p1 * p1 * p2
    return Cost(
        s=lg(p) ** 2,
        w=NU * (n * n / (8 * p1 * p1) + n * n / (2 * p1 * p2)),
        f=NU * n ** 3 / (8 * p),
    )


# --------------------- It-Inv-TRSM (Secs. VI-VII) ---------------------

def inv_phase_cost(n: float, n0: float, r1: float, r2: float,
                   p: float) -> Cost:
    """Diagonal-Inverter: n/n0 blocks inverted on r1 x r1 x r2 subgrids,
    plus the redistribution lines 6/9/16/17 (never leading order)."""
    per_block = tri_inv_cost(n0, r1, r2)
    # All n/n0 inversions run concurrently on disjoint subgrids: the
    # critical path is ONE block inversion; W/F below are per-processor.
    redist = Cost(s=4 * lg(p), w=2 * n * n0 / p * lg(p) + n * n0 / p)
    return Cost(s=per_block.s, w=per_block.w, f=per_block.f) + redist


def solve_phase_cost(n: float, k: float, n0: float,
                     p1: float, p2: float, overlap: bool = False):
    """n/n0 block solves:  X_i = L~_ii B_i  + allreduce over x (Sec. VII-B).

    ``overlap`` returns the PIPELINED form (DESIGN.md Sec. 16): the
    per-step collective words/messages and the per-step GEMM flops
    price ``max(comm, comp)`` instead of their sum.  The counts are
    identical either way — overlap hides time, not traffic."""
    m = n / n0
    p = p1 * p1 * p2
    w = m * ((n0 * n0 / (p1 * p1)) * ind(p2)
             + 4 * (n0 * k / (p1 * p2)) * ind(p1))
    comm = Cost(s=m * lg(p), w=w)
    comp = Cost(f=m * n0 * n0 * k / (p1 * p1 * p2))
    if overlap:
        return pipelined(comm, comp)
    return comm + comp


def update_phase_cost(n: float, k: float, n0: float,
                      p1: float, p2: float,
                      structure=None, overlap: bool = False):
    """Trailing updates: bcast of the L~ panel + GEMM + allreduce (VII-C).

    With a non-dense ``structure`` (a ``FactorStructure``), the sweep
    skips zero blocks: bandwidth and flops scale by the off-diagonal
    block fill (nnz_offdiag / (m(m-1)/2), the dense count), and the
    latency term counts only the columns that have at least one
    dependent block row — a column with no off-diagonal nonzero skips
    the update AND both collectives (DESIGN.md Sec. 14).

    ``overlap`` returns the pipelined ``max(comm, comp)`` form — the
    double-buffered sweep starts panel i+1's allgather before panel
    i's update GEMM executes (Sec. 16); skipped spans skip the
    prefetch too, so the structured scaling applies to both sides."""
    m = n / n0
    p = p1 * p1 * p2
    w = (m - 1) * (4 * (n * n0 - n) / (p1 * p1) * ind(p2)
                   + 4 * n0 * k / (p1 * p2) * ind(p1))
    s = (m - 1) * lg(p)
    f = (m - 1) * k * n * n0 / (p1 * p1 * p2)
    if structure is not None and not structure.is_dense:
        from repro.core.structure import analyze
        info = analyze(structure, int(n), int(n0))
        mi = info.m
        dense_off = mi * (mi - 1) / 2.0
        fill = info.nnz_offdiag / dense_off if dense_off else 0.0
        cols = info.update_cols / (mi - 1.0) if mi > 1 else 0.0
        w, f, s = w * fill, f * fill, s * cols
    if overlap:
        return pipelined(Cost(s=s, w=w), Cost(f=f))
    return Cost(s=s, w=w, f=f)


def it_inv_trsm_cost(n: float, k: float, n0: float, p1: float, p2: float,
                     r1: float, r2: float, overlap: bool = False):
    p = p1 * p1 * p2
    return (inv_phase_cost(n, n0, r1, r2, p)
            + solve_phase_cost(n, k, n0, p1, p2, overlap=overlap)
            + update_phase_cost(n, k, n0, p1, p2, overlap=overlap))


def it_inv_trsm_steady_cost(n: float, k: float, n0: float,
                            p1: float, p2: float,
                            structure=None, overlap: bool = False):
    """Per-solve It-Inv cost in the HOISTED steady state (DESIGN.md
    Secs. 9-10): the Diagonal-Inverter ran once at factor admission, so
    a resident-factor solve pays only the sweep (solve + update
    phases).  ``structure`` prices the level-scheduled sweep: the solve
    phase is unchanged (every diagonal block is on its own block row's
    critical path), the update phase pays only for nonzero blocks.
    ``overlap`` prices the double-buffered sweep's ``max(comm, comp)``
    per phase (a :class:`PipelinedCost` — same counts, smaller
    ``time``)."""
    return (solve_phase_cost(n, k, n0, p1, p2, overlap=overlap)
            + update_phase_cost(n, k, n0, p1, p2, structure=structure,
                                overlap=overlap))


# ------------------- control-plane wait pricing -------------------

def queue_wait_estimate(queued_cols: float, width: float,
                        inflight_waves: float, k: float,
                        steady_s: float,
                        dispatch_s: float = 0.0) -> float:
    """A priori queue-wait bound for one arriving request (DESIGN.md
    Sec. 15): seconds until a request of ``width`` columns joining a
    backlog of ``queued_cols`` columns completes, when each wave
    carries up to ``k`` columns and costs ``steady_s`` (the modeled —
    or measured-EWMA — per-wave service time) plus ``dispatch_s`` of
    launch overhead, with ``inflight_waves`` already dispatched ahead.

    This is the same a-priori-pricing discipline as :func:`plan_fleet`
    — the request is admitted or shed on ARITHMETIC, before any queue
    time is spent — just applied to the time axis instead of the
    bucket layout.  The estimate is deliberately a CEILING on wave
    count (a request never splits across waves), so admission errs
    toward shedding work it could not serve in time rather than
    admitting work it cannot."""
    waves = math.ceil((queued_cols + width) / max(k, 1.0)) \
        + inflight_waves
    return waves * (steady_s + dispatch_s)


# --------------------- Sec. IX comparison table ---------------------

def paper_table_row(n: float, k: float, p: float) -> dict:
    """The conclusion table: S/W/F for 'standard' (Rec-TRSM) vs
    'new method' (It-Inv-TRSM) in the applicable regime."""
    if n < 4 * k / p:
        regime = "1D"
        std = dict(S=lg(p), W=n * n, F=n * n * k / p)
        new = dict(S=lg(p) ** 2, W=n * n, F=n * n * k / p)
    elif n > 4 * k * math.sqrt(p):
        regime = "2D"
        std = dict(S=math.sqrt(p), W=lg(p) * n * k / math.sqrt(p),
                   F=n * n * k / p)
        new = dict(S=lg(p) ** 2 + (n / k) ** 0.75 * p ** (-1 / 8) * lg(p),
                   W=n * k / math.sqrt(p), F=n * n * k / p)
    else:
        regime = "3D"
        std = dict(S=(n * p / k) ** (2 / 3) * lg(p),
                   W=(n * n * k / p) ** (2 / 3), F=n * n * k / p)
        new = dict(S=lg(p) ** 2 + max(math.sqrt(n / k), 1.0) * lg(p),
                   W=(n * n * k / p) ** (2 / 3), F=2 * n * n * k / p)
    return dict(regime=regime, standard=std, new=new)
