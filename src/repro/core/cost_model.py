"""The paper's alpha-beta-gamma cost model (Secs. II, III, IV, VII).

All closed forms from the paper are implemented here, leading-order
constants included where the paper gives them.  ``Cost`` carries the
three critical-path counts:

    s : latency  — number of messages (collectives) on the critical path
    w : bandwidth — words sent/received on the critical path
    f : flops

``Machine`` instantiates the model with hardware constants; the TPU v5e
preset is used for all a-priori tuning decisions (Sec. VIII: "the exact
choice is machine dependent") and for the roofline collective term.
"""

from __future__ import annotations

import dataclasses
import math


def lg(x: float) -> float:
    return math.log2(max(x, 1.0))


def ind(p: float) -> float:
    """The paper's unit step 1_p  (1 if p > 1 else 0)."""
    return 1.0 if p > 1 else 0.0


@dataclasses.dataclass(frozen=True)
class Cost:
    s: float = 0.0   # messages
    w: float = 0.0   # words
    f: float = 0.0   # flops

    def __add__(self, o: "Cost") -> "Cost":
        return Cost(self.s + o.s, self.w + o.w, self.f + o.f)

    def __mul__(self, c: float) -> "Cost":
        return Cost(self.s * c, self.w * c, self.f * c)

    __rmul__ = __mul__

    def time(self, m: "Machine") -> float:
        return m.alpha * self.s + m.beta * self.w + m.gamma * self.f


@dataclasses.dataclass(frozen=True)
class Machine:
    """alpha [s/message], beta [s/word], gamma [s/flop]."""
    name: str
    alpha: float
    beta: float
    gamma: float


def tpu_v5e(dtype_bytes: int = 2) -> Machine:
    """TPU v5e: 197 TFLOP/s bf16, ~50 GB/s/link ICI, ~1us collective hop."""
    return Machine(
        name="tpu_v5e",
        alpha=1e-6,
        beta=dtype_bytes / 50e9,
        gamma=1.0 / 197e12,
    )


def tpu_v5e_dcn(dtype_bytes: int = 2) -> Machine:
    """Cross-pod (DCN) network: ~50us collective setup, ~25 GB/s/host.
    The high-alpha regime where the paper's latency-avoiding trade pays
    off even for square (n = k) solves."""
    return Machine(
        name="tpu_v5e_dcn",
        alpha=5e-5,
        beta=dtype_bytes / 25e9,
        gamma=1.0 / 197e12,
    )


# --------------------- collectives (Sec. II-C1) ---------------------

def allgather(n: float, p: float) -> Cost:
    return Cost(s=lg(p), w=n * ind(p))


def scatter(n: float, p: float) -> Cost:
    return Cost(s=lg(p), w=n * ind(p))


def gather(n: float, p: float) -> Cost:
    return Cost(s=lg(p), w=n * ind(p))


def reduce_scatter(n: float, p: float) -> Cost:
    return Cost(s=lg(p), w=n * ind(p), f=n * ind(p))


def alltoall(n: float, p: float) -> Cost:
    return Cost(s=lg(p), w=n * lg(p) / 2.0)


def reduction(n: float, p: float) -> Cost:
    return Cost(s=2 * lg(p), w=2 * n * ind(p), f=n * ind(p))


def allreduction(n: float, p: float) -> Cost:
    return Cost(s=2 * lg(p), w=2 * n * ind(p), f=n * ind(p))


def bcast(n: float, p: float) -> Cost:
    return Cost(s=2 * lg(p), w=2 * n * ind(p))


# --------------------- MM (Sec. III) ---------------------

def mm_cost_paper(n: float, k: float, p: float, p1: float,
                  p2: float) -> Cost:
    """3D matmul from a 2D cyclic start, line-by-line per the paper
    (Sec. III cost table), INCLUDING the two rectangular-grid transposes
    (lines 3 and 8, O(nk log(p)/p) each) required by the paper's 4D-grid
    construction.
    """
    c = Cost()
    c = c + Cost(s=lg(p2), w=(n * n / (p1 * p1)) * ind(p2))       # line 2
    c = c + Cost(s=lg(p), w=n * k * lg(p) / p)                    # line 3
    c = c + Cost(s=1, w=n * k / p)                                # line 4
    c = c + Cost(s=lg(p1), w=n * k / (p1 * p2) * ind(p1))         # line 5
    c = c + Cost(f=n * n * k / p)                                 # line 6
    c = c + Cost(s=lg(p1), w=n * k / (p1 * p2) * ind(p1),
                 f=n * k / (p1 * p2) * ind(p1))                   # line 7
    c = c + Cost(s=lg(p), w=n * k * lg(p) / p)                    # line 8
    return c


def mm_cost(n: float, k: float, p: float, p1: float, p2: float,
            m: float | None = None) -> Cost:
    """Cost of OUR MM schedule (repro.core.mm3d): the mesh-native cyclic
    layout removes the paper's lines 3/8 transposes; the x<->y exchange
    is a single permute (line 4).  Leading order matches the paper:
    W = m*n/p1^2 * 1_{p2} + 2nk/(p1 p2),  F = m*n*k/p,  S = O(log p).
    ``m`` is the row count of the left operand (defaults to n: square).
    """
    m = n if m is None else m
    c = Cost()
    c = c + Cost(s=lg(p2), w=(m * n / (p1 * p1)) * ind(p2))       # gather L
    c = c + Cost(s=ind(p1), w=n * k / p * ind(p1))                # permute
    c = c + Cost(s=lg(p1), w=n * k / (p1 * p2) * ind(p1))        # gather X
    c = c + Cost(f=m * n * k / p)                                 # GEMM
    c = c + Cost(s=lg(p1), w=m * k / (p1 * p2) * ind(p1),
                 f=m * k / (p1 * p2) * ind(p1))                   # red-scat
    return c


def w_mm_optimal(n: float, k: float, p: float) -> float:
    """Asymptotically optimal MM bandwidth (Demmel et al.), Sec. II-C2."""
    if n > k * math.sqrt(p):
        return n * k / math.sqrt(p)
    if n >= k / p:
        return (n * n * k / p) ** (2.0 / 3.0)
    return n * n


# --------------------- Recursive TRSM (Sec. IV) ---------------------

def rec_trsm_cost(n: float, k: float, p: float,
                  model: str = "paper", structure=None) -> Cost:
    """Closed-form leading-order cost of Rec-TRSM with the paper's
    parameter choices, by regime.

    ``model="tang2024"`` applies the bandwidth-cost correction of
    Tang, "A Reexamination of the Communication Bandwidth Cost
    Analysis of A Parallel Recursive Algorithm for Solving Triangular
    Systems of Linear Equations" (arXiv:2407.00871): in the recursive
    regimes the triangular operand is re-communicated across the
    lg(n/k)-deep recursion over n, so the paper's W under-counts by an
    n^2-order term — Θ(n^2/sqrt(p)) in the two-large-dimensions regime
    and the matching (n^2 k / p)^{2/3}-per-level term in the
    three-large-dimensions regime.  The 1D regime (no recursion over
    n) is unchanged.  Planner comparisons use the corrected figure so
    recursion is not over-credited against It-Inv serving
    (DESIGN.md Sec. 12).

    ``structure`` is accepted for signature parity with the It-Inv
    side but priced DENSE: Rec-TRSM has no structure-aware schedule,
    so crediting it with skipped blocks it cannot skip would bias the
    planner's dispatch (DESIGN.md Sec. 14)."""
    del structure  # priced dense — see docstring
    if model not in ("paper", "tang2024"):
        raise ValueError(f"unknown rec cost model {model!r}")
    corrected = model == "tang2024"
    if n < 4 * k / p:      # one large dimension
        return Cost(s=lg(p), w=n * n, f=n * n * k / p)
    if n > 4 * k * math.sqrt(p):   # two large dimensions
        w = n * k * lg(p) / math.sqrt(p)
        if corrected:
            w += n * n / math.sqrt(p)
        return Cost(s=math.sqrt(p), w=w, f=n * n * k / p)
    # three large dimensions
    w = (n * n * k / p) ** (2.0 / 3.0)
    if corrected:
        w *= max(lg(n / k), 1.0)   # one optimal-size term per level
    return Cost(s=(n * p / k) ** (2.0 / 3.0) * lg(p), w=w,
                f=n * n * k / p)


# --------------------- Triangular inversion (Sec. V) ---------------------

NU = 2.0 ** (1.0 / 3.0) / (2.0 ** (1.0 / 3.0) - 1.0)   # 2^{1/3}/(2^{1/3}-1)


def tri_inv_cost(n: float, p1: float, p2: float) -> Cost:
    """RecTriInv total cost (Sec. V-B)."""
    p = p1 * p1 * p2
    return Cost(
        s=lg(p) ** 2,
        w=NU * (n * n / (8 * p1 * p1) + n * n / (2 * p1 * p2)),
        f=NU * n ** 3 / (8 * p),
    )


# --------------------- It-Inv-TRSM (Secs. VI-VII) ---------------------

def inv_phase_cost(n: float, n0: float, r1: float, r2: float,
                   p: float) -> Cost:
    """Diagonal-Inverter: n/n0 blocks inverted on r1 x r1 x r2 subgrids,
    plus the redistribution lines 6/9/16/17 (never leading order)."""
    per_block = tri_inv_cost(n0, r1, r2)
    # All n/n0 inversions run concurrently on disjoint subgrids: the
    # critical path is ONE block inversion; W/F below are per-processor.
    redist = Cost(s=4 * lg(p), w=2 * n * n0 / p * lg(p) + n * n0 / p)
    return Cost(s=per_block.s, w=per_block.w, f=per_block.f) + redist


def solve_phase_cost(n: float, k: float, n0: float,
                     p1: float, p2: float) -> Cost:
    """n/n0 block solves:  X_i = L~_ii B_i  + allreduce over x (Sec. VII-B)."""
    m = n / n0
    p = p1 * p1 * p2
    w = m * ((n0 * n0 / (p1 * p1)) * ind(p2)
             + 4 * (n0 * k / (p1 * p2)) * ind(p1))
    return Cost(s=m * lg(p), w=w, f=m * n0 * n0 * k / (p1 * p1 * p2))


def update_phase_cost(n: float, k: float, n0: float,
                      p1: float, p2: float,
                      structure=None) -> Cost:
    """Trailing updates: bcast of the L~ panel + GEMM + allreduce (VII-C).

    With a non-dense ``structure`` (a ``FactorStructure``), the sweep
    skips zero blocks: bandwidth and flops scale by the off-diagonal
    block fill (nnz_offdiag / (m(m-1)/2), the dense count), and the
    latency term counts only the columns that have at least one
    dependent block row — a column with no off-diagonal nonzero skips
    the update AND both collectives (DESIGN.md Sec. 14)."""
    m = n / n0
    p = p1 * p1 * p2
    w = (m - 1) * (4 * (n * n0 - n) / (p1 * p1) * ind(p2)
                   + 4 * n0 * k / (p1 * p2) * ind(p1))
    s = (m - 1) * lg(p)
    f = (m - 1) * k * n * n0 / (p1 * p1 * p2)
    if structure is not None and not structure.is_dense:
        from repro.core.structure import analyze
        info = analyze(structure, int(n), int(n0))
        mi = info.m
        dense_off = mi * (mi - 1) / 2.0
        fill = info.nnz_offdiag / dense_off if dense_off else 0.0
        cols = info.update_cols / (mi - 1.0) if mi > 1 else 0.0
        w, f, s = w * fill, f * fill, s * cols
    return Cost(s=s, w=w, f=f)


def it_inv_trsm_cost(n: float, k: float, n0: float, p1: float, p2: float,
                     r1: float, r2: float) -> Cost:
    p = p1 * p1 * p2
    return (inv_phase_cost(n, n0, r1, r2, p)
            + solve_phase_cost(n, k, n0, p1, p2)
            + update_phase_cost(n, k, n0, p1, p2))


def it_inv_trsm_steady_cost(n: float, k: float, n0: float,
                            p1: float, p2: float,
                            structure=None) -> Cost:
    """Per-solve It-Inv cost in the HOISTED steady state (DESIGN.md
    Secs. 9-10): the Diagonal-Inverter ran once at factor admission, so
    a resident-factor solve pays only the sweep (solve + update
    phases).  ``structure`` prices the level-scheduled sweep: the solve
    phase is unchanged (every diagonal block is on its own block row's
    critical path), the update phase pays only for nonzero blocks."""
    return (solve_phase_cost(n, k, n0, p1, p2)
            + update_phase_cost(n, k, n0, p1, p2, structure=structure))


# ------------------- control-plane wait pricing -------------------

def queue_wait_estimate(queued_cols: float, width: float,
                        inflight_waves: float, k: float,
                        steady_s: float,
                        dispatch_s: float = 0.0) -> float:
    """A priori queue-wait bound for one arriving request (DESIGN.md
    Sec. 15): seconds until a request of ``width`` columns joining a
    backlog of ``queued_cols`` columns completes, when each wave
    carries up to ``k`` columns and costs ``steady_s`` (the modeled —
    or measured-EWMA — per-wave service time) plus ``dispatch_s`` of
    launch overhead, with ``inflight_waves`` already dispatched ahead.

    This is the same a-priori-pricing discipline as :func:`plan_fleet`
    — the request is admitted or shed on ARITHMETIC, before any queue
    time is spent — just applied to the time axis instead of the
    bucket layout.  The estimate is deliberately a CEILING on wave
    count (a request never splits across waves), so admission errs
    toward shedding work it could not serve in time rather than
    admitting work it cannot."""
    waves = math.ceil((queued_cols + width) / max(k, 1.0)) \
        + inflight_waves
    return waves * (steady_s + dispatch_s)


# --------------------- Sec. IX comparison table ---------------------

def paper_table_row(n: float, k: float, p: float) -> dict:
    """The conclusion table: S/W/F for 'standard' (Rec-TRSM) vs
    'new method' (It-Inv-TRSM) in the applicable regime."""
    if n < 4 * k / p:
        regime = "1D"
        std = dict(S=lg(p), W=n * n, F=n * n * k / p)
        new = dict(S=lg(p) ** 2, W=n * n, F=n * n * k / p)
    elif n > 4 * k * math.sqrt(p):
        regime = "2D"
        std = dict(S=math.sqrt(p), W=lg(p) * n * k / math.sqrt(p),
                   F=n * n * k / p)
        new = dict(S=lg(p) ** 2 + (n / k) ** 0.75 * p ** (-1 / 8) * lg(p),
                   W=n * k / math.sqrt(p), F=n * n * k / p)
    else:
        regime = "3D"
        std = dict(S=(n * p / k) ** (2 / 3) * lg(p),
                   W=(n * n * k / p) ** (2 / 3), F=n * n * k / p)
        new = dict(S=lg(p) ** 2 + max(math.sqrt(n / k), 1.0) * lg(p),
                   W=(n * n * k / p) ** (2 / 3), F=2 * n * n * k / p)
    return dict(regime=regime, standard=std, new=new)
