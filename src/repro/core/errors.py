"""The unified serving-error hierarchy (DESIGN.md Sec. 15).

Every typed failure the serving tier can hand a client — at submit
time or through a :class:`~repro.core.serving.SolveFuture` — derives
from one base, :class:`ServingError`, so a client that wants "anything
the serving tier sheds or strands" catches ONE type instead of
tracking the per-mechanism spellings:

* :class:`Overloaded` — depth-based admission control: the target
  slot's bounded queue is full, the request was shed at submit.
* :class:`DeadlineUnmeetable` — SLO-aware admission control (the
  control plane, :class:`~repro.core.control.AdmissionController`):
  the queue-wait estimate says the request cannot finish inside its
  ``slo_ms`` even if admitted, so it is shed up front.  A subclass of
  :class:`Overloaded` (both are load shedding; a depth-only client's
  ``except Overloaded`` keeps working) but surfaced ONLY through the
  request's :class:`~repro.core.serving.SolveFuture` — ``submit``
  still returns a handle, so open-loop producers need no extra
  try/except on the hot submit path.
* :class:`StrandedRequestError` — evict-under-flight: the request's
  slot was turned over between submit and pack, so serving it would
  hit the slot's NEW occupant; the future fails instead.

Compatibility is part of the contract: :class:`Overloaded` remains a
``RuntimeError`` and :class:`StrandedRequestError` remains a
``ValueError`` (their pre-hierarchy bases), so existing handlers that
caught those stdlib types are bit-identical.  The pre-hierarchy access
paths — ``repro.core.serving.Overloaded`` and
``repro.core.solver.StrandedRequestError`` — keep working as warn-once
aliases of THESE SAME class objects (see the README migration table);
``repro.api`` re-exports the canonical spellings.
"""

from __future__ import annotations


class ServingError(Exception):
    """Base of every typed serving-tier failure (shed / strand).  The
    concrete subclasses keep their historical stdlib bases
    (``RuntimeError`` / ``ValueError``) so pre-hierarchy handlers keep
    catching them."""


class Overloaded(ServingError, RuntimeError):
    """Typed admission-control rejection: the target slot's bounded
    queue is full, so the request was SHED at submit time — never
    enqueued, never served.  Open-loop producers treat this as
    backpressure (back off, retry, or drop); the server counts sheds
    in :meth:`~repro.core.serving.AsyncSolveServer.stats`."""


class DeadlineUnmeetable(Overloaded):
    """SLO-aware admission rejection (DESIGN.md Sec. 15): the
    cost-model-seeded queue-wait estimate says ``arrival +
    wait_estimate`` cannot meet ``slo_ms``, so serving the request
    would only burn capacity on a guaranteed SLO violation.  Unlike a
    depth shed this is NOT raised from ``submit`` — the request's
    :class:`~repro.core.serving.SolveFuture` is returned already
    failed with this error, so the producer's submit path stays
    exception-free and the shed is observable exactly where every
    other request outcome is: on the future."""


class StrandedRequestError(ServingError, ValueError):
    """A queued request's factor slot was evicted (or turned over to a
    new occupant) after the request was accepted: serving it would
    silently solve against the WRONG factor, so it fails instead.
    Raised by the synchronous :class:`~repro.core.solver.SolveServer`
    at pack time and surfaced through
    :meth:`~repro.core.serving.SolveFuture.result` on the async tier.
    ``replace`` preserves the slot generation and strands nothing;
    only evict / re-admit turnover does."""
