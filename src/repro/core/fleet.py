"""SolverFleet: the mixed-order, multi-tenant serving tier
(DESIGN.md Sec. 12).

A single :class:`~repro.core.bank.FactorBank` holds factors of ONE
order, but the paper's consumer pattern (Sec. I; the per-layer KFAC
producer of `optim.kfac_ca`) emits a whole SPECTRUM of orders per
model, and a fleet of tenant models multiplies that further.  This
module adds the tier above the banks:

* **Capacity planner** (:func:`plan_fleet`) — decides a priori, by
  pricing configurations with the alpha-beta-gamma cost model (no
  compilation, no devices), which factor orders SHARE a bucket via
  zero-padding to the bucket order versus get their own bank.  Padding
  an order-d factor into an order-n bucket trades extra per-solve
  sweep work (the modeled steady-state delta) for one fewer dispatch
  per mixed-order wave; the planner merges exactly when the modeled
  padding overhead is bought back by the saved dispatch.  The
  recursive alternative is priced with the Tang 2024 bandwidth
  correction (arXiv:2407.00871, ``rec_model="tang2024"``) so planner
  choices stay honest where the original analysis over-credits
  recursion.

* **SolverFleet** — a router over live-mutable capacity banks keyed by
  ``(n_bucket, PrecisionPolicy)``.  ``admit`` routes a factor to its
  planned bucket (zero-padded inside the compiled updater:
  ``FactorBank.admit(L, pad_to=n_bucket)``), hands back a
  :class:`FleetHandle`, and — when the bucket is full — reclaims the
  least-recently-used live slot ACROSS TENANTS (one fleet-wide LRU
  clock; the coldest slot in the target bucket is evicted and
  immediately re-used).  Reclamation rides the PR-5 ``UpdateSpec``
  churn path, so it never recompiles and never touches the host; the
  evicted slot's generation counter bumps, so a stale handle (or a
  request submitted before the reclaim) can never be served against
  the new occupant.

* **Fleet-wide stats** (:meth:`SolverFleet.stats`) — per-bucket
  occupancy plus admit / reclaim / lookup-hit-rate counters, surfaced
  by ``launch.serve --workload trsm-fleet --fleet-stats``.
"""

from __future__ import annotations

import dataclasses

from repro.core import cost_model as cm
from repro.core import precision as preclib
from repro.core import tuning
from repro.core.bank import FactorBank
from repro.core.grid import TrsmGrid
from repro.core.precision import PrecisionPolicy
from repro.core.solver import Solver


# ------------------------------ planning ------------------------------

# modeled host overhead of one extra program dispatch per wave (launch
# + panel bookkeeping) — the budget a merge's padding overhead must
# undercut.  Deliberately conservative: measured per-dispatch overhead
# on CPU/TPU hosts is 20-100us.
DEFAULT_DISPATCH_S = 5e-5


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """One planned bucket: the bank order every member order is
    zero-padded to, its precision policy, capacity, and the modeled
    per-wave costs that justified the membership."""
    n: int                       # bucket order (pad target)
    policy: PrecisionPolicy
    capacity: int
    orders: tuple[int, ...]      # member orders, descending
    counts: tuple[int, ...]      # factors per member order
    method: str                  # "inv" | "rec" (Tang-corrected pick)
    n0: int | None
    merged_s: float              # modeled s/wave serving members here
    split_s: float               # modeled s/wave with per-order banks
    structure: object | None = None   # FactorStructure (None = dense)
    overlap: str | None = "on"   # normalized SolveSpec.overlap value

    @property
    def key(self) -> tuple:
        return (self.n, self.policy)


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """The planner's output: every bucket, plus the routing map from
    member order to bucket."""
    buckets: tuple[BucketPlan, ...]
    k: int
    dispatch_s: float

    def bucket_for(self, order: int) -> BucketPlan:
        for b in self.buckets:
            if order in b.orders:
                return b
        # an unplanned order still routes: smallest bucket that fits
        fits = [b for b in self.buckets if b.n >= order]
        if not fits:
            raise ValueError(
                f"order {order} exceeds every bucket (max "
                f"{max(b.n for b in self.buckets)}); re-plan the fleet "
                f"with this order in the manifest")
        return min(fits, key=lambda b: b.n)

    def table(self) -> str:
        """The planner's bucket table, one row per bucket."""
        rows = [f"{'bucket n':>9} {'policy':>12} {'cap':>4} {'method':>6} "
                f"{'n0':>5}  {'orders (count)':<24} "
                f"{'merged s/wave':>13} {'split s/wave':>13}"]
        for b in self.buckets:
            members = ", ".join(f"{d}({c})"
                                for d, c in zip(b.orders, b.counts))
            rows.append(
                f"{b.n:>9} {b.policy.name:>12} {b.capacity:>4} "
                f"{b.method:>6} {str(b.n0):>5}  {members:<24} "
                f"{b.merged_s:>13.3e} {b.split_s:>13.3e}")
        return "\n".join(rows)


def _steady_s(n: int, k: int, grid: TrsmGrid, machine,
              n0: int | None = None, structure=None,
              overlap: bool = True) -> float:
    """Modeled steady-state seconds for one order-n, width-k solve on
    the grid — delegates to :func:`repro.core.tuning.serving_steady_s`
    so the planner and the admission controller's wait estimates price
    the SAME model (DESIGN.md Sec. 15)."""
    return tuning.serving_steady_s(n, k, grid, machine=machine, n0=n0,
                                   structure=structure, overlap=overlap)


def plan_fleet(orders, grid: TrsmGrid, *, k: int = 16, precision=None,
               dtype=None, machine: cm.Machine | None = None,
               dispatch_s: float | None = None,
               headroom: int = 0, structure=None,
               overlap="auto") -> FleetPlan:
    """Decide the fleet's buckets a priori — pure cost-model
    arithmetic, no compilation, no devices (a mesh-less
    ``plan_grid(p1, p2)`` works).

    ``orders`` is the mixed-order manifest: a ``{order: count}``
    mapping, or an iterable of orders (counted).  Greedy descending
    merge: each order joins the already-open bucket that minimizes the
    modeled padding overhead

        count * (steady_s(n_bucket) - steady_s(order))

    iff that overhead is bought back by the dispatch it saves per
    mixed-order wave (``dispatch_s``); otherwise it opens its own
    bucket.  Every bucket's method is the Tang-2024-corrected
    rec-vs-inv steady comparison at the bucket order.  ``headroom``
    adds spare capacity slots per bucket (reclaim-free churn room).
    ``structure`` (a :class:`~repro.core.structure.FactorStructure`)
    declares the block structure every member factor honors; it prices
    BOTH sides of each bucket's method choice (the It-Inv side from
    the skipped blocks, the recursive side from the mask's nnz — the
    admission mask zeroes the factor either way), picks each bucket's
    n0 from the structured argmin, and is stamped on the plan so
    :class:`SolverFleet` builds structured banks.  Padding into a
    bucket preserves the promise: the pad is a blockdiag(L, I) whose
    identity tail lives on diagonal blocks, which every mask keeps.

    ``machine`` defaults to the CALIBRATED machine when a committed
    calibration exists (``tuning.default_machine``, DESIGN.md
    Sec. 16), and an unset ``dispatch_s`` to the calibration's
    MEASURED per-dispatch overhead (falling back to
    :data:`DEFAULT_DISPATCH_S`) — the merge comparison is an absolute
    seconds-vs-seconds tradeoff, so both sides must be in the same
    measured units.  ``overlap`` prices buckets with the pipelined
    sweep (the serving default) and is stamped on each bucket so the
    fleet's banks compile the matching program.
    """
    if hasattr(orders, "items"):
        manifest = {int(d): int(c) for d, c in orders.items()}
    else:
        manifest = {}
        for d in orders:
            manifest[int(d)] = manifest.get(int(d), 0) + 1
    if not manifest:
        raise ValueError("empty order manifest")
    if any(d < 1 or c < 1 for d, c in manifest.items()):
        raise ValueError(f"orders and counts must be >= 1: {manifest}")
    policy = preclib.resolve(precision, dtype) if (
        precision is not None or dtype is not None) \
        else preclib.PRESETS["fp32"]
    machine = machine or tuning.default_machine()
    if dispatch_s is None:
        dispatch_s = tuning.default_dispatch_s(DEFAULT_DISPATCH_S)
    from repro.core import solver as solverlib
    overlap = solverlib._normalize_overlap(overlap)
    ov = overlap == "on"
    if structure is not None and structure.is_dense:
        structure = None

    # open buckets: [n_bucket, {order: count}]
    open_buckets: list[list] = []
    for d in sorted(manifest, reverse=True):
        count = manifest[d]
        own = _steady_s(d, k, grid, machine, structure=structure,
                        overlap=ov)
        best, best_extra = None, None
        for b in open_buckets:
            extra = count * (_steady_s(b[0], k, grid, machine,
                                       structure=structure,
                                       overlap=ov) - own)
            if best_extra is None or extra < best_extra:
                best, best_extra = b, extra
        if best is not None and best_extra <= dispatch_s:
            best[1][d] = count
        else:
            open_buckets.append([d, {d: count}])

    buckets = []
    for n_b, members in open_buckets:
        orders_desc = tuple(sorted(members, reverse=True))
        counts = tuple(members[d] for d in orders_desc)
        method, n0, _ = tuning.choose_serving_method(
            n_b, k, grid, machine, rec_model="tang2024",
            structure=structure, overlap=ov)
        merged_s = _steady_s(n_b, k, grid, machine, n0=n0,
                             structure=structure, overlap=ov) + dispatch_s
        split_s = sum(_steady_s(d, k, grid, machine,
                                structure=structure, overlap=ov)
                      + dispatch_s
                      for d in orders_desc)
        buckets.append(BucketPlan(
            n=n_b, policy=policy, capacity=sum(counts) + headroom,
            orders=orders_desc, counts=counts, method=method,
            n0=n0 if method == "inv" else None,
            merged_s=merged_s, split_s=split_s,
            structure=structure if method == "inv" else None,
            overlap=overlap))
    return FleetPlan(buckets=tuple(buckets), k=k, dispatch_s=dispatch_s)


# ------------------------------ the fleet ------------------------------

@dataclasses.dataclass(frozen=True)
class FleetHandle:
    """A tenant's claim on one bucket slot.  ``generation`` is the
    slot's turnover counter at admission: a cross-tenant reclaim bumps
    it, so a stale handle (its slot reclaimed for someone else) is
    detected on every fleet operation instead of silently serving the
    new occupant's factor."""
    bucket: tuple                # (n_bucket, PrecisionPolicy)
    slot: int
    generation: int
    tenant: str
    tag: object
    order: int                   # the factor's TRUE order d (<= n_bucket)


class _Bucket:
    def __init__(self, plan: BucketPlan, bank: FactorBank,
                 solver: Solver):
        self.plan = plan
        self.bank = bank
        self.solver = solver
        self.handles: dict[int, FleetHandle] = {}   # slot -> handle
        self.last_used: dict[int, int] = {}         # slot -> LRU clock
        # slot -> the natural (d, d) factor as admitted (a reference,
        # not a copy — typically the caller's pinned device array from
        # place_factor): live migration re-admits it into a replanned
        # bucket without an unscatter from cyclic storage
        self.factors: dict[int, object] = {}
        self.admits = 0
        self.reclaims = 0


class SolverFleet:
    """A router over live-mutable capacity banks keyed by
    ``(n_bucket, PrecisionPolicy)``, following a :class:`FleetPlan`
    (DESIGN.md Sec. 12).

        plan = api.plan_fleet({64: 2, 32: 3}, grid, k=8)
        fleet = api.SolverFleet(grid, plan)
        h = fleet.admit(L, tenant="modelA", tag="layer0")
        server = api.SolveServer(fleet, panel_k=8)
        server.submit(b, tenant="modelA", tag="layer0")
        outs = server.drain()        # {(tenant, tag): [X (d, j), ...]}

    Admission pads the factor to its planned bucket order inside the
    compiled updater; a full bucket reclaims its coldest slot (one
    fleet-wide LRU clock, cross-tenant) through evict + admit on the
    same churn path — zero retraces, zero host transfers, generation
    counters catching every stale claim.
    """

    def __init__(self, grid: TrsmGrid, plan: FleetPlan, *, cache=None,
                 lower: bool = True, transpose: bool = False,
                 map_mode: str = "vmap", warm: bool = False):
        from repro.core import session as sessionlib
        self.grid = grid
        self.plan = plan
        self.cache = cache if cache is not None \
            else sessionlib.default_cache()
        self._buckets: dict[tuple, _Bucket] = {}
        for bp in plan.buckets:
            bank = FactorBank(
                grid, bp.n, method=bp.method, n0=bp.n0,
                lower=lower, transpose=transpose, precision=bp.policy,
                map_mode=map_mode, capacity=bp.capacity,
                structure=bp.structure, overlap=bp.overlap,
                cache=self.cache)
            self._buckets[bp.key] = _Bucket(bp, bank,
                                            Solver.from_bank(bank))
        self._dir: dict[tuple, list[FleetHandle]] = {}  # (tenant,) index
        self._clock = 0
        self.admits = 0
        self.reclaims = 0
        self.lookup_hits = 0
        self.lookup_misses = 0
        if warm:
            self.warmup(plan.k)

    # ------------------------------ routing ------------------------------

    @property
    def buckets(self) -> tuple:
        """The bucket keys, ``(n_bucket, policy)`` each."""
        return tuple(self._buckets)

    def bucket(self, key) -> _Bucket:
        return self._buckets[key]

    def solver(self, key) -> Solver:
        """The width-C :class:`Solver` over one bucket's bank."""
        return self._buckets[key].solver

    def warmup(self, k: int | None = None) -> "SolverFleet":
        for b in self._buckets.values():
            b.solver.warmup(self.plan.k if k is None else k)
        return self

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _touch(self, handle: FleetHandle) -> None:
        self._buckets[handle.bucket].last_used[handle.slot] = self._tick()

    def _check_current(self, handle: FleetHandle) -> _Bucket:
        b = self._buckets.get(handle.bucket)
        if b is None:
            raise KeyError(f"unknown bucket {handle.bucket}")
        cur = b.handles.get(handle.slot)
        if cur is not handle or \
                b.bank.slot_generation(handle.slot) != handle.generation:
            raise KeyError(
                f"stale handle: bucket {handle.bucket[0]} slot "
                f"{handle.slot} was reclaimed (generation "
                f"{b.bank.slot_generation(handle.slot)} != "
                f"{handle.generation}) — re-admit the factor")
        return b

    def _reclaim(self, b: _Bucket) -> int:
        """Evict the least-recently-used live slot in the bucket —
        regardless of which tenant holds it (the cross-tenant LRU
        contract).  Host-side bookkeeping only; the freed slot's next
        admit overwrites the lane through the compiled updater."""
        slot = min(b.bank.live_slots(),
                   key=lambda s: b.last_used.get(s, 0))
        victim = b.handles.pop(slot)
        self._dir[victim.tenant].remove(victim)
        b.last_used.pop(slot, None)
        b.factors.pop(slot, None)
        b.bank.evict(slot)           # bumps the slot generation
        b.reclaims += 1
        self.reclaims += 1
        return slot

    def _admit_into(self, b: _Bucket, L, *, tenant: str,
                    tag: object, order: int) -> FleetHandle:
        """The admit core, targeted at one (possibly not-yet-routed)
        bucket: reclaim-if-full, padded bank admit, handle + directory
        bookkeeping.  :meth:`admit` routes through the plan;
        :meth:`apply_plan` targets migration destinations directly."""
        if b.bank.size == b.bank.capacity:
            self._reclaim(b)
        slot = b.bank.admit(L, pad_to=b.plan.n if order < b.plan.n
                            else None)
        handle = FleetHandle(bucket=b.plan.key, slot=slot,
                             generation=b.bank.slot_generation(slot),
                             tenant=tenant, tag=tag, order=order)
        b.handles[slot] = handle
        b.factors[slot] = L
        b.admits += 1
        self.admits += 1
        self._dir.setdefault(tenant, []).append(handle)
        # touch the TARGET bucket directly: during apply_plan it may
        # not be routed in self._buckets yet
        b.last_used[slot] = self._tick()
        return handle

    def admit(self, L, *, tenant: str = "default",
              tag: object = None) -> FleetHandle:
        """Route one natural-layout (d, d) factor to its planned
        bucket, zero-padding to the bucket order inside the compiled
        updater.  A full bucket first reclaims its coldest slot
        (cross-tenant LRU).  Returns the tenant's :class:`FleetHandle`."""
        order = int(L.shape[-1])
        bp = self.plan.bucket_for(order)
        return self._admit_into(self._buckets[bp.key], L,
                                tenant=tenant, tag=tag, order=order)

    def replace(self, handle: FleetHandle, L) -> FleetHandle:
        """Refresh the handle's slot in place (same order, same
        bucket) through the bank's compiled donated updater.  Raises
        ``KeyError`` on a stale handle (slot reclaimed since)."""
        b = self._check_current(handle)
        d = int(L.shape[-1])
        if d != handle.order:
            raise ValueError(f"replacement order {d} != admitted order "
                             f"{handle.order}; evict and re-admit to "
                             f"change order")
        b.bank.replace(handle.slot, L,
                       pad_to=b.plan.n if d < b.plan.n else None)
        b.factors[handle.slot] = L
        self._touch(handle)
        return handle

    def evict(self, handle: FleetHandle) -> None:
        """Explicitly release the handle's slot back to its bucket."""
        b = self._check_current(handle)
        b.handles.pop(handle.slot)
        b.last_used.pop(handle.slot, None)
        b.factors.pop(handle.slot, None)
        self._dir[handle.tenant].remove(handle)
        b.bank.evict(handle.slot)

    def lookup(self, tenant: str, *, order: int | None = None,
               tag: object = None) -> FleetHandle:
        """Find a tenant's handle by ``(tenant, order)`` and/or tag.
        Ambiguous lookups (several live handles match) raise with the
        candidate tags; misses raise ``KeyError`` and count toward the
        fleet hit rate."""
        matches = [h for h in self._dir.get(tenant, ())
                   if (order is None or h.order == order)
                   and (tag is None or h.tag == tag)]
        if not matches:
            self.lookup_misses += 1
            raise KeyError(
                f"no live factor for tenant {tenant!r}"
                + (f" at order {order}" if order is not None else "")
                + (f" tag {tag!r}" if tag is not None else "")
                + " (evicted by a cross-tenant reclaim? re-admit)")
        if len(matches) > 1:
            raise ValueError(
                f"ambiguous lookup for tenant {tenant!r}: "
                f"{len(matches)} live factors match; disambiguate with "
                f"tag= (candidates: {[h.tag for h in matches]})")
        self.lookup_hits += 1
        self._touch(matches[0])
        return matches[0]

    def handles(self, tenant: str | None = None) -> tuple:
        """All live handles (optionally one tenant's), admission order."""
        if tenant is not None:
            return tuple(self._dir.get(tenant, ()))
        return tuple(h for hs in self._dir.values() for h in hs)

    def manifest(self) -> dict[int, int]:
        """The LIVE mixed-order manifest, ``{order: count}`` over every
        resident handle — exactly the input :func:`plan_fleet` takes,
        so an autoscale replan prices the population actually being
        served, not the admission-time forecast."""
        man: dict[int, int] = {}
        for h in self.handles():
            man[h.order] = man.get(h.order, 0) + 1
        return man

    def apply_plan(self, new_plan: FleetPlan, *,
                   on_move=None) -> dict:
        """Live-migrate the fleet onto ``new_plan`` (the Autoscaler's
        apply path, DESIGN.md Sec. 15).

        Buckets are REBUILT only where the plan demands it: a bucket
        key that survives with sufficient capacity keeps its bank —
        same compiled programs, zero retraces for its residents —
        while new keys (a split) and under-capacity keys (a merge
        growing a bucket's population; capacity is the bank's cache
        key, so it cannot grow in place) get fresh banks.  Every
        handle whose order now routes elsewhere is re-admitted from
        its retained natural factor through the standard admit path
        (hoisted phase 1 runs once per moved factor, exactly like any
        admission) and its old slot is evicted — generation counters
        bump, so any stale claim on the old slot stays detectable.
        ``on_move(old_handle, new_handle)`` fires per migrated handle
        (the async tier re-keys queued requests there, stranding
        nothing); LRU clocks carry over so migration does not reset
        reclaim order.  Returns ``dict(moved=[(old, new), ...],
        opened=[...], closed=[...], rebuilt=[...])``."""
        for d in self.manifest():
            new_plan.bucket_for(d)       # raises if any order unroutable
        targets: dict[tuple, _Bucket] = {}
        opened, rebuilt = [], []
        for bp in new_plan.buckets:
            old = self._buckets.get(bp.key)
            if old is not None and old.bank.capacity >= bp.capacity:
                old.plan = bp            # keep the bank (and its key)
                targets[bp.key] = old
            else:
                bank = FactorBank(
                    self.grid, bp.n, method=bp.method, n0=bp.n0,
                    lower=old.bank.lower if old is not None else True,
                    transpose=old.bank.transpose if old is not None
                    else False,
                    precision=bp.policy,
                    map_mode=old.bank.map_mode if old is not None
                    else "vmap",
                    capacity=bp.capacity, structure=bp.structure,
                    overlap=bp.overlap, cache=self.cache)
                targets[bp.key] = _Bucket(bp, bank,
                                          Solver.from_bank(bank))
                (rebuilt if old is not None else opened).append(bp.key)
        moved = []
        for h in list(self.handles()):
            src = self._buckets[h.bucket]
            dest = targets.get(new_plan.bucket_for(h.order).key)
            if dest is src:
                continue                 # bucket survives: no move
            L = src.factors[h.slot]
            clock = src.last_used.get(h.slot, 0)
            new_h = self._admit_into(dest, L, tenant=h.tenant,
                                     tag=h.tag, order=h.order)
            dest.last_used[new_h.slot] = clock   # LRU order carries
            src.handles.pop(h.slot)
            src.last_used.pop(h.slot, None)
            src.factors.pop(h.slot, None)
            self._dir[h.tenant].remove(h)
            src.bank.evict(h.slot)       # bumps the old generation
            moved.append((h, new_h))
            if on_move is not None:
                on_move(h, new_h)
        closed = [key for key in self._buckets if key not in targets]
        self._buckets = targets
        self.plan = new_plan
        return dict(moved=moved, opened=opened, closed=closed,
                    rebuilt=rebuilt)

    def place_factor(self, L, order: int | None = None):
        """Pin a factor on device in its ROUTED bucket's bank (the
        ingestion upload, paid up front) so the admit/replace itself
        moves no host data — :meth:`FactorBank.place_factor` routed by
        order."""
        d = int(L.shape[-1]) if order is None else order
        return self._buckets[self.plan.bucket_for(d).key] \
            .bank.place_factor(L)

    # ------------------------------ stats ------------------------------

    def stats(self) -> dict:
        """Fleet-wide serving stats: per-bucket occupancy and reclaim
        counts plus the global admit/reclaim/lookup counters."""
        lookups = self.lookup_hits + self.lookup_misses
        per_bucket = {}
        for key, b in self._buckets.items():
            per_bucket[key] = dict(
                n=b.plan.n, capacity=b.bank.capacity,
                occupancy=b.bank.size, orders=b.plan.orders,
                admits=b.admits, reclaims=b.reclaims)
        return dict(
            buckets=per_bucket, admits=self.admits,
            reclaims=self.reclaims, lookup_hits=self.lookup_hits,
            lookup_misses=self.lookup_misses,
            hit_rate=(self.lookup_hits / lookups) if lookups else 1.0)

    def format_stats(self) -> str:
        st = self.stats()
        rows = [f"{'bucket n':>9} {'cap':>4} {'occ':>4} {'admits':>7} "
                f"{'reclaims':>9}  orders"]
        for (n, pol), b in st["buckets"].items():
            rows.append(f"{n:>9} {b['capacity']:>4} {b['occupancy']:>4} "
                        f"{b['admits']:>7} {b['reclaims']:>9}  "
                        f"{list(b['orders'])}")
        rows.append(f"fleet: admits={st['admits']} "
                    f"reclaims={st['reclaims']} "
                    f"hit_rate={st['hit_rate']:.3f} "
                    f"(hits={st['lookup_hits']} "
                    f"misses={st['lookup_misses']})")
        return "\n".join(rows)
