"""Processor grids and cyclic layouts for the TRSM algorithms.

The paper runs on a p1 x p1 x p2 grid with *cyclic* data layouts (the
triangular structure makes blocked layouts load-imbalanced and, more
importantly, the iterative sweep requires every rank to own a piece of
every diagonal block).  XLA shards arrays in contiguous blocks, so the
cyclic layout is realized as *permuted storage* (exactly ScaLAPACK-style
block-cyclic storage): the global array is stored row/column-permuted so
that a contiguous block shard corresponds to a stride-p cyclic index set.

Conventions used by all distributed algorithms in repro.core:

* mesh axes ("x", "y", "z") with sizes (p1, p1, p2)
* L: rows cyclic over x (global row g = l*p1 + x), columns cyclic over
  the pair rank t = z*p1 + y with stride p1*p2 (global col c_g =
  c*p1*p2 + z*p1 + y)  ->  storage sharded P("x", ("z", "y"))
* B: rows cyclic over x, columns blocked over z -> P("x", "z"), and
  replicated over y
* X (output): rows cyclic over *y* (a property of the paper's solve
  step: the allreduce over x leaves X on the transposed face),
  columns blocked over z -> P("y", "z")
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class TrsmGrid:
    mesh: Mesh
    p1: int
    p2: int

    @property
    def p(self) -> int:
        return self.p1 * self.p1 * self.p2

    def spec_L(self):
        return P("x", ("z", "y"))

    def spec_B(self):
        return P("x", "z")

    def spec_X(self):
        return P("y", "z")


def make_trsm_mesh(p1: int, p2: int, devices=None) -> TrsmGrid:
    devices = np.asarray(devices if devices is not None else jax.devices())
    p = p1 * p1 * p2
    assert devices.size >= p, (devices.size, p)
    mesh = Mesh(devices.reshape(-1)[:p].reshape(p1, p1, p2),
                axis_names=("x", "y", "z"))
    return TrsmGrid(mesh, p1, p2)


# ------------------------- cyclic storage helpers -------------------------

def cyclic_perm(n: int, p: int) -> np.ndarray:
    """Permutation mapping storage order -> global index for a stride-p
    cyclic layout: storage position (chunk r, slot l) holds global r + l*p."""
    return np.concatenate([np.arange(r, n, p) for r in range(p)])


def inv_perm(perm: np.ndarray) -> np.ndarray:
    out = np.empty_like(perm)
    out[perm] = np.arange(perm.size)
    return out


def to_cyclic_rows(a, p: int):
    """Natural -> cyclic storage along axis 0."""
    return a[cyclic_perm(a.shape[0], p)]


def from_cyclic_rows(a, p: int):
    return a[inv_perm(cyclic_perm(a.shape[0], p))]


def to_cyclic_matrix(L, p_row: int, p_col: int):
    """Natural -> cyclic storage for a matrix (rows stride p_row, cols
    stride p_col).  NOTE: this changes storage, not the operator: the
    algorithms index shards with the cyclic map, so correctness is
    preserved without the matrix being triangular in storage."""
    pr = cyclic_perm(L.shape[0], p_row)
    pc = cyclic_perm(L.shape[1], p_col)
    return L[pr][:, pc]


def from_cyclic_matrix(L, p_row: int, p_col: int):
    pr = inv_perm(cyclic_perm(L.shape[0], p_row))
    pc = inv_perm(cyclic_perm(L.shape[1], p_col))
    return L[pr][:, pc]


def cyclic_row_index(n: int, p: int, *, inverse: bool = False,
                     reverse: bool = False) -> np.ndarray:
    """Gather index realizing the cyclic-storage permutation along one
    axis, optionally composed with the reversal identity (the upper /
    transposed-solve reduction, DESIGN.md Sec. 3) into a SINGLE gather.

    forward (natural -> cyclic):  out[i] = a[idx[i]], idx = perm or
        (n-1-perm) when ``reverse`` (cyclic storage of the reversed
        array a[::-1]).
    inverse (cyclic -> natural):  idx = perm^-1, or perm^-1 reversed
        when ``reverse`` (natural layout of the reversed solution).
    The two compose to the identity for matching flags."""
    perm = cyclic_perm(n, p)
    if inverse:
        idx = inv_perm(perm)
        return np.ascontiguousarray(idx[::-1]) if reverse else idx
    return (n - 1 - perm) if reverse else perm


@functools.partial(jax.jit, static_argnames=("p", "inverse", "reverse"))
def cyclic_rows_device(a, p: int, *, inverse: bool = False,
                       reverse: bool = False):
    """On-device natural <-> cyclic storage permutation along the row
    axis (axis 0 for an (n, k) operand; axis -2 for a stacked
    (..., n, k) operand, so one gather permutes a whole factor bank's
    worth of right-hand sides).

    The jitted equivalent of :func:`to_cyclic_rows` /
    :func:`from_cyclic_rows`: one gather, computed where the operand
    lives (XLA turns the static index array into a data-movement-only
    program; under GSPMD the gather is partitioned over the mesh), so
    the solve pipeline never bounces rows through host NumPy."""
    if p == 1 and not reverse:
        return a                       # identity permutation: no gather
    axis = max(a.ndim - 2, 0)
    idx = cyclic_row_index(a.shape[axis], p, inverse=inverse,
                           reverse=reverse)
    return jnp.take(a, jnp.asarray(idx), axis=axis)


@functools.partial(jax.jit, static_argnames=(
    "p_row", "p_col", "inverse", "reverse_rows", "reverse_cols",
    "transpose"))
def cyclic_matrix_device(A, p_row: int, p_col: int, *,
                         inverse: bool = False, reverse_rows: bool = False,
                         reverse_cols: bool = False, transpose: bool = False):
    """On-device natural <-> cyclic storage permutation for a matrix,
    or for a STACK of matrices (leading batch axes: the permutations
    apply to the trailing two axes, so a factor bank's (M, n, n) stack
    is distributed by the same single fused gather program).

    Composes (optional) transposition and (optional) per-axis reversal
    with the two cyclic gathers, so an upper/transposed factor is
    distributed with the same single fused program as a lower one.
    ``transpose`` is applied before the row/col permutations (forward)
    — it is only meaningful for the forward direction, where the
    operator reductions L^T / JUJ are folded into distribution."""
    if transpose:
        A = jnp.swapaxes(A, -2, -1)
    if p_row > 1 or reverse_rows:      # p == 1 without reversal is the
        ri = cyclic_row_index(A.shape[-2], p_row, inverse=inverse,
                              reverse=reverse_rows)
        A = jnp.take(A, jnp.asarray(ri), axis=-2)
    if p_col > 1 or reverse_cols:      # identity: skip the gather
        ci = cyclic_row_index(A.shape[-1], p_col, inverse=inverse,
                              reverse=reverse_cols)
        A = jnp.take(A, jnp.asarray(ci), axis=-1)
    return A


def shard(grid: TrsmGrid, arr, spec):
    return jax.device_put(arr, NamedSharding(grid.mesh, spec))


def check_divisibility(n: int, k: int, n0: int, grid: TrsmGrid) -> None:
    p1, p2 = grid.p1, grid.p2
    assert n % n0 == 0, (n, n0)
    assert n0 % (p1 * p2) == 0, ("need p1*p2 | n0 for contiguous local "
                                 "diagonal blocks", n0, p1, p2)
    assert k % p2 == 0, (k, p2)
    # any block count m = n/n0 is supported: phase 1 picks alltoall
    # (p | m), cooperative doubling (m < p), or the allgather fallback.
