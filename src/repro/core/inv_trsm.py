"""Distributed It-Inv-TRSM (the paper's main contribution, Secs. VI-VII).

shard_map implementation on a p1 x p1 x p2 mesh ("x", "y", "z"), cyclic
storage per repro.core.grid.  Two phases:

1. *Diagonal-Inverter*: the n/n0 diagonal blocks of L are inverted in
   parallel.  Modes:
     - "alltoall"  (p | m): one all_to_all routes whole blocks to
       devices, local batched bottom-up doubling inversion, one
       all_to_all routes the transposed-face pieces back.  This is the
       TPU-native adaptation of the paper's subgrid scheme; it needs 2
       collectives instead of O(log^2 p) (a beyond-paper latency win,
       possible exactly when there are at least p diagonal blocks).
     - "doubling"  (m < p): the SPMD equivalent of the paper's
       r1 x r1 x r2 subgrid inversions — repro.core.tri_inv's batched
       bottom-up doubling restricted to the diagonal n0-blocks, with
       all p processors cooperating on all blocks (S = O(log^2 p), the
       paper's Sec. V cost).  Faces are then formed by one transpose +
       one allgather over z.
     - "allgather" (fallback, any m): every device gathers all diagonal
       blocks and inverts redundantly.  Correct but bandwidth-suboptimal
       (W = n*n0 instead of ~n0^2); used only for odd divisibility.
2. *Sweep* (solve + update, paper Alg. It-Inv-TRSM lines 3-10): for each
   block i:  X_i = psum_x(L~[y,x](S_i,S_i) @ B[x,z](S_i))  — a GEMM by
   the pre-inverted block replaces the latency/VPU-bound substitution —
   then the trailing update B -= psum_y(panel @ X_i) with the panel
   reconstructed by an allgather over z (the paper's bcast, line 6).

The collectives per iteration match the paper exactly: one allreduce
over x (solve), one bcast over z (panel), one allreduce over y (update).
All collectives go through repro.core.comm, so tracing the program
yields the critical-path S/W/F that Sec. VII derives (the fori_loop body
is recorded once and multiplied by the trip count via comm.scope).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import blocked, comm
from repro.core import tri_inv as ti
from repro.core.grid import TrsmGrid, check_divisibility

MESH_AXES = ("x", "y", "z")


def _assemble_blocks(Dg: jnp.ndarray, p1: int, p2: int) -> jnp.ndarray:
    """(p, m, a, b) gathered pieces (flattened (x,y,z)-major leading axis)
    -> (m, n0, n0) full blocks.  Rows interleave as g = l*p1 + x, columns
    as c*p1*p2 + z*p1 + y."""
    p, m, a, b = Dg.shape
    R = Dg.reshape(p1, p1, p2, m, a, b)            # (x, y, z, i, l, c)
    R = jnp.transpose(R, (3, 4, 0, 5, 2, 1))       # (i, l, x, c, z, y)
    return R.reshape(m, a * p1, b * p2 * p1)


def _piece_for(binv: jnp.ndarray, row_off, col_off, p1: int) -> jnp.ndarray:
    """Select the cyclic piece binv[:, row_off::p1, col_off::p1]
    with traced offsets."""
    m, n0, _ = binv.shape
    a = n0 // p1
    R = binv.reshape(m, a, p1, a, p1)
    R = jnp.moveaxis(R, (2, 4), (0, 1))            # (roff, coff, m, a, a)
    R = jax.lax.dynamic_index_in_dim(R, row_off, axis=0, keepdims=False)
    return jax.lax.dynamic_index_in_dim(R, col_off, axis=0, keepdims=False)


def _pieces_all_dests(binv: jnp.ndarray, p1: int, p2: int) -> jnp.ndarray:
    """For every destination (xd, yd, zd) build the transposed-face piece
    (rows ≡ yd, cols ≡ xd) of each local block: -> (p, mb, a, a)."""
    mb, n0, _ = binv.shape
    a = n0 // p1
    R = binv.reshape(mb, a, p1, a, p1)             # (i, l, roff, c, coff)
    R = jnp.transpose(R, (4, 2, 0, 1, 3))          # (coff=xd, roff=yd, i, l, c)
    R = jnp.broadcast_to(R[:, :, None], (p1, p1, p2, mb, a, a))
    return R.reshape(p1 * p1 * p2, mb, a, a)


def _swap_perm(p1: int):
    return [(x * p1 + y, y * p1 + x) for x in range(p1) for y in range(p1)]


def _invert_diag_blocks(Lloc, *, n, n0, p1, p2, block_inv, mode,
                        accum_dtype=None, overlap=False):
    """Phase 1: return Dt (m, n0/p1, n0/p1) — the transposed-face pieces
    (rows ≡ y, cols ≡ x) of the inverted diagonal blocks.

    When ``accum_dtype`` is wider than the operand dtype the block
    inversion itself runs at the accumulate precision (cast up, invert,
    cast back): the inverse re-enters the sweep as a GEMM operand at
    compute precision, but its entries are formed at full accuracy —
    the same contract as ``preferred_element_type`` on the MXU."""
    if accum_dtype is not None and jnp.dtype(accum_dtype) != Lloc.dtype:
        inner, ldt = block_inv, Lloc.dtype
        block_inv = lambda b: inner(b.astype(accum_dtype)).astype(ldt)
    m = n // n0
    p = p1 * p1 * p2
    a = n0 // p1
    b = n0 // (p1 * p2)
    xi = comm.axis_index("x")
    yi = comm.axis_index("y")

    V = Lloc.reshape(m, a, m, b)
    D = V[jnp.arange(m), :, jnp.arange(m), :]          # (m, a, b) local tiles

    if mode == "alltoall":
        assert m % p == 0, (m, p)
        mb = m // p
        # route: device f receives the pieces of blocks [f*mb, (f+1)*mb)
        Dr = comm.all_to_all(D, MESH_AXES, split_axis=0, concat_axis=0,
                             tiled=True)            # (p*mb, a, b)
        Dr = Dr.reshape(p, mb, a, b)
        blocks = _assemble_blocks(Dr, p1, p2)          # (mb, n0, n0)
        binv = block_inv(blocks)
        S = _pieces_all_dests(binv, p1, p2)            # (p, mb, a, a)
        Dt = comm.all_to_all(S.reshape(p * mb, a, a), MESH_AXES,
                             split_axis=0, concat_axis=0, tiled=True)
        return Dt                                      # (m, a, a), block order
    elif mode == "doubling":
        # cooperative inversion of the diagonal blocks (the SPMD
        # equivalent of the paper's subgrid RecTriInv), then form the
        # transposed faces: swap x<->y, gather cols over z, realign.
        Linv = ti.block_diag_inv_shard(Lloc, n=n, n0=n0, p1=p1, p2=p2,
                                       block_inv=block_inv)
        Vd = Linv.reshape(m, a, m, b)
        Dd = Vd[jnp.arange(m), :, jnp.arange(m), :]    # (m, a, b) cyclic
        if p1 > 1:
            if overlap:
                # start/finish split: the face exchange is in flight
                # while XLA schedules any independent work between the
                # two (the fused overlapped solve issues panel 0's
                # gather before phase 1, so on an async backend the
                # whole inversion — this ppermute included — hides
                # behind the first panel's collective)
                Dd = comm.ppermute_finish(
                    comm.ppermute_start(Dd, ("x", "y"), _swap_perm(p1)))
            else:
                Dd = comm.ppermute(Dd, ("x", "y"), _swap_perm(p1))
        if p2 > 1:
            Dg = comm.all_gather(Dd, "z", axis=2, tiled=True)  # (m,a,p2*b)
            Dg = Dg.reshape(m, a, p2, b).transpose(0, 1, 3, 2)
            Dd = Dg.reshape(m, a, b * p2)
        return Dd                                      # (m, a, a)
    elif mode == "allgather":
        Dg = comm.all_gather(D, MESH_AXES, axis=0, tiled=False)
        blocks = _assemble_blocks(Dg, p1, p2)          # (m, n0, n0)
        binv = block_inv(blocks)
        return _piece_for(binv, yi, xi, p1)            # (m, a, a)
    raise ValueError(mode)


def _sweep_shard(Lloc, Dt, Bloc, *, n, k, n0, p1, p2,
                 accum_dtype=None, unroll=False, spans=None,
                 overlap=False, prefetched0=None):
    """Phase 2 (sweep, paper Alg. It-Inv-TRSM lines 3-10) against
    ALREADY-INVERTED diagonal faces Dt (m, n0/p1, n0/p1).

    Split out of the fused solve so a factor bank can hoist phase 1 to
    admission time (the factor is immutable, so re-inverting its
    diagonal blocks every solve is pure steady-state waste) and serve
    with this sweep alone.  ``unroll`` unrolls the m-trip loop at trace
    time — the banked programs use it so XLA sees straight-line batched
    GEMMs instead of a loop of dynamic slices.

    ``spans`` turns the unrolled sweep LEVEL-SCHEDULED (DESIGN.md
    Sec. 14): one admission-time-computed ``(lo, hi)`` dependent-block
    range (or None) per source column, from
    ``repro.core.structure.analyze``.  The cyclic layout keeps every
    global block row on a CONTIGUOUS local row range (``n0 % p1 == 0``
    — global row ``g`` lives at local row ``g // p1``), so the
    trailing update of column i statically narrows to the local rows
    of blocks [lo, hi): the panel is row-sliced BEFORE the z-allgather
    (less W, not just fewer flops) and a column with no off-diagonal
    nonzero block skips its update — and its two collectives —
    entirely.  Admission masks the factor to the block structure, so
    any non-dependent block row inside a conservative span multiplies
    exact zeros.  Trace-time decisions only: requires ``unroll``.

    ``overlap`` SOFTWARE-PIPELINES the panel collective (DESIGN.md
    Sec. 16): column i's panel depends only on Lloc (never on the
    solve chain), so its z-allgather is STARTED one step early —
    before column i-1's update GEMM + y-allreduce execute — and
    FINISHED where the update consumes it.  The ops and operands are
    identical to the sequential sweep (same slices, gathers, dots,
    reductions), only the issue order changes, so the result is
    bit-identical; level-scheduled skipped spans also skip the
    prefetch (the prefetch chain walks the live columns only).  The
    ``fori_loop`` form carries the FINISHED panel instead (a loop
    iteration is a barrier, so an unfinished handle cannot cross it):
    a prologue gathers panel 0 and the body prefetches panel i+1 with
    a clamped slice — one extra (discarded) gather on the last trip,
    so traced cost records m+1 panel gathers instead of m.
    ``prefetched0`` lets the fused solve start panel 0's gather BEFORE
    phase 1, hiding the whole diagonal inversion (its ppermute
    included) behind the first panel collective."""
    m = n // n0
    nl = n // p1
    kl = k // p2
    a = n0 // p1
    b = n0 // (p1 * p2)
    xi = comm.axis_index("x")
    ct = Bloc.dtype
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else ct

    row_g = jnp.arange(nl) * p1 + xi                   # global row ids

    def _panel_start(i):
        """Issue column i's panel z-allgather (reads only Lloc; ``i``
        may be traced — dynamic_slice clamps an out-of-bounds start,
        which makes the fori path's last-trip prefetch harmless)."""
        if spans is not None:
            lo, hi = spans[i]
            rl, rows = lo * a, (hi - lo) * a
            panel = jax.lax.slice(Lloc, (rl, i * b),
                                  (rl + rows, (i + 1) * b))
        else:
            panel = jax.lax.dynamic_slice(Lloc, (0, i * b), (nl, b))
        return comm.all_gather_start(panel, "z", axis=0, tiled=False)

    def solve_step(i, Bcur, Xacc):
        Bi = jax.lax.dynamic_slice(Bcur, (i * a, 0), (a, kl))
        Dti = jax.lax.dynamic_index_in_dim(Dt, i, axis=0, keepdims=False)
        # solve via GEMM (l. 4-5); partials and the cross-x reduction
        # accumulate at acc (preferred_element_type on the MXU), the
        # carried values stay at compute precision.
        Xi = comm.psum(jax.lax.dot(Dti, Bi, preferred_element_type=acc),
                       "x").astype(ct)
        return Xi, jax.lax.dynamic_update_slice(Xacc, Xi, (i * a, 0))

    def apply_update(i, Bcur, Xi, pg):
        if spans is not None:
            # level-scheduled path: static row-span update.  lo >= i+1
            # always, so every span row is strictly below block i and
            # the row_g mask of the dense path is vacuous here.
            lo, hi = spans[i]
            rl, rows = lo * a, (hi - lo) * a
            pg = jnp.transpose(pg, (1, 2, 0)).reshape(rows, a)
            upd = comm.psum(
                jax.lax.dot(pg, Xi, preferred_element_type=acc),
                "y").astype(ct)
            Bspan = jax.lax.slice(Bcur, (rl, 0), (rl + rows, kl))
            return jax.lax.dynamic_update_slice(Bcur, Bspan - upd,
                                                (rl, 0))
        pg = jnp.transpose(pg, (1, 2, 0)).reshape(nl, a)  # cols t'=c*p2+z
        upd = comm.psum(jax.lax.dot(pg, Xi, preferred_element_type=acc),
                        "y").astype(ct)                # update (lines 7-8)
        mask = (row_g >= (i + 1) * n0).astype(ct)[:, None]
        return Bcur - mask * upd

    def body(i, carry, update=True):
        Bcur, Xacc = carry
        Xi, Xacc = solve_step(i, Bcur, Xacc)
        if not update:
            return Bcur, Xacc
        pg = comm.all_gather_finish(_panel_start(i))
        return apply_update(i, Bcur, Xi, pg), Xacc

    def live_update(i):
        # the final trailing update only touches the discarded
        # remainder of B; unrolling lets us drop it entirely —
        # and a level schedule drops every dependent-free column
        return i + 1 < m and (spans is None or spans[i] is not None)

    x0 = compat.pcast_varying(jnp.zeros((nl, kl), Bloc.dtype), ("y", "z"))
    if unroll:
        if overlap:
            # double-buffered: the prefetch chain walks the LIVE
            # columns (skipped spans skip the prefetch too); each live
            # column's gather is started exactly once — same collective
            # count and operands as the sequential unroll.
            live = [i for i in range(m) if live_update(i)]
            succ = {live[t]: live[t + 1] for t in range(len(live) - 1)}
            pending = None
            if live:
                pending = prefetched0 if prefetched0 is not None \
                    else _panel_start(live[0])
            carry = (Bloc, x0)
            for i in range(m):
                Bcur, Xacc = carry
                Xi, Xacc = solve_step(i, Bcur, Xacc)
                if live_update(i):
                    pg = comm.all_gather_finish(pending)
                    # issue the next live column's gather BEFORE this
                    # update's GEMM + y-allreduce consume this one
                    pending = _panel_start(succ[i]) if i in succ else None
                    Bcur = apply_update(i, Bcur, Xi, pg)
                carry = (Bcur, Xacc)
            return carry[1]
        carry = (Bloc, x0)
        for i in range(m):
            carry = body(i, carry, update=live_update(i))
        return carry[1]
    assert spans is None, "level-scheduled sweep requires unroll"
    if overlap:
        # fori form: a loop iteration is a barrier, so carry the
        # FINISHED gathered panel; the prologue gather runs outside
        # the x m cost scope (hence m+1 recorded panel gathers).
        pg0 = comm.all_gather_finish(
            prefetched0 if prefetched0 is not None else _panel_start(0))

        def body_ov(i, carry):
            Bcur, Xacc, pg = carry
            Xi, Xacc = solve_step(i, Bcur, Xacc)
            nxt = _panel_start(i + 1)      # clamped no-op on last trip
            Bcur = apply_update(i, Bcur, Xi, pg)
            return Bcur, Xacc, comm.all_gather_finish(nxt)

        with comm.scope(m):
            _, X, _ = jax.lax.fori_loop(0, m, body_ov, (Bloc, x0, pg0))
        return X
    with comm.scope(m):
        _, X = jax.lax.fori_loop(0, m, body, (Bloc, x0))
    return X


def _it_inv_trsm_shard(Lloc, Bloc, *, n, k, n0, p1, p2, block_inv, mode,
                       accum_dtype=None, overlap=False):
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else Bloc.dtype
    pre0 = None
    if overlap:
        # start panel 0's z-allgather BEFORE phase 1: the panel reads
        # only Lloc, so the whole diagonal inversion (its collectives
        # included) can hide behind the first panel collective
        nl, b = n // p1, n0 // (p1 * p2)
        panel0 = jax.lax.dynamic_slice(Lloc, (0, 0), (nl, b))
        pre0 = comm.all_gather_start(panel0, "z", axis=0, tiled=False)
    Dt = _invert_diag_blocks(Lloc, n=n, n0=n0, p1=p1, p2=p2,
                             block_inv=block_inv, mode=mode,
                             accum_dtype=acc, overlap=overlap)
    return _sweep_shard(Lloc, Dt, Bloc, n=n, k=k, n0=n0, p1=p1, p2=p2,
                        accum_dtype=acc, overlap=overlap,
                        prefetched0=pre0)


# Sharding of the inverted-diagonal-faces array Dt (m, n0, n0): rows
# cyclic over y, cols cyclic over x (the transposed face the solve GEMM
# consumes), replicated over z — permuted storage like everything else.
SPEC_DT = P(None, "y", "x")


def dt_shape(n: int, n0: int) -> tuple:
    """Global logical shape of the phase-1 output Dt under
    :data:`SPEC_DT`: one (n0, n0) inverted face per diagonal block.
    Used by capacity-allocated factor banks to preallocate the
    resident Dt stack a replace scatters into (DESIGN.md Sec. 11)."""
    return (n // n0, n0, n0)


def it_inv_phase1_sharded(grid: TrsmGrid, n: int, n0: int,
                          block_inv: Callable | None = None,
                          mode: str | None = None, accum_dtype=None):
    """Build the (un-jitted) shard_map program for phase 1 ALONE:
    L_cyc (n, n) P("x", ("z","y")) -> Dt (m, n0, n0) :data:`SPEC_DT`,
    the transposed-face pieces of the inverted diagonal blocks.

    This is the factor-bank admission path (DESIGN.md Sec. 9): a
    resident factor is immutable, so its diagonal-block inversion runs
    ONCE here and the steady state runs :func:`it_inv_sweep_sharded`
    against the resident Dt — the per-solve cost drops the inversion
    term, which is also why the bank's tuned n0 is larger
    (``tuning.serving_n0``)."""
    mode = mode or pick_phase1_mode(n, n0, grid)
    binv = block_inv if block_inv is not None else blocked.tri_inv_batched
    body = functools.partial(_invert_diag_blocks, n=n, n0=n0,
                             p1=grid.p1, p2=grid.p2, block_inv=binv,
                             mode=mode, accum_dtype=accum_dtype)
    return compat.shard_map(body, mesh=grid.mesh,
                            in_specs=(grid.spec_L(),),
                            out_specs=SPEC_DT,
                            check_vma=block_inv is None)


def it_inv_sweep_sharded(grid: TrsmGrid, n: int, k: int, n0: int,
                         accum_dtype=None, unroll: bool = True,
                         structure=None, overlap: bool = False):
    """Build the (un-jitted) shard_map program for the SWEEP against
    pre-inverted diagonal faces: (L_cyc, Dt, B_cyc) -> X_cyc.

    Layouts as :func:`it_inv_trsm_sharded` plus Dt per :data:`SPEC_DT`
    (an :func:`it_inv_phase1_sharded` output).  Mode-independent: the
    phase-1 scheme only matters when Dt is produced.

    ``structure`` (a non-dense
    :class:`~repro.core.structure.FactorStructure`) compiles the
    LEVEL-SCHEDULED sweep instead: the admission-time analysis's
    per-column update spans are baked in as static slice bounds, zero
    blocks are skipped at trace time, and the loop is force-unrolled
    (skip decisions need a trace-time i).  Dense/None compiles the
    byte-identical program this function always built.

    ``overlap`` compiles the DOUBLE-BUFFERED sweep (DESIGN.md Sec. 16):
    panel i+1's z-allgather is started before panel i's update
    executes — bit-identical output (same ops, different issue order),
    structure-aware (skipped spans skip the prefetch)."""
    check_divisibility(n, k, n0, grid)
    spans = None
    if structure is not None and not structure.is_dense:
        from repro.core.structure import analyze
        spans = analyze(structure, n, n0).spans
        unroll = True
    body = functools.partial(_sweep_shard, n=n, k=k, n0=n0,
                             p1=grid.p1, p2=grid.p2,
                             accum_dtype=accum_dtype, unroll=unroll,
                             spans=spans, overlap=overlap)
    return compat.shard_map(body, mesh=grid.mesh,
                            in_specs=(grid.spec_L(), SPEC_DT,
                                      grid.spec_B()),
                            out_specs=grid.spec_X())


def pick_phase1_mode(n: int, n0: int, grid: TrsmGrid) -> str:
    m = n // n0
    p = grid.p
    if m % p == 0:
        return "alltoall"
    s0 = min(ti.pick_s0(n, grid.p1, grid.p2), n0)
    feasible = (s0 % (grid.p1 * grid.p2) == 0 and n0 % s0 == 0
                and (n0 // s0) & (n0 // s0 - 1) == 0)
    return "doubling" if feasible else "allgather"


def it_inv_trsm_sharded(grid: TrsmGrid, n: int, k: int, n0: int,
                        block_inv: Callable | None = None,
                        mode: str | None = None, accum_dtype=None,
                        overlap: bool = False):
    """Build the (un-jitted) shard_map program for fixed shapes, for
    composition inside larger jitted pipelines (repro.core.session).

    Takes/returns *cyclic storage* arrays (see repro.core.grid):
      L_cyc: (n, n) P("x", ("z","y"));  B_cyc: (n, k) P("x", "z")
      returns X_cyc: (n, k) P("y", "z") (rows cyclic over y).

    ``accum_dtype``: GEMM accumulation precision for the sweep (and the
    phase-1 block inversions); defaults to the operand dtype.  With
    bf16 operands pass float32 so the MXU accumulates at full width.

    ``overlap`` software-pipelines the sweep's panel collective and
    starts panel 0's gather before phase 1 (DESIGN.md Sec. 16); the
    output stays bit-identical to the sequential program.
    """
    check_divisibility(n, k, n0, grid)
    mode = mode or pick_phase1_mode(n, n0, grid)
    if mode == "alltoall" and (n // n0) % grid.p != 0:
        mode = pick_phase1_mode(n, n0, grid)
    binv = block_inv if block_inv is not None else blocked.tri_inv_batched

    body = functools.partial(_it_inv_trsm_shard, n=n, k=k, n0=n0,
                             p1=grid.p1, p2=grid.p2, block_inv=binv,
                             mode=mode, accum_dtype=accum_dtype,
                             overlap=overlap)
    # Pallas interpret-mode kernels use an internal while_loop whose
    # vma bookkeeping trips shard_map's checker (jax#...); disable the
    # check only when a kernel hook is plugged in.
    check = block_inv is None
    return compat.shard_map(body, mesh=grid.mesh,
                         in_specs=(grid.spec_L(), grid.spec_B()),
                         out_specs=grid.spec_X(), check_vma=check)


def it_inv_trsm_fn(grid: TrsmGrid, n: int, k: int, n0: int, dtype,
                   block_inv: Callable | None = None,
                   mode: str | None = None):
    """Jitted distributed solver for fixed shapes (cyclic storage)."""
    return jax.jit(it_inv_trsm_sharded(grid, n, k, n0,
                                       block_inv=block_inv, mode=mode))


def solve(L, B, grid: TrsmGrid, n0: int, *, block_inv=None,
          mode: str | None = None):
    """Convenience end-to-end solve: natural-layout L, B in; X out.

    Device-resident: routes through the compiled-solver cache via a
    :class:`repro.core.solver.SolveSpec`, so the cyclic permutations
    run as on-device gathers and repeated same-shape calls reuse the
    compiled program."""
    from repro.core import precision as preclib
    from repro.core.solver import SolveSpec, solver_for
    spec = SolveSpec(n=B.shape[0], k=B.shape[1], grid=grid,
                     policy=preclib.resolve(None, jnp.result_type(L)),
                     method="inv", n0=n0, mode=mode,
                     block_inv=block_inv)
    prog = solver_for(spec)
    return prog.solve(prog.prep(L), B)
