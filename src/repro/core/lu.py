"""Distributed LU factorization (no pivoting) on the paper's primitives.

Completes the paper's Sec. I list — "TRSM is used ... to compute
factorizations with triangular matrices, such as Cholesky, LU, and QR":

    A = [[A11, A12], [A21, A22]]
    L11, U11 = LU(A11)                      (recursive)
    U12 = L11^{-1} A12                      (lower solve via inversion)
    L21 = A21 U11^{-1}                      (upper solve via inversion)
    A22' = A22 - L21 U12                    (Sec. III MM)
    L22, U22 = LU(A22')

Both triangular solves use *selective inversion* (invert + MM — the
paper's technique), with upper solves reduced to the lower case through
the distributed cyclic-storage transpose (repro.core.cholesky).
No pivoting: intended for diagonally-dominant / preconditioner-style
matrices (same contract as the paper's TRSM stability argument).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import comm
from repro.core import tri_inv as ti
from repro.core.cholesky import transpose_shard
from repro.core.grid import TrsmGrid
from repro.core.mm3d import mm3d_shard

MESH_AXES = ("x", "y", "z")


def _lu_base(Aloc, *, n, p1, p2):
    """Base case: allgather, factor locally, keep cyclic pieces."""
    from repro.core.tri_inv import _assemble_blocks, _cyclic_piece
    xi = comm.axis_index("x")
    yi = comm.axis_index("y")
    zi = comm.axis_index("z")
    Ag = comm.all_gather(Aloc[None], MESH_AXES, axis=0, tiled=False)
    A = _assemble_blocks(Ag, p1, p2)[0]

    def body(i, LU):
        L, U = LU
        piv = U[i, i]
        col = U[:, i] / piv
        mask = (jnp.arange(n) > i).astype(A.dtype)
        L = L.at[:, i].set(jnp.where(jnp.arange(n) == i, 1.0, col * mask))
        U = U - jnp.outer(col * mask, U[i])
        return L, U

    L0 = jnp.zeros_like(A)
    L, U = jax.lax.fori_loop(0, n, body, (L0, A))
    U = jnp.triu(U)
    return (_cyclic_piece(L[None], xi, yi, zi, p1, p2)[0],
            _cyclic_piece(U[None], xi, yi, zi, p1, p2)[0])


def _lu_rec(Aloc, *, n, n0, p1, p2):
    if n <= n0:
        return _lu_base(Aloc, n=n, p1=p1, p2=p2)
    h = n // 2
    hl, hc = h // p1, h // (p1 * p2)
    A11, A12 = Aloc[:hl, :hc], Aloc[:hl, hc:]
    A21, A22 = Aloc[hl:, :hc], Aloc[hl:, hc:]
    L11, U11 = _lu_rec(A11, n=h, n0=n0, p1=p1, p2=p2)
    # U12 = L11^{-1} A12 (lower-solve via inversion, Sec. V + III)
    L11i = ti.tri_inv_shard(L11, n=h, p1=p1, p2=p2)
    U12 = mm3d_shard(L11i, A12, m=h, n=h, k=h, p1=p1, p2=p2)
    # L21 = A21 U11^{-1}: transpose-reduce the upper solve
    # (A21 U11^{-1})^T = U11^{-T} A21^T ; U11^T is lower-triangular.
    U11T = transpose_shard(U11, mr=h, nc=h, p1=p1, p2=p2)
    U11Ti = ti.tri_inv_shard(U11T, n=h, p1=p1, p2=p2)
    A21T = transpose_shard(A21, mr=h, nc=h, p1=p1, p2=p2)
    L21T = mm3d_shard(U11Ti, A21T, m=h, n=h, k=h, p1=p1, p2=p2)
    L21 = transpose_shard(L21T, mr=h, nc=h, p1=p1, p2=p2)
    # trailing update + recurse
    A22u = A22 - mm3d_shard(L21, U12, m=h, n=h, k=h, p1=p1, p2=p2)
    L22, U22 = _lu_rec(A22u, n=h, n0=n0, p1=p1, p2=p2)
    zero = jnp.zeros((hl, hc), Aloc.dtype)
    L = jnp.concatenate([jnp.concatenate([L11, zero], axis=1),
                         jnp.concatenate([L21, L22], axis=1)], axis=0)
    U = jnp.concatenate([jnp.concatenate([U11, U12], axis=1),
                         jnp.concatenate([zero, U22], axis=1)], axis=0)
    return L, U


@functools.lru_cache(maxsize=64)
def lu_fn(grid: TrsmGrid, n: int, n0: int | None = None):
    n0 = n0 or max(grid.p1 * grid.p1 * grid.p2, n // 8)
    while n % n0 != 0:
        n0 *= 2
    body = functools.partial(_lu_rec, n=n, n0=min(n0, n),
                             p1=grid.p1, p2=grid.p2)
    spec = P("x", ("z", "y"))
    return jax.jit(compat.shard_map(body, mesh=grid.mesh, in_specs=(spec,),
                                 out_specs=(spec, spec)))


def lu_cyclic(A, grid: TrsmGrid, n0: int | None = None):
    """LU-factor A (natural layout) and return (L_cyc, U_cyc) in CYCLIC
    storage — the factorization's own working layout, un-unpermuted.

    The factor-producer end of the paper's producer->consumer loop:
    ``L_cyc`` feeds ``repro.core.bank.FactorBank.admit_cyclic``
    directly (lower solves), with no unpermute -> re-permute round
    trip.  (``U_cyc`` consumers need the transpose reduction folded at
    distribution, so upper banks ingest U via the natural layout.)"""
    from repro.core.grid import cyclic_matrix_device
    n = A.shape[0]
    p1, p2 = grid.p1, grid.p2
    Ac = cyclic_matrix_device(jnp.asarray(A), p1, p1 * p2)
    return lu_fn(grid, n, n0)(Ac)


def lu(A, grid: TrsmGrid, n0: int | None = None):
    """Natural-layout LU (no pivoting): returns (L, U), A = L @ U.

    Device-resident: on-device cyclic permutations, memoized program.
    For feeding a FactorBank keep the cyclic output: :func:`lu_cyclic`."""
    from repro.core.grid import cyclic_matrix_device
    p1, p2 = grid.p1, grid.p2
    Lc, Uc = lu_cyclic(A, grid, n0)
    return (cyclic_matrix_device(Lc, p1, p1 * p2, inverse=True),
            cyclic_matrix_device(Uc, p1, p1 * p2, inverse=True))
