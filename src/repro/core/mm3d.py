"""3D matrix multiplication from a 2D cyclic start (paper Sec. III).

Computes B = L @ X on the p1 x p1 x p2 mesh ("x", "y", "z") where all of
L, X and B live in the *same* cyclic storage scheme (see
``repro.core.grid``):

    rows cyclic over x  (global row r = l*p1 + x)
    cols cyclic over the pair t = z*p1 + y with stride p1*p2
        (global col c = c'*p1*p2 + z*p1 + y)

i.e. sharding spec ``P("x", ("z", "y"))`` for every operand.  Because
operand and result layouts coincide, MM calls compose (used heavily by
the distributed triangular inversion and the recursive TRSM).

Schedule (paper Alg. MM, adapted to the 3D mesh — see DESIGN.md):

    1. Lg = allgather(L, z)     -> L rows=x-residues, all cols = y-residues
       [cost  W = m*n/p1^2 * 1_{p2},  S = log p2]         (paper line 2)
    2. Xs = permute x<->y       -> X rows become y-residues
       [cost  W = n*k/p,  S = 1]                          (paper line 4)
    3. Xg = allgather(Xs, x)    -> X all cols of this z-slice, replicated x
       [cost  W = n*k/(p1*p2),  S = log p1]               (paper line 5)
    4. P  = Lg~ @ Xg            local GEMM
       [cost  F = m*n*k/p]                                (paper line 6)
    5. B  = reduce-scatter(P, y)  sum partials, keep col-chunk y
       [cost  W = F = m*k/(p1*p2),  S = log p1]           (paper line 7)

Our mesh-native layout removes the paper's lines 3 and 8 (the two
rectangular-grid transposes costing O(nk log(p)/p)): the reduce-scatter
lands directly on the input layout.  This is a (constant/log-factor)
improvement recorded in EXPERIMENTS.md; the leading-order cost matches
the paper exactly:  W = mn/p1^2 * 1_{p2} + 2nk/(p1 p2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import comm
from repro.core.grid import TrsmGrid, to_cyclic_matrix, from_cyclic_matrix


def _swap_perm(p1: int) -> list[tuple[int, int]]:
    """Permutation over the linearized ("x","y") pair sending (x,y)->(y,x)."""
    return [(x * p1 + y, y * p1 + x) for x in range(p1) for y in range(p1)]


def mm3d_shard(Lloc: jnp.ndarray, Xloc: jnp.ndarray, *,
               m: int, n: int, k: int, p1: int, p2: int,
               accum_dtype=None) -> jnp.ndarray:
    """Per-shard body (runs inside shard_map on the (x,y,z) mesh).

    Lloc: (m/p1, n/(p1*p2)) cyclic piece of the m x n left operand.
    Xloc: (n/p1, k/(p1*p2)) cyclic piece of the n x k right operand.
    Returns the (m/p1, k/(p1*p2)) cyclic piece of L @ X.
    ``accum_dtype``: GEMM/reduction precision (the local partial sums
    AND the cross-y reduce-scatter accumulate there); result is cast
    back to the operand dtype.
    """
    ml, ncl = Lloc.shape
    nl, kcl = Xloc.shape
    assert ml == m // p1 and ncl == n // (p1 * p2), (Lloc.shape, m, n, p1, p2)
    assert nl == n // p1 and kcl == k // (p1 * p2), (Xloc.shape, n, k, p1, p2)

    # 1. replicate L over z; realign gathered cols (z-major) to the
    #    X row order l = c'*p2 + z  (c'-major, z-minor).
    if p2 > 1:
        Lg = comm.all_gather(Lloc, "z", axis=1, tiled=True)  # (ml, p2*ncl)
        Lg = Lg.reshape(ml, p2, ncl).transpose(0, 2, 1).reshape(ml, ncl * p2)
    else:
        Lg = Lloc

    # 2-3. move X rows from x-residues to y-residues, then replicate the
    #      z-slice columns over x (cols end x'-major: col = x'*kcl + c').
    if p1 > 1:
        Xs = comm.ppermute(Xloc, ("x", "y"), _swap_perm(p1))
        Xg = comm.all_gather(Xs, "x", axis=1, tiled=True)    # (nl, p1*kcl)
    else:
        Xg = Xloc

    # 4. local GEMM: rows == x-residues, contraction over the y-residue
    #    class, cols = this z-slice.
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else Xloc.dtype
    Pp = jax.lax.dot(Lg, Xg, preferred_element_type=acc)     # (ml, k/p2)

    # 5. complete the contraction over y; keep col-chunk x' == y, which
    #    is exactly the input cyclic layout.
    if p1 > 1:
        Bloc = comm.psum_scatter(Pp, "y", scatter_dimension=1, tiled=True)
    else:
        Bloc = Pp
    return Bloc.astype(Xloc.dtype)


def mm3d_shard_batched(Lloc, Xloc, *, m, n, k, p1, p2):
    """vmap of mm3d_shard over a leading batch axis (collectives batch)."""
    f = functools.partial(mm3d_shard, m=m, n=n, k=k, p1=p1, p2=p2)
    return jax.vmap(f)(Lloc, Xloc)


def mm3d_fn(grid: TrsmGrid, m: int, n: int, k: int):
    """Jitted distributed MM for fixed shapes, cyclic storage in/out."""
    body = functools.partial(mm3d_shard, m=m, n=n, k=k,
                             p1=grid.p1, p2=grid.p2)
    spec = P("x", ("z", "y"))
    fn = compat.shard_map(body, mesh=grid.mesh, in_specs=(spec, spec),
                       out_specs=spec)
    return jax.jit(fn)


def matmul(L, X, grid: TrsmGrid):
    """Convenience natural-layout entry point: returns L @ X.

    Applies the cyclic-storage permutation on the way in/out.  In real
    deployments operands are *kept* in cyclic storage across calls."""
    import numpy as np
    m, n = L.shape
    n2, k = X.shape
    assert n == n2
    p1, p2 = grid.p1, grid.p2
    Lc = to_cyclic_matrix(np.asarray(L), p1, p1 * p2)
    Xc = to_cyclic_matrix(np.asarray(X), p1, p1 * p2)
    Bc = mm3d_fn(grid, m, n, k)(Lc, Xc)
    return from_cyclic_matrix(np.asarray(Bc), p1, p1 * p2)
