"""Precision policies for the solve pipeline (DESIGN.md Sec. 7).

The paper trades flops for parallelism — substitution becomes
multiplication by pre-inverted diagonal blocks — "while maintaining
numerical stability" (Sec. V).  On TPU that trade is only fully cashed
in at low precision: the MXU's peak throughput needs bf16 inputs.  A
:class:`PrecisionPolicy` separates the four dtype roles so the sweep
can run at MXU-native precision while the answer is recovered at high
precision by iterative refinement (``repro.core.refine``):

* ``storage``    — dtype of the resident cyclic factor fed to the sweep
                   (cast ONCE, at distribution time).
* ``compute``    — dtype the sweep's GEMM operands are held in (the
                   MXU input precision; presets keep it == storage).
* ``accumulate`` — dtype of GEMM partial sums (``preferred_element_type``
                   threaded down to the Pallas kernels and the shard_map
                   sweep; bf16 inputs accumulate in fp32 on the MXU at
                   no extra cost).
* ``residual``   — dtype of the refinement residual r = B - op(A)·X and
                   of the refined solution; a SECOND copy of the factor
                   is kept resident at this precision when
                   ``refine_steps > 0`` (classic mixed-precision
                   iterative refinement corrects toward the
                   high-precision operator, not the rounded one).

Presets (the ``precision=`` argument everywhere accepts these names):

    name         storage  compute  accumulate residual steps  io dtype
    ----         -------  -------  ---------- -------- -----  --------
    fp32         f32      f32      f32        f32      0      f32
    bf16         bf16     bf16     f32        f32      0      bf16
    bf16_refine  bf16     bf16     f32        f32      2      f32
    fp64_refine  f32      f32      f32        f64      2      f64

``fp64_refine`` needs ``jax_enable_x64``; it serves fp64 accuracy from
an fp32 sweep (the factor is never touched in fp64 by the sweep).

A policy is hashable and lands verbatim in the
``CompiledSolverCache`` key, so every distinct precision configuration
compiles (and retraces) exactly once per solve shape.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Dtype assignment for one solve pipeline; see module docstring.

    Dtypes are stored as canonical dtype-name strings so the policy is
    hashable (it is part of the compiled-program cache key) and prints
    compactly.  ``name`` is cosmetic and excluded from equality/hash:
    two policies with the same dtype roles and trip count are the SAME
    cache key (the preset ``"fp32"`` and the legacy uniform float32
    policy share one compiled program).  Use :func:`resolve` to build
    one from a preset name, a dtype, or another policy.
    """
    name: str = dataclasses.field(compare=False)
    storage: str
    compute: str
    accumulate: str
    residual: str
    refine_steps: int = 0

    def __post_init__(self):
        for field in ("storage", "compute", "accumulate", "residual"):
            canon = jnp.dtype(getattr(self, field)).name
            object.__setattr__(self, field, canon)
        if self.refine_steps < 0:
            raise ValueError(f"refine_steps must be >= 0, got "
                             f"{self.refine_steps}")

    # dtype-object views of the string fields
    @property
    def storage_dtype(self):
        return jnp.dtype(self.storage)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.compute)

    @property
    def accumulate_dtype(self):
        return jnp.dtype(self.accumulate)

    @property
    def residual_dtype(self):
        return jnp.dtype(self.residual)

    @property
    def io_dtype(self):
        """Dtype of the program boundary (B in, X out): the residual
        dtype when refining (that is the accuracy being served),
        otherwise the sweep's compute dtype."""
        return self.residual_dtype if self.refine_steps else \
            self.compute_dtype

    @property
    def refines(self) -> bool:
        return self.refine_steps > 0

    def describe(self) -> str:
        return (f"{self.name}: storage={self.storage} compute={self.compute} "
                f"accumulate={self.accumulate} residual={self.residual} "
                f"refine_steps={self.refine_steps}")


def _preset(name, storage, compute, accumulate, residual, steps):
    return PrecisionPolicy(name=name, storage=storage, compute=compute,
                           accumulate=accumulate, residual=residual,
                           refine_steps=steps)


PRESETS: dict[str, PrecisionPolicy] = {
    "fp32": _preset("fp32", "float32", "float32", "float32", "float32", 0),
    "bf16": _preset("bf16", "bfloat16", "bfloat16", "float32", "float32", 0),
    "bf16_refine": _preset("bf16_refine", "bfloat16", "bfloat16",
                           "float32", "float32", 2),
    "fp64_refine": _preset("fp64_refine", "float32", "float32",
                           "float32", "float64", 2),
}


def from_dtype(dtype) -> PrecisionPolicy:
    """The uniform (legacy) policy: every role at ``dtype``, no
    refinement — exactly the pre-policy pipeline behavior, so code that
    passes only ``dtype=`` keys and compiles identically to before."""
    d = jnp.dtype(dtype).name
    return PrecisionPolicy(name=d, storage=d, compute=d, accumulate=d,
                           residual=d, refine_steps=0)


def resolve(precision=None, dtype=None) -> PrecisionPolicy:
    """Normalize the ``precision=`` argument into a PrecisionPolicy.

    * ``PrecisionPolicy`` — returned as-is.
    * preset name (``"fp32" | "bf16" | "bf16_refine" | "fp64_refine"``)
      — looked up in :data:`PRESETS`.
    * ``None`` — the uniform policy at ``dtype`` (which must then be
      given): the legacy single-dtype pipeline.
    """
    if isinstance(precision, PrecisionPolicy):
        return precision
    if precision is not None:
        try:
            return PRESETS[precision]
        except KeyError:
            raise ValueError(
                f"unknown precision preset {precision!r}; expected one of "
                f"{sorted(PRESETS)} or a PrecisionPolicy") from None
    if dtype is None:
        raise ValueError("need precision= or dtype= to resolve a policy")
    return from_dtype(dtype)
