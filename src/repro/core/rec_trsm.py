"""Distributed recursive TRSM (paper Sec. IV) — the baseline algorithm.

Solves L X = B by recursively splitting L into quadrants:

    X1  = Rec-TRSM(L11, B1)
    B2' = B2 - MM(L21, X1)          (Sec. III MM)
    X2  = Rec-TRSM(L22, B2')

The recursion runs at trace time over *static* shapes (the paper's
recursion maps to straight-line SPMD code: every device executes every
level).  All operands stay in the shared cyclic storage scheme
``P("x", ("z", "y"))`` so quadrant extraction is plain local slicing
and MM calls compose without data movement.

Base case (n <= n0, paper lines 5-9): allgather L over the whole grid,
all-to-all B so every device owns n0 full rows of k/p distinct columns,
local triangular substitution solve, all-to-all back.  This is the
latency-bound step (one per base case, n/n0 of them sequentially) that
the paper's It-Inv-TRSM eliminates via pre-inversion.

Costs (validated against Sec. IV-A by the tracer):
  2D regime:  S = O(n/n0), W = O(nk log(n/n0) / sqrt(p))  — the extra
              log factor is the re-broadcast of L panels every level.
  3D regime:  S = O((np/k)^{2/3} log p), W = O((n^2 k / p)^{2/3}).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import comm
from repro.core.grid import TrsmGrid
from repro.core.mm3d import mm3d_shard

MESH_AXES = ("x", "y", "z")


def _base_case(Lloc, Bloc, *, n0, k, p1, p2, accum_dtype=None,
               pregathered=None):
    """Solve an n0 x n0 subproblem with substitution (paper lines 5-9).

    The local substitution runs at ``accum_dtype`` (cast up, solve,
    cast back) so low-precision operands do not serialize rounding
    error through the recurrence.

    ``pregathered`` accepts a handle from ``comm.all_gather_start`` on
    ``Lloc`` over the whole mesh: the overlapped recursion issues the
    base case's L-gather BEFORE the trailing-update MM that produces
    this base case's RHS (the gather reads only L, DESIGN.md Sec. 16),
    and this function merely finishes it — same collective, same
    operand, bit-identical result."""
    p = p1 * p1 * p2
    kc = k // (p1 * p2)            # local column count
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else Bloc.dtype

    # line 6: allgather L over the whole grid and reassemble.
    if pregathered is not None:
        Lg = comm.all_gather_finish(pregathered)           # (p, a, b)
    else:
        Lg = comm.all_gather(Lloc, MESH_AXES, axis=0, tiled=False)
    a, b = Lloc.shape
    R = Lg.reshape(p1, p1, p2, a, b)               # [x, y, z, l, c']
    R = jnp.transpose(R, (3, 0, 4, 2, 1))          # [l, x, c', z, y]
    Lfull = R.reshape(n0, n0)

    if p1 > 1:
        # line 7: all-to-all so each device owns full rows of its
        # column chunk (chunk x of the local kc columns, k/p columns).
        Bt = comm.all_to_all(Bloc, "x", split_axis=1, concat_axis=0,
                             tiled=True)            # (n0, kc/p1) x-major rows
        Bt = Bt.reshape(p1, n0 // p1, kc // p1)
        Bt = jnp.transpose(Bt, (1, 0, 2)).reshape(n0, kc // p1)
    else:
        Bt = Bloc

    # line 8: local substitution solve of the owned columns.
    Xt = jax.scipy.linalg.solve_triangular(
        Lfull.astype(acc), Bt.astype(acc), lower=True).astype(Bloc.dtype)

    if p1 > 1:
        # line 9: all-to-all back to cyclic rows / local columns.
        Xt = Xt.reshape(n0 // p1, p1, kc // p1)
        Xt = jnp.transpose(Xt, (1, 0, 2)).reshape(n0, kc // p1)
        Xloc = comm.all_to_all(Xt, "x", split_axis=0, concat_axis=1,
                               tiled=True)          # (n0/p1, kc)
    else:
        Xloc = Xt
    return Xloc


def _rec(Lloc, Bloc, *, n, k, n0, p1, p2, accum_dtype=None,
         overlap=False):
    if n <= n0:
        return _base_case(Lloc, Bloc, n0=n, k=k, p1=p1, p2=p2,
                          accum_dtype=accum_dtype)
    h = n // 2
    hl, hc = h // p1, h // (p1 * p2)
    L11 = Lloc[:hl, :hc]
    L21 = Lloc[hl:, :hc]
    L22 = Lloc[hl:, hc:]
    X1 = _rec(L11, Bloc[:hl], n=h, k=k, n0=n0, p1=p1, p2=p2,
              accum_dtype=accum_dtype, overlap=overlap)
    pre22 = None
    if overlap and h <= n0:
        # the second half is a base case: start its L-gather now so it
        # rides under the trailing-update MM (which never reads it)
        pre22 = comm.all_gather_start(L22, MESH_AXES, axis=0,
                                      tiled=False)
    U = mm3d_shard(L21, X1, m=h, n=h, k=k, p1=p1, p2=p2,
                   accum_dtype=accum_dtype)
    if pre22 is not None:
        X2 = _base_case(L22, Bloc[hl:] - U, n0=h, k=k, p1=p1, p2=p2,
                        accum_dtype=accum_dtype, pregathered=pre22)
    else:
        X2 = _rec(L22, Bloc[hl:] - U, n=h, k=k, n0=n0, p1=p1, p2=p2,
                  accum_dtype=accum_dtype, overlap=overlap)
    return jnp.concatenate([X1, X2], axis=0)


def default_n0(n: int, k: int, p1: int, p2: int) -> int:
    """Paper Sec. IV-A base-case sizes, snapped to feasibility.

    3D: n0 = n^{1/3} (k/p)^{2/3};  2D: n0 = max(sqrt p, n log p / sqrt p).
    Feasibility: p1*p2 | n0, n0 | n, both powers of two here."""
    import math
    p = p1 * p1 * p2
    if p2 > 1:
        ideal = n ** (1 / 3) * (k / p) ** (2 / 3)
    else:
        ideal = max(math.sqrt(p), n * max(math.log2(p), 1.0) / math.sqrt(p))
    gran = p1 * p1 * p2
    n0 = gran
    while n0 * 2 <= min(ideal, n) and n % (n0 * 2) == 0:
        n0 *= 2
    while n % n0 != 0 and n0 < n:
        n0 *= 2
    return min(n0, n)


def rec_trsm_sharded(grid: TrsmGrid, n: int, k: int,
                     n0: int | None = None, accum_dtype=None,
                     overlap: bool = False):
    """Un-jitted shard_map Rec-TRSM for fixed shapes (cyclic storage),
    for composition inside larger jitted pipelines (repro.core.session).

    L: (n, n) P("x", ("z","y"));  B: (n, k) P("x", ("z","y"));
    X returned in the same layout as B.  ``accum_dtype``: precision for
    the MM updates and base-case substitution (defaults to the operand
    dtype).  ``overlap`` prefetches each base case's L-gather under the
    preceding trailing-update MM (bit-identical output, DESIGN.md
    Sec. 16)."""
    n0 = n0 or default_n0(n, k, grid.p1, grid.p2)
    assert k % (grid.p1 * grid.p1 * grid.p2) == 0, (k, grid.p)
    body = functools.partial(_rec, n=n, k=k, n0=n0,
                             p1=grid.p1, p2=grid.p2,
                             accum_dtype=accum_dtype, overlap=overlap)
    spec = P("x", ("z", "y"))
    return compat.shard_map(body, mesh=grid.mesh, in_specs=(spec, spec),
                         out_specs=spec)


def rec_trsm_fn(grid: TrsmGrid, n: int, k: int, n0: int | None = None):
    """Jitted distributed Rec-TRSM for fixed shapes (cyclic storage)."""
    return jax.jit(rec_trsm_sharded(grid, n, k, n0))


def solve(L, B, grid: TrsmGrid, n0: int | None = None):
    """Natural-layout convenience entry point (device-resident: cached
    compiled program via a :class:`repro.core.solver.SolveSpec`,
    on-device cyclic permutations)."""
    from repro.core import precision as preclib
    from repro.core.solver import SolveSpec, solver_for
    n, k = B.shape
    spec = SolveSpec(n=n, k=k, grid=grid,
                     policy=preclib.resolve(None, jnp.result_type(L)),
                     method="rec",
                     n0=n0 or default_n0(n, k, grid.p1, grid.p2))
    prog = solver_for(spec)
    return prog.solve(prog.prep(L), B)
