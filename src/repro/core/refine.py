"""On-device iterative refinement for the cyclic solve pipeline
(DESIGN.md Sec. 7).

Classic mixed-precision refinement (Wilkinson; Carson/Higham for the
low-precision-factorization revival): solve in low precision, then
repeat

    r   = B - op(A) X          (residual precision)
    d   = solve(op(A), r)      (low-precision sweep, reused)
    X  += d

Each pass contracts the error by ~(eps_compute * kappa), so a couple of
passes recover residual-precision accuracy from a bf16 sweep whenever
the factor is not close to singular at bf16.

Everything here is designed to live INSIDE the one compiled program of
``repro.core.session``:

* the loop is a fixed-trip Python loop, unrolled at trace time — no
  host-side convergence test, hence zero steady-state host transfers
  and zero retraces (the session invariants extend to refined solves);
* the residual reuses the RESIDENT cyclic factor: for a factor
  distributed as ``L_cyc = Pr · op(A)_eff · Pc^T`` (rows stride-p1
  cyclic, cols stride-p1·p2 cyclic, reversal/transpose folded in —
  repro.core.grid), the operator applies to a natural-layout X as

      op(A) X  =  unpermute_rows( L_cyc @ permute_rows(X, col-map) )

  i.e. two O(nk) on-device gathers around one GEMM — no second layout,
  no host permutation, and the SAME expression serves all four
  (lower, transpose) operator variants because the reduction identities
  are already folded into ``L_cyc``'s gathers.
"""

from __future__ import annotations

import jax.lax
import jax.numpy as jnp

from repro.core import grid as gridlib
from repro.core.precision import PrecisionPolicy


def apply_cyclic_operator(L_cyc, X, *, p1: int, p2: int, reverse: bool,
                          accum_dtype=None):
    """Compute ``op(A) @ X`` (natural layout in and out) from the
    resident cyclic factor.

    ``L_cyc`` is the distribution-time gather of op(A) with row map
    ``G_r`` (stride p1, reversal ``reverse``) and column map ``G_c``
    (stride p1*p2, same reversal): ``L_cyc = G_r op(A) G_c^T``.  Then

        op(A) X = G_r^{-1} ( L_cyc @ G_c X )

    — one gather of X's rows by the factor's COLUMN map, the GEMM
    against the resident factor, and the inverse gather by the factor's
    ROW map.  The transpose flag needs no case here: it was applied to
    the matrix before distribution, so it is part of op(A) already.

    Accepts stacked operands too — L_cyc (M, n, n) with X (M, n, k) —
    in which case the gathers permute the trailing row axis and the
    GEMM is one batched contraction: a factor bank's whole refinement
    residual is three ops (DESIGN.md Sec. 9).
    """
    Xg = gridlib.cyclic_rows_device(X, p1 * p2, reverse=reverse)
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else X.dtype
    if L_cyc.ndim == 2:
        Y = jax.lax.dot(L_cyc, Xg.astype(L_cyc.dtype),
                        preferred_element_type=acc)
    else:
        Y = jax.lax.dot_general(
            L_cyc, Xg.astype(L_cyc.dtype),
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=acc)
    return gridlib.cyclic_rows_device(Y, p1, inverse=True, reverse=reverse)


def refined_solve(base_solve, L_lo, L_hi, B, *, policy: PrecisionPolicy,
                  p1: int, p2: int, reverse: bool):
    """The refined solve body (traced inside the session's program).

    ``base_solve(L_cyc, B) -> X`` is the compute-precision sweep
    (natural layout in/out, the existing permute -> shard_map sweep ->
    unpermute body).  ``L_lo``/``L_hi`` are the resident cyclic factor
    at storage and residual precision (``L_hi`` may be None when the
    policy does not refine).  Returns X at ``policy.io_dtype``.
    """
    io = policy.io_dtype
    B = jnp.asarray(B, io)
    X = base_solve(L_lo, B.astype(policy.compute_dtype))
    if not policy.refines:
        return X.astype(io)
    res = policy.residual_dtype
    X = X.astype(res)
    for _ in range(policy.refine_steps):        # unrolled: one program
        r = B - apply_cyclic_operator(L_hi, X, p1=p1, p2=p2,
                                      reverse=reverse, accum_dtype=res)
        d = base_solve(L_lo, r.astype(policy.compute_dtype))
        X = X + d.astype(res)
    return X
