"""Multi-device self-checks for the distributed core algorithms.

Run as a subprocess with forced host devices (tests do this):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.core.selfcheck [what]

Exits nonzero on the first failure.  Kept as a module (not a test) so it
can run under a different jax device configuration than the main pytest
process (which must see exactly 1 device).
"""

from __future__ import annotations

import os
import sys

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np


def _random_tril(seed, n, dtype=np.float64):
    rng = np.random.default_rng(seed)
    L = np.tril(rng.standard_normal((n, n)))
    return (L + n * np.eye(n)).astype(dtype)


def check_it_inv_trsm() -> int:
    from repro.core import grid as gridlib
    from repro.core import inv_trsm

    jax.config.update("jax_enable_x64", True)
    fails = 0
    cases = [
        # (p1, p2, n, k, n0, mode)
        (2, 2, 32, 8, 4, None),       # m=8 == p -> alltoall
        (2, 2, 32, 8, 8, None),       # m=4 < p -> allgather fallback
        (2, 2, 64, 16, 8, "alltoall"),
        (2, 2, 64, 16, 8, "allgather"),
        (2, 1, 32, 6, 8, None),
        (1, 2, 32, 8, 16, None),
        (1, 8, 64, 8, 8, None),
        (2, 2, 64, 64, 16, None),
        (1, 1, 16, 4, 4, None),
    ]
    for (p1, p2, n, k, n0, mode) in cases:
        grid = gridlib.make_trsm_mesh(p1, p2)
        L = _random_tril(n, n)
        B = np.random.default_rng(k).standard_normal((n, k))
        X = inv_trsm.solve(jnp.asarray(L), jnp.asarray(B), grid, n0,
                           mode=mode)
        ref = np.asarray(
            jax.scipy.linalg.solve_triangular(jnp.asarray(L),
                                              jnp.asarray(B), lower=True))
        err = np.abs(X - ref).max()
        ok = err < 1e-8
        print(f"it_inv_trsm p1={p1} p2={p2} n={n} k={k} n0={n0} "
              f"mode={mode}: err={err:.2e} {'OK' if ok else 'FAIL'}")
        if not ok:
            fails += 1
    return fails


def check_collective_order() -> int:
    """Verify the flattening order assumptions for tuple-axis collectives."""
    from jax.sharding import Mesh, PartitionSpec as P
    devs = np.asarray(jax.devices())[:8].reshape(2, 2, 2)
    mesh = Mesh(devs, ("x", "y", "z"))
    fails = 0

    def body(a):
        xi = jax.lax.axis_index("x")
        yi = jax.lax.axis_index("y")
        zi = jax.lax.axis_index("z")
        fid = (xi * 2 + yi) * 2 + zi
        g = jax.lax.all_gather(jnp.array([fid]), ("x", "y", "z"),
                               axis=0, tiled=True)
        return g[None]

    f = compat.shard_map(body, mesh=mesh, in_specs=P("x", ("z", "y")),
                      out_specs=P(("x", "y", "z")))
    out = np.asarray(jax.jit(f)(jnp.zeros((2, 4))))
    expect = np.arange(8)
    if not np.array_equal(out[0], expect):
        print("all_gather tuple-axis order MISMATCH:", out[0])
        fails += 1
    else:
        print("all_gather tuple-axis order OK (x-major row-major)")

    def body2(a):
        xi = jax.lax.axis_index("x")
        yi = jax.lax.axis_index("y")
        zi = jax.lax.axis_index("z")
        fid = (xi * 2 + yi) * 2 + zi
        # each device holds 8 items tagged (src, slot); after a tiled
        # all_to_all device d should hold items (src=0..7, slot=d)
        items = fid * 8 + jnp.arange(8)
        r = jax.lax.all_to_all(items, ("x", "y", "z"), split_axis=0,
                               concat_axis=0, tiled=True)
        return r[None]

    f2 = compat.shard_map(body2, mesh=mesh, in_specs=P("x", ("z", "y")),
                       out_specs=P(("x", "y", "z")))
    out2 = np.asarray(jax.jit(f2)(jnp.zeros((2, 4))))
    # device d (flattened x-major) holds rows d of the output spec
    for d in range(8):
        expect = np.arange(8) * 8 + d
        if not np.array_equal(out2[d], expect):
            print(f"all_to_all order MISMATCH on dev {d}:", out2[d])
            fails += 1
            break
    else:
        print("all_to_all tuple-axis order OK")
    return fails


def check_mm3d() -> int:
    from repro.core import grid as gridlib
    from repro.core import mm3d

    jax.config.update("jax_enable_x64", True)
    fails = 0
    for (p1, p2, m, n, k) in [(2, 2, 16, 16, 8), (2, 1, 8, 8, 4),
                              (1, 2, 8, 8, 8), (1, 8, 16, 16, 16),
                              (2, 2, 32, 16, 8), (1, 1, 8, 8, 4),
                              (2, 2, 16, 16, 64)]:
        grid = gridlib.make_trsm_mesh(p1, p2)
        rng = np.random.default_rng(m * n)
        L = rng.standard_normal((m, n))
        X = rng.standard_normal((n, k))
        B = mm3d.matmul(L, X, grid)
        err = np.abs(B - L @ X).max()
        ok = err < 1e-10
        print(f"mm3d p1={p1} p2={p2} m={m} n={n} k={k}: err={err:.2e} "
              f"{'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    return fails


def check_tri_inv() -> int:
    from repro.core import grid as gridlib
    from repro.core import tri_inv

    jax.config.update("jax_enable_x64", True)
    fails = 0
    for (p1, p2, n, s0, mode) in [(2, 2, 64, None, None),
                                  (2, 2, 64, 8, "alltoall"),
                                  (2, 2, 32, 8, "allgather"),
                                  (1, 2, 32, None, None),
                                  (2, 1, 32, None, None),
                                  (1, 8, 64, None, None),
                                  (1, 1, 16, None, None)]:
        grid = gridlib.make_trsm_mesh(p1, p2)
        L = _random_tril(n, n)
        Li = tri_inv.invert(L, grid, s0=s0, mode=mode)
        err = np.abs(Li @ L - np.eye(n)).max()
        ok = err < 1e-9 and np.allclose(np.triu(Li, 1), 0)
        print(f"tri_inv p1={p1} p2={p2} n={n} s0={s0} mode={mode}: "
              f"err={err:.2e} {'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    return fails


def check_rec_trsm() -> int:
    from repro.core import grid as gridlib
    from repro.core import rec_trsm

    jax.config.update("jax_enable_x64", True)
    fails = 0
    for (p1, p2, n, k, n0) in [(2, 2, 64, 16, 16), (2, 2, 64, 16, None),
                               (2, 1, 32, 8, 8), (1, 2, 32, 4, None),
                               (1, 8, 64, 16, None), (1, 1, 16, 4, 4),
                               (2, 2, 32, 32, 8)]:
        grid = gridlib.make_trsm_mesh(p1, p2)
        L = _random_tril(n, n)
        B = np.random.default_rng(1).standard_normal((n, k))
        X = rec_trsm.solve(L, B, grid, n0)
        err = np.abs(X - np.linalg.solve(L, B)).max()
        ok = err < 1e-9
        print(f"rec_trsm p1={p1} p2={p2} n={n} k={k} n0={n0}: "
              f"err={err:.2e} {'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    return fails


def check_cholesky() -> int:
    from repro.core import grid as gridlib
    from repro.core import cholesky

    jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(0)
    fails = 0
    for (p1, p2, n, n0) in [(2, 2, 32, 8), (2, 1, 32, 16), (1, 2, 16, 8),
                            (2, 2, 64, 16)]:
        grid = gridlib.make_trsm_mesh(p1, p2)
        M = rng.standard_normal((n, n))
        A = M @ M.T + n * np.eye(n)
        L = cholesky.cholesky(A, grid, n0)
        err = np.abs(L @ L.T - A).max()
        ok = err < 1e-8 and np.allclose(np.triu(L, 1), 0)
        print(f"cholesky p1={p1} p2={p2} n={n} n0={n0}: err={err:.2e} "
              f"{'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    # transpose helper
    for (p1, p2, mr, nc) in [(2, 2, 16, 32), (2, 1, 16, 8), (1, 2, 8, 16)]:
        grid = gridlib.make_trsm_mesh(p1, p2)
        A = rng.standard_normal((mr, nc))
        Ac = gridlib.to_cyclic_matrix(A, p1, p1 * p2)
        T = gridlib.from_cyclic_matrix(
            np.asarray(cholesky.transpose_fn(grid, mr, nc)(Ac)), p1, p1 * p2)
        ok = np.array_equal(T, A.T)
        print(f"transpose p1={p1} p2={p2} {mr}x{nc}: "
              f"{'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    return fails


def check_doubling_mode() -> int:
    from repro.core import grid as gridlib
    from repro.core import inv_trsm

    jax.config.update("jax_enable_x64", True)
    fails = 0
    for (p1, p2, n, k, n0) in [(2, 2, 64, 16, 32), (2, 2, 64, 16, 16),
                               (1, 8, 64, 8, 32)]:
        grid = gridlib.make_trsm_mesh(p1, p2)
        L = _random_tril(n, n)
        B = np.random.default_rng(2).standard_normal((n, k))
        X = inv_trsm.solve(L, B, grid, n0, mode="doubling")
        err = np.abs(X - np.linalg.solve(L, B)).max()
        ok = err < 1e-9
        print(f"doubling p1={p1} p2={p2} n={n} n0={n0}: err={err:.2e} "
              f"{'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    return fails


def check_lu() -> int:
    from repro.core import grid as gridlib
    from repro.core import lu

    jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(0)
    fails = 0
    for (p1, p2, n, n0) in [(2, 2, 32, 8), (2, 1, 32, 16), (1, 2, 16, 8),
                            (2, 2, 64, 16)]:
        grid = gridlib.make_trsm_mesh(p1, p2)
        A = rng.standard_normal((n, n)) + n * np.eye(n)
        L, U = lu.lu(A, grid, n0)
        err = np.abs(L @ U - A).max()
        ok = (err < 1e-8 and np.allclose(np.triu(L, 1), 0)
              and np.allclose(np.tril(U, -1), 0)
              and np.allclose(np.diag(L), 1))
        print(f"lu p1={p1} p2={p2} n={n} n0={n0}: err={err:.2e} "
              f"{'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    return fails


def check_session() -> int:
    """Device-resident pipeline: lower/upper/transposed solves via the
    compiled-solver cache and a width-1 Solver, on multi-device
    grids."""
    from repro import core
    from repro.core import grid as gridlib, session

    jax.config.update("jax_enable_x64", True)
    fails = 0
    rng = np.random.default_rng(3)
    for (p1, p2, n, k, n0, method) in [(2, 2, 64, 16, 16, "inv"),
                                       (2, 1, 32, 8, 8, "inv"),
                                       (1, 2, 32, 8, 16, "rec"),
                                       (2, 2, 64, 16, 16, "rec")]:
        grid = gridlib.make_trsm_mesh(p1, p2)
        L = _random_tril(n, n)
        B = rng.standard_normal((n, k))
        for lower, transpose in [(True, False), (False, False),
                                 (True, True), (False, True)]:
            A = L if lower else L.T
            op = A.T if transpose else A
            X = core.trsm(A, B, grid, method=method, n0=n0, lower=lower,
                          transpose=transpose)
            err = np.abs(op @ np.asarray(X) - B).max()
            ok = err < 1e-8
            print(f"session {method} p1={p1} p2={p2} n={n} "
                  f"lower={lower} T={transpose}: err={err:.2e} "
                  f"{'OK' if ok else 'FAIL'}")
            fails += 0 if ok else 1
        # steady state: resident factor, no retrace across repeated solves
        sess = core.Solver.from_factor(L, grid, method=method, n0=n0)
        sess.warmup(k)
        key = sess.program_for(k).key
        before = session.TRACE_COUNTS[key]
        Bs = [sess.place_rhs(rng.standard_normal((n, k)))
              for _ in range(3)]
        with jax.transfer_guard("disallow"):
            # donate=False: B is re-read below to verify the residual
            outs = [sess.solve(b, donate=False) for b in Bs]
        err = max(np.abs(L @ np.asarray(x[0]) - np.asarray(b[0])).max()
                  for b, x in zip(Bs, outs))
        steady = session.TRACE_COUNTS[key] == before
        ok = err < 1e-8 and steady
        print(f"session steady p1={p1} p2={p2} {method}: err={err:.2e} "
              f"retraces={'0' if steady else 'NONZERO'} "
              f"{'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    # mixed precision on a multi-device grid: bf16 sweep + on-device
    # refinement serves fp32-grade answers with the same steady state
    for (p1, p2, method) in [(2, 2, "inv"), (2, 2, "rec")]:
        grid = gridlib.make_trsm_mesh(p1, p2)
        n, k, n0 = 64, 16, 16
        L = _random_tril(5, n, np.float32)
        sess = core.Solver.from_factor(L, grid, method=method, n0=n0,
                                       precision="bf16_refine")
        sess.warmup(k)
        key = sess.program_for(k).key
        before = session.TRACE_COUNTS[key]
        B = sess.place_rhs(rng.standard_normal((n, k)).astype(np.float32))
        with jax.transfer_guard("disallow"):
            X = sess.solve(B, donate=False)
        rel = (np.linalg.norm(L.astype(np.float64)
                              @ np.asarray(X[0], np.float64)
                              - np.asarray(B[0]))
               / np.linalg.norm(np.asarray(B[0])))
        steady = session.TRACE_COUNTS[key] == before
        ok = rel < 1e-5 and steady and X.dtype == jnp.float32
        print(f"session bf16_refine p1={p1} p2={p2} {method}: "
              f"relres={rel:.2e} retraces={'0' if steady else 'NONZERO'} "
              f"{'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    return fails


def check_bank() -> int:
    """Multi-factor batched serving on multi-device grids: stacked
    admission + cyclic ingestion, vmap/scan mapped programs, mixed
    precision, and the banked steady state (DESIGN.md Sec. 9)."""
    from repro import core
    from repro.core import cholesky, grid as gridlib, session
    from repro.core.bank import FactorBank

    jax.config.update("jax_enable_x64", True)
    fails = 0
    rng = np.random.default_rng(9)
    M, n, k = 3, 64, 16
    for (p1, p2, method, map_mode, precision) in [
            (2, 2, "inv", "vmap", None),
            (2, 2, "inv", "scan", None),
            (2, 1, "rec", "vmap", None),
            (2, 2, "inv", "vmap", "bf16_refine")]:
        grid = gridlib.make_trsm_mesh(p1, p2)
        dt = np.float32 if precision else np.float64
        Ls = np.stack([_random_tril(10 + i, n, dt) for i in range(M)])
        bank = FactorBank(grid, n, method=method,
                          n0=None if method == "inv" else 16,
                          dtype=None if precision else dt,
                          precision=precision, map_mode=map_mode)
        bank.admit_stack(Ls[:2])
        bank.admit(Ls[2])
        sess = core.Solver.from_bank(bank)
        key = sess.program_for(k).key
        before = session.TRACE_COUNTS[key]
        sess.warmup(k)
        Bs = [sess.place_rhs(rng.standard_normal((M, n, k)).astype(dt))
              for _ in range(3)]
        with jax.transfer_guard("disallow"):
            outs = [sess.solve(b, donate=False) for b in Bs]
        rel = max(np.linalg.norm(Ls[i].astype(np.float64)
                                 @ np.asarray(x[i], np.float64)
                                 - np.asarray(b[i]))
                  / np.linalg.norm(np.asarray(b[i]))
                  for b, x in zip(Bs, outs) for i in range(M))
        steady = session.TRACE_COUNTS[key] == before + 1
        ok = rel < (1e-5 if precision else 1e-10) and steady
        print(f"bank {method} p1={p1} p2={p2} {map_mode} "
              f"{precision or 'uniform'} n0={bank.n0}: relres={rel:.2e} "
              f"retraces={'0' if steady else 'NONZERO'} "
              f"{'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    # cyclic ingestion from a grid-resident factorization
    grid = gridlib.make_trsm_mesh(2, 2)
    L0 = _random_tril(20, n)
    A = L0 @ L0.T
    bank = FactorBank(grid, n, dtype=np.float64)
    bank.admit_cyclic(cholesky.cholesky_cyclic(A, grid))
    sess = core.Solver.from_bank(bank)
    B = rng.standard_normal((1, n, k))
    X = np.asarray(sess.solve(sess.place_rhs(B))[0], np.float64)
    Lnat = np.asarray(cholesky.cholesky(A, grid), np.float64)
    rel = np.linalg.norm(Lnat @ X - B[0]) / np.linalg.norm(B[0])
    ok = rel < 1e-10
    print(f"bank cyclic-ingest p1=2 p2=2: relres={rel:.2e} "
          f"{'OK' if ok else 'FAIL'}")
    fails += 0 if ok else 1
    return fails


def check_overlap() -> int:
    """Bit-identity of the software-pipelined sweep (DESIGN.md
    Sec. 16): overlap on/off runs the SAME collectives on the same
    operands in a different issue order, so the solve output must be
    byte-equal — per method, per grid shape (degenerate p2=1 and
    p1=1 included), and per structure."""
    from repro import api
    from repro.core import grid as gridlib
    from repro.core.structure import FactorStructure

    jax.config.update("jax_enable_x64", True)
    fails = 0
    cases = [
        # (p1, p2, method, n, k, n0, structure)
        (2, 2, "inv", 64, 8, 16, None),
        (2, 1, "inv", 64, 8, 16, None),      # degenerate z axis
        (1, 2, "inv", 64, 8, 16, None),      # degenerate x/y axes
        (2, 2, "rec", 64, 8, 16, None),
        (2, 2, "inv", 64, 8, 16, FactorStructure.banded(16)),
    ]
    for (p1, p2, method, n, k, n0, st) in cases:
        grid = gridlib.make_trsm_mesh(p1, p2)
        L = _random_tril(n, n)
        if st is not None and st.kind == "banded":
            ii = np.arange(n)
            L *= np.abs(ii[:, None] - ii[None, :]) < st.bandwidth
        B = np.random.default_rng(k).standard_normal((n, k))
        outs = {}
        for ov in ("on", "off"):
            solver = api.Solver.from_factor(
                L, grid, method=method, n0=n0, structure=st, overlap=ov)
            outs[ov] = np.asarray(solver.solve(B, donate=False))
        bit = outs["on"].tobytes() == outs["off"].tobytes()
        err = np.abs(L @ outs["on"] - B).max()
        ok = bit and err < 1e-7
        tag = st.kind if st is not None else "dense"
        print(f"overlap p1={p1} p2={p2} {method} {tag}: "
              f"bit-identical={bit} err={err:.2e} "
              f"{'OK' if ok else 'FAIL'}")
        if not ok:
            fails += 1
    return fails


CHECKS = {
    "order": check_collective_order,
    "it_inv_trsm": check_it_inv_trsm,
    "mm3d": check_mm3d,
    "tri_inv": check_tri_inv,
    "rec_trsm": check_rec_trsm,
    "cholesky": check_cholesky,
    "doubling": check_doubling_mode,
    "lu": check_lu,
    "session": check_session,
    "bank": check_bank,
    "overlap": check_overlap,
}


def main(argv):
    what = argv[1] if len(argv) > 1 else None
    names = [what] if what else list(CHECKS)
    fails = 0
    for name in names:
        fails += CHECKS[name]()
    print(f"selfcheck: {fails} failures")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
