"""Open-loop async serving with latency SLOs (DESIGN.md Sec. 13;
re-exported via ``repro.api``).

Every tier below this one is CLOSED-loop: :class:`SolveServer.drain`
is a synchronous wave-packer driven by the caller, so the caller's own
pace is the admission control.  Production traffic is OPEN-loop —
requests arrive on their own schedule ("millions of users"), queues
must stay bounded, and the serving tier owes each request a completion
handle and a tail-latency story.  This module adds that front:

* :class:`AsyncSolveServer` — a background drain loop (one thread,
  injectable clock, injectable thread factory) over the existing
  :class:`~repro.core.solver.SolveServer` wave machinery and
  :class:`~repro.core.fleet.SolverFleet` router.  ``submit`` never
  blocks and never waits for a wave: it stamps the request into a
  bounded per-slot :class:`FairQueue` and returns a
  :class:`SolveFuture`.  The loop packs one wave per live slot per
  iteration and dispatches it through
  ``SolveServer._solve_wave`` — ONE compiled program for all traffic,
  zero retraces and zero host transfers in the steady state (the
  request's ingestion upload is paid at submit, exactly like
  ``place_rhs``).

* **Admission control.**  Each slot's queue is bounded
  (``queue_depth``); a submit against a full queue is SHED with a
  typed :class:`Overloaded` error — never enqueued, never served — so
  queue delay (and hence tail latency) is bounded by construction
  instead of growing without bound past saturation.

* **Weighted fair packing.**  Within one slot's panel, tenants share
  the ``panel_k`` columns by weighted fair queueing (virtual finish
  times): see :class:`FairQueue`.  FIFO per tenant, width bound per
  wave, weight-proportional interleaving within a wave, no
  starvation — property-tested in tests/test_property.py.

* **Pipelined dispatch.**  jax dispatch is asynchronous: a dispatched
  wave returns lazy device arrays immediately.  The loop keeps up to
  ``max_inflight`` waves un-finalized, so wave t+1 is packed on host
  while wave t executes on device; a future resolves (and its
  completion is timestamped) when its wave is FINALIZED
  (``block_until_ready``), so reported latencies are honest
  end-to-end numbers, not dispatch-time fictions.

* **Evict-under-flight safety.**  The per-slot generation counter
  recorded at submit time is re-checked at pack time: requests whose
  slot was turned over since fail their future with
  :class:`~repro.core.solver.StrandedRequestError` instead of hanging
  (or silently solving against the slot's new occupant).  Fleet-mode
  requests record the :class:`~repro.core.fleet.FleetHandle`
  generation, so a cross-tenant LRU reclaim strands exactly the
  displaced tenant's queued requests.

Determinism for tests: construct with a fake ``clock``, never call
:meth:`AsyncSolveServer.start`, and drive :meth:`step` /
:meth:`flush` by hand — no thread, no sleeps, no wall-clock
(tests/conftest.py packages this as ``FakeClock`` + ``DrainDriver``).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time as _time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import errors as _errors
from repro.core.solver import SolveServer, static_slice
from repro.core.solver import _warn_deprecated


# Overloaded now lives in the unified serving-error hierarchy
# (repro.core.errors, DESIGN.md Sec. 15); the historical spelling
# `repro.core.serving.Overloaded` is a warn-once alias of the same
# class via __getattr__ below.

def __getattr__(name: str):
    if name == "Overloaded":
        _warn_deprecated("repro.core.serving.Overloaded",
                         "repro.api.Overloaded (repro.core.errors)")
        # warn-once: bind the module attribute so subsequent accesses
        # resolve silently to the SAME class object
        globals()[name] = _errors.Overloaded
        return _errors.Overloaded
    raise AttributeError(f"module {__name__!r} has no attribute "
                         f"{name!r}")


class SystemClock:
    """The default wall clock.  The injection point is duck-typed:
    anything with ``monotonic()`` serves (tests pass a manual
    ``FakeClock`` and step the loop by hand, so async tests never
    sleep)."""

    monotonic = staticmethod(_time.monotonic)
    sleep = staticmethod(_time.sleep)


class SolveFuture:
    """Completion handle for one async solve request.

    ``result(timeout)`` blocks until the request's wave is finalized
    and returns the (n_true, j) solution block — or raises the typed
    failure (:class:`~repro.core.solver.StrandedRequestError` when the
    slot was evicted under the request, or whatever the dispatch
    raised).  ``exception(timeout)`` returns that error instead of
    raising.  ``latency()`` is completion minus arrival on the
    server's (injectable) clock, available once done."""

    __slots__ = ("tenant", "tag", "factor", "order", "width", "arrival",
                 "dispatched", "completed", "_event", "_value", "_error")

    def __init__(self, *, tenant, tag, factor, order, width, arrival):
        self.tenant = tenant
        self.tag = tag
        self.factor = factor        # queue key: slot or (bucket, slot)
        self.order = order          # true RHS row count served back
        self.width = width          # RHS column count
        self.arrival = arrival      # clock.monotonic() at submit
        self.dispatched = None      # set when the wave is dispatched
        self.completed = None       # set when the wave is finalized
        self._event = threading.Event()
        self._value = None
        self._error = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"solve future not done after {timeout}s "
                               f"(is the drain loop running?)")
        if self._error is not None:
            raise self._error
        return self._value

    def exception(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"solve future not done after {timeout}s "
                               f"(is the drain loop running?)")
        return self._error

    def latency(self) -> float | None:
        """Seconds from arrival to finalization (None until done)."""
        if self.completed is None:
            return None
        return self.completed - self.arrival

    def _resolve(self, value, now: float) -> None:
        self._value = value
        self.completed = now
        self._event.set()

    def _fail(self, error: BaseException, now: float) -> None:
        self._error = error
        self.completed = now
        self._event.set()


@dataclasses.dataclass
class _Request:
    """One queued request (internal): the placed RHS block plus the
    bookkeeping fairness, generations, and futures need."""
    seq: int
    b: object                   # (n_bucket, j) device columns
    width: int                  # j
    tenant: str
    key: object                 # queue key: slot (plain) | (bucket, slot)
    gen: int                    # slot generation at submit
    order: int                  # true row count (== n unless padded)
    future: SolveFuture
    vtag: float = 0.0           # WFQ virtual finish time (set on push)
    deadline: float | None = None   # arrival + slo (admission-stamped)


class FairQueue:
    """One panel slot's bounded, weighted-fair request queue.

    Fairness is weighted fair queueing by VIRTUAL FINISH TIME: tenant
    t's request of width w is stamped ``vtag = max(v[t], vclock) +
    w / weight(t)`` at admission (``v[t]``: t's last stamp; ``vclock``:
    the last PACKED stamp, so a tenant returning from idle gets no
    retroactive credit).  A wave packs stamped requests in ascending
    ``(vtag, seq)`` order and STOPS at the first that does not fit the
    remaining panel width.  The invariants that buys
    (property-tested in tests/test_property.py):

    * width bound — a wave never exceeds ``panel_k`` columns;
    * FIFO per tenant — stamps are strictly increasing per tenant;
    * weights honored WITHIN one wave — backlogged tenants' columns
      interleave in proportion to their weights (exactly so for
      unit-width requests);
    * no starvation — a request that does not fit keeps the lowest
      stamp and packs FIRST next wave into a fresh panel (every
      admitted width fits an empty panel, so cross-tenant head-of-line
      blocking costs at most one underfilled wave).

    ``depth`` bounds the queue; :meth:`push` raises
    :class:`Overloaded` when full.  Not thread-safe on its own — the
    server serializes access under its submit lock.
    """

    def __init__(self, panel_k: int, depth: int, weights=None):
        if depth < 1:
            raise ValueError(f"queue depth must be >= 1, got {depth}")
        self.panel_k = panel_k
        self.depth = depth
        self.weights = dict(weights) if weights else {}
        for t, w in self.weights.items():
            if not w > 0:
                raise ValueError(f"tenant {t!r} weight must be > 0, "
                                 f"got {w}")
        self._reqs: list[_Request] = []
        self._vt: dict = {}          # tenant -> last assigned stamp
        self._vclock = 0.0           # stamp of the last packed request

    def __len__(self) -> int:
        return len(self._reqs)

    def weight(self, tenant) -> float:
        return self.weights.get(tenant, 1.0)

    def set_weight(self, tenant, w: float) -> None:
        """Update one tenant's fair-share weight mid-stream.  Applies
        to stamps assigned from now on; already-queued requests keep
        the stamps they were admitted with (no retroactive reshuffle,
        so FIFO-per-tenant holds across the change)."""
        if not w > 0:
            raise ValueError(f"tenant {tenant!r} weight must be > 0, "
                             f"got {w}")
        self.weights[tenant] = w

    def queued_width(self) -> int:
        """Total queued RHS columns (the admission controller's
        queue-backlog signal)."""
        return sum(r.width for r in self._reqs)

    def push(self, req: _Request, *, force: bool = False) -> None:
        """``force=True`` bypasses the depth bound — migration re-keys
        an old bucket's queue into a new one and must strand/shed
        nothing, even when the target queue is momentarily over
        depth (it drains on the next waves)."""
        if not force and len(self._reqs) >= self.depth:
            raise _errors.Overloaded(
                f"slot {req.key} queue full ({self.depth} pending): "
                f"request for tenant {req.tenant!r} shed — back off "
                f"and resubmit")
        start = max(self._vt.get(req.tenant, 0.0), self._vclock)
        req.vtag = start + req.width / self.weight(req.tenant)
        self._vt[req.tenant] = req.vtag
        self._reqs.append(req)

    def _pack_order(self) -> list[tuple[tuple, _Request]]:
        """The pack ordering: each request paired with its effective
        (vtag, seq) sort key, ascending.

        Plain WFQ order is each request's own stamp.  When any queued
        request carries a ``deadline`` (SLO-aware admission), requests
        are reordered WITHIN each tenant's FIFO window by earliest
        deadline first: the multiset of a tenant's stamps is kept —
        so the cross-tenant weighted interleave and the width bound
        are exactly what plain WFQ would produce — but the tenant's
        own requests map onto those stamp slots in EDF order
        (deadline-less requests keep submission order via an infinite
        deadline tiebroken by seq).  Stamps themselves are never
        mutated, so future packs and the vclock stay consistent."""
        if not any(r.deadline is not None for r in self._reqs):
            return sorted(((r.vtag, r.seq), r) for r in self._reqs)
        by_tenant: dict = {}
        for r in self._reqs:
            by_tenant.setdefault(r.tenant, []).append(r)
        paired = []
        inf = float("inf")
        for reqs in by_tenant.values():
            slots = sorted((r.vtag, r.seq) for r in reqs)
            edf = sorted(reqs, key=lambda r: (
                r.deadline if r.deadline is not None else inf, r.seq))
            paired.extend(zip(slots, edf))
        paired.sort(key=lambda kr: kr[0])
        return paired

    def pack(self) -> list[_Request]:
        """Pop one wave: ascending effective (vtag, seq) — see
        :meth:`_pack_order` — stop at first non-fit.  Nonempty queue
        => nonempty wave (every admitted width <= panel_k)."""
        order = self._pack_order()
        width = take = 0
        for _, r in order:
            if width + r.width > self.panel_k:
                break
            width += r.width
            take += 1
        wave = [r for _, r in order[:take]]
        self._reqs = [r for _, r in order[take:]]
        if wave:
            self._vclock = max([self._vclock]
                               + [key[0] for key, _ in order[:take]])
        if not self._reqs:
            # system idle: reset virtual time (standard WFQ), so stamp
            # magnitudes cannot grow without bound across a long run
            self._vt.clear()
            self._vclock = 0.0
        return wave

    def pop_if(self, pred: Callable[[_Request], bool]) -> list[_Request]:
        """Remove and return every queued request matching ``pred``
        (the stranded-request sweep), FIFO order."""
        hit = [r for r in self._reqs if pred(r)]
        if hit:
            self._reqs = [r for r in self._reqs if not pred(r)]
            hit.sort(key=lambda r: r.seq)
        return hit


class AsyncSolveServer:
    """Open-loop async front over a :class:`~repro.core.solver.Solver`
    or :class:`~repro.core.fleet.SolverFleet` (DESIGN.md Sec. 13).

        solver = api.Solver.from_factor(L, grid)
        server = api.AsyncSolveServer(solver, panel_k=16,
                                      queue_depth=64,
                                      slo_ms=50.0).warmup()
        with server:                        # background drain loop
            fut = server.submit(b)          # -> SolveFuture, never waits
            X = fut.result(timeout=30)
        print(server.stats())               # p50/p99, goodput, sheds

    Plain mode addresses bank slots (``factor=``) exactly like
    :class:`SolveServer`; fleet mode (constructed over a
    :class:`SolverFleet`) addresses ``(tenant, order[, tag])`` and
    serves each solution sliced back to its true order.  ``tenant=``
    in plain mode is a fairness label only: tenants sharing a slot
    split its panel by :class:`FairQueue` weights.

    ``step()`` packs + dispatches exactly ONE wave (all live slots,
    one compiled dispatch per bucket) and finalizes waves beyond the
    ``max_inflight`` pipeline depth; the background thread just calls
    ``step`` whenever there is work.  Deterministic tests never call
    :meth:`start` — they drive ``step``/``flush`` by hand under a fake
    clock.
    """

    def __init__(self, solver, panel_k: int = 16, *,
                 queue_depth: int = 64, weights=None, clock=None,
                 slo_ms: float | None = None, max_inflight: int = 2,
                 thread_factory=None, poll_s: float = 0.001,
                 latency_window: int = 8192, admission=None,
                 wave_ewma_alpha: float = 0.25):
        from repro.core.fleet import SolverFleet
        if isinstance(solver, SolveServer):
            raise TypeError(
                "wrap the Solver or SolverFleet directly — "
                "AsyncSolveServer owns its queues and builds its own "
                "wave dispatcher")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got "
                             f"{max_inflight}")
        self.panel_k = panel_k
        self.queue_depth = queue_depth
        self.weights = weights
        self.slo_ms = slo_ms
        self.max_inflight = max_inflight
        self.fleet = solver if isinstance(solver, SolverFleet) else None
        if self.fleet is not None:
            self.solver = None
            self._servers: dict = {}    # bucket key -> wave dispatcher
        else:
            self.solver = solver
            self._server = SolveServer(solver, panel_k)
        self._clock = clock if clock is not None else SystemClock()
        self._now = self._clock.monotonic
        self._poll_s = poll_s
        self._thread_factory = thread_factory if thread_factory \
            is not None else threading.Thread
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        # RLock: an attached Autoscaler applies a migration from
        # inside step() (same thread, lock already held)
        self._step_lock = threading.RLock()
        self._queues: dict[object, FairQueue] = {}
        self._inflight: collections.deque = collections.deque()
        self._seq = 0
        self._thread = None
        self._stop_evt = threading.Event()
        self._drain_on_stop = True
        # control-plane hooks (DESIGN.md Sec. 15): an
        # AdmissionController consulted at submit, an Autoscaler
        # ticked after each step — both optional, both clocked by
        # self._clock only (no wall-clock on the decision path)
        self.admission = admission
        if admission is not None and hasattr(admission, "attach"):
            admission.attach(self)
        self._autoscaler = None
        # live service signal per dispatch unit (bucket key in fleet
        # mode, None in plain mode): EWMA of measured seconds per
        # finalized wave — the admission controller's wait-estimate
        # input once real observations exist (cost-model seed before)
        self.wave_ewma_alpha = wave_ewma_alpha
        self._wave_ewma: dict = {}
        # offered / served columns per dispatch unit (the autoscaler's
        # rate signals; under self._lock / step lock respectively)
        self._offered_cols: collections.Counter = collections.Counter()
        self._served_cols: collections.Counter = collections.Counter()
        # counters (under self._lock unless noted)
        self.submitted = 0
        self.served = 0            # finalized OK (step lock)
        self.shed = 0
        self.stranded = 0
        self.waves = 0             # dispatches (step lock)
        self._latencies: collections.deque = \
            collections.deque(maxlen=latency_window)
        self._slo_violations = 0
        self._tenants: dict[str, dict] = {}   # per-tenant breakdown

    def _tenant_stats(self, tenant: str) -> dict:
        ts = self._tenants.get(tenant)
        if ts is None:
            ts = self._tenants[tenant] = dict(
                submitted=0, served=0, shed=0, deadline_shed=0,
                stranded=0, slo_violations=0)
        return ts

    def _unit(self, key):
        """The dispatch unit a queue key belongs to (bucket key in
        fleet mode, None in plain mode)."""
        return key[0] if self.fleet is not None else None

    def attach_autoscaler(self, autoscaler) -> None:
        """Hook an :class:`~repro.core.control.Autoscaler`: ``step()``
        ticks it after finalization, on the server's injected clock."""
        self._autoscaler = autoscaler

    def set_admission(self, admission) -> None:
        """Install (or remove, with None) the admission controller
        after construction — e.g. only AFTER priming traffic, so
        startup compiles never feed the controller's signals."""
        with self._cond:
            self.admission = admission
        if admission is not None and hasattr(admission, "attach"):
            admission.attach(self)

    def reset_service_ewma(self) -> None:
        """Forget the measured seconds-per-wave signal.  Startup waves
        fold first-compile time into the EWMA; call this when priming
        is done so admission estimates start from the cost-model seed
        and refresh from STEADY-state waves only."""
        with self._cond:
            self._wave_ewma.clear()

    def set_weight(self, tenant: str, w: float) -> None:
        """Update one tenant's fair-share weight across every queue
        (and for queues created later)."""
        if not w > 0:
            raise ValueError(f"tenant {tenant!r} weight must be > 0, "
                             f"got {w}")
        with self._lock:
            self.weights = dict(self.weights or {})
            self.weights[tenant] = w
            for fq in self._queues.values():
                fq.set_weight(tenant, w)

    # ------------------------------ lifecycle ------------------------------

    def warmup(self) -> "AsyncSolveServer":
        """Compile the wave program(s) and pre-build the zero fillers,
        so the first wave — and every wave after it — runs at
        steady-state latency with zero transfers."""
        if self.fleet is not None:
            self.fleet.warmup(self.panel_k)
            for key in self.fleet.buckets:
                srv = self._server_for(key)
                srv._filler(srv.solver.dtype)
        else:
            self.solver.warmup(self.panel_k)
            self._server._filler(self.solver.dtype)
        return self

    def start(self) -> "AsyncSolveServer":
        """Spawn the background drain loop (thread via the injectable
        factory)."""
        if self._thread is not None:
            raise RuntimeError("drain loop already running")
        self._stop_evt.clear()
        self._thread = self._thread_factory(
            target=self._loop, name="async-solve-drain", daemon=True)
        self._thread.start()
        return self

    def stop(self, *, drain: bool = True,
             timeout: float | None = None) -> "AsyncSolveServer":
        """Stop the loop.  ``drain=True`` (default) serves everything
        still queued first, so every outstanding future resolves;
        ``drain=False`` abandons the queues (their futures never
        resolve — use only when tearing the whole process down)."""
        self._drain_on_stop = drain
        self._stop_evt.set()
        with self._cond:
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        if drain:                       # also covers never-started servers
            while self.step():
                pass
            self.flush()
        return self

    def __enter__(self) -> "AsyncSolveServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=True)

    def _loop(self) -> None:
        while True:
            served = self.step()
            if self._stop_evt.is_set():
                if not self._drain_on_stop or not self.pending():
                    break
                continue
            if not served:
                with self._cond:
                    if not self._has_work() \
                            and not self._stop_evt.is_set():
                        self._cond.wait(self._poll_s)
        if self._drain_on_stop:
            while self.step():
                pass
        self.flush()

    # ------------------------------ admission ------------------------------

    def _has_work(self) -> bool:
        return any(len(q) for q in self._queues.values())

    def pending(self) -> int:
        """Queued (not yet dispatched) requests across all slots."""
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def _queue_for(self, key) -> FairQueue:
        fq = self._queues.get(key)
        if fq is None:
            fq = self._queues[key] = FairQueue(
                self.panel_k, self.queue_depth, self.weights)
        return fq

    def _server_for(self, key) -> SolveServer:
        srv = self._servers.get(key)
        if srv is None:
            srv = self._servers[key] = SolveServer(
                self.fleet.solver(key), self.panel_k)
        return srv

    def submit(self, b, factor: int = 0, *, tenant: str = "default",
               tag: object = None) -> SolveFuture:
        """Enqueue one RHS block — (n,) vector or (n, j) columns — and
        return its :class:`SolveFuture`.  Never blocks and never
        dispatches; the drain loop picks the request up on its next
        wave.  Raises :class:`Overloaded` when the slot's queue is
        full (the request is shed), and the same submit-time
        validation errors as :class:`SolveServer` (unknown/inactive
        slot, over-wide request, shape mismatch).  In fleet mode the
        request is addressed by ``(tenant, order[, tag])`` — the RHS
        row count IS the order — and a missing/stale route raises
        ``KeyError`` here, at admission."""
        b = jnp.asarray(b)
        if b.ndim == 1:
            b = jax.lax.expand_dims(b, (1,))
        if b.ndim != 2:
            raise ValueError(f"rhs must be (n, j), got {b.shape}")
        if b.shape[1] > self.panel_k:
            raise ValueError(f"request wider than panel: {b.shape[1]} > "
                             f"{self.panel_k}")
        if self.fleet is not None:
            h = self.fleet.lookup(tenant, order=int(b.shape[0]), tag=tag)
            bank = self.fleet.bucket(h.bucket).bank
            n_b = h.bucket[0]
            b = jnp.asarray(b, self.fleet.solver(h.bucket).dtype)
            if b.shape[0] < n_b:
                b = jnp.pad(b, ((0, n_b - b.shape[0]), (0, 0)))
            key, gen, order = (h.bucket, h.slot), h.generation, h.order
        else:
            if tag is not None:
                raise ValueError("tag= addressing needs a fleet server "
                                 "(AsyncSolveServer(SolverFleet, ...))")
            if not 0 <= factor < self.solver.width:
                raise ValueError(f"unknown factor {factor}; bank holds "
                                 f"{self.solver.width}")
            bank = self.solver.bank
            if not bank.is_live(factor):
                raise ValueError(
                    f"inactive slot {factor}: evicted or never admitted "
                    f"(live slots: {list(self.solver.live_slots())})")
            if b.shape[0] != self.solver.n:
                raise ValueError(f"rhs must be ({self.solver.n}, j), "
                                 f"got {b.shape}")
            b = jnp.asarray(b, self.solver.dtype)
            key, order = factor, int(b.shape[0])
            gen = bank.slot_generation(factor)
        with self._cond:
            now = self._now()
            future = SolveFuture(tenant=tenant, tag=tag, factor=key,
                                 order=order, width=int(b.shape[1]),
                                 arrival=now)
            req = _Request(seq=self._seq, b=b, width=int(b.shape[1]),
                           tenant=tenant, key=key, gen=gen, order=order,
                           future=future)
            if self.admission is not None:
                # SLO-aware admission (DESIGN.md Sec. 15): the
                # controller stamps req.deadline, or sheds by raising
                # DeadlineUnmeetable — which surfaces ONLY through
                # the future (submit still returns a handle)
                try:
                    self.admission.admit(self, key, req, now)
                except _errors.DeadlineUnmeetable as e:
                    self.shed += 1
                    ts = self._tenant_stats(tenant)
                    ts["shed"] += 1
                    ts["deadline_shed"] += 1
                    future._fail(e, now)
                    return future
            try:
                self._queue_for(key).push(req)
            except _errors.Overloaded:
                self.shed += 1
                self._tenant_stats(tenant)["shed"] += 1
                raise
            self._seq += 1
            self.submitted += 1
            self._tenant_stats(tenant)["submitted"] += 1
            self._offered_cols[self._unit(key)] += req.width
            self._cond.notify()
        return future

    # ------------------------------ the loop ------------------------------

    def _generation(self, key) -> tuple[bool, int]:
        """(live, current generation) for a queue key, either mode."""
        if self.fleet is not None:
            bucket, slot = key
            try:
                bank = self.fleet.bucket(bucket).bank
            except KeyError:
                return False, -1     # bucket closed by a replan
            return bank.is_live(slot), bank.slot_generation(slot)
        return self.solver.bank.is_live(key), \
            self.solver.bank.slot_generation(key)

    def _fail_stranded(self, key, fq: FairQueue, now: float) -> None:
        live, gen = self._generation(key)
        stale = fq.pop_if(lambda r: not live or r.gen != gen)
        for r in stale:
            self.stranded += 1
            self._tenant_stats(r.tenant)["stranded"] += 1
            r.future._fail(_errors.StrandedRequestError(
                f"slot {key} evicted after submission (generation "
                f"{r.gen} -> {gen}, live={live}); the request would "
                f"be served against the slot's new occupant — "
                f"resubmit against a live factor"), now)

    def step(self) -> int:
        """Pack and dispatch ONE wave across all slots with queued
        work, then finalize waves beyond the pipeline depth; with no
        work, finalize everything in flight.  Returns the number of
        requests dispatched (0 = idle).  The background loop calls
        this; deterministic tests call it directly."""
        with self._step_lock:
            now = self._now()
            with self._lock:
                waves: dict = {}
                for key, fq in list(self._queues.items()):
                    self._fail_stranded(key, fq, now)
                    if len(fq):
                        wave = fq.pack()
                        if wave:
                            waves[key] = wave
            if not waves:
                self._finalize(all_waves=True)
                if self._autoscaler is not None:
                    self._autoscaler.tick()
                return 0
            dispatched = self._dispatch(waves)
            self._finalize(all_waves=False)
            if self._autoscaler is not None:
                self._autoscaler.tick()
            return dispatched

    def flush(self) -> None:
        """Finalize every in-flight wave (resolve its futures)."""
        with self._step_lock:
            self._finalize(all_waves=True)

    def _dispatch(self, waves: dict) -> int:
        """One compiled dispatch per dispatch unit (the whole bank in
        plain mode; per bucket in fleet mode); futures join the
        in-flight pipeline with their lazy outputs."""
        units: dict = {}             # dispatcher -> {slot: [req, ...]}
        for key, wave in waves.items():
            if self.fleet is not None:
                bucket, slot = key
                units.setdefault(self._server_for(bucket), {})[slot] = \
                    wave
            else:
                units.setdefault(self._server, {})[key] = wave
        now = self._now()
        pairs: list = []
        total = 0
        for srv, unit in units.items():
            by_seq = {r.seq: r for wave in unit.values() for r in wave}
            try:
                out = srv._solve_wave(
                    {slot: [(r.seq, r.b) for r in wave]
                     for slot, wave in unit.items()})
            except Exception as e:       # surface through the futures,
                for r in by_seq.values():     # never hang the loop
                    r.future._fail(e, now)
                continue
            self.waves += 1
            for xs in out.values():
                for seq, X in xs:
                    r = by_seq[seq]
                    if r.order < X.shape[0]:    # fleet: slice the
                        X = static_slice(       # padded tail back off
                            (0, 0), (r.order, r.width))(X)
                    r.future.dispatched = now
                    pairs.append((r, X))
                    total += 1
        if pairs:
            self._inflight.append(pairs)
        while len(self._inflight) > self.max_inflight:
            self._finalize_one()
        return total

    def _finalize(self, *, all_waves: bool) -> None:
        limit = 0 if all_waves else self.max_inflight - 1
        while len(self._inflight) > limit:
            self._finalize_one()

    def _finalize_one(self) -> None:
        pairs = self._inflight.popleft()
        jax.block_until_ready([X for _, X in pairs])
        now = self._now()
        units_seen = set()
        for r, X in pairs:
            r.future._resolve(X, now)
            self.served += 1
            ts = self._tenant_stats(r.tenant)
            ts["served"] += 1
            lat = r.future.latency()
            self._latencies.append(lat)
            if self.slo_ms is not None and lat * 1e3 > self.slo_ms:
                self._slo_violations += 1
                ts["slo_violations"] += 1
            unit = self._unit(r.key)
            self._served_cols[unit] += r.width
            # measured seconds per wave for this dispatch unit (one
            # sample per unit per finalized wave): the live service
            # signal wait estimation and autoscaling run on
            if unit not in units_seen and r.future.dispatched \
                    is not None:
                units_seen.add(unit)
                s = now - r.future.dispatched
                prev = self._wave_ewma.get(unit)
                a = self.wave_ewma_alpha
                self._wave_ewma[unit] = s if prev is None \
                    else (1 - a) * prev + a * s

    # ------------------------------- stats -------------------------------

    def stats(self) -> dict:
        """Serving counters + the latency distribution of the last
        ``latency_window`` completed requests: submitted / served /
        shed / stranded / waves / pending / inflight, p50/p99/max
        latency (ms), the violation count when an SLO was set, and the
        per-tenant breakdown under ``"tenants"`` (submitted / served /
        shed / deadline_shed / stranded / slo_violations each).

        Empty-window contract: with NO completed request in the
        window, every percentile field (``p50_ms`` / ``p99_ms`` /
        ``max_ms``) is ``None`` — never ``0.0``, which a scraper would
        read as an (excellent) measurement instead of an absence."""
        with self._lock:
            pending = sum(len(q) for q in self._queues.values())
            lat = sorted(self._latencies)
            tenants = {t: dict(ts) for t, ts in self._tenants.items()}
        def pct(q: float) -> float | None:
            if not lat:
                return None
            return lat[min(len(lat) - 1, int(q * len(lat)))] * 1e3
        return dict(
            submitted=self.submitted, served=self.served,
            shed=self.shed, stranded=self.stranded, waves=self.waves,
            pending=pending, inflight=len(self._inflight),
            queue_depth=self.queue_depth,
            p50_ms=pct(0.50), p99_ms=pct(0.99),
            max_ms=lat[-1] * 1e3 if lat else None,
            slo_ms=self.slo_ms, slo_violations=self._slo_violations,
            tenants=tenants)

    # ------------------------- migration support -------------------------

    def rekey_queue(self, old_handle, new_handle) -> int:
        """Live-migration hook (DESIGN.md Sec. 15): move every queued
        request addressed at ``old_handle``'s (bucket, slot) onto
        ``new_handle``'s, re-padding the staged RHS to the new bucket
        order and re-stamping the generation — so a fleet replan
        strands NOTHING.  Caller (the Autoscaler's apply path) holds
        the step lock; this takes the submit lock itself.  Returns the
        number of requests moved."""
        if self.fleet is None:
            raise ValueError("rekey_queue is fleet-mode only")
        old_key = (old_handle.bucket, old_handle.slot)
        new_key = (new_handle.bucket, new_handle.slot)
        n_old, n_new = old_handle.bucket[0], new_handle.bucket[0]
        with self._cond:
            fq = self._queues.get(old_key)
            if fq is None:
                return 0
            moved = fq.pop_if(lambda r: True)
            target = self._queue_for(new_key)
            for r in moved:
                if n_new > n_old:
                    # grow from the dispatcher's cached zero filler
                    # (device-resident) — not jnp.pad, whose constant
                    # fill value is a host->device upload
                    filler = self._server_for(new_handle.bucket) \
                        ._filler(r.b.dtype)
                    r.b = jnp.concatenate(
                        [r.b, static_slice((0, 0),
                                           (n_new - n_old, r.width))
                         (filler)], axis=0)
                elif n_new < n_old:
                    # rows past the true order are the admit-time zero
                    # padding; the narrower bucket keeps >= order rows
                    r.b = static_slice((0, 0), (n_new, r.width))(r.b)
                r.key = new_key
                r.gen = new_handle.generation
                r.future.factor = new_key
                target.push(r, force=True)
            if not len(fq):
                self._queues.pop(old_key, None)
            if moved:
                self._cond.notify()
        return len(moved)

    def drop_dispatch_unit(self, bucket_key) -> None:
        """Forget the wave dispatcher and any empty queues of a bucket
        the fleet closed on migration (stale queues would re-create
        phantom slots on the next sweep)."""
        self._servers.pop(bucket_key, None)
        with self._lock:
            for key in [k for k in self._queues
                        if k[0] == bucket_key and not len(
                            self._queues[k])]:
                self._queues.pop(key)
