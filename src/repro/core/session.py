"""Device-resident solve pipeline: compiled-solver cache + TrsmSession.

The paper's algorithms avoid *inter-processor* communication; this
module removes the remaining *host* communication from the end-to-end
entry points.  Historically every ``core.trsm`` call copied L/B to host
NumPy, permuted to cyclic storage on the CPU, re-uploaded, and re-traced
the shard_map program — a round-trip that dwarfs the collectives the
algorithm saves.  ScaLAPACK-style practice keeps factors resident in
distributed block-cyclic storage; this module does the same:

* ``CompiledSolverCache`` — an LRU of compiled solve programs keyed by
  :class:`repro.core.solver.SolveSpec` (the frozen declarative solve
  description; the SOLE key type — see DESIGN.md Sec. 10).  Each
  program fuses, in ONE jitted computation: the
  on-device cyclic permutation of B (with the upper/transpose reversal
  identity folded into the gather), the shard_map solver, the inverse
  permutation of X back to natural layout, and — when the precision
  policy refines — the fixed-trip iterative-refinement loop
  (``repro.core.refine``).  B's buffer is donated in the serving
  variant.
* ``TrsmSession`` — DEPRECATED shim over
  :class:`repro.core.solver.Solver` (``Solver.from_factor``): one
  resident factor served with zero steady-state host<->device
  transfers and zero retraces FOR EVERY PRECISION POLICY (asserted in
  tests via :data:`TRACE_COUNTS` and ``jax.transfer_guard``).  New
  code uses ``repro.api``.

Precision (DESIGN.md Sec. 7): a :class:`repro.core.precision
.PrecisionPolicy` splits the pipeline's dtypes into storage / compute /
accumulate / residual roles.  The factor is cast ONCE at distribution
time — to the storage dtype for the sweep and, when the policy refines,
additionally to the residual dtype for the on-device residual GEMM —
and the refinement loop is unrolled into the same compiled program, so
a ``bf16_refine`` session serves fp32-accurate solves with bf16 (MXU
native) sweep GEMMs and no extra host traffic.

Operator reductions (DESIGN.md Sec. 3), folded into distribution-time
gathers so the sweep only ever sees a lower-triangular operand:
    lower, op(L)=L      : Leff = L
    upper, op(U)=U      : Leff = JUJ   (reverse rows+cols), B/X reversed
    lower, op(L)=L^T    : Leff = J L^T J (transpose+reverse), B/X reversed
    upper, op(U)=U^T    : Leff = U^T  (transpose only)
i.e. transpose <=> ``transpose`` flag, reversal <=> ``lower ==
transpose``.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import threading
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import grid as gridlib
from repro.core import precision as preclib
from repro.core import refine as refinelib
from repro.core.grid import TrsmGrid
from repro.core.precision import PrecisionPolicy

# Retrace telemetry: bumped at *trace time* of each cached program, so a
# test can assert steady-state solves never re-trace (key -> count).
# Refined programs bump ONCE per trace, not once per inner sweep.
TRACE_COUNTS: collections.Counter = collections.Counter()


def _needs_reversal(lower: bool, transpose: bool) -> bool:
    return lower == transpose


@dataclasses.dataclass(frozen=True)
class SolverProgram:
    """A compiled (prep, solve) pair for one solve configuration.

    ``prep(L_nat) -> factor`` distributes the factor once: an on-device
    gather to cyclic storage with the operator reduction folded in,
    cast to the policy's storage dtype — plus a second, residual-dtype
    copy when the policy refines.  The result is an opaque tuple;
    treat it as the token that ``solve`` consumes.

    ``solve(factor, B_nat) -> X_nat`` is the steady-state program:
    B-permute -> sweep -> X-unpermute, with the policy's refinement
    passes unrolled inside.  ``solve_donating`` additionally donates
    B's buffer (serving path — the caller must not reuse B afterwards).

    ``rhs_sharding`` is the pinned natural-layout placement of B (and
    of the returned X): requests placed there up front (``jax.device_put``
    — see ``TrsmSession.place_rhs``) enter the program with no input
    resharding at all, so the steady state is literally transfer-free.

    Remaining fields record the resolved plan: ``method`` ("inv"/"rec"),
    ``mode`` (the inv phase-1 scheme), ``n0`` (diagonal-block size) and
    ``policy`` (the :class:`PrecisionPolicy` the program was built for).
    """
    key: object                  # the program's SolveSpec (cache key)
    prep: Callable
    solve: Callable
    solve_donating: Callable
    rhs_sharding: object
    method: str
    mode: str | None
    n0: int | None
    policy: PrecisionPolicy


class CompiledSolverCache:
    """LRU cache of :class:`SolverProgram`s, keyed by
    :class:`repro.core.solver.SolveSpec` — the sole key type.

    A spec carries everything that changes the compiled artifact (the
    solve shape, plan, operator variant, precision policy, grid/mesh
    identity, bank width and map mode — the field-by-field table is
    DESIGN.md Sec. 10), so two call sites that build equal specs share
    one compiled program and nothing can be left out of the key by
    accident.  The positional-tuple keys of PRs 1-3 are gone;
    ``get`` rejects non-spec keys.

    Thread-safe; eviction drops the jitted callables (XLA frees the
    executables with them).  Builds are single-flight per key: when two
    threads miss the same spec concurrently, exactly one runs
    ``build()`` (a trace/compile can take minutes) and the other waits
    for the finished program — one miss per build, a hit for every
    waiter, so the counters stay meaningful under contention.
    """

    def __init__(self, maxsize: int = 32):
        self.maxsize = maxsize
        self._lock = threading.Lock()
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self._inflight: dict = {}          # key -> Event of the builder
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key, build: Callable):
        from repro.core.solver import SolveSpec, UpdateSpec
        if not isinstance(key, (SolveSpec, UpdateSpec)):
            raise TypeError(
                f"CompiledSolverCache keys are SolveSpec (or UpdateSpec)"
                f" instances, got {type(key).__name__} (positional-tuple"
                f" keys were removed; build a spec via "
                f"repro.api.SolveSpec)")
        while True:
            with self._lock:
                if key in self._entries:
                    self._entries.move_to_end(key)
                    self.hits += 1
                    return self._entries[key]
                event = self._inflight.get(key)
                if event is None:          # we are the builder
                    event = threading.Event()
                    self._inflight[key] = event
                    self.misses += 1
                    break
            # another thread is building this key: wait for it, then
            # re-check (the entry is there on success; on a failed
            # build the waiter loops around and becomes the builder)
            event.wait()
        try:
            value = build()      # build outside the lock (tracing is slow)
        except BaseException:
            with self._lock:
                self._inflight.pop(key, None)
            event.set()
            raise
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
                self.evictions += 1
            self._inflight.pop(key, None)
        event.set()
        return value

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries

    def stats(self) -> dict:
        """Observability snapshot: size/hits/misses/evictions plus the
        derived hit rate (surfaced by ``launch.serve --cache-stats``
        and recorded by benchmarks/bench_serve_latency.py)."""
        with self._lock:
            total = self.hits + self.misses
            return dict(size=len(self._entries), hits=self.hits,
                        misses=self.misses, evictions=self.evictions,
                        hit_rate=self.hits / total if total else 0.0)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = self.misses = self.evictions = 0


_DEFAULT_CACHE = CompiledSolverCache()


def default_cache() -> CompiledSolverCache:
    """The process-wide program cache used by ``core.trsm`` and every
    session that does not pass an explicit ``cache=``."""
    return _DEFAULT_CACHE


# ------------------------- program construction -------------------------

@functools.lru_cache(maxsize=128)
def _build_prep(grid: TrsmGrid, lower: bool, transpose: bool, dtype,
                stacked: bool = False, structure=None,
                n0: int | None = None):
    """Jitted L_nat -> L_cyc distribution (shared by both methods: rec
    and inv use the same P("x", ("z","y")) factor layout).  Memoized on
    its full key — including the target dtype, so a refining policy's
    storage- and residual-precision copies are two entries — and every
    RHS width and every session for the same configuration reuses one
    traced program.  ``stacked`` builds the factor-bank variant: the
    SAME fused gather applied to an (M, n, n) stack in one program
    (grid.cyclic_matrix_device permutes the trailing two axes), output
    sharded P(None, "x", ("z","y")).

    A non-dense ``structure`` (with its serving block size ``n0`` —
    both join the memo key) ENFORCES the declared block structure at
    admission: every element outside the block mask is zeroed (in
    natural layout, before the gather), which is what makes the
    level-scheduled sweep's skipped blocks mathematically safe
    (DESIGN.md Sec. 14)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.structure import apply_block_mask
    p1, p2 = grid.p1, grid.p2
    rev = _needs_reversal(lower, transpose)

    def prep(L):
        L = jnp.asarray(L, dtype)
        if structure is not None and not structure.is_dense:
            L = apply_block_mask(L, structure, n0)
        return gridlib.cyclic_matrix_device(
            L, p1, p1 * p2, reverse_rows=rev, reverse_cols=rev,
            transpose=transpose)

    spec = P(None, *grid.spec_L()) if stacked else grid.spec_L()
    return jax.jit(prep, out_shardings=NamedSharding(grid.mesh, spec))


def _factor_preps(grid: TrsmGrid, lower: bool, transpose: bool,
                  policy: PrecisionPolicy, stacked: bool = False,
                  structure=None, n0: int | None = None) -> tuple:
    """The (storage[, residual]) distribution programs for a policy.
    Both copies mask to ``structure``: the refinement residual must see
    the same (masked) operator the sweep solves against."""
    preps = (_build_prep(grid, lower, transpose, policy.storage_dtype,
                         stacked, structure, n0),)
    if policy.refines:
        preps += (_build_prep(grid, lower, transpose,
                              policy.residual_dtype, stacked,
                              structure, n0),)
    return preps


@functools.lru_cache(maxsize=128)
def _build_phase1(grid: TrsmGrid, n: int, n0: int, mode: str,
                  accum, block_inv, stacked: bool = False):
    """Jitted phase-1 program L_cyc -> Dt (the inverted diagonal
    faces), shared by factor-bank admission and banked-program prep.
    ``stacked`` maps it over a leading factor axis (one program inverts
    a whole (M, n, n) stack's diagonal blocks)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import inv_trsm
    prog = inv_trsm.it_inv_phase1_sharded(
        grid, n, n0, mode=mode,
        accum_dtype=jnp.dtype(accum) if accum is not None else None,
        block_inv=block_inv)
    fn = jax.vmap(prog) if stacked else prog
    spec = P(None, *inv_trsm.SPEC_DT) if stacked else inv_trsm.SPEC_DT
    return jax.jit(fn, out_shardings=NamedSharding(grid.mesh, spec))


def _check_policy_supported(policy: PrecisionPolicy) -> None:
    for role in (policy.storage_dtype, policy.compute_dtype,
                 policy.accumulate_dtype, policy.residual_dtype):
        if role == jnp.dtype("float64") and \
                jax.dtypes.canonicalize_dtype(jnp.float64) != jnp.float64:
            raise ValueError(
                f"precision policy {policy.name!r} needs float64; enable "
                f"jax_enable_x64 (jax.config.update('jax_enable_x64', "
                f"True)) before building the solver")


def _build_solver(spec) -> SolverProgram:
    """Build the compiled (prep, solve) program pair for a concrete
    :class:`repro.core.solver.SolveSpec` (which is also the program's
    cache key and TRACE_COUNTS key)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    grid, key = spec.grid, spec
    n, k, n0 = spec.n, spec.k, spec.n0
    policy, method, mode = spec.policy, spec.method, spec.mode
    lower, transpose = spec.lower, spec.transpose
    block_inv = spec.block_inv
    bank, map_mode = spec.bank_width, spec.map_mode or "vmap"
    p1, p2 = grid.p1, grid.p2
    rev = _needs_reversal(lower, transpose)
    compute = policy.compute_dtype
    accum = policy.accumulate_dtype

    # Batched-bank programs map ONLY the cyclic-storage sweep over the
    # leading factor axis ("vmap": every sweep step is an M-wide
    # batched GEMM; "scan": factors serialized inside the same single
    # program, memory-lean for large banks).  Everything around the
    # sweep stays stack-level: the B-permute / X-unpermute are per-axis
    # row permutations IDENTICAL across factors, so they run as ONE
    # batched gather for the whole (M, n, k) stack, and the refinement
    # residual is one batched GEMM between two such gathers
    # (apply_cyclic_operator on stacked operands).
    def _map_factors(fn):
        if map_mode == "vmap":
            return jax.vmap(fn)

        def scanned(*stacks):
            return jax.lax.scan(lambda c, xs: (c, fn(*xs)), None,
                                stacks)[1]
        return scanned

    prefactored = bank is not None and method == "inv"
    if method == "inv":
        from repro.core import inv_trsm
        resolved_mode = mode or inv_trsm.pick_phase1_mode(n, n0, grid)
        # natural-B placement: columns over z (matching spec_B), rows
        # replicated so the row-permutation gather is shard-local.
        rhs_spec = P(None, "z")

        if prefactored:
            # Banked steady state: the diagonal-block inversion was
            # hoisted to admission (the factor is immutable), so the
            # program is the sweep alone against the resident Dt —
            # unrolled, so mapping over factors yields straight-line
            # batched GEMMs (DESIGN.md Sec. 9).  Unrolling is capped:
            # a factor order with no good power-of-two divisor can pin
            # n0 = 1, and a straight-line m = n sweep would blow up
            # trace/compile time — past the cap the sweep keeps its
            # fori_loop (still one mapped program).  A non-dense
            # structure compiles the LEVEL-SCHEDULED sweep (static
            # skip/slice decisions per block column, DESIGN.md
            # Sec. 14), which needs the unroll and overrides the cap.
            sweep = _map_factors(inv_trsm.it_inv_sweep_sharded(
                grid, n, k, n0, accum_dtype=accum,
                unroll=(n // n0) <= 64, structure=spec.structure,
                overlap=spec.overlap == "on"))

            def base_solve(L_pair, B):
                B_cyc = gridlib.cyclic_rows_device(
                    jnp.asarray(B, compute), p1, reverse=rev)
                X_cyc = sweep(L_pair[0], L_pair[1], B_cyc)
                return gridlib.cyclic_rows_device(X_cyc, p1, inverse=True,
                                                  reverse=rev)
        else:
            sharded = inv_trsm.it_inv_trsm_sharded(grid, n, k, n0,
                                                   block_inv=block_inv,
                                                   mode=resolved_mode,
                                                   accum_dtype=accum,
                                                   overlap=spec.overlap
                                                   == "on")

            def base_solve(L_cyc, B):
                B_cyc = gridlib.cyclic_rows_device(
                    jnp.asarray(B, compute), p1, reverse=rev)
                X_cyc = sharded(L_cyc, B_cyc)
                return gridlib.cyclic_rows_device(X_cyc, p1, inverse=True,
                                                  reverse=rev)
    elif method == "rec":
        from repro.core import rec_trsm
        resolved_mode = None
        sharded = rec_trsm.rec_trsm_sharded(grid, n, k, n0,
                                            accum_dtype=accum,
                                            overlap=spec.overlap == "on")
        if bank is not None:
            sharded = _map_factors(sharded)
        rhs_spec = P(None, ("z", "y"))

        def base_solve(L_cyc, B):
            B_cyc = gridlib.cyclic_matrix_device(
                jnp.asarray(B, compute), p1, p1 * p2, reverse_rows=rev)
            X_cyc = sharded(L_cyc, B_cyc)
            return gridlib.cyclic_matrix_device(
                X_cyc, p1, p1 * p2, inverse=True, reverse_rows=rev)
    else:
        raise ValueError(f"unknown method {method!r}")

    # Factor tuple layout (flat, shardable): (L_lo[, Dt][, L_hi]) — Dt
    # present only for prefactored (banked inv) programs, where the
    # sweep operand is the (L_lo, Dt) pair.  The refinement loop is
    # dimension-agnostic, so the SAME body serves single factors and
    # whole banks.
    def split(factor):
        L_sweep = (factor[0], factor[1]) if prefactored else factor[0]
        L_hi = factor[-1] if policy.refines else None
        return L_sweep, L_hi

    def program(factor, B):
        TRACE_COUNTS[key] += 1
        L_sweep, L_hi = split(factor)
        return refinelib.refined_solve(base_solve, L_sweep, L_hi, B,
                                       policy=policy, p1=p1, p2=p2,
                                       reverse=rev)

    stacked = bank is not None
    preps = _factor_preps(grid, lower, transpose, policy, stacked,
                          spec.structure, n0)
    if prefactored:
        ph1 = _build_phase1(grid, n, n0, resolved_mode, accum, block_inv,
                            stacked)

        def prep_fn(L):
            parts = tuple(p(L) for p in preps)     # (L_lo[, L_hi])
            return (parts[0], ph1(parts[0])) + parts[1:]
    else:
        def prep_fn(L):
            return tuple(p(L) for p in preps)

    def _lead(spec):
        return P(None, *spec) if stacked else spec

    factor_specs = [_lead(grid.spec_L())]
    if prefactored:
        from repro.core.inv_trsm import SPEC_DT
        factor_specs.append(_lead(SPEC_DT))
    if policy.refines:
        factor_specs.append(_lead(grid.spec_L()))
    factor_sh = tuple(NamedSharding(grid.mesh, s) for s in factor_specs)
    rhs_sh = NamedSharding(grid.mesh, _lead(rhs_spec))
    jit_kw = dict(in_shardings=(factor_sh, rhs_sh),
                  out_shardings=rhs_sh)
    return SolverProgram(
        key=key,
        prep=prep_fn,
        solve=jax.jit(program, **jit_kw),
        solve_donating=jax.jit(program, donate_argnums=(1,), **jit_kw),
        rhs_sharding=rhs_sh,
        method=method, mode=resolved_mode, n0=n0, policy=policy)


@dataclasses.dataclass(frozen=True)
class UpdaterProgram:
    """A compiled in-place bank updater for one
    :class:`repro.core.solver.UpdateSpec` (DESIGN.md Sec. 11).

    ``update(stacks, slot, L) -> stacks`` is ONE jitted program that
    re-runs the admission pipeline for a single factor — the fused
    distribution gather (operator reductions + policy dtype casts
    folded in; skipped for cyclic ingestion) and, for method "inv",
    the hoisted phase-1 diagonal-block inversion — and scatters every
    factor role (L_lo[, Dt][, L_hi]) into the resident (C, ...) stacks
    at ``slot`` via ``lax.dynamic_update_index_in_dim``.  The stacks
    argument is DONATED: XLA updates the resident buffers in place, so
    a replace moves one factor's worth of data, never the bank's.

    ``slot`` must be a device-resident int32 scalar (the bank pins one
    per slot at capacity allocation) so the steady-state churn path
    performs zero host->device transfers.
    """
    key: object                  # the program's UpdateSpec (cache key)
    update: Callable


def _build_updater(uspec) -> UpdaterProgram:
    """Build the compiled in-place updater for an
    :class:`repro.core.solver.UpdateSpec` (which is also its cache key
    and TRACE_COUNTS key)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    grid, key = uspec.grid, uspec
    policy = uspec.policy
    prefactored = uspec.method == "inv"
    chunked = uspec.chunk > 1
    if uspec.ingest == "natural":
        preps = _factor_preps(grid, uspec.lower, uspec.transpose, policy,
                              stacked=chunked,
                              structure=uspec.structure, n0=uspec.n0)
    if prefactored:
        ph1 = _build_phase1(grid, uspec.n, uspec.n0, uspec.mode,
                            policy.accumulate_dtype, uspec.block_inv,
                            stacked=chunked)

    def _pad(L):
        # blockdiag(L, I) at the bucket order: the padded tail rows are
        # e_i rows, so they solve to the (zero) padded RHS rows exactly,
        # and the zero coupling blocks keep the leading d x k solution
        # bit-identical to the unpadded order-d sweep (same n0).
        d, n = uspec.pad_from, uspec.n
        tail = jnp.arange(d, n)
        full = jnp.zeros((n, n), L.dtype).at[:d, :d].set(L)
        return full.at[tail, tail].set(jnp.ones((), L.dtype))

    def roles(L):
        if uspec.pad_from is not None:
            L = jax.vmap(_pad)(L) if chunked else _pad(L)
        if uspec.ingest == "natural":
            parts = tuple(p(L) for p in preps)         # (L_lo[, L_hi])
        else:                                          # cyclic: cast only
            dts = (policy.storage_dtype,)
            if policy.refines:
                dts += (policy.residual_dtype,)
            parts = tuple(jnp.asarray(L, dt) for dt in dts)
        if prefactored:
            parts = (parts[0], ph1(parts[0])) + parts[1:]
        return parts

    def update(stacks, slot, L):
        TRACE_COUNTS[key] += 1
        if chunked:                       # contiguous run of slots
            return tuple(
                jax.lax.dynamic_update_slice_in_dim(s, r, slot, axis=0)
                for s, r in zip(stacks, roles(L)))
        return tuple(jax.lax.dynamic_update_index_in_dim(s, r, slot, 0)
                     for s, r in zip(stacks, roles(L)))

    specs = [grid.spec_L()]
    if prefactored:
        from repro.core.inv_trsm import SPEC_DT
        specs.append(SPEC_DT)
    if policy.refines:
        specs.append(grid.spec_L())
    stack_sh = tuple(NamedSharding(grid.mesh, P(None, *s)) for s in specs)
    return UpdaterProgram(
        key=key,
        update=jax.jit(update, donate_argnums=(0,),
                       out_shardings=stack_sh))


def resolve_plan(grid: TrsmGrid, n: int, k: int, *, method: str = "inv",
                 n0: int | None = None, machine=None):
    """Host-side (pure arithmetic) resolution of method/n0 so the cache
    key is concrete.  Delegates to the ONE resolution path,
    :func:`repro.core.solver.resolve_plan` (the former
    ``resolve_plan`` / ``tuning.tune`` / ``choose_method`` overlap,
    folded)."""
    from repro.core import solver as solverlib
    return solverlib.resolve_plan(grid, n, k, method=method, n0=n0,
                                  machine=machine)


def get_solver(grid: TrsmGrid, *, n: int, k: int, dtype=None,
               method: str = "inv", n0: int | None = None,
               mode: str | None = None, lower: bool = True,
               transpose: bool = False, machine=None,
               block_inv: Callable | None = None,
               precision=None,
               bank: int | None = None, map_mode: str = "vmap",
               cache: CompiledSolverCache | None = None) -> SolverProgram:
    """Fetch (or build) the compiled solve program for a configuration.

    ``precision`` accepts a preset name (``"fp32"``, ``"bf16"``,
    ``"bf16_refine"``, ``"fp64_refine"``) or a
    :class:`~repro.core.precision.PrecisionPolicy`; when omitted, the
    uniform single-dtype policy at ``dtype`` is used (the legacy
    pipeline).  Exactly one of ``precision`` / ``dtype`` is required.

    ``bank`` requests the BATCHED program over a stack of M factors
    (``repro.core.bank.FactorBank``): ``factor`` becomes a tuple of
    (M, n, n) stacks and B an (M, n, k) stack, solved in one dispatch
    by mapping the per-factor body over the leading axis with
    ``map_mode`` ("vmap" | "scan", see DESIGN.md Sec. 9).  The bank
    width (and map mode) join the cache key: banks of different widths
    are different compiled artifacts, while every same-width bank of
    the same configuration shares one program.
    """
    from repro.core import solver as solverlib
    if bank is not None and bank < 1:
        raise ValueError(f"bank width must be >= 1, got {bank}")
    if map_mode not in ("vmap", "scan"):
        raise ValueError(f"unknown map_mode {map_mode!r}")
    method, n0 = resolve_plan(grid, n, k, method=method, n0=n0,
                              machine=machine)
    spec = solverlib.SolveSpec(
        n=n, k=k, grid=grid, policy=preclib.resolve(precision, dtype),
        method=method, n0=n0, mode=mode, lower=lower,
        transpose=transpose, block_inv=block_inv, bank_width=bank,
        map_mode=map_mode if bank is not None else None)
    return solverlib.solver_for(spec, cache)


# ------------------------------ sessions ------------------------------

class TrsmSession:
    """DEPRECATED single-factor serving session — a thin shim over
    :meth:`repro.core.solver.Solver.from_factor` (a width-1 factor
    bank), kept for source compatibility; results are bit-identical to
    the :class:`~repro.core.solver.Solver` path.

    The contract is unchanged (the "cyclic-storage contract", see
    ROADMAP.md and DESIGN.md Secs. 4-5, 10): the factor is distributed
    ONCE at construction, never touches the host again, and ``solve``
    runs one compiled program per RHS shape with zero steady-state
    host<->device transfers and zero retraces for every precision
    policy.  New code:

        solver = repro.api.Solver.from_factor(L, grid, n0=16)
        X = solver.solve(B)
    """

    def __init__(self, L, grid: TrsmGrid, *, method: str = "inv",
                 n0: int | None = None, mode: str | None = None,
                 lower: bool = True, transpose: bool = False,
                 machine=None, block_inv: Callable | None = None,
                 dtype=None, precision=None,
                 cache: CompiledSolverCache | None = None):
        from repro.core import solver as solverlib
        solverlib._warn_deprecated("TrsmSession", "Solver.from_factor")
        with solverlib._shim_quiet():
            self._solver = solverlib.Solver.from_factor(
                L, grid, method=method, n0=n0, mode=mode, lower=lower,
                transpose=transpose, machine=machine,
                block_inv=block_inv, dtype=dtype, precision=precision,
                cache=cache)

    @classmethod
    def _wrap(cls, solver) -> "TrsmSession":
        self = object.__new__(cls)
        self._solver = solver
        return self

    # ------------- former attributes, read off the Solver -------------

    @property
    def n(self) -> int:
        return self._solver.n

    @property
    def grid(self) -> TrsmGrid:
        return self._solver.grid

    @property
    def policy(self) -> PrecisionPolicy:
        return self._solver.policy

    @property
    def dtype(self):
        return self._solver.dtype

    @property
    def method(self) -> str:
        return self._solver.method

    @property
    def n0(self) -> int | None:
        return self._solver.n0

    @property
    def mode(self) -> str | None:
        return self._solver.bank.mode

    @property
    def cache(self) -> CompiledSolverCache:
        return self._solver.cache

    @property
    def solves_served(self) -> int:
        return self._solver.solves_served

    @property
    def factor_cyclic(self):
        """The resident sweep factor (cyclic storage, storage dtype)."""
        return self._solver.bank.factors_cyclic[0]

    @property
    def factor_cyclic_residual(self):
        """The residual-precision resident copy (None unless the
        policy refines)."""
        res = self._solver.bank.factors_cyclic_residual
        return None if res is None else res[0]

    def program_for(self, k: int) -> SolverProgram:
        return self._solver.program_for(k)

    def place_rhs(self, B):
        """Pin an (n, k) right-hand side to the solve program's input
        placement, returned at the legacy (n, k) shape (``solve``
        lifts it to the width-1 stack internally with a pure on-device
        expand, so the steady state stays transfer-free)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        prog = self.program_for(B.shape[1])
        # the program's RHS sharding minus the leading factor axis
        sharding = NamedSharding(self.grid.mesh,
                                 P(*prog.rhs_sharding.spec[1:]))
        return jax.device_put(jnp.asarray(B, self.dtype), sharding)

    def solve(self, B, *, donate: bool = True):
        """Solve op(L) X = B; accepts an (n, k) RHS or the (1, n, k)
        placed form, returns X as (n, k)."""
        if B.ndim == 3 and B.shape[0] == 1:
            return jax.lax.squeeze(self._solver.solve(B, donate=donate),
                                   (0,))
        if B.ndim != 2 or B.shape[0] != self.n:
            raise ValueError(f"rhs must be ({self.n}, k), got {B.shape}")
        return self._solver.solve(B, donate=donate)

    def warmup(self, k: int) -> "TrsmSession":
        self._solver.warmup(k)
        return self
