"""Unified front door for the solve stack: SolveSpec + Solver +
SolveServer (DESIGN.md Sec. 10; re-exported as ``repro.api``).

The paper's central claim is that the *choice* of algorithm — the
block-inversion size n0 interpolating between standard TRSM and full
triangular inversion, the processor grid, and the method itself — can
be made **a priori** from the communication cost analysis (Sec. VIII).
After three PRs that decision was scattered over four entry points
(``tuning.tune``, ``tuning.choose_method``, ``session.resolve_plan``,
``session.get_solver``) and two parallel class hierarchies
(``TrsmSession``/``TrsmRequestServer`` vs ``BatchedTrsmSession``/
``BankedTrsmServer``), keyed by a brittle positional tuple.  This
module collapses all of it into three declarative pieces:

* :class:`SolveSpec` — a frozen, hashable description of ONE solve
  configuration: the problem (n, k, operator variant), the plan
  (method, n0, mode, grid — resolvable a priori via
  :meth:`SolveSpec.auto`, which consumes a frozen
  :class:`~repro.core.tuning.TrsmPlan` verbatim), and the execution
  policy (precision, bank width, map mode).  A concrete spec **is**
  the :class:`~repro.core.session.CompiledSolverCache` key — the sole
  key type; the positional tuples are gone.

* :class:`Solver` — ONE serving class subsuming the former
  ``TrsmSession`` (single resident factor) and ``BatchedTrsmSession``
  (bank of M factors): a :class:`~repro.core.bank.FactorBank` is the
  admission layer and a width-1 bank IS the single-factor case.
  Admission distributes each factor once (operator reductions folded
  into the gather, policy dtype casts, phase 1 — the paper's
  Diagonal-Inverter — hoisted for method "inv"); the steady state is
  one compiled program per RHS width with zero host<->device
  transfers and zero retraces, at any bank width, for every precision
  policy.

* :class:`SolveServer` — ONE continuous-batching front-end subsuming
  ``TrsmRequestServer``/``BankedTrsmServer``: per-factor request
  queues, first-fit packed fixed-width panels, one dispatch per wave
  covering every factor, submit-order results.

The deprecated names remain as thin shims (one ``DeprecationWarning``
each, bit-identical results) so existing call sites keep working;
internal code must use this module (CI errors on internal callers of
the deprecated API).
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import threading
import warnings
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import errors as _errors
from repro.core import precision as preclib
from repro.core.bank import FactorBank
from repro.core.grid import TrsmGrid
from repro.core.precision import PrecisionPolicy
from repro.core.structure import FactorStructure


# --------------------------- deprecation shims ---------------------------

_QUIET = threading.local()


def _warn_deprecated(old: str, new: str) -> None:
    """One DeprecationWarning per deprecated entry point, attributed to
    the caller (stacklevel: helper -> shim -> caller).  Suppressed when
    a shim builds other shims internally (:func:`_shim_quiet`), so each
    deprecated call emits exactly ONE warning."""
    if getattr(_QUIET, "on", False):
        return
    warnings.warn(f"{old} is deprecated; use {new} (see the README "
                  f"migration table)", DeprecationWarning, stacklevel=3)


@contextlib.contextmanager
def _shim_quiet():
    prev = getattr(_QUIET, "on", False)
    _QUIET.on = True
    try:
        yield
    finally:
        _QUIET.on = prev


# ----------------------------- plan resolution -----------------------------

def plan_grid(p1: int, p2: int) -> TrsmGrid:
    """A mesh-less grid (p1 x p1 x p2) for plan-only specs: carries the
    processor-grid arithmetic of a :class:`SolveSpec` without touching
    devices.  Executable paths (:func:`solver_for`, :class:`Solver`)
    require a real mesh (``repro.core.grid.make_trsm_mesh``)."""
    return TrsmGrid(None, p1, p2)


def resolve_plan(grid: TrsmGrid, n: int, k: int, *, method: str = "inv",
                 n0: int | None = None, machine=None,
                 hoisted: bool = False,
                 structure: FactorStructure | None = None
                 ) -> tuple[str, int]:
    """The ONE place method/n0 defaults are resolved (pure host-side
    arithmetic, so cache keys are concrete).

    ``method="auto"`` dispatches through the Sec. VIII alpha-beta-gamma
    model — the fused comparison (``tuning.choose_method``) for
    one-shot solves, or the sweep-only steady comparison
    (``tuning.choose_serving_method``) when ``hoisted``: a resident
    factor pays phase 1 once at admission, so the inversion term must
    not count against "inv" in the per-solve dispatch.  An unset
    ``n0`` is consumed verbatim from the tuner's frozen
    :class:`~repro.core.tuning.TrsmPlan` for "inv" (``tune_for_grid``
    — or the hoisted-serving argmin ``serving_n0``), and set to the
    Sec. IV-A base-case size for "rec".

    ``structure`` (a :class:`~repro.core.structure.FactorStructure`)
    makes the hoisted dispatch and n0 argmin price exactly the blocks
    the level-scheduled sweep executes; the recursive alternative is
    priced dense (our recursion is structure-oblivious), so the
    comparison stays honest."""
    from repro.core import tuning
    if structure is not None and structure.is_dense:
        structure = None
    if method == "auto":
        if hoisted:
            method, h_n0, _ = tuning.choose_serving_method(
                n, k, grid, machine, n0=n0, structure=structure)
            if method == "inv" and n0 is None:
                n0 = h_n0
        else:
            method, _, _ = tuning.choose_method(n, k, grid.p, machine)
    if n0 is None:
        if method == "inv":
            n0 = tuning.serving_n0(n, grid, structure=structure) \
                if hoisted else \
                tuning.tune_for_grid(n, k, grid, machine).n0
        else:
            from repro.core import rec_trsm
            n0 = rec_trsm.default_n0(n, k, grid.p1, grid.p2)
    return method, n0


def _normalize_overlap(overlap) -> str | None:
    """Normalize an overlap request to its cache-key spelling.

    ``"off"``/``False``/``None`` -> ``None`` — byte-for-byte the key
    (and the program) pre-overlap specs always had, exactly like
    ``structure=dense -> None``.  ``"auto"``/``"on"``/``True`` ->
    ``"on"``: both methods support the pipelined sweep on every grid
    (degenerate meshes included — the prefetch degrades to the
    sequential issue order) and the result is bit-identical, so auto
    has no reason to ever resolve off (DESIGN.md Sec. 16)."""
    if overlap in (None, False, "off"):
        return None
    if overlap in (True, "auto", "on"):
        return "on"
    raise ValueError(f"overlap must be 'auto' | 'on' | 'off' | bool | "
                     f"None, got {overlap!r}")


# ------------------------------- SolveSpec -------------------------------

@dataclasses.dataclass(frozen=True)
class SolveSpec:
    """A frozen, hashable description of one solve configuration — and
    the sole :class:`~repro.core.session.CompiledSolverCache` key type.

    Field groups (the spec-field <-> cache-key table is DESIGN.md
    Sec. 10):

    * problem — ``n`` (factor order), ``k`` (RHS width; ``None`` marks
      a template spec that a :class:`Solver` completes per width),
      ``lower``/``transpose`` (the operator variant, DESIGN.md Sec. 3).
    * plan — ``method`` ("inv" | "rec"; ``"auto"`` is resolved BEFORE
      a spec exists, via :meth:`auto`), ``n0`` (diagonal-block size),
      ``mode`` (inv phase-1 scheme), ``grid`` (p1 x p1 x p2 placement;
      mesh identity is part of the key), ``block_inv`` (optional
      diagonal-inverter kernel hook).
    * execution — ``policy`` (the full
      :class:`~repro.core.precision.PrecisionPolicy`), ``bank_width``
      (``None`` = the unbanked one-shot program; M >= 1 = the batched
      program over an M-factor stack) and ``map_mode`` ("vmap" |
      "scan"; normalized to ``None`` when unbanked).
    * structure — the factor's
      :class:`~repro.core.structure.FactorStructure` (DESIGN.md
      Sec. 14).  ``None`` and ``FactorStructure.dense()`` are the SAME
      key (``__post_init__`` normalizes dense to ``None``), so a
      dense-structured spec compiles — and bit-identically runs — the
      exact program the unstructured path always has.
    * overlap — software pipelining of the steady-state sweep
      (DESIGN.md Sec. 16): ``"auto"`` (default) and ``"on"``/``True``
      normalize to ``"on"`` (prefetch panel j+1's collectives under
      panel j's compute); ``"off"``/``False`` normalize to ``None`` —
      the SAME cache key the pre-overlap specs always spelled, keying
      the bit-identical sequential-issue program (the
      structure-normalization discipline, applied again).

    Every field changes the compiled artifact, which is exactly why
    the spec is the cache key: two call sites that build equal specs
    share one compiled program, and nothing that matters can be left
    out of the key by accident.
    """
    n: int
    k: int | None
    grid: TrsmGrid
    policy: PrecisionPolicy
    method: str = "inv"
    n0: int | None = None
    mode: str | None = None
    lower: bool = True
    transpose: bool = False
    block_inv: Callable | None = None
    bank_width: int | None = None
    map_mode: str | None = None
    structure: FactorStructure | None = None
    overlap: str | bool | None = "auto"

    def __post_init__(self):
        if self.method not in ("inv", "rec"):
            raise ValueError(
                f"spec method must be 'inv' or 'rec', got {self.method!r}"
                f" (resolve 'auto' through SolveSpec.auto)")
        object.__setattr__(self, "overlap",
                           _normalize_overlap(self.overlap))
        if self.bank_width is not None and self.bank_width < 1:
            raise ValueError(f"bank width must be >= 1, got "
                             f"{self.bank_width}")
        if self.bank_width is None:
            object.__setattr__(self, "map_mode", None)
        elif self.map_mode is None:
            object.__setattr__(self, "map_mode", "vmap")
        if self.map_mode not in (None, "vmap", "scan"):
            raise ValueError(f"unknown map_mode {self.map_mode!r}")
        # dense IS the unstructured path: normalize so the two spell
        # the same cache key and compile the same (byte-identical)
        # program
        if self.structure is not None and self.structure.is_dense:
            object.__setattr__(self, "structure", None)

    # ------------------------------ queries ------------------------------

    @property
    def is_concrete(self) -> bool:
        """True when the spec can key a compiled program: shape and
        plan fully resolved, grid backed by a real mesh."""
        return (self.k is not None and self.n0 is not None
                and self.grid is not None
                and self.grid.mesh is not None)

    def with_k(self, k: int) -> "SolveSpec":
        """The same configuration at RHS width k."""
        return dataclasses.replace(self, k=k)

    def validate(self) -> "SolveSpec":
        """Check plan feasibility (raises ValueError): n0 must tile the
        factor (``n0 | n``) and, for "inv", respect the cyclic layout
        (``(p1*p2) | n0`` — each rank owns a contiguous slice of every
        diagonal block)."""
        n0 = self.n0
        if n0 is not None:
            if n0 < 1 or self.n % n0:
                raise ValueError(f"n0={n0} does not tile n={self.n}")
            if self.method == "inv" and self.grid is not None \
                    and n0 % (self.grid.p1 * self.grid.p2):
                raise ValueError(
                    f"n0={n0} infeasible for the cyclic layout on "
                    f"p1={self.grid.p1}, p2={self.grid.p2}")
        if self.structure is not None:
            self.structure.validate_for(self.n, lower=self.lower,
                                        transpose=self.transpose)
        return self

    # ---------------------------- construction ----------------------------

    @classmethod
    def auto(cls, n: int, k: int, *, grid: TrsmGrid | None = None,
             p: int | None = None, method: str = "auto",
             n0: int | None = None, mode: str | None = None,
             lower: bool = True, transpose: bool = False,
             machine=None, precision=None, dtype=None,
             block_inv: Callable | None = None,
             bank_width: int | None = None,
             map_mode: str | None = None,
             hoisted: bool | None = None,
             structure: FactorStructure | None = None,
             overlap: str | bool | None = "auto") -> "SolveSpec":
        """The a-priori front door: resolve the plan ONCE from the
        Sec. VIII cost model and freeze it into a spec.

        Pass either a ``grid`` (mesh pinned — n0/method tuned for it)
        or a processor count ``p`` (the tuner also picks p1/p2; the
        result carries a mesh-less :func:`plan_grid` and is a
        plan-only spec until re-targeted at a real mesh).  The tuner's
        frozen :class:`~repro.core.tuning.TrsmPlan` is consumed
        verbatim — same n0, same grid factors.  ``hoisted`` selects
        the serving-n0 argmin (defaults to True exactly when
        ``bank_width`` is set, i.e. when phase 1 runs at admission).
        ``precision`` accepts a preset name or PrecisionPolicy;
        ``dtype`` the legacy uniform policy; default fp32."""
        from repro.core import tuning
        if hoisted is None:
            hoisted = bank_width is not None
        if structure is not None and structure.is_dense:
            structure = None
        if structure is not None:
            structure.validate_for(n, lower=lower, transpose=transpose)
        if grid is None:
            if p is None:
                raise ValueError("SolveSpec.auto needs grid= or p=")
            if method == "auto":
                method, plan, _ = tuning.choose_method(n, k, p, machine)
            else:
                plan = tuning.tune(n, k, p, machine)
            grid = plan_grid(plan.p1, plan.p2)
            if n0 is None and method == "inv" and not hoisted:
                n0 = plan.n0                      # the plan, verbatim
        method, n0 = resolve_plan(grid, n, k, method=method, n0=n0,
                                  machine=machine, hoisted=hoisted,
                                  structure=structure)
        if precision is None and dtype is None:
            dtype = jnp.float32
        return cls(n=n, k=k, grid=grid,
                   policy=preclib.resolve(precision, dtype),
                   method=method, n0=n0, mode=mode, lower=lower,
                   transpose=transpose, block_inv=block_inv,
                   bank_width=bank_width, map_mode=map_mode,
                   structure=structure, overlap=overlap).validate()

    @classmethod
    def from_plan(cls, plan, *, k: int | None = None,
                  grid: TrsmGrid | None = None, precision=None,
                  dtype=None, mode: str | None = None,
                  lower: bool = True, transpose: bool = False,
                  block_inv: Callable | None = None,
                  bank_width: int | None = None,
                  map_mode: str | None = None) -> "SolveSpec":
        """Freeze a tuner-produced :class:`~repro.core.tuning.TrsmPlan`
        into a spec VERBATIM (method, n0, and grid factors are the
        plan's own).  ``grid`` may re-target the plan at a real mesh,
        but must agree with the plan's (p1, p2)."""
        if grid is None:
            grid = plan_grid(plan.p1, plan.p2)
        elif (grid.p1, grid.p2) != (plan.p1, plan.p2):
            raise ValueError(
                f"grid ({grid.p1}, {grid.p2}) does not match the "
                f"plan's ({plan.p1}, {plan.p2})")
        if precision is None and dtype is None:
            dtype = jnp.float32
        return cls(n=plan.n, k=plan.k if k is None else k, grid=grid,
                   policy=preclib.resolve(precision, dtype),
                   method=plan.method, n0=plan.n0, mode=mode,
                   lower=lower, transpose=transpose, block_inv=block_inv,
                   bank_width=bank_width, map_mode=map_mode).validate()


@dataclasses.dataclass(frozen=True)
class UpdateSpec:
    """A frozen, hashable description of one in-place bank update
    program — the second :class:`CompiledSolverCache` key type
    (DESIGN.md Sec. 11).

    Where a :class:`SolveSpec` keys the steady-state *solve* program,
    an UpdateSpec keys the *mutation* program: the single-factor
    admission pipeline (distribution gather + policy casts + hoisted
    phase 1) fused with a donated scatter into the bank's resident
    (C, ...) stacks.  Everything that changes the compiled artifact is
    a field: the factor order and plan, the precision policy (which
    roles exist and their dtypes), the operator variant (folded into
    the gather), the stack width C the scatter targets, and the
    ingestion layout (``"natural"`` runs the fused gather;
    ``"cyclic"`` takes a producer's working-layout factor and only
    casts).  Two same-shape banks share one compiled updater, and an
    updater never retraces across slots or occupancy changes.

    ``chunk`` widens the scatter to a CONTIGUOUS RUN of slots: the
    program takes a (chunk, n, n) stacked factor and writes slots
    ``start .. start + chunk - 1`` in one
    ``lax.dynamic_update_slice_in_dim`` — one dispatch where a per-slot
    loop would pay ``chunk`` (the ``refresh_banks`` stacked-parameter
    path).  ``pad_from`` declares the incoming factor is a smaller
    (d, d) order embedded into this bank's (n, n) bucket order: the
    program zero-pads rows/columns ``d..n-1`` and puts 1 on the padded
    diagonal (``blockdiag(L, I)`` in natural layout), so the padded
    tail solves to exact zeros against zero RHS rows and the leading
    d x k solution block is bit-identical to an unpadded order-d solve
    at the same n0 (DESIGN.md Sec. 12).
    """
    n: int
    grid: TrsmGrid
    policy: PrecisionPolicy
    method: str
    n0: int | None
    mode: str | None
    lower: bool
    transpose: bool
    block_inv: Callable | None
    bank_width: int              # C — the resident stack width
    ingest: str = "natural"      # "natural" | "cyclic"
    chunk: int = 1               # contiguous slots written per dispatch
    pad_from: int | None = None  # incoming factor order d (< n) or None
    structure: FactorStructure | None = None
    overlap: str | bool | None = None

    def __post_init__(self):
        if self.ingest not in ("natural", "cyclic"):
            raise ValueError(f"unknown ingest {self.ingest!r}")
        # the admission pipeline has no sweep to pipeline (phase 1's
        # doubling recurrence is serially dependent), so EVERY overlap
        # request normalizes to None: banks built with overlap on or
        # off share one compiled updater
        _normalize_overlap(self.overlap)       # validate the spelling
        object.__setattr__(self, "overlap", None)
        if self.structure is not None and self.structure.is_dense:
            object.__setattr__(self, "structure", None)
        if self.structure is not None:
            self.structure.validate_for(self.n, lower=self.lower,
                                        transpose=self.transpose)
            if self.ingest == "cyclic":
                raise ValueError(
                    "structured banks take natural ingestion only: the "
                    "admission mask is applied in natural layout, "
                    "before distribution")
        if self.bank_width < 1:
            raise ValueError(f"bank width must be >= 1, got "
                             f"{self.bank_width}")
        if not 1 <= self.chunk <= self.bank_width:
            raise ValueError(f"chunk must be in [1, bank_width="
                             f"{self.bank_width}], got {self.chunk}")
        if self.pad_from is not None:
            if not 1 <= self.pad_from < self.n:
                raise ValueError(f"pad_from must be in [1, n={self.n}), "
                                 f"got {self.pad_from}")
            if self.ingest == "cyclic":
                raise ValueError(
                    "pad_from requires natural ingestion (a cyclic "
                    "factor is already in the bucket-order storage "
                    "layout; zero-pad before distribution instead)")


def updater_for(uspec: UpdateSpec, cache=None):
    """Fetch (or build) the compiled in-place
    :class:`~repro.core.session.UpdaterProgram` for an update spec —
    the spec IS the cache key (same LRU as the solve programs)."""
    from repro.core import session
    if not isinstance(uspec, UpdateSpec):
        raise TypeError(f"updater_for takes an UpdateSpec, got "
                        f"{type(uspec).__name__}")
    session._check_policy_supported(uspec.policy)
    cache = cache if cache is not None else session.default_cache()
    return cache.get(uspec, lambda: session._build_updater(uspec))


def solver_for(spec: SolveSpec, cache=None):
    """Fetch (or build) the compiled
    :class:`~repro.core.session.SolverProgram` for a concrete spec —
    the spec IS the cache key."""
    from repro.core import session
    if not isinstance(spec, SolveSpec):
        raise TypeError(f"solver_for takes a SolveSpec, got "
                        f"{type(spec).__name__}")
    if not spec.is_concrete:
        raise ValueError(
            f"spec is not concrete (k={spec.k}, n0={spec.n0}, mesh="
            f"{'set' if spec.grid and spec.grid.mesh is not None else None}"
            f"): fill k/n0 and target a real mesh before compiling")
    session._check_policy_supported(spec.policy)
    cache = cache if cache is not None else session.default_cache()
    return cache.get(spec, lambda: session._build_solver(spec))


# -------------------------------- Solver --------------------------------

class Solver:
    """ONE serving class for resident triangular factors — any bank
    width, any precision policy, single- and multi-factor (DESIGN.md
    Sec. 10).

    A :class:`~repro.core.bank.FactorBank` is the admission layer: the
    factor(s) are distributed ONCE into stacked cyclic device storage
    (operator reductions folded into the gather, policy dtype casts,
    and — for method "inv" — phase 1, the paper's Diagonal-Inverter,
    hoisted so the steady state is the sweep alone).  A width-1 bank
    IS the single-factor case; there is no separate session type.

        solver = Solver.from_factor(L, grid, precision="bf16_refine")
        X = solver.solve(B)                   # B: (n, k) -> X: (n, k)

        solver = Solver.from_factors(Ls, grid)      # (M, n, n) stack
        X = solver.solve(Bs)                  # (M, n, k) in ONE dispatch

    ``solve`` accepts an (n, k) RHS when the width is 1 (returned in
    kind) or an (M, n, k) stack; after ``warmup`` the steady state
    performs zero host<->device transfers and zero retraces per RHS
    width, for every precision policy and every bank width (asserted
    in tests/test_api_solver.py at widths 1 and 16).

    Programs come from the :class:`CompiledSolverCache`, keyed by this
    solver's :meth:`spec_for` — same-width same-config solvers share
    one compiled program; factors are runtime operands, never baked-in
    constants.
    """

    def __init__(self, bank: FactorBank, *, cache=None):
        self.bank = bank
        self.cache = cache if cache is not None else bank.cache
        self.solves_served = 0

    # ---------------------------- constructors ----------------------------

    @classmethod
    def from_factor(cls, L, grid: TrsmGrid, *, method: str = "inv",
                    n0: int | None = None, mode: str | None = None,
                    lower: bool = True, transpose: bool = False,
                    machine=None, block_inv: Callable | None = None,
                    dtype=None, precision=None, map_mode: str = "vmap",
                    k_hint: int | None = None,
                    structure: FactorStructure | None = None,
                    overlap: str | bool | None = "auto",
                    cache=None) -> "Solver":
        """A width-1 solver around one natural-layout (n, n) factor
        (the former ``TrsmSession``).  ``method="auto"`` resolves the
        algorithm a priori from the cost model at ``k_hint`` RHS
        columns (default n); an unset n0 defaults to the
        hoisted-serving argmin (``tuning.serving_n0`` — phase 1 runs
        at admission, see DESIGN.md Sec. 9).  ``structure`` declares
        the factor's block structure (DESIGN.md Sec. 14): admission
        masks to it, the sweep skips outside it, and the n0 argmin
        prices it."""
        L = jnp.asarray(L) if dtype is None else jnp.asarray(L, dtype)
        if L.ndim != 2 or L.shape[0] != L.shape[1]:
            raise ValueError(f"factor must be square, got {L.shape}")
        n = L.shape[0]
        if method == "auto":
            method, n0 = resolve_plan(grid, n, k_hint or n,
                                      method="auto", n0=n0,
                                      machine=machine, hoisted=True,
                                      structure=structure)
        bank = FactorBank(grid, n, method=method, n0=n0, mode=mode,
                          lower=lower, transpose=transpose,
                          machine=machine, block_inv=block_inv,
                          dtype=None if precision is not None else L.dtype,
                          precision=precision, map_mode=map_mode,
                          structure=structure, overlap=overlap,
                          cache=cache)
        bank.admit(L)
        return cls(bank, cache=cache)

    @classmethod
    def from_factors(cls, Ls, grid: TrsmGrid, *, method: str = "inv",
                     n0: int | None = None, mode: str | None = None,
                     lower: bool = True, transpose: bool = False,
                     machine=None, block_inv: Callable | None = None,
                     dtype=None, precision=None, map_mode: str = "vmap",
                     capacity: int | None = None,
                     structure: FactorStructure | None = None,
                     overlap: str | bool | None = "auto",
                     cache=None) -> "Solver":
        """A width-M solver over an (M, n, n) natural-layout stack,
        admitted in one stacked gather (the former bank construction +
        ``BatchedTrsmSession``).  ``capacity=C`` (>= M) allocates a
        LIVE-MUTABLE bank at width C: the compiled program is keyed on
        C, so later ``replace_factor``/``evict_factor``/``admit_factor``
        churn never retraces (DESIGN.md Sec. 11)."""
        Ls = jnp.asarray(Ls) if dtype is None else jnp.asarray(Ls, dtype)
        if Ls.ndim != 3 or Ls.shape[-1] != Ls.shape[-2]:
            raise ValueError(f"factor stack must be (M, n, n), got "
                             f"{Ls.shape}")
        bank = FactorBank(grid, Ls.shape[-1], method=method, n0=n0,
                          mode=mode, lower=lower, transpose=transpose,
                          machine=machine, block_inv=block_inv,
                          dtype=None if precision is not None
                          else Ls.dtype,
                          precision=precision, map_mode=map_mode,
                          capacity=capacity, structure=structure,
                          overlap=overlap, cache=cache)
        bank.admit_stack(Ls)
        return cls(bank, cache=cache)

    @classmethod
    def from_bank(cls, bank: FactorBank, *, cache=None) -> "Solver":
        """Serve an existing (possibly still-growing) FactorBank."""
        return cls(bank, cache=cache)

    @classmethod
    def from_spec(cls, spec: SolveSpec, factors=None, *,
                  capacity: int | None = None, cache=None) -> "Solver":
        """Spec-driven construction: build the admission bank from a
        spec's plan/execution fields and admit ``factors`` (one (n, n)
        factor or an (M, n, n) stack).  The spec's grid must carry a
        real mesh, and when the spec pins a ``bank_width`` the admitted
        factor count must match it — the spec is the cache key, so a
        width mismatch would silently key programs on a different spec
        than the one declared.  ``capacity`` (defaulting to the spec's
        ``bank_width`` when ``factors`` is omitted) allocates a
        live-mutable bank at the spec's width, to be filled by
        ``admit_factor``/``replace_factor`` later — the declarative
        churn-serving entry point."""
        if spec.grid is None or spec.grid.mesh is None:
            raise ValueError("spec has a plan-only grid; re-target it "
                             "at a real mesh (make_trsm_mesh) first")
        spec.validate()
        if capacity is None and factors is None:
            capacity = spec.bank_width
        if capacity is not None and spec.bank_width is not None \
                and capacity != spec.bank_width:
            raise ValueError(
                f"capacity={capacity} contradicts the spec's "
                f"bank_width={spec.bank_width} (the spec is the cache "
                f"key; the capacity IS the compiled width)")
        bank = FactorBank(spec.grid, spec.n, method=spec.method,
                          n0=spec.n0, mode=spec.mode, lower=spec.lower,
                          transpose=spec.transpose,
                          block_inv=spec.block_inv,
                          precision=spec.policy,
                          map_mode=spec.map_mode or "vmap",
                          capacity=capacity, structure=spec.structure,
                          overlap=spec.overlap, cache=cache)
        solver = cls(bank, cache=cache)
        if factors is not None:
            factors = jnp.asarray(factors)
            if factors.ndim == 3:
                bank.admit_stack(factors)
            else:
                bank.admit(factors)
        if spec.bank_width is not None and bank.width != spec.bank_width:
            raise ValueError(
                f"spec pins bank_width={spec.bank_width} but "
                f"{bank.size} factor(s) were admitted; pass a "
                f"matching stack (or a spec with bank_width=None)")
        return solver

    # ------------------------------ queries ------------------------------

    @property
    def n(self) -> int:
        return self.bank.n

    @property
    def width(self) -> int:
        """The bank WIDTH the compiled program is keyed on — the
        capacity of a capacity-allocated bank (occupancy changes never
        re-key; free slots ride along as inert zero lanes), else the
        live factor count (append-only: admitting grows the width and
        the next solve keys on it)."""
        return self.bank.width

    @property
    def occupancy(self) -> int:
        """The number of LIVE resident factors (<= width)."""
        return self.bank.size

    @property
    def grid(self) -> TrsmGrid:
        return self.bank.grid

    @property
    def policy(self) -> PrecisionPolicy:
        return self.bank.policy

    @property
    def dtype(self):
        """I/O dtype (what ``solve`` returns, what :meth:`place_rhs`
        casts to): residual dtype when the policy refines, compute
        dtype otherwise."""
        return self.bank.policy.io_dtype

    @property
    def method(self) -> str:
        return self.bank.method

    @property
    def n0(self) -> int | None:
        return self.bank.n0

    def spec_for(self, k: int) -> SolveSpec:
        """The concrete :class:`SolveSpec` (== cache key) serving RHS
        width k at the current bank width."""
        b = self.bank
        n0 = b.n0
        if n0 is None:                       # "rec" with unpinned n0
            from repro.core import rec_trsm
            n0 = rec_trsm.default_n0(b.n, k, b.grid.p1, b.grid.p2)
        return SolveSpec(n=b.n, k=k, grid=b.grid, policy=b.policy,
                         method=b.method, n0=n0, mode=b.mode,
                         lower=b.lower, transpose=b.transpose,
                         block_inv=b.block_inv, bank_width=b.width,
                         map_mode=b.map_mode, structure=b.structure,
                         overlap=b.overlap)

    def program_for(self, k: int):
        """The compiled :class:`~repro.core.session.SolverProgram` for
        RHS width k (built and cached on first use)."""
        return solver_for(self.spec_for(k), self.cache)

    # ------------------------------ serving ------------------------------

    def _lift(self, B):
        """Normalize an RHS to the (M, n, k) stack form; returns
        (stack, was_2d)."""
        if B.ndim == 2:
            if self.width != 1:
                raise ValueError(
                    f"rhs stack must be ({self.width}, {self.n}, k) for "
                    f"a width-{self.width} solver, got {B.shape}")
            if B.shape[0] != self.n:
                raise ValueError(f"rhs must be ({self.n}, k), got "
                                 f"{B.shape}")
            return jax.lax.expand_dims(B, (0,)), True
        if B.ndim != 3 or B.shape[0] != self.width \
                or B.shape[1] != self.n:
            raise ValueError(f"rhs stack must be ({self.width}, "
                             f"{self.n}, k), got {B.shape}")
        return B, False

    def place_rhs(self, B):
        """Pin an RHS — (n, k) at width 1, or an (M, n, k) stack — to
        the solve program's input sharding, in stack form.  A serving
        client that places requests as they arrive pays the
        (unavoidable) ingestion transfer up front; ``solve`` itself
        then moves no data at all."""
        B, _ = self._lift(jnp.asarray(B, self.dtype))
        prog = self.program_for(B.shape[-1])
        return jax.device_put(B, prog.rhs_sharding)

    def solve(self, B, *, donate: bool = True):
        """Solve op(L_i) X_i = B_i for every resident factor in ONE
        dispatch; X is returned in the rank B was given (an (n, k) RHS
        at width 1 yields an (n, k) X).  ``donate=True`` (serving
        semantics) donates the RHS buffer."""
        B, squeeze = self._lift(B)
        prog = self.program_for(B.shape[-1])
        fn = prog.solve_donating if donate else prog.solve
        X = fn(self.bank.stacks(), B)
        self.solves_served += self.width
        # lax.squeeze, not X[0]: the getitem spelling lowers through
        # dynamic_slice, whose index operand is a host->device upload
        # on every call — it would break the zero-transfer steady state
        return jax.lax.squeeze(X, (0,)) if squeeze else X

    def warmup(self, k: int) -> "Solver":
        """Compile (and run once on zeros) the program for RHS width k
        at the current bank width, so the first real request is served
        at steady-state latency.  Also pre-runs the rank adapters
        (stack/slice) used by width-1 (n, k) serving.  A
        capacity-allocated bank can warm up EMPTY: the program is
        keyed on capacity, so it is already the one every later
        occupancy serves."""
        B = jnp.zeros((self.width, self.n, k), self.dtype)
        X = self.solve(B, donate=True)
        if self.width == 1:
            jax.lax.expand_dims(jnp.zeros((self.n, k), self.dtype),
                                (0,))                   # lift path
            jax.lax.squeeze(X, (0,))                    # squeeze path
        return self

    # ------------------------- live bank mutation -------------------------

    def admit_factor(self, L) -> int:
        """Admit one natural-layout (n, n) factor; returns its slot.
        On a capacity bank this fills (and re-uses) free slots in
        place — the compiled program does not change."""
        return self.bank.admit(L)

    def replace_factor(self, slot: int, L) -> int:
        """Refresh live ``slot`` in place with a new factor through
        the bank's compiled donated updater — zero retraces, zero host
        round trips, no rebuild (DESIGN.md Sec. 11)."""
        return self.bank.replace(slot, L)

    def evict_factor(self, slot: int) -> None:
        """Free live ``slot`` (capacity banks); the slot's lane goes
        inert until the next ``admit_factor`` re-uses it."""
        self.bank.evict(slot)

    def live_slots(self) -> tuple:
        """The live bank slots, ascending."""
        return self.bank.live_slots()


# ------------------------------ SolveServer ------------------------------

# StrandedRequestError now lives in the unified serving-error
# hierarchy (repro.core.errors, DESIGN.md Sec. 15); the historical
# spelling `repro.core.solver.StrandedRequestError` is a warn-once
# alias of the same class via __getattr__ below.

def __getattr__(name: str):
    if name == "StrandedRequestError":
        _warn_deprecated("repro.core.solver.StrandedRequestError",
                         "repro.api.StrandedRequestError "
                         "(repro.core.errors)")
        # warn-once: bind the module attribute so subsequent accesses
        # (and re-imports) resolve silently to the SAME class object
        globals()[name] = _errors.StrandedRequestError
        return _errors.StrandedRequestError
    raise AttributeError(f"module {__name__!r} has no attribute "
                         f"{name!r}")


@functools.lru_cache(maxsize=4096)
def static_slice(start: tuple, limit: tuple, squeeze: tuple = ()):
    """A jitted static slice (+ optional squeeze), cached per bounds.

    Op-by-op ``jax.lax.slice`` (and the ``X[f, :, a:b]`` getitem it
    underlies) ships its bounds as an int32 operand — one host->device
    upload per call, which breaks the zero-transfer steady state the
    serving tier asserts under ``jax.transfer_guard("disallow")``.
    Baking the bounds into a tiny jitted program moves that cost to a
    one-time compile; every subsequent call is a transfer-free
    dispatch.  Wave assembly/extraction cycles through a handful of
    layouts in steady state, so the cache stays tiny."""
    def run(A):
        out = jax.lax.slice(A, start, limit)
        return jax.lax.squeeze(out, squeeze) if squeeze else out
    return jax.jit(run)


def _pack_wave(queue: collections.deque, panel_k: int) -> list:
    """First-fit pack one panel's worth of requests off the queue.

    Walks the whole queue in FIFO order and takes EVERY request that
    still fits in the remaining panel width (not just a contiguous
    head-of-line prefix): a wide request at the head no longer strands
    narrow requests behind it in an underfilled panel.  Skipped
    requests keep their relative order for the next wave.  Returns the
    packed [(seq, b), ...]; the queue keeps the rest."""
    wave: list = []
    width = 0
    leftover: collections.deque = collections.deque()
    while queue:
        seq, b = queue.popleft()
        if width + b.shape[1] <= panel_k:
            wave.append((seq, b))
            width += b.shape[1]
        else:
            leftover.append((seq, b))
    queue.extend(leftover)
    return wave


class SolveServer:
    """ONE continuous-batching front-end for a :class:`Solver` at any
    width (subsumes ``TrsmRequestServer`` and ``BankedTrsmServer``).

    Incoming solve requests (RHS column blocks of varying width,
    addressed to a bank factor — factor 0 is the whole bank at width
    1) are first-fit packed into fixed-width (n, panel_k) panels, one
    panel slot per factor, and every wave is ONE dispatch covering all
    factors: one executable for all traffic, zero retraces and zero
    host transfers in the steady state.  Factors with an empty queue
    ride along as zero panels (a solve of zeros is zeros, so idle
    factors never contaminate results and the program shape never
    changes); ``drain`` returns each factor's solutions in its own
    submit order.

        server = SolveServer(Solver.from_factors(Ls, grid), panel_k=16)
        server.warmup()
        server.submit(b, factor=2)
        outs = server.drain()          # {factor: [X, ...]}

    Constructed over a :class:`~repro.core.fleet.SolverFleet` instead
    of a Solver, the server routes submits by ``(tenant, order)``
    through the fleet's planner-chosen buckets (DESIGN.md Sec. 12):
    one lazy inner per-bucket server, the RHS zero-padded to the
    bucket order at submit, the solution sliced back to the request's
    true (d, j) at drain:

        server = SolveServer(fleet, panel_k=16)
        server.submit(b, tenant="modelA", tag="layer0")
        outs = server.drain()          # {(tenant, tag): [X, ...]}
    """

    def __init__(self, solver, panel_k: int):
        from repro.core.fleet import SolverFleet
        self.fleet = solver if isinstance(solver, SolverFleet) else None
        self.solver = None if self.fleet is not None else solver
        self.panel_k = panel_k
        if self.fleet is not None:
            # bucket key -> lazy inner server; (bucket key, slot) ->
            # FIFO of (tenant, tag, order) for slicing drained panels
            self._servers: dict = {}
            self._routes: dict = {}
        # lazily keyed by factor index, validated against the solver's
        # CURRENT width — factors admitted after server construction
        # are servable immediately (the next wave's program is simply
        # keyed on the new width)
        self._queues: dict[int, collections.deque] = {}
        self._seq = 0
        # slot generation at submit time, per request: a request must
        # never be served against a factor admitted after its slot was
        # evicted (re-admission makes the slot live again, so liveness
        # alone cannot catch it)
        self._req_gen: dict[int, int] = {}
        self._fillers: dict = {}     # dtype -> cached (n, panel_k) zeros
        self.requests_served = 0
        self.waves_solved = 0

    @classmethod
    def from_spec(cls, spec: SolveSpec, factors, *, panel_k: int = 16,
                  cache=None, warm: bool = True) -> "SolveServer":
        """Spec-driven construction: admit ``factors`` under ``spec``
        and return a (warmed) server."""
        server = cls(Solver.from_spec(spec, factors, cache=cache),
                     panel_k=panel_k)
        return server.warmup() if warm else server

    @property
    def panels_solved(self) -> int:
        """Alias of ``waves_solved`` (a width-1 wave is one panel)."""
        return self.waves_solved

    def _server_for(self, key) -> "SolveServer":
        srv = self._servers.get(key)
        if srv is None:
            srv = self._servers[key] = SolveServer(
                self.fleet.solver(key), self.panel_k)
        return srv

    def submit(self, b, factor: int = 0, *, tenant: str | None = None,
               tag: object = None) -> None:
        """Enqueue one RHS block — an (n,) vector or (n, j) columns —
        for bank factor ``factor``.  Submits to an inactive (evicted /
        never-admitted) capacity slot are rejected: its lane is an
        inert zero panel, and solving real traffic against it would
        silently return garbage.

        In fleet mode the request is addressed by ``(tenant, order)``
        (+ ``tag`` when the tenant holds several factors of one
        order): the RHS row count IS the order, the fleet routes it to
        the planned bucket, and the panel is zero-padded to the bucket
        order (the padded factor's identity tail maps the zero rows to
        exact-zero solution rows)."""
        if self.fleet is not None:
            b = jnp.asarray(b)
            if b.ndim == 1:
                b = b[:, None]
            if b.ndim != 2:
                raise ValueError(f"rhs must be (d, j), got {b.shape}")
            h = self.fleet.lookup(tenant if tenant is not None
                                  else "default",
                                  order=int(b.shape[0]), tag=tag)
            n_b = h.bucket[0]
            if b.shape[0] < n_b:
                b = jnp.pad(b, ((0, n_b - b.shape[0]), (0, 0)))
            self._server_for(h.bucket).submit(b, factor=h.slot)
            self._routes.setdefault((h.bucket, h.slot),
                                    collections.deque()) \
                .append((h.tenant, h.tag, h.order))
            return
        if tenant is not None or tag is not None:
            raise ValueError("tenant=/tag= addressing needs a fleet "
                             "server (SolveServer(SolverFleet, ...))")
        if not 0 <= factor < self.solver.width:
            raise ValueError(f"unknown factor {factor}; bank holds "
                             f"{self.solver.width}")
        if not self.solver.bank.is_live(factor):
            raise ValueError(f"inactive slot {factor}: evicted or "
                             f"never admitted (live slots: "
                             f"{list(self.solver.live_slots())})")
        b = jnp.asarray(b, self.solver.dtype)
        if b.ndim == 1:
            b = b[:, None]
        if b.ndim != 2 or b.shape[0] != self.solver.n:
            raise ValueError(f"rhs must be ({self.solver.n}, j), "
                             f"got {b.shape}")
        if b.shape[1] > self.panel_k:
            raise ValueError(f"request wider than panel: {b.shape[1]} > "
                             f"{self.panel_k}")
        self._queues.setdefault(factor, collections.deque())
        self._req_gen[self._seq] = \
            self.solver.bank.slot_generation(factor)
        self._queues[factor].append((self._seq, b))
        self._seq += 1

    def pending(self) -> int:
        if self.fleet is not None:
            return sum(s.pending() for s in self._servers.values())
        return sum(len(q) for q in self._queues.values())

    def cancel(self, factor: int) -> int:
        """Drop every queued request for ``factor`` (and its bookkeeping);
        returns how many were dropped.  The recovery path when a slot
        was evicted with requests still pending: cancel the stranded
        slot, then ``drain`` serves the rest normally."""
        if self.fleet is not None:
            raise ValueError(
                "cancel is slot-addressed; a fleet server has no flat "
                "slot space (drain, or cancel on the bucket's own "
                "server)")
        q = self._queues.get(factor)
        if not q:
            return 0
        for seq, _ in q:
            self._req_gen.pop(seq, None)
        dropped = len(q)
        q.clear()
        # drop the dead key too, so pending()/drain stop iterating it
        self._queues.pop(factor, None)
        return dropped

    def _filler(self, dtype):
        """The all-zero (n, panel_k) panel idle factors ride along as —
        built ONCE per dtype and reused every wave, instead of
        reallocating per inactive slot per wave."""
        panel = self._fillers.get(dtype)
        if panel is None:
            panel = self._fillers[dtype] = \
                jnp.zeros((self.solver.n, self.panel_k), dtype)
        return panel

    def _solve_wave(self, waves: dict) -> dict:
        """Assemble and dispatch ONE wave: ``{slot: [(seq, b), ...]}``
        -> ``{slot: [(seq, X), ...]}``, packed order preserved, X the
        request's (n, j) column block.  Slots absent from ``waves``
        ride along as cached zero panels; underfilled panels are
        completed from the same cached filler (a slice of an existing
        device array, so the steady state stays transfer-free — a
        fresh ``jnp.pad``/getitem here would upload constants/indices
        on every wave).  Shared by :meth:`drain` (the synchronous
        caller-driven path) and the background drain loop of
        :class:`repro.core.serving.AsyncSolveServer`, which packs its
        own waves."""
        n, pk = self.solver.n, self.panel_k
        panels = []
        for f in range(self.solver.width):
            wave = waves.get(f, ())
            if wave:
                parts = [b for _, b in wave]
                w = sum(b.shape[1] for b in parts)
                if w < pk:
                    parts.append(static_slice((0, 0), (n, pk - w))(
                        self._filler(self.solver.dtype)))
                panel = parts[0] if len(parts) == 1 \
                    else jnp.concatenate(parts, axis=1)
            else:
                panel = self._filler(self.solver.dtype)
            panels.append(panel)
        X = self.solver.solve(jnp.stack(panels))
        self.waves_solved += 1
        out: dict = {}
        for f, wave in waves.items():
            off, xs = 0, []
            for seq, b in wave:
                j = b.shape[1]
                # jitted static slice, not X[f, :, off:...]: both the
                # getitem spelling and op-by-op lax.slice upload their
                # bounds as an int32 operand per wave
                xs.append((seq, static_slice(
                    (f, 0, off), (f + 1, n, off + j), (0,))(X)))
                off += j
            out[f] = xs
            self.requests_served += len(wave)
        return out

    def warmup(self) -> "SolveServer":
        if self.fleet is not None:
            self.fleet.warmup(self.panel_k)
            return self
        self.solver.warmup(self.panel_k)
        return self

    def drain(self) -> dict:
        """Serve all queued requests for all factors.  Returns
        {factor: [X, ...]} for every LIVE bank slot (empty list if
        none were queued; inactive capacity slots ride along as zero
        panels and are omitted), each factor's solutions in its own
        submit order.  Requests stranded on a slot that was evicted
        AFTER submission are an error — even if the slot was re-admitted
        since (a per-slot generation counter catches the turnover):
        their solutions would be garbage against whatever occupies the
        lane now.

        In fleet mode: drains every bucket's inner server and returns
        ``{(tenant, tag): [X, ...]}``, each solution sliced back to its
        request's true (d, j) — the padded tail rows are exact zeros
        and are dropped here."""
        if self.fleet is not None:
            results: dict[tuple, list] = {}
            for key, srv in self._servers.items():
                for slot, xs in srv.drain().items():
                    route = self._routes.get((key, slot))
                    for X in xs:
                        tenant, tag, d = route.popleft()
                        results.setdefault((tenant, tag), []).append(
                            X[:d, :] if d < X.shape[0] else X)
            self.requests_served = sum(s.requests_served
                                       for s in self._servers.values())
            self.waves_solved = sum(s.waves_solved
                                    for s in self._servers.values())
            return results
        pk = self.panel_k
        bank = self.solver.bank
        live = self.solver.live_slots()
        live_set = set(live)
        # a request is stale if its slot is gone OR was turned over
        # (evicted, even if re-admitted since) after it was submitted
        dead = sorted(f for f, q in self._queues.items() if q and (
            f not in live_set
            or any(self._req_gen[seq] != bank.slot_generation(f)
                   for seq, _ in q)))
        if dead:
            raise _errors.StrandedRequestError(
                f"pending requests for slot(s) {dead} evicted after "
                f"submission; drain before evicting a slot, or "
                f"cancel(factor) to drop the stranded requests")
        results: dict[int, dict] = {f: {} for f in live}
        while self.pending():
            waves = {f: _pack_wave(q, pk)
                     for f, q in self._queues.items() if q}
            for f, xs in self._solve_wave(waves).items():
                for seq, x in xs:
                    results[f][seq] = x
                    self._req_gen.pop(seq, None)
        return {f: [res[s] for s in sorted(res)]
                for f, res in results.items()}
