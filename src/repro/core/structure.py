"""Block-structure layer for triangular factors (DESIGN.md Sec. 14).

`FactorStructure` is a frozen, hashable description of WHERE the
nonzero blocks of a lower-triangular factor live:

  * ``dense``                 — every block at or below the diagonal;
  * ``banded(bandwidth)``     — element-level band: L[i, j] == 0 when
                                i - j > bandwidth;
  * ``block_sparse(mask)``    — explicit boolean block mask at the
                                mask's own granularity (n / len(mask)).

Following the hoisted phase-1 pattern, structure is analyzed ONCE per
(structure, n, n0) at admission/plan time — `analyze` is lru-cached
and everything it returns is static Python data, so the serving sweep
can make trace-time skip decisions and the steady state stays
zero-retrace.  The analysis yields:

  * the block-granularity nonzero mask at serving block size n0
    (coarser/finer masks are OR-coarsened conservatively, diagonal
    blocks forced present — every diagonal block sits on the critical
    path of its own block row, so the paper's selective-inversion dial
    keeps all of phase 1 and spends its selectivity in the sweep);
  * a per-block-row level schedule (level[i] = 1 + max level of i's
    prerequisites), a valid topological order of the block dependency
    DAG — tested by hypothesis in tests/test_structure.py;
  * per-column update spans: for source column i the half-open range
    [lo, hi) of dependent block rows, or None when column i has no
    off-diagonal nonzero block (the sweep then skips the trailing
    update for i entirely);
  * nonzero counts feeding the cost model (`cost_model`
    prices exactly the blocks the sweep executes).

Structure is a *promise* enforced at admission: `apply_block_mask`
zeroes every element outside the block mask with `jnp.where` (never a
multiply — 0 * NaN would leak), which makes skipping mathematically
safe and makes `block_sparse` with a full mask bit-identical to
`dense`.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

__all__ = ["FactorStructure", "StructureInfo", "analyze",
           "apply_block_mask"]

_KINDS = ("dense", "banded", "block_sparse")


@dataclass(frozen=True)
class FactorStructure:
    """Frozen, hashable block-structure descriptor.

    Participates verbatim in `SolveSpec`/`UpdateSpec` cache keys, so
    two factors with the same structure share one compiled program.
    Construct via the classmethods — `FactorStructure.dense()`,
    `.banded(bw)`, `.block_sparse(mask)` — or `parse` for CLI strings.
    """

    kind: str = "dense"
    bandwidth: int | None = None          # banded: element band width
    mask: tuple | None = None             # block_sparse: nested bools

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"structure kind must be one of {_KINDS}, got "
                f"{self.kind!r}")
        if self.kind == "banded":
            if self.bandwidth is None or int(self.bandwidth) < 1:
                raise ValueError(
                    "banded structure needs bandwidth >= 1 "
                    f"(got {self.bandwidth!r})")
            object.__setattr__(self, "bandwidth", int(self.bandwidth))
        elif self.bandwidth is not None:
            raise ValueError(f"{self.kind} structure takes no bandwidth")
        if self.kind == "block_sparse":
            m = np.asarray(self.mask, dtype=bool)
            if m.ndim != 2 or m.shape[0] != m.shape[1] or m.shape[0] < 1:
                raise ValueError(
                    f"block_sparse mask must be square 2-D, got shape "
                    f"{m.shape}")
            # normalize to nested tuples so the dataclass is hashable
            # and equality is structural
            object.__setattr__(
                self, "mask", tuple(tuple(bool(x) for x in row)
                                    for row in m))
        elif self.mask is not None:
            raise ValueError(f"{self.kind} structure takes no mask")

    # ------------------------- constructors -------------------------

    @classmethod
    def dense(cls) -> "FactorStructure":
        return cls("dense")

    @classmethod
    def banded(cls, bandwidth: int) -> "FactorStructure":
        return cls("banded", bandwidth=bandwidth)

    @classmethod
    def block_sparse(cls, mask) -> "FactorStructure":
        return cls("block_sparse", mask=mask)

    @classmethod
    def parse(cls, text: str, n: int | None = None) -> "FactorStructure":
        """Parse a CLI string: ``dense``, ``banded``/``banded:BW``,
        ``block-sparse``/``block_sparse``.

        Bare ``banded`` defaults to bandwidth n//8 (the bench regime)
        and bare ``block-sparse`` to a deterministic 8x8 example mask
        (diagonal + first subdiagonal + one low corner block); both
        need `n` only for the banded default.
        """
        text = text.strip().lower().replace("-", "_")
        if text == "dense":
            return cls.dense()
        if text.startswith("banded"):
            _, _, bw = text.partition(":")
            if bw:
                return cls.banded(int(bw))
            if n is None:
                raise ValueError("bare 'banded' needs n for the n//8 "
                                 "default; use banded:<bandwidth>")
            return cls.banded(max(1, n // 8))
        if text == "block_sparse":
            g = 8
            m = np.zeros((g, g), dtype=bool)
            for i in range(g):
                m[i, i] = True
                if i:
                    m[i, i - 1] = True
            m[g - 1, 0] = True
            return cls.block_sparse(m)
        raise ValueError(f"unknown structure {text!r} (want dense, "
                         "banded[:BW], block-sparse)")

    # --------------------------- queries ----------------------------

    @property
    def is_dense(self) -> bool:
        return self.kind == "dense"

    def validate_for(self, n: int, *, lower: bool = True,
                     transpose: bool = False) -> None:
        """Check this structure is usable for an order-n factor.

        Non-dense structure is restricted to the plain lower
        no-transpose path: the level-scheduled sweep walks block rows
        top-down, and upper/transposed factors reach it through the
        reversal gather which would silently invalidate the mask.
        """
        if self.is_dense:
            return
        if not lower or transpose:
            raise ValueError(
                "structured factors support lower=True, "
                "transpose=False only (the reversal gather would "
                "invalidate the block mask)")
        if self.kind == "banded" and self.bandwidth >= n:
            raise ValueError(
                f"bandwidth {self.bandwidth} >= n {n}: use dense")
        if self.kind == "block_sparse":
            g = len(self.mask)
            if n % g:
                raise ValueError(
                    f"block_sparse mask granularity {g} must divide "
                    f"n={n}")

    def block_mask(self, n: int, n0: int) -> np.ndarray:
        """(m, m) bool mask at serving granularity n0 (m = n // n0).

        Block (i, j) is True when the factor may hold a nonzero
        element there.  Diagonal blocks are always True; everything
        strictly above the diagonal is always False.  A block_sparse
        mask at a different granularity is OR-coarsened (conservative:
        a block is kept if ANY overlapping mask cell is set).
        """
        if n % n0:
            raise ValueError(f"n0={n0} must divide n={n}")
        m = n // n0
        out = np.zeros((m, m), dtype=bool)
        ii = np.arange(m)
        if self.kind == "dense":
            out = ii[:, None] >= ii[None, :]
        elif self.kind == "banded":
            # nearest element pair of block (i, j), j < i, is
            # (i*n0, (j+1)*n0 - 1): distance (i-j)*n0 - (n0-1)
            d = ii[:, None] - ii[None, :]
            out = (d >= 0) & (d * n0 - (n0 - 1) <= self.bandwidth)
        else:
            src = np.asarray(self.mask, dtype=bool)
            g = n // src.shape[0]          # element rows per mask cell
            for i in range(m):
                r0, r1 = i * n0, (i + 1) * n0
                for j in range(i + 1):
                    c0, c1 = j * n0, (j + 1) * n0
                    cell = src[r0 // g:(r1 + g - 1) // g,
                               c0 // g:(c1 + g - 1) // g]
                    out[i, j] = bool(cell.any())
        np.fill_diagonal(out, True)
        return np.tril(out)

    def nnz_blocks(self, n: int, n0: int) -> int:
        return int(self.block_mask(n, n0).sum())


@dataclass(frozen=True)
class StructureInfo:
    """Static admission-time analysis of one (structure, n, n0).

    All fields are plain Python data (hashable tuples) — safe to
    consult at trace time without touching devices.
    """

    n: int
    n0: int
    mask: tuple                       # (m, m) nested bool tuples
    levels: tuple                     # level[i] per block row
    spans: tuple                      # per column: (lo, hi) or None
    nnz_offdiag: int                  # off-diagonal nonzero blocks
    update_cols: int                  # columns with >= 1 dependent

    @property
    def m(self) -> int:
        return self.n // self.n0

    @property
    def n_levels(self) -> int:
        return 1 + max(self.levels) if self.levels else 0

    def mask_array(self) -> np.ndarray:
        return np.asarray(self.mask, dtype=bool)


@functools.lru_cache(maxsize=512)
def analyze(structure: FactorStructure, n: int, n0: int) -> StructureInfo:
    """Admission-time analysis: block mask, level schedule, update
    spans, nnz counts.  Pure + lru-cached, mirroring the hoisted
    phase-1 pattern (compute once, consult forever)."""
    bm = structure.block_mask(n, n0)
    m = n // n0
    levels = np.zeros(m, dtype=int)
    for i in range(m):
        deps = np.nonzero(bm[i, :i])[0]
        if deps.size:
            levels[i] = 1 + int(levels[deps].max())
    spans = []
    for j in range(m):
        dep = np.nonzero(bm[j + 1:, j])[0]
        if dep.size:
            spans.append((j + 1 + int(dep[0]), j + 2 + int(dep[-1])))
        else:
            spans.append(None)
    nnz_off = int(bm.sum() - m)
    return StructureInfo(
        n=n, n0=n0,
        mask=tuple(tuple(bool(x) for x in row) for row in bm),
        levels=tuple(int(x) for x in levels),
        spans=tuple(spans),
        nnz_offdiag=nnz_off,
        update_cols=sum(1 for s in spans if s is not None),
    )


def apply_block_mask(L, structure: FactorStructure, n0: int):
    """Zero every element of L outside the structure's block mask.

    Uses `jnp.where`, NOT a multiply: 0 * NaN/Inf would leak garbage
    into "zero" blocks and a multiply flips -0.0 signs, breaking the
    full-mask == dense bit-identity contract.  Dense structure returns
    L untouched (same object — the dense path stays byte-identical).
    """
    if structure.is_dense:
        return L
    n = L.shape[-1]
    bm = structure.block_mask(n, n0)
    elem = np.repeat(np.repeat(bm, n0, axis=0), n0, axis=1)
    return jnp.where(jnp.asarray(elem), L, jnp.zeros((), L.dtype))
