"""Distributed triangular inversion (paper Sec. V), SPMD bottom-up.

The paper's RecTriInv recursively splits L into quadrants, inverts the
two diagonal quadrants on *disjoint* processor subgrids, and completes
the off-diagonal block with two matrix multiplications:

    inv([[A, 0], [B, C]]) = [[inv(A), 0], [-inv(C) B inv(A), inv(C)]]

Divergent per-subgrid control flow does not fit SPMD, so we re-derive
the algorithm *bottom-up* ("recursive doubling"), which is the exact
mirror of the recursion tree executed level by level from the leaves:

  Phase A  invert all n/s0 diagonal s0-blocks in parallel (route whole
           blocks to devices with one all-to-all when n/s0 >= p — the
           TPU-native replacement for the paper's per-subgrid
           recursion; allgather fallback otherwise).
  Phase B  for s = s0, 2*s0, ..., n/2: finalize the off-diagonal block
           of every diagonal 2s-block with two *batched* distributed
           MMs (Sec. III algorithm, vmapped over the n/2s independent
           blocks; the batch plays the role of the paper's disjoint
           subgrids — all p processors cooperate on all blocks, which
           achieves a slightly *lower* bandwidth constant than the
           paper's shrinking-subgrid scheme; see EXPERIMENTS.md).

Latency is O(log(n/s0)) levels x O(log p) per level = O(log^2 p) — the
paper's headline polylog synchronization — and the flop/bandwidth costs
match Sec. V-B leading order.

Storage: cyclic, ``P("x", ("z", "y"))`` (see repro.core.grid).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import blocked, comm
from repro.core.grid import TrsmGrid
from repro.core.mm3d import mm3d_shard_batched

MESH_AXES = ("x", "y", "z")


# ------------------------ local-piece helpers ------------------------

def _diag_pieces(Lloc, m: int):
    """(nl, ncl) local cyclic piece -> (m, nl/m, ncl/m) local pieces of
    the m diagonal blocks."""
    nl, ncl = Lloc.shape
    V = Lloc.reshape(m, nl // m, m, ncl // m)
    idx = jnp.arange(m)
    return V[idx, :, idx, :]


def _set_diag_pieces(Lloc, pieces):
    nl, ncl = Lloc.shape
    m, a, b = pieces.shape
    V = Lloc.reshape(m, a, m, b)
    idx = jnp.arange(m)
    V = V.at[idx, :, idx, :].set(pieces)
    return V.reshape(nl, ncl)


def _assemble_blocks(Dg, p1: int, p2: int):
    """(p, m, a, b) gathered pieces (x-major flattened device axis) ->
    (m, a*p1, b*p1*p2) full blocks in natural element order."""
    p, m, a, b = Dg.shape
    R = Dg.reshape(p1, p1, p2, m, a, b)            # [x, y, z, i, l, c']
    R = jnp.transpose(R, (3, 4, 0, 5, 2, 1))       # [i, l, x, c', z, y]
    return R.reshape(m, a * p1, b * p2 * p1)


def _cyclic_piece(blocks, x, y, z, p1: int, p2: int):
    """(m, s, s) full blocks -> this device's cyclic piece
    (rows r = l*p1 + x, cols c = c'*p1*p2 + z*p1 + y): (m, s/p1, s/(p1p2)).
    x, y, z may be traced scalars."""
    m, s, _ = blocks.shape
    a, b = s // p1, s // (p1 * p2)
    R = blocks.reshape(m, a, p1, b, p2, p1)        # [i, l, x, c', z, y]
    R = jnp.moveaxis(R, (2, 4, 5), (0, 1, 2))      # [x, z, y, i, l, c']
    R = jax.lax.dynamic_index_in_dim(R, x, axis=0, keepdims=False)
    R = jax.lax.dynamic_index_in_dim(R, z, axis=0, keepdims=False)
    return jax.lax.dynamic_index_in_dim(R, y, axis=0, keepdims=False)


def _pieces_for_all(blocks, p1: int, p2: int):
    """(m, s, s) full blocks -> (p, m, s/p1, s/(p1p2)) cyclic pieces for
    every destination device, x-major device order."""
    m, s, _ = blocks.shape
    a, b = s // p1, s // (p1 * p2)
    R = blocks.reshape(m, a, p1, b, p2, p1)        # [i, l, x, c', z, y]
    R = jnp.transpose(R, (2, 5, 4, 0, 1, 3))       # [x, y, z, i, l, c']
    return R.reshape(p1 * p1 * p2, m, a, b)


# --------------------------- phase A ---------------------------

def _invert_diag_blocks_inplace(Lloc, *, n, s0, p1, p2, block_inv, mode):
    """Invert the n/s0 diagonal s0-blocks of L; return updated Lloc with
    the inverted blocks written back into cyclic storage."""
    m0 = n // s0
    p = p1 * p1 * p2
    D = _diag_pieces(Lloc, m0)                     # (m0, a, b)

    if mode == "alltoall":
        assert m0 % p == 0, (m0, p)
        mb = m0 // p
        Dr = comm.all_to_all(D, MESH_AXES, split_axis=0, concat_axis=0,
                             tiled=True)           # (m0, a, b) regrouped
        Dr = Dr.reshape(p, mb, *Dr.shape[1:])
        blocks = _assemble_blocks(Dr, p1, p2)      # (mb, s0, s0)
        binv = block_inv(blocks)
        S = _pieces_for_all(binv, p1, p2)          # (p, mb, a, b)
        Dt = comm.all_to_all(S.reshape(m0, *S.shape[2:]), MESH_AXES,
                             split_axis=0, concat_axis=0, tiled=True)
        return _set_diag_pieces(Lloc, Dt)
    elif mode == "allgather":
        xi = comm.axis_index("x")
        yi = comm.axis_index("y")
        zi = comm.axis_index("z")
        Dg = comm.all_gather(D, MESH_AXES, axis=0, tiled=False)
        blocks = _assemble_blocks(Dg, p1, p2)      # (m0, s0, s0)
        binv = block_inv(blocks)
        piece = _cyclic_piece(binv, xi, yi, zi, p1, p2)
        return _set_diag_pieces(Lloc, piece)
    raise ValueError(mode)


# --------------------------- phase B ---------------------------

def _doubling_levels(Lloc, *, n, s0, s_hi, p1, p2):
    """Run doubling levels s = s0 .. s_hi/2, finalizing off-diagonal
    blocks of every diagonal 2s-block up to block size s_hi."""
    s = s0
    while s < s_hi:
        nb = n // (2 * s)
        al, bl = 2 * s // p1, 2 * s // (p1 * p2)   # local piece dims
        blk = _diag_pieces(Lloc, nb)               # (nb, al, bl)
        a11 = blk[:, : al // 2, : bl // 2]         # inverted already
        a22 = blk[:, al // 2:, bl // 2:]           # inverted already
        l21 = blk[:, al // 2:, : bl // 2]          # original entries
        T = mm3d_shard_batched(l21, a11, m=s, n=s, k=s, p1=p1, p2=p2)
        new21 = -mm3d_shard_batched(a22, T, m=s, n=s, k=s, p1=p1, p2=p2)
        blk = blk.at[:, al // 2:, : bl // 2].set(new21)
        Lloc = _set_diag_pieces(Lloc, blk)
        s *= 2
    return Lloc


# --------------------------- entry points ---------------------------

def pick_s0(n: int, p1: int, p2: int) -> int:
    """Base block size: prefer m0 = n/s0 == p (one block per device,
    all-to-all routing); fall back to the smallest feasible block."""
    p = p1 * p1 * p2
    gran = p1 * p2
    if n % p == 0:
        s0 = n // p
        if s0 % gran == 0 and s0 >= gran:
            return s0
    s0 = gran
    while n % s0 != 0 and s0 < n:
        s0 *= 2
    return min(s0, n)


def phase_a_mode(n: int, s0: int, p: int) -> str:
    m0 = n // s0
    return "alltoall" if m0 % p == 0 else "allgather"


def tri_inv_shard(Lloc, *, n, p1, p2, s0=None, block_inv=None,
                  mode=None):
    """Per-shard body: full triangular inversion in cyclic storage."""
    s0 = s0 or pick_s0(n, p1, p2)
    mode = mode or phase_a_mode(n, s0, p1 * p1 * p2)
    binv = block_inv if block_inv is not None else blocked.tri_inv_batched
    Lloc = _invert_diag_blocks_inplace(Lloc, n=n, s0=s0, p1=p1, p2=p2,
                                       block_inv=binv, mode=mode)
    return _doubling_levels(Lloc, n=n, s0=s0, s_hi=n, p1=p1, p2=p2)


def block_diag_inv_shard(Lloc, *, n, n0, p1, p2, s0=None, block_inv=None,
                         mode=None):
    """Per-shard body: invert only the n/n0 diagonal n0-blocks (the
    paper's Diagonal-Inverter) using the same two-phase scheme, with
    doubling stopped at block size n0.  Off-diagonal panels between
    n0-blocks are untouched."""
    s0 = s0 or pick_s0(n, p1, p2)
    s0 = min(s0, n0)
    mode = mode or phase_a_mode(n, s0, p1 * p1 * p2)
    binv = block_inv if block_inv is not None else blocked.tri_inv_batched
    Lloc = _invert_diag_blocks_inplace(Lloc, n=n, s0=s0, p1=p1, p2=p2,
                                       block_inv=binv, mode=mode)
    if s0 < n0:
        nb = n // n0
        al, bl = n0 // p1, n0 // (p1 * p2)
        blk = _diag_pieces(Lloc, nb)
        # run the doubling levels on each n0-block independently by
        # flattening (n0-block, inner 2s-group) into one batch axis:
        s = s0
        while s < n0:
            inner = n0 // (2 * s)
            a2, b2 = 2 * s // p1, 2 * s // (p1 * p2)
            sub = blk.reshape(nb, inner, a2, inner, b2)
            idx = jnp.arange(inner)
            d = sub[:, idx, :, idx, :]             # (inner, nb, a2, b2)
            d = jnp.moveaxis(d, 0, 1).reshape(nb * inner, a2, b2)
            a11 = d[:, : a2 // 2, : b2 // 2]
            a22 = d[:, a2 // 2:, b2 // 2:]
            l21 = d[:, a2 // 2:, : b2 // 2]
            T = mm3d_shard_batched(l21, a11, m=s, n=s, k=s, p1=p1, p2=p2)
            new21 = -mm3d_shard_batched(a22, T, m=s, n=s, k=s,
                                        p1=p1, p2=p2)
            d = d.at[:, a2 // 2:, : b2 // 2].set(new21)
            d = jnp.moveaxis(d.reshape(nb, inner, a2, b2), 1, 0)
            sub = sub.at[:, idx, :, idx, :].set(d)
            blk = sub.reshape(nb, al, bl)
            s *= 2
        Lloc = _set_diag_pieces(Lloc, blk)
    return Lloc


def tri_inv_fn(grid: TrsmGrid, n: int, s0: int | None = None,
               block_inv=None, mode: str | None = None):
    """Jitted distributed inversion for fixed shapes (cyclic storage)."""
    body = functools.partial(tri_inv_shard, n=n, p1=grid.p1, p2=grid.p2,
                             s0=s0, block_inv=block_inv, mode=mode)
    spec = P("x", ("z", "y"))
    fn = compat.shard_map(body, mesh=grid.mesh, in_specs=(spec,),
                       out_specs=spec, check_vma=block_inv is None)
    return jax.jit(fn)


@functools.lru_cache(maxsize=64)
def _invert_fn(grid: TrsmGrid, n: int, s0, mode):
    return tri_inv_fn(grid, n, s0=s0, mode=mode)


def invert(L, grid: TrsmGrid, s0: int | None = None, mode=None):
    """Natural-layout convenience entry point (device-resident: on-device
    cyclic permutations, memoized compiled program)."""
    from repro.core.grid import cyclic_matrix_device
    n = L.shape[0]
    p1, p2 = grid.p1, grid.p2
    Lc = cyclic_matrix_device(jnp.asarray(L), p1, p1 * p2)
    out = _invert_fn(grid, n, s0, mode)(Lc)
    return cyclic_matrix_device(out, p1, p1 * p2, inverse=True)
