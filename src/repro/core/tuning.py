"""Parameter tuning (paper Sec. VIII).

Given (n, k, p) this module decides the processor-grid layout
(p1 x p1 x p2), the diagonal-block size n0, and the inversion subgrid
(r1, r2) — first from the paper's closed forms, then *snapped* to
feasible integers (powers of two, divisibility with the mesh and the
matrix), and finally refined by an argmin over the alpha-beta-gamma
model ("This cost analysis makes it possible to determine optimal block
sizes and processor grids a priori", Sec. I).
"""

from __future__ import annotations

import dataclasses
import functools
import math

from repro.core import cost_model as cm


# ------------------- calibrated default machine -------------------

@functools.lru_cache(maxsize=1)
def calibration() -> cm.Calibration | None:
    """The committed measured-cost calibration
    (``benchmarks/BENCH_overlap.json``, DESIGN.md Sec. 16), or None
    when absent.  Cached for the process lifetime: planners consult it
    on every decision."""
    return cm.load_calibration()


@functools.lru_cache(maxsize=1)
def default_machine() -> cm.Machine:
    """The machine every planner prices with when the caller passes
    none: the TPU v5e preset RESCALED by the committed calibration, so
    ``SolveSpec.auto``, :func:`serving_n0`,
    :func:`choose_serving_method` and ``fleet.plan_fleet`` all plan
    from measured-cost-corrected numbers.  Falls back to the nominal
    preset when no calibration is committed.  An explicit ``machine=``
    argument anywhere in this module bypasses calibration entirely
    (the caller knows its hardware)."""
    m = cm.tpu_v5e()
    cal = calibration()
    return cal.apply(m) if cal is not None else m


def default_dispatch_s(fallback: float) -> float:
    """Per-program dispatch overhead in the SAME units as the
    calibrated steady costs: the measured value from the committed
    calibration when present, else ``fallback`` (the fleet planner's
    nominal constant).  Comparing calibrated steady seconds against an
    uncalibrated dispatch constant would skew every absolute-seconds
    decision (bucket merges, queue-wait admission)."""
    cal = calibration()
    if cal is not None and cal.dispatch_s is not None:
        return cal.dispatch_s
    return fallback


@dataclasses.dataclass(frozen=True)
class TrsmPlan:
    """A resolved execution plan for one (n, k, p) solve problem.

    Fields:

    * ``regime`` — which of the paper's three asymptotic regimes the
      problem falls in (see :func:`regime`): ``"1d"`` (many RHS columns
      relative to n — parallelize over columns), ``"2d"`` (tall solves,
      k << n — the square processor grid), ``"3d"`` (the general case
      with a nontrivial replication axis).
    * ``p1, p2`` — processor grid factors: the mesh is p1 x p1 x p2
      (axes "x", "y", "z"); ``grid`` gives the tuple.
    * ``n0`` — diagonal-block size: the granularity of the paper's
      Diagonal-Inverter and of the sweep (one GEMM solve + one trailing
      update per n0-block).  Smaller n0 = more latency, less inversion
      flop overhead; the Sec. VIII sweet spot balances the two.
    * ``r1, r2`` — the inversion subgrid (Sec. VI-A): each diagonal
      block is inverted on an r1 x r1 x r2 subset of processors.
    * ``cost`` — the alpha-beta-gamma cost (S messages, W words,
      F flops) the model predicts for this plan.
    * ``n, k, p`` — the problem the plan was derived for.

    * ``method`` — which algorithm the plan is for: ``"inv"``
      (It-Inv-TRSM, what :func:`tune` costs) or ``"rec"`` (the
      recursive baseline; :func:`choose_method` stamps the winner).

    Plans are produced by :func:`tune` / :func:`tune_for_grid` /
    :func:`choose_method`; ``repro.core.solver.SolveSpec.auto`` (and
    through it the compiled-solver cache) consumes a plan VERBATIM
    when the caller leaves method/n0 unset, so a plan is also the
    provenance record for "why did the solver pick this block size".
    """
    regime: str          # "1d" | "2d" | "3d"
    p1: int
    p2: int
    n0: int
    r1: int
    r2: int
    cost: cm.Cost
    n: int
    k: int
    p: int
    method: str = "inv"

    @property
    def grid(self):
        return (self.p1, self.p1, self.p2)


def regime(n: int, k: int, p: int) -> str:
    """Classify (n, k, p) into the paper's parameter regimes.

    ``"1d"`` (n < 4k/p): the RHS dominates — a 1 x 1 x p grid with
    columns distributed is optimal.  ``"2d"`` (n > 4k sqrt(p)): the
    factor dominates — sqrt(p) x sqrt(p) x 1.  ``"3d"`` otherwise:
    both matter, and the z-axis replication of the paper's 3D
    algorithms pays for itself.  The thresholds are the crossing
    points of the Sec. VIII closed-form costs."""
    if n < 4 * k / p:
        return "1d"
    if n > 4 * k * math.sqrt(p):
        return "2d"
    return "3d"


def ideal_params(n: int, k: int, p: int) -> dict:
    """The paper's closed-form optima (Sec. VIII tables), un-snapped."""
    r = regime(n, k, p)
    if r == "1d":
        return dict(regime=r, p1=1.0, p2=float(p), n0=float(n),
                    r1=p ** (1 / 3), r2=p ** (1 / 3))
    if r == "2d":
        n0 = (n * k ** 3 * math.sqrt(p)) ** 0.25
        rr = (k / n) ** 0.25 * p ** (3 / 8)
        return dict(regime=r, p1=math.sqrt(p), p2=1.0, n0=n0, r1=rr, r2=rr)
    p1 = (p * n / (4 * k)) ** (1 / 3)
    p2 = (math.sqrt(p) * 4 * k / n) ** (2 / 3)
    n0 = min(math.sqrt(n * k), float(n))
    rr = min(p * math.sqrt(n * k) / n, float(p)) ** (1 / 3)
    return dict(regime=r, p1=p1, p2=p2, n0=n0, r1=rr, r2=rr)


def _pow2_divisors(x: int) -> list[int]:
    out = [1]
    d = 2
    while x % d == 0:
        out.append(d)
        d *= 2
    return out


def _snap_pow2(x: float, lo: int = 1, hi: int | None = None) -> int:
    """Nearest power of two to x within [lo, hi]."""
    x = max(x, 1.0)
    c = 2 ** round(math.log2(x))
    c = max(c, lo)
    if hi is not None:
        c = min(c, hi)
    return int(c)


def feasible_grids(p: int) -> list[tuple[int, int]]:
    """All (p1, p2) with p1^2 * p2 == p, p1 and p2 powers of two."""
    out = []
    p1 = 1
    while p1 * p1 <= p:
        if p % (p1 * p1) == 0:
            p2 = p // (p1 * p1)
            # only power-of-two axes are mappable onto TPU mesh factors
            if (p1 & (p1 - 1)) == 0 and (p2 & (p2 - 1)) == 0:
                out.append((p1, p2))
        p1 *= 2
    return out


def _feasible_n0(n: int, p1: int, p2: int) -> list[int]:
    """n0 must divide n and be a multiple of p1*p2 (cyclic layout needs
    p1 | n0 rows and p1*p2 | n0 cols for contiguous local blocks)."""
    base = max(p1 * p2, 1)
    out = []
    n0 = base
    while n0 <= n:
        if n % n0 == 0 and n0 % base == 0:
            out.append(n0)
        n0 *= 2
    if not out:
        out = [n]
    return out


def _inv_subgrid(n: int, n0: int, p: int) -> tuple[int, int]:
    """r1, r2 per Sec. VI-A: r1^2 r2 = p n0 / n, ideal ratio r2 = 4 r1.

    The subgrid is a processor ASSIGNMENT, so feasibility means
    r1^2 * r2 <= p.  Snapping each factor to its nearest power of two
    independently can overshoot (e.g. q = 6 snaps r2 from 3 up to 8,
    an 8-processor subgrid on a 6-processor machine); clamp each factor
    back down in power-of-two steps until the product fits."""
    q = max(1.0, min(float(p), p * n0 / n))
    r1 = _snap_pow2((q / 4.0) ** (1 / 3))
    while r1 > 1 and r1 * r1 > p:
        r1 //= 2
    r2 = _snap_pow2(max(1, int(q) // (r1 * r1)))
    while r2 > 1 and r1 * r1 * r2 > p:
        r2 //= 2
    return r1, r2


def tune(n: int, k: int, p: int,
         machine: cm.Machine | None = None) -> TrsmPlan:
    """Model-driven a-priori choice of (p1, p2, n0, r1, r2).

    Starts from the Sec. VIII closed forms, then argmins the full
    alpha-beta-gamma model over the feasible (power-of-two)
    neighborhood.  ``machine`` supplies the (alpha, beta, gamma)
    constants — latency, per-word, per-flop — defaulting to TPU v5e
    ICI numbers (``cost_model.tpu_v5e``); a high-alpha MPI-cluster
    machine shifts the argmin toward larger n0 / more replication,
    exactly the paper's Sec. IX sensitivity.  Precision does not enter
    the plan: a bf16 sweep changes gamma and beta by the same factor
    at leading order, leaving the argmin unchanged.  The default
    machine is CALIBRATED when a committed measurement file exists
    (:func:`default_machine`, DESIGN.md Sec. 16)."""
    machine = machine or default_machine()
    grids = feasible_grids(p)
    if not grids:
        # p admits no power-of-two p1^2 * p2 == p factorization (e.g.
        # p = 6): plan for the largest power of two <= p — using fewer
        # processors is always a valid (and mappable) assignment
        grids = feasible_grids(2 ** int(math.log2(p)))
    best = None
    for p1, p2 in grids:
        for n0 in _feasible_n0(n, p1, p2):
            r1, r2 = _inv_subgrid(n, n0, p)
            c = cm.it_inv_trsm_cost(n, k, n0, p1, p2, r1, r2)
            t = c.time(machine)
            if best is None or t < best[0]:
                best = (t, TrsmPlan(regime(n, k, p), p1, p2, n0, r1, r2,
                                    c, n, k, p))
    return best[1]


def tune_for_grid(n: int, k: int, grid,
                  machine: cm.Machine | None = None) -> TrsmPlan:
    """Tune n0 (and the inversion subgrid) for an already-built mesh.

    Same argmin as :func:`tune` but with (p1, p2) pinned to the given
    TrsmGrid — this is what ``repro.core.session.resolve_plan`` calls
    when a solver is requested without an explicit n0, so it is the
    default-n0 policy of the whole serving stack."""
    machine = machine or default_machine()
    p1, p2 = grid.p1, grid.p2
    p = grid.p
    best = None
    for n0 in _feasible_n0(n, p1, p2):
        r1, r2 = _inv_subgrid(n, n0, p)
        c = cm.it_inv_trsm_cost(n, k, n0, p1, p2, r1, r2)
        t = c.time(machine)
        if best is None or t < best[0]:
            best = (t, TrsmPlan(regime(n, k, p), p1, p2, n0, r1, r2,
                                c, n, k, p))
    return best[1]


def serving_n0(n: int, grid, structure=None) -> int:
    """Diagonal-block size for the HOISTED steady state (factor banks,
    DESIGN.md Sec. 9).

    The Sec. VIII argmin balances sweep latency (fewer, larger blocks)
    against diagonal-inversion flops (more, smaller blocks).  A factor
    bank inverts the diagonal blocks ONCE at admission, so the
    inversion term leaves the per-solve cost entirely and the argmin
    degenerates monotonically toward the largest feasible block.  We
    stop at n0 <= n/2 (the largest feasible block that keeps m >= 2,
    i.e. keeps the substitution structure of the sweep) as the
    stability hedge: the Sec. V bound on inversion error grows with
    the inverted block's order, and m = 1 would be full triangular
    inversion — an explicit opt-in (n0 = n), not a preference.  The
    one exception: when the cyclic layout admits NO block smaller than
    n (n0 = n is the only feasible size, e.g. n = p1^2*p2), m = 1 is
    forced rather than chosen and is returned — there is no hedged
    alternative to decline to pick.  k does not enter: with inversion
    hoisted, every remaining cost term scales the same way in k.

    With a non-dense ``structure`` the monotone argument breaks: a
    LARGER block coarsens the mask (OR-coarsening fills in zero
    blocks), so the sweep skips less.  The structured path argmins the
    structure-priced steady cost (``cost_model.it_inv_trsm_steady_cost``
    at a nominal k) over the same hedged feasible set, plus one alpha
    of dispatch overhead per executed sweep step — a step costs at
    least one program dispatch even on a 1-processor grid, where every
    model comm term is zero and a pure flop argmin would otherwise
    collapse to n0 = 1 (an m-step unrolled sweep of 1x1 blocks).  Ties
    go to the larger block (fewer sweep steps)."""
    feas = _feasible_n0(n, grid.p1, grid.p2)
    capped = [n0 for n0 in feas if n0 <= n // 2]
    cands = capped if capped else [max(feas)]
    if structure is None or structure.is_dense:
        return max(cands)
    from repro.core.structure import analyze
    machine = default_machine()
    best = None
    for n0 in sorted(cands, reverse=True):   # ties -> larger block
        info = analyze(structure, n, n0)
        t = cm.it_inv_trsm_steady_cost(
            n, 16, n0, grid.p1, grid.p2, structure=structure,
            overlap=True
        ).time(machine)
        t += machine.alpha * (info.m + info.update_cols)
        if best is None or t < best[0]:
            best = (t, n0)
    return best[1]


def serving_steady_s(n: int, k: int, grid, *,
                     machine: cm.Machine | None = None,
                     n0: int | None = None, structure=None,
                     overlap: bool = True) -> float:
    """Modeled steady-state seconds for one order-n, width-k solve on
    the grid — the HOISTED It-Inv sweep, i.e. the serving
    configuration (DESIGN.md Secs. 9, 15).  The one spelling of this
    quantity: the fleet planner prices bucket merges with it and the
    admission controller seeds its queue-wait estimates with it, so
    both control decisions price the same model.  ``n0`` defaults to
    the hoisted-serving argmin; ``structure`` prices the
    level-scheduled sweep's skipped blocks; ``overlap`` (on by
    default, matching the serving tier's resolved ``SolveSpec.overlap``)
    prices the double-buffered sweep's ``max(comm, comp)`` pipeline
    (Sec. 16).  The default machine is calibrated when a committed
    measurement exists."""
    machine = machine or default_machine()
    n0 = n0 if n0 is not None else serving_n0(n, grid,
                                              structure=structure)
    return cm.it_inv_trsm_steady_cost(
        n, k, n0, grid.p1, grid.p2, structure=structure,
        overlap=overlap).time(machine)


def tuning_table(n: int, k: int, p: int) -> dict:
    """Sec. VIII report: ideal closed forms vs snapped/argmin'd plan."""
    plan = tune(n, k, p)
    return dict(ideal=ideal_params(n, k, p),
                plan=dataclasses.asdict(plan))


def choose_method(n: int, k: int, p: int,
                  machine: cm.Machine | None = None):
    """Beyond-paper auto-dispatch: pick Rec-TRSM or It-Inv-TRSM from
    the alpha-beta-gamma model instantiated with the MACHINE constants.

    The paper's latency-for-bandwidth trade wins on high-alpha networks
    (MPI clusters, cross-pod DCN) and for latency-dominated shapes
    (k << n); on low-alpha ICI with n ~ k the recursive algorithm's
    lower bandwidth wins.  Returns (method, plan, modeled_times).
    The default machine is calibrated when a committed measurement
    exists (Sec. 16)."""
    machine = machine or default_machine()
    plan = tune(n, k, p, machine)
    t_inv = plan.cost.time(machine)
    t_rec = cm.rec_trsm_cost(n, k, p).time(machine)
    method = "inv" if t_inv <= t_rec else "rec"
    plan = dataclasses.replace(plan, method=method)
    return method, plan, {"inv": t_inv, "rec": t_rec}


def choose_serving_method(n: int, k: int, grid,
                          machine: cm.Machine | None = None,
                          n0: int | None = None,
                          rec_model: str = "paper",
                          structure=None, overlap: bool = True):
    """Auto-dispatch for the HOISTED steady state (a resident factor:
    phase 1 — the Diagonal-Inverter — runs once at admission).

    :func:`choose_method` compares the FUSED It-Inv cost, inversion
    term included; for a serving solver that term leaves the per-solve
    cost entirely, so the fused comparison systematically under-credits
    "inv" (exactly the regime the hoisting optimization targets).
    This variant compares Rec-TRSM against the sweep-only steady cost
    at the serving block size, on the pinned grid.  Returns
    ``(method, n0, modeled_times)`` — n0 is the serving argmin (or the
    caller's, passed through).  ``rec_model="tang2024"`` prices the
    recursive side with the corrected bandwidth term
    (:func:`repro.core.cost_model.rec_trsm_cost`) — the fleet planner's
    setting, so recursion is not over-credited.

    ``structure`` prices BOTH sides from the declared block structure:
    the It-Inv side with the level-scheduled sweep's skipped blocks,
    and the recursive side from the ``StructureInfo`` nnz counts (the
    admission mask zeroes the factor, so rec's L-proportional words
    and flops shrink with the fill even though its schedule cannot
    skip messages — ``cost_model.rec_trsm_cost``).  Pricing rec dense,
    as before, over-priced it on banded/block-sparse specs and biased
    the dispatch toward "inv" beyond what the skips justify.

    ``overlap`` (default on, matching the serving tier's resolved
    ``SolveSpec.overlap``) prices the It-Inv sweep pipelined; the
    default machine is calibrated when a committed measurement exists
    (Sec. 16)."""
    machine = machine or default_machine()
    n0 = n0 if n0 is not None else serving_n0(n, grid,
                                              structure=structure)
    t_inv = cm.it_inv_trsm_steady_cost(n, k, n0, grid.p1, grid.p2,
                                       structure=structure,
                                       overlap=overlap).time(machine)
    t_rec = cm.rec_trsm_cost(n, k, grid.p, model=rec_model,
                             structure=structure).time(machine)
    method = "inv" if t_inv <= t_rec else "rec"
    return method, n0, {"inv": t_inv, "rec": t_rec}
