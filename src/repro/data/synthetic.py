"""Deterministic synthetic data pipeline.

Every batch is a pure function of (step, host, shape) via counter-based
hashing (threefry), which gives the three properties the fault-tolerance
layer needs with zero I/O:

  * determinism: restarting from step s reproduces the exact stream, so
    checkpoint-restart is bit-exact (tested);
  * disjointness: hosts draw from disjoint key spaces, no coordination;
  * elasticity: re-sharding to a different host count re-partitions the
    same global stream (keys depend on the *global* example index).

A background-thread prefetcher overlaps host-side batch synthesis with
device compute (stand-in for a real storage-backed loader).
"""

from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig, ShapeConfig


def _example_key(step: int, global_index: int):
    return jax.random.fold_in(jax.random.key(step), global_index)


def host_batch(cfg: ModelConfig, seq: int, global_batch: int, step: int,
               host: int = 0, n_hosts: int = 1) -> dict:
    """The host's slice of the global batch at ``step``."""
    assert global_batch % n_hosts == 0
    per = global_batch // n_hosts
    idx = np.arange(host * per, (host + 1) * per)
    keys = jax.vmap(lambda i: _example_key(step, i))(jnp.asarray(idx))
    toks = jax.vmap(
        lambda k: jax.random.randint(k, (seq + 1,), 0, cfg.vocab))(keys)
    batch = {"tokens": toks[:, :seq], "labels": toks[:, 1:]}
    if cfg.embed_inputs:
        emb = jax.vmap(lambda k: jax.random.normal(
            k, (seq, cfg.d_model), jnp.float32))(keys)
        batch = {"embeds": emb, "labels": toks[:, 1:]}
    if cfg.enc_dec:
        frames = jax.vmap(lambda k: jax.random.normal(
            k, (cfg.enc_frames, cfg.d_model), jnp.float32))(keys)
        batch["frames"] = frames
    return batch


class Prefetcher:
    """Runs host_batch on a worker thread, ``depth`` batches ahead."""

    def __init__(self, cfg, seq, global_batch, start_step=0, depth=2,
                 host=0, n_hosts=1):
        self.cfg, self.seq, self.gb = cfg, seq, global_batch
        self.host, self.n_hosts = host, n_hosts
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._work, daemon=True)
        self._t.start()

    def _work(self):
        s = self._step
        while not self._stop.is_set():
            b = host_batch(self.cfg, self.seq, self.gb, s, self.host,
                           self.n_hosts)
            while not self._stop.is_set():
                try:
                    self._q.put((s, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._t.join(timeout=5)
