"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute under interpret=True —
the kernel body runs in Python per grid step, validating the exact TPU
program.  On TPU the same calls compile to Mosaic.  ``block_inv_kernel``
is the drop-in hook for the distributed solvers' ``block_inv=``
parameter (repro.core.inv_trsm / tri_inv).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import trmm as _trmm
from repro.kernels import tri_inv_block as _tib
from repro.kernels import trsm_block as _tsb
from repro.kernels import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bt", "bn", "accum_dtype"))
def trmm(L, X, bt: int = 128, bn: int = 128, accum_dtype=jnp.float32,
         block_mask=None):
    """C = tril(L) @ X (structure-skipping tiled MXU kernel).

    ``accum_dtype`` is the MXU accumulation width (scratch +
    preferred_element_type); float32 by default so bf16 operands
    accumulate at full precision.  ``block_mask`` (optional
    (n/bt, n/bt) validity tiles, e.g. ``FactorStructure.block_mask``)
    skips zero tiles on top of the above-diagonal skip."""
    return _trmm.trmm(L, X, bt=bt, bn=bn, accum_dtype=accum_dtype,
                      interpret=_interpret(), block_mask=block_mask)


@functools.partial(jax.jit, static_argnames=("accum_dtype",))
def tri_inv_blocks(Ls, accum_dtype=jnp.float32, valid=None):
    """Batched lower-triangular inversion (doubling, in-VMEM); level
    GEMMs accumulate at ``accum_dtype``.  ``valid`` (optional (m,)
    mask) writes zeros for flagged-out stack entries instead of
    inverting them."""
    return _tib.tri_inv_blocks(Ls, accum_dtype=accum_dtype,
                               interpret=_interpret(), valid=valid)


@functools.partial(jax.jit, static_argnames=("bn", "accum_dtype"))
def trsm_substitution(L, B, bn: int = 128, accum_dtype=jnp.float32,
                      valid=None):
    """Baseline substitution TRSM (VPU-serial; what the paper replaces).
    The row recurrence runs at ``accum_dtype``.  ``valid`` (optional
    (m,) mask) skips flagged-out stack entries, writing zeros."""
    return _tsb.trsm_substitution(L, B, bn=bn, accum_dtype=accum_dtype,
                                  interpret=_interpret(), valid=valid)


def block_inv_kernel(blocks: jnp.ndarray) -> jnp.ndarray:
    """Hook matching the ``block_inv`` signature of the distributed
    solvers: (m, n0, n0) -> batched inverses, Pallas-backed when the
    block size is a power of two (>= 2), pure-jnp doubling otherwise.

    Degenerate blocks are rejected eagerly: a zero-sized batch or a
    0x0 / non-square block would otherwise flow into the Pallas grid
    with a 0-extent dimension and fail deep inside Mosaic (or silently
    produce an empty program)."""
    if blocks.ndim != 3:
        raise ValueError(
            f"block_inv_kernel expects a (m, n0, n0) stack of blocks, "
            f"got ndim={blocks.ndim} shape={blocks.shape}")
    m, r, n0 = blocks.shape
    if r != n0:
        raise ValueError(
            f"diagonal blocks must be square, got {r}x{n0} "
            f"(shape={blocks.shape})")
    if m == 0 or n0 == 0:
        raise ValueError(
            f"degenerate block batch {blocks.shape}: zero-sized batches "
            f"cannot be inverted — check n0 / grid divisibility upstream")
    if n0 & (n0 - 1) == 0 and n0 >= 2:
        return _tib.tri_inv_blocks(blocks, interpret=_interpret())
    from repro.core import blocked
    return blocked.tri_inv_batched(blocks)
