"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth that tests/test_kernels.py sweeps against
(shapes x dtypes, interpret=True execution of the kernels on CPU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def trmm_ref(L: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """C = tril(L) @ X."""
    return jnp.tril(L) @ X


def tri_inv_blocks_ref(Ls: jnp.ndarray) -> jnp.ndarray:
    """Batched lower-triangular inversion: (m, n0, n0) -> inverses."""
    n0 = Ls.shape[-1]
    eye = jnp.eye(n0, dtype=Ls.dtype)

    def one(L):
        return jax.scipy.linalg.solve_triangular(L, eye, lower=True)

    return jax.vmap(one)(Ls)


def trsm_ref(L: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """X with tril(L) X = B."""
    return jax.scipy.linalg.solve_triangular(jnp.tril(L), B, lower=True)
