"""Pallas TPU kernel: batched inversion of small lower-triangular blocks.

This is the compute core of the paper's Diagonal-Inverter (Sec. VI-A):
after the all-to-all routes whole n0 x n0 diagonal blocks to devices,
each device inverts a *stack* of blocks.  The kernel runs the bottom-up
doubling scheme (Sec. V re-derived for SPMD, see repro.core.blocked)
entirely in VMEM:

    level s: for every diagonal 2s-block  [[A, 0], [B, C]]  (A, C already
    inverted) finalize the off-diagonal:  B' = -C^-1 B A^-1  — two MXU
    matmuls batched over all n0/(2s) sub-blocks.

All log2(n0) levels execute on one VMEM-resident tile, so the block is
read from HBM exactly once and written once — arithmetic intensity
n0/3 flops/byte at the HBM level, vs O(1) for row-by-row substitution.
The first level (1x1 diagonal) is a vectorized reciprocal on the VPU;
every other level is MXU work.

Grid: one block per grid step (the stack dimension); block sizes up to
512 fit VMEM (3 * n0^2 * 4B well under 16 MiB).  n0 must be a power of
two (the Diagonal-Inverter guarantees this by construction).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _doubling_inverse(L: jnp.ndarray,
                      accum_dtype=jnp.float32) -> jnp.ndarray:
    """In-VMEM bottom-up doubling inversion of one (n0, n0) tile.
    Static python loop over log2(n0) levels; jnp ops only.  The level
    GEMMs accumulate at ``accum_dtype`` (MXU preferred_element_type)."""
    n0 = L.shape[-1]
    eye = jnp.eye(n0, dtype=L.dtype)
    d = jnp.diagonal(L)
    A = L * (1.0 - eye) + jnp.diag(1.0 / d)
    s = 1
    while s < n0:
        nb = n0 // (2 * s)
        V = A.reshape(nb, 2 * s, nb, 2 * s)
        idx = jnp.arange(nb)
        blk = V[idx, :, idx, :]                     # (nb, 2s, 2s)
        a11i = blk[:, :s, :s]
        a22i = blk[:, s:, s:]
        l21 = blk[:, s:, :s]
        t = jax.lax.dot_general(l21, a11i, (((2,), (1,)), ((0,), (0,))),
                                preferred_element_type=accum_dtype)
        n21 = -jax.lax.dot_general(a22i, t.astype(A.dtype),
                                   (((2,), (1,)), ((0,), (0,))),
                                   preferred_element_type=accum_dtype)
        blk = blk.at[:, s:, :s].set(n21.astype(A.dtype))
        V = V.at[idx, :, idx, :].set(blk)
        A = V.reshape(n0, n0)
        s *= 2
    return A


def _tri_inv_kernel(l_ref, o_ref, *, accum_dtype):
    o_ref[0] = _doubling_inverse(l_ref[0], accum_dtype)


def _tri_inv_valid_kernel(v_ref, l_ref, o_ref, *, accum_dtype):
    """Validity-gated variant: an invalid stack entry (a block the
    structure's level schedule never touches) writes zeros instead of
    inverting — no division by its (arbitrary) diagonal."""
    @pl.when(v_ref[0, 0] != 0)
    def _inv():
        o_ref[0] = _doubling_inverse(l_ref[0], accum_dtype)

    @pl.when(v_ref[0, 0] == 0)
    def _skip():
        o_ref[0] = jnp.zeros_like(o_ref[0])


def _out_sds(shape, dtype, like):
    """ShapeDtypeStruct matching ``like``'s varying-manual-axes so the
    kernel composes inside shard_map bodies."""
    vma = getattr(jax.core.get_aval(like), "vma", None)
    if vma:
        return jax.ShapeDtypeStruct(shape, dtype, vma=vma)
    return jax.ShapeDtypeStruct(shape, dtype)


def tri_inv_blocks(Ls: jnp.ndarray, *, accum_dtype=jnp.float32,
                   interpret: bool = False, valid=None):
    """Invert a stack (m, n0, n0) of lower-triangular blocks.

    ``accum_dtype``: accumulation width of the doubling-level GEMMs
    (float32 by default — full MXU accumulation for bf16 operands).

    ``valid``: optional (m,) validity mask — stack entries flagged 0
    (blocks a :class:`~repro.core.structure.FactorStructure` schedule
    never touches) are written as zeros instead of inverted, so their
    arbitrary diagonals never reach a reciprocal.  ``None`` (default)
    compiles the exact unconditional kernel."""
    m, n0, n02 = Ls.shape
    assert n0 == n02 and (n0 & (n0 - 1)) == 0, Ls.shape
    if valid is None:
        return pl.pallas_call(
            functools.partial(_tri_inv_kernel,
                              accum_dtype=jnp.dtype(accum_dtype)),
            grid=(m,),
            in_specs=[pl.BlockSpec((1, n0, n0), lambda b: (b, 0, 0))],
            out_specs=pl.BlockSpec((1, n0, n0), lambda b: (b, 0, 0)),
            out_shape=_out_sds((m, n0, n0), Ls.dtype, Ls),
            interpret=interpret,
        )(Ls)
    v = jnp.asarray(valid, jnp.int32).reshape(m, 1)
    return pl.pallas_call(
        functools.partial(_tri_inv_valid_kernel,
                          accum_dtype=jnp.dtype(accum_dtype)),
        grid=(m,),
        in_specs=[pl.BlockSpec((1, 1), lambda b: (b, 0)),
                  pl.BlockSpec((1, n0, n0), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, n0, n0), lambda b: (b, 0, 0)),
        out_shape=_out_sds((m, n0, n0), Ls.dtype, Ls),
        interpret=interpret,
    )(v, Ls)
