"""Pallas TPU kernel: triangular matrix-matrix multiply  C = tril(L) @ X.

This is the MXU workhorse of It-Inv-TRSM: both the solve step (multiply
by the inverted diagonal block) and the trailing update (off-diagonal
panel times X_i) are triangular-structured GEMMs.  The kernel exploits
the structure by *skipping* every (row-tile, k-tile) pair above the
diagonal — for an n x n triangular operand that halves the compute and
the HBM->VMEM traffic relative to a dense GEMM.

Tiling: square (bt x bt) L tiles so the zero/nonzero tile test is exact
(tile (i, kk) is identically zero iff kk > i); X and C tiles are
(bt x bn).  The k-loop is the innermost grid dimension; a VMEM scratch
accumulator carries partial sums in f32 regardless of operand dtype
(MXU-native mixed precision), and tiles with kk > i are skipped with
pl.when, so the dominant loop issues one MXU matmul per visited tile.

Block shapes default to (128, 128): MXU-aligned (the systolic array is
128x128 after dtype packing) and three live tiles fit comfortably in
the ~16 MiB of VMEM up to bt = bn = 512.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _trmm_kernel(l_ref, x_ref, o_ref, acc_ref, *, nk: int, accum_dtype):
    i = pl.program_id(0)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(kk <= i)          # tiles strictly above the diagonal are 0
    def _mac():
        acc_ref[...] += jnp.dot(l_ref[...], x_ref[...],
                                preferred_element_type=accum_dtype)

    @pl.when(kk == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _trmm_masked_kernel(m_ref, l_ref, x_ref, o_ref, acc_ref, *,
                        nk: int, accum_dtype):
    """The structure-skipping variant: one extra (1, 1) validity tile
    per (i, kk); a zero entry skips the MXU op exactly like the
    above-diagonal test (DESIGN.md Sec. 14)."""
    i = pl.program_id(0)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((kk <= i) & (m_ref[0, 0] != 0))
    def _mac():
        acc_ref[...] += jnp.dot(l_ref[...], x_ref[...],
                                preferred_element_type=accum_dtype)

    @pl.when(kk == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _out_sds(shape, dtype, like):
    vma = getattr(jax.core.get_aval(like), "vma", None)
    if vma:
        return jax.ShapeDtypeStruct(shape, dtype, vma=vma)
    return jax.ShapeDtypeStruct(shape, dtype)


def trmm(L: jnp.ndarray, X: jnp.ndarray, *, bt: int = 128, bn: int = 128,
         accum_dtype=jnp.float32, interpret: bool = False,
         block_mask=None) -> jnp.ndarray:
    """C = tril(L) @ X with L: (n, n), X: (n, k).

    ``accum_dtype``: dtype of the VMEM scratch accumulator and the MXU
    partial sums (``preferred_element_type``).  Defaults to float32 —
    the MXU-native accumulation width for bf16/f32 inputs; pass the
    operand dtype to reproduce a narrow-accumulation GEMM exactly.

    ``block_mask``: optional (n/bt, n/bt) validity mask at TILE
    granularity (a ``FactorStructure.block_mask`` when bt == n0).  A
    zero tile skips the MXU op and its VMEM traffic on top of the
    above-diagonal skip; ``None`` (the default) compiles the exact
    dense-triangular kernel unchanged."""
    n, n2 = L.shape
    _, k = X.shape
    assert n == n2 and X.shape[0] == n, (L.shape, X.shape)
    accum_dtype = jnp.dtype(accum_dtype)
    bt = min(bt, n)
    bn = min(bn, k)
    assert n % bt == 0 and k % bn == 0, (n, k, bt, bn)
    ni, nj, nk = n // bt, k // bn, n // bt

    grid = (ni, nj, nk)
    # clamp the k-index for skipped tiles so we never prefetch
    # out of the triangle (the compute is pl.when-guarded).
    l_spec = pl.BlockSpec((bt, bt), lambda i, j, kk: (i, jnp.minimum(kk, i)))
    x_spec = pl.BlockSpec((bt, bn), lambda i, j, kk: (kk, j))
    o_spec = pl.BlockSpec((bt, bn), lambda i, j, kk: (i, j))
    if block_mask is None:
        return pl.pallas_call(
            functools.partial(_trmm_kernel, nk=nk,
                              accum_dtype=accum_dtype),
            grid=grid,
            in_specs=[l_spec, x_spec],
            out_specs=o_spec,
            out_shape=_out_sds((n, k), X.dtype, X),
            scratch_shapes=[pltpu.VMEM((bt, bn), accum_dtype)],
            interpret=interpret,
        )(L, X)
    mask = jnp.asarray(block_mask, jnp.int32)
    assert mask.shape == (ni, nk), (mask.shape, ni, nk)
    return pl.pallas_call(
        functools.partial(_trmm_masked_kernel, nk=nk,
                          accum_dtype=accum_dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk)),
                  l_spec, x_spec],
        out_specs=o_spec,
        out_shape=_out_sds((n, k), X.dtype, X),
        scratch_shapes=[pltpu.VMEM((bt, bn), accum_dtype)],
        interpret=interpret,
    )(mask, L, X)
