"""Pallas TPU kernel: base-case TRSM by forward substitution.

This kernel is deliberately the thing the paper REPLACES: a
row-sequential triangular solve.  On TPU the substitution recurrence
x_r = (b_r - L[r,:] X) / L[r,r] serializes on the VPU (no MXU work at
all) — which is exactly why It-Inv-TRSM's swap of base-case solves for
multiplications by pre-inverted blocks is a bigger win on TPU than on
the paper's MPI machine (DESIGN.md Sec. 2).  We keep it as (a) the
baseline for benchmarks/bench_gemm_fraction.py, which quantifies the
MXU-eligible flop share with and without inversion, and (b) a fallback
for non-power-of-two blocks.

Grid: (batch, column tiles).  The (n0, n0) L tile and an (n0, bn) X
tile live in VMEM; the row loop is a lax.fori_loop over VMEM values.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _trsm_kernel(l_ref, b_ref, x_ref, *, accum_dtype):
    L = l_ref[0]
    B = b_ref[0]
    n0 = L.shape[0]

    def body(r, X):
        # full-length dot; X rows >= r are still zero so they don't
        # contribute.  One VPU row op per r — the serial baseline.
        # The row dot and the subtraction run at accum_dtype so a
        # low-precision recurrence does not compound rounding row by
        # row; the carried X stays at the operand dtype.
        d = jnp.dot(L[r], X, preferred_element_type=accum_dtype)
        xr = (B[r].astype(accum_dtype) - d) / L[r, r].astype(accum_dtype)
        return X.at[r].set(xr.astype(X.dtype))

    x_ref[0] = jax.lax.fori_loop(0, n0, body, jnp.zeros_like(B))


def _trsm_valid_kernel(v_ref, l_ref, b_ref, x_ref, *, accum_dtype):
    """Validity-gated variant: a stack entry flagged 0 skips the whole
    substitution recurrence and writes zeros (its L is never read, so
    an arbitrary/zero diagonal cannot divide)."""
    @pl.when(v_ref[0, 0] != 0)
    def _solve():
        _trsm_kernel(l_ref, b_ref, x_ref, accum_dtype=accum_dtype)

    @pl.when(v_ref[0, 0] == 0)
    def _skip():
        x_ref[0] = jnp.zeros_like(x_ref[0])


def _out_sds(shape, dtype, like):
    vma = getattr(jax.core.get_aval(like), "vma", None)
    if vma:
        return jax.ShapeDtypeStruct(shape, dtype, vma=vma)
    return jax.ShapeDtypeStruct(shape, dtype)


def trsm_substitution(L: jnp.ndarray, B: jnp.ndarray, *, bn: int = 128,
                      accum_dtype=jnp.float32,
                      interpret: bool = False, valid=None) -> jnp.ndarray:
    """Solve tril(L) X = B by in-kernel forward substitution.

    L: (m, n0, n0) batched or (n0, n0); B matching (m, n0, k)/(n0, k).
    ``accum_dtype``: precision of the per-row dot/update recurrence
    (float32 by default; the carried solution stays at B's dtype).
    ``valid``: optional (m,) mask — entries flagged 0 (blocks outside
    a :class:`~repro.core.structure.FactorStructure` schedule) skip
    the recurrence and write zeros; ``None`` compiles the exact
    unconditional kernel."""
    squeeze = L.ndim == 2
    if squeeze:
        L, B = L[None], B[None]
    m, n0, _ = L.shape
    _, _, k = B.shape
    bn = min(bn, k)
    assert k % bn == 0, (k, bn)

    l_spec = pl.BlockSpec((1, n0, n0), lambda b, j: (b, 0, 0))
    b_spec = pl.BlockSpec((1, n0, bn), lambda b, j: (b, 0, j))
    if valid is None:
        out = pl.pallas_call(
            functools.partial(_trsm_kernel,
                              accum_dtype=jnp.dtype(accum_dtype)),
            grid=(m, k // bn),
            in_specs=[l_spec, b_spec],
            out_specs=b_spec,
            out_shape=_out_sds((m, n0, k), B.dtype, B),
            interpret=interpret,
        )(L, B)
        return out[0] if squeeze else out
    v = jnp.asarray(valid, jnp.int32).reshape(m, 1)
    out = pl.pallas_call(
        functools.partial(_trsm_valid_kernel,
                          accum_dtype=jnp.dtype(accum_dtype)),
        grid=(m, k // bn),
        in_specs=[pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
                  l_spec, b_spec],
        out_specs=b_spec,
        out_shape=_out_sds((m, n0, k), B.dtype, B),
        interpret=interpret,
    )(v, L, B)
    return out[0] if squeeze else out
