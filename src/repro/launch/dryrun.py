import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (architecture x input
shape) cell on the production meshes, record memory/cost analysis and
the collective schedule for the roofline table.

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch qwen3-1.7b --shape train_4k --mesh single,multi

Also the SolveSpec plan smoke (--spec): print the a-priori resolved
plan (method, grid, n0, inversion subgrid, modeled times) for solve
problems — by default one per paper regime — touching no devices:

    PYTHONPATH=src python -m repro.launch.dryrun --spec
    PYTHONPATH=src python -m repro.launch.dryrun --spec 16384,512,256

The XLA_FLAGS line above MUST run before any other import (jax locks
the device count on first init); that is why it is the first statement
of this file and why this flag is never set globally.

One JSON artifact per cell is written to experiments/dryrun/, so the
full 40-cell x 2-mesh sweep is resumable (--skip-existing).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, optim
from repro.configs import SHAPES, ARCH_IDS
from repro.launch.mesh import make_production_mesh
from repro.models import lm, whisper, sharding as shard_rules
from repro.roofline import analysis
from repro.train import serve_step as ss, train_step as ts

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# --------------------------- input specs ---------------------------

def _sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell
    (weak-type-correct, shardable, no device allocation)."""
    cfg = configs.get(arch)
    sh = SHAPES[shape_name]
    B, S = sh.global_batch, sh.seq_len
    if sh.kind == "train":
        batch = {"tokens": _sds((B, S)), "labels": _sds((B, S))}
        if cfg.embed_inputs:
            batch = {"embeds": _sds((B, S, cfg.d_model), jnp.bfloat16),
                     "labels": _sds((B, S))}
        if cfg.enc_dec:
            batch["frames"] = _sds((B, cfg.enc_frames, cfg.d_model),
                                   jnp.bfloat16)
        return batch
    if sh.kind == "prefill":
        batch = {"tokens": _sds((B, S))}
        if cfg.embed_inputs:
            batch = {"embeds": _sds((B, S, cfg.d_model), jnp.bfloat16)}
        if cfg.enc_dec:
            batch["frames"] = _sds((B, cfg.enc_frames, cfg.d_model),
                                   jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len-deep cache
    batch = {"tokens": _sds((B, 1))}
    if cfg.embed_inputs:
        batch = {"embeds": _sds((B, 1, cfg.d_model), jnp.bfloat16)}
    if cfg.enc_dec:
        batch["enc_states"] = _sds((B, cfg.enc_frames, cfg.d_model),
                                   jnp.bfloat16)
    return batch


def microbatches_for(cfg, sh, mesh) -> int:
    """Gradient-accumulation depth: bound per-device live activations;
    B/mb must stay shardable over the DP axes."""
    dp = int(np.prod([mesh.shape[a] for a in shard_rules.dp_axes(mesh)]))
    mb = 1
    # target <= ~8k tokens per device per microbatch
    while (sh.global_batch // mb) * sh.seq_len // dp > 8192 \
            and mb * 2 <= sh.global_batch // dp:
        mb *= 2
    return mb


# --------------------------- cell builders ---------------------------

def build_train(cfg, sh, mesh, arch, shard_mode="2d", mb=None,
                moment_dtype=jnp.float32):
    init = whisper.init if cfg.enc_dec else lm.init
    params = jax.eval_shape(lambda: init(cfg, jax.random.key(0)))
    opt = optim.get("adamw", moment_dtype=moment_dtype)
    opt_shapes = jax.eval_shape(opt.init, params)
    batch = input_specs(arch, sh.name)
    mb = mb or microbatches_for(cfg, sh, mesh)
    fn = ts.jit_train_step(cfg, mesh, opt, params, opt_shapes, batch,
                           microbatches=mb, remat=True,
                           shard_mode=shard_mode)
    return fn, (params, opt_shapes, batch), {"microbatches": mb}


def build_prefill(cfg, sh, mesh, arch, shard_mode="2d"):
    from jax.sharding import NamedSharding, PartitionSpec
    init = whisper.init if cfg.enc_dec else lm.init
    params = jax.eval_shape(lambda: init(cfg, jax.random.key(0)))
    batch = input_specs(arch, sh.name)
    pspecs = shard_rules.param_specs(cfg, params, mesh, shard_mode)
    bspecs = shard_rules.batch_specs(batch, mesh, shard_mode)
    ns = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, PartitionSpec))

    if cfg.enc_dec:
        def fn(params, batch):
            enc = whisper.encode(params, cfg, batch["frames"])
            logits, _ = whisper.decode(params, cfg, batch["tokens"], enc,
                                       last_only=True)
            return logits
    else:
        def fn(params, batch):
            logits, _ = lm.forward(params, cfg, batch.get("tokens"),
                                   embeds=batch.get("embeds"),
                                   last_only=True)
            return logits

    jfn = jax.jit(fn, in_shardings=(ns(pspecs), ns(bspecs)))
    return jfn, (params, batch), {}


def build_decode(cfg, sh, mesh, arch, kv_dtype=jnp.bfloat16):
    B = sh.global_batch
    batch = input_specs(arch, sh.name)
    init = whisper.init if cfg.enc_dec else lm.init
    init_cache = whisper.init_cache if cfg.enc_dec else lm.init_cache
    params = jax.eval_shape(lambda: init(cfg, jax.random.key(0)))
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, sh.seq_len, dtype=kv_dtype))
    fn = ss.jit_decode_step(cfg, mesh, params, cache, B)
    toks = batch.get("tokens", jax.ShapeDtypeStruct((B, 1), jnp.int32))
    args = [params, cache, toks]
    if cfg.enc_dec:
        args.append(batch["enc_states"])
    elif cfg.embed_inputs:
        args.append(batch["embeds"])
    return fn, tuple(args), {}


# -------------------------- SolveSpec smoke --------------------------

# one (n, k, p) per paper regime (Sec. VIII): tall solve (2d), the
# general 3d case, and the many-RHS 1d case
SPEC_REGIMES = [(16384, 128, 64), (16384, 512, 256), (256, 65536, 64)]


def run_spec_smoke(triples, structure: str | None = None,
                   overlap: str = "auto") -> int:
    """Resolve and print the a-priori plan (SolveSpec.auto) for each
    (n, k, p) — pure cost-model arithmetic, no devices touched.

    ``structure`` ("dense" | "banded[:BW]" | "block-sparse") resolves
    the HOISTED serving plan for a structured factor instead: the
    structured n0 argmin + sweep-only dispatch, with the analyzed
    level schedule printed next to the modeled times (DESIGN.md
    Sec. 14) — still no devices, nothing compiled.

    ``overlap`` ("auto" | "on" | "off") prices the steady-state sweep
    pipelined (prefetched collectives under compute) or sequential;
    every plan line is followed by the steady cost on the paper-model
    machine AND — when a committed calibration exists
    (benchmarks/BENCH_overlap.json, DESIGN.md Sec. 16) — the
    calibrated machine, so predicted-vs-calibrated is one flag away."""
    from repro.core import cost_model as cm, tuning
    from repro.core.solver import SolveSpec, _normalize_overlap
    ov = _normalize_overlap(overlap) == "on"
    base = cm.tpu_v5e()
    cal = tuning.calibration()
    cal_machine = tuning.default_machine()
    for (n, k, p) in triples:
        if structure is None:
            spec = SolveSpec.auto(n, k, p=p, overlap=overlap)
            method, plan, times = tuning.choose_method(n, k, p)
            assert method == spec.method, (method, spec.method)
            print(f"[spec] n={n} k={k} p={p}: "
                  f"regime={tuning.regime(n, k, p)}"
                  f" -> method={spec.method} grid={plan.p1}x{plan.p1}x"
                  f"{plan.p2} n0={spec.n0} r=({plan.r1},{plan.r2}) "
                  f"modeled inv={times['inv']:.3e}s "
                  f"rec={times['rec']:.3e}s "
                  f"(machine: {cal_machine.name})")
        else:
            from repro.core.structure import FactorStructure, analyze
            st = FactorStructure.parse(structure, n=n)
            spec = SolveSpec.auto(n, k, p=p, structure=st, hoisted=True,
                                  overlap=overlap)
            _, _, times = tuning.choose_serving_method(
                n, k, spec.grid, structure=spec.structure, overlap=ov)
            line = (f"[spec] n={n} k={k} p={p} structure={st.kind}: "
                    f"-> method={spec.method} grid={spec.grid.p1}x"
                    f"{spec.grid.p1}x{spec.grid.p2} n0={spec.n0} "
                    f"modeled inv={times['inv']:.3e}s "
                    f"rec={times['rec']:.3e}s")
            if spec.structure is not None:
                info = analyze(spec.structure, n, spec.n0)
                dense_off = info.m * (info.m - 1) // 2
                line += (f" levels={info.n_levels}/{info.m} "
                         f"offdiag={info.nnz_offdiag}/{dense_off}")
            print(line)
        # predicted vs calibrated steady cost at the resolved plan
        if spec.method == "inv" and spec.n0 is not None:
            c = cm.it_inv_trsm_steady_cost(
                n, k, spec.n0, spec.grid.p1, spec.grid.p2,
                structure=spec.structure, overlap=ov)
            steady = (f"[spec]   steady overlap={'on' if ov else 'off'} "
                      f"predicted={c.time(base):.3e}s")
            if cal is not None:
                steady += (f" calibrated={c.time(cal_machine):.3e}s "
                           f"(a={cal.a:.3g} b={cal.b:.3g} "
                           f"g={cal.g:.3g})")
            else:
                steady += " calibrated=n/a (no BENCH_overlap.json)"
            print(steady)
    return 0


# a mixed-order manifest spanning planner behaviors: the small orders
# merge into shared buckets (padding overhead < the saved dispatch),
# the large ones split out (the modeled n^2-order sweep delta at
# k=16 dwarfs one dispatch)
FLEET_MANIFEST = {16384: 2, 8192: 4, 1024: 8, 512: 16, 256: 32, 128: 32}


def run_fleet_smoke(p1: int = 2, p2: int = 2, k: int = 16) -> int:
    """Print the fleet capacity planner's bucket table for a
    mixed-order manifest — pure cost-model arithmetic on a mesh-less
    grid, no devices touched (DESIGN.md Sec. 12).  The recursive
    alternative inside each bucket's method pick is priced with the
    Tang 2024 bandwidth correction (arXiv:2407.00871)."""
    from repro.core import cost_model as cm, fleet as fleetlib
    from repro.core.solver import plan_grid
    grid = plan_grid(p1, p2)
    # the calibrated default first (whatever the measured dispatch
    # overhead and fitted rates price), then the pinned nominal
    # high-dispatch regime where merging must pay — the structural
    # assert lives on the latter
    plan_cal = fleetlib.plan_fleet(FLEET_MANIFEST, grid, k=k)
    print(f"[fleet] manifest={FLEET_MANIFEST} on p1={p1} p2={p2} "
          f"(p={grid.p}) k={k} dispatch_s={plan_cal.dispatch_s:.1e} "
          f"(calibrated default)")
    print(plan_cal.table())
    plan = fleetlib.plan_fleet(FLEET_MANIFEST, grid, k=k,
                               machine=cm.tpu_v5e(), dispatch_s=5e-5)
    print(f"[fleet] nominal high-dispatch regime dispatch_s=5.0e-05:")
    print(plan.table())
    for p_ in (plan_cal, plan):
        orders = sum(len(b.orders) for b in p_.buckets)
        assert orders == len(FLEET_MANIFEST), (orders, FLEET_MANIFEST)
    orders = sum(len(b.orders) for b in plan.buckets)
    print(f"[fleet] {orders} orders -> {len(plan.buckets)} bucket(s) "
          f"at 5.0e-05; calibrated default -> {len(plan_cal.buckets)}")
    assert len(plan.buckets) < orders, "planner merged nothing"
    return 0


# ------------------------------ runner ------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str,
             shard_mode: str = "2d", mb: int | None = None,
             kv_dtype: str = "bf16", moment_dtype: str = "f32") -> dict:
    cfg = configs.get(arch)
    sh = SHAPES[shape_name]
    ok, why = configs.shape_applicable(cfg, sh)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": sh.kind, "shard_mode": shard_mode,
           "kv_dtype": kv_dtype, "time": time.time()}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    kvd = {"bf16": jnp.bfloat16, "int8": jnp.int8}[kv_dtype]
    md = {"f32": jnp.float32, "bf16": jnp.bfloat16}[moment_dtype]
    t0 = time.time()
    if sh.kind == "train":
        fn, args, extra = build_train(cfg, sh, mesh, arch, shard_mode, mb,
                                      moment_dtype=md)
    elif sh.kind == "prefill":
        fn, args, extra = build_prefill(cfg, sh, mesh, arch, shard_mode)
    else:
        fn, args, extra = build_decode(cfg, sh, mesh, arch, kv_dtype=kvd)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem_rec = {}
    try:
        mem = compiled.memory_analysis()
        for f in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                mem_rec[f] = int(v)
    except Exception as e:        # CPU backend may not support it
        mem_rec["error"] = repr(e)
    mflops = analysis.model_flops_for(cfg, sh)
    roof, colls = analysis.from_compiled(compiled, n_chips, mflops)
    # analytic three-term model (scan-trip-count-correct; the raw
    # compiled numbers count while bodies once — kept as structural
    # evidence, see repro.roofline.model docstring)
    from repro.roofline import model as rmodel
    mesh_roles = dict(mesh.shape)
    if shard_mode == "fsdp_all":
        # TP axis re-roled into FSDP/SP: model the collective structure
        # accordingly (no per-layer TP reductions).
        mesh_roles = {"pod": mesh_roles.get("pod", 1),
                      "data": mesh_roles.get("data", 1)
                      * mesh_roles.get("model", 1), "model": 1}
        mesh_roles = {k: v for k, v in mesh_roles.items() if v > 1} or \
            {"data": 1}
    cm = rmodel.cell_model(cfg, sh, mesh_roles,
                           microbatches=extra.get("microbatches", 1),
                           kv_bytes=(1.03 if kv_dtype == "int8" else 2.0))
    rec.update(status="ok", n_chips=n_chips,
               lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
               memory=mem_rec, collectives=colls,
               compiled_raw=roof.to_dict(), roofline=cm.to_dict(),
               **extra)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--shard-mode", default="2d",
                    choices=["2d", "fsdp_all"])
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--tag", default="",
                    help="artifact suffix (perf-iteration runs)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--spec", nargs="*", default=None, metavar="N,K,P",
                    help="print the auto-resolved SolveSpec plan for "
                         "each n,k,p triple (default: one per paper "
                         "regime) and exit")
    ap.add_argument("--fleet", action="store_true",
                    help="print the fleet capacity planner's bucket "
                         "table for a mixed-order manifest (pure cost "
                         "model, no devices) and exit")
    ap.add_argument("--structure", default=None,
                    metavar="dense|banded[:BW]|block-sparse",
                    help="with --spec: resolve the hoisted serving "
                         "plan for a structured factor (structured n0 "
                         "argmin + level schedule; DESIGN.md Sec. 14)")
    ap.add_argument("--overlap", default="auto",
                    choices=["auto", "on", "off"],
                    help="with --spec: price the steady-state sweep "
                         "software-pipelined (on/auto) or sequential "
                         "(off); DESIGN.md Sec. 16")
    args = ap.parse_args()

    if args.spec is not None:
        triples = [tuple(int(x) for x in s.split(","))
                   for s in args.spec] or SPEC_REGIMES
        return run_spec_smoke(triples, structure=args.structure,
                              overlap=args.overlap)
    if args.fleet:
        return run_fleet_smoke()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shp in shapes:
            for mk in meshes:
                tag = f"{arch}__{shp}__{mk}"
                if args.tag:
                    tag += "__" + args.tag
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] {tag}: exists, skipping")
                    continue
                print(f"[dryrun] {tag}: lowering...", flush=True)
                try:
                    rec = run_cell(arch, shp, mk,
                                   shard_mode=args.shard_mode,
                                   mb=args.microbatches or None,
                                   kv_dtype=args.kv_dtype)
                except Exception as e:
                    rec = {"arch": arch, "shape": shp, "mesh": mk,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                st = rec["status"]
                msg = ""
                if st == "ok":
                    r = rec["roofline"]
                    msg = (f"compile={rec['compile_s']}s "
                           f"bottleneck={r['bottleneck']} "
                           f"frac={r['roofline_fraction']:.3f}")
                elif st == "skipped":
                    msg = rec["reason"][:60]
                else:
                    msg = rec["error"][:120]
                print(f"[dryrun] {tag}: {st} {msg}", flush=True)
    print(f"[dryrun] done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
