"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (required so smoke tests / benches keep seeing
1 device while the dry-run forces 512).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16 x 16 = 256 chips (v5e pod, 2D ICI torus).
    Multi-pod: 2 x 16 x 16 = 512 chips with a leading "pod" axis (DCN
    between pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for multi-device selfchecks (8 forced host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
