"""Production serving CLI: batched prefill + decode on a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --new-tokens 16 [--mesh debug]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_production_mesh, make_debug_mesh
from repro.models import lm
from repro.train import serve_step as ss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="debug",
                    choices=["single", "multi", "debug"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get(args.arch)
    if args.mesh == "debug":
        n = len(jax.devices())
        mesh = make_debug_mesh(max(n // 4, 1), min(4, n))
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    B = args.batch
    max_seq = args.prompt_len + args.new_tokens
    params = lm.init(cfg, jax.random.key(0))
    cache = lm.init_cache(cfg, B, max_seq)
    decode = ss.jit_decode_step(cfg, mesh, params, cache, B)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (B, args.prompt_len)))
    t0 = time.time()
    # prefill IS a decode step with S = prompt length (same code path)
    logits, cache = lm.decode_step(params, cfg, prompts, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    out = [tok]
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
    gen = np.asarray(jnp.concatenate(out, axis=1))
    dt = time.time() - t0
    for b in range(B):
        print(f"seq {b}: {gen[b, :12].tolist()}")
    print(f"{B * args.new_tokens} tokens in {dt:.2f}s "
          f"({B * args.new_tokens / dt:.1f} tok/s) on mesh "
          f"{dict(mesh.shape)}")


if __name__ == "__main__":
    main()
