"""Production serving CLI: batched prefill + decode on a mesh, or
TRSM solve serving against a device-resident factor.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --new-tokens 16 [--mesh debug]

    # the paper's workload: repeated solves against a fixed factor,
    # served from cyclic device storage (zero steady-state transfers);
    # --precision picks the mixed-precision policy per workload
    # (bf16_refine = MXU-native sweep + on-device refinement to fp32)
    PYTHONPATH=src python -m repro.launch.serve --workload trsm \
        --n 256 --panel-k 16 --requests 64 [--p1 2 --p2 2] \
        [--precision fp32|bf16|bf16_refine|fp64_refine] [--cache-stats]

    # multi-factor batched serving: M resident factors (a FactorBank),
    # per-factor request queues, every wave = ONE dispatch covering all
    # M factors (per-layer preconditioners / per-tenant models)
    PYTHONPATH=src python -m repro.launch.serve --workload trsm-bank \
        --bank 16 --n 256 --panel-k 16 --requests 256 \
        [--map-mode vmap|scan] [--precision bf16_refine]

    # churn serving: a capacity-allocated LIVE-MUTABLE bank —
    # factors are replaced / evicted / re-admitted in place between
    # waves (KFAC-style re-factorization, tenant churn) while the ONE
    # compiled program keyed on the capacity keeps serving: zero
    # retraces, zero rebuilds (DESIGN.md Sec. 11)
    PYTHONPATH=src python -m repro.launch.serve --workload trsm-churn \
        --bank 16 --n 256 --panel-k 16 --requests 256 --updates 32 \
        [--precision bf16_refine] [--cache-stats]

    # mixed-order multi-tenant fleet: the capacity planner buckets a
    # spectrum of factor orders (zero-padding small orders into shared
    # banks where the modeled overhead is bought back by the saved
    # dispatch), requests route by (tenant, order), full buckets
    # reclaim their coldest slot across tenants (DESIGN.md Sec. 12)
    PYTHONPATH=src python -m repro.launch.serve --workload trsm-fleet \
        --n 256 --panel-k 16 --requests 256 --updates 16 \
        [--precision bf16_refine] [--fleet-stats] [--cache-stats]

    # open-loop async traffic: Poisson arrivals at --rate req/s against
    # the background drain loop (AsyncSolveServer) — bounded queues,
    # typed shedding, SolveFuture handles, p50/p99 + goodput against
    # the --slo-ms latency objective (DESIGN.md Sec. 13)
    PYTHONPATH=src python -m repro.launch.serve --workload trsm-traffic \
        --n 256 --panel-k 16 --requests 512 --rate 500 --slo-ms 50 \
        [--queue-depth 128] [--precision bf16_refine] [--cache-stats]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_production_mesh, make_debug_mesh
from repro.models import lm
from repro.train import serve_step as ss


def _print_cache_stats():
    from repro import api
    st = api.default_cache().stats()
    print(f"compiled-solver cache: size={st['size']} hits={st['hits']} "
          f"misses={st['misses']} evictions={st['evictions']} "
          f"hit_rate={st['hit_rate']:.3f}")


def serve_trsm(args):
    """Serve TRSM solve requests against a device-resident factor."""
    from repro import api
    if args.precision == "fp64_refine":
        jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(0)
    n = args.n
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    if args.precision != "fp64_refine":
        L = L.astype(np.float32)
    structure = None
    if args.structure:
        # admission enforces the promise (masks L to the structure),
        # so serving a random dense factor under --structure is safe —
        # it solves against the masked operator (DESIGN.md Sec. 14)
        structure = api.FactorStructure.parse(args.structure, n=n)
    grid = api.make_trsm_mesh(args.p1, args.p2)
    solver = api.Solver.from_factor(L, grid, method=args.method,
                                    n0=args.n0, precision=args.precision,
                                    k_hint=args.panel_k,
                                    structure=structure,
                                    overlap=args.overlap)
    server = api.SolveServer(solver, args.panel_k).warmup()
    widths = rng.integers(1, args.panel_k + 1, args.requests)
    t0 = time.time()
    for w in widths:
        server.submit(jnp.asarray(rng.standard_normal((n, int(w)))))
    outs = server.drain()[0]
    if outs:
        jax.block_until_ready(outs[-1])
    dt = time.time() - t0
    panels = server.panels_solved
    policy = solver.policy
    print(f"served {server.requests_served} solve requests "
          f"({int(widths.sum())} columns) in {panels} panels, "
          f"{dt:.3f}s ({dt / max(panels, 1) * 1e3:.2f} ms/panel) "
          f"on grid p1={args.p1} p2={args.p2} n={n} "
          f"method={solver.method} precision={policy.name} "
          f"(sweep {policy.compute}, serve {policy.io_dtype.name}, "
          f"{policy.refine_steps} refine passes)")
    if args.cache_stats:
        _print_cache_stats()


def serve_trsm_bank(args):
    """Serve solve requests against a bank of M resident factors."""
    from repro import api
    if args.precision == "fp64_refine":
        jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(0)
    n, M = args.n, args.bank
    Ls = np.stack([np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
                   for _ in range(M)])
    if args.precision != "fp64_refine":
        Ls = Ls.astype(np.float32)
    grid = api.make_trsm_mesh(args.p1, args.p2)
    solver = api.Solver.from_factors(Ls, grid, method=args.method,
                                     n0=args.n0,
                                     precision=args.precision,
                                     map_mode=args.map_mode,
                                     overlap=args.overlap)
    server = api.SolveServer(solver, args.panel_k).warmup()
    widths = rng.integers(1, args.panel_k + 1, args.requests)
    t0 = time.time()
    for i, w in enumerate(widths):
        server.submit(rng.standard_normal((n, int(w))), int(i % M))
    outs = server.drain()
    jax.block_until_ready([x for xs in outs.values() for x in xs])
    dt = time.time() - t0
    waves = server.waves_solved
    policy = solver.policy
    print(f"served {server.requests_served} solve requests "
          f"({int(widths.sum())} columns) against {M} factors in "
          f"{waves} waves (one dispatch per wave, {M} solves each), "
          f"{dt:.3f}s ({dt / max(waves, 1) * 1e3:.2f} ms/wave, "
          f"{dt / max(waves * M, 1) * 1e3:.3f} ms/solve) on grid "
          f"p1={args.p1} p2={args.p2} n={n} "
          f"map_mode={solver.bank.map_mode} "
          f"precision={policy.name} ({policy.refine_steps} refine passes)")
    if args.cache_stats:
        _print_cache_stats()


def serve_trsm_churn(args):
    """Serve against a capacity-allocated live-mutable bank while the
    factor population churns: replace / evict / re-admit between
    waves, one compiled program (keyed on capacity) throughout."""
    from repro import api
    from repro.core import session
    if args.precision == "fp64_refine":
        jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(0)
    n, C = args.n, args.bank
    dt = np.float64 if args.precision == "fp64_refine" else np.float32

    def fresh():
        return (np.tril(rng.standard_normal((n, n)))
                + n * np.eye(n)).astype(dt)

    grid = api.make_trsm_mesh(args.p1, args.p2)
    bank = api.FactorBank(grid, n, method=args.method, n0=args.n0,
                          precision=args.precision,
                          dtype=None if args.precision else dt,
                          map_mode=args.map_mode, capacity=C,
                          overlap=args.overlap)
    solver = api.Solver.from_bank(bank)
    server = api.SolveServer(solver, args.panel_k).warmup()  # EMPTY warmup
    for _ in range(max(C // 2, 1)):          # start at half occupancy
        bank.admit(fresh())

    key = solver.spec_for(args.panel_k)
    uspec = bank.update_spec()
    traces0 = (session.TRACE_COUNTS[key], session.TRACE_COUNTS[uspec])

    widths = rng.integers(1, args.panel_k + 1, args.requests)
    per_wave = max(args.requests // max(args.updates, 1), 1)
    replaced = evicted = 0
    t_update = 0.0
    t0 = time.time()
    for i, w in enumerate(widths):
        live = bank.live_slots()
        server.submit(rng.standard_normal((n, int(w))).astype(dt),
                      int(live[i % len(live)]))
        if (i + 1) % per_wave == 0:
            outs = server.drain()
            jax.block_until_ready([x for xs in outs.values() for x in xs])
            # churn between waves: refresh one slot in place, and
            # periodically turn a slot over (evict -> re-admit)
            live = bank.live_slots()
            tu = time.time()
            bank.replace(int(live[replaced % len(live)]), fresh())
            replaced += 1
            if replaced % 3 == 0:
                victim = int(live[evicted % len(live)])
                bank.evict(victim)
                slot = bank.admit(fresh())
                if slot != victim:         # lowest-free-slot reuse
                    raise AssertionError((slot, victim))
                evicted += 1
            jax.block_until_ready(bank.factors_cyclic)
            t_update += time.time() - tu
    outs = server.drain()
    jax.block_until_ready([x for xs in outs.values() for x in xs])
    dt_total = time.time() - t0
    retraced = (session.TRACE_COUNTS[key] - traces0[0],
                session.TRACE_COUNTS[uspec] - traces0[1])
    # one compiled scatter per replace and per re-admit (evict itself
    # is host-side bookkeeping)
    updates = replaced + evicted
    policy = solver.policy
    print(f"served {server.requests_served} solve requests in "
          f"{server.waves_solved} waves against a capacity-{C} bank "
          f"(occupancy {bank.size}) with {updates} in-place updates "
          f"({replaced} replaces, {evicted} evict+readmit), "
          f"{dt_total:.3f}s total, "
          f"{t_update / max(updates, 1) * 1e3:.2f} ms/update; "
          f"retraces solve={retraced[0]} update={retraced[1]} "
          f"(steady state: 0/0) on grid p1={args.p1} p2={args.p2} n={n} "
          f"precision={policy.name}")
    if args.cache_stats:
        _print_cache_stats()


def serve_trsm_fleet(args):
    """Mixed-order multi-tenant serving through the fleet tier: the
    planner buckets the order spectrum, two tenants' factors land in
    planner-chosen buckets, requests route by (tenant, order), churn
    refreshes factors in place, and over-subscribed buckets reclaim
    their coldest slot across tenants (DESIGN.md Sec. 12)."""
    from repro import api
    from repro.core import session
    if args.precision == "fp64_refine":
        jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(0)
    n = args.n
    dt = np.float64 if args.precision == "fp64_refine" else np.float32
    orders = [n, n // 2, n // 4]        # the tenants' order spectrum

    def fresh(d):
        return (np.tril(rng.standard_normal((d, d)))
                + d * np.eye(d)).astype(dt)

    grid = api.make_trsm_mesh(args.p1, args.p2)
    # two tenants, two factors per order each
    manifest = {d: 4 for d in orders}
    plan = api.plan_fleet(manifest, grid, k=args.panel_k,
                          precision=args.precision, dtype=None
                          if args.precision else dt,
                          overlap=args.overlap)
    print(plan.table())
    fleet = api.SolverFleet(grid, plan)
    handles = {}
    for tenant in ("tenant-a", "tenant-b"):
        for d in orders:
            for j in range(2):
                tag = f"layer{orders.index(d)}-{j}"
                handles[(tenant, tag)] = fleet.admit(
                    fresh(d), tenant=tenant, tag=tag)
    server = api.SolveServer(fleet, args.panel_k).warmup()

    solve_keys = [fleet.solver(key).spec_for(args.panel_k)
                  for key in fleet.buckets]
    traces0 = sum(session.TRACE_COUNTS[k] for k in solve_keys)

    widths = rng.integers(1, args.panel_k + 1, args.requests)
    per_wave = max(args.requests // max(args.updates, 1), 1)
    keys = list(handles)
    replaced = reclaimed = 0
    t0 = time.time()
    for i, w in enumerate(widths):
        tenant, tag = keys[i % len(keys)]
        h = handles[(tenant, tag)]
        server.submit(rng.standard_normal((h.order, int(w))).astype(dt),
                      tenant=tenant, tag=tag)
        if (i + 1) % per_wave == 0:
            outs = server.drain()
            jax.block_until_ready([x for xs in outs.values()
                                   for x in xs])
            # churn between waves: refresh one factor in place; every
            # third update over-subscribes a bucket so the fleet
            # reclaims its coldest slot cross-tenant
            tenant, tag = keys[replaced % len(keys)]
            h = handles[(tenant, tag)]
            fleet.replace(h, fresh(h.order))
            replaced += 1
            if replaced % 3 == 0:
                d = orders[reclaimed % len(orders)]
                hot = fleet.admit(fresh(d), tenant="tenant-c",
                                  tag=f"burst{reclaimed}")
                reclaimed += 1
                # drop stale handles the reclaim displaced
                handles = {kt: hh for kt, hh in handles.items()
                           if hh is not hot and any(
                               hh is cur for cur in fleet.handles())}
                handles[("tenant-c", hot.tag)] = hot
                keys = list(handles)
    outs = server.drain()
    jax.block_until_ready([x for xs in outs.values() for x in xs])
    dt_total = time.time() - t0
    retraced = sum(session.TRACE_COUNTS[k]
                   for k in solve_keys) - traces0
    st = fleet.stats()
    print(f"served {server.requests_served} mixed-order requests "
          f"({len(orders)} orders, {len(fleet.buckets)} planned "
          f"bucket(s)) in {server.waves_solved} bucket-waves, "
          f"{dt_total:.3f}s; {replaced} in-place refreshes, "
          f"{st['reclaims']} cross-tenant reclaims; "
          f"retraces solve={retraced} (steady state: 0) on grid "
          f"p1={args.p1} p2={args.p2}")
    if args.fleet_stats:
        print(fleet.format_stats())
    if args.cache_stats:
        _print_cache_stats()


def serve_trsm_traffic(args):
    """Open-loop async serving: Poisson arrivals against the
    background drain loop, futures resolved as waves finalize, tail
    latency reported against the --slo-ms objective.

    ``--admission slo`` runs the SLO-aware admission controller
    (requests whose estimated queue wait cannot meet --slo-ms are shed
    at submit with DeadlineUnmeetable, surfaced through the future);
    ``--autoscale`` serves a mixed-order FLEET instead of a flat bank
    and attaches the planner-driven Autoscaler (DESIGN.md Sec. 15)."""
    import json

    from repro import api
    if args.precision == "fp64_refine":
        jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(0)
    n, M = args.n, min(args.bank, 4)
    dt = np.float64 if args.precision == "fp64_refine" else np.float32
    grid = api.make_trsm_mesh(args.p1, args.p2)

    def fresh(d):
        return (np.tril(rng.standard_normal((d, d)))
                + d * np.eye(d)).astype(dt)

    admission = api.AdmissionController(slo_ms=args.slo_ms) \
        if args.admission == "slo" else None
    if args.autoscale:
        # mixed-order fleet: half the factors at n, half at n // 2 —
        # the spectrum the autoscaler splits/merges under load drift
        orders = [n] * max(M // 2, 1) + [n // 2] * max(M // 2, 1)
        manifest = {}
        for d in orders:
            manifest[d] = manifest.get(d, 0) + 1
        plan = api.plan_fleet(manifest, grid, k=args.panel_k,
                              precision=args.precision,
                              dtype=None if args.precision else dt,
                              overlap=args.overlap)
        fleet = api.SolverFleet(grid, plan)
        tags = []
        for j, d in enumerate(orders):
            tag = f"f{j}"
            fleet.admit(fresh(d), tenant="traffic", tag=tag)
            tags.append((tag, d))
        server = api.AsyncSolveServer(
            fleet, args.panel_k, queue_depth=args.queue_depth,
            slo_ms=args.slo_ms).warmup()
        scaler = api.Autoscaler(server)
        policy = fleet.solver(next(iter(fleet.buckets))).policy
    else:
        Ls = np.stack([fresh(n) for _ in range(M)])
        solver = api.Solver.from_factors(Ls, grid, method=args.method,
                                         n0=args.n0,
                                         precision=args.precision,
                                         overlap=args.overlap)
        server = api.AsyncSolveServer(
            solver, args.panel_k, queue_depth=args.queue_depth,
            slo_ms=args.slo_ms).warmup()
        scaler = None
        policy = solver.policy
    width = max(args.panel_k // 4, 1)
    pools = {d: [jnp.asarray(rng.standard_normal((d, width))
                             .astype(dt)) for _ in range(32)]
             for d in ({n, n // 2} if args.autoscale else {n})}
    jax.block_until_ready(list(pools.values()))

    def sub(i, d=None):
        if args.autoscale:
            tag, order = tags[i % len(tags)]
            return server.submit(pools[order][i % 32],
                                 tenant="traffic", tag=tag)
        return server.submit(pools[n][i % 32], factor=i % M)

    # prime every wave composition before the clock starts: lazy
    # first compiles belong to startup, not to the measured traffic
    per_wave = M * max(args.panel_k // width, 1)
    for count in range(1, per_wave + 1):
        for i in range(count):
            sub(i)
        while server.pending() or server._inflight:
            server.step()
        server.flush()
    # admission goes live only now: priming compiles must not feed
    # the controller's service estimates
    server.reset_service_ewma()
    if admission is not None:
        server.set_admission(admission)
    gaps = rng.exponential(1.0 / args.rate, size=args.requests)
    shed = 0
    futs = []
    t0 = time.monotonic()
    sched = t0 + np.cumsum(gaps)
    with server:                       # background drain loop
        for i, t_i in enumerate(sched):
            delay = t_i - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            try:
                futs.append((t_i, sub(i)))
            except api.Overloaded:
                shed += 1              # depth shed (raised at submit)
        served, deadline_shed = [], 0
        for t_i, f in futs:
            try:
                f.result(timeout=120)
                served.append((t_i, f))
            except api.DeadlineUnmeetable:
                deadline_shed += 1     # SLO shed (through the future)
    elapsed = time.monotonic() - t0
    lat = np.asarray([f.completed for _, f in served]) \
        - np.asarray([t for t, _ in served])
    violations = int((lat * 1e3 > args.slo_ms).sum())

    def pct(q):
        return f"{np.percentile(lat, q) * 1e3:.2f}" if len(lat) \
            else "n/a"
    print(f"served {len(served)}/{args.requests} open-loop requests "
          f"(offered {args.rate:.0f} rps, goodput "
          f"{len(served) / elapsed:.0f} rps) in "
          f"{server.stats()['waves']} waves; p50 "
          f"{pct(50)} ms p99 "
          f"{pct(99)} ms vs SLO "
          f"{args.slo_ms:.0f} ms ({violations} violations); "
          f"shed {shed} at depth {args.queue_depth} + "
          f"{deadline_shed} at admission ({args.admission}) on grid "
          f"p1={args.p1} p2={args.p2} n={n} "
          f"precision={policy.name}")
    if scaler is not None:
        print(f"autoscaler: {len(scaler.replans)} replan(s) "
              + "".join(f"[{r['kind']}: {r['moved']} moved] "
                        for r in scaler.replans)
              + f"buckets now "
                f"{sorted(k[0] for k in server.fleet.buckets)}")
    if args.stats_json:
        st = server.stats()
        if scaler is not None:
            st["autoscaler"] = scaler.stats()
        if admission is not None:
            st["admission"] = admission.stats()
        print(json.dumps(st, default=str, sort_keys=True))
    if args.cache_stats:
        _print_cache_stats()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm",
                    choices=["lm", "trsm", "trsm-bank", "trsm-churn",
                             "trsm-fleet", "trsm-traffic"])
    ap.add_argument("--arch")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="debug",
                    choices=["single", "multi", "debug"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    # trsm workload
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--n0", type=int, default=None)
    ap.add_argument("--panel-k", type=int, default=16)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--p1", type=int, default=1)
    ap.add_argument("--p2", type=int, default=1)
    ap.add_argument("--structure", default=None,
                    metavar="dense|banded[:BW]|block-sparse",
                    help="factor block structure for the trsm workload "
                         "(level-scheduled sweep; DESIGN.md Sec. 14)")
    ap.add_argument("--overlap", default="auto",
                    choices=["auto", "on", "off"],
                    help="software-pipeline the steady-state sweep "
                         "(prefetch the next panel's collectives under "
                         "this panel's compute; bit-identical results; "
                         "DESIGN.md Sec. 16)")
    ap.add_argument("--method", default="inv",
                    choices=["inv", "rec", "auto"])
    ap.add_argument("--bank", type=int, default=16,
                    help="factor count M for the trsm-bank workload "
                         "(= capacity C for trsm-churn)")
    ap.add_argument("--updates", type=int, default=32,
                    help="in-place bank updates interleaved with the "
                         "waves (trsm-churn workload)")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="offered Poisson arrival rate in req/s "
                         "(trsm-traffic workload)")
    ap.add_argument("--slo-ms", type=float, default=50.0,
                    help="latency objective: completions slower than "
                         "this count as SLO violations (trsm-traffic)")
    ap.add_argument("--queue-depth", type=int, default=128,
                    help="per-slot bounded queue depth; submits beyond "
                         "it are shed with Overloaded (trsm-traffic)")
    ap.add_argument("--admission", default="depth",
                    choices=["depth", "slo"],
                    help="admission policy for trsm-traffic: 'depth' "
                         "sheds only on full queues; 'slo' also sheds "
                         "requests whose estimated queue wait cannot "
                         "meet --slo-ms (DeadlineUnmeetable through "
                         "the future; DESIGN.md Sec. 15)")
    ap.add_argument("--autoscale", action="store_true",
                    help="serve a mixed-order fleet with the "
                         "planner-driven Autoscaler attached: bucket "
                         "splits/merges follow offered-load drift "
                         "(trsm-traffic; DESIGN.md Sec. 15)")
    ap.add_argument("--stats-json", action="store_true",
                    help="dump one machine-readable JSON line of "
                         "server (+ admission/autoscaler) stats after "
                         "the run (trsm-traffic)")
    ap.add_argument("--map-mode", default="vmap",
                    choices=["vmap", "scan"],
                    help="how the bank program maps the factor axis")
    ap.add_argument("--precision", default=None,
                    choices=["fp32", "bf16", "bf16_refine", "fp64_refine"],
                    help="mixed-precision policy for the trsm workload "
                         "(default: uniform at the factor dtype)")
    ap.add_argument("--cache-stats", action="store_true",
                    help="print compiled-solver cache stats (hits/misses"
                         "/evictions/hit rate) after the drain")
    ap.add_argument("--fleet-stats", action="store_true",
                    help="print fleet-wide serving stats (per-bucket "
                         "occupancy, hit rate, reclaim count) after the "
                         "drain (trsm-fleet workload)")
    args = ap.parse_args()

    if args.workload == "trsm":
        return serve_trsm(args)
    if args.workload == "trsm-bank":
        return serve_trsm_bank(args)
    if args.workload == "trsm-churn":
        return serve_trsm_churn(args)
    if args.workload == "trsm-fleet":
        return serve_trsm_fleet(args)
    if args.workload == "trsm-traffic":
        return serve_trsm_traffic(args)
    if not args.arch:
        ap.error("--arch is required for the lm workload")

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get(args.arch)
    if args.mesh == "debug":
        n = len(jax.devices())
        mesh = make_debug_mesh(max(n // 4, 1), min(4, n))
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    B = args.batch
    max_seq = args.prompt_len + args.new_tokens
    params = lm.init(cfg, jax.random.key(0))
    cache = lm.init_cache(cfg, B, max_seq)
    decode = ss.jit_decode_step(cfg, mesh, params, cache, B)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (B, args.prompt_len)))
    t0 = time.time()
    # prefill IS a decode step with S = prompt length (same code path)
    logits, cache = lm.decode_step(params, cfg, prompts, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    out = [tok]
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
    gen = np.asarray(jnp.concatenate(out, axis=1))
    dt = time.time() - t0
    for b in range(B):
        print(f"seq {b}: {gen[b, :12].tolist()}")
    print(f"{B * args.new_tokens} tokens in {dt:.2f}s "
          f"({B * args.new_tokens / dt:.1f} tok/s) on mesh "
          f"{dict(mesh.shape)}")


if __name__ == "__main__":
    main()
