"""Production training CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --shape train_4k [--smoke] [--steps N] [--optimizer kfac_ca] \
        [--mesh single|multi|debug] [--compress] [--resume auto]

On this CPU container use --smoke (reduced config, debug mesh).  On a
real pod the same driver runs the full config on the production mesh:
mesh construction, sharding rules, checkpoint/restart, straggler
monitoring and the data pipeline are identical code paths.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, optim
from repro.configs import SHAPES
from repro.data import synthetic
from repro.launch.mesh import make_production_mesh, make_debug_mesh
from repro.models import lm, whisper
from repro.train import checkpoint as ckpt, ft
from repro.train import train_step as ts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS
                    + ["preset-100m"])
    ap.add_argument("--shape", default="train_4k",
                    choices=[s for s in SHAPES])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="debug",
                    choices=["single", "multi", "debug"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "kfac_ca"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="int8 cross-pod gradient compression")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--batch", type=int, default=0,
                    help="override global batch (smoke)")
    ap.add_argument("--seq", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get(args.arch)
    sh = SHAPES[args.shape]
    B = args.batch or (4 if args.smoke else sh.global_batch)
    S = args.seq or (64 if args.smoke else sh.seq_len)

    if args.mesh == "debug":
        n = len(jax.devices())
        mesh = make_debug_mesh(max(n // 4, 1), min(4, n))
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    print(f"mesh={dict(mesh.shape)} arch={cfg.name} B={B} S={S} "
          f"opt={args.optimizer}")

    opt = optim.get(args.optimizer, lr=args.lr)
    init = whisper.init if cfg.enc_dec else lm.init
    params = init(cfg, jax.random.key(0))
    opt_state = opt.init(params)
    opt_shapes = jax.eval_shape(lambda: opt_state)
    batch0 = synthetic.host_batch(cfg, S, B, 0)
    step_fn = ts.jit_train_step(cfg, mesh, opt, params, opt_shapes,
                                batch0, microbatches=args.microbatches,
                                remat=not args.smoke,
                                compress_grads=args.compress)

    start = 0
    if args.resume == "auto" and ckpt.latest_step(args.ckpt) is not None:
        like = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            {"p": params, "o": opt_state})
        restored, start = ckpt.restore(args.ckpt, ckpt.latest_step(args.ckpt),
                                       like)
        params, opt_state = restored["p"], restored["o"]
        print(f"resumed from step {start}")

    mon = ft.StepMonitor(n_hosts=1)
    hb = ft.Heartbeat(args.ckpt, host=0)
    pf = synthetic.Prefetcher(cfg, S, B, start_step=start)
    try:
        for i in range(start, args.steps):
            t0 = time.time()
            s_idx, batch = next(pf)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            dt = time.time() - t0
            mon.record(0, dt)
            hb.beat(i)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                      f"{dt * 1e3:.0f}ms"
                      + (" STRAGGLER" if mon.stragglers() else ""))
            if (i + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt, i + 1,
                          {"p": params, "o": opt_state}, blocking=False)
    finally:
        pf.close()
    ckpt.save(args.ckpt, args.steps, {"p": params, "o": opt_state})
    print("done")


if __name__ == "__main__":
    main()
