"""Model zoo: pytree-functional implementations of the 10 assigned
architectures (dense / MoE / hybrid-recurrent / SSM / VLM-backbone /
enc-dec audio backbone), built for pjit+GSPMD distribution.

Entry points:
    lm.init(cfg, key)                  parameter pytree
    lm.forward(params, cfg, tokens)    logits (train/prefill)
    lm.decode_step(params, cfg, ...)   single-token decode with caches
    lm.init_cache(cfg, batch, seq)     decode caches
    sharding.param_specs(cfg, params)  PartitionSpec pytree (FSDP x TP x EP)
"""
