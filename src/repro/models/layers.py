"""Building blocks shared by all architectures.

Functional style: ``init_*`` returns a param dict, ``*_apply`` consumes
it.  Everything is jit/scan/shard_map-friendly (static shapes, lax
control flow), and attention/recurrence implementations are chunked so
the 32k/512k assigned shapes compile with bounded live memory.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig


def _dense_init(key, shape, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale)


# ------------------------------ norms ------------------------------

def init_rmsnorm(d):
    return {"w": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    v = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(v + eps)).astype(dt) * p["w"].astype(dt)


# ------------------------------ RoPE ------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float, mrope_sections=None):
    """x: (B, S, H, hd); positions: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (qwen2-vl): the head_dim/2 frequency slots are split into
    (t, h, w) sections, each rotated by its own position stream."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if mrope_sections is None:
        if positions.ndim == 3:
            positions = positions[0]
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    else:
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) positions"
        secs = []
        off = 0
        for i, sec in enumerate(mrope_sections):
            secs.append(positions[i][..., None].astype(jnp.float32)
                        * freqs[off:off + sec])
            off += sec
        ang = jnp.concatenate(secs, axis=-1)            # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    dt = x.dtype
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(dt)


# ---------------------------- attention ----------------------------

def init_attn(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": _dense_init(ks[0], (d, cfg.n_heads * hd)),
        "wk": _dense_init(ks[1], (d, cfg.n_kv * hd)),
        "wv": _dense_init(ks[2], (d, cfg.n_kv * hd)),
        "wo": _dense_init(ks[3], (cfg.n_heads * hd, d)),
    }
    if cfg.qk_norm:
        p["qn"] = init_rmsnorm(hd)
        p["kn"] = init_rmsnorm(hd)
    return p


def _gqa_scores(q, k):
    """q: (B,Sq,H,hd), k: (B,Sk,G,hd) -> (B, G, H/G, Sq, Sk)."""
    B, Sq, H, hd = q.shape
    G = k.shape[2]
    qg = q.reshape(B, Sq, G, H // G, hd)
    return jnp.einsum("bsgrd,btgd->bgrst", qg, k)


def _gqa_out(w, v):
    """w: (B,G,R,Sq,Sk), v: (B,Sk,G,hd) -> (B,Sq,H,hd)."""
    B, G, R, Sq, Sk = w.shape
    o = jnp.einsum("bgrst,btgd->bsgrd", w, v)
    return o.reshape(B, Sq, G * R, o.shape[-1])


def _attend_full(q, k, v, *, causal: bool, window: int,
                 q0: int = 0, k0: int = 0):
    """Small/seq-bounded attention on materialized scores."""
    hd = q.shape[-1]
    s = _gqa_scores(q, k) / math.sqrt(hd)
    Sq, Sk = q.shape[1], k.shape[1]
    iq = (q0 + jnp.arange(Sq))[:, None]
    ik = (k0 + jnp.arange(Sk))[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= ik <= iq
    if window:
        mask &= ik > iq - window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    return _gqa_out(w, v)


def _attend_chunked(q, k, v, *, causal: bool, window: int,
                    q_chunk: int, kv_chunk: int):
    """Online-softmax attention: scan over kv chunks inside a map over
    q chunks.  Live memory is O(q_chunk * kv_chunk) per head."""
    B, S, H, hd = q.shape
    G = k.shape[2]
    nq = S // q_chunk
    nk = S // kv_chunk
    kc = k.reshape(B, nk, kv_chunk, G, hd)
    vc = v.reshape(B, nk, kv_chunk, G, hd)
    scale = 1.0 / math.sqrt(hd)

    def one_q_chunk(qi, qch):
        # qch: (B, q_chunk, H, hd)
        q0 = qi * q_chunk
        m0 = jnp.full((B, G, H // G, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, G, H // G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, H, hd), jnp.float32)

        def body(carry, inp):
            m, l, acc = carry
            ki, kch, vch = inp
            k0 = ki * kv_chunk
            s = _gqa_scores(qch, kch).astype(jnp.float32) * scale
            iq = (q0 + jnp.arange(q_chunk))[:, None]
            ik = (k0 + jnp.arange(kv_chunk))[None, :]
            msk = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                msk &= ik <= iq
            if window:
                msk &= ik > iq - window
            s = jnp.where(msk, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p.sum(axis=-1)
            o = _gqa_out(p.astype(qch.dtype), vch).astype(jnp.float32)
            corr_o = corr.transpose(0, 3, 1, 2).reshape(B, q_chunk, H)
            acc = acc * corr_o[..., None] + o
            return (m_new, l_new, acc), None

        xs = (jnp.arange(nk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0))
        # flash-style backward: recompute the (q_chunk x kv_chunk)
        # scores in the bwd pass instead of stashing them for every
        # chunk pair (which is O(S^2) residual memory).
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                      xs)
        ln = l.transpose(0, 3, 1, 2).reshape(B, q_chunk, H)
        return (acc / jnp.maximum(ln[..., None], 1e-30)).astype(qch.dtype)

    qs = jnp.moveaxis(q.reshape(B, nq, q_chunk, H, hd), 1, 0)
    out = jax.lax.map(lambda t: one_q_chunk(t[0], t[1]),
                      (jnp.arange(nq), qs))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)


ATTN_CHUNK = 1024


def attn_apply(p, x, cfg: ModelConfig, *, positions, cache=None,
               window: int = 0, causal: bool = True, norm_eps=1e-6):
    """Returns (y, new_cache).  cache = dict(k, v, pos) for decode."""
    B, S, d = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, cfg.n_kv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, cfg.n_kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["qn"], q, norm_eps)
        k = rmsnorm(p["kn"], k, norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    if cache is not None:
        # append this step's k/v at cache["pos"], attend to the cache.
        pos = cache["pos"]
        cap = cache["k"].shape[1]
        ring = bool(window) and cap <= window and S == 1
        quant = "k_scale" in cache
        if quant:
            kq, ks = _kv_quant(k)
            vq, vs = _kv_quant(v)
        else:
            kq, vq = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
        if S >= cap:
            # prefill block >= cache capacity (windowed caches): keep
            # only the trailing `cap` positions.
            K = kq[:, S - cap:]
            V = vq[:, S - cap:]
            if quant:
                Ks, Vs = ks[:, S - cap:], vs[:, S - cap:]
        else:
            at = (pos % cap) if ring else pos
            K = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, at,
                                                    axis=1)
            V = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, at,
                                                    axis=1)
            if quant:
                Ks = jax.lax.dynamic_update_slice_in_dim(
                    cache["k_scale"], ks, at, axis=1)
                Vs = jax.lax.dynamic_update_slice_in_dim(
                    cache["v_scale"], vs, at, axis=1)
        if S > ATTN_CHUNK and S % ATTN_CHUNK == 0:
            # chunked prefill: attends within the incoming block
            # (prefill-from-scratch: no earlier cache content).
            o = _attend_chunked(q, k, v, causal=causal, window=window,
                                q_chunk=ATTN_CHUNK, kv_chunk=ATTN_CHUNK)
        else:
            Sk = K.shape[1]
            Kd = _kv_dequant(K, Ks, q.dtype) if quant else \
                K.astype(q.dtype)
            Vd = _kv_dequant(V, Vs, q.dtype) if quant else \
                V.astype(q.dtype)
            s = _gqa_scores(q, Kd) / math.sqrt(hd)
            ik = jnp.arange(Sk)[None, :]
            iq = pos + jnp.arange(S)[:, None]
            if ring:
                # ring buffer: slot j holds absolute position
                # pos - ((slot - j) mod cap); valid if >= 0.
                slot = pos % cap
                aj = pos - ((slot - ik) % cap)
                msk = (aj >= 0) & (aj > iq - window)
            else:
                msk = ik <= iq
                if window:
                    msk &= ik > iq - window
            s = jnp.where(msk, s, -1e30)
            w = jax.nn.softmax(s.astype(jnp.float32),
                               axis=-1).astype(q.dtype)
            o = _gqa_out(w, Vd)
        new_cache = {"k": K, "v": V, "pos": pos + S}
        if quant:
            new_cache["k_scale"] = Ks
            new_cache["v_scale"] = Vs
    else:
        if S > ATTN_CHUNK and S % ATTN_CHUNK == 0:
            o = _attend_chunked(q, k, v, causal=causal, window=window,
                                q_chunk=ATTN_CHUNK, kv_chunk=ATTN_CHUNK)
        else:
            o = _attend_full(q, k, v, causal=causal, window=window)
        new_cache = None
    y = o.reshape(B, S, cfg.n_heads * hd) @ p["wo"].astype(x.dtype)
    return y, new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_seq: int,
                    dtype=jnp.bfloat16, window: int = 0):
    """dtype=jnp.int8 enables the quantized cache: K/V stored int8 with
    per-(position, kv-head) f16 scales — 2x less HBM traffic per decode
    step, the dominant term of the decode roofline."""
    s = min(max_seq, window) if window else max_seq
    hd = cfg.head_dim
    c = {"k": jnp.zeros((batch, s, cfg.n_kv, hd), dtype),
         "v": jnp.zeros((batch, s, cfg.n_kv, hd), dtype),
         "pos": jnp.zeros((), jnp.int32)}
    if dtype == jnp.int8:
        c["k_scale"] = jnp.zeros((batch, s, cfg.n_kv, 1), jnp.float16)
        c["v_scale"] = jnp.zeros((batch, s, cfg.n_kv, 1), jnp.float16)
    return c


def _kv_quant(x):
    """(B, S, G, hd) -> int8 values + per-(pos, head) f16 scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float16)


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


# ------------------------------- MLP -------------------------------

def init_mlp(key, d, f, gated=True):
    ks = jax.random.split(key, 3)
    if gated:
        return {"gate": _dense_init(ks[0], (d, f)),
                "up": _dense_init(ks[1], (d, f)),
                "down": _dense_init(ks[2], (f, d))}
    return {"up": _dense_init(ks[0], (d, f)),
            "down": _dense_init(ks[1], (f, d))}


def mlp_apply(p, x):
    if "gate" in p:
        h = jax.nn.silu(x @ p["gate"].astype(x.dtype)) \
            * (x @ p["up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ p["up"].astype(x.dtype))
    return h @ p["down"].astype(x.dtype)


# ------------------------------- MoE -------------------------------

def init_moe(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    scale = 1.0 / math.sqrt(d)
    p = {"router": _dense_init(ks[0], (d, e)),
         "gate": jax.random.normal(ks[1], (e, d, f)) * scale,
         "up": jax.random.normal(ks[2], (e, d, f)) * scale,
         "down": jax.random.normal(ks[3], (e, f, d)) / math.sqrt(f)}
    return p


MOE_GROUP = 4096


def moe_apply(p, x, cfg: ModelConfig, capacity_factor: float | None = None):
    """Token-choice top-k routing with capacity (GShard-style dense
    dispatch, EP-shardable over the expert axis).

    Long sequences are processed in groups of MOE_GROUP tokens with
    per-group capacity (lax.map), keeping the (G, e, cap) dispatch
    tensor bounded — the dense dispatch is O(G^2/e) and would be
    quadratic in the full token count otherwise."""
    B, S, d = x.shape
    T_all = B * S
    if T_all > MOE_GROUP and T_all % MOE_GROUP == 0:
        ng = T_all // MOE_GROUP
        xg = x.reshape(ng, 1, MOE_GROUP, d)
        ys, auxs = jax.lax.map(
            lambda g: moe_apply(p, g, cfg, capacity_factor), xg)
        return ys.reshape(B, S, d), auxs.mean()
    e, topk = cfg.n_experts, cfg.topk
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)             # (T, e)
    gk, ik = jax.lax.top_k(gates, topk)                 # (T, topk)
    gk = gk / jnp.maximum(gk.sum(-1, keepdims=True), 1e-9)

    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity
    cap = int(cf * topk * T / e)
    cap = max(min(cap, T * topk), 1)
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(ik, e, dtype=jnp.int32)     # (T, topk, e)
    flat = onehot.reshape(T * topk, e)
    pos = jnp.cumsum(flat, axis=0) - flat               # (T*topk, e)
    pos = (pos * flat).sum(-1).reshape(T, topk)
    keep = pos < cap
    # dispatch tensor (T, topk, e, cap): expert one-hot x queue-slot one-hot
    slot = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                          dtype=x.dtype)[..., :cap]     # (T, topk, cap)
    disp4 = jax.nn.one_hot(ik, e, dtype=x.dtype)[..., None] \
        * slot[..., None, :]                            # (T, topk, e, cap)
    comb = (disp4 * gk.astype(x.dtype)[..., None, None]).sum(1)
    disp = disp4.sum(1)                                 # (T, e, cap)
    xin = jnp.einsum("tec,td->ecd", disp, xt)           # (e, cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin,
                               p["gate"].astype(x.dtype))) \
        * jnp.einsum("ecd,edf->ecf", xin, p["up"].astype(x.dtype))
    out = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(x.dtype))
    y = jnp.einsum("tec,ecd->td", comb, out)
    aux = _load_balance_loss(gates, ik, e)
    return y.reshape(B, S, d), aux


def _load_balance_loss(gates, ik, e):
    """Switch-style auxiliary load-balancing loss."""
    T = gates.shape[0]
    me = gates.mean(axis=0)                             # mean gate per expert
    ce = jnp.zeros((e,), jnp.float32).at[ik.reshape(-1)].add(1.0) \
        / (T * ik.shape[-1])
    return e * jnp.sum(me * ce)


# --------------------------- RG-LRU (hybrid) ---------------------------

def init_rec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 7)
    d = cfg.d_model
    w = cfg.conv_width
    return {
        "in_x": _dense_init(ks[0], (d, d)),
        "in_g": _dense_init(ks[1], (d, d)),
        "conv_w": jax.random.normal(ks[2], (w, d)) / math.sqrt(w),
        "conv_b": jnp.zeros((d,)),
        "lam": jax.random.uniform(ks[3], (d,), minval=0.9, maxval=0.999),
        "w_ig": _dense_init(ks[4], (d, d)),     # input gate
        "w_rg": _dense_init(ks[5], (d, d)),     # recurrence gate
        "out": _dense_init(ks[6], (d, d)),
    }


_RG_C = 8.0


def _rg_lru(x, ig, rg, lam, h0=None):
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t); associative scan.
    x/ig/rg: (B, S, D); lam: (D,); h0: (B, D) carried state."""
    log_a = -_RG_C * jax.nn.softplus(-jnp.log(lam / (1 - lam))) \
        * jax.nn.sigmoid(rg)
    a = jnp.exp(log_a.astype(jnp.float32))
    gated = (jax.nn.sigmoid(ig) * x).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def comb(p, q):
        return (p[0] * q[0], p[1] * q[0] + q[1])

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rec_apply(p, x, cfg: ModelConfig, cache=None):
    """RecurrentGemma recurrent block.  cache = dict(h, conv) for decode."""
    B, S, d = x.shape
    xb = x @ p["in_x"].astype(x.dtype)
    gb = jax.nn.gelu(x @ p["in_g"].astype(x.dtype))
    w = p["conv_w"].shape[0]
    if cache is not None:
        xpad = jnp.concatenate([cache["conv"].astype(xb.dtype), xb], axis=1)
        new_conv = xpad[:, -(w - 1):]
    else:
        xpad = jnp.pad(xb, ((0, 0), (w - 1, 0), (0, 0)))
        new_conv = xpad[:, -(w - 1):]
    xc = sum(xpad[:, i:i + S] * p["conv_w"].astype(xb.dtype)[i]
             for i in range(w)) + p["conv_b"].astype(xb.dtype)
    ig = xc @ p["w_ig"].astype(x.dtype)
    rg = xc @ p["w_rg"].astype(x.dtype)
    h0 = cache["h"] if cache is not None else None
    h, h_last = _rg_lru(xc, ig, rg, p["lam"], h0)
    y = (h * gb) @ p["out"].astype(x.dtype)
    new_cache = ({"h": h_last.astype(jnp.float32), "conv": new_conv}
                 if cache is not None else None)
    return y, new_cache


def init_rec_cache(cfg: ModelConfig, batch: int):
    d, w = cfg.d_model, cfg.conv_width
    return {"h": jnp.zeros((batch, d), jnp.float32),
            "conv": jnp.zeros((batch, w - 1, d), jnp.bfloat16)}


# ------------------------------ mLSTM ------------------------------

def init_mlstm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 7)
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.d_model // cfg.n_heads
    return {
        "wq": _dense_init(ks[0], (d, d)),
        "wk": _dense_init(ks[1], (d, d)),
        "wv": _dense_init(ks[2], (d, d)),
        "wi": _dense_init(ks[3], (d, H)),
        "wf": _dense_init(ks[4], (d, H)),
        "wg": _dense_init(ks[5], (d, d)),       # output gate (silu)
        "out": _dense_init(ks[6], (d, d)),
    }


MLSTM_CHUNK = 1024


def _mlstm_chunk_scan(q, k, v, li, lf, C0, n0):
    """Chunkwise-parallel mLSTM.  q,k,v: (B,S,H,hd); li/lf: (B,S,H) log
    input/forget gates.  Carries (C, n) across chunks; intra-chunk is a
    (c x c) parallel form.  Returns h (B,S,H,hd) and final state."""
    B, S, H, hd = q.shape
    c = min(MLSTM_CHUNK, S)
    nc = S // c
    qc = jnp.moveaxis(q.reshape(B, nc, c, H, hd), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nc, c, H, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, c, H, hd), 1, 0)
    lic = jnp.moveaxis(li.reshape(B, nc, c, H), 1, 0)
    lfc = jnp.moveaxis(lf.reshape(B, nc, c, H), 1, 0)
    scale = 1.0 / math.sqrt(hd)

    def body(carry, inp):
        C, n = carry                       # (B,H,hd,hd), (B,H,hd)
        qi, ki, vi, lii, lfi = inp
        F = jnp.cumsum(lfi, axis=1)        # (B,c,H) running log-forget
        # inter-chunk: contribution of carried state
        dq = jnp.exp(F)[..., None]         # decay applied to carry
        h_inter = jnp.einsum("bthd,bhde->bthe", qi * dq * scale, C)
        n_inter = jnp.einsum("bthd,bhd->bth", qi * dq * scale, n)
        # intra-chunk parallel form
        dmat = F[:, :, None, :] - F[:, None, :, :] + lii[:, None, :, :]
        tq = jnp.arange(c)[:, None]
        tk = jnp.arange(c)[None, :]
        causal = (tk <= tq)[None, :, :, None]
        dmat = jnp.where(causal, dmat, -jnp.inf)
        w = jnp.exp(dmat)                  # (B, tq, tk, H)
        s = jnp.einsum("bthd,bshd->btsh", qi, ki) * scale
        sw = s * w
        h_intra = jnp.einsum("btsh,bshd->bthd", sw, vi)
        n_intra = sw.sum(axis=2)
        h_num = h_inter + h_intra
        n_den = jnp.abs(n_inter + n_intra)
        h = h_num / jnp.maximum(n_den, 1.0)[..., None]
        # state update for next chunk
        ftot = F[:, -1]                    # (B, H)
        dk = jnp.exp(ftot[:, None] - F + lii)          # (B,c,H)
        C = C * jnp.exp(ftot)[..., None, None] \
            + jnp.einsum("bshd,bshe->bhde", ki * dk[..., None], vi)
        n = n * jnp.exp(ftot)[..., None] \
            + jnp.einsum("bshd->bhd", ki * dk[..., None])
        return (C, n), h

    # checkpoint the chunk body: the (c x c) intra-chunk gate matrix is
    # recomputed in the bwd pass rather than stashed per chunk.
    (C, n), hs = jax.lax.scan(jax.checkpoint(body), (C0, n0),
                              (qc, kc, vc, lic, lfc))
    return jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd), (C, n)


def mlstm_apply(p, x, cfg: ModelConfig, cache=None):
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, H, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, H, hd)
    li = jax.nn.log_sigmoid(x @ p["wi"].astype(x.dtype)).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(x @ p["wf"].astype(x.dtype)).astype(jnp.float32)
    if cache is not None:
        C0, n0 = cache["C"], cache["n"]
    else:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    h, (C, n) = _mlstm_chunk_scan(qf, kf, vf, li, lf, C0, n0)
    h = h.astype(x.dtype).reshape(B, S, d)
    g = jax.nn.silu(x @ p["wg"].astype(x.dtype))
    y = (h * g) @ p["out"].astype(x.dtype)
    new_cache = {"C": C, "n": n} if cache is not None else None
    return y, new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    H = cfg.n_heads
    hd = cfg.d_model // H
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32)}


# ------------------------------ sLSTM ------------------------------

def init_slstm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    return {
        "wx": _dense_init(ks[0], (d, 4 * d)),
        "r": jax.random.normal(ks[1], (H, hd, 4 * hd)) / math.sqrt(hd),
        "out": _dense_init(ks[2], (d, d)),
    }


def slstm_apply(p, x, cfg: ModelConfig, cache=None):
    """sLSTM with exponential gating and per-head recurrent mixing.
    Sequential scan over time (inherently recurrent)."""
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    zx = (x @ p["wx"].astype(x.dtype)).reshape(B, S, H, 4 * hd) \
        .astype(jnp.float32)
    R = p["r"].astype(jnp.float32)

    if cache is not None:
        st0 = (cache["c"], cache["n"], cache["h"], cache["m"])
    else:
        z = jnp.zeros((B, H, hd), jnp.float32)
        st0 = (z, z, z, jnp.full((B, H, hd), -1e30, jnp.float32))

    def step(st, zt):
        c, n, h, m = st
        rec = jnp.einsum("bhd,hde->bhe", h, R)
        zi, zf, zz, zo = jnp.split(zt + rec, 4, axis=-1)
        m_new = jnp.maximum(zf + m, zi)
        i = jnp.exp(zi - m_new)
        f = jnp.exp(zf + m - m_new)
        c = f * c + i * jnp.tanh(zz)
        n = f * n + i
        h = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    st, hs = jax.lax.scan(step, st0, jnp.moveaxis(zx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    y = h @ p["out"].astype(x.dtype)
    new_cache = ({"c": st[0], "n": st[1], "h": st[2], "m": st[3]}
                 if cache is not None else None)
    return y, new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int):
    H = cfg.n_heads
    hd = cfg.d_model // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}
