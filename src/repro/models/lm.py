"""Unified decoder-only LM covering the dense / MoE / hybrid / SSM /
VLM-backbone families.

Layers are organized as repeating *units* (cfg.block_pattern); the unit
stack is jax.lax.scan'ed over stacked parameters, which keeps the HLO
size O(1) in depth (essential for the 126-layer dry-run cells) and
gives the standard remat point for activation checkpointing.  A
non-full trailing unit ("tail") is applied unrolled.

Cache threading for decode uses the same stacking: caches are pytrees
stacked over units, scanned jointly with the parameters.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import layers as L


# ----------------------------- layout -----------------------------

def pattern_layout(cfg: ModelConfig):
    pat = tuple(cfg.block_pattern)
    n_units, tail = divmod(cfg.n_layers, len(pat))
    return pat, n_units, tail


# ------------------------------ init ------------------------------

def _init_block(key, kind: str, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind == "attn":
        p = {"ln1": L.init_rmsnorm(d), "attn": L.init_attn(ks[0], cfg),
             "ln2": L.init_rmsnorm(d)}
        if cfg.n_experts:
            p["moe"] = L.init_moe(ks[1], cfg)
            if cfg.dense_residual:
                p["mlp"] = L.init_mlp(ks[2], d, cfg.d_ff)
        elif cfg.d_ff:
            p["mlp"] = L.init_mlp(ks[1], d, cfg.d_ff)
        return p
    if kind == "rec":
        return {"ln1": L.init_rmsnorm(d), "rec": L.init_rec(ks[0], cfg),
                "ln2": L.init_rmsnorm(d),
                "mlp": L.init_mlp(ks[1], d, cfg.d_ff)}
    if kind == "mlstm":
        return {"ln1": L.init_rmsnorm(d), "mix": L.init_mlstm(ks[0], cfg)}
    if kind == "slstm":
        return {"ln1": L.init_rmsnorm(d), "mix": L.init_slstm(ks[0], cfg)}
    raise ValueError(kind)


def init(cfg: ModelConfig, key) -> dict:
    pat, n_units, tail = pattern_layout(cfg)
    keys = jax.random.split(key, 3 + cfg.n_layers)
    params: dict = {}
    vp = cfg.vocab_padded
    params["embed"] = (jax.random.normal(keys[0], (vp, cfg.d_model))
                       * 0.02).astype(jnp.float32)
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(keys[1], (cfg.d_model, vp))
                          * 0.02).astype(jnp.float32)
    params["ln_f"] = L.init_rmsnorm(cfg.d_model)

    li = iter(keys[3:])
    if n_units:
        units = []
        for _ in range(n_units):
            units.append({f"b{i}": _init_block(next(li), kind, cfg)
                          for i, kind in enumerate(pat)})
        params["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *units)
    if tail:
        params["tail"] = [
            _init_block(next(li), pat[i], cfg) for i in range(tail)]
    return params


# ------------------------------ blocks ------------------------------

def _block_apply(kind: str, p, x, cfg: ModelConfig, *, positions,
                 cache=None):
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        window = cfg.local_window if cfg.family == "hybrid" else 0
        h, c = L.attn_apply(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                            cfg, positions=positions,
                            cache=cache["attn"] if cache else None,
                            window=window, norm_eps=cfg.norm_eps)
        x = x + h
        hin = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.n_experts:
            y, aux = L.moe_apply(p["moe"], hin, cfg)
            if cfg.dense_residual:
                y = y + L.mlp_apply(p["mlp"], hin)
            x = x + y
        elif cfg.d_ff:
            x = x + L.mlp_apply(p["mlp"], hin)
        new_cache = {"attn": c} if cache else None
    elif kind == "rec":
        h, c = L.rec_apply(p["rec"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                           cfg, cache=cache["rec"] if cache else None)
        x = x + h
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
        new_cache = {"rec": c} if cache else None
    elif kind == "mlstm":
        h, c = L.mlstm_apply(p["mix"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                             cfg, cache=cache["mix"] if cache else None)
        x = x + h
        new_cache = {"mix": c} if cache else None
    elif kind == "slstm":
        h, c = L.slstm_apply(p["mix"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                             cfg, cache=cache["mix"] if cache else None)
        x = x + h
        new_cache = {"mix": c} if cache else None
    else:
        raise ValueError(kind)
    return x, new_cache, aux


def _init_block_cache(kind: str, cfg: ModelConfig, batch: int,
                      max_seq: int, dtype):
    if kind == "attn":
        window = cfg.local_window if cfg.family == "hybrid" else 0
        return {"attn": L.init_attn_cache(cfg, batch, max_seq, dtype,
                                          window=window)}
    if kind == "rec":
        return {"rec": L.init_rec_cache(cfg, batch)}
    if kind == "mlstm":
        return {"mix": L.init_mlstm_cache(cfg, batch)}
    if kind == "slstm":
        return {"mix": L.init_slstm_cache(cfg, batch)}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    pat, n_units, tail = pattern_layout(cfg)
    cache: dict = {}
    if n_units:
        us = [{f"b{i}": _init_block_cache(kind, cfg, batch, max_seq, dtype)
               for i, kind in enumerate(pat)} for _ in range(n_units)]
        cache["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *us)
    if tail:
        cache["tail"] = [
            _init_block_cache(pat[i], cfg, batch, max_seq, dtype)
            for i in range(tail)]
    return cache


# ----------------------------- forward -----------------------------

def _run_stack(params, cfg, x, positions, cache=None, remat=False,
               unroll=False):
    """Apply all layers; returns (x, new_cache, aux_sum).
    unroll=True replaces the unit scan with a Python loop (used for
    flop-accounting validation: XLA cost_analysis counts while bodies
    once, so the scanned form under-reports)."""
    pat, n_units, tail = pattern_layout(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict = {}

    if n_units and unroll and cache is None:
        for u in range(n_units):
            up = jax.tree.map(lambda a: a[u], params["units"])
            for i, kind in enumerate(pat):
                x, _, aux = _block_apply(kind, up[f"b{i}"], x, cfg,
                                         positions=positions)
                aux_total = aux_total + aux
    elif n_units:
        def unit(xc, scanned):
            x, auxa = xc
            up, uc = scanned
            ncs = {}
            for i, kind in enumerate(pat):
                bc = uc[f"b{i}"] if uc is not None else None
                x, nc, aux = _block_apply(kind, up[f"b{i}"], x, cfg,
                                          positions=positions, cache=bc)
                ncs[f"b{i}"] = nc
                auxa = auxa + aux
            return (x, auxa), (ncs if uc is not None else 0)

        ufn = jax.checkpoint(unit) if remat else unit
        ucache = cache.get("units") if cache else None
        if ucache is None:
            (x, aux_total), _ = jax.lax.scan(
                lambda c, p_: ufn(c, (p_, None)), (x, aux_total),
                params["units"])
        else:
            (x, aux_total), ncs = jax.lax.scan(
                ufn, (x, aux_total), (params["units"], ucache))
            new_cache["units"] = ncs

    if tail:
        tail_caches = []
        for i in range(tail):
            bc = cache["tail"][i] if cache else None
            x, nc, aux = _block_apply(pat[i], params["tail"][i], x, cfg,
                                      positions=positions, cache=bc)
            tail_caches.append(nc)
            aux_total = aux_total + aux
        if cache:
            new_cache["tail"] = tail_caches

    return x, (new_cache if cache else None), aux_total


def forward(params, cfg: ModelConfig, tokens=None, *, embeds=None,
            positions=None, remat: bool = False, dtype=jnp.bfloat16,
            last_only: bool = False, unroll: bool = False,
            logits_spec=None):
    """Full-sequence forward (train / prefill).  Returns (logits, aux).

    tokens: (B, S) int32, or embeds: (B, S, D) for stub-frontend archs.
    positions: (B, S) or (3, B, S) for M-RoPE; defaults to arange.
    last_only: emit logits only for the final position (prefill)."""
    if embeds is None:
        x = params["embed"].astype(dtype)[tokens]
    else:
        x = embeds.astype(dtype)
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[None], (3, B, S))
    x, _, aux = _run_stack(params, cfg, x, positions, remat=remat,
                           unroll=unroll)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ head.astype(x.dtype)
    logits = _mask_padded_vocab(logits, cfg)
    if logits_spec is not None:
        # pin the (B, S, V) logits sharding: without this the SPMD
        # partitioner replicates them across the pod axis (hundreds of
        # GB/dev for big-vocab archs on the multi-pod mesh).
        logits = jax.lax.with_sharding_constraint(logits, logits_spec)
    return logits, aux


def _mask_padded_vocab(logits, cfg):
    """Padded vocab rows (see configs.vocab_padded) get -inf logits so
    softmax/argmax ignore them; elementwise, sharding-friendly."""
    if cfg.vocab_padded == cfg.vocab:
        return logits
    valid = jnp.arange(cfg.vocab_padded) < cfg.vocab
    return jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))


def decode_step(params, cfg: ModelConfig, tokens, cache, *, embeds=None,
                dtype=jnp.bfloat16):
    """One-token decode: tokens (B, 1) + caches -> (logits, new_cache)."""
    if embeds is None:
        x = params["embed"].astype(dtype)[tokens]
    else:
        x = embeds.astype(dtype)
    B, S = x.shape[:2]
    pos = _decode_positions(cfg, cache, B, S)
    x, new_cache, _ = _run_stack(params, cfg, x, pos, cache=cache)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = _mask_padded_vocab(x @ head.astype(x.dtype), cfg)
    return logits, new_cache


def _decode_positions(cfg, cache, B, S):
    """Current absolute position from the first attention cache; pure
    recurrent stacks (no attn cache) fall back to a step counter that we
    thread as cache['pos'] if present, else zero (positions only matter
    for RoPE in attention blocks)."""
    pos0 = _find_attn_pos(cache)
    if pos0 is None:
        pos0 = jnp.zeros((), jnp.int32)
    p = pos0 + jnp.arange(S)[None]
    p = jnp.broadcast_to(p, (B, S))
    if cfg.mrope_sections:
        p = jnp.broadcast_to(p[None], (3, B, S))
    return p


def _find_attn_pos(tree):
    if isinstance(tree, dict):
        if "pos" in tree and not isinstance(tree["pos"], dict):
            p = tree["pos"]
            # stacked over units: take the first
            return p.reshape(-1)[0] if p.ndim else p
        for v in tree.values():
            r = _find_attn_pos(v)
            if r is not None:
                return r
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            r = _find_attn_pos(v)
            if r is not None:
                return r
    return None


# ------------------------------ loss ------------------------------

def loss_fn(params, cfg: ModelConfig, batch, *, remat=False,
            dtype=jnp.bfloat16, aux_weight: float = 0.01,
            logits_spec=None):
    """Next-token cross entropy (+ MoE aux loss).  batch: dict with
    tokens (B, S) and labels (B, S) (already shifted), optional embeds."""
    logits, aux = forward(params, cfg, batch.get("tokens"),
                          embeds=batch.get("embeds"), remat=remat,
                          dtype=dtype, logits_spec=logits_spec)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    # label log-prob via a one-hot reduction instead of take_along_axis:
    # a gather over the TP-sharded vocab dim forces the SPMD partitioner
    # to replicate the (B, S, V) logits; the masked sum reduces the
    # sharded dim with a psum instead.
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=lf.dtype)
    ll = jnp.sum(lf * onehot, axis=-1)
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    loss = ((lse - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux_weight * aux
