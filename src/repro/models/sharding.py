"""Sharding rules: parameter / cache / batch PartitionSpecs.

Layout on the production mesh (pod, data, model):

  * DP over ("pod", "data") for activations and the gradient allreduce.
  * FSDP (ZeRO-3): parameters, gradients and optimizer state sharded
    over "data" on their first non-TP dimension.
  * TP (Megatron): attention heads / FFN width over "model";
    paired projections are row/col-parallel so each block needs exactly
    one reduce per sublayer.
  * EP: MoE expert dimension over "model" (experts never co-reside with
    the TP shards they would conflict with: expert weights are 3D
    (E, D, F) with E on "model", D on "data").
  * KV caches: batch over DP, sequence over "model" (decode-time TP has
    little head parallelism to exploit for GQA kv=8, so the cache's big
    axis -- sequence -- takes the model axis instead; attention scores
    are then reduced over "model" by GSPMD).

Every rule degrades gracefully: a dimension that does not divide its
mesh axes is replicated instead (``_fit``), so odd vocabularies
(whisper's 51865) and head counts (smollm's 15) lower cleanly.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig


def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh, axis):
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(mesh: Mesh, shape, dims):
    """Null out spec dims that don't divide the dimension size."""
    out = []
    for size, d in zip(shape, dims):
        out.append(d if d and size % _axis_size(mesh, d) == 0 else None)
    return P(*out)


# --------------------------- parameter rules ---------------------------

def _leaf_spec(mesh, path, leaf, fsdp="data", tp="model"):
    names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    name = names[-1]
    parent = names[-2] if len(names) > 1 else None
    nd = leaf.ndim
    stacked = ("units" in names or "enc" in names or "dec" in names)
    base = nd - 1 if stacked else nd

    def spec(*dims):
        dims = (None,) * (nd - len(dims)) + tuple(dims)  # leading stack dims
        return _fit(mesh, leaf.shape, dims)

    if name == "embed":
        # vocab over TP: the lookup becomes a partitioned gather
        # (mask + psum over the model axis), and — decisive for train
        # memory — logits and their gradients stay vocab-sharded.
        # (V-replicated layouts force a full (B,S,V) logits-grad
        # all-gather per device: ~160 GB/dev for qwen3 multi-pod.
        # Sharding BOTH dims instead trips involuntary full
        # rematerialization in the partitioner.)
        return spec(tp, None)
    if name == "head":
        return spec(None, tp)
    if name in ("wq", "wk", "wv", "gate", "up", "wg", "wx", "in_x", "in_g",
                "w_ig", "w_rg", "wi", "wf"):
        if parent in ("moe",) or base == 3:
            # (E, D, F): EP over the expert dim when it divides the
            # model axis; otherwise fall back to TP on the FFN width
            # (e.g. grok's 8 experts < 16-way model axis).
            E = leaf.shape[-3]
            if tp and E % _axis_size(mesh, tp) == 0:
                return spec(tp, fsdp, None)
            return spec(None, fsdp, tp)
        return spec(fsdp, tp)
    if name in ("wo", "down", "out"):
        if parent in ("moe",) or base == 3:
            E = leaf.shape[-3]
            if tp and E % _axis_size(mesh, tp) == 0:
                return spec(tp, None, fsdp)
            return spec(None, tp, fsdp)
        return spec(tp, fsdp)
    if name == "router":
        return spec(fsdp, None)
    if name == "r":                            # sLSTM recurrent (H, hd, 4hd)
        return spec(None, None, tp)
    if name == "conv_w":
        return spec(None, tp)
    # norms, biases, lambdas, scalars: replicate
    return P(*([None] * nd))


def roles(mesh: Mesh, mode: str = "2d"):
    """Map sharding mode -> (fsdp_axes, tp_axis).

    "2d" (default): FSDP over "data", TP over "model".
    "fsdp_all": pure ZeRO-3 — parameters sharded over data x model, no
        tensor parallelism; activations take the model axis as sequence
        parallelism (see batch_specs).  Kills the per-layer TP
        reductions — the hillclimb lever for small collective-bound
        models (EXPERIMENTS.md Sec. Perf)."""
    if mode == "2d":
        return "data", "model"
    if mode == "fsdp_all":
        return ("data", "model"), None
    raise ValueError(mode)


def param_specs(cfg: ModelConfig, params, mesh: Mesh, mode: str = "2d"):
    """PartitionSpec pytree matching ``params`` (works on shapes from
    jax.eval_shape too)."""
    fsdp, tp = roles(mesh, mode)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(mesh, path, leaf, fsdp=fsdp, tp=tp),
        params)


def param_shardings(cfg, params, mesh, mode: str = "2d"):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, params, mesh, mode))


# ----------------------------- cache rules -----------------------------

def _cache_leaf_spec(mesh, path, leaf, tp="model"):
    names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    name = names[-1]
    dp = dp_axes(mesh)
    nd = leaf.ndim
    stacked = "units" in names or "self" in names
    lead = 1 if stacked else 0

    def spec(*dims):
        dims = (None,) * lead + tuple(dims)
        dims = dims + (None,) * (nd - len(dims))
        return _fit(mesh, leaf.shape, dims)

    if name in ("k", "v", "k_scale", "v_scale"):
        return spec(dp, tp)           # (B, S, G, ...): batch DP, seq TP
    if name == "C":                   # mLSTM (B, H, hd, hd)
        return spec(dp, None, tp)
    if name in ("n", "h", "c", "m"):  # recurrent states (B, ...)
        return spec(dp)
    if name == "conv":                # (B, w-1, D)
        return spec(dp, None, tp)
    if name == "pos":
        return P(*([None] * nd))
    return spec(dp)


def cache_specs(cfg: ModelConfig, cache, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_leaf_spec(mesh, path, leaf), cache)


# ----------------------------- batch rules -----------------------------

def batch_specs(batch, mesh: Mesh, mode: str = "2d"):
    dp = dp_axes(mesh)
    seq_axis = "model" if mode == "fsdp_all" else None

    def one(path, leaf):
        nd = leaf.ndim
        dims = (dp, seq_axis) + (None,) * (nd - 2) if nd >= 2 \
            else (dp,)
        return _fit(mesh, leaf.shape, dims[:nd])

    return jax.tree_util.tree_map_with_path(one, batch)
