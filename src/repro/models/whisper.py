"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, enc_frames, D) from input_specs().
Sinusoidal positions on the encoder, causal decoder with cross-attention.
Decode caches: per-layer self-attn cache + precomputed cross K/V.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import layers as L


def _sinusoid_at(positions, d, dtype=jnp.float32):
    """Sinusoidal embeddings at explicit (possibly traced) positions."""
    pos = positions[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                           axis=-1).astype(dtype)


def _sinusoid(S, d, dtype=jnp.float32):
    return _sinusoid_at(jnp.arange(S), d, dtype)


def _init_xattn(key, cfg):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    return {"wq": L._dense_init(ks[0], (d, cfg.n_heads * hd)),
            "wk": L._dense_init(ks[1], (d, cfg.n_kv * hd)),
            "wv": L._dense_init(ks[2], (d, cfg.n_kv * hd)),
            "wo": L._dense_init(ks[3], (cfg.n_heads * hd, d))}


def init(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": L.init_rmsnorm(d), "attn": L.init_attn(k1, cfg),
                "ln2": L.init_rmsnorm(d),
                "mlp": L.init_mlp(k2, d, cfg.d_ff, gated=False)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": L.init_rmsnorm(d), "attn": L.init_attn(k1, cfg),
                "lnx": L.init_rmsnorm(d), "xattn": _init_xattn(k2, cfg),
                "ln2": L.init_rmsnorm(d),
                "mlp": L.init_mlp(k3, d, cfg.d_ff, gated=False)}

    ek = jax.random.split(ks[0], cfg.n_enc_layers)
    dk = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": (jax.random.normal(ks[2], (cfg.vocab_padded, d)) * 0.02),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[enc_layer(k) for k in ek]),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[dec_layer(k) for k in dk]),
        "ln_enc": L.init_rmsnorm(d),
        "ln_f": L.init_rmsnorm(d),
    }


def encode(params, cfg: ModelConfig, frames, dtype=jnp.bfloat16):
    """frames: (B, enc_frames, D) stub embeddings -> encoder states."""
    B, S, d = frames.shape
    x = frames.astype(dtype) + _sinusoid(S, d, dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def layer(x, p):
        h, _ = L.attn_apply(p["attn"], L.rmsnorm(p["ln1"], x), cfg,
                            positions=pos, causal=False)
        x = x + h
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(p["ln2"], x))
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(layer), x, params["enc"])
    return L.rmsnorm(params["ln_enc"], x)


def _cross_attend(p, x, enc_kv, cfg):
    B, S, d = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.n_heads, hd)
    K, V = enc_kv
    s = L._gqa_scores(q, K.astype(q.dtype)) / math.sqrt(hd)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    o = L._gqa_out(w, V.astype(q.dtype))
    return o.reshape(B, S, cfg.n_heads * hd) @ p["wo"].astype(x.dtype)


def _enc_kv(params, cfg, enc_states):
    """Precompute per-layer cross-attention K/V from encoder states."""
    B, S, d = enc_states.shape
    hd = cfg.head_dim

    def one(p):
        K = (enc_states @ p["xattn"]["wk"].astype(enc_states.dtype)
             ).reshape(B, S, cfg.n_kv, hd)
        V = (enc_states @ p["xattn"]["wv"].astype(enc_states.dtype)
             ).reshape(B, S, cfg.n_kv, hd)
        return K, V

    return jax.vmap(one)(params["dec"])    # stacked over layers


def decode(params, cfg: ModelConfig, tokens, enc_states, *, cache=None,
           dtype=jnp.bfloat16, last_only: bool = False):
    """Decoder forward.  Full-seq (cache=None) or one-step (cache)."""
    B, S = tokens.shape
    x = params["embed"].astype(dtype)[tokens]
    if cache is None:
        pos0 = jnp.zeros((), jnp.int32)
    else:
        pos0 = cache["self"]["attn"]["pos"].reshape(-1)[0]
    x = x + _sinusoid_at(pos0 + jnp.arange(S), x.shape[-1], dtype)[None]
    pos = jnp.broadcast_to(pos0 + jnp.arange(S)[None], (B, S))
    enc_kv = _enc_kv(params, cfg, enc_states)

    def layer(carry, scanned):
        x = carry
        if cache is None:
            p, (Ki, Vi) = scanned
            c = None
        else:
            p, (Ki, Vi), c = scanned
        h, nc = L.attn_apply(p["attn"], L.rmsnorm(p["ln1"], x), cfg,
                             positions=pos,
                             cache=c["attn"] if c is not None else None)
        x = x + h
        x = x + _cross_attend(p["xattn"], L.rmsnorm(p["lnx"], x),
                              (Ki, Vi), cfg)
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(p["ln2"], x))
        return x, ({"attn": nc} if c is not None else 0)

    if cache is None:
        # remat per decoder layer: cross-attn weights (B, H, S, 1500)
        # would otherwise be stashed for every layer.
        x, _ = jax.lax.scan(jax.checkpoint(layer), x,
                            (params["dec"], enc_kv))
        new_cache = None
    else:
        x, ncs = jax.lax.scan(layer, x,
                              (params["dec"], enc_kv, cache["self"]))
        new_cache = {"self": ncs}
    x = L.rmsnorm(params["ln_f"], x)
    if last_only:
        x = x[:, -1:]
    logits = x @ params["embed"].T.astype(x.dtype)
    from repro.models.lm import _mask_padded_vocab
    logits = _mask_padded_vocab(logits, cfg)
    return logits, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16):
    caches = [{"attn": L.init_attn_cache(cfg, batch, max_seq, dtype)}
              for _ in range(cfg.n_layers)]
    return {"self": jax.tree.map(lambda *xs: jnp.stack(xs), *caches)}


def loss_fn(params, cfg: ModelConfig, batch, dtype=jnp.bfloat16,
            logits_spec=None, **_):
    enc = encode(params, cfg, batch["frames"], dtype)
    logits, _ = decode(params, cfg, batch["tokens"], enc, dtype=dtype)
    if logits_spec is not None:
        logits = jax.lax.with_sharding_constraint(logits, logits_spec)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    # one-hot reduction (not take_along_axis): see lm.loss_fn — gathers
    # over the TP-sharded vocab dim replicate the logits.
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=lf.dtype)
    ll = jnp.sum(lf * onehot, axis=-1)
    return (lse - ll).mean()
