"""Optimizers: AdamW baseline and the KFAC-CA second-order optimizer
whose preconditioner solves run through the paper's CA-TRSM."""

from repro.optim.adamw import adamw  # noqa: F401
from repro.optim.kfac_ca import kfac_ca  # noqa: F401


def get(name: str, **kw):
    if name == "adamw":
        return adamw(**kw)
    if name == "kfac_ca":
        return kfac_ca(**kw)
    raise ValueError(name)
