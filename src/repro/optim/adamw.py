"""AdamW on parameter pytrees (optax-style (init, update) pair)."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    # (grads, state, params) -> (new_params, new_state, metrics)
    update: Callable


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
          clip_norm=1.0, moment_dtype=jnp.float32):
    """lr may be a float or a schedule fn(step)->lr."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=moment_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(moment_dtype)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            mh = m2 / bc1
            vh = v2 / bc2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * \
                p.astype(moment_dtype)
            return (p - lr_t * delta.astype(p.dtype)).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        is_t = lambda t: isinstance(t, tuple)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_t)
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_t)
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is_t)
        return new_params, {"m": new_m, "v": new_v, "step": step}, \
            {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, update)
