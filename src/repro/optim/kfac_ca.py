"""KFAC-CA: Kronecker-factored preconditioning whose solves run through
the paper's inversion-based TRSM.

This is where the paper's technique becomes a first-class framework
feature (DESIGN.md Sec. 3).  For every eligible 2D weight W (d_out x
d_in) we maintain Kronecker factor EMAs

    A = EMA[G G^T] + lambda I      (d_out x d_out)
    B = EMA[G^T G] + lambda I      (d_in  x d_in)

and precondition   P = A^{-1} G B^{-1}.

Both applications are SPD solves through the Cholesky factors of A and
B — i.e. FOUR triangular solves per tensor per step, exactly the
TRSM-inside-a-factorization pattern the paper cites as its motivation.
The solves use It-Inv-TRSM (multiplication by pre-inverted diagonal
blocks — repro.core.blocked.it_inv_trsm_local; on pod-scale factor
matrices the distributed repro.core.inv_trsm engine plugs into the same
``solver`` hook).  The Cholesky itself is the selective-inversion
blocked factorization from repro.core.cholesky.

Stacked parameters (scan units, MoE experts) are handled by vmapping
the whole preconditioner over the leading axis.  Non-eligible tensors
(norms, embeddings beyond max_dim, 1D) fall back to AdamW.  Updates are
grafted to the AdamW update norm for trust-region control.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import blocked, cholesky
from repro.optim.adamw import Optimizer, adamw, clip_by_global_norm, \
    global_norm


def _pow2_divisor(n: int, cap: int = 64) -> int:
    d = 1
    while n % (d * 2) == 0 and d * 2 <= cap:
        d *= 2
    return d


def _trsm_solver(L, Bm):
    """It-Inv-TRSM local solve; n0 = largest pow2 divisor (<= 64)."""
    n0 = _pow2_divisor(L.shape[-1])
    return blocked.it_inv_trsm_local(L, Bm, n0)


def _spd_solve(chol, X):
    return blocked.spd_solve(chol, X, _trsm_solver)


def _chol(A):
    bs = _pow2_divisor(A.shape[-1], cap=128)
    if bs >= 8:
        return cholesky.chol_blocked_local(A, bs)
    return jnp.linalg.cholesky(A)


def _spd_inv(M):
    """SPD inverse through the paper's machinery: blocked Cholesky
    (selective-inversion panels) + two triangular solves on I."""
    c = _chol(M)
    return _spd_solve(c, jnp.eye(M.shape[-1], dtype=M.dtype))


def _inv_sqrt(A, iters: int = 14):
    """A^{-1/2} by Denman-Beavers:  Y <- (Y + Z^{-1})/2, Z <- (Z + Y^{-1})/2
    with Y -> A^{1/2}, Z -> A^{-1/2}.  Every iteration is two SPD
    inversions == two Cholesky factorizations + four CA-TRSM solves, so
    the whole preconditioner refresh is triangular-solve bound — the
    workload the paper optimizes."""
    d = A.shape[-1]
    c = jnp.trace(A) / d + 1e-30
    Y = A / c
    Z = jnp.eye(d, dtype=A.dtype)
    for _ in range(iters):
        Yn = 0.5 * (Y + _spd_inv(Z))
        Z = 0.5 * (Z + _spd_inv(Y))
        Y = Yn
    return Z / jnp.sqrt(c)


def _precondition(G, Aema, Bema, damping, mode="whiten"):
    """Precondition G through Cholesky + CA-TRSM solves.

    mode="whiten" (default): P = (A + lI)^{-1/2} G on the smaller side.
    With A = G G^T exactly this is U V^T — the fully orthogonalized
    (Muon-style / Shampoo-exponent) gradient; with the EMA it is the
    running-whitened variant.  The inverse root runs through
    Denman-Beavers, i.e. a chain of Cholesky + TRSM solves.

    mode="two_sided": P = A^{-1} G B^{-1} (4 solves) — kept as an
    ablation; with gradient-only factors this is S^{-3} in G's singular
    basis and converges poorly (tested), which is WHY whiten is the
    default.

    mode="inverse": one-sided (A + lI)^{-1} G (S^{-1}) — ablation."""
    do, di = G.shape
    if mode == "two_sided":
        lamA = damping * (jnp.trace(Aema) / do + 1e-12)
        lamB = damping * (jnp.trace(Bema) / di + 1e-12)
        cA = _chol(Aema + lamA * jnp.eye(do, dtype=Aema.dtype))
        cB = _chol(Bema + lamB * jnp.eye(di, dtype=Bema.dtype))
        P = _spd_solve(cA, G)             # A^{-1} G      (2 solves)
        P = _spd_solve(cB, P.T).T         # ... B^{-1}    (2 solves)
        return P
    transpose = do > di
    Gw = G.T if transpose else G
    A = Bema if transpose else Aema
    d = Gw.shape[0]
    lam = damping * (jnp.trace(A) / d + 1e-12)
    Ad = A + lam * jnp.eye(d, dtype=A.dtype)
    if mode == "inverse":
        P = _spd_solve(_chol(Ad), Gw)
    else:
        P = _inv_sqrt(Ad) @ Gw            # (A + lI)^{-1/2} G
    return P.T if transpose else P


def _damped_chol(M, damping: float):
    """``chol(M + lam I)`` with the preconditioner's trace-scaled
    damping — the factor the banks serve."""
    d = M.shape[-1]
    lam = damping * (jnp.trace(M) / d + 1e-12)
    return _chol(M + lam * jnp.eye(d, dtype=M.dtype))


def _iter_kron_factors(state):
    """Yield ``(name, side, M)`` for every Kronecker factor EMA in a
    kfac_ca state — the one traversal order ``factor_banks_from_state``
    banks in and ``refresh_banks`` refreshes in."""
    leaves = jax.tree_util.tree_leaves_with_path(
        state["kron"], is_leaf=lambda t: isinstance(t, tuple))
    for path, kron in leaves:
        if not (isinstance(kron, tuple) and len(kron) == 2):
            continue
        name = jax.tree_util.keystr(path)
        for side, M in zip(("A", "B"), kron):
            yield name, side, M


def _kron_order_counts(state) -> dict:
    """{order d: number of Kronecker factors of that order} — the
    mixed-order manifest the fleet planner prices."""
    counts: dict[int, int] = {}
    for _, _, M in _iter_kron_factors(state):
        d = int(M.shape[-1])
        counts[d] = counts.get(d, 0) + \
            (1 if M.ndim == 2 else int(M.shape[0]))
    return counts


def fleet_plan_from_state(state, grid=None, *, k: int = 16,
                          precision=None, machine=None,
                          dispatch_s=None, headroom: int = 0):
    """Price a kfac_ca state's mixed-order factor manifest through the
    fleet capacity planner (:func:`repro.core.fleet.plan_fleet`) — pure
    cost-model arithmetic, no devices; a mesh-less
    ``api.plan_grid(p1, p2)`` works."""
    from repro.core import fleet as fleetlib
    from repro.core.grid import make_trsm_mesh
    grid = grid if grid is not None else make_trsm_mesh(1, 1)
    kw = {} if dispatch_s is None else {"dispatch_s": dispatch_s}
    return fleetlib.plan_fleet(_kron_order_counts(state), grid, k=k,
                               precision=precision, machine=machine,
                               headroom=headroom, **kw)


def factor_banks_from_state(state, *, damping: float = 1e-3,
                            grid=None, precision=None,
                            method: str = "inv", n0: int | None = None,
                            map_mode: str = "vmap",
                            capacity="auto", fleet=None,
                            tenant: str = "kfac"):
    """Pool a kfac_ca optimizer state's per-layer Cholesky factors into
    :class:`repro.core.FactorBank`s for batched serving (DESIGN.md
    Sec. 9).

    Every eligible tensor contributes its DAMPED Kronecker-factor
    Cholesky factors — ``chol(A + lam I)`` (d_out side) and
    ``chol(B + lam I)`` (d_in side), the same damping rule the
    preconditioner applies — and factors of equal order are grouped
    into one bank per dimension, so applying / auditing the whole
    model's preconditioners is one batched dispatch per distinct layer
    width instead of 2 x #layers session solves.

    Returns ``(banks, manifest)``: ``banks`` maps dimension d to a
    FactorBank of all d x d factors — serve one with
    ``repro.api.Solver.from_bank(banks[d])`` (one dispatch per wave
    across the layer group) — and ``manifest`` maps d to the parallel
    list of ``(param_path, side, unit)`` tags (side "A" = output/Gram
    side, "B" = input side; unit indexes stacked 3D parameters, None
    for 2D) — ``manifest[d][i]`` names the factor at bank index i.

    ``capacity`` controls the banks' mutability (DESIGN.md Sec. 11):
    the default ``"auto"`` allocates each bank at exactly its factor
    count, so every KFAC bank is live-mutable (replace / evict /
    re-admit, fleet-reclaimable) with the SAME width — and therefore
    the same compiled programs — the old append-only banking produced.
    An int (uniform) or ``{d: C}`` mapping over-allocates churn
    headroom; ``capacity=None`` restores width-frozen append-only
    banks.

    ``fleet`` re-targets the banking at the mixed-order tier instead
    (DESIGN.md Sec. 12): pass a :class:`repro.core.fleet.SolverFleet`
    (or ``True`` to build one from :func:`fleet_plan_from_state`'s
    planner output) and every factor is admitted into its
    planner-chosen bucket under ``tenant`` with its manifest tag.
    Returns ``(fleet, manifest)`` where ``manifest`` maps each
    ``(param_path, side, unit)`` tag to its
    :class:`~repro.core.fleet.FleetHandle`; per-order ``banks[d]``
    dict consumers are unaffected (the default path is unchanged).
    """
    from repro.core import FactorBank
    from repro.core.grid import make_trsm_mesh

    grid = grid if grid is not None else make_trsm_mesh(1, 1)

    if fleet is not None and fleet is not False:
        from repro.core.fleet import SolverFleet
        if fleet is True:
            plan = fleet_plan_from_state(state, grid,
                                         precision=precision)
            fleet = SolverFleet(grid, plan)
        elif not isinstance(fleet, SolverFleet):
            raise TypeError(f"fleet must be a SolverFleet or True, got "
                            f"{type(fleet).__name__}")
        fleet.kfac_damping = damping
        handles: dict = {}
        for name, side, M in _iter_kron_factors(state):
            if M.ndim == 2:
                handles[(name, side, None)] = fleet.admit(
                    _damped_chol(M, damping), tenant=tenant,
                    tag=(name, side, None))
            else:
                cs = jax.vmap(lambda m: _damped_chol(m, damping))(M)
                for u in range(M.shape[0]):
                    handles[(name, side, u)] = fleet.admit(
                        cs[u], tenant=tenant, tag=(name, side, u))
        return fleet, handles

    counts = _kron_order_counts(state)

    def _cap(d):
        if capacity is None:
            return None
        if capacity == "auto":
            return counts[d]
        if isinstance(capacity, int):
            return capacity
        return capacity[d]

    banks: dict[int, FactorBank] = {}
    manifest: dict[int, list] = {}

    def admit(d, L, tags):
        """Admit one (d, d) factor or a stacked (u, d, d) chunk — the
        stack goes through the bank's one-dispatch admit_stack path."""
        if d not in banks:
            banks[d] = FactorBank(grid, d, method=method, n0=n0,
                                  dtype=None if precision is not None
                                  else L.dtype,
                                  precision=precision, map_mode=map_mode,
                                  capacity=_cap(d))
            # record the banking-time damping so refresh_banks cannot
            # silently diverge from the factors the manifest describes
            banks[d].kfac_damping = damping
            manifest[d] = []
        if L.ndim == 2:
            banks[d].admit(L)
        else:
            banks[d].admit_stack(L)
        manifest[d].extend(tags)

    for name, side, M in _iter_kron_factors(state):
        if M.ndim == 2:
            admit(M.shape[-1], _damped_chol(M, damping),
                  [(name, side, None)])
        else:                       # stacked units: vmapped chol,
            cs = jax.vmap(lambda m: _damped_chol(m, damping))(M)
            admit(M.shape[-1], cs,  # one stacked admission
                  [(name, side, u) for u in range(M.shape[0])])
    return banks, manifest


def refresh_banks(banks, manifest, state, *, damping: float | None = None):
    """Per-step IN-PLACE refresh of the banks built by
    :func:`factor_banks_from_state` (DESIGN.md Sec. 11).

    A KFAC preconditioner re-factorizes every ``update_freq`` steps;
    re-banking would re-admit every layer and (on the first width
    change) recompile — exactly the repeated admission cost the
    paper's hoisting argument says to never pay twice.  Instead, each
    banked factor's damped Cholesky is recomputed from the CURRENT EMA
    state and ``bank.replace``d into the slot the manifest assigned it
    at banking time: one compiled donated scatter per factor, zero
    retraces, occupancy and slot assignment unchanged — the serving
    side (``Solver.from_bank`` / ``SolveServer``) never notices the
    swap.  Stacked 3D parameters factorize in one vmapped Cholesky and
    — when their manifest slots form a contiguous run in a capacity
    bank (the banking-time layout) — scatter in ONE chunked dispatch
    through ``bank.replace_run`` instead of u single-slot dispatches
    (``UpdateSpec.chunk``, DESIGN.md Sec. 11); non-contiguous or
    append-only layouts fall back to per-unit replaces.  ``damping``
    defaults to the value RECORDED on each bank at banking time, so
    the refreshed factors stay exactly the ones the manifest
    describes; pass it explicitly only to re-damp on purpose.  Returns
    ``banks``.

    ``banks`` may also be the :class:`~repro.core.fleet.SolverFleet`
    returned by ``factor_banks_from_state(..., fleet=...)`` (with its
    tag -> handle manifest): each factor is then refreshed through its
    bucket's compiled updater via ``fleet.replace`` — same zero-retrace
    churn path, planner-chosen buckets.
    """
    from repro.core.fleet import SolverFleet
    if isinstance(banks, SolverFleet):
        damp = damping if damping is not None else \
            getattr(banks, "kfac_damping", 1e-3)
        for name, side, M in _iter_kron_factors(state):
            if M.ndim == 2:
                h = manifest.get((name, side, None))
                if h is not None:
                    banks.replace(h, _damped_chol(M, damp))
            else:
                cs = jax.vmap(lambda m: _damped_chol(m, damp))(M)
                for u in range(M.shape[0]):
                    h = manifest.get((name, side, u))
                    if h is not None:
                        banks.replace(h, cs[u])
        return banks

    index = {d: {tag: i for i, tag in enumerate(tags)}
             for d, tags in manifest.items()}
    for name, side, M in _iter_kron_factors(state):
        d = M.shape[-1]
        slots = index.get(d, {})
        if not slots:
            continue
        damp = damping if damping is not None else \
            getattr(banks[d], "kfac_damping", 1e-3)
        if M.ndim == 2:
            slot = slots.get((name, side, None))
            if slot is not None:
                banks[d].replace(slot, _damped_chol(M, damp))
        else:
            cs = jax.vmap(lambda m: _damped_chol(m, damp))(M)
            run = [slots.get((name, side, u))
                   for u in range(M.shape[0])]
            if None not in run and \
                    getattr(banks[d], "capacity", None) is not None \
                    and run == list(range(run[0], run[0] + len(run))):
                # contiguous banking-time layout: ONE chunked dispatch
                banks[d].replace_run(run[0], cs)
            else:
                for u, slot in enumerate(run):
                    if slot is not None:
                        banks[d].replace(slot, cs[u])
    return banks


def kfac_ca(lr=1e-3, ema=0.95, damping=1e-3, max_dim=8192, min_dim=8,
            clip_norm=1.0, update_freq: int = 1, mode: str = "whiten",
            **adam_kw):
    """Optimizer factory.  ``update_freq``: refresh the factor EMAs and
    re-factorize every k steps (stale preconditioner in between).
    ``mode``: "whiten" (default, one-sided) | "two_sided" (ablation)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)
    inner = adamw(lr=lr_fn, clip_norm=0.0, **adam_kw)

    def eligible(p):
        if p.ndim == 2:
            return (min_dim <= p.shape[0] <= max_dim
                    and min_dim <= p.shape[1] <= max_dim)
        if p.ndim == 3:     # stacked units / experts: vmap over axis 0
            return (min_dim <= p.shape[1] <= max_dim
                    and min_dim <= p.shape[2] <= max_dim)
        return False

    def init(params):
        def fstate(p):
            if not eligible(p):
                return ()
            if p.ndim == 2:
                do, di = p.shape
                return (jnp.zeros((do, do), jnp.float32),
                        jnp.zeros((di, di), jnp.float32))
            u, do, di = p.shape
            return (jnp.zeros((u, do, do), jnp.float32),
                    jnp.zeros((u, di, di), jnp.float32))

        return {"adam": inner.init(params),
                "kron": jax.tree.map(fstate, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        # adam pass computes the grafting baseline for every tensor
        adam_params, adam_state, _ = inner.update(grads, state["adam"],
                                                  params)
        lr_t = lr_fn(step)
        do_refresh = (step % update_freq) == 0

        def upd(p, g, kron, a_new):
            if not eligible(p):
                return a_new, kron
            gf = g.astype(jnp.float32)
            A, B = kron

            if p.ndim == 2:
                A2 = jnp.where(do_refresh, ema * A + (1 - ema) * gf @ gf.T,
                               A)
                B2 = jnp.where(do_refresh, ema * B + (1 - ema) * gf.T @ gf,
                               B)
                P = _precondition(gf, A2, B2, damping, mode)
            else:
                A2 = jnp.where(do_refresh,
                               ema * A + (1 - ema)
                               * jnp.einsum("uij,ukj->uik", gf, gf), A)
                B2 = jnp.where(do_refresh,
                               ema * B + (1 - ema)
                               * jnp.einsum("uji,ujk->uik", gf, gf), B)
                P = jax.vmap(functools.partial(
                    _precondition, damping=damping, mode=mode))(gf, A2, B2)
            # graft to the adam update magnitude
            adam_delta = (p - a_new).astype(jnp.float32)
            scale = jnp.linalg.norm(adam_delta) \
                / jnp.maximum(jnp.linalg.norm(lr_t * P), 1e-12)
            newp = (p.astype(jnp.float32)
                    - lr_t * P * scale).astype(p.dtype)
            return newp, (A2, B2)

        is_kron = lambda t: isinstance(t, tuple)
        out = jax.tree.map(upd, params, grads, state["kron"], adam_params,
                           is_leaf=lambda t: isinstance(t, tuple))
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_kron)
        new_kron = jax.tree.map(lambda t: t[1], out, is_leaf=is_kron)
        new_state = {"adam": adam_state, "kron": new_kron, "step": step}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, update)
