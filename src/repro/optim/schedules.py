"""LR schedules (pure functions of the step)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def constant(base_lr: float):
    return lambda step: jnp.asarray(base_lr, jnp.float32)
