"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bandwidth
    collective term = collective_bytes_per_device / ICI_link_bandwidth

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed — the
compiled module is the SPMD-partitioned per-device program, so these
are per-device numbers); collective bytes are NOT in cost_analysis, so
we parse the partitioned HLO text and sum the payload of every
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (result shape; operand shape for reduce-scatter,
whose result is the reduced shard).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (we charge one link, the conservative serialization bound).
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<dt>\w+)\[(?P<shape>[\d,]*)\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")

_TUPLE_ELT_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _nbytes(dt: str, shape: str) -> int:
    n = 1
    for t in shape.split(","):
        if t:
            n *= int(t)
    return n * _DTYPE_BYTES.get(dt, 4)


def parse_collectives(hlo_text: str) -> dict:
    """-> {op: {"bytes": int, "count": int}} per device.

    Counts each op once (all-reduce-start/done pairs are deduped by
    only counting non-`-done` forms)."""
    out: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if m.group("dt") is not None:
            nb = _nbytes(m.group("dt"), m.group("shape"))
        else:
            # tuple result: sum elements (take the tuple right after '=')
            tup = line.split("=", 1)[1].split(op)[0]
            nb = sum(_nbytes(d, s) for d, s in _TUPLE_ELT_RE.findall(tup))
        if op == "reduce-scatter":
            # result is the reduced shard; charge the full input
            groups = re.search(r"replica_groups=\{\{([\d,]+)\}",
                               hlo_text[:0] or line)
            factor = 1
            if groups:
                factor = len(groups.group(1).split(","))
            nb *= factor
        d = out.setdefault(op, {"bytes": 0, "count": 0})
        d["bytes"] += nb
        d["count"] += 1
    return out


def scan_trip_counts(hlo_text: str) -> int:
    """Upper bound check helper: number of while loops (scans)."""
    return hlo_text.count(" while(")


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    n_chips: int
    model_flops: float = 0.0          # 6*N*D (train) / 2*N*D (inference)

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_dev * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the step achieves under the
        max-term execution model: t_bound = max(3 terms); achievable
        MFU = (useful flops / chips / t_bound) / peak."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t == 0:
            return 0.0
        per_chip = self.model_flops / self.n_chips / t
        return per_chip / PEAK_FLOPS

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(compiled, n_chips: int, model_flops: float) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text())
    cb = sum(v["bytes"] for v in colls.values())
    return Roofline(flops, nbytes, cb, n_chips, model_flops), colls


def model_flops_for(cfg, shape) -> float:
    """Useful model flops: 6*N*D (train) / 2*N*D (inference) with
    N = flop_param_count (matmul-participating active params; see
    configs.ModelConfig.flop_param_count) plus the encoder side for
    enc-dec archs (scales with enc_frames, not decoder tokens)."""
    n = cfg.flop_param_count
    mult = 6.0 if shape.kind == "train" else 2.0
    if shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.seq_len * shape.global_batch
    f = mult * n * tokens
    if cfg.enc_dec and shape.kind != "decode":
        f += mult * cfg.enc_param_count * cfg.enc_frames \
            * shape.global_batch
    return f
