"""Analytic roofline accounting per (arch x shape x mesh) cell.

WHY THIS EXISTS: XLA's ``cost_analysis()`` counts every ``while`` body
ONCE, so any program with lax.scan (our layer stack, microbatch
accumulation, chunked attention) under-reports flops/bytes by the trip
counts.  The dry-run therefore records BOTH the raw compiled numbers
(structural evidence: the collective op set, per-iteration payloads,
memory fit) and this analytic model, which is exact for flops (validated
against an UNROLLED smoke compile in tests/test_roofline.py) and
first-order for HBM/collective traffic.  The roofline tables in
EXPERIMENTS.md use the analytic terms.

All formulas are per STEP and GLOBAL; ``per_device`` divides by chip
count at the end.  dtype = bf16 compute (2 bytes), f32 optimizer state.
"""

from __future__ import annotations

import dataclasses
import math

from repro.configs import ModelConfig, ShapeConfig
from repro.models.layers import ATTN_CHUNK, MLSTM_CHUNK, MOE_GROUP
from repro.roofline.analysis import PEAK_FLOPS, HBM_BW, ICI_BW

BYTES = 2          # bf16 activations/weights in compute
OPT_BYTES = 4      # f32 master/moments


def _block_counts(cfg: ModelConfig) -> dict:
    pat = cfg.block_pattern
    n_units, tail = divmod(cfg.n_layers, len(pat))
    counts: dict[str, int] = {}
    for i, kind in enumerate(pat):
        counts[kind] = counts.get(kind, 0) + n_units + (1 if i < tail else 0)
    return counts


def _layer_matmul_params(cfg: ModelConfig, kind: str) -> float:
    """Matmul-weight element count of one block of ``kind``."""
    d, hd = cfg.d_model, cfg.head_dim
    attn = d * cfg.n_heads * hd * 2 + 2 * d * cfg.n_kv * hd
    if kind == "attn":
        p = attn
        if cfg.n_experts:
            p += cfg.topk * 3 * d * cfg.moe_d_ff + d * cfg.n_experts
            if cfg.dense_residual:
                p += 3 * d * cfg.d_ff
        elif cfg.d_ff:
            p += (3 if cfg.family != "audio" else 2) * d * cfg.d_ff
        return p
    if kind == "rec":
        return 5 * d * d + 3 * d * cfg.d_ff
    if kind == "mlstm":
        return 5 * d * d
    if kind == "slstm":
        hd2 = d // cfg.n_heads
        return 4 * d * d + 4 * d * hd2 + d * d
    raise ValueError(kind)


def _attn_ctx(cfg, sh: ShapeConfig) -> float:
    """Average attended context length per query."""
    window = cfg.local_window or 0
    if sh.kind == "decode":
        ctx = sh.seq_len
        return min(window, ctx) if window else ctx
    ctx = sh.seq_len / 2.0                        # causal average
    return min(window, ctx) if window else ctx


@dataclasses.dataclass
class CellModel:
    flops: float            # global per step
    hbm_bytes: float        # global per step
    coll_bytes: float       # global per step (sum over devices)
    model_flops: float      # useful 6ND / 2ND flops
    chips: int

    @property
    def t_compute(self):
        return self.flops / self.chips / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.hbm_bytes / self.chips / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / self.chips / ICI_BW

    @property
    def bottleneck(self):
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def useful_ratio(self):
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self):
        tb = max(self.t_compute, self.t_memory, self.t_collective)
        if tb == 0:
            return 0.0
        return self.model_flops / self.chips / tb / PEAK_FLOPS

    def to_dict(self):
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "coll_bytes": self.coll_bytes,
                "model_flops": self.model_flops, "chips": self.chips,
                "t_compute": self.t_compute, "t_memory": self.t_memory,
                "t_collective": self.t_collective,
                "bottleneck": self.bottleneck,
                "useful_ratio": self.useful_ratio,
                "roofline_fraction": self.roofline_fraction}


def forward_flops(cfg: ModelConfig, sh: ShapeConfig, tokens: float) -> float:
    """Global forward flops for ``tokens`` processed tokens."""
    counts = _block_counts(cfg)
    d, hd = cfg.d_model, cfg.head_dim
    f = 0.0
    for kind, n in counts.items():
        f += 2.0 * tokens * _layer_matmul_params(cfg, kind) * n
        if kind == "attn":
            ctx = _attn_ctx(cfg, sh)
            f += 2.0 * 2.0 * tokens * ctx * cfg.n_heads * hd * n
        if kind == "mlstm":
            c = min(MLSTM_CHUNK, max(int(tokens // max(sh.global_batch, 1)),
                                     1))
            hd2 = d // cfg.n_heads
            f += 2.0 * 2.0 * tokens * min(c, 1024) * d * n   # intra-chunk
            f += 2.0 * tokens * hd2 * d * n                  # state update
        if kind == "slstm":
            hd2 = d // cfg.n_heads
            f += 2.0 * tokens * 4 * d * hd2 * n
    # logits (+ encoder for enc-dec)
    f += 2.0 * tokens * d * cfg.vocab
    if cfg.enc_dec:
        enc_tokens = sh.global_batch * cfg.enc_frames
        attn_enc = d * cfg.n_heads * hd * 2 + 2 * d * cfg.n_kv * hd
        f += (2.0 * enc_tokens * (attn_enc + 2 * d * cfg.d_ff)
              + 4.0 * enc_tokens * cfg.enc_frames * cfg.n_heads * hd) \
            * cfg.n_enc_layers
        # cross attention in every decoder layer
        f += (2.0 * tokens * attn_enc
              + 4.0 * tokens * cfg.enc_frames * cfg.n_heads * hd) \
            * cfg.n_layers
    return f


def cell_model(cfg: ModelConfig, sh: ShapeConfig, mesh_shape: dict,
               microbatches: int = 1, kv_bytes: float = BYTES) -> CellModel:
    chips = 1
    for v in mesh_shape.values():
        chips *= v
    tp = mesh_shape.get("model", 1)
    dp = chips // tp
    pods = mesh_shape.get("pod", 1)

    B, S = sh.global_batch, sh.seq_len
    tokens = float(B * S) if sh.kind != "decode" else float(B)
    pbytes = cfg.param_count * BYTES
    counts = _block_counts(cfg)
    n_attn = counts.get("attn", 0)

    from repro.roofline.analysis import model_flops_for
    fwd = forward_flops(cfg, sh, tokens)
    if sh.kind == "train":
        flops = 3.0 * fwd + 10.0 * cfg.param_count     # bwd ~2x fwd + opt
    else:
        flops = fwd
    model_flops = model_flops_for(cfg, sh)

    # ---------------- HBM traffic (global) ----------------
    d = cfg.d_model
    act_io = 24.0 if sh.kind == "train" else 8.0       # bytes/(token*d*layer)
    hbm = act_io * tokens * d * cfg.n_layers
    if sh.kind == "train":
        # weights streamed per microbatch (fwd+bwd) + optimizer state rw
        hbm += 2.0 * microbatches * pbytes + 6.0 * cfg.param_count * OPT_BYTES
    else:
        hbm += pbytes
    if sh.kind == "decode":
        # KV cache read(+write) dominates
        ctx = _attn_ctx(cfg, sh)
        cache = n_attn * 2 * B * min(ctx, S) * cfg.n_kv * cfg.head_dim \
            * kv_bytes
        hbm += 2.0 * cache
        if "mlstm" in counts:
            hd2 = d // cfg.n_heads
            hbm += 2.0 * counts["mlstm"] * B * cfg.n_heads * hd2 * hd2 * 4
    if sh.kind in ("train", "prefill") and n_attn:
        # chunked attention re-reads KV once per query chunk
        ctx = _attn_ctx(cfg, sh)
        passes = max(S // ATTN_CHUNK, 1)
        hbm += n_attn * B * passes * min(2 * ctx, S) \
            * cfg.n_kv * cfg.head_dim * BYTES

    # ---------------- collective traffic (global) ----------------
    coll = 0.0
    # TP: 2 reduction points per block fwd (attn out, ffn out), x2 in bwd;
    # each moves ~2x payload (reduce-scatter + all-gather) per device ring.
    tp_payload = tokens * d * BYTES
    red_per_block = 2.0
    n_blocks = cfg.n_layers + (cfg.n_enc_layers if cfg.enc_dec else 0)
    if tp > 1:
        factor = 4.0 if sh.kind == "train" else 2.0
        coll += factor * red_per_block * n_blocks * tp_payload \
            * (tp - 1) / tp * 2
    # FSDP: per microbatch all-gather layer shards fwd+bwd, reduce-scatter
    # grads (train only).
    if dp // pods > 1 and sh.kind == "train":
        coll += (2.0 * microbatches + 1.0) * (pbytes / tp) * dp
    # EP dispatch (MoE): tokens routed to experts and back, fwd+bwd
    if cfg.n_experts and tp > 1:
        moe_payload = tokens * d * BYTES * 2.0 * cfg.topk
        coll += (3.0 if sh.kind == "train" else 1.0) \
            * counts.get("attn", 0) * moe_payload
    # cross-pod gradient allreduce (2x shard bytes per device)
    if pods > 1 and sh.kind == "train":
        coll += 2.0 * (cfg.param_count * OPT_BYTES / (tp * dp // pods)) \
            * chips
    # logits reduction (head contraction sharded)
    if tp > 1:
        coll += tokens * min(cfg.vocab / tp, d) * 4 * 2

    return CellModel(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                     model_flops=model_flops, chips=chips)
