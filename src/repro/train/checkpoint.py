"""Sharded checkpointing with manifest, async save, and
reshard-on-load (elastic re-scaling).

Format: one .npz per host holding that host's addressable shards,
flattened by tree path, plus manifest.json (step, tree structure,
global shapes/dtypes, PartitionSpecs as strings).  A checkpoint is
*complete* only once its manifest is written (the npz is fsync'd
first), so a crash mid-save never yields a restorable-but-corrupt
state; ``latest_step`` only ever returns complete checkpoints.

Elastic restore: arrays are saved as GLOBAL arrays (per-host shards are
reassembled on load); ``restore`` takes the *target* mesh/shardings, so
a checkpoint written on a 2x16x16 mesh restores onto 16x16 (or any
other shape with divisibility) — tested by tests/test_checkpoint.py.
"""

from __future__ import annotations

import json
import os
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


def save(ckpt_dir: str, step: int, state: dict, *, blocking: bool = True):
    """state: arbitrary pytree dict (params, opt_state, ...)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tag = f"step_{step:08d}"
    path = os.path.join(ckpt_dir, tag)

    def _write():
        os.makedirs(path, exist_ok=True)
        arrays = _flatten(state)
        tmp = os.path.join(path, "host0.npz.tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(path, "host0.npz"))
        manifest = {
            "step": step,
            "keys": sorted(arrays.keys()),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "time": time.time(),
        }
        mtmp = os.path.join(path, "manifest.json.tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(mtmp, os.path.join(path, "manifest.json"))

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    """Largest step with a COMPLETE manifest."""
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "manifest.json")):
            s = int(d.split("_")[1])
            best = s if best is None or s > best else best
    return best


def restore(ckpt_dir: str, step: int, like: dict, *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for the TARGET mesh — this is the elastic
    reshard-on-load path (device_put slices the global array per the
    new sharding)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "host0.npz"))

    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat_like[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(flat_like[1], leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    return tree, manifest["step"]
