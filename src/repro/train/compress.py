"""Gradient compression for the cross-pod allreduce.

Shared-scale int8 with stochastic rounding: the pod-level gradient
allreduce is the slowest collective in a multi-pod job (data-center
network, not ICI).  Quantizing to int8 with a pmax-shared scale cuts
its payload 4x vs f32 (2x vs bf16) at <1 ulp-of-int8 bias (stochastic
rounding is unbiased; tested).  The sum of p int8 values fits int32 for
any realistic pod count, so the reduction itself is exact.

``psum_compressed`` is the drop-in for jax.lax.psum inside shard_map;
``tag_for_compression`` marks a gradient pytree so the train step's
optimizer allreduce path uses it (wired in train_step).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _stochastic_round(x, key):
    lo = jnp.floor(x)
    frac = x - lo
    return lo + (jax.random.uniform(key, x.shape) < frac)


def quantize(g, key, axis_name=None):
    """-> (int8 q, f32 scale).  Scale shared across ``axis_name`` so the
    reduced sum can be dequantized with one multiply."""
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = _stochastic_round(g.astype(jnp.float32) / scale, key)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def psum_compressed(g, axis_name, key):
    """int8 allreduce with shared scale; exact int32 summation."""
    q, scale = quantize(g, key, axis_name)
    s = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return dequantize(s, scale)


def tag_for_compression(grads):
    """Placeholder marker pass: with jit+GSPMD the gradient allreduce is
    implicit, so compression is applied in the shard_map training
    variant (examples/train_lm.py --compress); under jit we keep the
    pytree unchanged (documented limitation of the GSPMD path)."""
    return grads
