"""Fault tolerance: restart-from-checkpoint driver, heartbeat/straggler
monitoring.

At pod scale the failure model is: a host (or its TPU) dies -> the
whole SPMD program dies -> the job restarts on a (possibly reshaped)
slice and must resume bit-exactly.  The pieces here:

  * ``run_with_restarts`` — the restart loop: run the training driver,
    catch worker failure, restore from the latest COMPLETE checkpoint
    and continue.  Combined with the deterministic pipeline
    (repro.data.synthetic, a pure function of step) resume is bit-exact
    (tested in tests/test_ft.py, including a mid-run kill).
  * ``StepMonitor`` — per-host step-time EWMA; hosts slower than
    ``straggler_factor`` x the fleet median are flagged.  On a real
    fleet the action is to exclude the host and re-shard the data axis
    (the elastic restore path in checkpoint.py); here the detection
    logic is exercised in tests with injected timings.
  * ``Heartbeat`` — liveness file the coordinator can watch.
"""

from __future__ import annotations

import dataclasses
import os
import time


class WorkerFailure(RuntimeError):
    """Raised (or injected in tests) when a worker dies mid-run."""


def run_with_restarts(train_fn, *, restore_fn, max_restarts: int = 3,
                      on_restart=None):
    """train_fn(start_state) -> final_state; restore_fn() -> start_state.

    Restarts train_fn from the latest checkpoint on WorkerFailure, up to
    max_restarts times."""
    attempts = 0
    while True:
        state = restore_fn()
        try:
            return train_fn(state), attempts
        except WorkerFailure:
            attempts += 1
            if attempts > max_restarts:
                raise
            if on_restart:
                on_restart(attempts)


@dataclasses.dataclass
class StepMonitor:
    n_hosts: int
    alpha: float = 0.2                    # EWMA coefficient
    straggler_factor: float = 1.5

    def __post_init__(self):
        self.ewma = [None] * self.n_hosts

    def record(self, host: int, step_time: float):
        e = self.ewma[host]
        self.ewma[host] = step_time if e is None else \
            (1 - self.alpha) * e + self.alpha * step_time

    def stragglers(self) -> list[int]:
        vals = [e for e in self.ewma if e is not None]
        if len(vals) < 2:
            return []
        med = sorted(vals)[len(vals) // 2]
        return [h for h, e in enumerate(self.ewma)
                if e is not None and e > self.straggler_factor * med]


class Heartbeat:
    def __init__(self, path: str, host: int):
        self.path = os.path.join(path, f"heartbeat_{host}")
        os.makedirs(path, exist_ok=True)

    def beat(self, step: int):
        with open(self.path, "w") as f:
            f.write(f"{step} {time.time()}")

    @staticmethod
    def last(path: str, host: int):
        p = os.path.join(path, f"heartbeat_{host}")
        if not os.path.exists(p):
            return None
        step, t = open(p).read().split()
        return int(step), float(t)
