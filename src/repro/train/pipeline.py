"""GPipe-style pipeline parallelism over a mesh axis.

Optional re-factoring of the "pod" axis into pipeline stages: each
stage holds a contiguous slice of layers; microbatches stream through a
collective_permute ring.  shard_map body — every device is one stage.

Schedule: T = M + S - 1 ticks.  At tick t, stage s computes microbatch
(t - s) if 0 <= t - s < M (otherwise it computes on a zero buffer whose
result is discarded — the classic GPipe bubble, wasting (S-1)/(M+S-1)
of compute, which is why M >> S in production configs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def pipeline_apply(stage_fn, stage_params, microbatches, *, mesh: Mesh,
                   axis: str = "pipe"):
    """Run microbatches (M, B, ...) through S = mesh.shape[axis] stages.

    stage_fn(params_slice, x) -> y applies one stage's layers.
    stage_params: pytree stacked over stages (leading dim S).
    Returns (M, B, ...) outputs from the last stage."""
    S = mesh.shape[axis]
    M = microbatches.shape[0]
    T = M + S - 1

    def body(params_local, mbs_local):
        # params_local: this stage's slice — shard_map keeps the (now
        # size-1) stage dim, so squeeze it; mbs_local: full microbatch
        # stream (replicated).
        params_local = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        buf = compat.pcast_varying(jnp.zeros_like(mbs_local[0]), axis)
        outs = compat.pcast_varying(
            jnp.zeros((M,) + mbs_local.shape[1:], mbs_local.dtype), axis)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any); others use the
            # buffer received from the previous stage.
            mb_idx = jnp.clip(t, 0, M - 1)
            x_in = jnp.where(sid == 0,
                             jax.lax.dynamic_index_in_dim(
                                 mbs_local, mb_idx, keepdims=False),
                             buf)
            y = stage_fn(params_local, x_in)
            # last stage records its result for microbatch t - (S-1)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            record = (sid == S - 1) & (t >= S - 1)
            upd = jax.lax.dynamic_update_index_in_dim(
                outs, y.astype(outs.dtype), out_idx, axis=0)
            outs = jnp.where(record, upd, outs)
            # ring-shift activations to the next stage
            perm = [(i, (i + 1) % S) for i in range(S)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, T, tick, (buf, outs))
        # all stages exit with the same schedule; only the last stage's
        # outs are real — broadcast them to every stage for a clean
        # replicated output.
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    fn = compat.shard_map(body, mesh=mesh, in_specs=(spec_p, P()),
                       out_specs=P())
    return fn(stage_params, microbatches)
