"""Multi-device self-checks for the training/serving stack.

Run in a subprocess with forced host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.train.selfcheck [what]
"""

from __future__ import annotations

import os
import sys
import tempfile

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat


def _mesh(shape, axes):
    devs = np.asarray(jax.devices()[:int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, axes)


def check_train_step() -> int:
    from repro import configs, optim
    from repro.data import synthetic
    from repro.models import lm
    from repro.train import train_step as ts

    fails = 0
    for arch in ["qwen3-1.7b", "grok-1-314b", "recurrentgemma-2b"]:
        cfg = configs.get_smoke(arch)
        mesh = _mesh((2, 4), ("data", "model"))
        params = lm.init(cfg, jax.random.key(0))
        opt = optim.get("adamw", lr=1e-3)
        opt_state = opt.init(params)
        batch = synthetic.host_batch(cfg, seq=32, global_batch=4, step=0)
        opt_shapes = jax.eval_shape(opt.init, params)
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            step = ts.jit_train_step(cfg, mesh, opt, params, opt_shapes,
                                     batch, microbatches=2, remat=True)
            p2, o2, m = step(params, opt_state, batch)
            p3, o3, m2 = step(p2, o2, batch)
        ok = bool(jnp.isfinite(m["loss"])) and bool(jnp.isfinite(m2["loss"]))
        print(f"train_step {arch}: loss {float(m['loss']):.3f} -> "
              f"{float(m2['loss']):.3f} {'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    return fails


def check_serve_step() -> int:
    from repro import configs
    from repro.models import lm
    from repro.train import serve_step as ss

    fails = 0
    for arch in ["qwen3-1.7b", "recurrentgemma-2b"]:
        cfg = configs.get_smoke(arch)
        mesh = _mesh((2, 4), ("data", "model"))
        params = lm.init(cfg, jax.random.key(0))
        B, S = 4, 16
        cache = lm.init_cache(cfg, B, S)
        with mesh:
            fn = ss.jit_decode_step(cfg, mesh, params, cache, B)
            toks = jnp.zeros((B, 1), jnp.int32)
            logits, cache2 = fn(params, cache, toks)
            logits2, _ = fn(params, cache2, toks)
        ok = bool(jnp.isfinite(logits).all()) and \
            bool(jnp.isfinite(logits2).all()) and \
            logits.shape == (B, 1, cfg.vocab)
        print(f"serve_step {arch}: {'OK' if ok else 'FAIL'}")
        fails += 0 if ok else 1
    return fails


def check_pipeline() -> int:
    from repro.train.pipeline import pipeline_apply

    mesh = _mesh((4,), ("pipe",))
    S, M, B, d = 4, 6, 2, 8
    rng = np.random.default_rng(0)
    # 4 stages, each an affine map; reference = sequential composition
    Ws = jnp.asarray(rng.standard_normal((S, d, d)) / np.sqrt(d))
    bs = jnp.asarray(rng.standard_normal((S, d)) * 0.1)
    x = jnp.asarray(rng.standard_normal((M, B, d)))

    def stage(p, h):
        W, b = p
        return jnp.tanh(h @ W + b)

    out = pipeline_apply(stage, (Ws, bs), x, mesh=mesh, axis="pipe")
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ Ws[s] + bs[s])
    err = float(jnp.abs(out - ref).max())
    ok = err < 1e-5
    print(f"pipeline S={S} M={M}: err={err:.2e} {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


def check_compress() -> int:
    from repro.train import compress

    mesh = _mesh((8,), ("pod",))
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)

    def body(g):
        key = jax.random.fold_in(jax.random.key(0),
                                 jax.lax.axis_index("pod"))
        return compress.psum_compressed(g, "pod", key)

    out = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=P(),
                                out_specs=P()))(g)
    want = np.asarray(g) * 8
    rel = np.abs(np.asarray(out) - want).max() / np.abs(want).max()
    # int8 quantization: expect ~1% relative error, unbiased
    ok = rel < 0.05
    print(f"compress int8 psum: rel={rel:.4f} {'OK' if ok else 'FAIL'}")

    # unbiasedness of stochastic rounding
    # 256*16 samples put the +-0.02 gate at ~3 sigma — flaky under PRNG
    # stream changes across jax versions; 256*64 brings it to ~5.5 sigma.
    keys = jax.random.split(jax.random.key(1), 256)
    x = jnp.full((64,), 0.3)
    qs = jax.vmap(lambda k: compress._stochastic_round(x, k))(keys)
    mean = float(qs.mean())
    ok2 = abs(mean - 0.3) < 0.02
    print(f"stochastic rounding mean {mean:.3f} (want 0.3) "
          f"{'OK' if ok2 else 'FAIL'}")
    return (0 if ok else 1) + (0 if ok2 else 1)


def check_ckpt_reshard() -> int:
    """Save with an 8-device (2,4) mesh, restore onto (1,4) — elastic."""
    from repro import configs, optim
    from repro.models import lm, sharding as sr
    from repro.train import checkpoint as ckpt

    cfg = configs.get_smoke("qwen3-1.7b")
    params = lm.init(cfg, jax.random.key(0))
    mesh8 = _mesh((2, 4), ("data", "model"))
    sh8 = sr.param_shardings(cfg, params, mesh8)
    p8 = jax.tree.map(lambda a, s: jax.device_put(a, s), params, sh8)

    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, {"params": p8})
        assert ckpt.latest_step(d) == 7
        mesh4 = _mesh((1, 4), ("data", "model"))
        sh4 = sr.param_shardings(cfg, params, mesh4)
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                            params)
        restored, step = ckpt.restore(d, 7, {"params": like},
                                      shardings={"params": sh4})
    same = all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(params),
                               jax.tree.leaves(restored["params"])))
    print(f"ckpt reshard 8->4 devices: {'OK' if same else 'FAIL'}")
    return 0 if same else 1


CHECKS = {
    "train_step": check_train_step,
    "serve_step": check_serve_step,
    "pipeline": check_pipeline,
    "compress": check_compress,
    "ckpt_reshard": check_ckpt_reshard,
}


def main(argv):
    what = argv[1] if len(argv) > 1 else None
    names = [what] if what else list(CHECKS)
    fails = 0
    for name in names:
        fails += CHECKS[name]()
    print(f"selfcheck: {fails} failures")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
