"""Serving: batched prefill + single-token decode with sharded KV/state
caches.

``lm.decode_step`` handles S >= 1 uniformly (the attention cache path
appends a block of S tokens at the current position with intra-block
causal masking), so prefill IS a decode step with S = prompt length —
one code path, no cache-format skew between prefill and decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig
from repro.models import lm, whisper, sharding as shard_rules


def make_decode_fn(cfg: ModelConfig):
    """Returns a decode fn with the exact positional signature for the
    config: (params, cache, tokens[, embeds | enc_states])."""
    if cfg.enc_dec:
        def fn(params, cache, tokens, enc_states):
            return whisper.decode(params, cfg, tokens, enc_states,
                                  cache=cache)
        return fn
    if cfg.embed_inputs:
        def fn(params, cache, tokens, embeds):
            return lm.decode_step(params, cfg, tokens, cache,
                                  embeds=embeds)
        return fn

    def fn(params, cache, tokens):
        return lm.decode_step(params, cfg, tokens, cache)
    return fn


def serve_shardings(cfg: ModelConfig, mesh: Mesh, params, cache):
    pspecs = shard_rules.param_specs(cfg, params, mesh)
    cspecs = shard_rules.cache_specs(cfg, cache, mesh)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return ns(pspecs), ns(cspecs)


def jit_decode_step(cfg: ModelConfig, mesh: Mesh, params, cache,
                    batch_size: int):
    """Jitted one-token decode with explicit shardings (dry-run target).
    The positional signature follows make_decode_fn for the config."""
    ps, cs = serve_shardings(cfg, mesh, params, cache)
    dp = shard_rules.dp_axes(mesh)
    bdp = dp if batch_size % _sz(mesh, dp) == 0 else None
    tok_sh = NamedSharding(mesh, P(bdp, None))
    fn = make_decode_fn(cfg)
    in_sh = [ps, cs, tok_sh]
    if cfg.enc_dec or cfg.embed_inputs:
        in_sh.append(NamedSharding(mesh, P(bdp, None, None)))
    return jax.jit(fn, in_shardings=tuple(in_sh),
                   out_shardings=(None, cs), donate_argnums=(1,))


def _sz(mesh, axes):
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


# ------------------- TRSM solve serving (paper workload) -------------------

class TrsmRequestServer:
    """Continuous-batching front-end for a :class:`repro.core.TrsmSession`.

    Incoming solve requests (right-hand-side column blocks of varying
    width) are packed into fixed-width (n, panel_k) panels so every
    request is served by the SAME compiled program — one executable,
    zero retraces, zero host transfers in the steady state (the
    device-resident analogue of fixed-batch token serving above).  The
    last panel of a drain is zero-padded; solves of zero columns are
    zero, so padding never contaminates results.
    """

    def __init__(self, session, panel_k: int):
        self.session = session
        self.panel_k = panel_k
        self._queue: list = []
        self.requests_served = 0
        self.panels_solved = 0

    def submit(self, b) -> None:
        """Enqueue one RHS block: (n,) vector or (n, j) columns."""
        b = jnp.asarray(b, self.session.dtype)
        if b.ndim == 1:
            b = b[:, None]
        if b.ndim != 2 or b.shape[0] != self.session.n:
            raise ValueError(f"rhs must be ({self.session.n}, j), "
                             f"got {b.shape}")
        if b.shape[1] > self.panel_k:
            raise ValueError(f"request wider than panel: {b.shape[1]} > "
                             f"{self.panel_k}")
        self._queue.append(b)

    def pending(self) -> int:
        return len(self._queue)

    def warmup(self):
        self.session.warmup(self.panel_k)
        return self

    def drain(self) -> list:
        """Serve all queued requests; returns solutions in submit order."""
        out: list = []
        while self._queue:
            wave: list = []
            width = 0
            while self._queue and \
                    width + self._queue[0].shape[1] <= self.panel_k:
                b = self._queue.pop(0)
                wave.append(b)
                width += b.shape[1]
            panel = jnp.concatenate(wave, axis=1)
            if width < self.panel_k:
                panel = jnp.pad(panel,
                                ((0, 0), (0, self.panel_k - width)))
            X = self.session.solve(panel)
            self.panels_solved += 1
            off = 0
            for b in wave:
                out.append(X[:, off:off + b.shape[1]])
                off += b.shape[1]
            self.requests_served += len(wave)
        return out


def make_trsm_server(L, *, p1: int = 1, p2: int = 1, panel_k: int = 16,
                     method: str = "inv", n0: int | None = None,
                     lower: bool = True, transpose: bool = False,
                     precision=None):
    """Build a warmed TrsmRequestServer on a fresh (p1, p1, p2) grid.

    ``precision`` is forwarded to :class:`TrsmSession` — a preset name
    ("fp32", "bf16", "bf16_refine", "fp64_refine") or a
    PrecisionPolicy; per-workload, so one process can serve e.g. a
    bf16_refine panel stream and an fp32 panel stream side by side
    (distinct compiled programs, same cache)."""
    from repro.core import TrsmSession
    from repro.core.grid import make_trsm_mesh
    grid = make_trsm_mesh(p1, p2)
    sess = TrsmSession(L, grid, method=method, n0=n0, lower=lower,
                       transpose=transpose, precision=precision)
    return TrsmRequestServer(sess, panel_k).warmup()


def greedy_generate(cfg: ModelConfig, params, prompt, max_new: int,
                    max_seq: int):
    """Reference serving loop (single host): prefill then greedy decode."""
    B, S = prompt.shape
    cache = lm.init_cache(cfg, B, max_seq)
    logits, cache = lm.decode_step(params, cfg, prompt, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    out = [tok]
    for _ in range(max_new - 1):
        logits, cache = lm.decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
