"""Serving: batched prefill + single-token decode with sharded KV/state
caches.

``lm.decode_step`` handles S >= 1 uniformly (the attention cache path
appends a block of S tokens at the current position with intra-block
causal masking), so prefill IS a decode step with S = prompt length —
one code path, no cache-format skew between prefill and decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig
from repro.models import lm, whisper, sharding as shard_rules


def make_decode_fn(cfg: ModelConfig):
    """Returns a decode fn with the exact positional signature for the
    config: (params, cache, tokens[, embeds | enc_states])."""
    if cfg.enc_dec:
        def fn(params, cache, tokens, enc_states):
            return whisper.decode(params, cfg, tokens, enc_states,
                                  cache=cache)
        return fn
    if cfg.embed_inputs:
        def fn(params, cache, tokens, embeds):
            return lm.decode_step(params, cfg, tokens, cache,
                                  embeds=embeds)
        return fn

    def fn(params, cache, tokens):
        return lm.decode_step(params, cfg, tokens, cache)
    return fn


def serve_shardings(cfg: ModelConfig, mesh: Mesh, params, cache):
    pspecs = shard_rules.param_specs(cfg, params, mesh)
    cspecs = shard_rules.cache_specs(cfg, cache, mesh)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return ns(pspecs), ns(cspecs)


def jit_decode_step(cfg: ModelConfig, mesh: Mesh, params, cache,
                    batch_size: int):
    """Jitted one-token decode with explicit shardings (dry-run target).
    The positional signature follows make_decode_fn for the config."""
    ps, cs = serve_shardings(cfg, mesh, params, cache)
    dp = shard_rules.dp_axes(mesh)
    bdp = dp if batch_size % _sz(mesh, dp) == 0 else None
    tok_sh = NamedSharding(mesh, P(bdp, None))
    fn = make_decode_fn(cfg)
    in_sh = [ps, cs, tok_sh]
    if cfg.enc_dec or cfg.embed_inputs:
        in_sh.append(NamedSharding(mesh, P(bdp, None, None)))
    return jax.jit(fn, in_shardings=tuple(in_sh),
                   out_shardings=(None, cs), donate_argnums=(1,))


def _sz(mesh, axes):
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def greedy_generate(cfg: ModelConfig, params, prompt, max_new: int,
                    max_seq: int):
    """Reference serving loop (single host): prefill then greedy decode."""
    B, S = prompt.shape
    cache = lm.init_cache(cfg, B, max_seq)
    logits, cache = lm.decode_step(params, cfg, prompt, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    out = [tok]
    for _ in range(max_new - 1):
        logits, cache = lm.decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
