"""Serving: batched prefill + single-token decode with sharded KV/state
caches.

``lm.decode_step`` handles S >= 1 uniformly (the attention cache path
appends a block of S tokens at the current position with intra-block
causal masking), so prefill IS a decode step with S = prompt length —
one code path, no cache-format skew between prefill and decode.
"""

from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig
from repro.models import lm, whisper, sharding as shard_rules


def make_decode_fn(cfg: ModelConfig):
    """Returns a decode fn with the exact positional signature for the
    config: (params, cache, tokens[, embeds | enc_states])."""
    if cfg.enc_dec:
        def fn(params, cache, tokens, enc_states):
            return whisper.decode(params, cfg, tokens, enc_states,
                                  cache=cache)
        return fn
    if cfg.embed_inputs:
        def fn(params, cache, tokens, embeds):
            return lm.decode_step(params, cfg, tokens, cache,
                                  embeds=embeds)
        return fn

    def fn(params, cache, tokens):
        return lm.decode_step(params, cfg, tokens, cache)
    return fn


def serve_shardings(cfg: ModelConfig, mesh: Mesh, params, cache):
    pspecs = shard_rules.param_specs(cfg, params, mesh)
    cspecs = shard_rules.cache_specs(cfg, cache, mesh)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return ns(pspecs), ns(cspecs)


def jit_decode_step(cfg: ModelConfig, mesh: Mesh, params, cache,
                    batch_size: int):
    """Jitted one-token decode with explicit shardings (dry-run target).
    The positional signature follows make_decode_fn for the config."""
    ps, cs = serve_shardings(cfg, mesh, params, cache)
    dp = shard_rules.dp_axes(mesh)
    bdp = dp if batch_size % _sz(mesh, dp) == 0 else None
    tok_sh = NamedSharding(mesh, P(bdp, None))
    fn = make_decode_fn(cfg)
    in_sh = [ps, cs, tok_sh]
    if cfg.enc_dec or cfg.embed_inputs:
        in_sh.append(NamedSharding(mesh, P(bdp, None, None)))
    return jax.jit(fn, in_shardings=tuple(in_sh),
                   out_shardings=(None, cs), donate_argnums=(1,))


def _sz(mesh, axes):
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


# ------------------- TRSM solve serving (paper workload) -------------------

def _pack_wave(queue: collections.deque, panel_k: int) -> list:
    """First-fit pack one panel's worth of requests off the queue.

    Walks the whole queue in FIFO order and takes EVERY request that
    still fits in the remaining panel width (not just a contiguous
    head-of-line prefix): a wide request at the head no longer strands
    narrow requests behind it in an underfilled panel.  Skipped
    requests keep their relative order for the next wave.  Returns the
    packed [(seq, b), ...]; the queue keeps the rest."""
    wave: list = []
    width = 0
    leftover: collections.deque = collections.deque()
    while queue:
        seq, b = queue.popleft()
        if width + b.shape[1] <= panel_k:
            wave.append((seq, b))
            width += b.shape[1]
        else:
            leftover.append((seq, b))
    queue.extend(leftover)
    return wave


class TrsmRequestServer:
    """Continuous-batching front-end for a :class:`repro.core.TrsmSession`.

    Incoming solve requests (right-hand-side column blocks of varying
    width) are packed into fixed-width (n, panel_k) panels so every
    request is served by the SAME compiled program — one executable,
    zero retraces, zero host transfers in the steady state (the
    device-resident analogue of fixed-batch token serving above).
    Panels are packed FIRST-FIT over the queue (a wide head-of-line
    request cannot strand narrow ones into underfilled panels), and
    ``drain`` returns solutions in submit order regardless of packing
    order.  The last panel of a drain is zero-padded; solves of zero
    columns are zero, so padding never contaminates results.
    """

    def __init__(self, session, panel_k: int):
        self.session = session
        self.panel_k = panel_k
        self._queue: collections.deque = collections.deque()
        self._seq = 0
        self.requests_served = 0
        self.panels_solved = 0

    def submit(self, b) -> None:
        """Enqueue one RHS block: (n,) vector or (n, j) columns."""
        b = jnp.asarray(b, self.session.dtype)
        if b.ndim == 1:
            b = b[:, None]
        if b.ndim != 2 or b.shape[0] != self.session.n:
            raise ValueError(f"rhs must be ({self.session.n}, j), "
                             f"got {b.shape}")
        if b.shape[1] > self.panel_k:
            raise ValueError(f"request wider than panel: {b.shape[1]} > "
                             f"{self.panel_k}")
        self._queue.append((self._seq, b))
        self._seq += 1

    def pending(self) -> int:
        return len(self._queue)

    def warmup(self):
        self.session.warmup(self.panel_k)
        return self

    def drain(self) -> list:
        """Serve all queued requests; returns solutions in submit order."""
        results: dict[int, object] = {}
        while self._queue:
            wave = _pack_wave(self._queue, self.panel_k)
            width = sum(b.shape[1] for _, b in wave)
            panel = jnp.concatenate([b for _, b in wave], axis=1)
            if width < self.panel_k:
                panel = jnp.pad(panel,
                                ((0, 0), (0, self.panel_k - width)))
            X = self.session.solve(panel)
            self.panels_solved += 1
            off = 0
            for seq, b in wave:
                results[seq] = X[:, off:off + b.shape[1]]
                off += b.shape[1]
            self.requests_served += len(wave)
        return [results[s] for s in sorted(results)]


class BankedTrsmServer:
    """Continuous-batching front-end for a multi-factor
    :class:`repro.core.BatchedTrsmSession` (DESIGN.md Sec. 9).

    Per-factor request queues, ONE packed drain: each wave first-fit
    packs every factor's queue into that factor's (n, panel_k) panel
    slot of an (M, n, panel_k) stack and solves the whole stack in one
    dispatch — M factors' traffic, one executable, one launch per wave.
    Factors with an empty queue ride along as zero panels (a solve of
    zeros is zeros, so idle factors never contaminate results and the
    program shape never changes).
    """

    def __init__(self, session, panel_k: int):
        self.session = session
        self.panel_k = panel_k
        # lazily keyed by factor index, validated against the bank's
        # CURRENT width — factors admitted after server construction
        # are servable immediately (the next wave's program is simply
        # keyed on the new width)
        self._queues: dict[int, collections.deque] = {}
        self._seq = 0
        self.requests_served = 0
        self.waves_solved = 0

    def submit(self, factor: int, b) -> None:
        """Enqueue one RHS block for bank factor ``factor``."""
        if not 0 <= factor < self.session.bank.size:
            raise ValueError(f"unknown factor {factor}; bank holds "
                             f"{self.session.bank.size}")
        b = jnp.asarray(b, self.session.dtype)
        if b.ndim == 1:
            b = b[:, None]
        if b.ndim != 2 or b.shape[0] != self.session.n:
            raise ValueError(f"rhs must be ({self.session.n}, j), "
                             f"got {b.shape}")
        if b.shape[1] > self.panel_k:
            raise ValueError(f"request wider than panel: {b.shape[1]} > "
                             f"{self.panel_k}")
        self._queues.setdefault(factor, collections.deque())
        self._queues[factor].append((self._seq, b))
        self._seq += 1

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def warmup(self):
        self.session.warmup(self.panel_k)
        return self

    def drain(self) -> dict:
        """Serve all queued requests for all factors.  Returns
        {factor: [X, ...]} for every CURRENT bank factor (empty list if
        none were queued), each factor's solutions in its own submit
        order."""
        n, pk = self.session.n, self.panel_k
        M = self.session.bank.size
        results: dict[int, dict] = {f: {} for f in range(M)}
        while self.pending():
            waves = {f: _pack_wave(q, pk)
                     for f, q in self._queues.items() if q}
            panels = []
            for f in range(M):
                wave = waves.get(f, [])
                if wave:
                    panel = jnp.concatenate([b for _, b in wave], axis=1)
                    w = panel.shape[1]
                    if w < pk:
                        panel = jnp.pad(panel, ((0, 0), (0, pk - w)))
                else:
                    panel = jnp.zeros((n, pk), self.session.dtype)
                panels.append(panel)
            X = self.session.solve(jnp.stack(panels))
            self.waves_solved += 1
            for f, wave in waves.items():
                off = 0
                for seq, b in wave:
                    results[f][seq] = X[f, :, off:off + b.shape[1]]
                    off += b.shape[1]
                self.requests_served += len(wave)
        return {f: [res[s] for s in sorted(res)]
                for f, res in results.items()}


def make_trsm_server(L, *, p1: int = 1, p2: int = 1, panel_k: int = 16,
                     method: str = "inv", n0: int | None = None,
                     lower: bool = True, transpose: bool = False,
                     precision=None):
    """Build a warmed TrsmRequestServer on a fresh (p1, p1, p2) grid.

    ``precision`` is forwarded to :class:`TrsmSession` — a preset name
    ("fp32", "bf16", "bf16_refine", "fp64_refine") or a
    PrecisionPolicy; per-workload, so one process can serve e.g. a
    bf16_refine panel stream and an fp32 panel stream side by side
    (distinct compiled programs, same cache)."""
    from repro.core import TrsmSession
    from repro.core.grid import make_trsm_mesh
    grid = make_trsm_mesh(p1, p2)
    sess = TrsmSession(L, grid, method=method, n0=n0, lower=lower,
                       transpose=transpose, precision=precision)
    return TrsmRequestServer(sess, panel_k).warmup()


def make_trsm_bank_server(Ls, *, p1: int = 1, p2: int = 1,
                          panel_k: int = 16, method: str = "inv",
                          n0: int | None = None, lower: bool = True,
                          transpose: bool = False, precision=None,
                          map_mode: str = "vmap"):
    """Build a warmed BankedTrsmServer over a stack of factors.

    ``Ls`` is an (M, n, n) natural-layout stack (or a list of (n, n)
    factors); it is distributed into a
    :class:`repro.core.FactorBank` by ONE stacked gather and served by
    one batched compiled program per RHS width.  All
    :func:`make_trsm_server` options apply bank-wide."""
    import numpy as np
    from repro.core import BatchedTrsmSession, FactorBank
    from repro.core.grid import make_trsm_mesh
    Ls = np.asarray(Ls)
    grid = make_trsm_mesh(p1, p2)
    bank = FactorBank(grid, Ls.shape[-1], method=method, n0=n0,
                      lower=lower, transpose=transpose,
                      dtype=None if precision is not None else Ls.dtype,
                      precision=precision, map_mode=map_mode)
    bank.admit_stack(Ls)
    return BankedTrsmServer(BatchedTrsmSession(bank), panel_k).warmup()


def greedy_generate(cfg: ModelConfig, params, prompt, max_new: int,
                    max_seq: int):
    """Reference serving loop (single host): prefill then greedy decode."""
    B, S = prompt.shape
    # Cache capacity check: prefill appends S positions, then each of
    # the max_new - 1 decode steps appends one more (the final token is
    # returned without re-entering the cache).  Past max_seq the
    # dynamic-update-slice cache write clamps and silently corrupts
    # earlier positions, so overflow must be an error, not garbage.
    if S + max_new - 1 > max_seq:
        raise ValueError(
            f"prompt ({S} tokens) + max_new ({max_new}) needs "
            f"{S + max_new - 1} cache positions but max_seq={max_seq}; "
            f"raise max_seq or shorten the request")
    cache = lm.init_cache(cfg, B, max_seq)
    logits, cache = lm.decode_step(params, cfg, prompt, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    out = [tok]
    for _ in range(max_new - 1):
        logits, cache = lm.decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
