"""Serving: batched prefill + single-token decode with sharded KV/state
caches.

``lm.decode_step`` handles S >= 1 uniformly (the attention cache path
appends a block of S tokens at the current position with intra-block
causal masking), so prefill IS a decode step with S = prompt length —
one code path, no cache-format skew between prefill and decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig
from repro.models import lm, whisper, sharding as shard_rules


def make_decode_fn(cfg: ModelConfig):
    """Returns a decode fn with the exact positional signature for the
    config: (params, cache, tokens[, embeds | enc_states])."""
    if cfg.enc_dec:
        def fn(params, cache, tokens, enc_states):
            return whisper.decode(params, cfg, tokens, enc_states,
                                  cache=cache)
        return fn
    if cfg.embed_inputs:
        def fn(params, cache, tokens, embeds):
            return lm.decode_step(params, cfg, tokens, cache,
                                  embeds=embeds)
        return fn

    def fn(params, cache, tokens):
        return lm.decode_step(params, cfg, tokens, cache)
    return fn


def serve_shardings(cfg: ModelConfig, mesh: Mesh, params, cache):
    pspecs = shard_rules.param_specs(cfg, params, mesh)
    cspecs = shard_rules.cache_specs(cfg, cache, mesh)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return ns(pspecs), ns(cspecs)


def jit_decode_step(cfg: ModelConfig, mesh: Mesh, params, cache,
                    batch_size: int):
    """Jitted one-token decode with explicit shardings (dry-run target).
    The positional signature follows make_decode_fn for the config."""
    ps, cs = serve_shardings(cfg, mesh, params, cache)
    dp = shard_rules.dp_axes(mesh)
    bdp = dp if batch_size % _sz(mesh, dp) == 0 else None
    tok_sh = NamedSharding(mesh, P(bdp, None))
    fn = make_decode_fn(cfg)
    in_sh = [ps, cs, tok_sh]
    if cfg.enc_dec or cfg.embed_inputs:
        in_sh.append(NamedSharding(mesh, P(bdp, None, None)))
    return jax.jit(fn, in_shardings=tuple(in_sh),
                   out_shardings=(None, cs), donate_argnums=(1,))


def _sz(mesh, axes):
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


# ------------------- TRSM solve serving (paper workload) -------------------
#
# The unified front-end lives in repro.core.solver (SolveServer, one
# class for any bank width; re-exported as repro.api).  The classes
# below are DEPRECATED shims kept for source compatibility — each
# emits one DeprecationWarning and delegates to the Solver/SolveServer
# path (bit-identical results; see the README migration table).

from repro.core import solver as solverlib

_pack_wave = solverlib._pack_wave          # compat alias


class TrsmRequestServer(solverlib.SolveServer):
    """DEPRECATED single-factor request server — a thin shim over
    :class:`repro.core.solver.SolveServer` at bank width 1.  New code:

        server = repro.api.SolveServer(solver, panel_k)
    """

    def __init__(self, session, panel_k: int):
        solverlib._warn_deprecated("TrsmRequestServer",
                                   "repro.api.SolveServer")
        super().__init__(session._solver, panel_k)
        self.session = session

    def submit(self, b) -> None:
        """Enqueue one RHS block: (n,) vector or (n, j) columns."""
        super().submit(b, factor=0)

    def drain(self) -> list:
        """Serve all queued requests; returns solutions in submit
        order."""
        return super().drain()[0]


class BankedTrsmServer(solverlib.SolveServer):
    """DEPRECATED multi-factor request server — a thin shim over
    :class:`repro.core.solver.SolveServer` (which serves any bank
    width with per-factor queues and one dispatch per wave)."""

    def __init__(self, session, panel_k: int):
        solverlib._warn_deprecated("BankedTrsmServer",
                                   "repro.api.SolveServer")
        super().__init__(session._solver, panel_k)
        self.session = session

    def submit(self, factor: int, b) -> None:
        """Enqueue one RHS block for bank factor ``factor`` (note the
        legacy (factor, b) argument order)."""
        super().submit(b, factor=factor)


def make_trsm_server(L, *, p1: int = 1, p2: int = 1, panel_k: int = 16,
                     method: str = "inv", n0: int | None = None,
                     lower: bool = True, transpose: bool = False,
                     precision=None):
    """DEPRECATED: build a warmed single-factor request server on a
    fresh (p1, p1, p2) grid.  New code:

        solver = repro.api.Solver.from_factor(L, grid, ...)
        server = repro.api.SolveServer(solver, panel_k).warmup()
    """
    from repro.core import TrsmSession
    from repro.core.grid import make_trsm_mesh
    solverlib._warn_deprecated("make_trsm_server",
                               "repro.api.SolveServer")
    with solverlib._shim_quiet():
        grid = make_trsm_mesh(p1, p2)
        sess = TrsmSession(L, grid, method=method, n0=n0, lower=lower,
                           transpose=transpose, precision=precision)
        return TrsmRequestServer(sess, panel_k).warmup()


def make_trsm_bank_server(Ls, *, p1: int = 1, p2: int = 1,
                          panel_k: int = 16, method: str = "inv",
                          n0: int | None = None, lower: bool = True,
                          transpose: bool = False, precision=None,
                          map_mode: str = "vmap"):
    """DEPRECATED: build a warmed banked request server over an
    (M, n, n) natural-layout stack.  New code:

        solver = repro.api.Solver.from_factors(Ls, grid, ...)
        server = repro.api.SolveServer(solver, panel_k).warmup()
    """
    import numpy as np
    from repro.core import BatchedTrsmSession, FactorBank
    from repro.core.grid import make_trsm_mesh
    solverlib._warn_deprecated("make_trsm_bank_server",
                               "repro.api.SolveServer")
    with solverlib._shim_quiet():
        Ls = np.asarray(Ls)
        grid = make_trsm_mesh(p1, p2)
        bank = FactorBank(grid, Ls.shape[-1], method=method, n0=n0,
                          lower=lower, transpose=transpose,
                          dtype=None if precision is not None
                          else Ls.dtype,
                          precision=precision, map_mode=map_mode)
        bank.admit_stack(Ls)
        return BankedTrsmServer(BatchedTrsmSession(bank),
                                panel_k).warmup()


def greedy_generate(cfg: ModelConfig, params, prompt, max_new: int,
                    max_seq: int):
    """Reference serving loop (single host): prefill then greedy decode."""
    B, S = prompt.shape
    # Cache capacity check: prefill appends S positions, then each of
    # the max_new - 1 decode steps appends one more (the final token is
    # returned without re-entering the cache).  Past max_seq the
    # dynamic-update-slice cache write clamps and silently corrupts
    # earlier positions, so overflow must be an error, not garbage.
    if S + max_new - 1 > max_seq:
        raise ValueError(
            f"prompt ({S} tokens) + max_new ({max_new}) needs "
            f"{S + max_new - 1} cache positions but max_seq={max_seq}; "
            f"raise max_seq or shorten the request")
    cache = lm.init_cache(cfg, B, max_seq)
    logits, cache = lm.decode_step(params, cfg, prompt, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    out = [tok]
    for _ in range(max_new - 1):
        logits, cache = lm.decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
