"""Distributed train step: FSDP x TP x EP sharding, gradient
accumulation over microbatches, remat, mixed precision, optional
cross-pod int8 gradient compression.

``make_train_step`` returns a jitted function with explicit
in/out_shardings derived from repro.models.sharding, suitable both for
real execution and for the .lower().compile() dry-run.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig
from repro.models import lm, whisper, sharding as shard_rules
from repro.optim.adamw import Optimizer


def loss_for(cfg: ModelConfig):
    return whisper.loss_fn if cfg.enc_dec else lm.loss_fn


def _sz(mesh, axes):
    import numpy as np
    if not axes:
        return 1
    axes = axes if isinstance(axes, tuple) else (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


# ------------------------ optimizer state specs ------------------------

def _path_key(path):
    out = []
    for k in path:
        out.append(getattr(k, "key", None) if hasattr(k, "key")
                   else getattr(k, "idx", None))
    return tuple(out)


def opt_state_specs(opt_shapes, params, pspecs):
    """Specs for the optimizer state: leaves mirroring a parameter
    (same path suffix and shape) inherit its spec (FSDP'd optimizer
    state = ZeRO); everything else is replicated."""
    pdict = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        pdict[_path_key(path)] = leaf.shape
    sdict = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(pspecs)[0]:
        sdict[_path_key(path)] = leaf

    def lookup(path, leaf):
        key = _path_key(path)
        for i in range(len(key)):
            suf = key[i:]
            if suf in pdict and pdict[suf] == leaf.shape:
                return sdict[suf]
        return P()

    # pspecs leaves are PartitionSpecs (tuples!); stop tree traversal at them
    return jax.tree_util.tree_map_with_path(lookup, opt_shapes)


# ----------------------------- train step -----------------------------

def make_train_step(cfg: ModelConfig, mesh: Mesh, optimizer: Optimizer,
                    *, microbatches: int = 1, remat: bool = True,
                    dtype=jnp.bfloat16, compress_grads: bool = False,
                    logits_spec=None):
    loss_fn = loss_for(cfg)
    dp = shard_rules.dp_axes(mesh)
    lspec = logits_spec

    def step_fn(params, opt_state, batch):
        def loss_of(p, b):
            if cfg.enc_dec:
                return loss_fn(p, cfg, b, dtype=dtype, logits_spec=lspec)
            return loss_fn(p, cfg, b, remat=remat, dtype=dtype,
                           logits_spec=lspec)

        if microbatches > 1:
            def resh(x):
                bsz = x.shape[0]
                b = x.reshape(microbatches, bsz // microbatches,
                              *x.shape[1:])
                # keep the batch dim sharded over DP through the
                # reshape — without the constraint the SPMD partitioner
                # falls back to full rematerialization (replicating the
                # global batch per device) on the multi-pod mesh.
                if bsz // microbatches % max(_sz(mesh, dp), 1) == 0:
                    spec = P(None, dp, *([None] * (x.ndim - 1)))
                    b = jax.lax.with_sharding_constraint(
                        b, NamedSharding(mesh, spec))
                return b
            mbatch = jax.tree.map(resh, batch)

            def acc(carry, mb):
                l, g = jax.value_and_grad(loss_of)(params, mb)
                return (carry[0] + l,
                        jax.tree.map(jnp.add, carry[1], g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (lsum, gsum), _ = jax.lax.scan(acc, (jnp.zeros(()), zeros),
                                           mbatch)
            loss = lsum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        if compress_grads and "pod" in mesh.axis_names:
            from repro.train import compress
            grads = compress.tag_for_compression(grads)

        new_params, new_opt, metrics = optimizer.update(grads, opt_state,
                                                        params)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return step_fn


def shardings_for(cfg: ModelConfig, mesh: Mesh, params, opt_shapes,
                  batch, mode: str = "2d"):
    """(params, opt_state, batch) NamedShardings + metric replication."""
    pspecs = shard_rules.param_specs(cfg, params, mesh, mode)
    ospecs = opt_state_specs(opt_shapes, params, pspecs)
    bspecs = shard_rules.batch_specs(batch, mesh, mode)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return ns(pspecs), ns(ospecs), ns(bspecs)


def jit_train_step(cfg: ModelConfig, mesh: Mesh, optimizer: Optimizer,
                   params, opt_shapes, batch, shard_mode: str = "2d",
                   **kw):
    """Fully-sharded jitted step (also the dry-run lowering target)."""
    ps, os_, bs = shardings_for(cfg, mesh, params, opt_shapes, batch,
                                shard_mode)
    # pin per-microbatch logits (B_mb, S, V) to (DP, None, TP): without
    # the constraint the SPMD partitioner replicates them across the
    # pod axis (hundreds of GB/dev for big-vocab archs).
    dp = shard_rules.dp_axes(mesh)
    mb = kw.get("microbatches", 1)
    B = next(iter(jax.tree.leaves(batch))).shape[0]
    bdp = dp if (B // mb) % max(_sz(mesh, dp), 1) == 0 else None
    vshard = "model" if ("model" in mesh.axis_names
                         and cfg.vocab % mesh.shape["model"] == 0
                         and shard_mode == "2d") else None
    kw.setdefault("logits_spec",
                  NamedSharding(mesh, P(bdp, None, vshard)))
    fn = make_train_step(cfg, mesh, optimizer, **kw)
    return jax.jit(fn,
                   in_shardings=(ps, os_, bs),
                   out_shardings=(ps, os_, None),
                   donate_argnums=(0, 1))
