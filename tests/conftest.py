"""Shared fixtures: the deterministic serving-loop harness.

Async-serving tests must be reproducible: no background thread, no
``sleep``, no wall-clock.  The pieces:

* :class:`FakeClock` — a manual clock matching the duck type
  :class:`repro.core.serving.SystemClock` injects (``monotonic()`` +
  ``sleep()``); time moves only when the test calls ``advance``.
* :class:`DrainDriver` — drives an :class:`AsyncSolveServer` whose
  :meth:`start` was never called: ``step(advance=..)`` runs exactly
  one wave, ``run_until_idle`` steps until queues and the in-flight
  pipeline are empty — raising instead of hanging when the server
  never quiesces.

Tests that DO want the real background thread (lifecycle, stress)
call ``server.start()``/``with server:`` themselves and are the only
async tests allowed to block on wall-clock timeouts.
"""

import pytest


class FakeClock:
    """Manual monotonic clock for deterministic serving tests."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def monotonic(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot rewind a monotonic clock ({dt})")
        self._t += dt
        return self._t

    def sleep(self, dt: float) -> None:
        # a *deterministic* sleep: just advances the fake time
        self.advance(dt)


class DrainDriver:
    """Single-step driver for an AsyncSolveServer with no thread."""

    def __init__(self, server, clock=None):
        self.server = server
        self.clock = clock

    def step(self, advance: float = 0.0) -> int:
        """One wave (pack + dispatch + pipeline finalize); optionally
        advance the fake clock first.  Returns requests dispatched."""
        if advance and self.clock is not None:
            self.clock.advance(advance)
        return self.server.step()

    def run_until_idle(self, max_waves: int = 1000,
                       advance: float = 0.0) -> int:
        """Step until no queued work and nothing in flight.  Raises
        AssertionError after ``max_waves`` instead of hanging — a
        bounded stand-in for 'the loop would have drained this'."""
        total = 0
        for _ in range(max_waves):
            total += self.step(advance)
            if not self.server.pending() \
                    and not self.server._inflight:
                return total
        raise AssertionError(
            f"server not idle after {max_waves} waves "
            f"(pending={self.server.pending()}, "
            f"inflight={len(self.server._inflight)})")

    def run_until(self, pred, max_waves: int = 1000,
                  advance: float = 0.0) -> int:
        """Step until ``pred()`` is true (checked BEFORE each wave, so
        an already-true predicate steps zero times).  The control-plane
        harness: 'step until the autoscaler has replanned', 'until this
        future resolved'.  Raises AssertionError after ``max_waves``."""
        total = 0
        for _ in range(max_waves):
            if pred():
                return total
            total += self.step(advance)
        if pred():
            return total
        raise AssertionError(
            f"predicate still false after {max_waves} waves "
            f"(pending={self.server.pending()}, "
            f"inflight={len(self.server._inflight)})")


@pytest.fixture
def fake_clock():
    return FakeClock()


@pytest.fixture
def drain_driver(fake_clock):
    """Factory: ``drain_driver(server)`` -> DrainDriver sharing the
    test's fake clock (pass ``clock=fake_clock`` to the server)."""
    def make(server):
        return DrainDriver(server, fake_clock)
    return make
