"""The unified front door (repro.api / repro.core.solver): SolveSpec
as the sole compiled-program cache key, the Solver steady state at bank
widths 1 and 16 for every precision preset, spec-driven servers, and
cache eviction/recompile behavior (DESIGN.md Sec. 10)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro import api
from repro.core import session, tuning
from repro.core.solver import SolveSpec

PRESET_CASES = [
    (None, np.float64, 1e-10),          # legacy uniform-dtype policy
    ("fp32", np.float32, 1e-5),
    ("bf16", np.float32, 5e-2),
    ("bf16_refine", np.float32, 1e-5),
    ("fp64_refine", np.float64, 1e-11),
]


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def grid():
    return api.make_trsm_mesh(1, 1)


def _factors(M, n, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    Ls = np.stack([np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
                   for _ in range(M)])
    return Ls.astype(dtype), rng


def _check(Ls, X, B, tol):
    X = np.asarray(X, np.float64)
    for i in range(Ls.shape[0]):
        rel = (np.linalg.norm(Ls[i].astype(np.float64) @ X[i] - B[i])
               / np.linalg.norm(B[i]))
        assert rel < tol, (i, rel)


# ------------------------- SolveSpec semantics -------------------------

def test_spec_is_the_sole_cache_key_type(grid):
    cache = session.CompiledSolverCache()
    with pytest.raises(TypeError, match="SolveSpec"):
        cache.get((32, 4, 8), lambda: None)
    solver = api.Solver.from_factor(np.eye(32, dtype=np.float32), grid,
                                    n0=8, cache=cache)
    prog = solver.program_for(4)
    assert isinstance(prog.key, SolveSpec)
    assert prog.key == solver.spec_for(4)
    assert prog.key in cache


def test_spec_normalizes_and_validates():
    g = api.plan_grid(2, 2)
    pol = api.PRESETS["fp32"]
    s = SolveSpec(n=64, k=8, grid=g, policy=pol, n0=16,
                  map_mode="scan")                 # unbanked: map_mode
    assert s.map_mode is None                      # normalized away
    assert SolveSpec(n=64, k=8, grid=g, policy=pol, n0=16,
                     bank_width=4).map_mode == "vmap"
    with pytest.raises(ValueError, match="method"):
        SolveSpec(n=64, k=8, grid=g, policy=pol, method="auto")
    with pytest.raises(ValueError, match="bank width"):
        SolveSpec(n=64, k=8, grid=g, policy=pol, bank_width=0)
    with pytest.raises(ValueError, match="map_mode"):
        SolveSpec(n=64, k=8, grid=g, policy=pol, bank_width=2,
                  map_mode="pmap")
    with pytest.raises(ValueError, match="tile"):
        SolveSpec(n=64, k=8, grid=g, policy=pol, n0=48).validate()
    with pytest.raises(ValueError, match="cyclic layout"):
        SolveSpec(n=64, k=8, grid=g, policy=pol, n0=2).validate()


def test_spec_auto_consumes_plan_verbatim():
    n, k, p = 1 << 14, 1 << 9, 256
    method, plan, _ = tuning.choose_method(n, k, p)
    spec = SolveSpec.auto(n, k, p=p)
    assert plan.method == method
    assert spec.method == method
    assert (spec.grid.p1, spec.grid.p2) == (plan.p1, plan.p2)
    if method == "inv":
        assert spec.n0 == plan.n0                  # verbatim
    # from_plan: the same plan, frozen directly
    spec2 = SolveSpec.from_plan(plan)
    assert (spec2.method, spec2.n0, spec2.grid.p1, spec2.grid.p2) == \
        (method, plan.n0, plan.p1, plan.p2)
    with pytest.raises(ValueError, match="does not match"):
        SolveSpec.from_plan(plan, grid=api.plan_grid(plan.p1 * 2,
                                                     plan.p2))


def test_plan_only_spec_cannot_compile():
    spec = SolveSpec.auto(64, 8, p=4)
    assert spec.grid.mesh is None and not spec.is_concrete
    with pytest.raises(ValueError, match="concrete"):
        api.solver_for(spec)
    with pytest.raises(ValueError, match="plan-only"):
        api.Solver.from_spec(spec, np.eye(64, dtype=np.float32))


def test_spec_retarget_plan_at_real_mesh(grid):
    """The a-priori flow: resolve a plan-only spec, then re-target it
    at a live mesh and serve through Solver.from_spec."""
    plan = tuning.tune_for_grid(64, 8, grid)
    spec = SolveSpec.from_plan(plan, grid=grid, precision="fp32")
    Ls, rng = _factors(1, 64)
    solver = api.Solver.from_spec(spec, Ls[0])
    B = rng.standard_normal((64, 8)).astype(np.float32)
    X = solver.solve(B)
    assert X.shape == (64, 8)
    _check(Ls, np.asarray(X)[None], B[None], 1e-4)


# --------------------- the acceptance steady state ---------------------

@pytest.mark.parametrize("width", [1, 16])
@pytest.mark.parametrize("precision,in_dt,rtol", PRESET_CASES)
def test_solver_steady_state_widths(grid, width, precision, in_dt, rtol):
    """Zero transfers / zero retraces at bank widths 1 and 16 for every
    precision preset — the acceptance bar for the unified Solver."""
    n, k = 32, 4
    Ls, rng = _factors(width, n, dtype=in_dt)
    solver = api.Solver.from_factors(
        Ls, grid, n0=8, precision=precision,
        dtype=None if precision else in_dt)
    assert solver.width == width
    key = solver.program_for(k).key
    before = session.TRACE_COUNTS[key]
    solver.warmup(k)
    assert session.TRACE_COUNTS[key] == before + 1
    Bs = [solver.place_rhs(rng.standard_normal((width, n, k)).astype(in_dt))
          for _ in range(3)]
    refs = [np.asarray(b) for b in Bs]
    with jax.transfer_guard("disallow"):
        outs = [solver.solve(b) for b in Bs]
    assert session.TRACE_COUNTS[key] == before + 1
    for b, x in zip(refs, outs):
        assert x.dtype == solver.dtype
        _check(Ls, x, b, rtol)
    assert solver.solves_served == 4 * width


def test_width1_solver_serves_2d_rhs_in_kind(grid):
    L, rng = _factors(1, 64, dtype=np.float64)
    solver = api.Solver.from_factor(L[0], grid, n0=16).warmup(8)
    B = rng.standard_normal((64, 8))
    X = solver.solve(B, donate=False)
    assert X.shape == (64, 8)
    np.testing.assert_allclose(L[0] @ np.asarray(X), B, atol=1e-8)
    # the placed (stack) form round-trips as a stack
    Bp = solver.place_rhs(rng.standard_normal((64, 8)))
    assert Bp.shape == (1, 64, 8)
    assert solver.solve(Bp).shape == (1, 64, 8)


def test_solver_rank_validation(grid):
    Ls, _ = _factors(2, 32, dtype=np.float32)
    solver = api.Solver.from_factors(Ls, grid, n0=8, dtype=np.float32)
    with pytest.raises(ValueError, match="rhs stack"):
        solver.solve(np.zeros((32, 4), np.float32))     # 2D at width 2
    with pytest.raises(ValueError, match="rhs stack"):
        solver.solve(np.zeros((3, 32, 4), np.float32))  # width mismatch
    single = api.Solver.from_factor(Ls[0], grid, n0=8)
    with pytest.raises(ValueError, match="rhs must be"):
        single.solve(np.zeros((16, 4), np.float32))
    with pytest.raises(ValueError, match="factor must be square"):
        api.Solver.from_factor(np.zeros((8, 4), np.float32), grid)
    with pytest.raises(ValueError, match="factor stack"):
        api.Solver.from_factors(np.zeros((8, 4), np.float32), grid)


def test_solver_auto_method_resolves_at_construction(grid):
    L, rng = _factors(1, 64, dtype=np.float32)
    solver = api.Solver.from_factor(L[0], grid, method="auto", k_hint=8)
    assert solver.method in ("inv", "rec")
    B = rng.standard_normal((64, 8)).astype(np.float32)
    X = solver.solve(B)
    _check(L, np.asarray(X)[None], B[None], 1e-4)


# ------------------------ eviction / recompile ------------------------

def test_evicted_program_recompiles_to_steady_state(grid):
    """A program evicted from the LRU must rebuild cleanly AND return
    to the zero-transfer zero-retrace steady state after re-warmup."""
    cache = session.CompiledSolverCache(maxsize=1)
    Ls, rng = _factors(1, 32, dtype=np.float64)
    solver = api.Solver.from_factor(Ls[0], grid, n0=8, cache=cache)
    solver.warmup(4)
    key4 = solver.program_for(4).key
    solver.warmup(2)                    # evicts the k=4 program
    st = cache.stats()
    assert st["evictions"] >= 1 and len(cache) == 1
    assert key4 not in cache
    traces = session.TRACE_COUNTS[key4]
    solver.warmup(4)                    # recompile after evict
    assert session.TRACE_COUNTS[key4] == traces + 1
    Bs = [solver.place_rhs(rng.standard_normal((32, 4)))
          for _ in range(2)]
    refs = [np.asarray(b) for b in Bs]
    with jax.transfer_guard("disallow"):
        outs = [solver.solve(b) for b in Bs]
    assert session.TRACE_COUNTS[key4] == traces + 1
    for b, x in zip(refs, outs):
        _check(Ls, x, b, 1e-10)


# ----------------------------- SolveServer -----------------------------

def test_solve_server_from_spec_and_mixed_widths(grid):
    Ls, rng = _factors(3, 64)
    spec = SolveSpec.auto(64, 4, grid=grid, method="inv",
                          precision="fp32", bank_width=3)
    server = api.SolveServer.from_spec(spec, Ls, panel_k=4)
    subs = {f: [] for f in range(3)}
    for i in range(8):
        f = i % 3
        r = rng.standard_normal(
            (64, int(rng.integers(1, 5)))).astype(np.float32)
        subs[f].append(r)
        server.submit(r, factor=f)
    outs = server.drain()
    assert server.pending() == 0
    for f in range(3):
        assert [o.shape[1] for o in outs[f]] == \
            [r.shape[1] for r in subs[f]]
        for r, x in zip(subs[f], outs[f]):
            rel = (np.linalg.norm(Ls[f] @ np.asarray(x, np.float64) - r)
                   / np.linalg.norm(r))
            assert rel < 1e-4, (f, rel)
    with pytest.raises(ValueError, match="unknown factor"):
        server.submit(np.zeros((64, 1), np.float32), factor=3)
    with pytest.raises(ValueError, match="wider than panel"):
        server.submit(np.zeros((64, 5), np.float32))


def test_solve_server_width1_defaults_to_factor_zero(grid):
    L, rng = _factors(1, 64)
    solver = api.Solver.from_factor(L[0], grid, n0=16)
    server = api.SolveServer(solver, panel_k=4).warmup()
    reqs = [rng.standard_normal((64, w)).astype(np.float32)
            for w in (3, 4, 1)]
    for r in reqs:
        server.submit(r)
    outs = server.drain()[0]
    assert server.panels_solved == 2          # first-fit: [3+1], [4]
    assert [o.shape[1] for o in outs] == [3, 4, 1]
    for r, x in zip(reqs, outs):
        np.testing.assert_allclose(L[0] @ np.asarray(x, np.float64), r,
                                   atol=1e-3)


def test_same_spec_shares_program_across_solvers(grid):
    """Two solvers with equal specs (different factor VALUES) share one
    compiled program — the spec is the whole key, factors are runtime
    operands."""
    cache = session.CompiledSolverCache()
    La, rng = _factors(2, 32, seed=1, dtype=np.float64)
    Lb, _ = _factors(2, 32, seed=2, dtype=np.float64)
    s1 = api.Solver.from_factors(La, grid, n0=8, cache=cache)
    s2 = api.Solver.from_factors(Lb, grid, n0=8, cache=cache)
    assert s1.spec_for(4) == s2.spec_for(4)
    B = rng.standard_normal((2, 32, 4))
    Xa = s1.solve(s1.place_rhs(B))
    Xb = s2.solve(s2.place_rhs(B))
    assert cache.stats()["misses"] == 1 and cache.stats()["hits"] >= 1
    _check(La, Xa, B, 1e-10)
    _check(Lb, Xb, B, 1e-10)
    assert not np.allclose(np.asarray(Xa), np.asarray(Xb))
    # replacing any spec field re-keys: a different width is a miss
    assert dataclasses.replace(s1.spec_for(4), bank_width=1) != \
        s1.spec_for(4)
