"""The stable-API contract: every ``api.Name`` spelling in DESIGN.md
must be importable from ``repro.api`` (DESIGN.md Sec. 14).

DESIGN.md is the contract document — its Sec. 14 stable-API list (and
any other ``api.Name`` spelling in the file) is what downstream
scripts are told to rely on.  This test greps the document for those
spellings and imports each one, so re-export drift (a name documented
but dropped from ``repro.api``, or renamed without updating the doc)
fails CI instead of failing a user.
"""

from __future__ import annotations

import os
import re

import pytest

pytestmark = pytest.mark.fast

DESIGN = os.path.join(os.path.dirname(__file__), "..", "DESIGN.md")
SPELLING = re.compile(r"`api\.([A-Za-z_][A-Za-z0-9_]*)`")


def _documented_names():
    with open(DESIGN) as f:
        return sorted(set(SPELLING.findall(f.read())))


def test_design_documents_a_stable_api():
    """The contract list exists and includes the structure layer."""
    names = _documented_names()
    assert len(names) >= 20, names
    assert "FactorStructure" in names
    assert "SolveSpec" in names and "Solver" in names


def test_every_documented_name_is_importable():
    from repro import api

    missing = [n for n in _documented_names() if not hasattr(api, n)]
    assert not missing, (
        f"DESIGN.md documents api.{missing} but repro.api does not "
        f"export them — update the re-exports or the Sec. 14 list")


def test_documented_names_are_real_objects():
    """Each export is a class or callable, not a stub/None."""
    from repro import api

    for n in _documented_names():
        obj = getattr(api, n)
        assert obj is not None, n
        if n != "PRESETS":            # the one data export (a mapping)
            assert callable(obj) or isinstance(obj, type), n
