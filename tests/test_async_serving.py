"""Open-loop async serving (repro.core.serving / DESIGN.md Sec. 13):
queue bounds + typed shedding, FIFO-per-tenant ordering and future
resolution order, weighted fair packing, evict-under-flight stranding
through the future (plain AND fleet), the zero-retrace/zero-transfer
steady state, and a producer-thread stress with capacity churn.

Everything except the lifecycle/stress tests runs with NO background
thread and NO wall-clock: the server gets the ``fake_clock`` fixture
and a ``DrainDriver`` (tests/conftest.py) steps waves by hand.
"""

import threading

import jax
import numpy as np
import pytest

from repro import api
from repro.core import session
from repro.core.serving import FairQueue, _Request

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def grid():
    return api.make_trsm_mesh(1, 1)


def _factors(M, n=32, seed=0):
    rng = np.random.default_rng(seed)
    Ls = np.stack([np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
                   for _ in range(M)])
    return Ls.astype(np.float32), rng


def _server(grid, *, M=2, n=32, capacity=None, panel_k=4, **kw):
    Ls, rng = _factors(M, n)
    bank = api.FactorBank(grid, n, n0=8, capacity=capacity,
                          dtype=np.float32)
    if capacity is None:
        bank.admit_stack(Ls)
    else:
        for L in Ls:
            bank.admit(L)
    solver = api.Solver.from_bank(bank)
    return (api.AsyncSolveServer(solver, panel_k, **kw).warmup(),
            Ls, bank, rng)


def _rel(L, X, b):
    X = np.asarray(X, np.float64)
    return (np.linalg.norm(L.astype(np.float64) @ X - np.asarray(b))
            / max(np.linalg.norm(b), 1e-30))


# ---------------------- futures + wave correctness ----------------------

def test_futures_resolve_correct_solutions(grid, fake_clock,
                                           drain_driver):
    srv, Ls, _, rng = _server(grid, clock=fake_clock)
    drv = drain_driver(srv)
    reqs = [(i % 2, rng.standard_normal((32, 1 + i % 3))
             .astype(np.float32)) for i in range(7)]
    futs = [srv.submit(b, factor=f) for f, b in reqs]
    assert srv.pending() == 7 and not any(f.done() for f in futs)
    drv.run_until_idle(advance=0.25)
    for (f, b), fut in zip(reqs, futs):
        assert fut.done() and fut.exception() is None
        assert _rel(Ls[f], fut.result(), b) < 1e-4
        assert fut.result().shape == b.shape
        # completion stamps come from the injected clock
        assert fut.latency() is not None and fut.latency() > 0
    st = srv.stats()
    assert st["served"] == 7 and st["shed"] == 0
    assert st["p99_ms"] >= st["p50_ms"] > 0


def test_vector_rhs_served_as_column(grid, fake_clock, drain_driver):
    srv, Ls, _, rng = _server(grid, clock=fake_clock)
    b = rng.standard_normal(32).astype(np.float32)
    fut = srv.submit(b)
    drain_driver(srv).run_until_idle()
    assert fut.result().shape == (32, 1)
    assert _rel(Ls[0], fut.result()[:, 0], b) < 1e-4


def test_future_timeout_raises_not_hangs(grid, fake_clock):
    srv, _, _, rng = _server(grid, clock=fake_clock)
    fut = srv.submit(rng.standard_normal((32, 1)).astype(np.float32))
    with pytest.raises(TimeoutError, match="drain loop"):
        fut.result(timeout=0.01)    # nobody is stepping the server
    with pytest.raises(TimeoutError):
        fut.exception(timeout=0.01)


# ------------------- admission control / queue bounds -------------------

def test_queue_bound_sheds_with_typed_overloaded(grid, fake_clock,
                                                 drain_driver):
    srv, Ls, _, rng = _server(grid, queue_depth=3, clock=fake_clock)
    bs = [rng.standard_normal((32, 1)).astype(np.float32)
          for _ in range(3)]
    futs = [srv.submit(b, factor=1) for b in bs]
    with pytest.raises(api.Overloaded, match="shed"):
        srv.submit(bs[0], factor=1)
    # per-slot bound: the OTHER slot's queue still admits
    other = srv.submit(bs[0], factor=0)
    assert srv.stats()["shed"] == 1 and srv.pending() == 4
    # shedding never poisons the queue: everything admitted serves
    drain_driver(srv).run_until_idle()
    for b, f in zip(bs, futs):
        assert _rel(Ls[1], f.result(), b) < 1e-4
    assert other.done() and srv.stats()["served"] == 4


def test_submit_validation_errors(grid, fake_clock):
    srv, _, bank, rng = _server(grid, M=2, capacity=4,
                                clock=fake_clock)
    b = rng.standard_normal((32, 1)).astype(np.float32)
    with pytest.raises(ValueError, match="unknown factor"):
        srv.submit(b, factor=7)
    with pytest.raises(ValueError, match="inactive slot"):
        srv.submit(b, factor=3)
    with pytest.raises(ValueError, match="wider than panel"):
        srv.submit(rng.standard_normal((32, 9)).astype(np.float32))
    with pytest.raises(ValueError, match=r"must be \(32, j\)"):
        srv.submit(rng.standard_normal((16, 1)).astype(np.float32))
    with pytest.raises(ValueError, match="needs a fleet"):
        srv.submit(b, tag="adapter")
    # validation rejects are NOT sheds, and nothing was enqueued
    assert srv.stats()["shed"] == 0 and srv.pending() == 0


# ----------------------- ordering and fairness -----------------------

def _waves_of(srv, futs, drv, max_waves=50):
    """Step until idle, recording which futures complete on each
    step — the observable wave/resolution order."""
    waves = []
    for _ in range(max_waves):
        before = [f.done() for f in futs]
        drv.step(advance=0.1)
        newly = [i for i, (was, f) in enumerate(zip(before, futs))
                 if not was and f.done()]
        if newly:
            waves.append(newly)
        if not srv.pending() and not srv._inflight:
            break
    assert all(f.done() for f in futs)
    return waves


def test_fifo_per_tenant_and_resolution_order(grid, fake_clock,
                                              drain_driver):
    """Per tenant, futures resolve in submit order, and completion
    timestamps are nondecreasing across waves."""
    srv, _, _, rng = _server(grid, M=1, panel_k=2, max_inflight=1,
                             clock=fake_clock)
    futs = []
    for i in range(6):
        t = "alice" if i % 2 == 0 else "bob"
        futs.append(srv.submit(
            rng.standard_normal((32, 1)).astype(np.float32), tenant=t))
    waves = _waves_of(srv, futs, drain_driver(srv))
    assert len(waves) == 3 and all(len(w) == 2 for w in waves)
    flat = [i for w in waves for i in w]
    for tenant in ("alice", "bob"):
        order = [i for i in flat if futs[i].tenant == tenant]
        assert order == sorted(order)          # FIFO per tenant
    stamps = [futs[w[0]].completed for w in waves]
    assert stamps == sorted(stamps)


def test_weighted_fairness_within_one_wave(grid, fake_clock,
                                           drain_driver):
    """Backlogged 3:1 tenants split an 8-wide panel 6:2 in the first
    wave (unit-width requests => exact weight proportionality)."""
    srv, _, _, rng = _server(grid, M=1, panel_k=8, max_inflight=1,
                             queue_depth=32,
                             weights={"a": 3.0, "b": 1.0},
                             clock=fake_clock)
    futs = []
    for i in range(8):                         # interleaved arrivals
        for t in ("a", "b"):
            futs.append(srv.submit(
                rng.standard_normal((32, 1)).astype(np.float32),
                tenant=t))
    waves = _waves_of(srv, futs, drain_driver(srv))
    first = [futs[i].tenant for i in waves[0]]
    assert len(first) == 8
    assert first.count("a") == 6 and first.count("b") == 2
    # weights shape WHO shares a wave, never whether someone is served
    assert all(f.done() and f.exception() is None for f in futs)


def test_unweighted_tenants_share_equally(grid, fake_clock,
                                          drain_driver):
    srv, _, _, rng = _server(grid, M=1, panel_k=4, max_inflight=1,
                             queue_depth=32, clock=fake_clock)
    futs = [srv.submit(rng.standard_normal((32, 1)).astype(np.float32),
                       tenant=t)
            for _ in range(4) for t in ("a", "b")]
    waves = _waves_of(srv, futs, drain_driver(srv))
    for w in waves:
        tenants = [futs[i].tenant for i in w]
        assert tenants.count("a") == 2 and tenants.count("b") == 2


def test_max_inflight_pipelines_waves(grid, fake_clock, drain_driver):
    """With the default pipeline depth, one wave stays un-finalized
    while the next is packed (async dispatch overlap); flush()
    resolves the tail."""
    srv, _, _, rng = _server(grid, M=1, panel_k=1, max_inflight=2,
                             clock=fake_clock)
    futs = [srv.submit(rng.standard_normal((32, 1)).astype(np.float32))
            for _ in range(3)]
    drv = drain_driver(srv)
    drv.step()
    assert len(srv._inflight) == 1 and not futs[0].done()
    drv.step()                      # dispatch #2 finalizes #1
    assert futs[0].done() and not futs[1].done()
    drv.step()
    assert futs[1].done() and not futs[2].done()
    srv.flush()
    assert futs[2].done() and len(srv._inflight) == 0


# -------------------- evict-under-flight: stranding --------------------

def test_stranded_future_on_evict_then_readmit_plain(grid, fake_clock,
                                                     drain_driver):
    """The generation counter catches slot TURNOVER, not just death:
    evict + re-admit leaves the slot live, but the queued request
    fails through its future with the typed error — no hang, no solve
    against the new occupant."""
    srv, Ls, bank, rng = _server(grid, M=2, capacity=2,
                                 clock=fake_clock)
    Lnew, _ = _factors(1, seed=99)
    b = rng.standard_normal((32, 1)).astype(np.float32)
    stale = srv.submit(b, factor=1)
    bank.evict(1)
    assert bank.admit(Lnew[0]) == 1 and bank.is_live(1)
    fresh = srv.submit(b, factor=1)       # new generation: stays valid
    drv = drain_driver(srv)
    drv.run_until_idle()
    err = stale.exception(timeout=0)
    assert isinstance(err, api.StrandedRequestError)
    assert isinstance(err, ValueError)    # old except-clauses keep working
    assert "evicted after submission" in str(err)
    with pytest.raises(api.StrandedRequestError):
        stale.result(timeout=0)
    assert _rel(Lnew[0], fresh.result(timeout=0), b) < 1e-4
    st = srv.stats()
    assert st["stranded"] == 1 and st["served"] >= 1


def test_dead_slot_strands_whole_queue_plain(grid, fake_clock,
                                             drain_driver):
    srv, _, bank, rng = _server(grid, M=2, capacity=2,
                                clock=fake_clock)
    futs = [srv.submit(rng.standard_normal((32, 1)).astype(np.float32),
                       factor=0) for _ in range(3)]
    bank.evict(0)
    drain_driver(srv).run_until_idle()
    for f in futs:
        assert isinstance(f.exception(timeout=0),
                          api.StrandedRequestError)
    assert srv.stats()["stranded"] == 3


def test_stranded_future_on_fleet_cross_tenant_reclaim(grid,
                                                       fake_clock,
                                                       drain_driver):
    """Fleet mode records the FleetHandle generation at submit; a
    cross-tenant LRU reclaim of the slot strands exactly the displaced
    tenant's queued requests while the reclaimer's serve fine."""
    plan = api.plan_fleet({64: 1}, grid=grid)
    assert plan.buckets[0].capacity == 1      # full => admit reclaims
    fleet = api.SolverFleet(grid, plan)
    Ls, rng = _factors(2, n=64, seed=3)
    fleet.admit(Ls[0], tenant="alice")
    srv = api.AsyncSolveServer(fleet, panel_k=4,
                               clock=fake_clock).warmup()
    b = rng.standard_normal((64, 1)).astype(np.float32)
    doomed = srv.submit(b, tenant="alice")
    fleet.admit(Ls[1], tenant="bob")          # reclaims alice's slot
    fresh = srv.submit(b, tenant="bob")
    drain_driver(srv).run_until_idle()
    assert isinstance(doomed.exception(timeout=0),
                      api.StrandedRequestError)
    assert _rel(Ls[1], fresh.result(timeout=0), b) < 1e-4
    # and alice's route is gone at ADMISSION now, not at drain
    with pytest.raises(KeyError, match="re-admit"):
        srv.submit(b, tenant="alice")


def test_fleet_async_mixed_orders_slice_back(grid, fake_clock,
                                             drain_driver):
    """Mixed-order tenants share a bucket; each solution comes back at
    its TRUE order (padded rows sliced off)."""
    plan = api.plan_fleet({48: 1, 64: 1}, grid=grid)
    fleet = api.SolverFleet(grid, plan)
    rng = np.random.default_rng(4)
    Ls = {}
    for t, order in (("alice", 48), ("bob", 64)):
        L = (np.tril(rng.standard_normal((order, order)))
             + order * np.eye(order)).astype(np.float32)
        Ls[t] = L
        fleet.admit(L, tenant=t)
    srv = api.AsyncSolveServer(fleet, panel_k=4,
                               clock=fake_clock).warmup()
    futs = {t: srv.submit(
        rng.standard_normal((L.shape[0], 2)).astype(np.float32),
        tenant=t) for t, L in Ls.items()}
    drain_driver(srv).run_until_idle()
    for t, f in futs.items():
        X = f.result(timeout=0)
        assert X.shape == (Ls[t].shape[0], 2)
        assert f.exception() is None


# ------------------------- the steady state -------------------------

def test_async_steady_state_zero_retrace_zero_transfer(grid,
                                                       fake_clock,
                                                       drain_driver):
    """After warmup + one priming wave, waves pack and dispatch with
    ZERO retraces and ZERO host->device transfers — submits of
    device-resident RHS included (the acceptance invariant the open
    Poisson bench leans on)."""
    srv, Ls, _, rng = _server(grid, M=2, panel_k=4, max_inflight=1,
                              clock=fake_clock)
    key = srv.solver.program_for(srv.panel_k).key
    import jax.numpy as jnp
    bs = [jnp.asarray(rng.standard_normal((32, 2)).astype(np.float32))
          for _ in range(8)]
    jax.block_until_ready(bs)
    drv = drain_driver(srv)
    srv.submit(bs[0], factor=0)               # priming wave
    drv.run_until_idle()
    before = session.TRACE_COUNTS[key]
    with jax.transfer_guard("disallow"):
        futs = [srv.submit(b, factor=i % 2)
                for i, b in enumerate(bs)]
        drv.run_until_idle()
    assert session.TRACE_COUNTS[key] == before   # zero retraces
    for i, (b, f) in enumerate(zip(bs, futs)):
        assert _rel(Ls[i % 2], f.result(timeout=0), np.asarray(b)) \
            < 1e-4


# ----------------------- lifecycle + the thread -----------------------

def test_context_manager_runs_real_drain_loop(grid):
    srv, Ls, _, rng = _server(grid)
    spawned = []
    real_factory = threading.Thread

    def factory(**kw):                        # injectable executor
        t = real_factory(**kw)
        spawned.append(t)
        return t

    srv._thread_factory = factory
    bs = [rng.standard_normal((32, 1)).astype(np.float32)
          for _ in range(5)]
    with srv:
        futs = [srv.submit(b, factor=i % 2) for i, b in enumerate(bs)]
        outs = [f.result(timeout=60) for f in futs]
    assert len(spawned) == 1 and not spawned[0].is_alive()
    for i, (b, X) in enumerate(zip(bs, outs)):
        assert _rel(Ls[i % 2], X, b) < 1e-4
    with pytest.raises(RuntimeError, match="already running"):
        with srv:
            srv.start()


def test_stop_drains_queued_work(grid):
    """stop(drain=True) serves everything still queued, so no future
    is ever left hanging by a clean shutdown."""
    srv, _, _, rng = _server(grid)
    futs = [srv.submit(rng.standard_normal((32, 1)).astype(np.float32))
            for _ in range(4)]
    srv.start()
    srv.stop(drain=True)
    assert all(f.done() for f in futs)
    assert srv.stats()["served"] == 4 and srv.pending() == 0


def test_concurrency_stress_producers_vs_churn(grid):
    """N producer threads against ONE real drain loop while a churn
    thread replaces and evicts/re-admits slots: every future completes
    (served or typed-stranded, never a hang), counts conserve, and the
    compiled program never retraces."""
    n, C, panel_k = 32, 4, 4
    Ls, rng = _factors(C, n, seed=11)
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    for L in Ls:
        bank.admit(L)
    solver = api.Solver.from_bank(bank)
    srv = api.AsyncSolveServer(solver, panel_k, queue_depth=16,
                               max_inflight=2).warmup()
    key = solver.program_for(panel_k).key
    traces = session.TRACE_COUNTS[key]
    N, per = 4, 25
    futures, shed = [], [0] * N
    flock = threading.Lock()
    barrier = threading.Barrier(N + 2)
    stop_churn = threading.Event()
    errors = []

    def producer(w):
        try:
            prng = np.random.default_rng(100 + w)
            barrier.wait()
            for i in range(per):
                b = prng.standard_normal((n, 1)).astype(np.float32)
                # steady slots 0/1 only; churn owns slots 2/3
                try:
                    f = srv.submit(b, factor=(w + i) % 2,
                                   tenant=f"w{w}")
                except api.Overloaded:
                    shed[w] += 1
                    continue
                with flock:
                    futures.append(f)
        except Exception as e:                # pragma: no cover
            errors.append(e)

    def churn():
        try:
            crng = np.random.default_rng(999)
            barrier.wait()
            while not stop_churn.is_set():
                slot = int(crng.integers(2, C))
                Lnew = (np.tril(crng.standard_normal((n, n)))
                        + n * np.eye(n)).astype(np.float32)
                if crng.integers(2):
                    bank.replace(slot, Lnew)  # generation-preserving
                else:
                    bank.evict(slot)
                    bank.admit(Lnew)          # turnover: strands queue
        except Exception as e:                # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=producer, args=(w,))
               for w in range(N)]
    threads.append(threading.Thread(target=churn))
    for t in threads:
        t.start()
    with srv:
        barrier.wait()
        for t in threads[:-1]:
            t.join(60)
        stop_churn.set()
        threads[-1].join(60)
        # submit a few against the churned slots too: they either
        # serve or strand with the typed error — never hang
        for slot in (2, 3):
            if bank.is_live(slot):
                try:
                    futures.append(srv.submit(
                        np.zeros((n, 1), np.float32), factor=slot))
                except (ValueError, api.Overloaded):
                    pass
    assert not errors
    assert all(f.done() for f in futures)     # stop(drain=True) above
    outcomes = [f.exception() for f in futures]
    assert all(e is None or isinstance(e, api.StrandedRequestError)
               for e in outcomes)
    st = srv.stats()
    assert st["served"] + st["stranded"] == len(futures)
    assert st["shed"] == sum(shed)            # count conservation
    # capacity churn NEVER recompiles the wave program
    assert session.TRACE_COUNTS[key] == traces


# ------------------------- FairQueue unit tests -------------------------

def _req(seq, tenant="t", width=1):
    return _Request(seq=seq, b=None, width=width, tenant=tenant,
                    key=0, gen=0, order=32, future=None)


def test_fairqueue_width_bound_stops_at_first_nonfit():
    fq = FairQueue(panel_k=4, depth=16)
    for seq, w in enumerate([2, 3, 1]):       # 2 fits, 3 doesn't, STOP
        fq.push(_req(seq, width=w))
    wave = fq.pack()
    assert [r.seq for r in wave] == [0]       # no skip-ahead past #1
    assert [r.seq for r in fq.pack()] == [1, 2]


def test_fairqueue_wide_request_never_starves():
    """A panel-wide request pays its width (later virtual finish), but
    a CONTINUOUS stream of narrow competitors cannot starve it: its
    fixed tag becomes the minimum within a bounded number of waves,
    and it then packs alone into a fresh panel."""
    fq = FairQueue(panel_k=4, depth=64)
    fq.push(_req(0, "slow", width=4))
    seq, served = 1, []
    for _ in range(10):
        for _ in range(4):                    # keep the pressure on
            fq.push(_req(seq, "fast", width=1))
            seq += 1
        served.append([r.seq for r in fq.pack()])
        if [0] in served:
            break
    assert [0] in served[:3]                  # alone, within 3 waves


def test_fairqueue_depth_bound_and_idle_reset():
    fq = FairQueue(panel_k=4, depth=2)
    fq.push(_req(0))
    fq.push(_req(1))
    with pytest.raises(api.Overloaded, match="full"):
        fq.push(_req(2))
    fq.pack()
    assert fq._vclock == 0.0 and not fq._vt   # idle => WFQ state reset
    fq.push(_req(3))                          # and admission reopens
    assert len(fq) == 1


def test_fairqueue_pop_if_removes_matching_fifo():
    fq = FairQueue(panel_k=8, depth=16)
    for seq in range(6):
        fq.push(_req(seq, tenant="a" if seq % 2 else "b"))
    hit = fq.pop_if(lambda r: r.tenant == "a")
    assert [r.seq for r in hit] == [1, 3, 5]
    assert len(fq) == 3
    assert fq.pop_if(lambda r: False) == []


def test_fairqueue_pop_if_frees_width_and_depth():
    fq = FairQueue(panel_k=8, depth=2)
    fq.push(_req(0, width=3))
    fq.push(_req(1, width=2))
    assert fq.queued_width() == 5
    fq.pop_if(lambda r: r.seq == 0)
    assert fq.queued_width() == 2
    fq.push(_req(2))                  # depth slot freed by the pop
    assert [r.seq for r in fq.pack()] == [1, 2]
    assert fq.pop_if(lambda r: True) == []    # empty queue: no-op


def test_fairqueue_weight_update_under_churn():
    fq = FairQueue(panel_k=2, depth=64)
    seq = 0

    def burst(counts):
        nonlocal seq
        for t, c in counts:
            for _ in range(c):
                fq.push(_req(seq, t))
                seq += 1

    burst([("a", 2), ("b", 2)])
    drained = []
    while len(fq):
        drained.extend(fq.pack())
    # equal weights: the wave interleaves fairly
    assert sorted(r.tenant for r in drained[:2]) == ["a", "b"]
    fq.set_weight("a", 4.0)           # mid-stream reweigh
    with pytest.raises(ValueError, match="weight"):
        fq.set_weight("a", 0.0)
    burst([("a", 4), ("b", 4)])
    drained2 = []
    while len(fq):
        drained2.extend(fq.pack())
    # churn loses nothing, per-tenant FIFO holds, and the heavier
    # tenant now FRONT-LOADS the drain order
    assert len(drained2) == 8
    for t in ("a", "b"):
        mine = [r.seq for r in drained2 if r.tenant == t]
        assert mine == sorted(mine)
    first_half = [r.tenant for r in drained2[:4]]
    assert first_half.count("a") > first_half.count("b")


def test_server_set_weight_applies_to_live_and_future_queues(
        grid, fake_clock, drain_driver):
    srv, Ls, _, rng = _server(grid, clock=fake_clock,
                              weights={"a": 1.0, "b": 1.0})
    b = rng.standard_normal((32, 1)).astype(np.float32)
    f0 = srv.submit(b, factor=0, tenant="a")  # queue 0 exists now
    srv.set_weight("a", 8.0)
    with pytest.raises(ValueError, match="weight"):
        srv.set_weight("a", -1.0)
    assert srv._queues[0].weight("a") == 8.0  # live queue updated
    f1 = srv.submit(b, factor=1, tenant="a")  # queue 1 created after
    assert srv._queues[1].weight("a") == 8.0
    drain_driver(srv).run_until_idle()
    srv.flush()
    assert f0.exception(timeout=0) is None
    assert f1.exception(timeout=0) is None


def test_fairqueue_rejects_bad_config():
    with pytest.raises(ValueError, match="depth"):
        FairQueue(panel_k=4, depth=0)
    with pytest.raises(ValueError, match="weight"):
        FairQueue(panel_k=4, depth=4, weights={"t": 0.0})


def test_async_server_rejects_wrapping_a_solveserver(grid):
    Ls, _ = _factors(1)
    solver = api.Solver.from_factor(Ls[0], grid, n0=8)
    with pytest.raises(TypeError, match="directly"):
        api.AsyncSolveServer(api.SolveServer(solver, 4))
    with pytest.raises(ValueError, match="max_inflight"):
        api.AsyncSolveServer(solver, 4, max_inflight=0)
