"""method='auto' dispatch: the alpha-beta-gamma model instantiated with
machine constants picks the right algorithm per (shape, network) —
EXPERIMENTS.md Sec. Perf cell C."""

import pytest

from repro.core import cost_model as cm, tuning


def test_auto_picks_rec_for_square_on_ici():
    m, _, t = tuning.choose_method(16384, 16384, 256, cm.tpu_v5e())
    assert m == "rec"
    assert t["rec"] < t["inv"]


def test_auto_picks_inv_for_small_k_on_ici():
    m, plan, t = tuning.choose_method(16384, 512, 256, cm.tpu_v5e())
    assert m == "inv"
    assert t["inv"] < t["rec"] / 3     # the paper's headline regime


def test_auto_picks_inv_on_dcn():
    m, _, t = tuning.choose_method(16384, 16384, 256, cm.tpu_v5e_dcn())
    assert m == "inv"


def test_auto_end_to_end_solve():
    import os
    # runs on 1 device: grid (1,1,1); auto still dispatches correctly
    import jax
    import numpy as np
    from repro import core
    from repro.core import grid as gridlib

    grid = gridlib.make_trsm_mesh(1, 1)
    rng = np.random.default_rng(0)
    n, k = 64, 16
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    B = rng.standard_normal((n, k))
    X = core.trsm(L, B, grid, method="auto")
    np.testing.assert_allclose(X, np.linalg.solve(L, B), atol=5e-4)


def test_latency_improvement_scales_with_p():
    """The paper's S-advantage grows with p — auto flips to inv as the
    machine's alpha grows or p grows at fixed shape."""
    n, k = 1 << 15, 1 << 9
    adv = []
    for p in [64, 256, 1024]:
        rec = cm.rec_trsm_cost(n, k, p)
        plan = tuning.tune(n, k, p)
        adv.append(rec.s / plan.cost.s)
    assert adv[0] < adv[1] < adv[2]
