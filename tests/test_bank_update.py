"""Live-mutable FactorBank (DESIGN.md Sec. 11): capacity allocation,
in-place replace/replace_cyclic, evict/re-admit slot lifecycle, the
zero-transfer/zero-retrace churn steady state for every precision
preset at several occupancies, UpdateSpec cache keying, and the
server-side inactive-slot handling."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import cholesky, grid as gridlib, session
from repro.core.solver import UpdateSpec

PRESET_CASES = [
    ("fp32", np.float32, 1e-4),
    ("bf16", np.float32, 5e-2),
    ("bf16_refine", np.float32, 1e-4),
    ("fp64_refine", np.float64, 1e-10),
]


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def grid():
    return gridlib.make_trsm_mesh(1, 1)


def _factors(M, n=32, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    Ls = np.stack([np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
                   for _ in range(M)])
    return Ls.astype(dtype), rng


def _rel(L, x, b):
    x = np.asarray(x, np.float64)
    return np.linalg.norm(L.astype(np.float64) @ x - b) \
        / np.linalg.norm(b)


# ------------------------- capacity allocation -------------------------

def test_capacity_bank_width_pinned_and_empty_warmup(grid):
    """The compiled program is keyed on capacity, not occupancy: an
    EMPTY capacity bank warms up, and admissions never re-key."""
    n, C, k = 32, 4, 4
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    solver = api.Solver.from_bank(bank)
    assert solver.width == C and solver.occupancy == 0
    assert bank.live_slots() == ()
    solver.warmup(k)                       # compiles at width C, empty
    key = solver.spec_for(k)
    assert key.bank_width == C
    traces = session.TRACE_COUNTS[key]
    Ls, rng = _factors(2)
    assert bank.admit(Ls[0]) == 0 and bank.admit(Ls[1]) == 1
    assert solver.spec_for(k) == key       # occupancy is not in the key
    B = np.zeros((C, n, k), np.float32)
    B[0] = rng.standard_normal((n, k))
    ref = B.copy()
    X = solver.solve(solver.place_rhs(B))
    assert session.TRACE_COUNTS[key] == traces
    assert _rel(Ls[0], np.asarray(X)[0], ref[0]) < 1e-4


def test_capacity_bank_validation(grid):
    with pytest.raises(ValueError, match="capacity"):
        api.FactorBank(grid, 32, capacity=0, dtype=np.float32)
    bank = api.FactorBank(grid, 32, n0=8, capacity=2, dtype=np.float32)
    Ls, _ = _factors(3)
    bank.admit(Ls[0])
    bank.admit(Ls[1])
    with pytest.raises(ValueError, match="bank full"):
        bank.admit(Ls[2])
    with pytest.raises(ValueError, match="bank full"):
        bank.admit_stack(Ls[:1])
    with pytest.raises(ValueError, match="out of range"):
        bank.replace(5, Ls[2])
    bank.evict(0)
    with pytest.raises(ValueError, match="not live"):
        bank.replace(0, Ls[2])             # evicted: admit, not replace
    with pytest.raises(ValueError, match="not live"):
        bank.evict(0)                      # double evict
    legacy = api.FactorBank(grid, 32, n0=8, dtype=np.float32)
    legacy.admit(Ls[0])
    with pytest.raises(ValueError, match="capacity-allocated"):
        legacy.evict(0)


def test_failed_admission_returns_the_slot(grid, monkeypatch):
    """A scatter that fails mid-admission (e.g. the updater's first
    compile is interrupted) must put the slot back on the free list —
    not leak it as neither-live-nor-free."""
    bank = api.FactorBank(grid, 32, n0=8, capacity=2, dtype=np.float32)
    Ls, _ = _factors(1)
    monkeypatch.setattr(
        bank, "_scatter",
        lambda *a, **k: (_ for _ in ()).throw(RuntimeError("compile")))
    with pytest.raises(RuntimeError, match="compile"):
        bank.admit(Ls[0])
    assert bank._free == [0, 1] and bank.size == 0
    monkeypatch.undo()
    assert bank.admit(Ls[0]) == 0          # the slot is usable again
    assert bank.live_slots() == (0,)


def test_capacity_full_width_admit_stack_fast_path(grid):
    """An empty capacity bank filled to exactly C takes the one-
    stacked-gather path and ends fully live."""
    n, C = 32, 3
    Ls, rng = _factors(C)
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    assert bank.admit_stack(Ls) == [0, 1, 2]
    assert bank.size == C and bank.live_slots() == (0, 1, 2)
    solver = api.Solver.from_bank(bank)
    B = rng.standard_normal((C, n, 4)).astype(np.float32)
    ref = B.copy()
    X = np.asarray(solver.solve(solver.place_rhs(B)))
    for i in range(C):
        assert _rel(Ls[i], X[i], ref[i]) < 1e-4, i


# ----------------------- replace / evict / admit -----------------------

def test_replace_updates_one_slot_in_place(grid):
    n, C, k = 32, 4, 4
    Ls, rng = _factors(C, seed=1)
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    bank.admit_stack(Ls)
    solver = api.Solver.from_bank(bank).warmup(k)
    Lnew, _ = _factors(1, seed=7)
    assert solver.replace_factor(2, Lnew[0]) == 2
    B = rng.standard_normal((C, n, k)).astype(np.float32)
    ref = B.copy()
    X = np.asarray(solver.solve(solver.place_rhs(B)))
    for i in range(C):                     # slot 2 serves the NEW factor,
        L = Lnew[0] if i == 2 else Ls[i]   # the others are untouched
        assert _rel(L, X[i], ref[i]) < 1e-4, i


def test_replace_cyclic_from_producer(grid):
    n, C = 32, 2
    Ls, rng = _factors(C, seed=2)
    A = (Ls[0] @ Ls[0].T).astype(np.float32)            # SPD
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    bank.admit_stack(Ls)
    bank.replace_cyclic(1, cholesky.cholesky_cyclic(A, grid))
    solver = api.Solver.from_bank(bank)
    B = rng.standard_normal((C, n, 4)).astype(np.float32)
    ref = B.copy()
    X = np.asarray(solver.solve(solver.place_rhs(B)))
    Lr = np.asarray(cholesky.cholesky(A, grid), np.float64)
    assert _rel(Ls[0], X[0], ref[0]) < 1e-4
    assert np.linalg.norm(Lr @ np.asarray(X[1], np.float64) - ref[1]) \
        / np.linalg.norm(ref[1]) < 1e-4
    upper = api.FactorBank(grid, n, n0=8, capacity=1, lower=False,
                           dtype=np.float32)
    upper.admit(np.triu(Ls[0].T))
    with pytest.raises(ValueError, match="cyclic ingestion"):
        upper.replace_cyclic(0, np.eye(n, dtype=np.float32))


def test_evict_then_admit_reuses_lowest_free_slot(grid):
    n, C = 32, 4
    Ls, _ = _factors(6, seed=3)
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    slots = [bank.admit(Ls[i]) for i in range(4)]
    assert slots == [0, 1, 2, 3]
    bank.evict(2)
    bank.evict(0)
    assert bank.live_slots() == (1, 3) and bank.size == 2
    assert bank.admit(Ls[4]) == 0          # lowest free slot first
    assert bank.admit(Ls[5]) == 2
    assert bank.live_slots() == (0, 1, 2, 3)


def test_legacy_bank_replace_in_place(grid):
    """replace works on append-only banks too (the KFAC refresh path):
    the fused stacks are scattered into, no chunk rebuild."""
    n, k = 32, 4
    Ls, rng = _factors(3, seed=4)
    bank = api.FactorBank(grid, n, n0=8, dtype=np.float32)
    bank.admit_stack(Ls)
    Lnew, _ = _factors(1, seed=8)
    bank.replace(1, Lnew[0])
    solver = api.Solver.from_bank(bank)
    B = rng.standard_normal((3, n, k)).astype(np.float32)
    ref = B.copy()
    X = np.asarray(solver.solve(solver.place_rhs(B)))
    for i, L in enumerate((Ls[0], Lnew[0], Ls[2])):
        assert _rel(L, X[i], ref[i]) < 1e-4, i


def test_incremental_stack_fuse_across_interleaved_admits(grid):
    """stacks() fuses pending chunks onto the cached fused stack (not
    a re-concat of the whole history) and stays correct when admits
    interleave with solves."""
    n, k = 32, 4
    Ls, rng = _factors(4, seed=5)
    bank = api.FactorBank(grid, n, n0=8, dtype=np.float32)
    bank.admit(Ls[0])
    assert bank.stacks()[0].shape[0] == 1
    assert not bank._chunks                # fused: nothing pending
    bank.admit(Ls[1])
    bank.admit_stack(Ls[2:])
    assert len(bank._chunks) == 2          # pending until next stacks()
    st = bank.stacks()
    assert st[0].shape[0] == 4 and not bank._chunks
    assert bank.stacks() is st             # cached, no rebuild
    solver = api.Solver.from_bank(bank)
    B = rng.standard_normal((4, n, k)).astype(np.float32)
    ref = B.copy()
    X = np.asarray(solver.solve(solver.place_rhs(B)))
    for i in range(4):
        assert _rel(Ls[i], X[i], ref[i]) < 1e-4, i


# ------------------- the churn steady state (acceptance) -------------------

@pytest.mark.parametrize("occupancy", [1, 2, 4])
@pytest.mark.parametrize("precision,in_dt,rtol", PRESET_CASES)
def test_churn_steady_state_zero_transfers_zero_retraces(
        grid, occupancy, precision, in_dt, rtol):
    """The tentpole invariant: an interleaved churn-and-solve schedule
    (solve, replace, solve, evict + re-admit, solve) performs zero
    host<->device transfers and zero retraces — for every precision
    preset, at occupancies 1, C/2, and C."""
    n, C, k = 32, 4, 4
    Ls, rng = _factors(occupancy, dtype=in_dt, seed=occupancy)
    bank = api.FactorBank(grid, n, n0=8, capacity=C, precision=precision)
    solver = api.Solver.from_bank(bank).warmup(k)
    for L in Ls:
        bank.admit(L)
    key, uspec = solver.spec_for(k), bank.update_spec()
    assert isinstance(uspec, UpdateSpec)
    traces = (session.TRACE_COUNTS[key], session.TRACE_COUNTS[uspec])

    live = dict(zip(bank.live_slots(), Ls))
    fresh, _ = _factors(2, dtype=in_dt, seed=90 + occupancy)
    placed = [bank.place_factor(L) for L in fresh]
    Bs = [solver.place_rhs(rng.standard_normal((C, n, k)).astype(in_dt))
          for _ in range(3)]
    refs = [np.asarray(b) for b in Bs]
    outs = []
    with jax.transfer_guard("disallow"):
        outs.append((solver.solve(Bs[0]), dict(live)))
        first = min(live)
        solver.replace_factor(first, placed[0])     # in-place refresh
        live[first] = fresh[0]
        outs.append((solver.solve(Bs[1]), dict(live)))
        last = max(live)
        solver.evict_factor(last)                   # turn the slot over
        assert solver.admit_factor(placed[1]) == last
        live[last] = fresh[1]
        outs.append((solver.solve(Bs[2]), dict(live)))
    assert (session.TRACE_COUNTS[key],
            session.TRACE_COUNTS[uspec]) == traces
    for (x, live_then), ref in zip(outs, refs):
        x = np.asarray(x)
        for slot, L in live_then.items():
            assert _rel(L, x[slot], ref[slot]) < rtol, (slot, precision)


def test_occupancies_share_one_program_and_updater(grid):
    """Banks of the same capacity at different occupancies hit the
    SAME compiled solve program and the SAME updater (the occupancy is
    not a cache key)."""
    n, C, k = 32, 4, 4
    cache = session.CompiledSolverCache()
    kw = dict(n0=8, capacity=C, dtype=np.float32, cache=cache)
    keys = set()
    for occ in (1, 2, 4):
        Ls, _ = _factors(occ, seed=occ)
        bank = api.FactorBank(grid, n, **kw)
        for L in Ls:
            bank.admit(L)
        solver = api.Solver.from_bank(bank, cache=cache).warmup(k)
        keys.add((solver.spec_for(k), bank.update_spec()))
    assert len(keys) == 1
    st = cache.stats()
    assert st["misses"] == 2               # one solve program, one updater
    assert st["hits"] >= 4


# --------------------------- UpdateSpec keying ---------------------------

def test_update_spec_is_a_cache_key(grid):
    n, C = 32, 2
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    uspec = bank.update_spec()
    assert uspec.bank_width == C and uspec.ingest == "natural"
    assert bank.update_spec("cyclic") != uspec     # ingest re-keys
    assert dataclasses.replace(uspec, bank_width=3) != uspec
    with pytest.raises(ValueError, match="ingest"):
        UpdateSpec(n=n, grid=grid, policy=api.PRESETS["fp32"],
                   method="inv", n0=8, mode=None, lower=True,
                   transpose=False, block_inv=None, bank_width=C,
                   ingest="weird")
    with pytest.raises(TypeError, match="UpdateSpec"):
        api.updater_for((1, 2))


# ----------------------- chunked scatter (Sec. 11) -----------------------

def test_replace_run_refreshes_contiguous_slots_in_one_dispatch(grid):
    """The chunk-width updater: replace_run scatters a stacked
    (u, n, n) batch into u contiguous live slots as ONE compiled
    dispatch (UpdateSpec.chunk = u), where a per-slot loop pays u."""
    n, C, k = 32, 4, 4
    Ls, rng = _factors(C, seed=11)
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    for L in Ls:
        bank.admit(L)
    solver = api.Solver.from_bank(bank).warmup(k)
    key = solver.spec_for(k)
    traces = session.TRACE_COUNTS[key]

    fresh, _ = _factors(3, seed=12)
    before = bank.updates_dispatched
    assert bank.replace_run(1, fresh) == range(1, 4)
    assert bank.updates_dispatched == before + 1       # ONE dispatch
    assert session.TRACE_COUNTS[key] == traces         # no solve retrace
    uspec = bank.update_spec(chunk=3)
    assert uspec.chunk == 3 and uspec != bank.update_spec()

    B = rng.standard_normal((C, n, k)).astype(np.float32)
    ref = B.copy()
    X = np.asarray(solver.solve(solver.place_rhs(B)))
    for i, L in enumerate((Ls[0], fresh[0], fresh[1], fresh[2])):
        assert _rel(L, X[i], ref[i]) < 1e-4, i

    # the second run re-uses the chunk-3 program: dispatch + no retrace
    utraces = session.TRACE_COUNTS[uspec]
    fresh2, _ = _factors(3, seed=13)
    bank.replace_run(1, fresh2)
    assert bank.updates_dispatched == before + 2
    assert session.TRACE_COUNTS[uspec] == utraces

    # a width-1 run degenerates to the plain single-slot updater
    one, _ = _factors(1, seed=14)
    bank.replace_run(0, one)
    X = np.asarray(solver.solve(solver.place_rhs(B)))
    assert _rel(one[0], X[0], ref[0]) < 1e-4


def test_replace_run_validation(grid):
    n, C = 32, 4
    Ls, _ = _factors(4, seed=15)
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    for L in Ls:
        bank.admit(L)
    with pytest.raises(ValueError, match="out of range"):
        bank.replace_run(2, Ls[:3])        # run overflows the bank
    bank.evict(2)
    with pytest.raises(ValueError, match="not live"):
        bank.replace_run(1, Ls[:3])        # slot 2 evicted mid-run
    legacy = api.FactorBank(grid, n, n0=8, dtype=np.float32)
    legacy.admit_stack(Ls)
    with pytest.raises(ValueError, match="capacity-allocated"):
        legacy.replace_run(0, Ls)
    with pytest.raises(ValueError, match="chunk"):
        bank.update_spec(chunk=0)
    with pytest.raises(ValueError, match="chunk"):
        bank.update_spec(chunk=C + 1)


def test_kfac_refresh_stacked_param_single_dispatch(grid):
    """refresh_banks refreshes a stacked (u, d, d) parameter's u bank
    slots in ONE chunked dispatch (they are admitted contiguously), so
    a bank holding {w: 1 slot, stack: u slots} refreshes in 2 dispatches
    instead of 1 + u."""
    import importlib
    kfac = importlib.import_module("repro.optim.kfac_ca")
    rng = np.random.default_rng(16)
    params = {"w": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
              "stack": jnp.asarray(rng.standard_normal((3, 16, 8)),
                                   jnp.float32)}
    opt = kfac.kfac_ca(min_dim=8)
    state = opt.init(params)
    grads = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    _, state, _ = opt.update(grads, state, params)
    banks, manifest = kfac.factor_banks_from_state(state, grid=grid)
    # satellite: KFAC banks are live-mutable by default now
    assert all(b.capacity == b.size for b in banks.values())
    before = {d: b.updates_dispatched for d, b in banks.items()}

    grads = jax.tree.map(lambda p: -0.2 * jnp.ones_like(p), params)
    _, state, _ = opt.update(grads, state, params)
    kfac.refresh_banks(banks, manifest, state)
    # per bank: one dispatch for w's slot + ONE for stack's 3-slot run
    for d, b in banks.items():
        assert b.updates_dispatched - before[d] == 2, d
        assert b.size == 4

    # the refreshed bank serves the current state (spot-check d=16)
    solver = api.Solver.from_bank(banks[16])
    B = rng.standard_normal((4, 16, 4)).astype(np.float32)
    ref = B.copy()
    X = np.asarray(solver.solve(solver.place_rhs(B)), np.float64)
    for i, (name, side, unit) in enumerate(manifest[16]):
        for nm, sd, M in kfac._iter_kron_factors(state):
            if (nm, sd) == (name, side):
                Mx = M if unit is None else M[unit]
                Lc = np.asarray(kfac._damped_chol(Mx, 1e-3), np.float64)
                assert np.linalg.norm(Lc @ X[i] - ref[i]) \
                    / np.linalg.norm(ref[i]) < 1e-4, (i, name)
                break


def test_kfac_banks_capacity_modes(grid):
    """factor_banks_from_state capacity=: "auto" (default) sizes each
    bank to its order's factor count, an int is a uniform override,
    None restores append-only width-frozen banks."""
    import importlib
    kfac = importlib.import_module("repro.optim.kfac_ca")
    rng = np.random.default_rng(17)
    params = {"w": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)}
    opt = kfac.kfac_ca(min_dim=8)
    state = opt.init(params)
    grads = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    _, state, _ = opt.update(grads, state, params)
    auto, _ = kfac.factor_banks_from_state(state, grid=grid)
    assert {d: b.capacity for d, b in auto.items()} == {16: 1, 8: 1}
    auto[16].evict(0)                      # live-mutable by default
    wide, _ = kfac.factor_banks_from_state(state, grid=grid, capacity=4)
    assert {d: b.capacity for d, b in wide.items()} == {16: 4, 8: 4}
    legacy, _ = kfac.factor_banks_from_state(state, grid=grid,
                                             capacity=None)
    assert all(b.capacity is None for b in legacy.values())
    with pytest.raises(ValueError, match="capacity-allocated"):
        legacy[16].evict(0)


# ------------------------ server slot lifecycle ------------------------

def test_server_rejects_inactive_slots_and_drains_live(grid):
    n, C, k = 32, 4, 4
    Ls, rng = _factors(3, seed=6)
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    slots = [bank.admit(L) for L in Ls]
    server = api.SolveServer(api.Solver.from_bank(bank), k).warmup()
    with pytest.raises(ValueError, match="inactive slot"):
        server.submit(np.zeros((n, 1), np.float32), factor=3)
    with pytest.raises(ValueError, match="unknown factor"):
        server.submit(np.zeros((n, 1), np.float32), factor=C)
    bank.evict(slots[1])
    with pytest.raises(ValueError, match="inactive slot"):
        server.submit(np.zeros((n, 1), np.float32), factor=slots[1])
    reqs = {f: rng.standard_normal((n, 2)).astype(np.float32)
            for f in (slots[0], slots[2])}
    for f, r in reqs.items():
        server.submit(r, factor=f)
    outs = server.drain()
    assert set(outs) == {slots[0], slots[2]}   # live slots only
    for f, r in reqs.items():
        assert _rel(Ls[slots.index(f)], outs[f][0], r) < 1e-4


def test_server_rejects_drain_of_evicted_pending_requests(grid):
    n, C = 32, 2
    Ls, _ = _factors(2, seed=7)
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    bank.admit_stack(Ls)
    server = api.SolveServer(api.Solver.from_bank(bank), 4)
    server.submit(np.zeros((n, 1), np.float32), factor=1)
    bank.evict(1)
    with pytest.raises(ValueError, match="evicted"):
        server.drain()


def test_server_rejects_stale_requests_after_slot_turnover(grid):
    """Re-admitting an evicted slot makes it live again, but a request
    submitted BEFORE the turnover must still error at drain (it would
    be solved against the wrong factor) — the per-slot generation
    counter catches what liveness alone cannot."""
    n, C = 32, 2
    Ls, rng = _factors(3, seed=8)
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    bank.admit_stack(Ls[:2])
    server = api.SolveServer(api.Solver.from_bank(bank), 4)
    server.submit(np.zeros((n, 1), np.float32), factor=1)
    bank.evict(1)
    readmitted = bank.admit(Ls[2])         # slot 1 is live again...
    assert readmitted == 1 and bank.is_live(1)
    with pytest.raises(ValueError, match="evicted after submission"):
        server.drain()                     # ...but the request is stale
    # cancel is the recovery path: drop the stranded requests, then a
    # fresh submit against the re-admitted factor serves fine
    assert server.cancel(1) == 1
    assert server.cancel(1) == 0 and not server._req_gen
    r = rng.standard_normal((n, 2)).astype(np.float32)
    server.submit(r, factor=1)
    outs = server.drain()
    assert _rel(Ls[2], outs[1][0], r) < 1e-4


def test_from_spec_capacity_churn_entry_point(grid):
    """The declarative churn entry point: a bank_width spec with no
    factors allocates an empty capacity bank to fill later."""
    from repro.core.solver import SolveSpec
    spec = SolveSpec.auto(32, 4, grid=grid, method="inv", n0=8,
                          precision="fp32", bank_width=3)
    solver = api.Solver.from_spec(spec)
    assert solver.width == 3 and solver.occupancy == 0
    Ls, rng = _factors(1, seed=9)
    slot = solver.admit_factor(Ls[0])
    B = np.zeros((3, 32, 4), np.float32)
    B[slot] = rng.standard_normal((32, 4))
    ref = B.copy()
    X = np.asarray(solver.solve(solver.place_rhs(B)))
    assert _rel(Ls[0], X[slot], ref[slot]) < 1e-4
    with pytest.raises(ValueError, match="contradicts"):
        api.Solver.from_spec(spec, capacity=5)
