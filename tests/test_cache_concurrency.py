"""CompiledSolverCache under concurrency: the read paths
(__len__/__contains__/stats) hold the lock against concurrent
mutation, and misses are single-flight — two threads missing the same
SolveSpec build ONCE (a trace/compile can take minutes), with
hits/misses/evictions staying accurate."""

import threading
import time

import pytest

from repro import api
from repro.core import session
from repro.core.solver import SolveSpec


def _spec(i: int, k: int = 8) -> SolveSpec:
    """Distinct hashable plan-only specs (get() never inspects the
    mesh; only solver_for requires concreteness)."""
    return SolveSpec(n=64 * (i + 1), k=k, grid=api.plan_grid(1, 1),
                     policy=api.PRESETS["fp32"], n0=16)


def test_single_flight_builds_once_across_threads():
    cache = session.CompiledSolverCache()
    key = _spec(0)
    builds = []
    started = threading.Barrier(8)

    def build():
        builds.append(threading.get_ident())
        time.sleep(0.05)               # a slow "compile" both threads hit
        return object()

    results = [None] * 8

    def worker(i):
        started.wait()
        results[i] = cache.get(key, build)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(builds) == 1, "duplicate build of the same spec"
    assert all(r is results[0] for r in results)
    st = cache.stats()
    assert st["misses"] == 1           # ONE miss for the one build
    assert st["hits"] == 7             # every waiter scored a hit
    assert st["evictions"] == 0 and st["size"] == 1


def test_failed_build_releases_the_key():
    """A builder that raises must not wedge waiters: the next caller
    becomes the builder and succeeds."""
    cache = session.CompiledSolverCache()
    key = _spec(1)
    with pytest.raises(RuntimeError, match="boom"):
        cache.get(key, lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    sentinel = object()
    assert cache.get(key, lambda: sentinel) is sentinel
    assert cache.stats()["misses"] == 2


def test_concurrent_readers_and_writers_stress():
    """Hammer get (distinct keys, LRU evictions) from writer threads
    while readers spin on len/contains/stats — none of which may race
    the OrderedDict mutation (the bug: unlocked reads during popitem/
    move_to_end)."""
    cache = session.CompiledSolverCache(maxsize=8)
    keys = [_spec(i) for i in range(32)]
    stop = threading.Event()
    errors = []

    def writer(seed):
        try:
            for r in range(3):
                for i, key in enumerate(keys):
                    if (i + seed) % 2:
                        cache.get(key, object)
        except Exception as e:          # pragma: no cover
            errors.append(e)

    def reader():
        try:
            while not stop.is_set():
                len(cache)
                keys[0] in cache
                st = cache.stats()
                assert st["size"] <= 8
                assert 0.0 <= st["hit_rate"] <= 1.0
        except Exception as e:          # pragma: no cover
            errors.append(e)

    writers = [threading.Thread(target=writer, args=(s,))
               for s in range(4)]
    readers = [threading.Thread(target=reader) for _ in range(2)]
    for t in readers + writers:
        t.start()
    for t in writers:
        t.join()
    stop.set()
    for t in readers:
        t.join()
    assert not errors, errors
    st = cache.stats()
    assert st["size"] <= 8
    assert st["evictions"] >= len(keys) - 8
    # conservation: every get either hit or missed
    assert st["hits"] + st["misses"] == 4 * 3 * len(keys) // 2


def test_len_contains_stats_consistent_snapshot():
    cache = session.CompiledSolverCache(maxsize=2)
    a, b, c = _spec(0), _spec(1), _spec(2)
    cache.get(a, object)
    cache.get(b, object)
    assert len(cache) == 2 and a in cache and b in cache
    cache.get(c, object)               # evicts a (LRU)
    assert len(cache) == 2 and a not in cache and c in cache
    st = cache.stats()
    assert st == dict(size=2, hits=0, misses=3, evictions=1,
                      hit_rate=0.0)
    cache.clear()
    assert len(cache) == 0 and cache.stats()["misses"] == 0
