"""Control plane (repro.core.control / DESIGN.md Sec. 15): the
unified ServingError hierarchy with warn-once legacy aliases,
SLO-aware admission (deadline stamping, DeadlineUnmeetable through the
future, the idle probe path), deadline-EDF reordering inside a
tenant's fair-share window, and the planner-driven autoscaler —
split under saturation, merge at idle, convergence, live migration
that strands nothing, and the zero-retrace/zero-transfer steady state
on non-migrating waves.

Everything runs on the deterministic FakeClock/DrainDriver harness
(tests/conftest.py): no background thread, no wall-clock reads — the
same decision sequence replays on every run.
"""

import json
import warnings

import jax
import numpy as np
import pytest

from repro import api
from repro.core import cost_model as cm
from repro.core import session
from repro.core.serving import FairQueue, _Request

pytestmark = [pytest.mark.fast, pytest.mark.control]


@pytest.fixture(scope="module")
def grid():
    return api.make_trsm_mesh(1, 1)


def _lower(d, rng):
    L = np.tril(rng.standard_normal((d, d))).astype(np.float32)
    L[np.diag_indices(d)] = np.abs(L[np.diag_indices(d)]) + d
    return L


def _rel(L, X, b):
    X = np.asarray(X, np.float64)[:L.shape[0]]
    return (np.linalg.norm(L.astype(np.float64) @ X - np.asarray(b))
            / max(np.linalg.norm(b), 1e-30))


def _fleet_server(grid, clock, *, man={32: 3, 24: 3}, panel_k=4,
                  depth=64, slo_ms=None, admission=None, seed=0):
    """Mixed-order fleet (merged into ONE bucket at the default
    dispatch budget) + async server on the fake clock."""
    rng = np.random.default_rng(seed)
    plan = api.plan_fleet(dict(man), grid, k=panel_k)
    fleet = api.SolverFleet(grid, plan)
    Ls, handles = {}, {}
    for d, count in man.items():
        for i in range(count):
            Ls[(d, i)] = _lower(d, rng)
            handles[(d, i)] = fleet.admit(Ls[(d, i)],
                                          tenant=f"t{d}", tag=f"f{i}")
    srv = api.AsyncSolveServer(fleet, panel_k, queue_depth=depth,
                               slo_ms=slo_ms, admission=admission,
                               clock=clock).warmup()
    return srv, fleet, Ls, handles, rng


# ------------------------- error hierarchy -------------------------

def test_serving_error_hierarchy():
    assert issubclass(api.Overloaded, api.ServingError)
    assert issubclass(api.DeadlineUnmeetable, api.Overloaded)
    assert issubclass(api.StrandedRequestError, api.ServingError)
    # stdlib bases are part of the compat contract: pre-hierarchy
    # handlers written against them keep catching
    assert issubclass(api.Overloaded, RuntimeError)
    assert issubclass(api.StrandedRequestError, ValueError)
    assert not issubclass(api.StrandedRequestError, api.Overloaded)
    # one catch-all for "the serving tier refused/failed this request"
    for exc in (api.Overloaded("x"), api.DeadlineUnmeetable("x"),
                api.StrandedRequestError("x")):
        with pytest.raises(api.ServingError):
            raise exc


def test_legacy_error_spellings_warn_once_and_alias(recwarn):
    import repro.core.serving as serving
    import repro.core.solver as solver
    for mod, name in ((serving, "Overloaded"),
                      (solver, "StrandedRequestError")):
        mod.__dict__.pop(name, None)     # reset the warn-once binding
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            first = getattr(mod, name)
            again = getattr(mod, name)   # second access: cached, quiet
        assert first is again is getattr(api, name)
        msgs = [x for x in w if "README migration table"
                in str(x.message)]
        assert len(msgs) == 1 and issubclass(
            msgs[0].category, DeprecationWarning)


def test_unknown_attr_still_raises():
    import repro.core.serving as serving
    import repro.core.solver as solver
    for mod in (serving, solver):
        with pytest.raises(AttributeError, match="no attribute"):
            mod.no_such_name


# ------------------------- stats contracts -------------------------

def test_stats_empty_window_is_none(grid, fake_clock):
    srv, _, _, _, _ = _fleet_server(grid, fake_clock)
    st = srv.stats()
    assert st["served"] == 0 and st["shed"] == 0
    assert st["p50_ms"] is None and st["p99_ms"] is None
    assert st["max_ms"] is None
    assert st["tenants"] == {}


def test_stats_per_tenant_breakdown(grid, fake_clock, drain_driver):
    srv, fleet, Ls, handles, rng = _fleet_server(grid, fake_clock,
                                                 slo_ms=0.5)
    drv = drain_driver(srv)
    for (d, i), h in handles.items():
        srv.submit(rng.standard_normal((d, 2)).astype(np.float32),
                   tenant=f"t{d}", tag=f"f{i}")
    drv.run_until_idle(advance=0.25)     # every wave blows the SLO
    srv.flush()
    st = srv.stats()
    assert set(st["tenants"]) == {"t32", "t24"}
    for t in ("t32", "t24"):
        ts = st["tenants"][t]
        assert ts["submitted"] == ts["served"] == 3
        assert ts["slo_violations"] == 3
        assert ts["shed"] == ts["deadline_shed"] == 0
        assert ts["stranded"] == 0
    assert st["slo_violations"] == 6
    # the breakdown is a copy: mutating it never corrupts the server
    st["tenants"]["t32"]["served"] = 999
    assert srv.stats()["tenants"]["t32"]["served"] == 3


# ------------------------- admission -------------------------

def test_queue_wait_estimate_arithmetic():
    # 7 queued + 1 new = 2 waves of 4, plus 1 in flight = 3 waves
    assert cm.queue_wait_estimate(7, 1, 1, 4, 0.01) \
        == pytest.approx(0.03)
    assert cm.queue_wait_estimate(0, 1, 0, 4, 0.01) \
        == pytest.approx(0.01)
    # dispatch overhead is paid per wave
    assert cm.queue_wait_estimate(7, 1, 1, 4, 0.01, 0.001) \
        == pytest.approx(0.033)


@pytest.mark.parametrize("occupancy", [1, 6])    # 1 and C (=3+3)
def test_admission_sheds_deadline_unmeetable(grid, fake_clock,
                                             drain_driver, occupancy):
    man = {32: min(occupancy, 3), 24: max(occupancy - 3, 0)}
    man = {d: c for d, c in man.items() if c}
    ctrl = api.AdmissionController(slo_ms=50.0)
    srv, fleet, Ls, handles, rng = _fleet_server(
        grid, fake_clock, man=man, slo_ms=50.0, admission=ctrl)
    drv = drain_driver(srv)
    keys = sorted(handles)
    # measured signal: one wave at 10 ms -> EWMA seeds to 10 ms/wave
    d0, i0 = keys[0]
    first = srv.submit(rng.standard_normal((d0, 1)).astype(np.float32),
                       tenant=f"t{d0}", tag=f"f{i0}")
    drv.run_until_idle(advance=0.010)
    srv.flush()
    assert first.exception(timeout=0) is None
    unit = next(iter(fleet.buckets))
    assert srv._wave_ewma[unit] == pytest.approx(0.010)
    # 50 ms / 10 ms-per-wave / (panel_k=4 cols) -> ~20 columns admit;
    # beyond that the estimate exceeds the SLO and submits shed
    futs = []
    for j in range(40 * len(keys)):
        d, i = keys[j % len(keys)]
        futs.append(srv.submit(
            rng.standard_normal((d, 1)).astype(np.float32),
            tenant=f"t{d}", tag=f"f{i}"))      # NEVER raises
    shed = [f for f in futs if f.done()]
    ok = [f for f in futs if not f.done()]
    assert shed and ok, (len(shed), len(ok))
    for f in shed:
        assert isinstance(f.exception(timeout=0),
                          api.DeadlineUnmeetable)
        assert isinstance(f.exception(timeout=0), api.Overloaded)
    st = srv.stats()
    assert st["shed"] == len(shed) == ctrl.shed
    per_tenant = sum(ts["deadline_shed"]
                     for ts in st["tenants"].values())
    assert per_tenant == len(shed)
    # admitted requests were stamped and ALL serve
    drv.run_until_idle(advance=0.001)
    srv.flush()
    assert all(f.exception(timeout=0) is None for f in ok)
    assert srv.stranded == 0


def test_admission_probe_path_unwedges(grid, fake_clock,
                                       drain_driver):
    ctrl = api.AdmissionController(slo_ms=10.0)
    srv, fleet, Ls, handles, rng = _fleet_server(
        grid, fake_clock, slo_ms=10.0, admission=ctrl)
    unit = next(iter(fleet.buckets))
    srv._wave_ewma[unit] = 10.0          # poisoned: 10 s per wave
    # idle system: the probe admits anyway (and refreshes the EWMA)
    fut = srv.submit(np.random.default_rng(1)
                     .standard_normal((32, 1)).astype(np.float32),
                     tenant="t32", tag="f0")
    assert not fut.done()                # admitted, not shed
    drain_driver(srv).run_until_idle(advance=0.0005)
    srv.flush()
    assert fut.exception(timeout=0) is None
    assert srv._wave_ewma[unit] < 10.0   # signal recovered


def test_admission_without_slo_is_depth_only(grid, fake_clock):
    ctrl = api.AdmissionController()     # no SLO anywhere
    srv, _, _, _, rng = _fleet_server(grid, fake_clock, depth=2,
                                      admission=ctrl)
    b = rng.standard_normal((32, 1)).astype(np.float32)
    srv.submit(b, tenant="t32", tag="f0")
    srv.submit(b, tenant="t32", tag="f0")
    with pytest.raises(api.Overloaded):  # depth bound still raises
        srv.submit(b, tenant="t32", tag="f0")
    assert ctrl.shed == 0                # the controller shed nothing


# ------------------------- deadline EDF packing -------------------------

def _req(seq, tenant="t", width=1, deadline=None):
    return _Request(seq=seq, b=None, width=width, tenant=tenant,
                    key=0, gen=0, order=32, future=None,
                    deadline=deadline)


def test_pack_reorders_within_tenant_by_deadline():
    fq = FairQueue(panel_k=3, depth=16)
    fq.push(_req(0, deadline=9.0))
    fq.push(_req(1, deadline=1.0))
    fq.push(_req(2, deadline=5.0))
    assert [r.seq for r in fq.pack()] == [1, 2, 0]


def test_pack_without_deadlines_is_fifo():
    fq = FairQueue(panel_k=3, depth=16)
    for seq in range(3):
        fq.push(_req(seq))
    assert [r.seq for r in fq.pack()] == [0, 1, 2]


def test_deadline_reorder_preserves_cross_tenant_shares():
    # identical queues, one with deadlines: tenant B's positions and
    # every tenant's SLOT COUNT in the wave must be unchanged —
    # deadlines reorder only WITHIN a tenant's fair-share window
    def fill(fq, with_deadlines):
        dl = [7.0, 1.0, 4.0] if with_deadlines else [None] * 3
        fq.push(_req(0, "a", deadline=dl[0]))
        fq.push(_req(1, "b"))
        fq.push(_req(2, "a", deadline=dl[1]))
        fq.push(_req(3, "b"))
        fq.push(_req(4, "a", deadline=dl[2]))
        fq.push(_req(5, "b"))
    plain = FairQueue(panel_k=6, depth=16)
    edf = FairQueue(panel_k=6, depth=16)
    fill(plain, False)
    fill(edf, True)
    base = [(r.tenant, r.seq) for r in plain.pack()]
    wave = [(r.tenant, r.seq) for r in edf.pack()]
    assert [t for t, _ in base] == [t for t, _ in wave]
    assert [s for t, s in wave if t == "b"] \
        == [s for t, s in base if t == "b"]
    assert [s for t, s in wave if t == "a"] == [2, 4, 0]  # EDF
    # None deadlines sort LAST within the tenant, FIFO among them
    fq = FairQueue(panel_k=3, depth=16)
    fq.push(_req(0))
    fq.push(_req(1, deadline=1.0))
    fq.push(_req(2))
    assert [r.seq for r in fq.pack()] == [1, 0, 2]


def test_deadline_reorder_respects_width_bound():
    # EDF brings seq 2 forward; the width bound still stops the wave
    # at the first non-fit IN PACK ORDER
    fq = FairQueue(panel_k=3, depth=16)
    fq.push(_req(0, width=2, deadline=5.0))
    fq.push(_req(1, width=3, deadline=9.0))
    fq.push(_req(2, width=1, deadline=1.0))
    assert [r.seq for r in fq.pack()] == [2, 0]
    assert [r.seq for r in fq.pack()] == [1]


# ------------------------- autoscaler -------------------------

def _pressurize(srv, scaler, handles, rng, clock, count=20):
    """Re-baseline the rate window, then offer a burst over a short
    interval so the next tick sees saturation."""
    scaler.observe(now=clock.monotonic())
    futs = []
    for j in range(count):
        for (d, i) in sorted(handles):
            futs.append(srv.submit(
                rng.standard_normal((d, 4)).astype(np.float32),
                tenant=f"t{d}", tag=f"f{i}"))
    clock.advance(0.01)
    return futs


def test_autoscaler_requires_fleet(grid, fake_clock):
    Ls = np.stack([_lower(16, np.random.default_rng(0))])
    solver = api.Solver.from_factors(Ls, grid, n0=8)
    srv = api.AsyncSolveServer(solver, 4, clock=fake_clock)
    with pytest.raises(ValueError, match="fleet"):
        api.Autoscaler(srv)


def test_autoscale_split_triggers_and_converges(grid, fake_clock,
                                                drain_driver):
    srv, fleet, Ls, handles, rng = _fleet_server(grid, fake_clock)
    drv = drain_driver(srv)
    scaler = api.Autoscaler(srv, dwell_s=0.5, rate_alpha=1.0)
    assert sorted(k[0] for k in fleet.buckets) == [32]   # merged
    # one measured wave -> finite service signal
    f0 = srv.submit(rng.standard_normal((32, 2)).astype(np.float32),
                    tenant="t32", tag="f0")
    drv.run_until_idle(advance=0.001)
    srv.flush()
    futs = _pressurize(srv, scaler, handles, rng, fake_clock)
    report = scaler.tick(now=fake_clock.monotonic())
    assert report is not None and len(report["moved"]) == 3
    assert sorted(k[0] for k in fleet.buckets) == [24, 32]
    assert scaler.replans[-1]["kind"] == "split"
    # generations: every live handle still round-trips the directory
    for h in fleet.handles():
        assert fleet.bucket(h.bucket).bank.slot_generation(h.slot) \
            == h.generation
    # nothing stranded: every queued future resolves CORRECTLY
    drv.run_until_idle(advance=0.001)
    srv.flush()
    assert srv.stranded == 0
    for f in futs:
        assert f.exception(timeout=0) is None
    # convergence: sustained pressure re-prices to the SAME buckets
    fake_clock.advance(1.0)
    _pressurize(srv, scaler, handles, rng, fake_clock)
    assert scaler.tick(now=fake_clock.monotonic()) is None
    assert len(scaler.replans) == 1
    # ...and the split-time dispatch budget itself is a fixed point
    fixed = scaler.replan(scaler.replans[-1]["dispatch_s"])
    assert set(b.key for b in fixed.buckets) == set(fleet.buckets)
    drv.run_until_idle(advance=0.001)
    srv.flush()


def test_autoscale_merge_triggers_and_converges(grid, fake_clock,
                                                drain_driver):
    srv, fleet, Ls, handles, rng = _fleet_server(grid, fake_clock)
    drv = drain_driver(srv)
    scaler = api.Autoscaler(srv, dwell_s=0.5, rate_alpha=1.0)
    f0 = srv.submit(rng.standard_normal((32, 2)).astype(np.float32),
                    tenant="t32", tag="f0")
    drv.run_until_idle(advance=0.001)
    srv.flush()
    futs = _pressurize(srv, scaler, handles, rng, fake_clock)
    scaler.tick(now=fake_clock.monotonic())
    drv.run_until_idle(advance=0.001)
    srv.flush()
    assert sorted(k[0] for k in fleet.buckets) == [24, 32]
    # idle: the offered EWMA decays to ~0 -> merge back to one bucket
    fake_clock.advance(5.0)
    report = None
    for _ in range(4):
        fake_clock.advance(5.0)
        report = scaler.tick(now=fake_clock.monotonic())
        if report is not None:
            break
    assert report is not None
    assert sorted(k[0] for k in fleet.buckets) == [32]
    assert scaler.replans[-1]["kind"] == "merge"
    assert srv.stranded == 0
    # converged: further idle ticks are no-ops
    fake_clock.advance(5.0)
    assert scaler.tick(now=fake_clock.monotonic()) is None
    # the re-merged bucket still serves every order correctly
    b = rng.standard_normal((24, 1)).astype(np.float32)
    f = srv.submit(b, tenant="t24", tag="f1")
    drv.run_until_idle(advance=0.001)
    srv.flush()
    assert f.exception(timeout=0) is None
    assert _rel(Ls[(24, 1)], f.result(timeout=0), b) < 1e-4


@pytest.mark.parametrize("occupancy", [1, 6])
def test_migrate_under_flight_strands_nothing(grid, fake_clock,
                                              drain_driver,
                                              occupancy):
    man = {32: min(occupancy, 3), 24: max(occupancy - 3, 0)}
    man = {d: c for d, c in man.items() if c}
    srv, fleet, Ls, handles, rng = _fleet_server(grid, fake_clock,
                                                 man=man)
    drv = drain_driver(srv)
    # attach=False: this test drives replan/apply BY HAND while a
    # wave is in flight — no step-driven ticks interfering
    scaler = api.Autoscaler(srv, attach=False)
    keys = sorted(handles)
    # queue several waves' worth, dispatch ONE (leaves it in flight)
    bs = []
    futs = []
    for j in range(4):
        for (d, i) in keys:
            b = rng.standard_normal((d, 2)).astype(np.float32)
            bs.append(((d, i), b))
            futs.append(srv.submit(b, tenant=f"t{d}", tag=f"f{i}"))
    drv.step(advance=0.001)
    assert srv._inflight
    # force a migration while that wave is in flight: re-price at
    # zero dispatch budget (full split by order)
    plan = scaler.replan(0.0)
    queued_before = srv.pending()
    if len(man) > 1:
        assert len(plan.buckets) == 2    # it IS a real split
        report = scaler.apply(plan)
        assert len(report["moved"]) == man[24]
    drv.run_until_idle(advance=0.001)
    srv.flush()
    assert srv.stranded == 0
    for ((d, i), b), f in zip(bs, futs):
        assert f.exception(timeout=0) is None
        X = np.asarray(f.result(timeout=0))
        assert _rel(Ls[(d, i)], X[:d], b) < 1e-4
    assert srv.pending() == 0 and not srv._inflight


def test_non_migrating_waves_stay_zero_retrace_zero_transfer(
        grid, fake_clock, drain_driver):
    srv, fleet, Ls, handles, rng = _fleet_server(grid, fake_clock)
    drv = drain_driver(srv)
    # dwell blocks every replan after the first: steady-state waves
    # must run with NO further migrations
    scaler = api.Autoscaler(srv, dwell_s=1e9, rate_alpha=1.0)
    # split, then run one wave per bucket (first-compile of the new
    # bucket belongs to the migration, not to steady state)
    f0 = srv.submit(rng.standard_normal((32, 2)).astype(np.float32),
                    tenant="t32", tag="f0")
    drv.run_until_idle(advance=0.001)
    srv.flush()
    futs = _pressurize(srv, scaler, handles, rng, fake_clock, count=3)
    assert scaler.tick(now=fake_clock.monotonic()) is not None
    drv.run_until_idle(advance=0.001)
    srv.flush()
    # steady state AFTER the migration: zero retraces, zero transfers
    solve_keys = [fleet.solver(key).spec_for(srv.panel_k)
                  for key in fleet.buckets]
    traces0 = sum(session.TRACE_COUNTS[k] for k in solve_keys)
    # same (slot x width) composition the drained burst compiled, so
    # nothing under the guard traces for the first time
    pool = {d: jax.numpy.asarray(
        rng.standard_normal((d, 4)).astype(np.float32))
        for d in (32, 24)}
    jax.block_until_ready(list(pool.values()))
    steady = []
    with jax.transfer_guard("disallow"):
        for j in range(6):
            for (d, i) in sorted(handles):
                steady.append(srv.submit(pool[d], tenant=f"t{d}",
                                         tag=f"f{i}"))
            drv.run_until_idle(advance=0.001)
        srv.flush()
    assert sum(session.TRACE_COUNTS[k] for k in solve_keys) \
        == traces0
    for f in steady:
        assert f.exception(timeout=0) is None
    assert srv.stranded == 0


def test_autoscaler_stats_json_serializable(grid, fake_clock):
    srv, fleet, _, _, _ = _fleet_server(grid, fake_clock)
    scaler = api.Autoscaler(srv)
    ctrl = api.AdmissionController(slo_ms=5.0)
    json.dumps(scaler.stats())
    json.dumps(ctrl.stats())
    json.dumps(srv.stats())
