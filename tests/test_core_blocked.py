import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blocked


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def random_tril(key, n, dtype=jnp.float64, diag_boost=None):
    """Well-conditioned random lower-triangular test matrix."""
    L = jax.random.normal(key, (n, n), dtype=dtype)
    L = jnp.tril(L)
    boost = float(n) if diag_boost is None else diag_boost
    return L + boost * jnp.eye(n, dtype=dtype)


def ref_solve(L, B):
    return jax.scipy.linalg.solve_triangular(L, B, lower=True)


@pytest.mark.parametrize("n", [1, 4, 8, 16, 48, 64])
def test_tri_inv_doubling(n):
    L = random_tril(jax.random.key(n), n)
    Linv = blocked.tri_inv_doubling(L)
    np.testing.assert_allclose(Linv @ L, np.eye(n), atol=1e-9)
    assert np.allclose(np.triu(np.asarray(Linv), 1), 0.0)


@pytest.mark.parametrize("n,n0", [(16, 4), (64, 8), (64, 64), (32, 1)])
def test_block_diag_invert(n, n0):
    L = random_tril(jax.random.key(7), n)
    Lt = blocked.block_diag_invert(L, n0)
    Ln, Ltn = np.asarray(L), np.asarray(Lt)
    for i in range(n // n0):
        s = slice(i * n0, (i + 1) * n0)
        np.testing.assert_allclose(Ltn[s, s] @ Ln[s, s], np.eye(n0),
                                   atol=1e-9)
    # off-diagonal panels untouched
    mask = np.ones((n, n), bool)
    for i in range(n // n0):
        s = slice(i * n0, (i + 1) * n0)
        mask[s, s] = False
    np.testing.assert_array_equal(Ltn[mask], Ln[mask])


@pytest.mark.parametrize("n,k,n0", [(16, 8, 4), (64, 16, 8), (64, 3, 16),
                                    (32, 32, 32), (8, 1, 2)])
def test_it_inv_trsm_local(n, k, n0):
    kb, kl = jax.random.split(jax.random.key(n * k))
    L = random_tril(kb, n)
    B = jax.random.normal(kl, (n, k), dtype=jnp.float64)
    X = blocked.it_inv_trsm_local(L, B, n0)
    np.testing.assert_allclose(X, ref_solve(L, B), rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("n,k,n0", [(16, 8, 4), (64, 16, 8)])
def test_rec_trsm_local(n, k, n0):
    kb, kl = jax.random.split(jax.random.key(3))
    L = random_tril(kb, n)
    B = jax.random.normal(kl, (n, k), dtype=jnp.float64)
    X = blocked.rec_trsm_local(L, B, n0)
    np.testing.assert_allclose(X, ref_solve(L, B), rtol=1e-9, atol=1e-9)


def test_forward_substitution():
    L = random_tril(jax.random.key(0), 24)
    B = jax.random.normal(jax.random.key(1), (24, 5), dtype=jnp.float64)
    np.testing.assert_allclose(blocked.forward_substitution(L, B),
                               ref_solve(L, B), atol=1e-9)


def test_upper_and_transpose_reductions():
    L = random_tril(jax.random.key(5), 32)
    B = jax.random.normal(jax.random.key(6), (32, 4), dtype=jnp.float64)
    solver = lambda l, b: blocked.it_inv_trsm_local(l, b, 8)
    XU = blocked.solve_upper(L.T, B, solver)
    np.testing.assert_allclose(L.T @ XU, B, atol=1e-8)
    XT = blocked.solve_lower_t(L, B, solver)
    np.testing.assert_allclose(L.T @ XT, B, atol=1e-8)
    # SPD solve via Cholesky factor
    A = L @ L.T
    Xs = blocked.spd_solve(L, B, solver)
    np.testing.assert_allclose(A @ Xs, B, atol=1e-7)
