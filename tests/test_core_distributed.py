"""Distributed-core correctness: runs repro.core.selfcheck in a
subprocess with 8 forced host devices (the main pytest process must keep
seeing exactly 1 device, so collectives are exercised out-of-process)."""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_selfcheck(name: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.core.selfcheck", name],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"selfcheck {name} failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout


pytestmark = pytest.mark.slow


@pytest.mark.parametrize("check", ["order", "mm3d", "tri_inv", "rec_trsm",
                                   "it_inv_trsm", "doubling", "cholesky",
                                   "lu", "session", "bank", "overlap"])
def test_selfcheck(check):
    out = run_selfcheck(check)
    assert "FAIL" not in out
    assert "0 failures" in out
