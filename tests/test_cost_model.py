"""Paper cost-model validation: the traced collective costs of the
implemented algorithms must match the Sec. III/VII closed forms, and the
Sec. VIII/IX tables must reproduce the paper's asymptotic statements.

These tests ARE the paper's 'experiments': the paper has no wall-clock
results — its contribution is the cost analysis, which we check against
the instrumented implementation (see repro.core.comm)."""

import math

import jax
import numpy as np
import pytest

from repro.core import comm, cost_model as cm, tuning


# ---------------- closed-form model sanity (Secs. II-VII) ----------------

def test_collective_costs_match_paper_forms():
    p = 16
    n = 1024
    assert cm.allgather(n, p).s == math.log2(p)
    assert cm.allgather(n, p).w == n
    assert cm.allreduction(n, p).s == 2 * math.log2(p)
    assert cm.allreduction(n, p).w == 2 * n
    assert cm.allreduction(n, p).f == n
    assert cm.alltoall(n, p).w == n * math.log2(p) / 2
    # degenerate axis: no data moves
    assert cm.allgather(n, 1).w == 0
    assert cm.allreduction(n, 1).w == 0


def test_mm_cost_leading_order():
    n, k, p1, p2 = 1 << 12, 1 << 10, 8, 4
    p = p1 * p1 * p2
    lead = n * n / p1 ** 2 + 2 * n * k / (p1 * p2)
    # our schedule: leading order exactly, plus the nk/p permute
    c = cm.mm_cost(n, k, p, p1, p2)
    assert c.w == pytest.approx(lead + n * k / p, rel=0.01)
    assert c.f == pytest.approx(n * n * k / p, rel=0.01)
    assert c.s == pytest.approx(math.log2(p2) + 2 * math.log2(p1) + 1,
                                rel=0.01)
    # the paper's schedule carries the two O(nk log(p)/p) transposes
    cp = cm.mm_cost_paper(n, k, p, p1, p2)
    assert cp.w == pytest.approx(lead + 2 * n * k * math.log2(p) / p
                                 + n * k / p, rel=0.01)
    assert cp.w > c.w


def test_tri_inv_cost_is_polylog_latency():
    n, p1, p2 = 1 << 14, 8, 16
    c = cm.tri_inv_cost(n, p1, p2)
    p = p1 * p1 * p2
    assert c.s == pytest.approx(math.log2(p) ** 2)
    assert c.f == pytest.approx(cm.NU * n ** 3 / (8 * p))


def test_paper_table_regimes():
    # Sec. IX comparison table: latency improvement factor in 3D regime
    n, k, p = 1 << 16, 1 << 10, 1 << 9
    row = cm.paper_table_row(n, k, p)
    assert row["regime"] == "3D"
    ratio = row["standard"]["S"] / row["new"]["S"]
    # expected Theta((n/k)^{1/6} p^{2/3}); check within a log factor
    expect = (n / k) ** (1 / 6) * p ** (2 / 3)
    assert ratio == pytest.approx(expect, rel=3.0)
    # bandwidth parity in 3D
    assert row["standard"]["W"] == pytest.approx(row["new"]["W"])
    # 2D regime: bandwidth improves by log p
    n2 = int(4 * k * math.sqrt(p) * 4)
    row2 = cm.paper_table_row(n2, k, p)
    assert row2["regime"] == "2D"
    assert row2["standard"]["W"] / row2["new"]["W"] == \
        pytest.approx(math.log2(p))


# ---------------- traced implementation vs closed forms ----------------

def _sds(shape):
    return jax.ShapeDtypeStruct(shape, np.float32)


def test_traced_mm3d_matches_model():
    from repro.core import grid as gridlib, mm3d
    grid = gridlib.make_trsm_mesh(1, 1)   # single device: shapes only
    # trace the SHARD body at the logical per-device shapes for a
    # virtual p1=p2=2 grid: comm records use the mesh axis sizes, so we
    # must trace on a real multi-device mesh -> covered in selfcheck;
    # here we validate the single-device degenerate case (no comm).
    fn = mm3d.mm3d_fn(grid, 32, 32, 16)
    t = comm.traced_cost(fn, _sds((32, 32)), _sds((32, 16)))
    assert t.s == 0 and t.w == 0


def test_tuning_regime_boundaries():
    p = 64
    k = 1024
    assert tuning.regime(int(4 * k / p) - 100, k, p) == "1d"
    assert tuning.regime(int(4 * k * math.sqrt(p)) + 100, k, p) == "2d"
    assert tuning.regime(4 * k, k, p) == "3d"


def test_tune_returns_feasible_plan():
    for (n, k, p) in [(1 << 14, 1 << 10, 64), (1 << 12, 1 << 12, 16),
                      (256, 1 << 14, 64), (1 << 15, 128, 256)]:
        plan = tuning.tune(n, k, p)
        assert plan.p1 * plan.p1 * plan.p2 == p
        assert n % plan.n0 == 0
        assert plan.n0 % (plan.p1 * plan.p2) == 0
        assert plan.cost.f > 0


def test_tune_matches_ideal_regime_shape():
    # 2D regime should pick a flat grid (p2 small), 1D a tall one
    k = 1 << 10
    p = 64
    plan2d = tuning.tune(int(8 * k * math.sqrt(p)), k, p)
    plan1d = tuning.tune(max(4, int(2 * k / p)), k, p)
    assert plan2d.p1 >= plan1d.p1
    assert plan1d.p2 >= plan2d.p2


def test_it_inv_cost_beats_rec_latency_in_3d():
    # the headline claim: S improvement Theta((n/k)^{1/6} p^{2/3}).
    # Pinned to the NOMINAL machine: the claim is the paper's, about
    # the model — the committed host calibration (whose gamma-heavy
    # fit legitimately shifts argmins) must not enter here.
    n, k, p = 1 << 16, 1 << 10, 1 << 9
    rec = cm.rec_trsm_cost(n, k, p)
    plan = tuning.tune(n, k, p, machine=cm.tpu_v5e())
    it = plan.cost
    assert it.s < rec.s / 20   # orders of magnitude, conservatively
    # flops within the paper's 2x
    assert it.f <= 2.2 * rec.f
