"""The deprecated serving API (TrsmSession / BatchedTrsmSession /
TrsmRequestServer / BankedTrsmServer / make_trsm_server /
make_trsm_bank_server) stays source-compatible as thin shims: each
constructor emits exactly ONE DeprecationWarning (no cascade from
nested shims) and produces BIT-IDENTICAL results to the unified
Solver/SolveServer path, for every precision preset."""

import warnings

import jax
import numpy as np
import pytest

from repro import api, core
from repro.core.bank import BatchedTrsmSession, FactorBank
from repro.train import serve_step as ss

PRESETS = [None, "fp32", "bf16", "bf16_refine", "fp64_refine"]


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def grid():
    return api.make_trsm_mesh(1, 1)


def _mats(n=32, k=4, M=2, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    Ls = np.stack([np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
                   for _ in range(M)]).astype(dtype)
    B = rng.standard_normal((n, k)).astype(dtype)
    return Ls, B


def _one_deprecation(record) -> None:
    deps = [w for w in record if issubclass(w.category,
                                            DeprecationWarning)]
    assert len(deps) == 1, [str(w.message) for w in deps]


# --------------------------- warning counts ---------------------------

def test_trsm_session_warns_exactly_once(grid):
    Ls, _ = _mats()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        core.TrsmSession(Ls[0], grid, n0=8)
    _one_deprecation(rec)


def test_batched_session_warns_exactly_once(grid):
    Ls, _ = _mats()
    bank = FactorBank(grid, 32, n0=8, dtype=np.float32)
    bank.admit_stack(Ls)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        BatchedTrsmSession(bank)
    _one_deprecation(rec)


def test_make_trsm_server_warns_exactly_once():
    Ls, _ = _mats()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ss.make_trsm_server(Ls[0], panel_k=4, n0=8)
    _one_deprecation(rec)


def test_make_trsm_bank_server_warns_exactly_once():
    Ls, _ = _mats()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ss.make_trsm_bank_server(Ls, panel_k=4, n0=8)
    _one_deprecation(rec)


def test_request_server_shims_warn_exactly_once(grid):
    Ls, _ = _mats()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        sess = core.TrsmSession(Ls[0], grid, n0=8)
        bank = FactorBank(grid, 32, n0=8, dtype=np.float32)
        bank.admit_stack(Ls)
        bsess = BatchedTrsmSession(bank)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ss.TrsmRequestServer(sess, panel_k=4)
    _one_deprecation(rec)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ss.BankedTrsmServer(bsess, panel_k=4)
    _one_deprecation(rec)


# ------------------------ bit-identical results ------------------------

@pytest.mark.parametrize("precision", PRESETS)
def test_session_shim_bit_identical_to_solver(grid, precision):
    in_dt = np.float64 if precision in (None, "fp64_refine") \
        else np.float32
    Ls, B = _mats(dtype=in_dt)
    kw = dict(method="inv", n0=8, precision=precision)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        sess = core.TrsmSession(Ls[0], grid, **kw)
    solver = api.Solver.from_factor(Ls[0], grid, **kw)
    X_shim = np.asarray(sess.solve(B.copy(), donate=False))
    X_new = np.asarray(solver.solve(B.copy(), donate=False))
    assert X_shim.dtype == X_new.dtype == solver.dtype
    np.testing.assert_array_equal(X_shim, X_new)


@pytest.mark.parametrize("precision", PRESETS)
def test_batched_shim_bit_identical_to_solver(grid, precision):
    in_dt = np.float64 if precision in (None, "fp64_refine") \
        else np.float32
    Ls, B = _mats(dtype=in_dt)
    Bs = np.stack([B, 2 * B])
    kw = dict(method="inv", n0=8,
              dtype=None if precision else in_dt, precision=precision)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        bank = FactorBank(grid, 32, **kw)
        bank.admit_stack(Ls)
        bsess = BatchedTrsmSession(bank)
        X_shim = np.asarray(bsess.solve(bsess.place_rhs(Bs),
                                        donate=False))
    solver = api.Solver.from_factors(Ls, grid, **kw)
    X_new = np.asarray(solver.solve(solver.place_rhs(Bs), donate=False))
    np.testing.assert_array_equal(X_shim, X_new)


@pytest.mark.parametrize("precision", PRESETS)
def test_server_shim_bit_identical_to_solve_server(precision):
    in_dt = np.float64 if precision in (None, "fp64_refine") \
        else np.float32
    Ls, _ = _mats(dtype=in_dt)
    rng = np.random.default_rng(7)
    reqs = [rng.standard_normal((32, w)).astype(in_dt)
            for w in (3, 1, 4, 2)]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = ss.make_trsm_server(Ls[0], panel_k=4, n0=8,
                                  precision=precision)
    solver = api.Solver.from_factor(
        Ls[0], api.make_trsm_mesh(1, 1), n0=8,
        dtype=None if precision else in_dt, precision=precision)
    new = api.SolveServer(solver, panel_k=4).warmup()
    for r in reqs:
        old.submit(r)
        new.submit(r)
    outs_old = old.drain()
    outs_new = new.drain()[0]
    assert len(outs_old) == len(outs_new) == len(reqs)
    for a, b in zip(outs_old, outs_new):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shim_sessions_expose_legacy_surface(grid):
    """The attributes PR-1..3 call sites read must survive on the
    shims (n, dtype, policy, n0, method, solves_served, the resident
    factor views, program_for keys)."""
    Ls, B = _mats(dtype=np.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        sess = core.TrsmSession(Ls[0], grid, n0=8,
                                precision="bf16_refine")
    assert sess.n == 32 and sess.method == "inv" and sess.n0 == 8
    assert sess.dtype == np.float32 and sess.policy.refines
    assert sess.factor_cyclic.shape == (32, 32)
    assert sess.factor_cyclic_residual is not None
    sess.warmup(4)
    assert sess.solves_served == 1
    assert sess.program_for(4).key.bank_width == 1
