"""Docs stay true: the README quickstart snippet executes, every
intra-repo link/file reference in README.md / DESIGN.md / ROADMAP.md
resolves, and every "DESIGN.md Sec. N" citation in the code points at
a section that actually exists (the bug this kills: code citing a
design doc that was never written)."""

import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOCS = ["README.md", "DESIGN.md", "ROADMAP.md"]

# bases a doc reference may be relative to (DESIGN.md abbreviates
# src/repro/core/session.py as core/session.py etc.)
BASES = [ROOT, ROOT / "src", ROOT / "src" / "repro"]

_FENCE = re.compile(r"```.*?```", re.S)
_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")
_INLINE_PATH = re.compile(
    r"`([\w.-][\w./-]*\.(?:py|md|json|txt|ini|yml|yaml))`")
_BARE_PATH = re.compile(
    r"(?<![\w/`.])((?:src|tests|benchmarks|examples|experiments)"
    r"/[\w./-]+\.(?:py|md|json))")
_CITATION = re.compile(r"DESIGN\.md\s+Sec\.\s*(\d+(?:\.\d+)?)")
_HEADING_NUM = re.compile(r"^#+\s+(\d+(?:\.\d+)?)[.\s]", re.M)


def _gitignored(path: str) -> bool:
    """Paths the docs may legitimately name but a fresh checkout lacks
    (e.g. benchmarks/results.json, the live bench output)."""
    gi = ROOT / ".gitignore"
    if not gi.exists():
        return False
    return path in {ln.strip().lstrip("/") for ln in
                    gi.read_text().splitlines() if ln.strip()}


def _resolves(path: str) -> bool:
    return _gitignored(path) or \
        any((base / path).exists() for base in BASES)


def _read(name: str) -> str:
    p = ROOT / name
    assert p.exists(), f"{name} missing at repo root"
    return p.read_text()


# ------------------------------ links ------------------------------

@pytest.mark.parametrize("doc", DOCS)
def test_markdown_links_resolve(doc):
    text = _read(doc)
    links = [t for t in _MD_LINK.findall(text)
             if not t.startswith(("http://", "https://", "mailto:"))]
    assert links or doc == "ROADMAP.md"   # README/DESIGN must cross-link
    missing = [t for t in links if not _resolves(t)]
    assert not missing, f"{doc}: dangling links {missing}"


@pytest.mark.parametrize("doc", DOCS)
def test_file_references_resolve(doc):
    """Every path-looking reference — `inline code` or bare prose —
    must exist (relative to the repo root or the source roots)."""
    prose = _FENCE.sub("", _read(doc))
    refs = set(_INLINE_PATH.findall(prose)) | set(_BARE_PATH.findall(prose))
    missing = sorted(r for r in refs if not _resolves(r))
    assert not missing, f"{doc}: dangling file references {missing}"


def test_design_sections_cited_by_code_exist():
    """grep the codebase for "DESIGN.md Sec. N" and require a numbered
    heading N in DESIGN.md (section numbers are stable API)."""
    headings = set(_HEADING_NUM.findall(_read("DESIGN.md")))
    assert headings, "DESIGN.md has no numbered headings"
    missing = []
    for sub in ("src", "benchmarks", "examples", "experiments", "tests"):
        for f in (ROOT / sub).rglob("*.py"):
            for num in _CITATION.findall(f.read_text()):
                if num not in headings and num.split(".")[0] \
                        not in headings:
                    missing.append((str(f.relative_to(ROOT)), num))
    assert not missing, f"citations to nonexistent DESIGN.md sections: " \
                        f"{missing}"


def test_trsm_block_citation_resolves():
    """The acceptance-criteria regression: trsm_block.py cites
    DESIGN.md Sec. 2, which must exist."""
    src = (ROOT / "src/repro/kernels/trsm_block.py").read_text()
    nums = _CITATION.findall(src)
    assert nums, "trsm_block.py no longer cites DESIGN.md (update test)"
    headings = set(_HEADING_NUM.findall(_read("DESIGN.md")))
    assert all(n in headings for n in nums), (nums, headings)


# --------------------------- the quickstart ---------------------------

def test_readme_quickstart_snippets_execute():
    """Run EVERY README ```python block verbatim (each asserts its own
    correctness bound), so neither the Solver quickstart nor the
    SolveSpec/SolveServer example can rot."""
    text = _read("README.md")
    blocks = re.findall(r"```python\n(.*?)```", text, re.S)
    assert len(blocks) >= 2, "README.md lost its quickstart blocks"
    for i, block in enumerate(blocks):
        ns: dict = {}
        exec(compile(block, f"README.md:quickstart[{i}]", "exec"), ns)
        if i == 0:
            # the front-door snippet leaves its solution in scope
            assert ns["X"].shape == (ns["n"], ns["k"])


def test_readme_quickstart_uses_new_api():
    """The executable quickstart must teach repro.api (the unified
    front door), not the deprecated session spellings."""
    text = _read("README.md")
    blocks = re.findall(r"```python\n(.*?)```", text, re.S)
    joined = "\n".join(blocks)
    assert "from repro import api" in joined
    assert "TrsmSession" not in joined


def test_tier1_command_documented():
    """README must carry the exact tier-1 verify command ROADMAP
    promises."""
    readme = _read("README.md")
    assert 'python -m pytest -q -m "not slow"' in readme
