"""Multi-factor batched serving (repro.core.bank): admission paths vs
the per-factor reference, the batched steady-state invariants for every
precision preset, cyclic ingestion from the factor producers, the
banked request server, and the KFAC per-layer hookup (single-device
grid; the multi-device variants run in the `bank` selfcheck —
repro.core.selfcheck, exercised by tests/test_core_distributed.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import cholesky, grid as gridlib, lu, session
from repro.core.bank import BatchedTrsmSession, FactorBank


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def grid():
    return gridlib.make_trsm_mesh(1, 1)


def _factors(M=4, n=64, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    Ls = np.stack([np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
                   for _ in range(M)])
    return Ls.astype(dtype), rng


def _check(Ls, X, B, tol):
    X = np.asarray(X, np.float64)
    for i in range(Ls.shape[0]):
        rel = (np.linalg.norm(Ls[i].astype(np.float64) @ X[i] - B[i])
               / np.linalg.norm(B[i]))
        assert rel < tol, (i, rel)


# ----------------------------- correctness -----------------------------

@pytest.mark.parametrize("method,map_mode", [("inv", "vmap"),
                                             ("inv", "scan"),
                                             ("rec", "vmap")])
def test_bank_matches_per_factor_reference(grid, method, map_mode):
    Ls, rng = _factors()
    B = rng.standard_normal((4, 64, 8)).astype(np.float32)
    bank = FactorBank(grid, 64, method=method,
                      n0=None if method == "inv" else 16,
                      dtype=np.float32, map_mode=map_mode)
    assert bank.admit(Ls[0]) == 0
    assert bank.admit_stack(Ls[1:]) == range(1, 4)
    sess = BatchedTrsmSession(bank)
    X = sess.solve(sess.place_rhs(B))
    assert X.shape == (4, 64, 8) and X.dtype == sess.dtype
    _check(Ls, X, B, 1e-4)
    # per-factor sessions agree
    ref = core.TrsmSession(Ls[2], grid, method=method,
                           n0=bank.n0 if method == "inv" else 16)
    Xr = ref.solve(ref.place_rhs(B[2]))
    np.testing.assert_allclose(np.asarray(X[2]), np.asarray(Xr),
                               atol=1e-4)


@pytest.mark.parametrize("lower,transpose", [(False, False), (True, True),
                                             (False, True)])
def test_bank_operator_variants(grid, lower, transpose):
    Ls, rng = _factors()
    As = Ls if lower else np.ascontiguousarray(np.swapaxes(Ls, 1, 2))
    B = rng.standard_normal((4, 64, 8)).astype(np.float32)
    bank = FactorBank(grid, 64, lower=lower, transpose=transpose,
                      dtype=np.float32)
    bank.admit_stack(As)
    X = np.asarray(BatchedTrsmSession(bank).solve(
        jnp.asarray(B)), np.float64)
    for i in range(4):
        op = As[i].T if transpose else As[i]
        rel = np.linalg.norm(op @ X[i] - B[i]) / np.linalg.norm(B[i])
        assert rel < 1e-4, (lower, transpose, i, rel)


def test_bank_input_validation(grid):
    bank = FactorBank(grid, 64, dtype=np.float32)
    with pytest.raises(ValueError, match="factor must be"):
        bank.admit(np.zeros((32, 32), np.float32))
    with pytest.raises(ValueError, match="factor must be"):
        bank.admit_stack(np.zeros((2, 32, 32), np.float32))
    with pytest.raises(ValueError, match="empty bank"):
        bank.stacks()
    with pytest.raises(ValueError, match="map_mode"):
        FactorBank(grid, 64, dtype=np.float32, map_mode="pmap")
    with pytest.raises(ValueError, match="method"):
        FactorBank(grid, 64, dtype=np.float32, method="auto")
    bank.admit(np.eye(64, dtype=np.float32))
    sess = BatchedTrsmSession(bank)
    with pytest.raises(ValueError, match="rhs stack"):
        sess.solve(jnp.zeros((2, 64, 4)))     # M mismatch
    with pytest.raises(ValueError, match="rhs stack"):
        sess.solve(jnp.zeros((64, 4)))        # missing factor axis


# ------------------- cyclic ingestion (producer loop) -------------------

def test_bank_cyclic_ingestion_from_cholesky_and_lu(grid):
    Ls, rng = _factors(M=2)
    A1 = (Ls[0] @ Ls[0].T).astype(np.float32)           # SPD
    A2 = (Ls[1] + 64 * np.eye(64)).astype(np.float32)   # diag-dominant
    bank = FactorBank(grid, 64, dtype=np.float32)
    bank.admit_cyclic(cholesky.cholesky_cyclic(A1, grid))
    bank.admit_cyclic(lu.lu_cyclic(A2, grid)[0])
    B = rng.standard_normal((2, 64, 8)).astype(np.float32)
    X = np.asarray(BatchedTrsmSession(bank).solve(jnp.asarray(B)),
                   np.float64)
    L1 = np.asarray(cholesky.cholesky(A1, grid), np.float64)
    L2 = np.asarray(lu.lu(A2, grid)[0], np.float64)
    for L, x, b in zip((L1, L2), X, B):
        assert np.linalg.norm(L @ x - b) / np.linalg.norm(b) < 1e-4
    # the natural-layout producers agree with their cyclic outputs
    np.testing.assert_allclose(
        np.asarray(gridlib.cyclic_matrix_device(
            cholesky.cholesky_cyclic(A1, grid), grid.p1,
            grid.p1 * grid.p2, inverse=True)),
        np.asarray(cholesky.cholesky(A1, grid)))


def test_bank_cyclic_ingestion_rejects_folded_variants(grid):
    bank = FactorBank(grid, 64, dtype=np.float32, lower=False)
    with pytest.raises(ValueError, match="cyclic ingestion"):
        bank.admit_cyclic(np.eye(64, dtype=np.float32))


# --------------------- steady-state invariants ---------------------

@pytest.mark.parametrize("precision,in_dt,rtol", [
    (None, np.float64, 1e-10),
    ("fp32", np.float32, 1e-5),
    ("bf16", np.float32, 5e-2),
    ("bf16_refine", np.float32, 1e-5),
    ("fp64_refine", np.float64, 1e-11),
])
def test_bank_steady_state_no_transfers_no_retraces(grid, precision,
                                                    in_dt, rtol):
    M, n, k = 3, 64, 8
    Ls, rng = _factors(M=M, dtype=in_dt)
    bank = FactorBank(grid, n, precision=precision,
                      dtype=None if precision else in_dt)
    bank.admit_stack(Ls)
    sess = BatchedTrsmSession(bank)
    key = sess.program_for(k).key          # built, not yet traced
    before = session.TRACE_COUNTS[key]
    sess.warmup(k)
    assert session.TRACE_COUNTS[key] == before + 1
    Bs = [sess.place_rhs(rng.standard_normal((M, n, k)).astype(in_dt))
          for _ in range(3)]
    refs = [np.asarray(b) for b in Bs]
    with jax.transfer_guard("disallow"):
        outs = [sess.solve(b) for b in Bs]
    assert session.TRACE_COUNTS[key] == before + 1
    for b, x in zip(refs, outs):
        assert x.dtype == sess.dtype
        _check(Ls, x, b, rtol)
    assert sess.solves_served == (1 + len(Bs)) * M


def test_bank_width_is_a_cache_key(grid):
    Ls, rng = _factors()
    cache = session.CompiledSolverCache()
    kw = dict(dtype=np.float32, cache=cache)
    b2 = FactorBank(grid, 64, **kw)
    b2.admit_stack(Ls[:2])
    b3 = FactorBank(grid, 64, **kw)
    b3.admit_stack(Ls[:3])
    s2, s3 = BatchedTrsmSession(b2), BatchedTrsmSession(b3)
    assert s2.program_for(8).key != s3.program_for(8).key
    assert cache.stats()["misses"] == 2
    # same width, same config -> same program (cache hit)
    b2b = FactorBank(grid, 64, **kw)
    b2b.admit_stack(Ls[2:])
    assert BatchedTrsmSession(b2b).program_for(8).key == \
        s2.program_for(8).key
    assert cache.stats()["hits"] >= 1


# ------------------------- banked request server -------------------------

def test_banked_server_per_factor_queues_one_packed_drain(grid):
    from repro.train import serve_step as ss
    M, n, panel_k = 3, 64, 4
    Ls, rng = _factors(M=M)
    server = ss.make_trsm_bank_server(Ls, panel_k=panel_k)
    subs = {f: [] for f in range(M)}
    for i in range(8):
        f = i % M
        r = rng.standard_normal((n, int(rng.integers(1, panel_k + 1))))
        r = r.astype(np.float32)
        subs[f].append(r)
        server.submit(f, r)
    outs = server.drain()
    assert server.pending() == 0
    # factor 0 got 3 requests of width <= 4: at most 3 waves, each ONE
    # dispatch covering all factors
    assert server.waves_solved <= 3
    assert server.requests_served == 8
    for f in range(M):
        assert [o.shape[1] for o in outs[f]] == \
            [r.shape[1] for r in subs[f]]
        for r, x in zip(subs[f], outs[f]):
            rel = (np.linalg.norm(Ls[f] @ np.asarray(x, np.float64) - r)
                   / np.linalg.norm(r))
            assert rel < 1e-4, (f, rel)
    with pytest.raises(ValueError, match="unknown factor"):
        server.submit(M, np.zeros((n, 1), np.float32))
    with pytest.raises(ValueError, match="wider than panel"):
        server.submit(0, np.zeros((n, panel_k + 1), np.float32))


def test_banked_server_serves_factors_admitted_after_construction(grid):
    """The bank is mutable: a factor admitted after the server is built
    must be submittable and drain must cover the new width (the next
    wave simply compiles at the new bank width)."""
    from repro.train import serve_step as ss
    Ls, rng = _factors(M=3)
    server = ss.make_trsm_bank_server(Ls[:2], panel_k=4)
    server.session.bank.admit(Ls[2])
    reqs = {f: rng.standard_normal((64, 2)).astype(np.float32)
            for f in range(3)}
    for f, r in reqs.items():
        server.submit(f, r)
    outs = server.drain()
    assert server.waves_solved == 1 and set(outs) == {0, 1, 2}
    for f, r in reqs.items():
        rel = (np.linalg.norm(
            Ls[f] @ np.asarray(outs[f][0], np.float64) - r)
            / np.linalg.norm(r))
        assert rel < 1e-4, (f, rel)
    with pytest.raises(ValueError, match="unknown factor"):
        server.submit(3, np.zeros((64, 1), np.float32))


# --------------------------- KFAC hookup ---------------------------

def test_kfac_factor_banks_serve_per_layer_solves(grid):
    import importlib
    kfac = importlib.import_module("repro.optim.kfac_ca")
    rng = np.random.default_rng(3)
    params = {"w": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
              "stack": jnp.asarray(rng.standard_normal((2, 16, 8)),
                                   jnp.float32),
              "norm": jnp.ones((16,), jnp.float32)}   # ineligible
    opt = kfac.kfac_ca(min_dim=8)
    state = opt.init(params)
    grads = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    _, state, _ = opt.update(grads, state, params)
    banks, manifest = kfac.factor_banks_from_state(state, grid=grid)
    # w and stack (2 units) contribute: A-side d=16 x3, B-side d=8 x3
    assert {d: b.size for d, b in banks.items()} == {16: 3, 8: 3}
    assert [tag[1] for tag in manifest[16]] == ["A", "A", "A"]
    assert sorted((tag[2] for tag in manifest[16]),
                  key=lambda u: (u is None, u)) == [0, 1, None]
    sess = BatchedTrsmSession(banks[16])
    B = rng.standard_normal((3, 16, 4)).astype(np.float32)
    X = np.asarray(sess.solve(sess.place_rhs(B)), np.float64)
    assert np.isfinite(X).all()
    # each solve inverts the damped Cholesky factor it was banked with
    Lc = np.asarray(bank_factor_natural(banks[16], 0), np.float64)
    rel = np.linalg.norm(Lc @ X[0] - B[0]) / np.linalg.norm(B[0])
    assert rel < 1e-4, rel


def test_kfac_refresh_banks_updates_in_place(grid):
    """A later optimizer step changes the Kronecker EMAs;
    refresh_banks re-factorizes every banked factor INTO ITS EXISTING
    SLOT (no rebank, no width change, no retrace of the serving
    program) and the served solves track the new state."""
    import importlib
    kfac = importlib.import_module("repro.optim.kfac_ca")
    from repro import api
    rng = np.random.default_rng(5)
    params = {"w": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
              "stack": jnp.asarray(rng.standard_normal((2, 16, 8)),
                                   jnp.float32)}
    opt = kfac.kfac_ca(min_dim=8)
    state = opt.init(params)
    grads = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    _, state, _ = opt.update(grads, state, params)
    banks, manifest = kfac.factor_banks_from_state(state, grid=grid)
    solver = api.Solver.from_bank(banks[16]).warmup(4)
    key = solver.spec_for(4)
    traces = session.TRACE_COUNTS[key]
    sizes = {d: b.size for d, b in banks.items()}

    grads = jax.tree.map(lambda p: -0.2 * jnp.ones_like(p), params)
    _, state, _ = opt.update(grads, state, params)   # EMAs move
    assert kfac.refresh_banks(banks, manifest, state) is banks
    assert {d: b.size for d, b in banks.items()} == sizes
    assert session.TRACE_COUNTS[key] == traces       # no retrace

    B = rng.standard_normal((3, 16, 4)).astype(np.float32)
    ref = B.copy()
    X = np.asarray(solver.solve(solver.place_rhs(B)), np.float64)
    # every slot now inverts the CURRENT state's damped factor
    for i, (name, side, unit) in enumerate(manifest[16]):
        for nm, sd, M in kfac._iter_kron_factors(state):
            if (nm, sd) == (name, side):
                Mx = M if unit is None else M[unit]
                Lc = np.asarray(kfac._damped_chol(Mx, 1e-3), np.float64)
                rel = np.linalg.norm(Lc @ X[i] - ref[i]) \
                    / np.linalg.norm(ref[i])
                assert rel < 1e-4, (i, rel)
                break


def bank_factor_natural(bank, i):
    """Undo the cyclic distribution of bank factor i (test helper)."""
    return gridlib.cyclic_matrix_device(
        bank.stacks()[0][i], bank.grid.p1, bank.grid.p1 * bank.grid.p2,
        inverse=True)


# ------------------------ batched cyclic gathers ------------------------

def test_stacked_cyclic_gathers_match_per_matrix():
    A = np.random.default_rng(4).standard_normal((3, 16, 16))
    for pr, pc in ((2, 4), (4, 2)):
        stacked = np.asarray(gridlib.cyclic_matrix_device(
            jnp.asarray(A), pr, pc))
        for i in range(3):
            np.testing.assert_array_equal(
                stacked[i], gridlib.to_cyclic_matrix(A[i], pr, pc))
    rows = np.asarray(gridlib.cyclic_rows_device(jnp.asarray(A), 4))
    for i in range(3):
        np.testing.assert_array_equal(rows[i],
                                      gridlib.to_cyclic_rows(A[i], 4))
