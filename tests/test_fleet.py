"""The mixed-order multi-tenant serving tier (DESIGN.md Sec. 12):
the cost-model-driven capacity planner, padded admission bit-identity,
fleet routing / lookup / cross-tenant LRU reclamation, the
zero-transfer/zero-retrace steady state for every precision preset at
several occupancies, the mixed-order SolveServer front end, and the
KFAC fleet hookup."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import cost_model as cm
from repro.core import fleet as fleetlib
from repro.core import session, tuning

PRESET_CASES = [
    ("fp32", np.float32, 1e-4),
    ("bf16", np.float32, 5e-2),
    ("bf16_refine", np.float32, 1e-4),
    ("fp64_refine", np.float64, 1e-10),
]


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def grid():
    return api.make_trsm_mesh(1, 1)


def _tri(d, seed=0, dtype=np.float32, lower=True):
    rng = np.random.default_rng(seed)
    T = np.tril(rng.standard_normal((d, d))) + d * np.eye(d)
    return (T if lower else T.T).astype(dtype)


def _rel(T, x, b):
    x = np.asarray(x, np.float64)
    return np.linalg.norm(T.astype(np.float64) @ x - b) \
        / np.linalg.norm(b)


# ------------------------- the capacity planner -------------------------

def test_plan_fleet_structure_and_routing():
    """Pure cost-model arithmetic on a mesh-less grid: every manifest
    order lands in exactly one bucket, the bucket order is its largest
    member, capacity counts every member factor plus headroom, and the
    routing map resolves planned AND unplanned orders."""
    g = api.plan_grid(2, 2)
    manifest = {16384: 2, 8192: 4, 1024: 8, 512: 16, 256: 32, 128: 32}
    # explicit nominal high-dispatch regime: this test exercises the
    # planner's merge STRUCTURE, which the calibrated default machine
    # (gamma-heavy fit + ~10x smaller measured dispatch_s)
    # legitimately prices out of merging
    plan = api.plan_fleet(manifest, g, k=16, headroom=1,
                          machine=cm.tpu_v5e(), dispatch_s=5e-5)
    covered = {}
    for b in plan.buckets:
        assert b.n == max(b.orders)
        assert b.capacity == sum(b.counts) + 1
        assert b.method in ("inv", "rec")
        assert (b.n0 is None) == (b.method == "rec")
        for d, c in zip(b.orders, b.counts):
            covered[d] = c
    assert covered == manifest
    # the driver of the tentpole: small orders share, so the fleet
    # serves the manifest in fewer buckets than orders
    assert 1 < len(plan.buckets) < len(manifest)
    assert plan.bucket_for(256) is plan.bucket_for(128)
    # an unplanned order routes to the smallest bucket that fits
    assert plan.bucket_for(100).n == plan.bucket_for(128).n
    with pytest.raises(ValueError, match="exceeds every bucket"):
        plan.bucket_for(1 << 20)
    assert "bucket n" in plan.table() and "16384" in plan.table()


def test_plan_fleet_dispatch_budget_is_the_merge_knob():
    """dispatch_s is the planner's only merge knob: a zero budget
    forbids every merge (one bucket per order), a huge budget merges
    everything into the largest order's bucket."""
    g = api.plan_grid(1, 1)
    orders = [512, 256, 128, 64]
    split = api.plan_fleet(orders, g, k=8, dispatch_s=0.0)
    assert len(split.buckets) == len(orders)
    merged = api.plan_fleet(orders, g, k=8, dispatch_s=1e9)
    assert len(merged.buckets) == 1 and merged.buckets[0].n == 512
    assert merged.buckets[0].orders == (512, 256, 128, 64)
    assert merged.buckets[0].capacity == 4
    # an iterable manifest counts duplicates
    dup = api.plan_fleet([64, 64, 64], g, k=8)
    assert dup.buckets[0].counts == (3,)


def test_plan_fleet_validation():
    g = api.plan_grid(1, 1)
    with pytest.raises(ValueError, match="empty"):
        api.plan_fleet({}, g)
    with pytest.raises(ValueError, match=">= 1"):
        api.plan_fleet({64: 0}, g)
    with pytest.raises(ValueError, match=">= 1"):
        api.plan_fleet({0: 3}, g)


def test_tang2024_rec_correction():
    """The planner prices the recursive alternative with the Tang 2024
    bandwidth correction (arXiv:2407.00871): never cheaper than the
    paper's count, strictly costlier in the 2D and 3D regimes, and
    unknown model names are rejected."""
    p = 64
    for n, k in [(1 << 14, 1 << 4), (1 << 14, 1 << 10), (1 << 10, 1)]:
        base = cm.rec_trsm_cost(n, k, p)
        tang = cm.rec_trsm_cost(n, k, p, model="tang2024")
        assert tang.w >= base.w and tang.s == base.s and tang.f == base.f
    # two-large-dimensions regime (n > 4k sqrt(p)): + n^2/sqrt(p) words
    n, k = 1 << 14, 1 << 4
    assert cm.rec_trsm_cost(n, k, p, model="tang2024").w \
        == pytest.approx(cm.rec_trsm_cost(n, k, p).w + n * n / 8.0)
    # three-large-dimensions regime (4k/p <= n <= 4k sqrt(p)): one
    # optimal-size bandwidth term per recursion level, lg(n/k) of them
    n, k = 1 << 14, 1 << 10
    assert cm.rec_trsm_cost(n, k, p, model="tang2024").w \
        == pytest.approx(cm.rec_trsm_cost(n, k, p).w * 4.0)
    with pytest.raises(ValueError, match="model"):
        cm.rec_trsm_cost(64, 4, 4, model="tang2023")
    # and the tuner threads the model through
    g = api.plan_grid(2, 2)
    m, n0, _ = tuning.choose_serving_method(1 << 12, 16, g,
                                            rec_model="tang2024")
    assert m in ("inv", "rec")


# --------------------- padded admission bit-identity ---------------------

@pytest.mark.parametrize("lower,transpose", [
    (True, False), (True, True), (False, False), (False, True)])
def test_padded_admission_bit_identical_leading_block(grid, lower,
                                                      transpose):
    """The satellite-4 contract: admitting an order-d factor into an
    order-n bucket with pad_to=n (blockdiag(T, I) inside the compiled
    updater) solves the leading d x k block BIT-IDENTICALLY to an
    unpadded width-1 bank at the same n0, and the inert tail is exact
    zeros — for all four lower/upper x transpose variants."""
    d, n, k, n0 = 16, 32, 4, 8
    T = _tri(d, seed=d + 2 * lower + transpose, lower=lower)
    B = np.random.default_rng(3).standard_normal((d, k)) \
        .astype(np.float32)

    ref_bank = api.FactorBank(grid, d, n0=n0, capacity=1, lower=lower,
                              transpose=transpose, dtype=np.float32)
    ref_bank.admit(T)
    ref_solver = api.Solver.from_bank(ref_bank)
    Xr = np.asarray(ref_solver.solve(ref_solver.place_rhs(B[None])))[0]

    bucket = api.FactorBank(grid, n, n0=n0, capacity=1, lower=lower,
                            transpose=transpose, dtype=np.float32)
    assert bucket.admit(T, pad_to=n) == 0
    solver = api.Solver.from_bank(bucket)
    Bp = np.zeros((1, n, k), np.float32)
    Bp[0, :d] = B
    Xp = np.asarray(solver.solve(solver.place_rhs(Bp)))[0]

    assert np.array_equal(Xp[:d], Xr), (lower, transpose)
    assert np.array_equal(Xp[d:], np.zeros((n - d, k), np.float32))
    # and the padded replace path refreshes through the same program
    T2 = _tri(d, seed=77, lower=lower)
    bucket.replace(0, T2, pad_to=n)
    ref_bank.replace(0, T2)
    Xr2 = np.asarray(ref_solver.solve(ref_solver.place_rhs(B[None])))[0]
    Xp2 = np.asarray(solver.solve(solver.place_rhs(Bp)))[0]
    assert np.array_equal(Xp2[:d], Xr2)


def test_padded_admission_validation(grid):
    bank = api.FactorBank(grid, 32, n0=8, capacity=2, dtype=np.float32)
    with pytest.raises(ValueError, match="pad_to=16 must equal"):
        bank.admit(_tri(8), pad_to=16)
    with pytest.raises(ValueError, match="1 <= d <= 32"):
        bank.admit(np.zeros((40, 40), np.float32), pad_to=32)
    legacy = api.FactorBank(grid, 32, n0=8, dtype=np.float32)
    with pytest.raises(ValueError, match="capacity-allocated"):
        legacy.admit(_tri(16), pad_to=32)
    # pad_to == n with a full-order factor is a plain admission
    assert bank.admit(_tri(32), pad_to=32) == 0
    assert bank.update_spec(pad_from=16) != bank.update_spec()


# ----------------------- routing, LRU, staleness -----------------------

def _mini_fleet(grid, precision="fp32", k=4):
    plan = api.plan_fleet({32: 2, 16: 2}, grid, k=k,
                          precision=precision)
    assert len(plan.buckets) == 1      # tiny orders always merge
    assert plan.buckets[0].capacity == 4
    return api.SolverFleet(grid, plan)


def test_fleet_admit_lookup_and_stats(grid):
    fleet = _mini_fleet(grid)
    dt = np.float32
    ha = fleet.admit(_tri(16, seed=1, dtype=dt), tenant="a", tag="l0")
    hb = fleet.admit(_tri(32, seed=2, dtype=dt), tenant="b", tag="l0")
    ha2 = fleet.admit(_tri(16, seed=3, dtype=dt), tenant="a", tag="l1")
    assert (ha.slot, hb.slot, ha2.slot) == (0, 1, 2)
    assert ha.bucket == hb.bucket == (32, fleet.plan.buckets[0].policy)
    assert (ha.order, hb.order) == (16, 32)
    assert fleet.lookup("a", order=16, tag="l0") is ha
    assert fleet.lookup("b", order=32) is hb
    with pytest.raises(ValueError, match="ambiguous"):
        fleet.lookup("a", order=16)    # two live order-16 handles
    with pytest.raises(KeyError, match="no live factor"):
        fleet.lookup("a", order=8)
    assert fleet.handles("a") == (ha, ha2)
    assert len(fleet.handles()) == 3
    st = fleet.stats()
    assert st["admits"] == 3 and st["reclaims"] == 0
    assert st["lookup_hits"] == 2 and st["lookup_misses"] == 1
    bkey = fleet.buckets[0]
    assert st["buckets"][bkey]["occupancy"] == 3
    assert st["buckets"][bkey]["capacity"] == 4
    assert "hit_rate" in st and "fleet:" in fleet.format_stats()


def test_fleet_cross_tenant_lru_reclaim_and_stale_handles(grid):
    """A full bucket reclaims the least-recently-used live slot ACROSS
    tenants; the victim's handle goes stale (generation bumped) and
    every fleet operation through it raises instead of serving the new
    occupant."""
    fleet = _mini_fleet(grid)
    hs = [fleet.admit(_tri(16, seed=i, dtype=np.float32),
                      tenant=t, tag=i)
          for i, t in enumerate(["a", "a", "b", "b"])]
    # touch everything but hs[1] -> hs[1] is the coldest
    fleet.lookup("a", tag=0)
    fleet.lookup("b", tag=2)
    fleet.lookup("b", tag=3)
    h_new = fleet.admit(_tri(16, seed=9, dtype=np.float32),
                        tenant="c", tag="hot")
    assert h_new.slot == hs[1].slot == 1   # the victim's slot, re-used
    assert h_new.generation == hs[1].generation + 1
    assert fleet.reclaims == 1
    assert hs[1] not in fleet.handles()
    with pytest.raises(KeyError, match="stale handle"):
        fleet.replace(hs[1], _tri(16, dtype=np.float32))
    with pytest.raises(KeyError, match="stale handle"):
        fleet.evict(hs[1])
    with pytest.raises(KeyError, match="no live factor"):
        fleet.lookup("a", tag=1)           # victim gone from the index
    # explicit evict frees the slot without a reclaim
    fleet.evict(hs[0])
    assert fleet.bucket(hs[0].bucket).bank.size == 3
    h_back = fleet.admit(_tri(16, seed=10, dtype=np.float32),
                         tenant="a", tag=0)
    assert h_back.slot == hs[0].slot and fleet.reclaims == 1


def test_fleet_replace_rejects_order_change(grid):
    fleet = _mini_fleet(grid)
    h = fleet.admit(_tri(16, dtype=np.float32), tenant="a")
    with pytest.raises(ValueError, match="order 32 != admitted"):
        fleet.replace(h, _tri(32, dtype=np.float32))


# ------------------- the steady state (acceptance bar) -------------------

@pytest.mark.parametrize("occupancy", [1, 2, 4])
@pytest.mark.parametrize("precision,in_dt,rtol", PRESET_CASES)
def test_fleet_steady_state_zero_transfers_zero_retraces(
        grid, occupancy, precision, in_dt, rtol):
    """The tentpole invariant: mixed-order routing, in-place refresh,
    and cross-tenant LRU reclamation perform zero host<->device
    transfers and zero retraces — for every precision preset, at
    occupancies 1, C/2, and C."""
    k, n_b = 4, 32
    fleet = _mini_fleet(grid, precision=precision, k=k).warmup(k)
    bkey = fleet.buckets[0]
    bank, solver = fleet.bucket(bkey).bank, fleet.solver(bkey)
    C = bank.capacity

    orders = [16, 32, 16, 32][:occupancy]
    tenants = ["a", "b", "a", "b"][:occupancy]
    Ls = [_tri(d, seed=10 + i, dtype=in_dt)
          for i, d in enumerate(orders)]
    hs = [fleet.admit(L, tenant=t, tag=i)
          for i, (L, t) in enumerate(zip(Ls, tenants))]
    live = {h.slot: (L, h.order) for h, L in zip(hs, Ls)}

    # everything the steady state consumes is placed BEFORE the guard
    fresh = [_tri(orders[0], seed=50, dtype=in_dt),
             _tri(orders[-1], seed=51, dtype=in_dt)]
    placed = [fleet.place_factor(L) for L in fresh]
    rng = np.random.default_rng(occupancy)
    Bs = [solver.place_rhs(
        rng.standard_normal((C, n_b, k)).astype(in_dt))
        for _ in range(3)]
    refs = [np.asarray(b) for b in Bs]

    skey = solver.spec_for(k)
    uspecs = [bank.update_spec(pad_from=16 if d < n_b else None)
              for d in sorted(set(orders))]
    traces = [session.TRACE_COUNTS[s] for s in (skey, *uspecs)]

    outs = []
    with jax.transfer_guard("disallow"):
        outs.append((solver.solve(Bs[0]), dict(live)))      # routing
        fleet.replace(hs[0], placed[0])                     # refresh
        live[hs[0].slot] = (fresh[0], hs[0].order)
        outs.append((solver.solve(Bs[1]), dict(live)))
        h_new = fleet.admit(placed[1], tenant="c")          # turnover
        if occupancy == C:                                  # ...reclaims
            victim = hs[1]          # coldest: admitted 2nd, never touched
            assert h_new.slot == victim.slot
            assert fleet.reclaims == 1
        else:
            assert fleet.reclaims == 0
        live[h_new.slot] = (fresh[1], h_new.order)
        outs.append((solver.solve(Bs[2]), dict(live)))
    assert [session.TRACE_COUNTS[s] for s in (skey, *uspecs)] == traces

    if occupancy == C:
        with pytest.raises(KeyError, match="stale handle"):
            fleet.replace(hs[1], placed[1])
    # every live lane solves ITS factor: the leading d x k block of a
    # padded lane is the order-d solution of the leading d rows
    for (X, live_then), ref in zip(outs, refs):
        X = np.asarray(X)
        for slot, (L, d) in live_then.items():
            assert _rel(np.asarray(L), X[slot][:d], ref[slot][:d]) \
                < rtol, (slot, precision, occupancy)


# ----------------------- mixed-order serving front -----------------------

def test_solve_server_fleet_mode_routes_by_tenant_and_order(grid):
    """SolveServer over a SolverFleet: requests route by
    (tenant, order[, tag]), mixed orders in one submission stream drain
    as one wave per BUCKET (not per order), and results come back
    keyed by (tenant, tag) at the request's TRUE order."""
    fleet = _mini_fleet(grid)
    dt = np.float32
    La = _tri(16, seed=1, dtype=dt)
    Lb = _tri(32, seed=2, dtype=dt)
    Lc = _tri(16, seed=3, dtype=dt)
    fleet.admit(La, tenant="a", tag="l0")
    fleet.admit(Lb, tenant="b", tag="l0")
    fleet.admit(Lc, tenant="c", tag="l0")
    server = api.SolveServer(fleet, panel_k=8).warmup()

    rng = np.random.default_rng(4)
    ba = rng.standard_normal((16, 2)).astype(dt)
    bb = rng.standard_normal((32, 3)).astype(dt)
    bc = rng.standard_normal((16,)).astype(dt)      # 1-D lifts to (d, 1)
    server.submit(ba, tenant="a", tag="l0")
    server.submit(bb, tenant="b", tag="l0")
    server.submit(bc, tenant="c", tag="l0")
    assert server.pending() == 3
    outs = server.drain()
    assert server.pending() == 0
    assert set(outs) == {("a", "l0"), ("b", "l0"), ("c", "l0")}
    assert outs[("a", "l0")][0].shape == (16, 2)
    assert outs[("b", "l0")][0].shape == (32, 3)
    assert outs[("c", "l0")][0].shape == (16, 1)
    assert _rel(La, outs[("a", "l0")][0], ba) < 1e-4
    assert _rel(Lb, outs[("b", "l0")][0], bb) < 1e-4
    assert _rel(Lc, outs[("c", "l0")][0], bc[:, None]) < 1e-4
    # one bucket -> the three mixed-order requests drained in ONE wave
    assert server.waves_solved == 1 and server.requests_served == 3

    with pytest.raises(KeyError, match="no live factor"):
        server.submit(ba, tenant="zz")
    with pytest.raises(ValueError, match="fleet"):
        server.cancel(0)
    plain = api.SolveServer(
        api.Solver.from_bank(fleet.bucket(fleet.buckets[0]).bank), 8)
    with pytest.raises(ValueError, match="fleet"):
        plain.submit(np.zeros((32, 1), dt), tenant="a")


# ----------------------------- KFAC hookup -----------------------------

def test_kfac_fleet_retarget_and_refresh(grid):
    """factor_banks_from_state(fleet=True) banks the whole mixed-order
    Kronecker spectrum in the fleet's planned buckets; refresh_banks
    retargets the in-place churn path at the fleet handles."""
    import importlib
    kfac = importlib.import_module("repro.optim.kfac_ca")
    rng = np.random.default_rng(7)
    params = {"w": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
              "stack": jnp.asarray(rng.standard_normal((2, 16, 8)),
                                   jnp.float32)}
    opt = kfac.kfac_ca(min_dim=8)
    state = opt.init(params)
    grads = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    _, state, _ = opt.update(grads, state, params)

    plan = kfac.fleet_plan_from_state(state, grid, k=4)
    assert {d for b in plan.buckets for d in b.orders} == {16, 8}
    fleet, handles = kfac.factor_banks_from_state(state, grid=grid,
                                                  fleet=True)
    assert isinstance(fleet, api.SolverFleet)
    # one handle per (param, side, unit): w is 2D (unit None), stack
    # contributes 2 units per side
    assert len(handles) == 6
    assert {(side, unit) for _, side, unit in handles} \
        == {("A", None), ("B", None), ("A", 0), ("A", 1),
            ("B", 0), ("B", 1)}
    assert {h.order for h in handles.values()} == {16, 8}

    grads = jax.tree.map(lambda p: -0.2 * jnp.ones_like(p), params)
    _, state, _ = opt.update(grads, state, params)
    assert kfac.refresh_banks(fleet, handles, state) is fleet

    # each handle now serves the CURRENT state's damped factor
    # (spot-check the 2D param's A side — the only 2D/A entry)
    nm_w, _, M_w = next((nm, sd, M) for nm, sd, M
                        in kfac._iter_kron_factors(state)
                        if M.ndim == 2 and sd == "A")
    h = handles[(nm_w, "A", None)]
    solver = fleet.solver(h.bucket)
    C, n_b = solver.width, h.bucket[0]
    B = np.zeros((C, n_b, 4), np.float32)
    B[h.slot, :h.order] = rng.standard_normal((h.order, 4))
    ref = B.copy()
    X = np.asarray(solver.solve(solver.place_rhs(B)), np.float64)
    Lc = np.asarray(kfac._damped_chol(M_w, 1e-3), np.float64)
    rel = np.linalg.norm(
        Lc @ X[h.slot][:h.order] - ref[h.slot][:h.order]) \
        / np.linalg.norm(ref[h.slot][:h.order])
    assert rel < 1e-4, rel
    with pytest.raises(TypeError, match="fleet"):
        kfac.factor_banks_from_state(state, grid=grid, fleet="yes")
