"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against
the ref.py pure-jnp oracles (kernels execute under interpret=True on
CPU — the exact TPU program body, run in Python)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref
from repro.kernels.trmm import trmm
from repro.kernels.tri_inv_block import tri_inv_blocks
from repro.kernels.trsm_block import trsm_substitution


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def rand_tril(rng, n, dtype, batch=None):
    shape = (n, n) if batch is None else (batch, n, n)
    L = np.tril(rng.standard_normal(shape))
    L = L + n * np.broadcast_to(np.eye(n), shape)
    return jnp.asarray(L, dtype=dtype)


# ------------------------------ trmm ------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,k,bt,bn", [
    (128, 128, 128, 128),
    (256, 128, 128, 128),
    (256, 256, 64, 128),
    (64, 32, 32, 32),
    (128, 384, 64, 128),
    (512, 64, 128, 64),
])
def test_trmm_matches_ref(n, k, bt, bn, dtype):
    rng = np.random.default_rng(n + k)
    L = rand_tril(rng, n, dtype)
    X = jnp.asarray(rng.standard_normal((n, k)), dtype=dtype)
    got = trmm(L, X, bt=bt, bn=bn, interpret=True)
    want = ref.trmm_ref(L.astype(jnp.float32), X.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **_tol(dtype))


def test_trmm_ignores_upper_triangle():
    """Tiles above the diagonal must never contribute, even if nonzero."""
    rng = np.random.default_rng(0)
    n, k = 128, 64
    Lfull = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    X = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
    got = trmm(Lfull, X, bt=32, bn=32, interpret=True)
    want = ref.trmm_ref(Lfull, X)   # ref applies tril
    # diagonal tiles are loaded as-is: zero the intra-tile upper part
    Ltl = jnp.tril(Lfull)
    got2 = trmm(Ltl, X, bt=32, bn=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------- tri_inv_block ---------------------------

@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("m,n0", [(1, 8), (4, 16), (8, 32), (2, 64),
                                  (16, 4), (3, 128), (1, 256)])
def test_tri_inv_blocks_matches_ref(m, n0, dtype):
    rng = np.random.default_rng(m * n0)
    Ls = rand_tril(rng, n0, dtype, batch=m)
    got = tri_inv_blocks(Ls, interpret=True)
    want = ref.tri_inv_blocks_ref(Ls)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # L L^-1 == I, the stronger invariant
    prod = np.einsum("bij,bjk->bik", np.asarray(got), np.asarray(Ls))
    np.testing.assert_allclose(prod, np.broadcast_to(np.eye(n0), prod.shape),
                               atol=1e-4)


@given(m=st.sampled_from([1, 2, 4]), n0=st.sampled_from([4, 8, 16, 32]),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=15, deadline=None)
def test_tri_inv_blocks_property(m, n0, seed):
    rng = np.random.default_rng(seed)
    Ls = rand_tril(rng, n0, jnp.float32, batch=m)
    got = tri_inv_blocks(Ls, interpret=True)
    prod = np.einsum("bij,bjk->bik", np.asarray(got), np.asarray(Ls))
    np.testing.assert_allclose(prod, np.broadcast_to(np.eye(n0), prod.shape),
                               atol=1e-3)


# ---------------------------- trsm_block ----------------------------

@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("m,n0,k,bn", [(1, 32, 64, 64), (4, 16, 32, 32),
                                       (2, 64, 128, 64), (1, 128, 128, 128)])
def test_trsm_substitution_matches_ref(m, n0, k, bn, dtype):
    rng = np.random.default_rng(n0 * k)
    Ls = rand_tril(rng, n0, dtype, batch=m)
    Bs = jnp.asarray(rng.standard_normal((m, n0, k)), dtype=dtype)
    got = trsm_substitution(Ls, Bs, bn=bn, interpret=True)
    want = jax.vmap(ref.trsm_ref)(Ls, Bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_trsm_substitution_unbatched():
    rng = np.random.default_rng(3)
    L = rand_tril(rng, 32, jnp.float32)
    B = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
    got = trsm_substitution(L, B, bn=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.trsm_ref(L, B)),
                               rtol=1e-4, atol=1e-4)


# ------------------------- kernel <-> solver hook -------------------------

def test_block_inv_kernel_hook_in_local_solver():
    """The Pallas batched inverter plugs into it_inv_trsm_local."""
    from repro.core import blocked
    rng = np.random.default_rng(7)
    n, k, n0 = 64, 16, 16
    L = rand_tril(rng, n, jnp.float32)
    B = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
    X = blocked.it_inv_trsm_local(L, B, n0, block_inv=ops.block_inv_kernel)
    want = ref.trsm_ref(L, B)
    np.testing.assert_allclose(np.asarray(X), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
