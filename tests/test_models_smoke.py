"""Per-architecture smoke tests: reduced configs of the same family,
one forward + one train-grad step on CPU, asserting shapes and no NaNs.
Plus decode-vs-prefill consistency (KV caches, recurrent states) and
chunked-vs-full equivalences for the memory-bounded paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import ARCH_IDS
from repro.models import layers as L, lm, whisper


def synth_batch(cfg, batch=2, seq=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq))),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)))}
    if cfg.embed_inputs:
        b["embeds"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_model)), jnp.float32)
        del b["tokens"]
    if cfg.enc_dec:
        b["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.enc_frames, cfg.d_model)),
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.key(0)
    batch, seq = 2, 32
    data = synth_batch(cfg, batch, seq)

    if cfg.enc_dec:
        params = whisper.init(cfg, key)
        loss, grads = jax.value_and_grad(
            lambda p: whisper.loss_fn(p, cfg, data))(params)
    else:
        params = lm.init(cfg, key)
        logits, aux = lm.forward(params, cfg, data.get("tokens"),
                                 embeds=data.get("embeds"))
        assert logits.shape == (batch, seq, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        loss, grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, data))(params)

    assert bool(jnp.isfinite(loss)), (arch, loss)
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.isfinite(g).all()) for g in leaves), arch


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "granite-8b", "grok-1-314b",
                                  "arctic-480b", "recurrentgemma-2b",
                                  "xlstm-1.3b", "qwen2-vl-72b"])
def test_decode_matches_forward(arch):
    """Prefill the first S-1 tokens step-by-step, then the decode logits
    for the final position must match the full forward."""
    import dataclasses
    cfg = configs.get_smoke(arch)
    if cfg.n_experts:
        # capacity dropping is batch-composition dependent, so exact
        # decode==forward equivalence needs the no-drop capacity.
        cfg = dataclasses.replace(cfg, moe_capacity=float(cfg.n_experts))
    key = jax.random.key(1)
    params = lm.init(cfg, key)
    B, S = 2, 8
    rng = np.random.default_rng(3)
    if cfg.embed_inputs:
        embeds = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)),
                             jnp.float32)
        full, _ = lm.forward(params, cfg, embeds=embeds,
                             dtype=jnp.float32)
    else:
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
        full, _ = lm.forward(params, cfg, tokens, dtype=jnp.float32)

    cache = lm.init_cache(cfg, B, S, dtype=jnp.float32)
    last = None
    for t in range(S):
        if cfg.embed_inputs:
            last, cache = lm.decode_step(params, cfg, None, cache,
                                         embeds=embeds[:, t:t + 1],
                                         dtype=jnp.float32)
        else:
            last, cache = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                         cache, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_whisper_decode_matches_forward():
    cfg = configs.get_smoke("whisper-tiny")
    params = whisper.init(cfg, jax.random.key(2))
    B, S = 2, 8
    rng = np.random.default_rng(5)
    frames = jnp.asarray(rng.standard_normal((B, cfg.enc_frames,
                                              cfg.d_model)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    enc = whisper.encode(params, cfg, frames, dtype=jnp.float32)
    full, _ = whisper.decode(params, cfg, tokens, enc, dtype=jnp.float32)
    cache = whisper.init_cache(cfg, B, S, dtype=jnp.float32)
    last = None
    for t in range(S):
        last, cache = whisper.decode(params, cfg, tokens[:, t:t + 1], enc,
                                     cache=cache, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_full():
    cfg = configs.get_smoke("granite-8b")
    B, S, H, G, hd = 2, 64, 4, 2, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, G, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, G, hd)), jnp.float32)
    full = L._attend_full(q, k, v, causal=True, window=0)
    chunked = L._attend_chunked(q, k, v, causal=True, window=0,
                                q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=1e-4, atol=1e-4)
    # windowed (local attention) path
    fullw = L._attend_full(q, k, v, causal=True, window=24)
    chunkw = L._attend_chunked(q, k, v, causal=True, window=24,
                               q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(chunkw), np.asarray(fullw),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_chunked_matches_stepwise():
    """The chunkwise-parallel mLSTM must equal token-by-token recurrence."""
    cfg = configs.get_smoke("xlstm-1.3b")
    B, S = 2, 16
    rng = np.random.default_rng(0)
    params = L.init_mlstm(jax.random.key(0), cfg)
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    full, _ = L.mlstm_apply(params, x, cfg)

    cache = L.init_mlstm_cache(cfg, B)
    outs = []
    for t in range(S):
        y, cache = L.mlstm_apply(params, x[:, t:t + 1], cfg, cache=cache)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-3, atol=1e-3)


def test_rglru_scan_matches_stepwise():
    cfg = configs.get_smoke("recurrentgemma-2b")
    B, S = 2, 12
    rng = np.random.default_rng(1)
    params = L.init_rec(jax.random.key(0), cfg)
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    full, _ = L.rec_apply(params, x, cfg)
    cache = L.init_rec_cache(cfg, B)
    cache = {"h": cache["h"], "conv": cache["conv"].astype(jnp.float32)}
    outs = []
    for t in range(S):
        y, cache = L.rec_apply(params, x[:, t:t + 1], cfg, cache=cache)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_int8_kv_cache_decode_close_to_fp():
    """Quantized KV cache: decode logits within quantization tolerance
    of the fp cache path, cache arrays actually int8."""
    cfg = configs.get_smoke("granite-8b")
    params = lm.init(cfg, jax.random.key(1))
    B, S = 2, 12
    rng = np.random.default_rng(4)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    cache_fp = lm.init_cache(cfg, B, S, dtype=jnp.float32)
    cache_q = lm.init_cache(cfg, B, S, dtype=jnp.int8)
    k_leaf = jax.tree.leaves(
        jax.tree.map(lambda a: a.dtype, cache_q))
    assert any(d == jnp.int8 for d in k_leaf)
    last_fp = last_q = None
    for t in range(S):
        last_fp, cache_fp = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                           cache_fp, dtype=jnp.float32)
        last_q, cache_q = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                         cache_q, dtype=jnp.float32)
    lp = jax.nn.log_softmax(np.asarray(last_fp[:, 0], np.float64))
    lq = jax.nn.log_softmax(np.asarray(last_q[:, 0], np.float64))
    assert np.abs(lp - lq).max() < 0.1, np.abs(lp - lq).max()


def test_ring_buffer_windowed_decode():
    """Decoding past a windowed (ring-buffer) cache's capacity must
    match the full-sequence forward with the same attention window."""
    cfg = configs.get_smoke("recurrentgemma-2b")   # window=32
    params = lm.init(cfg, jax.random.key(2))
    B, S = 1, 48                                   # decode past window
    rng = np.random.default_rng(5)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))
    full, _ = lm.forward(params, cfg, tokens, dtype=jnp.float32)
    cache = lm.init_cache(cfg, B, cfg.local_window, dtype=jnp.float32)
    last = None
    for t in range(S):
        last, cache = lm.decode_step(params, cfg, tokens[:, t:t + 1],
                                     cache, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_moe_routes_and_balances():
    cfg = configs.get_smoke("grok-1-314b")
    params = L.init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 64)),
                    jnp.float32)
    y, aux = L.moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) > 0.0


def test_moe_grouped_dispatch(monkeypatch):
    """Token-grouped MoE (bounded dispatch tensor) must behave like the
    single-group path: finite, shape-preserving, and with per-group
    capacity semantics (no silent token loss at generous capacity)."""
    import dataclasses
    from repro.models import layers as LL
    cfg = dataclasses.replace(configs.get_smoke("grok-1-314b"),
                              moe_capacity=8.0)   # generous: no drops
    params = LL.init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 64, 64)),
                    jnp.float32)
    y_one, aux_one = LL.moe_apply(params, x, cfg)      # single group
    monkeypatch.setattr(LL, "MOE_GROUP", 32)           # 4 groups
    y_grp, aux_grp = LL.moe_apply(params, x, cfg)
    assert y_grp.shape == x.shape
    assert bool(jnp.isfinite(y_grp).all())
    # with no capacity drops the grouped result equals the global one
    np.testing.assert_allclose(np.asarray(y_grp), np.asarray(y_one),
                               rtol=1e-4, atol=1e-4)


def test_attention_chunk_boundary():
    """S exactly at/above ATTN_CHUNK flips to the chunked path; logits
    must agree with the full path."""
    from repro.models import layers as LL
    cfg = configs.get_smoke("granite-8b")
    p = LL.init_attn(jax.random.key(0), cfg)
    B = 1
    rng = np.random.default_rng(0)
    pos = jnp.broadcast_to(jnp.arange(2 * LL.ATTN_CHUNK)[None],
                           (B, 2 * LL.ATTN_CHUNK))
    x = jnp.asarray(rng.standard_normal((B, 2 * LL.ATTN_CHUNK,
                                         cfg.d_model)) * 0.1, jnp.float32)
    y_chunked, _ = LL.attn_apply(p, x, cfg, positions=pos)   # S = 2048
    # force the full path by lifting the chunk size
    import unittest.mock as mock
    with mock.patch.object(LL, "ATTN_CHUNK", 1 << 30):
        y_full, _ = LL.attn_apply(p, x, cfg, positions=pos)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_are_plausible():
    """Config-level 6ND bookkeeping sanity: full configs land near the
    published sizes."""
    approx = {
        "qwen3-1.7b": 2.0e9, "granite-8b": 8e9, "smollm-360m": 3.6e8,
        "llama3-405b": 4.05e11, "grok-1-314b": 3.14e11,
        "arctic-480b": 4.8e11, "recurrentgemma-2b": 2.7e9,
        "qwen2-vl-72b": 7.2e10, "xlstm-1.3b": 1.3e9,
    }
    for arch, target in approx.items():
        n = configs.get(arch).param_count
        assert 0.4 * target < n < 2.6 * target, (arch, n, target)


def test_greedy_generate_guards_cache_overflow():
    """prompt + max_new beyond the cache capacity must be a clear
    ValueError, not a silently clamped (corrupted) cache write."""
    from repro.train import serve_step as ss
    cfg = configs.get_smoke("smollm-360m")
    params = lm.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (1, 4)))
    # S + max_new - 1 positions are written: 4 + 3 - 1 = 6 fits exactly
    out = ss.greedy_generate(cfg, params, prompt, max_new=3, max_seq=6)
    assert out.shape == (1, 3)
    with pytest.raises(ValueError, match="max_seq"):
        ss.greedy_generate(cfg, params, prompt, max_new=4, max_seq=6)
