"""Software-pipelined sweep (overlap) + measured-cost calibration
(DESIGN.md Sec. 16): spec normalization and cache-key discipline,
bit-identity of the overlapped sweep per precision preset, the
zero-retrace / zero-transfer steady state with overlap on, the async
comm primitives on degenerate meshes (and their sync compat fallback),
PipelinedCost algebra, and the fit/load calibration layer that the
planners price from.

Multi-device bit-identity (p1=2 grids, degenerate p2=1 / p1=1 axes,
structured sweeps) runs out-of-process in the slow tier:
``repro.core.selfcheck overlap`` via tests/test_core_distributed.py.
"""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro import api, compat
from repro.core import comm, session, tuning
from repro.core import cost_model as cm
from repro.core.solver import SolveSpec, UpdateSpec, _normalize_overlap
from repro.core.structure import FactorStructure

pytestmark = pytest.mark.overlap

PRESET_CASES = [
    (None, np.float64, 1e-10),
    ("fp32", np.float32, 1e-5),
    ("bf16", np.float32, 5e-2),
    ("bf16_refine", np.float32, 1e-5),
    ("fp64_refine", np.float64, 1e-11),
]


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def grid():
    return api.make_trsm_mesh(1, 1)


def _factor(n, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    return L.astype(dtype), rng


# --------------------- spec field normalization ---------------------

def test_overlap_spelling_normalization():
    assert _normalize_overlap("auto") == "on"
    assert _normalize_overlap(True) == "on"
    assert _normalize_overlap("on") == "on"
    assert _normalize_overlap("off") is None
    assert _normalize_overlap(False) is None
    assert _normalize_overlap(None) is None
    with pytest.raises(ValueError, match="overlap"):
        _normalize_overlap("maybe")


def test_spec_normalizes_overlap_like_structure():
    """``overlap="off"`` must be byte-for-byte the pre-overlap spec —
    the same normalize-to-None discipline as structure=dense — so
    committed cache keys and plan hashes are stable across the
    refactor."""
    from repro.core import precision
    kw = dict(n=64, k=8, grid=api.plan_grid(2, 1), n0=16,
              policy=precision.PRESETS["fp32"])
    on = SolveSpec(**kw)                       # default "auto" -> "on"
    assert on.overlap == "on"
    off = SolveSpec(**kw, overlap="off")
    assert off.overlap is None
    assert off == SolveSpec(**kw, overlap=False)
    assert off == SolveSpec(**kw, overlap=None)
    assert hash(off) == hash(SolveSpec(**kw, overlap=None))
    assert off == dataclasses.replace(on, overlap="off")
    assert on != off
    with pytest.raises(ValueError, match="overlap"):
        SolveSpec(**kw, overlap="sometimes")


def test_auto_spec_carries_overlap():
    spec = SolveSpec.auto(64, 8, p=4)
    assert spec.overlap == "on"
    assert SolveSpec.auto(64, 8, p=4, overlap="off").overlap is None


def test_update_spec_overlap_always_none(grid):
    """Admission has no steady-state sweep to pipeline: UpdateSpec
    validates the spelling but always normalizes to None, so admission
    program keys never fork on overlap."""
    bank = api.FactorBank(grid, 32, n0=8, dtype=np.float32)
    L, _ = _factor(32)
    bank.admit(L)
    assert bank.update_spec().overlap is None
    with pytest.raises(ValueError, match="overlap"):
        dataclasses.replace(bank.update_spec(), overlap="banana")


def test_solver_overlap_keys_distinct_programs(grid):
    L, _ = _factor(32)
    s_on = api.Solver.from_factor(L, grid, n0=8, overlap="on")
    s_off = api.Solver.from_factor(L, grid, n0=8, overlap="off")
    assert s_on.spec_for(4).overlap == "on"
    assert s_off.spec_for(4).overlap is None
    assert s_on.spec_for(4) != s_off.spec_for(4)
    # default is auto -> on
    assert api.Solver.from_factor(L, grid, n0=8).spec_for(4).overlap \
        == "on"


# ------------------------- bit-identity -------------------------

@pytest.mark.parametrize("precision,in_dt,rtol", PRESET_CASES)
def test_overlap_bit_identity_per_preset(grid, precision, in_dt, rtol):
    """The pipelined sweep issues the SAME collectives on the same
    operands in a different order: the solve must be byte-equal to the
    sequential sweep for every precision preset, not merely close."""
    n, k = 32, 4
    L, rng = _factor(n, dtype=in_dt)
    B = rng.standard_normal((n, k)).astype(in_dt)
    outs = {}
    for ov in ("on", "off"):
        solver = api.Solver.from_factor(
            L, grid, n0=8, precision=precision,
            dtype=None if precision else in_dt, overlap=ov)
        outs[ov] = np.asarray(solver.solve(B, donate=False))
    assert outs["on"].tobytes() == outs["off"].tobytes()
    rel = (np.linalg.norm(L.astype(np.float64) @ outs["on"] - B)
           / np.linalg.norm(B))
    assert rel < rtol


@pytest.mark.parametrize("method", ["inv", "rec"])
def test_overlap_bit_identity_methods(grid, method):
    n, k = 64, 8
    L, rng = _factor(n, dtype=np.float64)
    B = rng.standard_normal((n, k))
    outs = {}
    for ov in ("on", "off"):
        solver = api.Solver.from_factor(L, grid, method=method, n0=16,
                                        overlap=ov)
        outs[ov] = np.asarray(solver.solve(B, donate=False))
    assert outs["on"].tobytes() == outs["off"].tobytes()


def test_overlap_bit_identity_structured(grid):
    n, k = 64, 8
    st = FactorStructure.banded(16)
    rng = np.random.default_rng(3)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    L *= np.abs(np.arange(n)[:, None] - np.arange(n)[None, :]) < 16
    B = rng.standard_normal((n, k))
    outs = {}
    for ov in ("on", "off"):
        solver = api.Solver.from_factor(L, grid, n0=16, structure=st,
                                        overlap=ov)
        outs[ov] = np.asarray(solver.solve(B, donate=False))
    assert outs["on"].tobytes() == outs["off"].tobytes()


# ------------------ steady state with overlap on ------------------

def test_overlap_on_steady_state_zero_retrace_zero_transfer(grid):
    """The acceptance invariant (DESIGN.md Secs. 10/16) with the
    pipelined sweep: one trace at warmup, then repeated solves move no
    host data and retrace nothing."""
    n, k = 32, 4
    L, rng = _factor(n, dtype=np.float32)
    # a private program cache: the trace-count bump is then exactly
    # this solver's warmup, independent of specs other tests built
    solver = api.Solver.from_factor(L, grid, n0=8, overlap="on",
                                    cache=session.CompiledSolverCache())
    key = solver.program_for(k).key
    assert key.overlap == "on"
    before = session.TRACE_COUNTS[key]
    solver.warmup(k)
    assert session.TRACE_COUNTS[key] == before + 1
    Bs = [solver.place_rhs(rng.standard_normal((n, k)).astype(np.float32))
          for _ in range(3)]
    with jax.transfer_guard("disallow"):
        outs = [solver.solve(b) for b in Bs]
    assert session.TRACE_COUNTS[key] == before + 1
    for x in outs:
        assert np.isfinite(np.asarray(x)).all()


# ---------------- async comm primitives, degenerate mesh ----------------

def test_async_primitives_value_equal_sync_on_degenerate_mesh(grid):
    """p1 = p2 = 1: every axis is a singleton, the hardest degenerate
    case for a start/finish split (gathers are reshapes, permutes are
    identity).  The async pair must return exactly the sync wrapper's
    value."""
    from jax.sharding import PartitionSpec as P

    def sync_body(x):
        g = comm.all_gather(x, "z", axis=0, tiled=True)
        return comm.ppermute(g, "x", [(0, 0)])

    def async_body(x):
        h = comm.all_gather_start(x, "z", axis=0, tiled=True)
        g = comm.all_gather_finish(h)
        hp = comm.ppermute_start(g, "x", [(0, 0)])
        return comm.ppermute_finish(hp)

    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    outs = {}
    for name, body in [("sync", sync_body), ("async", async_body)]:
        f = compat.shard_map(body, mesh=grid.mesh, in_specs=P(),
                             out_specs=P())
        outs[name] = np.asarray(jax.jit(f)(x))
    assert np.array_equal(outs["sync"], outs["async"])
    assert np.array_equal(outs["sync"], x)      # singleton axes: no-op


def test_async_pair_prices_identically_to_sync(grid):
    """The cost is recorded once, at start — a start/finish pair must
    trace to the SAME (s, w, f) as the synchronous wrapper it
    replaces, so overlapped and sequential sweeps report identical
    counts."""
    from jax.sharding import PartitionSpec as P

    def sync_body(x):
        return comm.all_gather(x, "z", axis=0, tiled=False)

    def async_body(x):
        return comm.all_gather_finish(
            comm.all_gather_start(x, "z", axis=0, tiled=False))

    x = jax.ShapeDtypeStruct((4, 4), np.float32)
    costs = {}
    for name, body in [("sync", sync_body), ("async", async_body)]:
        f = compat.shard_map(body, mesh=grid.mesh, in_specs=P(),
                             out_specs=P(None))
        costs[name] = comm.traced_cost(jax.jit(f), x)
    assert costs["sync"].s == costs["async"].s
    assert costs["sync"].w == costs["async"].w
    assert costs["sync"].f == costs["async"].f


def test_compat_fallback_contract():
    """On jax builds with no async collective API (every 0.4.x) the
    compat shims must report so, and the fallback handles must be the
    gathered values themselves (eager issue + identity finish)."""
    has = compat.has_async_collectives()
    assert has == (hasattr(jax.lax, "all_gather_start")
                   and hasattr(jax.lax, "all_gather_finish"))
    if not has:
        # identity-finish: finishing twice is harmless
        from jax.sharding import PartitionSpec as P
        g = api.make_trsm_mesh(1, 1)

        def body(x):
            h = compat.async_all_gather_start(x, "y", axis=0, tiled=True)
            return compat.async_all_gather_finish(
                compat.async_all_gather_finish(h))

        x = np.ones((2, 2), np.float32)
        f = compat.shard_map(body, mesh=g.mesh, in_specs=P(),
                             out_specs=P())
        assert np.array_equal(np.asarray(jax.jit(f)(x)), x)


# ------------------------ PipelinedCost algebra ------------------------

def test_pipelined_cost_counts_invariant_time_max():
    m = cm.tpu_v5e()
    comm_c = cm.Cost(s=4, w=1e6)
    comp_c = cm.Cost(f=5e9)
    p = cm.pipelined(comm_c, comp_c)
    # overlap hides time, not traffic
    assert (p.s, p.w, p.f) == (comm_c.s, comm_c.w, comp_c.f)
    assert p.time(m) == pytest.approx(
        max(comm_c.time(m), comp_c.time(m)))
    assert p.serial().time(m) == pytest.approx(
        comm_c.time(m) + comp_c.time(m))
    assert p.time(m) <= p.serial().time(m)
    # stages concatenate; plain Cost lifts to a serial stage
    q = p + p
    assert q.time(m) == pytest.approx(2 * p.time(m))
    extra = cm.Cost(s=1, w=10, f=10)
    assert (p + extra).time(m) == pytest.approx(
        p.time(m) + extra.time(m))
    assert (extra + p).time(m) == pytest.approx(
        p.time(m) + extra.time(m))
    assert (2 * p).w == pytest.approx(2 * p.w)


def test_steady_cost_overlap_never_slower_in_model():
    m = cm.tpu_v5e()
    for (n, k, n0, p1, p2) in [(4096, 64, 256, 2, 2), (65536, 256, 1024,
                                                       8, 4)]:
        seq = cm.it_inv_trsm_steady_cost(n, k, n0, p1, p2)
        ov = cm.it_inv_trsm_steady_cost(n, k, n0, p1, p2, overlap=True)
        assert isinstance(ov, cm.PipelinedCost)
        assert (ov.s, ov.w, ov.f) == (seq.s, seq.w, seq.f)
        assert ov.time(m) <= seq.time(m)


def test_structured_overlap_cost_scales_both_sides():
    st = FactorStructure.banded(512 // 8)
    dense = cm.it_inv_trsm_steady_cost(512, 16, 64, 2, 1, overlap=True)
    strct = cm.it_inv_trsm_steady_cost(512, 16, 64, 2, 1, structure=st,
                                       overlap=True)
    assert strct.w < dense.w and strct.f < dense.f
    assert strct.time(cm.tpu_v5e()) < dense.time(cm.tpu_v5e())


# -------------------------- calibration --------------------------

def test_fit_calibration_recovers_synthetic_scales():
    base = cm.tpu_v5e()
    truth = cm.Calibration(a=3.0, b=0.5, g=2.0)
    tm = truth.apply(base)
    rng = np.random.default_rng(0)
    rows = []
    for _ in range(12):
        s = float(rng.uniform(10, 1e4))
        w = float(rng.uniform(1e4, 1e8))
        f = float(rng.uniform(1e6, 1e12))
        c = cm.Cost(s=s, w=w, f=f)
        rows.append(dict(s=s, w=w, f=f, measured_s=c.time(tm),
                         predicted_s=c.time(base)))
    cal = cm.fit_calibration(rows, base, dispatch_s=1e-5)
    assert cal.a == pytest.approx(truth.a, rel=1e-6)
    assert cal.b == pytest.approx(truth.b, rel=1e-6)
    assert cal.g == pytest.approx(truth.g, rel=1e-6)
    assert cal.dispatch_s == 1e-5
    calm = cal.apply(base)
    assert calm.name == base.name + "+cal"
    err0 = np.median([abs(r["predicted_s"] - r["measured_s"])
                      / r["measured_s"] for r in rows])
    err1 = np.median([abs(cm.Cost(r["s"], r["w"], r["f"]).time(calm)
                          - r["measured_s"]) / r["measured_s"]
                      for r in rows])
    assert err1 * 2 <= err0


def test_load_calibration_roundtrip(tmp_path):
    # loads are cached per path, so probe missing/corrupt on paths of
    # their own
    assert cm.load_calibration(tmp_path / "absent.json") is None
    p = tmp_path / "BENCH_overlap.json"
    p.write_text(json.dumps(dict(calibration=dict(
        a=1.5, b=0.8, g=1.1, dispatch_s=2e-5))))
    cal = cm.load_calibration(p)
    assert cal == cm.Calibration(a=1.5, b=0.8, g=1.1, dispatch_s=2e-5)
    junk = tmp_path / "junk.json"
    junk.write_text("{not json")
    assert cm.load_calibration(junk) is None       # corrupt -> None


def test_committed_calibration_drives_planners():
    """The committed BENCH_overlap.json must load, and every a-priori
    entry point (default_machine, default_dispatch_s, plan_fleet's
    defaults) must price from it."""
    cal = cm.load_calibration()
    assert cal is not None, (
        "benchmarks/BENCH_overlap.json missing or has no calibration "
        "block: regenerate with `python -m benchmarks.run paper_table`")
    assert cal.a > 0 and cal.b > 0 and cal.g > 0
    assert cal.dispatch_s and cal.dispatch_s > 0
    assert tuning.calibration() == cal
    m = tuning.default_machine()
    base = cm.tpu_v5e()
    assert m.name == base.name + "+cal"
    assert m.alpha == pytest.approx(base.alpha * cal.a)
    assert m.beta == pytest.approx(base.beta * cal.b)
    assert m.gamma == pytest.approx(base.gamma * cal.g)
    assert tuning.default_dispatch_s(123.0) == cal.dispatch_s


def test_calibration_plan_shift_is_the_expected_one():
    """The fitted rescale deliberately moves the latency/bandwidth/
    compute balance; any plan change it induces is pinned HERE, so a
    recalibration that silently flips plans fails loudly instead.
    The committed fit (alpha up ~3 orders on simulated-host timings)
    pushes latency-sensitive regimes toward fewer, larger blocks and
    the rec/inv dispatch toward rec on latency-bound shapes."""
    base = cm.tpu_v5e()
    calm = tuning.default_machine()
    regimes = [(16384, 128, 64), (16384, 512, 256), (4096, 64, 16),
               (256, 65536, 64), (1024, 32, 8)]
    shifts = []
    for (n, k, p) in regimes:
        s_base = tuning.tune(n, k, p, machine=base)
        s_cal = tuning.tune(n, k, p)     # calibrated default
        # every calibrated plan is still feasible
        spec = SolveSpec.auto(n, k, p=p)
        spec.validate()
        if (s_base.n0, s_base.p1, s_base.p2) != \
                (s_cal.n0, s_cal.p1, s_cal.p2):
            shifts.append((n, k, p))
    # the shift set is pinned: update deliberately on recalibration
    assert shifts == PINNED_PLAN_SHIFTS, (
        f"calibration changed auto plans for {shifts}; if intended, "
        f"update PINNED_PLAN_SHIFTS and the DESIGN.md Sec. 16 note")


# concrete (n, k, p) regimes whose SolveSpec.auto plan differs under
# the committed calibration vs nominal constants (empty = the current
# fit shifts rates without crossing any argmin boundary)
PINNED_PLAN_SHIFTS = [(16384, 128, 64), (16384, 512, 256),
                      (4096, 64, 16), (1024, 32, 8)]
