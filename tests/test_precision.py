"""Mixed-precision pipeline: PrecisionPolicy resolution, the on-device
iterative-refinement loop (repro.core.refine), policy-aware cache keys,
and the kernels' explicit accumulate dtypes.

Single-device grid; the multi-device variants of the solve paths run in
repro.core.selfcheck (marked slow)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import grid as gridlib, precision, refine, session


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def grid():
    return gridlib.make_trsm_mesh(1, 1)


def _mats(n=128, k=16, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    B = rng.standard_normal((n, k))
    return L.astype(dtype), B.astype(dtype)


def _relres(L, X, B):
    X = np.asarray(X, np.float64)
    return (np.linalg.norm(L.astype(np.float64) @ X - B)
            / np.linalg.norm(B))


# ----------------------------- the policy -----------------------------

def test_presets_have_expected_roles():
    p = precision.PRESETS["bf16_refine"]
    assert (p.storage, p.compute, p.accumulate, p.residual) == \
        ("bfloat16", "bfloat16", "float32", "float32")
    assert p.refine_steps == 2 and p.refines
    assert p.io_dtype == jnp.dtype("float32")
    # non-refining presets serve at the compute dtype
    assert precision.PRESETS["bf16"].io_dtype == jnp.dtype("bfloat16")
    assert precision.PRESETS["fp32"].io_dtype == jnp.dtype("float32")
    assert precision.PRESETS["fp64_refine"].io_dtype == \
        jnp.dtype("float64")


def test_resolve_accepts_name_policy_dtype():
    p = precision.resolve("bf16_refine")
    assert precision.resolve(p) is p
    legacy = precision.resolve(None, np.float64)
    assert legacy.storage == legacy.residual == "float64"
    assert not legacy.refines
    with pytest.raises(ValueError, match="unknown precision preset"):
        precision.resolve("fp8_dream")
    with pytest.raises(ValueError, match="precision= or dtype="):
        precision.resolve(None, None)
    with pytest.raises(ValueError, match="refine_steps"):
        precision.PrecisionPolicy(name="bad", storage="float32",
                                  compute="float32", accumulate="float32",
                                  residual="float32", refine_steps=-1)


def test_policies_are_distinct_cache_keys(grid):
    cache = session.CompiledSolverCache()
    for prec in ("fp32", "bf16", "bf16_refine"):
        session.get_solver(grid, n=32, k=4, n0=8, precision=prec,
                           cache=cache)
    assert len(cache) == 3 and cache.stats()["misses"] == 3
    # same preset again: a hit, not a rebuild
    session.get_solver(grid, n=32, k=4, n0=8, precision="bf16_refine",
                       cache=cache)
    assert cache.stats()["hits"] == 1
    # the cosmetic name is NOT part of the key: the legacy uniform
    # float32 policy and the "fp32" preset share one compiled program
    assert precision.resolve(None, np.float32) == \
        precision.PRESETS["fp32"]
    session.get_solver(grid, n=32, k=4, n0=8, dtype=np.float32,
                       cache=cache)
    assert cache.stats()["hits"] == 2 and len(cache) == 3


def test_fp64_policy_requires_x64(grid):
    jax.config.update("jax_enable_x64", False)
    try:
        with pytest.raises(ValueError, match="needs float64"):
            session.get_solver(grid, n=32, k=4, n0=8,
                               precision="fp64_refine")
    finally:
        jax.config.update("jax_enable_x64", True)


# ----------------------- the refinement operator -----------------------

@pytest.mark.parametrize("lower,transpose", [(True, False), (False, False),
                                             (True, True), (False, True)])
def test_apply_cyclic_operator_matches_dense(lower, transpose):
    """op(A) @ X reconstructed from the RESIDENT cyclic factor must
    equal the dense product, for every operator reduction variant."""
    n, k, p1, p2 = 32, 5, 2, 2
    rng = np.random.default_rng(4)
    L = np.tril(rng.standard_normal((n, n))) + np.eye(n)
    A = L if lower else L.T
    op = A.T if transpose else A
    X = rng.standard_normal((n, k))
    rev = lower == transpose
    L_cyc = gridlib.cyclic_matrix_device(
        jnp.asarray(A), p1, p1 * p2, reverse_rows=rev, reverse_cols=rev,
        transpose=transpose)
    got = refine.apply_cyclic_operator(L_cyc, jnp.asarray(X),
                                       p1=p1, p2=p2, reverse=rev)
    np.testing.assert_allclose(np.asarray(got), op @ X, atol=1e-10)


@pytest.mark.parametrize("method", ["inv", "rec"])
def test_bf16_refine_recovers_fp32_accuracy(grid, method):
    """The acceptance bar: bf16_refine within 10x of the pure-fp32
    relative residual (same solve, same grid)."""
    L, B = _mats(n=256, k=16)
    X32 = core.trsm(L, B, grid, method=method, n0=32, precision="fp32")
    Xbf = core.trsm(L, B, grid, method=method, n0=32,
                    precision="bf16_refine")
    r32, rbf = _relres(L, X32, B), _relres(L, Xbf, B)
    assert rbf < 10 * r32, (r32, rbf)
    # and the unrefined bf16 sweep really is orders of magnitude worse
    # (i.e. the refinement is doing the work, not the test being loose)
    rraw = _relres(L, core.trsm(L, B, grid, method=method, n0=32,
                                precision="bf16"), B)
    assert rraw > 50 * rbf, (rraw, rbf)


def test_fp64_refine_exceeds_fp32_sweep_accuracy(grid):
    L, B = _mats(n=128, k=8, dtype=np.float64)
    X = core.trsm(L, B, grid, method="inv", n0=32,
                  precision="fp64_refine")
    assert X.dtype == jnp.dtype("float64")
    assert _relres(L, X, B) < 1e-12
    # the fp32 sweep alone cannot reach that
    assert _relres(L, core.trsm(L.astype(np.float32),
                                B.astype(np.float32), grid, method="inv",
                                n0=32, precision="fp32"), B) > 1e-9


def test_refine_steps_monotone(grid):
    """Each unrolled pass tightens the residual until it saturates."""
    L, B = _mats(n=128, k=8)
    res = []
    for steps in (0, 1, 2):
        pol = precision.PrecisionPolicy(
            name=f"bf16_r{steps}", storage="bfloat16", compute="bfloat16",
            accumulate="float32", residual="float32", refine_steps=steps)
        X = core.trsm(L, B, grid, method="inv", n0=32, precision=pol)
        res.append(_relres(L, X, B))
    assert res[1] < res[0] / 10, res
    assert res[2] <= res[1], res


def test_session_serves_refined_dtype_and_residual_copy(grid):
    L, _ = _mats(n=64, k=8)
    sess = core.TrsmSession(L, grid, method="inv", n0=16,
                            precision="bf16_refine")
    assert sess.dtype == jnp.dtype("float32")
    assert sess.factor_cyclic.dtype == jnp.dtype("bfloat16")
    assert sess.factor_cyclic_residual.dtype == jnp.dtype("float32")
    # non-refining session keeps a single resident copy
    sess32 = core.TrsmSession(L, grid, method="inv", n0=16,
                              precision="fp32")
    assert sess32.factor_cyclic_residual is None


def test_request_server_serves_bf16_refine():
    from repro.train import serve_step as ss
    n = 64
    rng = np.random.default_rng(5)
    L = (np.tril(rng.standard_normal((n, n)))
         + n * np.eye(n)).astype(np.float32)
    server = ss.make_trsm_server(L, panel_k=4, n0=16,
                                 precision="bf16_refine")
    reqs = [rng.standard_normal((n, w)).astype(np.float32)
            for w in (1, 3, 2)]
    for r in reqs:
        server.submit(r)
    outs = server.drain()
    for r, x in zip(reqs, outs):
        assert x.dtype == jnp.dtype("float32")
        assert _relres(L, x, r.astype(np.float64)) < 1e-5


# ------------------------ kernel accumulate dtypes ------------------------

def test_trmm_accum_dtype_controls_accuracy():
    from repro.kernels import ops, ref
    rng = np.random.default_rng(9)
    n, k = 256, 128
    L = jnp.asarray(np.tril(rng.standard_normal((n, n))), jnp.bfloat16)
    X = jnp.asarray(rng.standard_normal((n, k)), jnp.bfloat16)
    want = np.asarray(ref.trmm_ref(L.astype(jnp.float32),
                                   X.astype(jnp.float32)))
    got32 = np.asarray(ops.trmm(L, X, accum_dtype=jnp.float32), np.float32)
    gotbf = np.asarray(ops.trmm(L, X, accum_dtype=jnp.bfloat16), np.float32)
    err32 = np.abs(got32 - want).max()
    errbf = np.abs(gotbf - want).max()
    # fp32 accumulation of bf16 operands beats bf16 accumulation
    assert err32 < errbf, (err32, errbf)


def test_tri_inv_blocks_accum_dtype():
    from repro.kernels import ops
    rng = np.random.default_rng(3)
    n0 = 32
    Ls = np.tril(rng.standard_normal((4, n0, n0))) \
        + n0 * np.broadcast_to(np.eye(n0), (4, n0, n0))
    out = ops.tri_inv_blocks(jnp.asarray(Ls, jnp.float32),
                             accum_dtype=jnp.float32)
    prod = np.einsum("bij,bjk->bik", np.asarray(out), Ls)
    np.testing.assert_allclose(
        prod, np.broadcast_to(np.eye(n0), prod.shape), atol=1e-4)


def test_trsm_substitution_accum_dtype():
    from repro.kernels import ops, ref
    rng = np.random.default_rng(6)
    n0, k = 32, 32
    L = np.tril(rng.standard_normal((n0, n0))) + n0 * np.eye(n0)
    B = rng.standard_normal((n0, k))
    got = ops.trsm_substitution(jnp.asarray(L, jnp.float32),
                                jnp.asarray(B, jnp.float32),
                                accum_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.trsm_ref(
                                   jnp.asarray(L, jnp.float32),
                                   jnp.asarray(B, jnp.float32))),
                               rtol=1e-4, atol=1e-4)
